"""Closed-loop SoC simulation: online DFS under a million-request day.

The run-time half of the Vespa workflow.  A 16-tile 4x4 SoC (12 dfmul
accelerator tiles with K=8, each its own frequency island, + MEM/CPU/IO)
serves a ~1M-request diurnal trace three ways:

1. fixed max frequency (the baseline every DFS paper compares against),
2. the Fig.-4 memory-bound policy: stream-bound islands drop their clock,
   a backpressure guard restores them if queues ever build,
3. the PID utilization tracker: rates servo the measured busy fraction.

Expected outcome (asserted): DFS cuts energy/request by >= 10% at matched
p99 latency.  Then the DSE bridge re-ranks static Pareto survivors by
simulated runtime scores — the static sweep and the runtime loop as one
pipeline.

With ``--pipeline`` the example instead builds a replicated-accelerator
pipeline SoC (3 front-end tiles chained into 3 back-end tiles via a
FlowPattern) and serves a hotspot diurnal trace (all external load on one
front-end replica) four ways — fixed, DFS-only, load-balancer-only, and
LB+DFS — asserting the scenario gate: LB+DFS achieves lower
energy/request than either policy alone at matched p99.

With ``--faults`` the pipeline SoC instead faces a *failure*: a back-end
replica dies for 800 ticks straddling the peak of a 2x diurnal surge,
under a 50ms deadline SLO.  Fixed-max without recovery drops the
stranded share (> 5%); respill recovery through the alive-masked
balancer — with or without DFS, and with the online fault detector in
the loop instead of the injected oracle mask — survives at < 1% drops
and a bounded p99 (asserted).

With ``--observe`` the default scenario runs once more at monitoring
level ``full``: the hardware-counter plane (per-tile busy/stall/energy,
per-link flit/utilization), the control-plane decision trace (every DFS
commit, guard trip and fault transition as a schema'd event), and the
Prometheus/JSON metrics export — all while the simulated numbers stay
bit-for-bit identical to the unobserved run (asserted).

    PYTHONPATH=src python examples/closed_loop.py
    PYTHONPATH=src python examples/closed_loop.py --requests 100000 --dse
    PYTHONPATH=src python examples/closed_loop.py --pipeline
    PYTHONPATH=src python examples/closed_loop.py --faults
    PYTHONPATH=src python examples/closed_loop.py --observe
"""
import argparse
from functools import partial

import numpy as np

from repro.configs.vespa_soc import CHSTONE
from repro.core.dfs import PIDRatePolicy, policy_memory_bound
from repro.core.dse import closed_loop_score, grid_sweep
from repro.core.perfmodel import AccelWorkload, SoCPerfModel
from repro.runtime.fault import SimFaultConfig, SimFaultSupervisor
from repro.sim import (ControllerHarness, FaultSchedule, FlowPattern,
                       LoadBalancer, SimConfig, SimEngine, SimPlatform,
                       SLOConfig, Trace, diurnal_trace, with_total)


def build_platform() -> SimPlatform:
    """12 memory-bound dfmul tiles (K=8) fill the 4x4 grid around
    MEM(1,0)/CPU(0,0)/IO(0,3).  At K=8 the compute term is parallelized
    away, so every tile's service time is dominated by its serialized
    NoC/MEM stream path — exactly the Fig.-4 stream-bound regime DFS
    exploits."""
    m = SoCPerfModel()
    pos = [(r, c) for r in range(4) for c in range(4)
           if (r, c) not in {(1, 0), (0, 0), (0, 3)}][:12]
    wls = [AccelWorkload("dfmul", 8.70, 1.1, replication=8) for _ in pos]
    return SimPlatform.build(m, wls, pos, noc_rate=1.0, n_tg=2,
                             req_mb=0.005)


STAGE0 = ("fe0", "fe1", "fe2")
STAGE1 = ("be0", "be1", "be2")


def run_pipeline(ticks: int = 5000, seed: int = 11) -> None:
    """Scenario gate: LB + DFS jointly beat either alone on a replicated
    two-stage accelerator pipeline under a hotspot workload."""
    m = SoCPerfModel()
    pos = [(r, c) for r in range(4) for c in range(4)
           if (r, c) not in {(1, 0), (0, 0), (0, 3)}][:6]
    wls = [AccelWorkload("dfmul", 8.70, 1.1, replication=8) for _ in pos]
    plat = SimPlatform.build(
        m, wls, pos, names=STAGE0 + STAGE1, n_tg=2, req_mb=0.005,
        flows=FlowPattern.chain(STAGE0, STAGE1))
    print(f"pipeline platform: {'+'.join(STAGE0)} -> {'+'.join(STAGE1)} "
          f"-> MEM on 4x4 (completions of a front-end tile feed the "
          f"back-end stage)")

    # hotspot: ALL external load lands on fe0 — the pathological skew a
    # static placement cannot fix and a balancer trivially can
    rng = np.random.default_rng(seed)
    t = np.arange(ticks)
    lam = 13.0 * (1.0 + 0.4 * np.sin(2 * np.pi * t / ticks))
    ext = np.zeros((ticks, 6))
    ext[:, 0] = rng.poisson(lam)
    tr = Trace(ext, 1e-3)
    print(f"trace: {tr.n_requests:,.0f} external requests over "
          f"{tr.duration_s:.1f}s sim, every one addressed to fe0\n")

    cfg = SimConfig(control_interval=25)

    def run(dfs: bool, lb: bool):
        ctl = (ControllerHarness(
            plat.islands, partial(policy_memory_bound, threshold=0.55,
                                  low_rate=0.5), queue_guard_ticks=3.0)
            if dfs else None)
        bal = LoadBalancer((STAGE0, STAGE1), plat.names) if lb else None
        return SimEngine(plat, config=cfg, controller=ctl,
                         balancer=bal).run(tr)

    runs = {"fixed": run(False, False), "dfs-only": run(True, False),
            "lb-only": run(False, True), "lb+dfs": run(True, True)}
    for name, r in runs.items():
        print(f"{name:9s} {r.summary()}")

    both, dfs, lb = runs["lb+dfs"], runs["dfs-only"], runs["lb-only"]
    sv_dfs = 1.0 - both.energy_per_request_j / dfs.energy_per_request_j
    sv_lb = 1.0 - both.energy_per_request_j / lb.energy_per_request_j
    print(f"\nlb+dfs energy/request: {sv_dfs:.1%} below dfs-only "
          f"(hotspot queueing collapse at p99 "
          f"{dfs.p99_latency_s * 1e3:.0f}ms), {sv_lb:.1%} below lb-only "
          f"(full-rate replicas)")

    # the scenario gate: jointly better than either policy alone
    assert both.energy_per_request_j < 0.97 * dfs.energy_per_request_j
    assert both.energy_per_request_j < 0.97 * lb.energy_per_request_j
    assert both.p99_latency_s <= dfs.p99_latency_s
    assert both.p99_latency_s <= max(2.0 * lb.p99_latency_s, 5e-3)
    assert both.completed >= 0.99 * lb.completed
    print("acceptance: lb+dfs < dfs-only and < lb-only energy/request "
          "at matched p99 ✓")


def run_faults(ticks: int = 4000) -> None:
    """Scenario gate: a back-end replica dies for 800 ticks of a 2x
    diurnal surge.  Without recovery the stranded share is dropped;
    respill + alive-masked splits absorb the failure, with or without
    DFS in the loop — and an online detector (never shown the injected
    schedule) finds the kill within a few ticks."""
    m = SoCPerfModel()
    pos = [(r, c) for r in range(4) for c in range(4)
           if (r, c) not in {(1, 0), (0, 0), (0, 3)}][:6]
    wls = [AccelWorkload("dfmul", 8.70, 1.1, replication=8) for _ in pos]
    plat = SimPlatform.build(
        m, wls, pos, names=STAGE0 + STAGE1, n_tg=2, req_mb=0.005,
        flows=FlowPattern.chain(STAGE0, STAGE1))
    cap = SimEngine(plat).capacity_rps()
    stage_cap = float(cap[:3].sum())
    mean = np.zeros(6)
    mean[:3] = 0.45 * stage_cap / 3.0
    tr = diurnal_trace(mean, ticks, 6, dt=1e-3, depth=1.0 / 3.0, seed=11,
                       phase=-np.pi / 2.0)
    ks, ke = int(0.45 * ticks), int(0.65 * ticks)
    sched = FaultSchedule().kill_tile("be1", start=ks, end=ke)
    print(f"pipeline platform: {'+'.join(STAGE0)} -> {'+'.join(STAGE1)}; "
          f"be1 killed on ticks [{ks}, {ke}) — the 2x surge peak")
    print(f"trace: {tr.n_requests:,.0f} requests over {tr.duration_s:.1f}s "
          f"sim, 50ms deadline SLO\n")

    def run(name, *, recover, dfs=False, detect=False):
        slo = (SLOConfig(deadline_s=0.05, on_kill="respill", max_retries=1)
               if recover else
               SLOConfig(deadline_s=0.05, on_kill="drop", max_retries=0))
        ctl = (ControllerHarness(
            plat.islands, partial(policy_memory_bound, threshold=0.55,
                                  low_rate=0.5), queue_guard_ticks=3.0)
            if dfs else None)
        sup = (SimFaultSupervisor(SimFaultConfig(dead_ticks=3))
               if detect else None)
        eng = SimEngine(
            plat, config=SimConfig(control_interval=25), controller=ctl,
            faults=sched, slo=slo, supervisor=sup,
            balancer=LoadBalancer((STAGE0, STAGE1), plat.names,
                                  mode="even"))
        r = eng.run(tr)
        print(f"{name:16s} drop={r.drop_rate:6.2%} "
              f"(slo={r.dropped_slo:,.0f} fault={r.dropped_fault:,.0f}) "
              f"retried={r.retried:,.0f} p99={r.p99_latency_s * 1e3:.1f}ms "
              f"E/req={r.energy_per_request_j * 1e3:.2f}mJ")
        return r, sup

    base, _ = run("fixed,no-rec", recover=False)
    rec, _ = run("fixed,recovery", recover=True)
    dfs_n, _ = run("dfs,no-rec", recover=False, dfs=True)
    dfs_r, _ = run("dfs,recovery", recover=True, dfs=True)
    det, sup = run("dfs,rec+detect", recover=True, dfs=True, detect=True)
    evs = [e for e in sup.events if e["kind"] == "detected_dead"]
    print(f"\nonline detector: kill at tick {ks}, detected at tick "
          f"{evs[0]['tick']} (latency {evs[0]['tick'] - ks} ticks)")

    # the scenario gate: recovery turns a >5% outage into <1% drops at a
    # bounded p99, with and without DFS in the loop
    assert base.drop_rate > 0.05 and dfs_n.drop_rate > 0.05
    assert rec.drop_rate < 0.01 and dfs_r.drop_rate < 0.01
    assert det.drop_rate < 0.01
    assert rec.p99_latency_s <= 0.05 + tr.dt
    assert dfs_r.energy_j < rec.energy_j
    print("acceptance: replica kill mid-surge survives with <1% drops at "
          "bounded p99, DFS still saving energy ✓")


def run_observe(ticks: int = 4000) -> None:
    """Monitoring demo: the default DFS scenario replayed at
    ``observe="full"`` — counters, decision trace and metrics export —
    with the zero-perturbation contract checked on the spot."""
    from repro.sim import Observer, export_metrics

    plat = build_platform()
    cap = SimEngine(plat).capacity_rps()
    tr = diurnal_trace(cap * 0.35, ticks, plat.n_tiles, dt=1e-3,
                       depth=0.5, seed=7)
    ctl = lambda: ControllerHarness(  # noqa: E731 — fresh per run
        plat.islands, partial(policy_memory_bound, threshold=0.55,
                              low_rate=0.5), queue_guard_ticks=3.0)
    cfg = SimConfig(control_interval=25)

    ob = Observer("full")
    res = SimEngine(plat, config=cfg, controller=ctl(),
                    observe=ob).run(tr)
    blind = SimEngine(plat, config=cfg, controller=ctl()).run(tr)
    assert res.p99_latency_s == blind.p99_latency_s
    assert res.energy_j == blind.energy_j
    print("zero-perturbation: observed run == unobserved run, "
          "bit for bit ✓\n")

    cp = ob.counters
    s = cp.summary()
    print(f"counter plane over {s['ticks']:,.0f} ticks: "
          f"{s['invocations']:,.0f} invocations, "
          f"busy {s['busy_frac']:.1%}, stall {s['stall_frac']:.1%}, "
          f"mean link util {s['mean_link_util']:.1%}, "
          f"{s['energy_j']:.1f} J")
    busy = cp.mean_busy()
    top = np.argsort(busy)[::-1][:3]
    for a in top:
        print(f"  {plat.names[a]:>6s}: busy {busy[a]:.1%}, "
              f"stalled {cp.stall_frac()[a]:.1%}, "
              f"eff rate {cp.effective_rate()[a]:.2f}")

    print(f"\ndecision trace ({len(ob.trace)} events): "
          f"{ob.trace.counts()}")
    for ev in ob.trace.events()[:4]:
        print(f"  {ev.tick:>5d} {ev.kind:<12s} {ev.subject}")

    reg = export_metrics(telemetry=res.telemetry, counters=cp,
                         trace=ob.trace)
    text = reg.render_prometheus()
    print(f"\nPrometheus export: {len(reg.names())} families, "
          f"{len(text.splitlines())} lines; e.g.")
    for line in text.splitlines():
        if line.startswith("sim_tile_busy_ticks_total") \
                or line.startswith("sim_trace_events_total"):
            print(f"  {line}")
            break
    print("  ...")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=1_000_000)
    ap.add_argument("--ticks", type=int, default=8_700)
    ap.add_argument("--dt", type=float, default=5e-3)
    ap.add_argument("--dse", action="store_true",
                    help="also re-rank grid_sweep survivors by simulation")
    ap.add_argument("--pipeline", action="store_true",
                    help="run the replicated-accelerator pipeline scenario "
                         "(FlowPattern chain + LoadBalancer + DFS)")
    ap.add_argument("--faults", action="store_true",
                    help="run the fault-injection scenario (replica kill "
                         "mid-surge + SLO deadline + respill recovery)")
    ap.add_argument("--observe", action="store_true",
                    help="run the monitoring demo (counter plane, decision "
                         "trace, Prometheus export, zero-perturbation check)")
    args = ap.parse_args()

    if args.pipeline:
        run_pipeline()
        return
    if args.faults:
        run_faults()
        return
    if args.observe:
        run_observe()
        return

    plat = build_platform()
    eng = SimEngine(plat)
    cap = eng.capacity_rps()
    print(f"platform: {plat.n_tiles} accel tiles on 4x4, "
          f"{cap.sum():,.0f} req/s capacity at max rates")

    trace = with_total(
        diurnal_trace(cap * 0.35, args.ticks, plat.n_tiles, dt=args.dt,
                      depth=0.5, seed=7),
        args.requests)
    print(f"trace: {trace.n_requests:,.0f} requests over "
          f"{trace.duration_s:.0f}s sim (diurnal, mean util "
          f"{trace.offered_rps / cap.sum():.2f})\n")

    cfg = SimConfig(control_interval=25)
    runs = {}
    for name, ctl in [
            ("fixed-max", None),
            ("dfs-membound", ControllerHarness(
                plat.islands,
                partial(policy_memory_bound, threshold=0.55, low_rate=0.5),
                queue_guard_ticks=3.0)),
            ("dfs-pid", ControllerHarness(
                plat.islands, PIDRatePolicy(target=0.7),
                queue_guard_ticks=3.0))]:
        r = SimEngine(plat, config=cfg, controller=ctl).run(trace)
        runs[name] = r
        print(f"{name:14s} {r.summary()}")
        print(f"{'':14s} telemetry: {r.telemetry.summary()}")

    base = runs["fixed-max"]
    print()
    for name in ("dfs-membound", "dfs-pid"):
        r = runs[name]
        saving = 1.0 - r.energy_per_request_j / base.energy_per_request_j
        print(f"{name}: {saving:.1%} energy/request saving, "
              f"p99 {r.p99_latency_s * 1e3:.1f}ms "
              f"vs fixed {base.p99_latency_s * 1e3:.1f}ms, "
              f"{r.swaps} hitless swaps")

    # the acceptance claim: >=10% energy saving at matched p99
    mb = runs["dfs-membound"]
    saving = 1.0 - mb.energy_per_request_j / base.energy_per_request_j
    assert saving >= 0.10, f"energy saving {saving:.1%} < 10%"
    assert mb.p99_latency_s <= max(2.0 * base.p99_latency_s, 5e-3), (
        mb.p99_latency_s, base.p99_latency_s)
    assert mb.completed >= 0.99 * base.completed
    print("\nacceptance: >=10% energy/request saving at matched p99 ✓")

    if args.dse:
        print("\n--- DSE bridge: re-rank static survivors by simulation ---")
        m = plat.model
        wls = [AccelWorkload("dfadd", *CHSTONE["dfadd"]),
               AccelWorkload("dfmul", *CHSTONE["dfmul"])]
        res = grid_sweep(m, wls, ks=(1, 2, 4, 8),
                         acc_rates=(0.2, 0.6, 1.0),
                         noc_rates=(0.5, 1.0), n_tg=2)
        tr = diurnal_trace(3000.0, 2000, 2, dt=1e-3, depth=0.5, seed=9)
        score = closed_loop_score(
            res, tr, model=m, top=6, p99_sla_s=0.02, req_mb=0.002,
            controller_factory=lambda p: ControllerHarness(
                p.islands, PIDRatePolicy(), queue_guard_ticks=3.0))
        print(f"swept {len(res):,} static points; simulated top "
              f"{score.indices.shape[0]} Pareto survivors:")
        for rank, j in enumerate(score.order):
            dp = res.design_point(int(score.indices[j]))
            print(f"  #{rank + 1} K={dp.replication} "
                  f"pos={dp.placement} rates={dp.rates} "
                  f"p99={score.p99_latency_s[j] * 1e3:.1f}ms "
                  f"E/req={score.energy_per_request_j[j] * 1e3:.2f}mJ")
        best = res.design_point(int(score.ranked_indices()[0]))
        print(f"closed-loop winner: K={best.replication} "
              f"pos={best.placement} (static thr {best.throughput:.2f})")


if __name__ == "__main__":
    main()
