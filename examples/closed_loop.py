"""Closed-loop SoC simulation: online DFS under a million-request day.

The run-time half of the Vespa workflow.  A 16-tile 4x4 SoC (12 dfmul
accelerator tiles with K=8, each its own frequency island, + MEM/CPU/IO)
serves a ~1M-request diurnal trace three ways:

1. fixed max frequency (the baseline every DFS paper compares against),
2. the Fig.-4 memory-bound policy: stream-bound islands drop their clock,
   a backpressure guard restores them if queues ever build,
3. the PID utilization tracker: rates servo the measured busy fraction.

Expected outcome (asserted): DFS cuts energy/request by >= 10% at matched
p99 latency.  Then the DSE bridge re-ranks static Pareto survivors by
simulated runtime scores — the static sweep and the runtime loop as one
pipeline.

    PYTHONPATH=src python examples/closed_loop.py
    PYTHONPATH=src python examples/closed_loop.py --requests 100000 --dse
"""
import argparse
from functools import partial

import numpy as np

from repro.configs.vespa_soc import CHSTONE
from repro.core.dfs import PIDRatePolicy, policy_memory_bound
from repro.core.dse import closed_loop_score, grid_sweep
from repro.core.perfmodel import AccelWorkload, SoCPerfModel
from repro.sim import (ControllerHarness, SimConfig, SimEngine, SimPlatform,
                       diurnal_trace, with_total)


def build_platform() -> SimPlatform:
    """12 memory-bound dfmul tiles (K=8) fill the 4x4 grid around
    MEM(1,0)/CPU(0,0)/IO(0,3).  At K=8 the compute term is parallelized
    away, so every tile's service time is dominated by its serialized
    NoC/MEM stream path — exactly the Fig.-4 stream-bound regime DFS
    exploits."""
    m = SoCPerfModel()
    pos = [(r, c) for r in range(4) for c in range(4)
           if (r, c) not in {(1, 0), (0, 0), (0, 3)}][:12]
    wls = [AccelWorkload("dfmul", 8.70, 1.1, replication=8) for _ in pos]
    return SimPlatform.build(m, wls, pos, noc_rate=1.0, n_tg=2,
                             req_mb=0.005)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=1_000_000)
    ap.add_argument("--ticks", type=int, default=8_700)
    ap.add_argument("--dt", type=float, default=5e-3)
    ap.add_argument("--dse", action="store_true",
                    help="also re-rank grid_sweep survivors by simulation")
    args = ap.parse_args()

    plat = build_platform()
    eng = SimEngine(plat)
    cap = eng.capacity_rps()
    print(f"platform: {plat.n_tiles} accel tiles on 4x4, "
          f"{cap.sum():,.0f} req/s capacity at max rates")

    trace = with_total(
        diurnal_trace(cap * 0.35, args.ticks, plat.n_tiles, dt=args.dt,
                      depth=0.5, seed=7),
        args.requests)
    print(f"trace: {trace.n_requests:,.0f} requests over "
          f"{trace.duration_s:.0f}s sim (diurnal, mean util "
          f"{trace.offered_rps / cap.sum():.2f})\n")

    cfg = SimConfig(control_interval=25)
    runs = {}
    for name, ctl in [
            ("fixed-max", None),
            ("dfs-membound", ControllerHarness(
                plat.islands,
                partial(policy_memory_bound, threshold=0.55, low_rate=0.5),
                queue_guard_ticks=3.0)),
            ("dfs-pid", ControllerHarness(
                plat.islands, PIDRatePolicy(target=0.7),
                queue_guard_ticks=3.0))]:
        r = SimEngine(plat, config=cfg, controller=ctl).run(trace)
        runs[name] = r
        print(f"{name:14s} {r.summary()}")
        print(f"{'':14s} telemetry: {r.telemetry.summary()}")

    base = runs["fixed-max"]
    print()
    for name in ("dfs-membound", "dfs-pid"):
        r = runs[name]
        saving = 1.0 - r.energy_per_request_j / base.energy_per_request_j
        print(f"{name}: {saving:.1%} energy/request saving, "
              f"p99 {r.p99_latency_s * 1e3:.1f}ms "
              f"vs fixed {base.p99_latency_s * 1e3:.1f}ms, "
              f"{r.swaps} hitless swaps")

    # the acceptance claim: >=10% energy saving at matched p99
    mb = runs["dfs-membound"]
    saving = 1.0 - mb.energy_per_request_j / base.energy_per_request_j
    assert saving >= 0.10, f"energy saving {saving:.1%} < 10%"
    assert mb.p99_latency_s <= max(2.0 * base.p99_latency_s, 5e-3), (
        mb.p99_latency_s, base.p99_latency_s)
    assert mb.completed >= 0.99 * base.completed
    print("\nacceptance: >=10% energy/request saving at matched p99 ✓")

    if args.dse:
        print("\n--- DSE bridge: re-rank static survivors by simulation ---")
        m = plat.model
        wls = [AccelWorkload("dfadd", *CHSTONE["dfadd"]),
               AccelWorkload("dfmul", *CHSTONE["dfmul"])]
        res = grid_sweep(m, wls, ks=(1, 2, 4, 8),
                         acc_rates=(0.2, 0.6, 1.0),
                         noc_rates=(0.5, 1.0), n_tg=2)
        tr = diurnal_trace(3000.0, 2000, 2, dt=1e-3, depth=0.5, seed=9)
        score = closed_loop_score(
            res, tr, model=m, top=6, p99_sla_s=0.02, req_mb=0.002,
            controller_factory=lambda p: ControllerHarness(
                p.islands, PIDRatePolicy(), queue_guard_ticks=3.0))
        print(f"swept {len(res):,} static points; simulated top "
              f"{score.indices.shape[0]} Pareto survivors:")
        for rank, j in enumerate(score.order):
            dp = res.design_point(int(score.indices[j]))
            print(f"  #{rank + 1} K={dp.replication} "
                  f"pos={dp.placement} rates={dp.rates} "
                  f"p99={score.p99_latency_s[j] * 1e3:.1f}ms "
                  f"E/req={score.energy_per_request_j[j] * 1e3:.2f}mJ")
        best = res.design_point(int(score.ranked_indices()[0]))
        print(f"closed-loop winner: K={best.replication} "
              f"pos={best.placement} (static thr {best.throughput:.2f})")


if __name__ == "__main__":
    main()
