"""End-to-end driver: train a ~100M-param model for a few hundred steps.

Runs the full production loop on CPU: synthetic pipeline -> jitted train
step (remat'd scan) -> AdamW -> async checkpoints -> C3 monitoring -> a DFS
hitless reconfiguration mid-run -> a simulated failure + exact recovery.

    PYTHONPATH=src python examples/train_100m.py --steps 300
(defaults to 60 steps so CI-style runs stay fast; --steps 300 reproduces
the full curve)
"""
import argparse
import dataclasses

import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.dfs import TileTelemetry
from repro.models.layers import AttnOptions
from repro.optim import adamw
from repro.runtime.fault import FaultSupervisor
from repro.runtime.train import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt-dir", default="/tmp/vespa_100m")
    args = ap.parse_args()

    # ~100M-param danube-family config (d=512, 12L, 32k vocab)
    cfg = dataclasses.replace(
        get_config("h2o-danube-1.8b"),
        n_layers=12, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=2048, sliding_window=256)
    n = cfg.n_params()
    print(f"training {n/1e6:.0f}M params for {args.steps} steps")

    shape = ShapeConfig("train", seq_len=256, global_batch=8, kind="train")
    tc = TrainConfig(
        log_every=10, ckpt_every=50, ckpt_dir=args.ckpt_dir, monitor_every=10,
        opt=adamw.AdamWConfig(lr=6e-4, warmup_steps=20,
                              total_steps=args.steps))
    tr = Trainer(cfg, shape, tc=tc,
                 lm_kwargs=dict(opts=AttnOptions(backend="chunked",
                                                 q_block=128, kv_block=128),
                                remat=True))
    sup = FaultSupervisor(tr)

    losses = []
    tr.run(args.steps // 2,
           on_metrics=lambda s, m: losses.append((s, m["loss"])) or
           print(f"  step {s:4d} loss {m['loss']:.4f} lr {m['lr']:.2e}"))

    # mid-run DFS reconfiguration (hitless: swap between steps)
    tel = {t.name: TileTelemetry(1.0, 0, 0, 0, boundness=0.9)
           for t in tr.plan.tiles}
    from repro.core.dfs import policy_memory_bound
    tr.actuator.reconfigure(policy_memory_bound(tr.islands, tel))
    print("DFS: derating memory-bound islands (hitless commit next step)")

    # simulated failure + exact recovery
    if tr.store().latest_step() is not None:
        print("simulating node failure ...")
        tr.params = None
        sup.recover()
        print(f"recovered at step {tr.step}")

    tr.run(args.steps - tr.step,
           on_metrics=lambda s, m: losses.append((s, m["loss"])) or
           print(f"  step {s:4d} loss {m['loss']:.4f}"))

    first = np.mean([l for _, l in losses[:3]])
    last = np.mean([l for _, l in losses[-3:]])
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({'OK' if last < first else 'NOT DECREASING'})")
    print(tr.monitor.table())


if __name__ == "__main__":
    main()
