"""Design-space exploration: the Vespa workflow end to end.

Runs the batched DSE engine over the full design space for a CHStone
accelerator on the paper's 4x4 SoC — replication K x the complete
island-rate ladders x every grid placement — prints the Pareto front and
points/second, cross-checks a few points against the scalar reference
path, then applies the batched DFS energy policy to the chosen design.

    PYTHONPATH=src python examples/dse_sweep.py --accel dfadd

Per-island mode (paper C2 — one independent rate axis per accelerator
island, evaluated chunked/streaming, with the heterogeneous-rate Pareto
point that strictly dominates the best shared-rate point):

    PYTHONPATH=src python examples/dse_sweep.py --independent-islands

Physical-DVFS mode (the V^2 f tech-node model vs the linear proxy —
the two energy landscapes pick different frequencies):

    PYTHONPATH=src python examples/dse_sweep.py --tech-node 16 \\
        --tech-variant cons
"""
import argparse

import numpy as np

from repro.configs.vespa_soc import CHSTONE
from repro.core.dfs import policy_energy_per_token_sweep
from repro.core.dse import grid_sweep, summarize_result
from repro.core.islands import (IslandConfig, IslandSpec, NOC_LADDER,
                                TILE_LADDER)
from repro.core.perfmodel import (NOC_POWER_SHARE, AccelWorkload,
                                  SoCPerfModel, chip_power)
from repro.core.voltage import TECH_NODES, TECH_VARIANTS, TechModel


def independent_islands_demo(n_tg: int, backend: str) -> None:
    """Joint 3-accelerator sweep, shared vs per-island rate axes.

    The shared sweep only explores the diagonal of the rate space; the
    per-island sweep (chunked — the cross-product is ~1e6 points even on
    this small grid) finds off-diagonal points that strictly dominate the
    shared sweep's best energy point: derate the tiny compute-bound
    island, keep the memory-bound streams fast.
    """
    m = SoCPerfModel()
    wls = [AccelWorkload(n, *CHSTONE[n])
           for n in ("dfadd", "dfmul", "dfsin")]
    kw = dict(ks=(1, 2, 4), acc_rates=TILE_LADDER.levels(),
              noc_rates=(0.5, 1.0), tg_rates=(1.0,),
              positions=((1, 1), (3, 3), (0, 2)), n_tg=n_tg,
              backend=backend)
    shared = grid_sweep(m, wls, **kw)
    indep = grid_sweep(m, wls, **kw, island_rates="independent",
                       chunk_points=200_000)
    print(f"shared sweep: {len(shared):,} points "
          f"({shared.points_per_second:,.0f} pts/s)")
    print(f"per-island sweep: {len(indep):,} points in "
          f"{indep.n_chunks} chunks "
          f"({indep.points_per_second:,.0f} pts/s, "
          f"peak chunk {indep.peak_chunk_bytes / 1e6:.0f} MB)")

    spf = shared.pareto_indices()
    best = int(spf[np.argmin(
        shared.objective_values("energy_per_unit", spf))])
    bt, ba, be = (float(shared.objective_values(o, [best])[0])
                  for o in ("throughput", "area", "energy_per_unit"))
    print(f"\nbest shared-rate point: rates={shared.island_rates(best)} "
          f"thr={bt:.2f} area={ba:.3f} E/u={be:.3f}")

    ipf = indep.pareto_indices()
    it, ia, ie = (indep.objective_values(o, ipf)
                  for o in ("throughput", "area", "energy_per_unit"))
    dom = (it >= bt) & (ia <= ba) & (ie <= be) & \
          ((it > bt) | (ia < ba) | (ie < be))
    assert dom.any(), "expected a dominating heterogeneous point"
    j = int(ipf[dom][np.argmin(ie[dom])])
    jt, je = (float(indep.objective_values(o, [j])[0])
              for o in ("throughput", "energy_per_unit"))
    print(f"dominating heterogeneous point: "
          f"rates={indep.island_rates(j)} thr={jt:.2f} "
          f"(+{(jt / bt - 1) * 100:.1f}%) E/u={je:.3f} "
          f"({(je / be - 1) * 100:.1f}%)")
    print(f"\n{int(dom.sum())} per-island Pareto points strictly dominate "
          "the best shared-rate point — the design space the shared-axis "
          "sweep cannot see.")


def tech_demo(node: int, variant: str, n_tg: int, backend: str) -> None:
    """The V^2 f front diverges from the linear proxy's front.

    Sweeps the paper's 3-accelerator 4x4 SoC twice over the same
    frequency grid — once under the legacy linear voltage proxy, once
    under the tech node's physical ``V(f) = Vth + f (Vdd - Vth)`` curve
    — then re-evaluates the linear front under V^2 f.  The physical
    model punishes high frequencies quadratically in voltage, so its
    best point runs some islands slower and strictly beats the linear
    pick once both are priced physically.
    """
    tm = TechModel(node, variant)
    print(f"tech model: {tm}")
    m = SoCPerfModel()
    wls = [AccelWorkload(n, *CHSTONE[n])
           for n in ("dfadd", "dfmul", "dfsin")]
    kw = dict(ks=(2, 4), acc_rates=(0.4, 0.7, 1.0, 1.3),
              noc_rates=(0.5, 1.0), tg_rates=(1.0,),
              positions=((1, 1), (3, 3), (0, 2)), n_tg=n_tg,
              backend=backend, island_rates="independent")
    lin = grid_sweep(m, wls, **kw)
    phys = grid_sweep(m, wls, **kw, tech_node=node, tech_variant=variant)
    # the trailing tech axis has size 1: flat indices line up
    e_phys = phys.energy_per_unit.ravel()

    def front(res):
        pf = res.pareto_indices()
        e = res.objective_values("energy_per_unit", pf)
        return pf[np.argsort(e, kind="stable")]

    f_lin, f_phys = front(lin), front(phys)
    print(f"\n{'':>10} {'linear front':>34} {'V^2f front':>34}")
    for r in range(5):
        li, pi = int(f_lin[r]), int(f_phys[r])
        lr = {k: round(v, 2) for k, v in lin.island_rates(li).items()}
        pr = {k: round(v, 2) for k, v in phys.island_rates(pi).items()}
        print(f"  #{r}  lin:{lr} E_lin={lin.energy_per_unit.ravel()[li]:.3f}"
              f" E_phys={e_phys[li]:.3f} | phys:{pr} E={e_phys[pi]:.3f}")
    best_lin, best_phys = int(f_lin[0]), int(f_phys[0])
    gain = (1 - e_phys[best_phys] / e_phys[best_lin]) * 100
    print(f"\nlinear pick re-scored under V^2 f: {e_phys[best_lin]:.4f} "
          f"W/(MB/s); the physical sweep's pick: "
          f"{e_phys[best_phys]:.4f} W/(MB/s) ({gain:+.1f}% better)")
    assert e_phys[best_phys] <= e_phys[best_lin]
    dl = lin.design_point(best_lin).rates
    dp = phys.design_point(best_phys).rates
    moved = {k: (dl[k], dp[k]) for k in dl if dl[k] != dp[k]}
    print(f"islands the physical model re-frequencies: {moved} — the "
          "linear proxy cannot see the node's voltage curve, so it "
          "overclocks islands the V^2 term says to slow down.")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--accel", default="dfadd", choices=sorted(CHSTONE))
    ap.add_argument("--tg", type=int, default=4,
                    help="active traffic generators")
    ap.add_argument("--backend", default="numpy", choices=("numpy", "jax"))
    ap.add_argument("--independent-islands", action="store_true",
                    help="per-island rate axes (chunked sweep) + the "
                         "heterogeneous-dominance demo")
    ap.add_argument("--tech-node", type=int, default=None,
                    choices=TECH_NODES,
                    help="physical-DVFS demo: V^2 f front vs linear front"
                         " at this process node")
    ap.add_argument("--tech-variant", default="itrs",
                    choices=TECH_VARIANTS)
    args = ap.parse_args()

    if args.tech_node is not None:
        tech_demo(args.tech_node, args.tech_variant, args.tg, args.backend)
        return
    if args.independent_islands:
        independent_islands_demo(args.tg, args.backend)
        return

    base, ai = CHSTONE[args.accel]
    wl = AccelWorkload(args.accel, base, ai)
    model = SoCPerfModel()

    # Full ladders, all placements, K up to 8 — one vectorized sweep.
    res = grid_sweep(
        model, wl, ks=(1, 2, 4, 8),
        acc_rates=TILE_LADDER.levels(), noc_rates=NOC_LADDER.levels(),
        tg_rates=TILE_LADDER.levels(), n_tg=args.tg, backend=args.backend)
    print(f"swept {len(res):,} design points for {args.accel} "
          f"(ai={ai}, {'compute' if wl.compute_bound else 'memory'}-bound) "
          f"in {res.elapsed_s:.3f}s [{args.backend}]")
    print(summarize_result(res))

    # Spot-check the batched engine against the scalar reference path.
    spots = res.topk_indices(3)
    worst = 0.0
    for i in spots:
        dp = res.design_point(int(i))
        k = dp.replication[wl.name]
        s = model.accel_throughput(
            AccelWorkload(wl.name, base, ai, replication=k),
            dp.placement[wl.name], dp.rates, args.tg)
        worst = max(worst, abs(s - dp.throughput) / max(s, 1e-12))
    print(f"\nscalar-path spot check on top-3: max rel err {worst:.2e}")

    best = res.design_point(int(res.topk_indices(1)[0]))
    print(f"chosen design: K={best.replication} rates={best.rates} "
          f"placement={best.placement}")
    print(f"throughput {best.throughput:.2f} MB/s at "
          f"{best.energy_per_unit:.1f} W/(MB/s)")

    # Batched DFS energy policy on the chosen design: all acc x noc rate
    # combinations are evaluated in one vectorized call.
    k = best.replication[wl.name]
    pos = best.placement[wl.name]
    islands = IslandConfig((
        IslandSpec("acc", (wl.name,), TILE_LADDER, 1.0),
        IslandSpec("noc_mem", ("NOC", "MEM"), NOC_LADDER, 1.0)))

    def eval_batch(rates):
        fa, fn = rates["acc"], rates["noc_mem"]
        tps = model.accel_throughput_batch(
            base_mbps=base, wire_share=wl.wire_share, k=k,
            f_acc=fa, f_noc=fn, f_tg=1.0, n_tg=args.tg, pos=pos)
        watts = chip_power(fa, 1.0) + NOC_POWER_SHARE * chip_power(fn, 1.0)
        return tps, np.broadcast_to(watts, np.shape(tps))

    rates = policy_energy_per_token_sweep(islands, eval_batch)
    print(f"DFS energy policy (batched ladder sweep): {rates}")


if __name__ == "__main__":
    main()
