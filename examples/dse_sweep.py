"""Design-space exploration: the Vespa workflow end to end.

Runs the batched DSE engine over the full design space for a CHStone
accelerator on the paper's 4x4 SoC — replication K x the complete
island-rate ladders x every grid placement — prints the Pareto front and
points/second, cross-checks a few points against the scalar reference
path, then applies the batched DFS energy policy to the chosen design.

    PYTHONPATH=src python examples/dse_sweep.py --accel dfadd
"""
import argparse

import numpy as np

from repro.configs.vespa_soc import CHSTONE
from repro.core.dfs import policy_energy_per_token_sweep
from repro.core.dse import grid_sweep, summarize_result
from repro.core.islands import (IslandConfig, IslandSpec, NOC_LADDER,
                                TILE_LADDER)
from repro.core.perfmodel import AccelWorkload, SoCPerfModel, chip_power


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--accel", default="dfadd", choices=sorted(CHSTONE))
    ap.add_argument("--tg", type=int, default=4,
                    help="active traffic generators")
    ap.add_argument("--backend", default="numpy", choices=("numpy", "jax"))
    args = ap.parse_args()

    base, ai = CHSTONE[args.accel]
    wl = AccelWorkload(args.accel, base, ai)
    model = SoCPerfModel()

    # Full ladders, all placements, K up to 8 — one vectorized sweep.
    res = grid_sweep(
        model, wl, ks=(1, 2, 4, 8),
        acc_rates=TILE_LADDER.levels(), noc_rates=NOC_LADDER.levels(),
        tg_rates=TILE_LADDER.levels(), n_tg=args.tg, backend=args.backend)
    print(f"swept {len(res):,} design points for {args.accel} "
          f"(ai={ai}, {'compute' if wl.compute_bound else 'memory'}-bound) "
          f"in {res.elapsed_s:.3f}s [{args.backend}]")
    print(summarize_result(res))

    # Spot-check the batched engine against the scalar reference path.
    spots = res.topk_indices(3)
    worst = 0.0
    for i in spots:
        dp = res.design_point(int(i))
        k = dp.replication[wl.name]
        s = model.accel_throughput(
            AccelWorkload(wl.name, base, ai, replication=k),
            dp.placement[wl.name], dp.rates, args.tg)
        worst = max(worst, abs(s - dp.throughput) / max(s, 1e-12))
    print(f"\nscalar-path spot check on top-3: max rel err {worst:.2e}")

    best = res.design_point(int(res.topk_indices(1)[0]))
    print(f"chosen design: K={best.replication} rates={best.rates} "
          f"placement={best.placement}")
    print(f"throughput {best.throughput:.2f} MB/s at "
          f"{best.energy_per_unit:.1f} W/(MB/s)")

    # Batched DFS energy policy on the chosen design: all acc x noc rate
    # combinations are evaluated in one vectorized call.
    k = best.replication[wl.name]
    pos = best.placement[wl.name]
    islands = IslandConfig((
        IslandSpec("acc", (wl.name,), TILE_LADDER, 1.0),
        IslandSpec("noc_mem", ("NOC", "MEM"), NOC_LADDER, 1.0)))

    def eval_batch(rates):
        fa, fn = rates["acc"], rates["noc_mem"]
        tps = model.accel_throughput_batch(
            base_mbps=base, wire_share=wl.wire_share, k=k,
            f_acc=fa, f_noc=fn, f_tg=1.0, n_tg=args.tg, pos=pos)
        watts = chip_power(fa, 1.0) + 0.3 * chip_power(fn, 1.0)
        return tps, np.broadcast_to(watts, np.shape(tps))

    rates = policy_energy_per_token_sweep(islands, eval_batch)
    print(f"DFS energy policy (batched ladder sweep): {rates}")


if __name__ == "__main__":
    main()
