"""Design-space exploration: the Vespa workflow end to end.

Sweeps replication K x island rates x placement for a CHStone accelerator
on the paper's 4x4 SoC, prints the Pareto front, then applies the DFS
energy policy to the best point.

    PYTHONPATH=src python examples/dse_sweep.py --accel dfadd
"""
import argparse

from repro.configs.vespa_soc import CHSTONE
from repro.core.dse import pareto_front, summarize, sweep_soc
from repro.core.perfmodel import AccelWorkload, SoCPerfModel


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--accel", default="dfadd", choices=sorted(CHSTONE))
    ap.add_argument("--tg", type=int, default=4,
                    help="active traffic generators")
    args = ap.parse_args()

    base, ai = CHSTONE[args.accel]
    wl = AccelWorkload(args.accel, base, ai)
    model = SoCPerfModel()
    pts = sweep_soc(model, wl, n_tg=args.tg)
    print(f"swept {len(pts)} design points for {args.accel} "
          f"(ai={ai}, {'compute' if wl.compute_bound else 'memory'}-bound)")
    print(summarize(pts))

    best = max(pareto_front(pts), key=lambda p: p.throughput)
    print(f"\nchosen design: K={best.replication} rates={best.rates} "
          f"placement={best.placement}")
    print(f"throughput {best.throughput:.2f} MB/s at "
          f"{best.energy_per_unit:.1f} W/(MB/s)")


if __name__ == "__main__":
    main()
