"""Serve a small model with batched requests + RTT monitoring.

Continuous-batching engine over vmap slots; the C3 round-trip-time counter
(dispatch -> first token) is the paper's DMA RTT analogue.

    PYTHONPATH=src python examples/serve_batched.py --requests 12
"""
import argparse

import numpy as np

from repro.configs import get_config
from repro.models.layers import AttnOptions
from repro.runtime.serve import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="musicgen-large")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    eng = ServeEngine(cfg, batch_slots=args.slots, window=128,
                      lm_kwargs=dict(opts=AttnOptions(backend="naive"),
                                     remat=False))
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(
            rid=i, max_new=12,
            prompt=rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)))

    done = eng.run(ticks=80)
    s = eng.stats()
    print(f"completed {int(s['completed'])}/{args.requests} requests, "
          f"{int(s['tokens'])} tokens, {s['tokens_per_tick']:.2f} tok/tick")
    print(f"RTT ticks: mean={s['mean_rtt_ticks']:.1f} "
          f"per-request={[r.rtt for r in done]}")
    print(f"C3 mem.rtt counter: {float(eng.counters['mem']['rtt']):.0f}")


if __name__ == "__main__":
    main()
