"""Quickstart: build any assigned architecture, run forward / prefill /
decode, and inspect the Vespa tile plan + monitoring counters.

    PYTHONPATH=src python examples/quickstart.py --arch gemma-2b
"""
import argparse

import jax
import jax.numpy as jnp

import repro.core as C
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models.layers import AttnOptions
from repro.models.transformer import LM


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=ASSIGNED_ARCHS)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()     # CPU-sized, same family
    print(f"arch={args.arch} family={cfg.family} "
          f"(full model: {get_config(args.arch).n_params()/1e9:.2f}B params)")

    lm = LM(cfg, opts=AttnOptions(backend="naive"), remat=False)
    params = lm.init(jax.random.PRNGKey(0))

    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    logits, aux = lm.forward(params, tokens=toks)
    print(f"forward: logits {logits.shape}, aux={float(aux):.3f}")

    lg, cache = lm.prefill(params, tokens=toks, cache_len=64)
    nxt = jnp.argmax(lg, -1)[:, None]
    lg2, cache = lm.decode_step(params, cache, tokens=nxt)
    print(f"prefill+decode: next tokens {jnp.argmax(lg2, -1).tolist()}")

    # the Vespa view: tiles, islands, counters
    plan = C.default_plan(cfg)
    islands = C.default_islands(plan)
    print("tiles:", [f"{t.name}(K={t.replication},{t.island})"
                     for t in plan.tiles])
    print("islands:", {i.name: i.rate for i in islands.islands})
    ctr = C.init_counters(plan)
    ctr = C.charge_boundary(ctr, "attn", "mem", logits)
    mc = C.MonitorClient()
    mc.read(ctr, step=1)
    print(mc.table())


if __name__ == "__main__":
    main()
