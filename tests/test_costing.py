"""Scan-aware FLOP counter + while-aware HLO collective parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.costing import (collective_stats, flops_of_jaxpr,
                                  hbm_bytes, _split_computations)


def test_dot_flops_exact():
    a = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    jx = jax.make_jaxpr(lambda a, b: a @ b)(a, b)
    assert flops_of_jaxpr(jx.jaxpr) == 2 * 8 * 32 * 16


def test_scan_multiplies_by_trip_count():
    d, L, B = 16, 7, 4
    W = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((B, d), jnp.float32)

    def f(W, x):
        def body(x, w):
            return x @ w, None
        return jax.lax.scan(body, x, W)[0]
    jx = jax.make_jaxpr(f)(W, x)
    assert flops_of_jaxpr(jx.jaxpr) >= 2 * B * d * d * L


def test_remat_grad_counts_recompute():
    d, L, B = 16, 4, 4
    W = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((B, d), jnp.float32)

    def net(W, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        return jnp.sum(jax.lax.scan(jax.checkpoint(body), x, W)[0])

    plain = flops_of_jaxpr(jax.make_jaxpr(net)(W, x).jaxpr)
    grad = flops_of_jaxpr(jax.make_jaxpr(jax.grad(net))(W, x).jaxpr)
    # grad-with-remat ~= fwd + refwd + 2x bwd matmuls ~= 4x fwd dots
    assert grad >= 3.2 * plain


SYNTH_HLO = """
HloModule m

%cond.1 (arg: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body.1 (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %x = f32[8,16] get-tuple-element(%p), index=1
  %ar = f32[8,16]{1,0} all-reduce(%x), replica_groups=[2,4]<=[8], to_apply=%add
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8,16]) tuple(%i, %ar)
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16] parameter(0)
  %init = (s32[], f32[8,16]) tuple(s32[] constant(0), %a)
  %w = (s32[], f32[8,16]) while(%init), condition=%cond.1, body=%body.1
  %ag = f32[64,16]{1,0} all-gather(%a), replica_groups=[1,8]<=[8], dimensions={0}
  ROOT %r = f32[8,16] get-tuple-element(%w), index=1
}
"""


def test_collective_parser_while_multiplier():
    st = collective_stats(SYNTH_HLO, default_group=8)
    # all-reduce inside while: 5 trips x 2*(3/4)*8*16*4B = 5 * 768
    ar = st["per_op_bytes"]["all-reduce"]
    assert ar == pytest.approx(5 * 2 * (3 / 4) * 8 * 16 * 4)
    # all-gather at entry: (7/8) * 64*16*4
    ag = st["per_op_bytes"]["all-gather"]
    assert ag == pytest.approx((7 / 8) * 64 * 16 * 4)


def test_split_computations_finds_entry():
    comps, entry = _split_computations(SYNTH_HLO)
    assert entry == "main"
    assert "body.1" in comps and "cond.1" in comps


def test_hbm_bytes_orders():
    from repro.configs import get_config
    from repro.configs.base import LM_SHAPES
    cfg = get_config("granite-8b")
    train = hbm_bytes(cfg, LM_SHAPES["train_4k"])
    dec = hbm_bytes(cfg, LM_SHAPES["decode_32k"])
    # train moves optimizer state (10B/param); decode sweeps the KV cache
    assert train > 10 * cfg.n_params()
    kv = cfg.n_layers * 2 * cfg.n_kv_heads * cfg.head_dim * 2 * 32768 * 128
    assert dec > kv


def test_mla_cache_compression_visible_in_memory_term():
    """DeepSeek MLA: compressed cache => decode HBM sweep ~7x smaller than
    an equivalent GQA cache would be."""
    from repro.configs import get_config
    from repro.configs.base import LM_SHAPES
    cfg = get_config("deepseek-v2-lite-16b")
    dec = hbm_bytes(cfg, LM_SHAPES["decode_32k"])
    mla_kv = cfg.n_layers * (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2
    gqa_kv = cfg.n_layers * 2 * cfg.n_heads * cfg.head_dim * 2
    assert gqa_kv / mla_kv > 6.5
