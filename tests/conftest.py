import os
import sys

# Tests run with the real (single-CPU-device) platform; ONLY the dry-run
# sets xla_force_host_platform_device_count (per assignment).  Distributed
# tests that need >1 device spawn subprocesses (see test_distributed.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")

import numpy as np  # noqa: E402
import pytest  # noqa: E402


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="run slow soak/scale tests (1M-request replay, B=512 batch)")


def pytest_collection_modifyitems(config, items):
    """`slow` tests are skipped unless opted in; every non-slow test gains
    the `tier1` marker so `-m tier1` names the default fast suite."""
    markexpr = config.getoption("-m") or ""
    run_slow = config.getoption("--runslow") or "slow" in markexpr
    skip_slow = pytest.mark.skip(
        reason="slow soak: opt in with --runslow (or -m slow)")
    for item in items:
        if "slow" in item.keywords:
            if not run_slow:
                item.add_marker(skip_slow)
        else:
            item.add_marker(pytest.mark.tier1)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
