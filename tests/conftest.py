import os
import sys

# Tests run with the real (single-CPU-device) platform; ONLY the dry-run
# sets xla_force_host_platform_device_count (per assignment).  Distributed
# tests that need >1 device spawn subprocesses (see test_distributed.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
