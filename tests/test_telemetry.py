"""Telemetry JSON export round-trips (ISSUE 5 satellite).

A batched telemetry dump serialized with ``to_json`` must reconstruct,
through plain ``json.loads``, exactly the arrays the recorder holds —
and each design's slice of the parsed document must equal the
``design(b)`` view the differential tests compare against (and, at B=1,
the sequential recorder's own export).

Plus the PR 7 satellites: ``_json_safe`` on numpy-laden event payloads,
``weighted_percentiles`` edge cases, and ``RingBuffer`` wraparound with
multi-axis (``(B, width)``) rows.
"""
import json

import numpy as np
import pytest

from repro.sim import (BatchSimEngine, BatchSimPlatform, SimConfig,
                       SimEngine, SimPlatform, Telemetry, diurnal_trace)
from repro.sim.telemetry import (RingBuffer, _json_safe,
                                 weighted_percentiles)
from repro.core.perfmodel import AccelWorkload, SoCPerfModel


def _platforms(n=3):
    m = SoCPerfModel()
    pos = [(r, c) for r in range(4) for c in range(4)
           if (r, c) not in {(1, 0), (0, 0), (0, 3)}][:4]
    wls = [AccelWorkload("dfmul", 8.70, 1.1, replication=8) for _ in pos]
    return [SimPlatform.build(m, wls, pos, noc_rate=r, n_tg=2,
                              req_mb=0.005)
            for r in np.linspace(1.0, 0.6, n)]


def _run_batched(plats, *, capacity=64):
    bplat = BatchSimPlatform.stack(plats)
    eng = BatchSimEngine(bplat, config=SimConfig(
        telemetry_interval=10, telemetry_capacity=capacity))
    cap = SimEngine(plats[0]).capacity_rps()
    tr = diurnal_trace(cap * 0.5, 400, 4, dt=1e-3, depth=0.5, seed=2)
    r = eng.run(tr)
    return r, tr


def test_batch_telemetry_json_roundtrip_per_design_slices():
    plats = _platforms()
    r, tr = _run_batched(plats)
    telem = r.telemetry
    doc = json.loads(telem.to_json())

    # schema survives
    assert doc["schema"]["n_designs"] == len(plats)
    assert tuple(doc["schema"]["tiles"]) == plats[0].names
    assert doc["rows_recorded"] == telem.scalars.total_appended

    # every channel reconstructs exactly (float64 -> repr -> float64 is
    # lossless for json.dumps round-trips)
    for ch in ("island_rates", "queue_depth", "busy"):
        np.testing.assert_array_equal(
            np.asarray(doc[ch]), getattr(telem, ch).array(), err_msg=ch)
    for name, col in doc["scalars"].items():
        np.testing.assert_array_equal(np.asarray(col),
                                      telem.series(name), err_msg=name)

    # per-design slices of the parsed doc == the design(b) views
    for b in range(len(plats)):
        d = telem.design(b)
        for ch in ("island_rates", "queue_depth", "busy"):
            np.testing.assert_array_equal(
                np.asarray(doc[ch])[:, b, :], d[ch], err_msg=(ch, b))
        for name in telem.SCALARS:
            np.testing.assert_array_equal(
                np.asarray(doc["scalars"][name])[:, b],
                d["scalars"][name], err_msg=(name, b))


def test_batch_telemetry_roundtrip_after_ring_wraparound():
    """Once the ring overwrites old rows, the export still reconstructs
    the retained window in chronological order."""
    plats = _platforms(2)
    r, _ = _run_batched(plats, capacity=16)      # 40 intervals > 16 rows
    telem = r.telemetry
    assert telem.scalars.total_appended > telem.scalars.capacity
    doc = json.loads(telem.to_json())
    ticks = np.asarray(doc["scalars"]["tick"])
    assert ticks.shape[0] == 16
    assert np.all(np.diff(ticks[:, 0]) > 0)      # oldest-first
    np.testing.assert_array_equal(np.asarray(doc["queue_depth"]),
                                  telem.queue_depth.array())


def test_batch_b1_export_matches_sequential_export():
    """The B=1 batched dump is (channel for channel) the sequential
    recorder's dump — the telemetry leg of the differential contract."""
    plat = _platforms(1)[0]
    cfg = SimConfig(telemetry_interval=10, telemetry_capacity=64)
    cap = SimEngine(plat).capacity_rps()
    tr = diurnal_trace(cap * 0.5, 300, 4, dt=1e-3, depth=0.5, seed=2)
    seq = SimEngine(plat, config=cfg).run(tr)
    bat = BatchSimEngine(BatchSimPlatform.stack([plat]), config=cfg).run(tr)
    sdoc = json.loads(seq.telemetry.to_json())
    bdoc = json.loads(bat.telemetry.to_json())
    for ch in ("island_rates", "queue_depth", "busy"):
        np.testing.assert_array_equal(np.asarray(bdoc[ch])[:, 0, :],
                                      np.asarray(sdoc[ch]), err_msg=ch)
    for name in Telemetry.SCALARS:
        np.testing.assert_array_equal(
            np.asarray(bdoc["scalars"][name])[:, 0],
            np.asarray(sdoc["scalars"][name]), err_msg=name)
    assert bdoc["rows_recorded"] == sdoc["rows_recorded"]


# ------------------------------------------------------------- _json_safe


def test_json_safe_strips_numpy_leaves():
    """Event payloads carry np scalars/arrays/tuples/sets — every leaf
    must come out as a plain Python value ``json.dumps`` accepts."""
    payload = {
        "rate": np.float64(0.75),
        "count": np.int64(3),
        "flag": np.bool_(True),
        "rates": np.asarray([0.5, 1.0]),
        "grid": np.arange(4).reshape(2, 2),
        "mixed": (np.float32(1.5), [np.int32(2), {"k": np.float64(0.1)}]),
        "names": {"a"},                 # sets become lists
        1: "int key",                   # keys stringify
    }
    safe = _json_safe(payload)
    out = json.loads(json.dumps(safe))  # must not raise
    assert out["rate"] == 0.75 and out["count"] == 3
    assert out["flag"] is True
    assert out["rates"] == [0.5, 1.0]
    assert out["grid"] == [[0, 1], [2, 3]]
    assert out["mixed"] == [1.5, [2, {"k": 0.1}]]
    assert out["names"] == ["a"]
    assert out["1"] == "int key"
    assert type(safe["rate"]) is float and type(safe["count"]) is int


def test_telemetry_event_export_survives_numpy_payloads():
    """``Telemetry.to_json`` must serialize events whose payloads carry
    numpy values (island rate vectors, np.float64 totals)."""
    t = Telemetry.__new__(Telemetry)    # schema-free shell is enough
    t.events = []
    t.events.append({"tick": np.int64(25), "kind": "commit",
                     "rates": np.asarray([0.5, 1.0])})
    doc = _json_safe({"events": t.events})
    back = json.loads(json.dumps(doc))
    assert back["events"][0] == {"tick": 25, "kind": "commit",
                                 "rates": [0.5, 1.0]}


# -------------------------------------------------- weighted_percentiles


def test_weighted_percentiles_zero_weights_and_empty():
    nan3 = weighted_percentiles([], [], (50.0, 99.0))
    assert nan3.shape == (2,) and np.isnan(nan3).all()
    # all-zero weights reduce to the empty sample, not a 0/0
    out = weighted_percentiles([1.0, 2.0], [0.0, 0.0], (50.0,))
    assert np.isnan(out).all()


def test_weighted_percentiles_single_bin_and_extremes():
    out = weighted_percentiles([3.5], [10.0], (0.0, 50.0, 100.0))
    assert (out == 3.5).all()
    # q=0 lands on the smallest value, q=100 on the largest
    v, w = [1.0, 2.0, 3.0], [1.0, 1.0, 1.0]
    lo, hi = weighted_percentiles(v, w, (0.0, 100.0))
    assert lo == 1.0 and hi == 3.0


def test_weighted_percentiles_mass_concentration_and_order():
    """Weights are request counts: a bin holding 99% of the mass owns the
    p50; input order must not matter (stable sort on values)."""
    v = np.asarray([0.010, 0.001, 0.005])
    w = np.asarray([1.0, 98.0, 1.0])
    p50, p99 = weighted_percentiles(v, w, (50.0, 99.0))
    assert p50 == 0.001 and p99 == 0.005
    p50s, p99s = weighted_percentiles(np.sort(v), w[np.argsort(v)],
                                      (50.0, 99.0))
    assert p50 == p50s and p99 == p99s
    # zero-weight bins are dropped before percentile selection
    p99z = weighted_percentiles(np.append(v, 9.9), np.append(w, 0.0),
                                (99.0,))[0]
    assert p99z == 0.005


def test_weighted_percentiles_matches_expanded_sample():
    """Against the brute-force definition: expand each bin into ``w``
    copies and take the rank statistic directly."""
    rng = np.random.default_rng(3)
    v = rng.uniform(0.001, 0.1, size=40)
    w = rng.integers(1, 20, size=40).astype(float)
    expanded = np.sort(np.repeat(v, w.astype(int)))
    for q in (50.0, 90.0, 99.0):
        got = weighted_percentiles(v, w, (q,))[0]
        idx = int(np.ceil(q / 100.0 * expanded.size)) - 1
        assert got == expanded[max(idx, 0)]


# ------------------------------------------------------------- RingBuffer


def test_ringbuffer_multi_axis_rows_wraparound():
    """(B, width) rows — the batched telemetry shape — must wrap exactly
    like scalar-lead rows: retained window, oldest first, each row
    intact."""
    rb = RingBuffer(5, (3, 2))
    assert rb.row_shape == (3, 2) and rb.width == 2
    rows = [np.full((3, 2), float(i)) for i in range(12)]
    for r in rows:
        rb.append(r)
    assert len(rb) == 5 and rb.total_appended == 12
    got = rb.array()
    assert got.shape == (5, 3, 2)
    np.testing.assert_array_equal(got, np.stack(rows[7:]))
    np.testing.assert_array_equal(rb.last(), rows[-1])
    # array() copies out of the ring: mutating the copy can't corrupt it
    got[:] = -1.0
    np.testing.assert_array_equal(rb.array(), np.stack(rows[7:]))


def test_ringbuffer_exact_capacity_boundary():
    rb = RingBuffer(4, (2, 3))
    for i in range(4):
        rb.append(np.full((2, 3), float(i)))
    assert len(rb) == 4
    np.testing.assert_array_equal(rb.array()[:, 0, 0],
                                  np.asarray([0.0, 1.0, 2.0, 3.0]))
    rb.append(np.full((2, 3), 4.0))     # first overwrite
    np.testing.assert_array_equal(rb.array()[:, 0, 0],
                                  np.asarray([1.0, 2.0, 3.0, 4.0]))


def test_ringbuffer_rejects_degenerate_shapes():
    with pytest.raises(AssertionError):
        RingBuffer(0, 3)
    with pytest.raises(AssertionError):
        RingBuffer(4, (2, 0))
