"""Telemetry JSON export round-trips (ISSUE 5 satellite).

A batched telemetry dump serialized with ``to_json`` must reconstruct,
through plain ``json.loads``, exactly the arrays the recorder holds —
and each design's slice of the parsed document must equal the
``design(b)`` view the differential tests compare against (and, at B=1,
the sequential recorder's own export).
"""
import json

import numpy as np

from repro.sim import (BatchSimEngine, BatchSimPlatform, SimConfig,
                       SimEngine, SimPlatform, Telemetry, diurnal_trace)
from repro.core.perfmodel import AccelWorkload, SoCPerfModel


def _platforms(n=3):
    m = SoCPerfModel()
    pos = [(r, c) for r in range(4) for c in range(4)
           if (r, c) not in {(1, 0), (0, 0), (0, 3)}][:4]
    wls = [AccelWorkload("dfmul", 8.70, 1.1, replication=8) for _ in pos]
    return [SimPlatform.build(m, wls, pos, noc_rate=r, n_tg=2,
                              req_mb=0.005)
            for r in np.linspace(1.0, 0.6, n)]


def _run_batched(plats, *, capacity=64):
    bplat = BatchSimPlatform.stack(plats)
    eng = BatchSimEngine(bplat, config=SimConfig(
        telemetry_interval=10, telemetry_capacity=capacity))
    cap = SimEngine(plats[0]).capacity_rps()
    tr = diurnal_trace(cap * 0.5, 400, 4, dt=1e-3, depth=0.5, seed=2)
    r = eng.run(tr)
    return r, tr


def test_batch_telemetry_json_roundtrip_per_design_slices():
    plats = _platforms()
    r, tr = _run_batched(plats)
    telem = r.telemetry
    doc = json.loads(telem.to_json())

    # schema survives
    assert doc["schema"]["n_designs"] == len(plats)
    assert tuple(doc["schema"]["tiles"]) == plats[0].names
    assert doc["rows_recorded"] == telem.scalars.total_appended

    # every channel reconstructs exactly (float64 -> repr -> float64 is
    # lossless for json.dumps round-trips)
    for ch in ("island_rates", "queue_depth", "busy"):
        np.testing.assert_array_equal(
            np.asarray(doc[ch]), getattr(telem, ch).array(), err_msg=ch)
    for name, col in doc["scalars"].items():
        np.testing.assert_array_equal(np.asarray(col),
                                      telem.series(name), err_msg=name)

    # per-design slices of the parsed doc == the design(b) views
    for b in range(len(plats)):
        d = telem.design(b)
        for ch in ("island_rates", "queue_depth", "busy"):
            np.testing.assert_array_equal(
                np.asarray(doc[ch])[:, b, :], d[ch], err_msg=(ch, b))
        for name in telem.SCALARS:
            np.testing.assert_array_equal(
                np.asarray(doc["scalars"][name])[:, b],
                d["scalars"][name], err_msg=(name, b))


def test_batch_telemetry_roundtrip_after_ring_wraparound():
    """Once the ring overwrites old rows, the export still reconstructs
    the retained window in chronological order."""
    plats = _platforms(2)
    r, _ = _run_batched(plats, capacity=16)      # 40 intervals > 16 rows
    telem = r.telemetry
    assert telem.scalars.total_appended > telem.scalars.capacity
    doc = json.loads(telem.to_json())
    ticks = np.asarray(doc["scalars"]["tick"])
    assert ticks.shape[0] == 16
    assert np.all(np.diff(ticks[:, 0]) > 0)      # oldest-first
    np.testing.assert_array_equal(np.asarray(doc["queue_depth"]),
                                  telem.queue_depth.array())


def test_batch_b1_export_matches_sequential_export():
    """The B=1 batched dump is (channel for channel) the sequential
    recorder's dump — the telemetry leg of the differential contract."""
    plat = _platforms(1)[0]
    cfg = SimConfig(telemetry_interval=10, telemetry_capacity=64)
    cap = SimEngine(plat).capacity_rps()
    tr = diurnal_trace(cap * 0.5, 300, 4, dt=1e-3, depth=0.5, seed=2)
    seq = SimEngine(plat, config=cfg).run(tr)
    bat = BatchSimEngine(BatchSimPlatform.stack([plat]), config=cfg).run(tr)
    sdoc = json.loads(seq.telemetry.to_json())
    bdoc = json.loads(bat.telemetry.to_json())
    for ch in ("island_rates", "queue_depth", "busy"):
        np.testing.assert_array_equal(np.asarray(bdoc[ch])[:, 0, :],
                                      np.asarray(sdoc[ch]), err_msg=ch)
    for name in Telemetry.SCALARS:
        np.testing.assert_array_equal(
            np.asarray(bdoc["scalars"][name])[:, 0],
            np.asarray(sdoc["scalars"][name]), err_msg=name)
    assert bdoc["rows_recorded"] == sdoc["rows_recorded"]
