"""Per-arch smoke tests (reduced configs) + decode/prefill consistency."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models.layers import AttnOptions
from repro.models.transformer import LM

KEY = jax.random.PRNGKey(0)


def _lm(cfg, **kw):
    return LM(cfg, opts=AttnOptions(backend="naive"), remat=False, **kw)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = get_config(arch).reduced()
    lm = _lm(cfg)
    params = lm.init(KEY)
    B, S = 2, 32
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    logits, aux = lm.forward(params, tokens=toks)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert not bool(jnp.isnan(logits).any())
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_train_step_no_nan(arch):
    cfg = get_config(arch).reduced()
    lm = LM(cfg, opts=AttnOptions(backend="naive"), remat=True)
    params = lm.init(KEY)
    B, S = 2, 32
    batch = {
        "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
    }
    (loss, parts), grads = jax.value_and_grad(lm.loss_fn, has_aux=True)(
        params, batch)
    assert jnp.isfinite(loss)
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_decode_matches_forward(arch):
    """The serving path must agree with the training forward — exactly."""
    cfg = get_config(arch).reduced()
    lm = _lm(cfg)
    params = jax.tree_util.tree_map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        lm.init(KEY))
    B, S = 2, 33
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full, _ = lm.forward(params, tokens=toks)
    scale = float(jnp.max(jnp.abs(full))) or 1.0
    lg_pref, cache = lm.prefill(params, tokens=toks[:, :S - 1], cache_len=S + 4)
    cache = jax.tree_util.tree_map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        cache)
    assert float(jnp.max(jnp.abs(lg_pref - full[:, S - 2]))) / scale < 1e-4
    lg_dec, cache = lm.decode_step(params, cache, tokens=toks[:, S - 1:S])
    assert float(jnp.max(jnp.abs(lg_dec - full[:, S - 1]))) / scale < 1e-4


def test_sliding_window_ring_buffer_eviction():
    """Danube SWA: decode far past the window must equal windowed forward."""
    cfg = get_config("h2o-danube-1.8b").reduced()      # window = 32
    lm = _lm(cfg)
    params = jax.tree_util.tree_map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        lm.init(KEY))
    B, S = 1, 40                                       # beyond the window
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full, _ = lm.forward(params, tokens=toks)
    _, cache = lm.prefill(params, tokens=toks[:, :S - 1], cache_len=64)
    cache = jax.tree_util.tree_map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        cache)
    lg, _ = lm.decode_step(params, cache, tokens=toks[:, S - 1:S])
    scale = float(jnp.max(jnp.abs(full)))
    assert float(jnp.max(jnp.abs(lg - full[:, S - 1]))) / scale < 1e-4


def test_embeds_input_path():
    """Modality-frontend stub: precomputed embeddings instead of tokens."""
    cfg = get_config("musicgen-large").reduced()
    lm = _lm(cfg)
    params = lm.init(KEY)
    B, S = 2, 16
    emb = jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32) * 0.02
    logits, _ = lm.forward(params, embeds=emb)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


def test_hybrid_shared_tile_param_sharing():
    """Zamba2: one physical shared-attention tile (params not per-layer)."""
    cfg = get_config("zamba2-7b").reduced()
    lm = _lm(cfg)
    params = lm.init(KEY)
    assert "shared_attn" in params
    # blocks are stacked over layers; shared tile has no layer dim
    wq = params["shared_attn"]["attn"]["wq"]
    assert wq.ndim == 2
    ssm_w = params["blocks"]["ssm"]["w_x"]
    assert ssm_w.shape[0] == cfg.n_layers
