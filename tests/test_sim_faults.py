"""Fault injection, SLO semantics, and recovery: differential + scenario tests.

The load-bearing guarantees:

* **compilation** — half-open [start, end) windows, island kills expand to
  their sampled tiles, link degradation hits both directed links, and the
  derived ``island_dead`` mask is exactly "every sampled tile dead",
* **differential parity** — a nonempty :class:`FaultSchedule` (kills +
  link degrade + SLO deadline + retry through a LoadBalancer) replays
  bit-for-bit between the sequential engine and a B=1 batch row (states,
  histories, drop/retry ledgers), and the ``lax.scan`` backend matches
  the NumPy reference within the existing float32 tolerances,
* **invariants** — work conservation *every tick* (offered == served +
  explicit drops + backlog), queue non-negativity through kill/revive
  cycles, and monotone cumulative drop ledgers — seeded sweeps always,
  hypothesis-fuzzed when available,
* **the scenario gate** — a replica kill mid-diurnal-surge on the 3+3
  pipeline: without recovery the stranded share is dropped (> 5%);
  with respill + alive-masked splits the run survives (< 1% drops,
  bounded p99), with or without the DFS controller in the loop,
* **DSE under failure** — ``closed_loop_score(fault_schedule=...)``
  re-ranks survivors relative to the fault-free score, with the batched
  and sequential paths producing identical scores.
"""
import json
from functools import partial

import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.configs.vespa_soc import CHSTONE
from repro.core.dfs import policy_memory_bound
from repro.core.dse import closed_loop_score, grid_sweep
from repro.core.perfmodel import AccelWorkload, SoCPerfModel
from repro.runtime.fault import (OnlineFaultDetector, SimFaultConfig,
                                 SimFaultSupervisor)
from repro.sim import (BatchSimEngine, BatchSimPlatform, ControllerHarness,
                       FaultSchedule, FlowPattern, LoadBalancer, SimConfig,
                       SimEngine, SimPlatform, SLOConfig, Telemetry,
                       compile_faults, diurnal_trace, poisson_trace)
from repro.sim.faults import respill_stranded

STAGE0 = ("fe0", "fe1", "fe2")
STAGE1 = ("be0", "be1", "be2")


# --------------------------------------------------------------- fixtures
def make_platform(n_tiles=6, *, req_mb=0.005, k=8, names=None, flows=None,
                  island_groups=None):
    m = SoCPerfModel()
    pos = [(r, c) for r in range(4) for c in range(4)
           if (r, c) not in {(1, 0), (0, 0), (0, 3)}][:n_tiles]
    wls = [AccelWorkload("dfmul", 8.70, 1.1, replication=k) for _ in pos]
    return SimPlatform.build(m, wls, pos, names=names, n_tg=2,
                             req_mb=req_mb, flows=flows,
                             island_groups=island_groups)


def pipeline_platform():
    return make_platform(6, names=STAGE0 + STAGE1,
                         flows=FlowPattern.chain(STAGE0, STAGE1))


def offered(trace, result):
    """Total externally offered work == completed + drops + backlog."""
    return float(np.asarray(trace.arrivals).sum())


# ------------------------------------------------------------ compilation
def test_compile_faults_windows_and_island_masks():
    plat = make_platform(4)
    names = plat.names
    isl = plat.islands
    sched = (FaultSchedule()
             .kill_tile(names[1], start=10, end=20)
             .kill_island(isl.names()[0], start=15, end=25)
             .degrade_link((1, 1), (1, 2), 0.25, start=5, end=30)
             .stick_island(isl.names()[-1], start=40, rate=0.3))
    cf = compile_faults(sched, ticks=50, names=names, islands=isl,
                        noc=plat.model.noc)
    A = len(names)
    assert cf.tile_alive.shape == (50, A)
    # half-open windows: dead exactly on [10, 20), alive at 9 and 20
    col = 1
    assert cf.tile_alive[9, col] == 1.0 and cf.tile_alive[20, col] == 0.0 \
        if names[1] in isl.islands[0].tiles else True
    assert (cf.tile_alive[10:20, col] == 0.0).all()
    # island kill covers every sampled tile of the island
    tiles0 = [i for i, n in enumerate(names) if n in isl.islands[0].tiles]
    assert (cf.tile_alive[np.ix_(range(15, 25), tiles0)] == 0.0).all()
    # island_dead is "all sampled tiles dead" — true inside the window
    assert cf.island_dead[16, 0]
    assert not cf.island_dead[0].any()
    # link degrade hits both directed links, and only in-window
    assert cf.has_link
    assert (cf.link_scale[5:30] < 1.0).sum(axis=1).max() == 2
    assert (cf.link_scale[0:5] == 1.0).all()
    assert (cf.link_scale[30:] == 1.0).all()
    # stuck tail window runs to the horizon; rate recorded
    assert cf.stuck[40:, -1].all() and not cf.stuck[:40, -1].any()
    assert np.isfinite(cf.stuck_rate[45, -1])
    assert cf.has_stuck and cf.has_stuck_rate
    # events are tick-sorted transitions
    ticks = [e["tick"] for e in cf.events]
    assert ticks == sorted(ticks)
    kinds = {e["kind"] for e in cf.events}
    assert {"fault_kill", "fault_revive", "fault_link_degrade",
            "fault_stuck"} <= kinds


def test_compile_faults_rejects_unknown_names():
    plat = make_platform(3)
    for bad in (FaultSchedule().kill_tile("nope", start=0),
                FaultSchedule().kill_island("nope", start=0),
                FaultSchedule().degrade_link((0, 0), (3, 3), 0.5, start=0)):
        with pytest.raises(AssertionError):
            compile_faults(bad, ticks=10, names=plat.names,
                           islands=plat.islands, noc=plat.model.noc)


def test_slo_config_validation():
    with pytest.raises(AssertionError):
        SLOConfig(on_kill="explode")
    with pytest.raises(AssertionError):
        SLOConfig(max_retries=2)
    with pytest.raises(AssertionError):
        SLOConfig(deadline_s=0.0)
    assert SLOConfig().recovers
    assert not SLOConfig(on_kill="drop").recovers
    assert not SLOConfig(max_retries=0).recovers


def test_respill_stranded_semantics():
    # 4 tiles, one balancer group over the first 3; tile 1 dead
    bal = LoadBalancer([("a", "b", "c")], ("a", "b", "c", "d"),
                       mode="even")
    q = np.array([2.0, 3.0, 1.0, 5.0])
    rq = np.array([0.5, 1.0, 0.0, 0.0])
    alive = np.array([1.0, 0.0, 1.0, 1.0])
    q2, rq2, spill, dropped = respill_stranded(q, rq, alive, bal)
    np.testing.assert_array_equal(q2, [2.0, 0.0, 1.0, 5.0])
    np.testing.assert_array_equal(rq2, [0.5, 0.0, 0.0, 0.0])
    # fresh stranded work re-spills; the already-retried share drops
    np.testing.assert_array_equal(spill, [0.0, 2.0, 0.0, 0.0])
    np.testing.assert_array_equal(dropped, [0.0, 1.0, 0.0, 0.0])
    # no balancer -> everything stranded drops
    _, _, spill0, dropped0 = respill_stranded(q, rq, alive, None)
    assert spill0.sum() == 0.0 and dropped0[1] == 3.0
    # whole group dead -> no survivor to spill to
    _, _, spill1, dropped1 = respill_stranded(
        q, rq, np.array([0.0, 0.0, 0.0, 1.0]), bal)
    assert spill1.sum() == 0.0
    np.testing.assert_array_equal(dropped1, [2.0, 3.0, 1.0, 0.0])


# --------------------------------------------- satellite: balancer guards
def test_load_balancer_zero_capacity_and_nan_guard():
    """All-dead / zero-capacity groups must not emit NaNs: weights are
    sanitized and the uniform fallback keeps conservation exact."""
    bal = LoadBalancer([("a", "b"), ("c", "d")], ("a", "b", "c", "d"),
                       mode="capacity")
    arr = np.array([4.0, 0.0, 2.0, 2.0])
    q = np.zeros(4)
    cap = np.array([0.0, 0.0, np.nan, -1.0])   # dead group + garbage caps
    out = bal.split(arr, q, cap)
    assert np.isfinite(out).all()
    assert out.sum() == pytest.approx(arr.sum())
    # alive mask steers every request of a group to its survivors
    out2 = bal.split(np.array([4.0, 0.0, 0.0, 0.0]), q,
                     np.ones(4), alive=np.array([0.0, 1.0, 1.0, 1.0]))
    assert out2[0] == 0.0 and out2[1] == pytest.approx(4.0)
    # adaptive mode with huge backlog stays finite too
    bal3 = LoadBalancer([("a", "b")], ("a", "b"), mode="adaptive")
    out3 = bal3.split(np.array([2.0, 0.0]), np.array([1e308, 0.0]),
                      np.array([0.0, 0.0]))
    assert np.isfinite(out3).all() and out3.sum() == pytest.approx(2.0)


# ------------------------------------------------- differential: B=1 bits
def faulted_setup(ticks=600, seed=4):
    names = ("a0", "a1", "a2", "b0", "b1", "b2")
    plat = make_platform(6, names=names)
    sched = (FaultSchedule()
             .kill_tile("a1", start=150, end=380)
             .kill_tile("b2", start=300)
             .degrade_link((1, 1), (1, 2), 0.3, start=100, end=500))
    slo = SLOConfig(deadline_s=0.03, on_kill="respill", max_retries=1)
    cap = SimEngine(plat).capacity_rps()
    # hot enough that real backlog exists when the kill lands (the peak
    # of the sinusoid sits at ticks/4, right on the first kill window)
    tr = diurnal_trace(cap * 0.85, ticks, 6, dt=1e-3, depth=0.5,
                       seed=seed)
    groups = (names[:3], names[3:])
    return plat, sched, slo, tr, groups


def test_batch_b1_matches_sequential_bitforbit_under_faults():
    plat, sched, slo, tr, groups = faulted_setup()
    cfg = SimConfig(telemetry_interval=20, telemetry_capacity=64)

    seq_eng = SimEngine(plat, config=cfg, faults=sched, slo=slo,
                        balancer=LoadBalancer(groups, plat.names,
                                              mode="even"))
    seq = seq_eng.run(tr)
    bplat = BatchSimPlatform.stack([plat])
    bat_eng = BatchSimEngine(bplat, config=cfg, faults=sched, slo=slo,
                             balancer=LoadBalancer(groups, plat.names,
                                                   mode="even"))
    bat = bat_eng.run(tr)

    assert bat.completed[0] == seq.completed
    assert bat.residual[0] == seq.residual
    assert bat.energy_j[0] == seq.energy_j
    assert bat.p99_latency_s[0] == seq.p99_latency_s
    assert bat.dropped_slo[0] == seq.dropped_slo
    assert bat.dropped_fault[0] == seq.dropped_fault
    assert bat.retried[0] == seq.retried
    assert bat.drop_rate[0] == seq.drop_rate
    # a fault actually fired and the SLO actually dropped something
    assert seq.dropped_fault > 0.0 or seq.retried > 0.0
    assert seq.dropped_slo > 0.0
    # full state including the retry class, elementwise exact
    for f in ("queue", "retry_q", "busy", "pkts_in", "pkts_out"):
        np.testing.assert_array_equal(
            getattr(bat_eng.last_state, f)[0],
            getattr(seq_eng.last_state, f), err_msg=f)
    # tick histories and the explicit queue-drop ledger
    for sh, bh in zip(seq_eng.last_histories, bat_eng.last_histories):
        np.testing.assert_array_equal(bh[:, 0], sh)
    np.testing.assert_array_equal(
        bat_eng.last_fault_histories["queue_drops"][:, 0],
        seq_eng.last_fault_histories["queue_drops"])


def test_jax_backend_matches_numpy_under_faults():
    pytest.importorskip("jax")
    plat, sched, slo, tr, groups = faulted_setup(ticks=500)
    # add a stuck-rate fault so the scan's actuator-override path runs
    sched = sched.stick_island(plat.islands.names()[0], start=50, end=250,
                               rate=0.4)
    bplat = BatchSimPlatform.stack([plat, plat])
    kw = dict(faults=sched, slo=slo,
              balancer=LoadBalancer(groups, plat.names, mode="even"))
    rn = BatchSimEngine(bplat, **kw).run(tr)
    rj = BatchSimEngine(bplat, backend="jax", **kw).run(tr)
    np.testing.assert_allclose(rj.completed, rn.completed, rtol=1e-3)
    np.testing.assert_allclose(rj.energy_j, rn.energy_j, rtol=1e-3)
    np.testing.assert_allclose(rj.dropped_slo, rn.dropped_slo,
                               rtol=1e-3, atol=1e-2)
    np.testing.assert_allclose(rj.dropped_fault, rn.dropped_fault,
                               rtol=1e-3, atol=1e-2)
    np.testing.assert_allclose(rj.retried, rn.retried,
                               rtol=1e-3, atol=1e-2)
    np.testing.assert_allclose(rj.drop_rate, rn.drop_rate,
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(rj.p99_latency_s, rn.p99_latency_s,
                               rtol=1e-3, atol=tr.dt)


# ----------------------------------------------------------- invariants
def check_conservation(plat, tr, *, sched, slo, groups=None, ctl=None):
    bal = (LoadBalancer(groups, plat.names, mode="even")
           if groups else None)
    eng = SimEngine(plat, faults=sched, slo=slo, balancer=bal,
                    controller=ctl)
    r = eng.run(tr)
    qd = eng.last_fault_histories["queue_drops"]
    # explicit ledgers are non-negative and the per-tick drop history
    # sums to the run totals
    assert r.dropped_slo >= 0 and r.dropped_fault >= 0 and r.retried >= 0
    assert (qd >= -1e-9).all()
    # conservation: offered == completed + explicit drops + backlog
    total_q = float(eng.last_state.queue.sum())
    lhs = offered(tr, r)
    rhs = r.completed + r.dropped_slo + r.dropped_fault + total_q
    np.testing.assert_allclose(lhs, rhs, rtol=1e-9, atol=1e-6)
    # queues stayed non-negative through every kill/revive
    assert (eng.last_state.queue >= 0.0).all()
    assert (eng.last_state.retry_q >= -1e-12).all()
    assert float(eng.last_state.retry_q.sum()) <= total_q + 1e-9
    return r


def run_conservation_case(seed, kill_start, kill_len, on_kill, deadline):
    names = ("a0", "a1", "a2", "b0", "b1", "b2")
    plat = make_platform(6, names=names)
    cap = SimEngine(plat).capacity_rps()
    tr = poisson_trace(float(cap.sum()) * 0.6, 400, 6, dt=1e-3, seed=seed)
    sched = (FaultSchedule()
             .kill_tile("a1", start=kill_start, end=kill_start + kill_len)
             .kill_tile("b0", start=kill_start + 50))
    slo = SLOConfig(deadline_s=deadline, on_kill=on_kill,
                    max_retries=1 if on_kill == "respill" else 0)
    check_conservation(plat, tr, sched=sched, slo=slo,
                       groups=(names[:3], names[3:]))


@pytest.mark.parametrize("on_kill", ["respill", "drop", "wait"])
def test_conservation_under_faults_seeded(on_kill):
    for seed, start, ln, dl in [(0, 50, 100, 0.02), (1, 120, 200, None),
                                (2, 10, 380, 0.05)]:
        run_conservation_case(seed, start, ln, on_kill, dl)


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**16),
           kill_start=st.integers(0, 350),
           kill_len=st.integers(1, 300),
           on_kill=st.sampled_from(["respill", "drop", "wait"]),
           deadline=st.sampled_from([None, 0.01, 0.05]))
    def test_conservation_under_faults_fuzzed(seed, kill_start, kill_len,
                                              on_kill, deadline):
        run_conservation_case(seed, kill_start, kill_len, on_kill,
                              deadline)


def test_kill_revive_queue_drains_and_power_gates():
    """A killed tile serves nothing and burns nothing; after revive its
    (waited) backlog drains and completion resumes."""
    plat = make_platform(3)
    cap = SimEngine(plat).capacity_rps()
    tr = poisson_trace(float(cap.sum()) * 0.5, 300, 3, dt=1e-3, seed=7)
    sched = FaultSchedule().kill_tile(plat.names[0], start=50, end=150)
    eng = SimEngine(plat, faults=sched,
                    slo=SLOConfig(on_kill="wait"))
    r = eng.run(tr)
    adm, served = eng.last_histories
    assert served[50:150, 0].sum() == 0.0            # dead: serves nothing
    assert served[150:, 0].sum() > 0.0               # revived: drains
    assert r.dropped_fault == 0.0                    # "wait" never drops
    # power gating: the same run with the tile alive burns MORE energy
    r_free = SimEngine(plat).run(tr)
    assert r.energy_j < r_free.energy_j
    # conservation incl. the wait backlog
    np.testing.assert_allclose(
        offered(tr, r),
        r.completed + r.dropped_slo + float(eng.last_state.queue.sum()),
        rtol=1e-9, atol=1e-6)


def test_stuck_rate_overrides_hardware_not_software():
    """A stuck actuator pins the island's silicon rate; the controller's
    software state keeps evolving and service recovers to the software
    view when the fault clears."""
    plat = make_platform(3, island_groups=None)
    cap = SimEngine(plat).capacity_rps()
    tr = poisson_trace(float(cap.sum()) * 0.9, 300, 3, dt=1e-3, seed=3)
    isl = plat.islands.names()[0]
    sched = FaultSchedule().stick_island(isl, start=0, end=200, rate=0.05)
    eng = SimEngine(plat, faults=sched)
    r = eng.run(tr)
    free_eng = SimEngine(plat)
    r_free = free_eng.run(tr)
    # pinned near-zero the island serves strictly less while stuck ...
    served = eng.last_histories[1]
    served_free = free_eng.last_histories[1]
    assert served[:200].sum() < served_free[:200].sum()
    # ... then recovers to the SOFTWARE rate when the fault clears and
    # drains the built-up backlog (more served than the free run's tail)
    assert served[200:].sum() > served_free[200:].sum()
    assert r.p99_latency_s > r_free.p99_latency_s
    assert r.completed <= r_free.completed + 1e-9


# --------------------------------------------------------- online detect
def test_online_fault_detector_latch_and_revive_probe():
    det = OnlineFaultDetector(3, SimFaultConfig(dead_ticks=3))
    cap = np.array([1.0, 0.0, 1.0])
    served = np.array([1.0, 0.0, 1.0])
    queue = np.array([0.0, 5.0, 0.0])
    for _ in range(2):
        nd, na = det.observe(served, queue, cap)
        assert not nd.any()                       # below the streak
    nd, na = det.observe(served, queue, cap)
    assert nd[1] and det.believed_dead[1]         # latched on tick 3
    # an idle healthy tile (no backlog) is never suspected
    assert not det.believed_dead[0]
    # revive probe: observable capacity clears the belief immediately
    nd, na = det.observe(served, queue, np.array([1.0, 1.0, 1.0]))
    assert na[1] and not det.believed_dead[1]


def test_sim_fault_supervisor_events_and_straggler_gating():
    sup = SimFaultSupervisor(SimFaultConfig(dead_ticks=2,
                                            straggler_ticks=5))
    sup.begin_run(("x", "y", "z"))
    served = np.array([1.0, 0.0, 1.0])
    queue = np.array([0.0, 1.0, 0.0])
    cap = np.array([1.0, 0.0, 1.0])
    evs = []
    for t in range(3):
        evs += sup.observe(t, served=served, queue=queue, cap=cap)
    assert [e["kind"] for e in evs] == ["detected_dead"]
    assert evs[0]["tiles"] == ["y"]
    np.testing.assert_array_equal(sup.believed_alive, [1.0, 0.0, 1.0])
    # straggler skew must PERSIST straggler_ticks before one event fires
    cap = np.ones(3)
    busy_skew = np.array([0.9, 0.2, 0.2])
    n0 = len(sup.events)
    for t in range(3, 3 + 4):                     # 4 < straggler_ticks
        sup.observe(t, served=np.ones(3), queue=np.zeros(3), cap=cap,
                    busy=busy_skew)
    stragglers = [e for e in sup.events if e["kind"] == "straggler_suspect"]
    assert not stragglers
    for t in range(7, 7 + 10):
        sup.observe(t, served=np.ones(3), queue=np.zeros(3), cap=cap,
                    busy=busy_skew)
    stragglers = [e for e in sup.events if e["kind"] == "straggler_suspect"]
    assert len(stragglers) == 1                   # deduped set-change emit
    assert stragglers[0]["tiles"] == ["x"]


def test_supervisor_in_the_loop_detection_latency():
    """The engine routes on BELIEVED availability: detection fires a few
    ticks after the kill, telemetry carries the events, and recovery
    still keeps the run essentially drop-free."""
    plat = pipeline_platform()
    cap = SimEngine(plat).capacity_rps()
    stage_cap = float(cap[:3].sum())
    mean = np.zeros(6)
    mean[:3] = 0.45 * stage_cap / 3.0
    tr = diurnal_trace(mean, 1200, 6, dt=1e-3, depth=1.0 / 3.0, seed=11,
                       phase=-np.pi / 2.0)
    sched = FaultSchedule().kill_tile("be1", start=400, end=900)
    sup = SimFaultSupervisor(SimFaultConfig(dead_ticks=3))
    eng = SimEngine(
        plat, config=SimConfig(telemetry_interval=50),
        faults=sched, slo=SLOConfig(deadline_s=0.05),
        balancer=LoadBalancer((STAGE0, STAGE1), plat.names, mode="even"),
        supervisor=sup)
    r = eng.run(tr)
    dead_evs = [e for e in sup.events if e["kind"] == "detected_dead"]
    alive_evs = [e for e in sup.events if e["kind"] == "detected_alive"]
    assert dead_evs and dead_evs[0]["tiles"] == ["be1"]
    # latency: at least dead_ticks after the kill, but well bounded
    assert 400 + 2 <= dead_evs[0]["tick"] <= 400 + 30
    assert alive_evs and alive_evs[0]["tick"] >= 900
    # the engine forwarded detection events into telemetry
    tl_kinds = [e["kind"] for e in r.telemetry.events]
    assert "detected_dead" in tl_kinds and "fault_kill" in tl_kinds
    assert r.drop_rate < 0.01


# ---------------------------------------------------------- scenario gate
def surge_kill_run(*, recover, dfs=False, supervisor=None, ticks=4000):
    plat = pipeline_platform()
    cap = SimEngine(plat).capacity_rps()
    stage_cap = float(cap[:3].sum())
    mean = np.zeros(6)
    mean[:3] = 0.45 * stage_cap / 3.0
    tr = diurnal_trace(mean, ticks, 6, dt=1e-3, depth=1.0 / 3.0, seed=11,
                       phase=-np.pi / 2.0)       # trough -> 2x surge peak
    sched = FaultSchedule().kill_tile("be1", start=1800, end=2600)
    slo = (SLOConfig(deadline_s=0.05, on_kill="respill", max_retries=1)
           if recover else
           SLOConfig(deadline_s=0.05, on_kill="drop", max_retries=0))
    ctl = (ControllerHarness(
        plat.islands, partial(policy_memory_bound, threshold=0.55,
                              low_rate=0.5), queue_guard_ticks=3.0)
        if dfs else None)
    eng = SimEngine(
        plat, config=SimConfig(control_interval=25), controller=ctl,
        faults=sched, slo=slo, supervisor=supervisor,
        balancer=LoadBalancer((STAGE0, STAGE1), plat.names, mode="even"))
    r = eng.run(tr)
    return eng, r, tr


def test_scenario_gate_replica_kill_mid_surge():
    """The PR's scenario gate: a back-end replica dies for 800 ticks of
    a 2x diurnal surge.  Without recovery the stranded share is dropped;
    with respill + alive-masked splits the pipeline survives at a
    bounded p99 and an order-of-magnitude lower drop rate — work
    conserved every tick in both runs."""
    eng_n, r_n, tr = surge_kill_run(recover=False)
    eng_r, r_r, _ = surge_kill_run(recover=True)

    # without recovery: the kill window's share of work is lost
    assert r_n.drop_rate > 0.05
    # with recovery: survivors absorb the respill, nearly nothing drops
    assert r_r.drop_rate < 0.01
    assert r_r.retried > 0.0
    assert r_r.completed > r_n.completed
    # bounded tail in both: the deadline caps queueing delay
    assert r_n.p99_latency_s <= 0.05 + tr.dt
    assert r_r.p99_latency_s <= 0.05 + tr.dt
    # work conservation, both modes; the chain forwards stage-0
    # completions with one tick of latency, so the last tick's front-end
    # output is still in flight when the run ends
    for eng, r in ((eng_n, r_n), (eng_r, r_r)):
        in_flight = float(eng.last_histories[1][-1, :3].sum())
        lhs = offered(tr, r)
        rhs = (r.completed + r.dropped_slo + r.dropped_fault
               + float(eng.last_state.queue.sum()) + in_flight)
        np.testing.assert_allclose(lhs, rhs, rtol=1e-9, atol=1e-6)

    # the gate holds with the DFS controller in the loop too
    _, r_dn, _ = surge_kill_run(recover=False, dfs=True)
    _, r_dr, _ = surge_kill_run(recover=True, dfs=True)
    assert r_dn.drop_rate > 0.05
    assert r_dr.drop_rate < 0.01
    # and DFS still saves energy while the fault plays out
    assert r_dr.energy_j < r_r.energy_j


# ------------------------------------------------------ DSE under failure
def test_closed_loop_score_reranks_under_faults():
    """A stuck-at-low-rate actuator mid-run re-orders survivors: the
    design whose static win came from a throttled (energy-lean) island
    config loses more capacity under the stuck fault than the full-rate
    design, and the fault-aware ranking flips them.  Batched and
    sequential scoring stay identical."""
    m = SoCPerfModel()
    wls = [AccelWorkload("dfadd", *CHSTONE["dfadd"]),
           AccelWorkload("dfmul", *CHSTONE["dfmul"])]
    res = grid_sweep(m, wls, ks=(1, 2, 4, 8), acc_rates=(0.2, 0.6, 1.0),
                     noc_rates=(0.5, 1.0), n_tg=2)
    # diverse Pareto survivors: one (K, acc_rate, noc_rate) combo each
    thr = res.throughput.ravel()
    seen, idx = set(), []
    for j in sorted(res.pareto_indices(), key=lambda j: -thr[j]):
        dp = res.design_point(int(j))
        key = (dp.replication["dfmul"], dp.rates["acc"],
               dp.rates["noc_mem"])
        if key not in seen:
            seen.add(key)
            idx.append(int(j))
        if len(idx) == 4:
            break
    tr = diurnal_trace(np.array([3000.0, 9000.0]), 1500, 2, dt=1e-3,
                       depth=0.5, seed=9)
    base = dict(model=m, indices=idx, req_mb=0.002, p99_sla_s=0.02)

    s_free = closed_loop_score(res, tr, **base)
    assert s_free.drop_rate is None               # fault-free: no ledger

    fs = FaultSchedule().stick_island("dfmul", start=300, end=1200,
                                     rate=0.2)
    kw = dict(**base, fault_schedule=fs,
              slo=SLOConfig(deadline_s=0.02), max_drop_rate=0.02)
    s_fb = closed_loop_score(res, tr, **kw)
    s_fs = closed_loop_score(res, tr, **kw, batch=False)

    # batched == sequential, exactly (drop ledgers, tails, final order)
    np.testing.assert_array_equal(s_fb.drop_rate, s_fs.drop_rate)
    np.testing.assert_array_equal(np.asarray(s_fb.p99_latency_s),
                                  np.asarray(s_fs.p99_latency_s))
    np.testing.assert_array_equal(s_fb.order, s_fs.order)
    # the fault produced real, design-dependent drops ...
    assert (np.asarray(s_fb.drop_rate) > 0.0).all()
    assert len(set(np.round(s_fb.drop_rate, 6))) > 1
    # ... and at least one pair re-ranked relative to fault-free
    assert not np.array_equal(np.asarray(s_free.order),
                              np.asarray(s_fb.order))


# ------------------------------------------------ satellite: telemetry IO
def test_fault_counters_round_trip_through_telemetry_json(tmp_path):
    plat, sched, slo, tr, groups = faulted_setup(ticks=300)
    eng = SimEngine(plat, config=SimConfig(telemetry_interval=25),
                    faults=sched, slo=slo,
                    balancer=LoadBalancer(groups, plat.names, mode="even"))
    r = eng.run(tr)
    p = tmp_path / "tl.json"
    r.telemetry.to_json(str(p))
    doc = json.loads(p.read_text())
    for ch in ("dropped_slo", "dropped_fault", "retried", "dropped"):
        vals = doc["scalars"][ch]
        assert vals, ch
        # cumulative run totals: monotone non-decreasing
        assert all(b >= a - 1e-9 for a, b in zip(vals, vals[1:])), ch
    # the last sample's cumulative counters match the run totals
    assert doc["scalars"]["dropped_slo"][-1] == pytest.approx(
        r.dropped_slo, rel=1e-9)
    assert doc["scalars"]["dropped_fault"][-1] == pytest.approx(
        r.dropped_fault, rel=1e-9)
    assert doc["scalars"]["retried"][-1] == pytest.approx(
        r.retried, rel=1e-9)
    # fault transitions rode along as events
    kinds = {e["kind"] for e in doc["events"]}
    assert "fault_kill" in kinds


# ------------------------------------------------------------- slow soak
@pytest.mark.slow
def test_fleet_kill_soak_long_run():
    """Half the back-end stage dies and revives twice over a long soak;
    conservation and bounded drops must hold throughout."""
    plat = pipeline_platform()
    cap = SimEngine(plat).capacity_rps()
    stage_cap = float(cap[:3].sum())
    mean = np.zeros(6)
    mean[:3] = 0.4 * stage_cap / 3.0
    tr = diurnal_trace(mean, 20_000, 6, dt=1e-3, depth=0.4, seed=5)
    sched = (FaultSchedule()
             .kill_tile("be0", start=3000, end=6000)
             .kill_tile("be1", start=5000, end=9000)
             .kill_tile("be0", start=12_000, end=15_000)
             .kill_tile("be2", start=13_000, end=14_000))
    eng = SimEngine(
        plat, config=SimConfig(control_interval=25),
        faults=sched, slo=SLOConfig(deadline_s=0.05),
        balancer=LoadBalancer((STAGE0, STAGE1), plat.names, mode="even"))
    r = eng.run(tr)
    # overlapping kills leave one back-end survivor for 1000 ticks; the
    # deadline sheds what it can't absorb, but drops stay bounded
    assert r.drop_rate < 0.04
    qd = eng.last_fault_histories["queue_drops"]
    assert (qd >= -1e-9).all()
    in_flight = float(eng.last_histories[1][-1, :3].sum())
    np.testing.assert_allclose(
        offered(tr, r),
        r.completed + r.dropped_slo + r.dropped_fault
        + float(eng.last_state.queue.sum()) + in_flight,
        rtol=1e-9, atol=1e-5)
