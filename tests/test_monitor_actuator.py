"""C3 monitor counter semantics + C2 actuator commit/swap atomicity.

The paper-verbatim contracts that nothing else in the suite pins down:

* ``exec_time`` auto-resets (holds the latest per-step value) while
  ``pkts_in``/``pkts_out``/``rtt`` accumulate until *manually* reset;
* disabled counters never materialize;
* ``manual_reset`` touches only the requested tiles/kinds;
* the dual-buffer actuator never exposes a half-written config — readers
  racing a reconfigure/commit storm only ever observe fully-formed
  versions, monotonic swaps, and a bounded history.
"""
import threading

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (DFSActuator, MonitorClient, charge, charge_boundary,
                        default_islands, default_plan, init_counters,
                        manual_reset)
from repro.core.dfs import DEFAULT_HISTORY_MAXLEN
from repro.core.monitor import PKT_BYTES, MonitorSample
from repro.core.tiles import TilePlan, TileSpec


def make_plan():
    return TilePlan(arch="t", tiles=(
        TileSpec("attn", "attn", monitors=("exec_time", "pkts_in",
                                           "pkts_out", "rtt")),
        TileSpec("mem", "mem", monitors=("pkts_in", "pkts_out")),
        TileSpec("noc", "noc", monitors=()),
    ))


# ------------------------------------------------------------ C3 counters
def test_exec_time_replaces_value():
    c = init_counters(make_plan())
    c = charge(c, "attn", exec_time=3.0)
    c = charge(c, "attn", exec_time=5.0)
    # latest value, NOT 8.0: exec_time auto-resets at each start/stop
    assert float(c["attn"]["exec_time"]) == 5.0


def test_pkts_and_rtt_accumulate():
    c = init_counters(make_plan())
    c = charge(c, "attn", pkts_in=2.0, pkts_out=1.0, rtt=0.5)
    c = charge(c, "attn", pkts_in=3.0, pkts_out=4.0, rtt=0.25)
    assert float(c["attn"]["pkts_in"]) == 5.0
    assert float(c["attn"]["pkts_out"]) == 5.0
    assert float(c["attn"]["rtt"]) == 0.75


def test_disabled_counters_never_materialize():
    c = init_counters(make_plan())
    assert set(c["mem"]) == {"pkts_in", "pkts_out"}
    assert c["noc"] == {}
    c = charge(c, "mem", exec_time=9.0, rtt=1.0, pkts_in=1.0)
    assert "exec_time" not in c["mem"] and "rtt" not in c["mem"]
    assert float(c["mem"]["pkts_in"]) == 1.0
    # charging an unknown tile is a silent no-op (no register, no trap)
    assert charge(c, "nope", pkts_in=1.0) == c


def test_manual_reset_scopes_to_tiles_and_kinds():
    c = init_counters(make_plan())
    c = charge(c, "attn", exec_time=2.0, pkts_in=4.0, rtt=1.0)
    c = charge(c, "mem", pkts_in=6.0)
    r = manual_reset(c, tiles=["attn"])
    # accumulating counters of attn cleared; exec_time survives by default
    assert float(r["attn"]["pkts_in"]) == 0.0
    assert float(r["attn"]["rtt"]) == 0.0
    assert float(r["attn"]["exec_time"]) == 2.0
    # other tiles untouched
    assert float(r["mem"]["pkts_in"]) == 6.0
    # explicit kinds override the default exclusion of exec_time
    r2 = manual_reset(c, kinds=("exec_time",))
    assert float(r2["attn"]["exec_time"]) == 0.0
    assert float(r2["attn"]["pkts_in"]) == 4.0


def test_charge_boundary_conserves_packets():
    c = init_counters(make_plan())
    payload = np.zeros((4, PKT_BYTES // 4), dtype=np.float32)  # 4 pkts
    c = charge_boundary(c, "attn", "mem", payload)
    assert float(c["attn"]["pkts_out"]) == pytest.approx(4.0)
    assert float(c["mem"]["pkts_in"]) == pytest.approx(4.0)


# ------------------------------------------------------- C2 actuator swap
def islands():
    return default_islands(default_plan(get_config("granite-8b")))


def test_commit_without_reconfigure_is_noop():
    act = DFSActuator(islands())
    v0 = act.live().version
    assert act.commit().version == v0
    assert act.swaps == 0


def test_abort_drops_shadow_without_exposure():
    act = DFSActuator(islands())
    v0 = act.live().version
    act.reconfigure({"noc_mem": 0.5})
    act.abort()
    assert act.commit().version == v0          # nothing to swap anymore
    assert act.live().rate_of("mem") == 1.0


def test_history_is_bounded_with_custom_maxlen():
    act = DFSActuator(islands(), history_maxlen=5)
    assert act.history_maxlen == 5
    for i in range(50):
        act.reconfigure({"noc_mem": 0.5 if i % 2 else 1.0})
        act.commit()
    h = act.history()
    assert act.swaps == 50
    assert len(h) == 5
    # the kept window is the most recent commits, in order
    versions = [v for v, _ in h]
    assert versions == sorted(versions)
    assert versions[-1] == act.live().version


def test_history_default_maxlen_bounds_growth():
    act = DFSActuator(islands())
    for i in range(DEFAULT_HISTORY_MAXLEN + 37):
        act.reconfigure({"noc_mem": 0.5 if i % 2 else 1.0})
        act.commit()
    assert len(act.history()) == DEFAULT_HISTORY_MAXLEN


def test_concurrent_commit_swap_atomicity():
    """Readers racing a reconfigure/commit storm must only ever observe
    fully-formed configs: every island present, version monotonic per
    reader, rates always on the ladder."""
    act = DFSActuator(islands())
    names = set(act.live().names())
    stop = threading.Event()
    errors = []

    def reader():
        last_version = -1
        while not stop.is_set():
            cfg = act.live()
            try:
                assert set(cfg.names()) == names
                assert cfg.version >= last_version
                for isl in cfg.islands:
                    if not isl.fixed:
                        assert isl.rate in isl.ladder.levels()
                last_version = cfg.version
            except AssertionError as e:        # pragma: no cover
                errors.append(e)
                return

    def writer(seed):
        rng = np.random.default_rng(seed)
        for _ in range(300):
            act.reconfigure({"noc_mem": float(rng.uniform(0.1, 1.0))})
            if rng.random() < 0.1:
                act.abort()
            else:
                act.commit()

    readers = [threading.Thread(target=reader) for _ in range(3)]
    writers = [threading.Thread(target=writer, args=(s,)) for s in range(3)]
    for t in readers + writers:
        t.start()
    for t in writers:
        t.join()
    stop.set()
    for t in readers:
        t.join()
    assert not errors
    assert act.swaps <= 900
    assert len(act.history()) <= DEFAULT_HISTORY_MAXLEN

# -------------------------------------------------------- monitor client
def test_monitor_client_sample_history_is_bounded():
    """Long soaks must not grow the sample history without limit — the
    deque keeps only the newest ``max_samples`` reads (the same fix
    ``ActuatorState.history`` got)."""
    mc = MonitorClient(max_samples=16)
    c = init_counters(make_plan())
    for step in range(50):
        c = charge(c, "attn", pkts_in=1.0)
        mc.read(c, step)
    assert len(mc.samples) == 16
    assert mc.samples[0].step == 34 and mc.samples[-1].step == 49
    # rates() differentiates only the retained window
    pts = mc.rates("attn", "pkts_in")
    assert len(pts) <= 15 and all(s >= 35 for s, _ in pts)


def test_monitor_client_rates_differentiates_consecutive_reads():
    mc = MonitorClient()
    rows = [({"attn": {"pkts_in": 100.0}}, 0, 0.0),
            ({"attn": {"pkts_in": 160.0}}, 10, 2.0),
            ({"attn": {"pkts_in": 160.0}}, 20, 2.0),   # dt == 0: skipped
            ({"attn": {"pkts_in": 190.0}}, 30, 5.0)]
    for counters, step, wall in rows:
        mc.samples.append(MonitorSample(step=step, wall_time=wall,
                                        counters=counters))
    assert mc.rates("attn") == [(10, 30.0), (30, 10.0)]
    assert mc.rates("attn", "pkts_out") == [(10, 0.0), (30, 0.0)]


def test_monitor_client_table_layout_is_memoized():
    """The column layout recomputes only when the tile/kind set changes —
    not per render — and the rendered table tracks the newest sample."""
    mc = MonitorClient()
    c = init_counters(make_plan())
    mc.read(charge(c, "attn", pkts_in=2.0), 0)
    first = mc.table()
    layout = mc._layout
    mc.read(charge(c, "attn", pkts_in=7.0), 1)
    second = mc.table()
    assert mc._layout is layout         # same key -> cached layout object
    assert first != second and "step 1" in second
    # a changed counter set invalidates the memo
    mc.read({"attn": {"pkts_in": 1.0}, "extra": {"rtt": 0.5}}, 2)
    mc.table()
    assert mc._layout is not layout
    assert [t for t, _ in mc._layout] == ["attn", "extra"]


def test_monitor_client_empty_table():
    assert MonitorClient().table() == "(no samples)"
