"""Per-island frequency axes in the DSE sweep (paper C2, end to end).

The reproduction's fidelity contract for the per-island sweep:

* ``grid_sweep(island_rates="independent")`` restricted to
  all-islands-equal rates reproduces the shared-``f_acc`` sweep **bit for
  bit** (same op sequence by construction),
* chunked/streaming sweeps return *identical* Pareto fronts and top-k to
  one-shot sweeps, at any chunk size, with globally addressable indices,
* on the paper's 4x4 SoC with >=3 accelerator islands, the independent
  sweep finds heterogeneous-rate Pareto points that **strictly dominate**
  the best shared-rate point — the fidelity gap the shared-axis sweep
  could never see (it only explores the diagonal of the rate space),
* the sweep-side plumbing delivers per-design (B, I) island-rate vectors
  into the batched co-sim bit-identically to the per-point path,
* the routing/incidence caches stay bounded across many-config sweeps and
  ``IslandConfig.island_of`` is memoized per instance.
"""
import numpy as np
import pytest

from repro.configs.vespa_soc import CHSTONE
from repro.core.dse import (ChunkedSweepResult, SweepResult,
                            closed_loop_score, grid_sweep)
from repro.core.islands import (IslandConfig, IslandSpec, NOC_LADDER,
                                TILE_LADDER, default_islands)
from repro.core.noc import (NocConfig, _xy_route_cached, hops,
                            routing_tables, stacked_incidence)
from repro.core.perfmodel import AccelWorkload, SoCPerfModel

WLS2 = (AccelWorkload("dfsin", *CHSTONE["dfsin"]),
        AccelWorkload("gsm", *CHSTONE["gsm"]))
WLS3 = (AccelWorkload("dfadd", *CHSTONE["dfadd"]),
        AccelWorkload("dfmul", *CHSTONE["dfmul"]),
        AccelWorkload("dfsin", *CHSTONE["dfsin"]))
SMALL = dict(ks=(1, 2), acc_rates=(0.2, 0.6, 1.0), noc_rates=(0.5, 1.0),
             tg_rates=(0.5, 1.0), positions=((1, 1), (3, 3), (0, 2)),
             n_tg=4)
OBJS = ("throughput", "area", "energy_per_unit", "mem_traffic")


# ------------------------------------------------- all-equal bit-for-bit
def test_independent_all_equal_matches_shared_bitforbit():
    """Every shared-sweep point == the independent-sweep point with all
    accelerator islands at that rate, on all four objectives, exactly."""
    m = SoCPerfModel()
    rs = grid_sweep(m, WLS2, **SMALL)
    ri = grid_sweep(m, WLS2, **SMALL, island_rates="independent")
    assert ri.independent_islands and not rs.independent_islands
    # shared axes: K0 K1 fn fa ft p0 p1 ; independent: K0 K1 fn fa fa ft ...
    coords = np.indices(rs.shape).reshape(len(rs.shape), -1)
    k0, k1, fn, fa, ft, p0, p1 = coords
    idx_i = np.ravel_multi_index((k0, k1, fn, fa, fa, ft, p0, p1), ri.shape)
    for obj in OBJS:
        assert np.array_equal(getattr(rs, obj), getattr(ri, obj)[idx_i]), obj
    assert np.array_equal(rs.valid, ri.valid[idx_i])


def test_memory_traffic_per_accel_equals_shared_when_equal():
    m = SoCPerfModel()
    f = np.asarray([0.2, 0.5, 1.0])
    per = m.memory_traffic_batch(f_acc_per_accel=[f, f, f], f_noc=0.7,
                                 f_tg=1.0, n_tg=4)
    shared = m.memory_traffic_batch(f_acc=f, f_noc=0.7, f_tg=1.0, n_tg=4,
                                    n_accels=3)
    np.testing.assert_allclose(per, shared, rtol=1e-14)
    # heterogeneous rates genuinely differ from any shared setting
    het = m.memory_traffic_batch(f_acc_per_accel=[f * 0 + 1.0, f * 0 + 0.1],
                                 f_noc=1.0, f_tg=0.0, n_tg=0)
    assert het[0] == pytest.approx(
        float(m.memory_traffic_batch(f_acc=1.0, f_noc=1.0, f_tg=0.0,
                                     n_tg=0, n_accels=1))
        + float(m.memory_traffic_batch(f_acc=0.1, f_noc=1.0, f_tg=0.0,
                                       n_tg=0, n_accels=1)))


# --------------------------------------------------- chunked == one-shot
@pytest.mark.parametrize("mode", ["shared", "independent"])
@pytest.mark.parametrize("chunk", [17, 101, 430])
def test_chunked_matches_oneshot(mode, chunk):
    """tier-1 smoke for the streaming sweep: identical Pareto front,
    identical top-k on every tracked objective, identical survivor
    objective values, at any chunk size."""
    m = SoCPerfModel()
    one = grid_sweep(m, WLS2, **SMALL, island_rates=mode)
    ch = grid_sweep(m, WLS2, **SMALL, island_rates=mode,
                    chunk_points=chunk, topk_track=16)
    assert isinstance(one, SweepResult)
    assert isinstance(ch, ChunkedSweepResult)
    assert len(ch) == len(one) and ch.n_valid == one.n_valid
    assert np.array_equal(ch.pareto_indices(), one.pareto_indices())
    for obj in OBJS:
        assert np.array_equal(ch.topk_indices(10, obj),
                              one.topk_indices(10, obj)), obj
        pf = ch.pareto_indices()
        assert np.array_equal(ch.objective_values(obj, pf),
                              one.objective_values(obj, pf))
    # survivors materialize identically (incl. per-island rate maps)
    i = int(ch.topk_indices(1)[0])
    assert ch.design_point(i) == one.design_point(i)
    assert ch.island_rates(i) == one.island_rates(i)


def test_chunked_lookup_guardrails():
    m = SoCPerfModel()
    ch = grid_sweep(m, WLS2, **SMALL, chunk_points=50, topk_track=8)
    tracked = int(ch.topk_indices(1)[0])
    ch.objective_values("throughput", [tracked])        # fine
    untracked = int(np.setdiff1d(np.arange(len(ch)), ch.cand_indices)[0])
    with pytest.raises(KeyError):
        ch.objective_values("throughput", [untracked])
    with pytest.raises(ValueError):
        ch.topk_indices(9)                              # > topk_track
    # untracked indices still decode (global addressability): exact
    # replication/placement/rates, NaN objectives
    full = grid_sweep(SoCPerfModel(), WLS2, **SMALL)
    dp = ch.design_point(untracked)
    ref = full.design_point(untracked)
    assert (dp.replication, dp.placement, dp.rates) == \
        (ref.replication, ref.placement, ref.rates)
    assert np.isnan(dp.throughput) and np.isnan(dp.energy_per_unit)
    assert ch.island_rates(untracked) == full.island_rates(untracked)


# --------------------------------------------- heterogeneous dominance
def test_heterogeneous_point_dominates_best_shared():
    """Acceptance: on the 4x4 SoC with 3 accelerator islands, the
    per-island sweep finds a Pareto point strictly dominating the best
    shared-rate point (minimum energy/unit on the shared Pareto front —
    which is also the shared pick under the paper's energy-at-bounded-
    throughput-loss DFS criterion).  The shared sweep cannot see this
    point: it lies off the diagonal of the rate space (derate the tiny
    compute-bound island, keep the memory-bound streams fast)."""
    m = SoCPerfModel()
    kw = dict(ks=(1, 2, 4), acc_rates=TILE_LADDER.levels(),
              noc_rates=(0.5, 1.0), tg_rates=(1.0,),
              positions=((1, 1), (3, 3), (0, 2)), n_tg=4)
    rs = grid_sweep(m, WLS3, **kw)
    # the independent sweep runs chunked/streaming — the real use shape
    ri = grid_sweep(m, WLS3, **kw, island_rates="independent",
                    chunk_points=200_000)
    assert len(ri) == len(rs) * len(TILE_LADDER.levels()) ** 2 > 1e6

    spf = rs.pareto_indices()
    best = int(spf[np.argmin(rs.objective_values("energy_per_unit", spf))])
    t, a, e = (float(rs.objective_values(o, [best])[0])
               for o in ("throughput", "area", "energy_per_unit"))

    ipf = ri.pareto_indices()
    it, ia, ie = (ri.objective_values(o, ipf)
                  for o in ("throughput", "area", "energy_per_unit"))
    dom = (it >= t) & (ia <= a) & (ie <= e) & \
          ((it > t) | (ia < a) | (ie < e))
    assert dom.any(), "no heterogeneous point dominates the best shared pt"
    # the dominator is genuinely heterogeneous and strictly better
    j = int(ipf[dom][np.argmin(ie[dom])])
    rates = ri.island_rates(j)
    accel_rates = [rates[w.name] for w in WLS3]
    assert len(set(accel_rates)) > 1, rates
    assert float(ri.objective_values("energy_per_unit", [j])[0]) < e
    assert float(ri.objective_values("throughput", [j])[0]) >= t


# -------------------------------------------- sweep -> batched co-sim
def test_from_design_points_vectorized_matches_stack():
    """BatchSimPlatform.from_design_points (one design_arrays decode) is
    bit-identical to stacking SimPlatform.from_design_point per index —
    per-island (B, I) rate vectors included."""
    from repro.sim import BatchSimPlatform
    from repro.sim.engine import SimPlatform
    m = SoCPerfModel()
    for mode in ("shared", "independent"):
        res = grid_sweep(m, WLS2, **SMALL, island_rates=mode)
        idx = res.pareto_indices()[:8]
        fast = BatchSimPlatform.from_design_points(m, res, idx, req_mb=0.1)
        slow = BatchSimPlatform.stack(
            [SimPlatform.from_design_point(m, res.design_point(int(i)),
                                           res.workloads, req_mb=0.1,
                                           n_tg=res.n_tg) for i in idx])
        for f in ("base_mbps", "wire_share", "k", "pos_idx", "req_mb",
                  "rates", "f_tg"):
            assert np.array_equal(getattr(fast, f), getattr(slow, f)), \
                (mode, f)
        assert fast.names == slow.names
        assert fast.islands.names() == slow.islands.names()
        if mode == "independent":
            # heterogeneous sweeps must reach the sim as heterogeneous
            # (B, I) rows, not a collapsed shared rate
            assert any(len(set(r[:-1])) > 1 for r in fast.rates.tolist())


def test_closed_loop_score_on_chunked_independent():
    """The full pipeline on a chunked per-island sweep: streaming sweep ->
    Pareto survivors -> one batched replay; sequential path ranks
    identically."""
    from repro.sim import diurnal_trace
    m = SoCPerfModel()
    res = grid_sweep(m, WLS2, ks=(1, 2), acc_rates=(0.2, 0.6, 1.0),
                     noc_rates=(0.5, 1.0), tg_rates=(1.0,),
                     positions=((1, 1), (3, 3), (0, 2)), n_tg=4,
                     island_rates="independent", chunk_points=100)
    trace = lambda seed: diurnal_trace(          # noqa: E731
        5000.0, 400, 2, dt=1e-3, seed=seed)
    sc = closed_loop_score(res, trace, model=m, top=4)
    sc_seq = closed_loop_score(res, trace, model=m, top=4, batch=False)
    assert np.array_equal(sc.ranked_indices(), sc_seq.ranked_indices())
    assert np.allclose(sc.p99_latency_s, sc_seq.p99_latency_s)


# ------------------------------------------------- cache boundedness
def test_many_config_sweep_does_not_retain_incidence_tables():
    """1k distinct NocConfigs through the routing/incidence path must not
    pin 1k tables (the old unbounded lru_cache did)."""
    routing_tables.cache_clear()
    base_routes = _xy_route_cached.cache_info().currsize
    for i in range(1000):
        cfg = NocConfig(4, 4, link_bw=1.0 + i * 1e-6)
        t = routing_tables(cfg)
        inc = stacked_incidence(cfg, np.asarray([1, 5, 9]), 4)
        assert inc.shape == (3, t.n_links)
        assert hops(cfg, (0, 0), (3, 3)) == 6
    info = routing_tables.cache_info()
    assert info.maxsize is not None and info.currsize <= info.maxsize <= 64
    rinfo = _xy_route_cached.cache_info()
    assert rinfo.maxsize is not None
    assert rinfo.currsize <= rinfo.maxsize
    hinfo = hops.cache_info()
    assert hinfo.maxsize is not None and hinfo.currsize <= hinfo.maxsize
    assert base_routes <= rinfo.maxsize


def test_island_of_memoized_per_instance():
    from repro.core.tiles import default_plan
    from repro.configs import get_config
    cfg = default_islands(default_plan(get_config("granite-8b").reduced()))
    first = cfg.island_of(cfg.islands[0].tiles[0])
    assert first is cfg.islands[0]
    assert "_tile_index_cache" in cfg.__dict__          # memo built
    # linear-scan semantics preserved: unknown tile raises KeyError
    with pytest.raises(KeyError):
        cfg.island_of("no-such-tile")
    # rate changes build a new instance -> fresh map, updated rates seen
    name = next(i.name for i in cfg.islands if not i.fixed)
    cfg2 = cfg.with_rates({name: 0.2})
    assert "_tile_index_cache" not in cfg2.__dict__
    assert cfg2.rate_of(cfg2.island_of(
        next(t for i in cfg2.islands if i.name == name
             for t in i.tiles)).tiles[0]) == pytest.approx(
        dict((i.name, i.rate) for i in cfg2.islands)[name])


# ----------------------------------------------------- 1e8-point soak
@pytest.mark.slow
def test_chunked_1e8_points_under_memory_bound():
    """>=1e8-point per-island chunked sweep completes with peak tracked
    block memory under the documented bound (~41 bytes/point of chunk),
    and its top survivor reproduces the scalar model exactly."""
    m = SoCPerfModel()
    chunk = 4_000_000
    res = grid_sweep(
        m, WLS3, ks=(1, 2, 4), acc_rates=TILE_LADDER.levels(),
        noc_rates=NOC_LADDER.levels(), tg_rates=(0.5, 0.75, 1.0),
        positions=((1, 1), (3, 3), (0, 2), (2, 2), (1, 2), (0, 1)),
        n_tg=4, island_rates="independent", chunk_points=chunk)
    assert len(res) >= 100_000_000, len(res)
    # documented memory model: ~41 bytes per chunk point (5 float64
    # panels incl. one kernel temp + 1 bool mask), rounded up to whole
    # trailing-axis panels
    assert res.peak_chunk_bytes <= 41 * 2 * chunk
    dp = res.design_point(int(res.topk_indices(1)[0]))
    total = sum(
        m.accel_throughput(
            AccelWorkload(w.name, w.base_mbps, w.ai,
                          replication=dp.replication[w.name]),
            dp.placement[w.name],
            {"acc": dp.rates[w.name], "noc_mem": dp.rates["noc_mem"],
             "tg": dp.rates["tg"]}, res.n_tg)
        for w in WLS3)
    assert dp.throughput == pytest.approx(total, rel=1e-9)
