"""Dry-run spec builders + DFS energy policy + SSM long-context decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import abstract_mesh
import repro.core as C
from repro.configs import get_config
from repro.configs.base import LM_SHAPES, ShapeConfig
from repro.launch import specs as SP
from repro.models.layers import AttnOptions
from repro.models.transformer import LM


def _mesh11():
    # a 1-device mesh with the production axis NAMES exercises all spec
    # logic (divisibility checks treat size-1 axes as always divisible)
    return jax.make_mesh((1, 1), ("data", "model"))


def test_cache_shardings_cover_every_leaf():
    mesh = _mesh11()
    for arch in ("granite-8b", "deepseek-v2-lite-16b", "mamba2-370m",
                 "zamba2-7b"):
        cfg = get_config(arch)
        lm = LM(cfg, opts=AttnOptions(backend="naive"), remat=False)
        cache_abs, tok = SP.abstract_decode_inputs(
            lm, ShapeConfig("d", 256, 4, "decode"))
        sh = SP.cache_shardings(lm, cache_abs, mesh)
        n_abs = len(jax.tree_util.tree_leaves(cache_abs))
        n_sh = len(jax.tree_util.tree_leaves(
            sh, is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding)))
        assert n_abs == n_sh, (arch, n_abs, n_sh)


def test_batch_shardings_fallback_drops_trailing_axes():
    """global_batch < product(batch axes) must fall back, never replicate
    silently (the multi-pod FSDP regression)."""
    mesh = abstract_mesh((2, 2, 2), ("pod", "data", "model"))
    batch = {"tokens": jax.ShapeDtypeStruct((4, 8), jnp.int32)}
    sh = SP.batch_shardings(batch, mesh, extra=("model",))
    spec = sh["tokens"].spec
    # 4 % 8 != 0 -> drop "model": (pod, data) = 4-way fits exactly
    assert spec[0] == ("pod", "data")


def test_param_shardings_respect_divisibility():
    mesh = abstract_mesh((1, 2), ("data", "model"))
    cfg = get_config("phi3-medium-14b")       # kv = 10 heads
    lm = LM(cfg, opts=AttnOptions(backend="naive"), remat=False)
    sh = SP.param_shardings(lm, mesh)
    # flattened kv dim 10*128=1280 divides 2 -> sharded
    assert sh["blocks"]["attn"]["wk"].spec[2] == "model"
    # norm scales replicated
    assert sh["final_norm"].spec == jax.sharding.PartitionSpec(None,)


def test_energy_policy_derates_within_throughput_budget():
    cfg = get_config("granite-8b")
    plan = C.default_plan(cfg)
    islands = C.default_islands(plan)
    tel = {t.name: C.TileTelemetry(1.0, 0, 0, 0, 0.9) for t in plan.tiles}

    def perf_eval(rates):
        # toy model: throughput set by noc_mem; power sums islands
        tps = 100.0 * rates.get("noc_mem", 1.0)
        watts = sum(C.chip_power(r, 1.0) for r in rates.values())
        return tps, watts

    best = C.policy_energy_per_token(islands, tel, perf_eval)
    tps, _ = perf_eval(best)
    base_tps, _ = perf_eval({k: 1.0 for k in best})
    assert tps >= 0.98 * base_tps              # throughput constraint held
    # at least one non-bottleneck island was derated
    assert any(v < 1.0 for k, v in best.items() if k != "noc_mem")


def test_ssm_long_decode_past_window():
    """Mamba2 decode is O(1): decoding 3x past the 'cache length' works and
    matches the full forward (no window to evict)."""
    cfg = get_config("mamba2-370m").reduced()
    lm = LM(cfg, opts=AttnOptions(backend="naive"), remat=False)
    params = jax.tree_util.tree_map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        lm.init(jax.random.PRNGKey(0)))
    B, S = 1, 97
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    full, _ = lm.forward(params, tokens=toks)
    _, cache = lm.prefill(params, tokens=toks[:, :32])
    cache = jax.tree_util.tree_map(
        lambda a: a.astype(jnp.float32) if hasattr(a, "dtype")
        and a.dtype == jnp.bfloat16 else a, cache)
    for t in range(32, S):
        lg, cache = lm.decode_step(params, cache, tokens=toks[:, t:t + 1])
    scale = float(jnp.max(jnp.abs(full)))
    err = float(jnp.max(jnp.abs(lg - full[:, -1]))) / scale
    assert err < 1e-3, err


def test_hbm_model_moe_decode_reads_full_weights():
    from repro.launch.costing import hbm_bytes
    cfg = get_config("deepseek-v2-lite-16b")
    dec = hbm_bytes(cfg, LM_SHAPES["decode_32k"])
    # batch 128 x top-6 >> 64 experts: the sweep reads ~all weights
    assert dec > cfg.n_params() * 2


def test_mra_k_scales_weight_reads():
    from repro.launch.costing import hbm_bytes
    cfg = get_config("deepseek-v2-lite-16b")
    b1 = hbm_bytes(cfg, LM_SHAPES["decode_32k"], mra_k=1)
    b4 = hbm_bytes(cfg, LM_SHAPES["decode_32k"], mra_k=4)
    assert b4 > b1                              # the paper's area cost
    assert b4 - b1 == pytest.approx(3 * cfg.n_params() * 2, rel=0.01)
