"""Tile-to-tile flow patterns, per-design arrival tensors and the
load-balancer admission policy: differential + property tests.

The load-bearing guarantees of the generalized co-sim surface:

* **differential parity** — the batched engine at B=1 is *bit-for-bit*
  the sequential engine on tile-to-tile chains (open loop, DFS
  controllers, the load balancer, and all of them together), and a
  shared ``(T, A)`` trace broadcast to a ``(T, B, A)`` tensor reproduces
  the shared-trace replay exactly;
* **properties** — link-level flow conservation (the dense incidence
  contraction equals the ragged reference accumulation, and every route
  covers exactly its hop count of links), chain-stage completion-curve
  ordering (stage ``i+1`` never completes more than stage ``i``), queue
  non-negativity / work conservation with the forward coupling in the
  loop, and the balancer's per-group splits summing to the offered load
  — hypothesis-fuzzed when available, seeded sweeps otherwise;
* **the scenario gate** — on a replicated-accelerator pipeline SoC with
  a hotspot workload, load balancing + DFS achieves lower energy/request
  than DFS-only and than load-balancer-only without giving up tail
  latency.
"""
import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.core.dfs import (BatchMemoryBoundPolicy, BatchPIDRatePolicy,
                            PIDRatePolicy, policy_memory_bound)
from repro.core.dse import closed_loop_score, grid_sweep
from repro.core.noc import (NocConfig, flow_incidence, link_loads_batch,
                            pos_index, routing_tables)
from repro.core.perfmodel import AccelWorkload, SoCPerfModel
from repro.sim import (BatchControllerHarness, BatchSimEngine,
                       BatchSimPlatform, BatchTrace, ControllerHarness,
                       FlowPattern, LoadBalancer, SimConfig, SimEngine,
                       SimPlatform, Trace, compile_flows, constant_trace,
                       diurnal_trace, mmpp_trace)
from functools import partial


# --------------------------------------------------------------- fixtures
STAGE0 = ("fe0", "fe1", "fe2")
STAGE1 = ("be0", "be1", "be2")
PIPE = FlowPattern.chain(STAGE0, STAGE1)
GROUPS = (STAGE0, STAGE1)


def pipeline_platform(*, n_tg=2, req_mb=0.005, noc_rate=1.0, k=8,
                      flows=PIPE):
    """3 front-end + 3 back-end stream-bound tiles chained front->back."""
    m = SoCPerfModel()
    pos = [(r, c) for r in range(4) for c in range(4)
           if (r, c) not in {(1, 0), (0, 0), (0, 3)}][:6]
    wls = [AccelWorkload("dfmul", 8.70, 1.1, replication=k) for _ in pos]
    return SimPlatform.build(m, wls, pos, names=STAGE0 + STAGE1,
                             noc_rate=noc_rate, n_tg=n_tg, req_mb=req_mb,
                             flows=flows)


def hotspot_trace(rate_per_tick, ticks=900, *, dt=1e-3, seed=3,
                  spread=False):
    """External arrivals land on the front-end stage only — all on fe0
    (the hotspot) or evenly over the stage (``spread``)."""
    rng = np.random.default_rng(seed)
    arr = np.zeros((ticks, 6))
    lam = np.full(3, rate_per_tick / 3.0) if spread else \
        np.asarray([rate_per_tick, 0.0, 0.0])
    arr[:, :3] = rng.poisson(np.broadcast_to(lam, (ticks, 3)))
    return Trace(arr, dt)


def batch_controller(bplat, policy, **kw):
    return BatchControllerHarness(bplat.islands, bplat.rates, policy,
                                  tile_names=bplat.names, **kw)


# -------------------------------------------------- flow compile + tables
def test_compile_flows_default_is_legacy_mem_pattern():
    plat = pipeline_platform(flows=None)
    m = plat.model
    cf = compile_flows(m, plat.names, plat.pos_idx, None)
    mem_idx = pos_index(m.noc, m.mem_pos)
    assert np.all(cf.dst_idx == mem_idx)
    np.testing.assert_array_equal(cf.hop_counts,
                                  m.hop_counts(pos_idx=plat.pos_idx))
    np.testing.assert_array_equal(cf.inc, SimEngine(plat)._inc)
    assert cf.forward is None and not cf.chained
    assert cf.demand == m.own_demand and isinstance(cf.demand, float)


def test_compile_flows_chain_routes_and_forward():
    plat = pipeline_platform()
    m = plat.model
    cf = compile_flows(m, plat.names, plat.pos_idx, PIPE)
    # front-end tile j streams to its assigned back-end replica; the
    # back-end (last stage) streams to MEM
    for j in range(3):
        assert cf.dst_idx[j] == plat.pos_idx[3 + j]
    mem_idx = pos_index(m.noc, m.mem_pos)
    assert np.all(cf.dst_idx[3:] == mem_idx)
    # hop counts follow the actual destinations
    t = routing_tables(m.noc)
    np.testing.assert_array_equal(
        cf.hop_counts, t.hop_matrix[plat.pos_idx, cf.dst_idx])
    # forward: stage-0 rows split uniformly over stage 1; last stage exits
    F = cf.forward
    np.testing.assert_allclose(F[:3, 3:], np.full((3, 3), 1.0 / 3.0))
    assert np.all(F[:3, :3] == 0.0) and np.all(F[3:, :] == 0.0)
    np.testing.assert_array_equal(cf.stage_of, [0, 0, 0, 1, 1, 1])


def test_flow_pattern_validation():
    with pytest.raises(AssertionError):        # tile in two stages
        FlowPattern.chain(("a", "b"), ("b",))
    with pytest.raises(AssertionError):        # empty stage
        FlowPattern(stages=((),))
    plat = pipeline_platform()
    with pytest.raises(AssertionError):        # unknown stage tile
        compile_flows(plat.model, plat.names, plat.pos_idx,
                      FlowPattern.chain(("nope",), STAGE1))
    with pytest.raises(AssertionError):        # self-stream
        compile_flows(plat.model, plat.names, plat.pos_idx,
                      FlowPattern(dests={"fe0": "fe0"}))
    with pytest.raises(AssertionError):        # unknown demand override
        compile_flows(plat.model, plat.names, plat.pos_idx,
                      FlowPattern(demand={"nope": 0.3}))
    with pytest.raises(AssertionError):        # contradictory dests
        FlowPattern(dests=(("a", "b"), ("a", "c")))
    with pytest.raises(AssertionError):        # contradictory demand
        FlowPattern(demand=(("a", 0.1), ("a", 0.2)))
    # dicts freeze to sorted tuples: structural equality across spellings
    assert FlowPattern(dests={"a": "b", "c": "MEM"}) == \
        FlowPattern(dests=(("c", "MEM"), ("a", "b")))


def check_link_flow_conservation(cfg, src, dst, busy, demand):
    """The dense incidence contraction the tick loop runs distributes
    exactly each flow's demand onto each link of its route: it matches
    the ragged reference accumulation, and each route covers exactly its
    hop count of links."""
    inc, hops = flow_incidence(cfg, src, dst)
    np.testing.assert_array_equal(inc.sum(axis=-1), hops)
    loads = np.einsum("a,al->l", demand * busy, inc)
    ref = link_loads_batch(cfg, src, dst, demand * busy)
    np.testing.assert_allclose(loads, ref, rtol=1e-12, atol=1e-12)
    # total offered bytes are conserved onto links: demand x hops each
    np.testing.assert_allclose(loads.sum(), (demand * busy * hops).sum(),
                               rtol=1e-12)


@pytest.mark.parametrize("seed", range(5))
def test_link_flow_conservation_seeded(seed):
    rng = np.random.default_rng(seed)
    cfg = NocConfig(4, 4, torus=bool(seed % 2))
    n = cfg.rows * cfg.cols
    A = int(rng.integers(1, 10))
    src = rng.integers(0, n, size=A)
    dst = rng.integers(0, n, size=A)
    check_link_flow_conservation(cfg, src, dst, rng.random(A),
                                 rng.random(A) * 0.4)


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 6),
       st.booleans(), st.integers(min_value=1, max_value=12))
def test_link_flow_conservation_fuzzed(seed, torus, n_flows):
    rng = np.random.default_rng(seed)
    cfg = NocConfig(4, 4, torus=torus)
    n = cfg.rows * cfg.cols
    check_link_flow_conservation(
        cfg, rng.integers(0, n, size=n_flows),
        rng.integers(0, n, size=n_flows),
        rng.random(n_flows), rng.random(n_flows) * 0.4)


# ------------------------------------------------------ differential: B=1
@pytest.mark.parametrize("kind", ["hotspot", "spread", "mmpp"])
@pytest.mark.parametrize("ctl,lb", [(False, False), (True, False),
                                    (False, True), (True, True)])
def test_pipeline_b1_matches_sequential_bitforbit(kind, ctl, lb):
    """B=1 batched tile-to-tile chain replay == sequential engine,
    bit-for-bit, with every combination of DFS controller and balancer."""
    plat = pipeline_platform()
    bplat = BatchSimPlatform.stack([plat])
    if kind == "mmpp":
        cap = SimEngine(plat).capacity_rps()
        tr = mmpp_trace(cap * 0.1, cap * 1.2, 700, 6, dt=1e-3, seed=4)
    else:
        tr = hotspot_trace(14.0, 700, spread=(kind == "spread"))
    cfg = SimConfig(control_interval=25)
    s_ctl = (ControllerHarness(
        plat.islands, partial(policy_memory_bound, threshold=0.55,
                              low_rate=0.5), queue_guard_ticks=3.0)
        if ctl else None)
    b_ctl = (batch_controller(
        bplat, BatchMemoryBoundPolicy(threshold=0.55, low_rate=0.5),
        queue_guard_ticks=3.0) if ctl else None)
    mk_lb = (lambda names: LoadBalancer(GROUPS, names)) if lb else \
        (lambda names: None)

    seq_eng = SimEngine(plat, config=cfg, controller=s_ctl,
                        balancer=mk_lb(plat.names))
    seq = seq_eng.run(tr)
    bat_eng = BatchSimEngine(bplat, config=cfg, controller=b_ctl,
                             balancer=mk_lb(bplat.names))
    bat = bat_eng.run(tr)

    assert bat.completed[0] == seq.completed
    assert bat.residual[0] == seq.residual
    assert bat.energy_j[0] == seq.energy_j
    assert bat.p50_latency_s[0] == seq.p50_latency_s
    assert bat.p99_latency_s[0] == seq.p99_latency_s
    for f in ("queue", "busy", "pkts_in", "pkts_out", "rtt_acc"):
        np.testing.assert_array_equal(
            getattr(bat_eng.last_state, f)[0],
            getattr(seq_eng.last_state, f), err_msg=f)
    adm_b, srv_b = bat_eng.last_histories
    adm_s, srv_s = seq_eng.last_histories
    np.testing.assert_array_equal(adm_b[:, 0], adm_s)
    np.testing.assert_array_equal(srv_b[:, 0], srv_s)
    if ctl:
        assert int(bat.swaps[0]) == seq.swaps
        seq_rates = np.asarray([i.rate for i in s_ctl.live().islands])
        np.testing.assert_array_equal(b_ctl.rates[0], seq_rates)


# ------------------------------------------ per-design arrival tensors
@pytest.mark.parametrize("controlled", [False, True])
def test_broadcast_batch_trace_reproduces_shared_trace_exactly(controlled):
    """(T, A) broadcast to (T, B, A) == the shared-trace replay,
    bit-for-bit (incl. flows + balancer + controller in the loop)."""
    plats = [pipeline_platform(noc_rate=r) for r in (1.0, 0.8, 0.6)]
    bplat = BatchSimPlatform.stack(plats)
    tr = hotspot_trace(12.0, 600)
    cfg = SimConfig(control_interval=25)

    def mk():
        ctl = (batch_controller(bplat, BatchPIDRatePolicy(target=0.7),
                                queue_guard_ticks=3.0)
               if controlled else None)
        return BatchSimEngine(bplat, config=cfg, controller=ctl,
                              balancer=LoadBalancer(GROUPS, bplat.names))

    e_shared = mk()
    r_shared = e_shared.run(tr)
    e_bcast = mk()
    r_bcast = e_bcast.run(BatchTrace.broadcast(tr, bplat.n_designs))

    for f in ("completed", "residual", "energy_j", "p50_latency_s",
              "p99_latency_s", "dropped", "swaps"):
        np.testing.assert_array_equal(getattr(r_bcast, f),
                                      getattr(r_shared, f), err_msg=f)
    np.testing.assert_array_equal(e_bcast.last_histories[1],
                                  e_shared.last_histories[1])
    np.testing.assert_allclose(r_bcast.offered,
                               np.full(3, float(tr.arrivals.sum())))


def test_stacked_batch_trace_rows_match_per_design_sequential():
    """Each design of a (T, B, A) tensor replays ITS OWN trace: batch
    rows are bit-for-bit the sequential runs on the per-design slices."""
    plat = pipeline_platform()
    B = 3
    traces = [hotspot_trace(10.0 + 3 * b, 500, seed=b, spread=(b == 1))
              for b in range(B)]
    bt = BatchTrace.stack(traces)
    assert bt.n_designs == B and bt.ticks == 500
    bplat = BatchSimPlatform.stack([plat] * B)
    lb = LoadBalancer(GROUPS, plat.names)
    bat_eng = BatchSimEngine(bplat, balancer=lb)
    bat = bat_eng.run(bt)
    for b in range(B):
        seq_eng = SimEngine(plat, balancer=LoadBalancer(GROUPS, plat.names))
        seq = seq_eng.run(bt.design(b))
        # the tick-by-tick simulation of each row is bit-identical
        np.testing.assert_array_equal(bat_eng.last_histories[0][:, b],
                                      seq_eng.last_histories[0], err_msg=b)
        np.testing.assert_array_equal(bat_eng.last_histories[1][:, b],
                                      seq_eng.last_histories[1], err_msg=b)
        assert bat.energy_j[b] == seq.energy_j, b
        assert bat.p99_latency_s[b] == seq.p99_latency_s, b
        assert bat.residual[b] == seq.residual, b
        # summary aggregates reduce (T, B, A) slabs in a different order
        # than (T, A) ones — float64 roundoff, not bit-for-bit
        np.testing.assert_allclose(bat.completed[b], seq.completed,
                                   rtol=1e-12)
        np.testing.assert_allclose(bat.offered[b], seq.offered, rtol=1e-12)


def test_batch_trace_shape_guards():
    plat = pipeline_platform()
    bplat = BatchSimPlatform.stack([plat, plat])
    tr = hotspot_trace(10.0, 50)
    with pytest.raises(AssertionError):        # design-axis mismatch
        BatchSimEngine(bplat).run(BatchTrace.broadcast(tr, 3))
    with pytest.raises(AssertionError):        # dest mismatch
        BatchSimEngine(bplat).run(Trace(np.zeros((50, 4)), 1e-3))
    with pytest.raises(AssertionError):        # 2-D tensor is not a batch
        BatchTrace(np.zeros((50, 6)), 1e-3)


# ------------------------------------------------------------- invariants
def check_pipeline_invariants(ext_arrivals, *, lb_mode=None, control=False,
                              max_queue=float("inf")) -> None:
    """Replay a random external trace through the chained platform and
    assert the fluid/chain invariants at every tick."""
    ext_arrivals = np.asarray(ext_arrivals, dtype=np.float64)
    T = ext_arrivals.shape[0]
    plat = pipeline_platform()
    bplat = BatchSimPlatform.stack([plat])
    ctl = (batch_controller(bplat, BatchPIDRatePolicy(target=0.6),
                            queue_guard_ticks=2.0) if control else None)
    lb = (LoadBalancer(GROUPS, plat.names, mode=lb_mode)
          if lb_mode else None)
    eng = BatchSimEngine(bplat, config=SimConfig(control_interval=10,
                                                 max_queue=max_queue),
                         controller=ctl, balancer=lb)
    r = eng.run(Trace(ext_arrivals, 1e-3))
    admitted, served = (h[:, 0] for h in eng.last_histories)

    ca = np.cumsum(admitted, axis=0)
    cs = np.cumsum(served, axis=0)
    # queue non-negativity + per-tile work conservation (with the chain
    # coupling, "arrivals" at a tile include forwarded completions)
    backlog = ca - cs
    assert np.all(backlog >= -1e-9)
    assert np.all(served >= -1e-12)
    np.testing.assert_allclose(backlog[-1].sum(), r.residual[0],
                               rtol=1e-9, atol=1e-9)
    # monotone completion curves
    assert np.all(np.diff(cs, axis=0) >= -1e-12)
    # chain-stage completion ordering: the back-end can never have
    # completed more than the front-end has handed it
    np.testing.assert_array_less(cs[:, 3:].sum(axis=1),
                                 cs[:, :3].sum(axis=1) + 1e-9)
    # admitted totals == external + landed forwarded completions, and
    # each external request completes at most once (exit-stage services):
    # external = completed + backlog + the final tick's in-flight carry
    if max_queue == float("inf"):
        fwd = np.einsum("ta,aj->tj", served, eng._forward)
        np.testing.assert_allclose(
            admitted.sum(), ext_arrivals.sum() + fwd[:-1].sum(),
            rtol=1e-9)
        np.testing.assert_allclose(
            r.completed[0] + backlog[-1].sum() + fwd[-1].sum(),
            ext_arrivals.sum(), rtol=1e-9, atol=1e-9)
        assert r.completed[0] <= ext_arrivals.sum() + 1e-9


def check_balancer_split(arr, queue, cap, groups, names, mode) -> None:
    lb = LoadBalancer(groups, names, mode=mode)
    out = lb.split(arr, queue, cap)
    assert out.shape == np.asarray(arr).shape
    assert np.all(out >= -1e-12)
    # per-group sums preserved: the split IS the offered load
    for g in groups:
        ids = [names.index(t) for t in g]
        np.testing.assert_allclose(out[..., ids].sum(axis=-1),
                                   np.asarray(arr)[..., ids].sum(axis=-1),
                                   rtol=1e-9, atol=1e-9)
    # uncovered tiles pass through untouched
    ungrouped = [i for i, n in enumerate(names)
                 if not any(n in g for g in groups)]
    if ungrouped:
        np.testing.assert_array_equal(out[..., ungrouped],
                                      np.asarray(arr, dtype=np.float64)
                                      [..., ungrouped])


BAL_SEEDS = [(s, m) for s in range(3)
             for m in ("even", "capacity", "adaptive")]


@pytest.mark.parametrize("seed,mode", BAL_SEEDS)
def test_balancer_split_sums_seeded(seed, mode):
    rng = np.random.default_rng(seed)
    names = tuple(f"t{i}" for i in range(7))
    groups = (("t0", "t1", "t2"), ("t4", "t5"))
    lead = () if seed == 0 else (4,)
    check_balancer_split(rng.gamma(1.0, 20.0, lead + (7,)),
                         rng.gamma(1.0, 5.0, lead + (7,)),
                         rng.random(lead + (7,)) * 10 + 0.1,
                         groups, names, mode)


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 6),
       st.sampled_from(LoadBalancer.MODES), st.booleans())
def test_balancer_split_sums_fuzzed(seed, mode, batched):
    rng = np.random.default_rng(seed)
    names = tuple(f"t{i}" for i in range(6))
    groups = (("t0", "t3"), ("t1", "t2", "t5"))
    lead = (int(rng.integers(1, 5)),) if batched else ()
    check_balancer_split(rng.gamma(1.0, 30.0, lead + (6,)),
                         rng.gamma(1.0, 8.0, lead + (6,)),
                         rng.random(lead + (6,)) * 5 + 1e-3,
                         groups, names, mode)


def test_balancer_zero_weight_group_falls_back_to_even_split():
    """A group whose every replica weighs zero (cap forced to 0) must
    still conserve its offered load — even split, never discarded."""
    names = ("a", "b", "c", "d")
    groups = (("a", "b"), ("c", "d"))
    arr = np.asarray([10.0, 2.0, 8.0, 0.0])
    queue = np.zeros(4)
    cap = np.asarray([0.0, 0.0, 3.0, 1.0])     # group 0 fully gated
    for mode in ("capacity", "adaptive"):
        out = LoadBalancer(groups, names, mode=mode).split(arr, queue, cap)
        np.testing.assert_allclose(out[:2], [6.0, 6.0], err_msg=mode)
        np.testing.assert_allclose(out[2:].sum(), 8.0, err_msg=mode)
    # and the generic conservation checker agrees
    check_balancer_split(arr, queue, cap, groups, names, "capacity")


def test_balancer_group_validation():
    names = ("a", "b", "c")
    with pytest.raises(AssertionError):
        LoadBalancer([("a", "zz")], names)
    with pytest.raises(AssertionError):
        LoadBalancer([("a",), ("a", "b")], names)      # overlapping
    with pytest.raises(AssertionError):
        LoadBalancer([("a", "b")], names, mode="nope")


PIPE_SEEDS = [
    (0, None, False, float("inf")), (1, "adaptive", False, float("inf")),
    (2, "capacity", True, float("inf")), (3, "even", True, 25.0),
    (4, "adaptive", True, 10.0),
]


@pytest.mark.parametrize("seed,lb_mode,control,max_queue", PIPE_SEEDS)
def test_pipeline_invariants_seeded(seed, lb_mode, control, max_queue):
    rng = np.random.default_rng(seed)
    T = int(rng.integers(30, 90))
    ext = np.zeros((T, 6))
    ext[:, :3] = rng.gamma(1.5, 6.0, size=(T, 3)) * rng.random((T, 1))
    check_pipeline_invariants(ext, lb_mode=lb_mode, control=control,
                              max_queue=max_queue)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 6),
       st.integers(min_value=10, max_value=70),
       st.sampled_from((None,) + LoadBalancer.MODES),
       st.booleans(), st.booleans())
def test_pipeline_invariants_fuzzed(seed, ticks, lb_mode, control, bounded):
    rng = np.random.default_rng(seed)
    ext = np.zeros((ticks, 6))
    ext[:, :3] = rng.gamma(1.2, 8.0, size=(ticks, 3)) * rng.random(
        (ticks, 1))
    check_pipeline_invariants(
        ext, lb_mode=lb_mode, control=control,
        max_queue=(30.0 if bounded else float("inf")))


# ------------------------------------------------------- jax scan backend
def test_jax_backend_matches_numpy_on_pipeline():
    pytest.importorskip("jax")
    plats = [pipeline_platform(noc_rate=r) for r in (1.0, 0.8)]
    bplat = BatchSimPlatform.stack(plats)
    bt = BatchTrace.stack([hotspot_trace(12.0, 500, seed=1),
                           hotspot_trace(9.0, 500, seed=2, spread=True)])
    cfg = SimConfig(control_interval=25)

    def mk(backend):
        ctl = batch_controller(
            bplat, BatchMemoryBoundPolicy(threshold=0.55, low_rate=0.5),
            queue_guard_ticks=3.0)
        return BatchSimEngine(bplat, config=cfg, controller=ctl,
                              balancer=LoadBalancer(GROUPS, bplat.names),
                              backend=backend)

    rn = mk("numpy").run(bt)
    rj = mk("jax").run(bt)
    np.testing.assert_allclose(rj.completed, rn.completed, rtol=1e-3)
    np.testing.assert_allclose(rj.energy_j, rn.energy_j, rtol=1e-3)
    np.testing.assert_allclose(rj.residual, rn.residual,
                               rtol=1e-3, atol=1e-2)
    np.testing.assert_array_equal(rj.swaps, rn.swaps)
    np.testing.assert_allclose(rj.p99_latency_s, rn.p99_latency_s,
                               atol=2 * bt.dt, rtol=0.05)


# ----------------------------------------------- DSE bridge: flows in loop
def _pipeline_sweep():
    m = SoCPerfModel()
    wls = [AccelWorkload("dfadd", 9.22, 0.9),
           AccelWorkload("dfmul", 8.70, 1.1)]
    res = grid_sweep(m, wls, ks=(1, 2, 4, 8), acc_rates=(0.2, 0.6, 1.0),
                     noc_rates=(0.5, 1.0), n_tg=2)
    return m, res


def test_closed_loop_score_pipeline_batch_matches_sequential():
    """Scoring survivors under a pipeline workload (flows= + balancer in
    the loop): the batched replay == the sequential reference, ranking
    and scores identical."""
    m, res = _pipeline_sweep()
    idx = res.topk_indices(12)
    flows = FlowPattern.chain(("dfadd",), ("dfmul",))
    ext = np.zeros((400, 2))
    ext[:, 0] = np.random.default_rng(5).poisson(3.0, 400)
    tr = Trace(ext, 1e-3)
    kw = dict(model=m, indices=idx, req_mb=0.002, flows=flows,
              sim_config=SimConfig(control_interval=25),
              balancer_factory=lambda p: LoadBalancer(
                  [("dfadd",), ("dfmul",)], p.names))
    seq = closed_loop_score(
        res, tr, batch=False,
        controller_factory=lambda p: ControllerHarness(
            p.islands, PIDRatePolicy(target=0.7), queue_guard_ticks=3.0),
        **kw)
    bat = closed_loop_score(
        res, tr,
        batch_controller_factory=lambda bp: BatchControllerHarness(
            bp.islands, bp.rates, BatchPIDRatePolicy(target=0.7),
            tile_names=bp.names, queue_guard_ticks=3.0),
        **kw)
    np.testing.assert_array_equal(bat.p99_latency_s, seq.p99_latency_s)
    np.testing.assert_array_equal(bat.energy_per_request_j,
                                  seq.energy_per_request_j)
    np.testing.assert_array_equal(bat.ranked_indices(), seq.ranked_indices())


def test_closed_loop_score_accepts_batch_trace_both_paths():
    """A per-design (T, B, A) tensor scores each survivor on its own
    trace; the sequential path slices the same tensor per design and
    produces identical scores."""
    m, res = _pipeline_sweep()
    idx = res.topk_indices(6)
    rng = np.random.default_rng(9)
    bt = BatchTrace(rng.poisson(2.0, (300, 6, 2)).astype(float), 1e-3)
    a = closed_loop_score(res, bt, model=m, indices=idx, req_mb=0.002)
    b = closed_loop_score(res, bt, model=m, indices=idx, req_mb=0.002,
                          batch=False)
    np.testing.assert_array_equal(a.p99_latency_s, b.p99_latency_s)
    np.testing.assert_array_equal(a.energy_per_request_j,
                                  b.energy_per_request_j)
    np.testing.assert_array_equal(a.ranked_indices(), b.ranked_indices())
    # the per-design tensors actually differed
    assert len(np.unique(a.p99_latency_s)) > 1 or \
        len(np.unique(a.energy_per_request_j)) > 1
    # a design-axis / survivor-count mismatch is rejected up front on
    # BOTH paths (never silently pairs survivor j with the wrong row)
    for batch in (True, False):
        with pytest.raises(AssertionError):
            closed_loop_score(res, bt, model=m, indices=idx[:4],
                              req_mb=0.002, batch=batch)


# ------------------------------------------------------- the scenario gate
def scenario_runs(ticks=2500, seed=11):
    """LB+DFS vs DFS-only vs LB-only on the replicated pipeline SoC under
    a hotspot diurnal workload (all external load on fe0)."""
    plat = pipeline_platform()
    rng = np.random.default_rng(seed)
    t = np.arange(ticks)
    lam = 13.0 * (1.0 + 0.4 * np.sin(2 * np.pi * t / ticks))
    ext = np.zeros((ticks, 6))
    ext[:, 0] = rng.poisson(lam)
    tr = Trace(ext, 1e-3)
    cfg = SimConfig(control_interval=25)

    def run(dfs, lb):
        ctl = (ControllerHarness(
            plat.islands, partial(policy_memory_bound, threshold=0.55,
                                  low_rate=0.5), queue_guard_ticks=3.0)
            if dfs else None)
        bal = LoadBalancer(GROUPS, plat.names) if lb else None
        return SimEngine(plat, config=cfg, controller=ctl,
                         balancer=bal).run(tr)

    return {"dfs_only": run(True, False), "lb_only": run(False, True),
            "lb_dfs": run(True, True)}


def test_scenario_lb_plus_dfs_beats_either_alone():
    """The acceptance gate: on the replicated-accelerator pipeline SoC,
    load balancing + DFS achieves lower energy/request than DFS-only and
    than LB-only, at matched (no worse) tail latency."""
    runs = scenario_runs()
    both, dfs, lb = runs["lb_dfs"], runs["dfs_only"], runs["lb_only"]
    # strictly cheaper per request than either policy alone
    assert both.energy_per_request_j < 0.97 * dfs.energy_per_request_j, \
        (both.energy_per_request_j, dfs.energy_per_request_j)
    assert both.energy_per_request_j < 0.97 * lb.energy_per_request_j, \
        (both.energy_per_request_j, lb.energy_per_request_j)
    # at matched p99 (the repo's 2x-or-5ms convention, as in
    # examples/closed_loop.py): no worse than the DFS-only tail, within
    # the matched band of the full-rate balanced tail
    assert both.p99_latency_s <= dfs.p99_latency_s
    assert both.p99_latency_s <= max(2.0 * lb.p99_latency_s, 5e-3)
    # and it does not buy this by serving less
    assert both.completed >= 0.99 * lb.completed
    assert both.completed >= dfs.completed