"""Closed-loop simulation subsystem: traffic, engine, control, DSE bridge.

The load-bearing properties:

* static parity — with a constant saturating trace and controllers off,
  the engine's sustained throughput equals the perfmodel/grid_sweep
  static prediction (the ISSUE's 5% criterion; it is exact by
  construction and tested much tighter),
* conservation — offered == completed + dropped + residual, counters
  account every admitted/served packet,
* closed loop — the Fig.-4 DFS policy cuts energy/request by >= 10% under
  diurnal traffic at bounded p99 vs. the fixed max-frequency baseline,
* the DSE bridge re-ranks sweep survivors by simulated runtime scores.
"""
import numpy as np
import pytest

from repro.core.dfs import PIDRatePolicy, policy_memory_bound
from repro.core.dse import closed_loop_score, grid_sweep
from repro.core.perfmodel import AccelWorkload, SoCPerfModel
from repro.sim import (ControllerHarness, RingBuffer, SimConfig, SimEngine,
                       SimPlatform, constant_trace, diurnal_trace, mmpp_trace,
                       poisson_trace, replay_trace, superpose,
                       weighted_percentiles, with_total)

from functools import partial


# --------------------------------------------------------------- fixtures
def make_platform(n_tiles=12, *, req_mb=0.005, noc_rate=1.0, n_tg=2, k=8):
    m = SoCPerfModel()
    pos = [(r, c) for r in range(4) for c in range(4)
           if (r, c) not in {(1, 0), (0, 0), (0, 3)}][:n_tiles]
    wls = [AccelWorkload("dfmul", 8.70, 1.1, replication=k) for _ in pos]
    return SimPlatform.build(m, wls, pos, noc_rate=noc_rate, n_tg=n_tg,
                             req_mb=req_mb)


# ---------------------------------------------------------------- traffic
def test_constant_trace_shape_and_total():
    tr = constant_trace(1000.0, 500, 4, dt=1e-3)
    assert tr.arrivals.shape == (500, 4)
    assert tr.n_requests == pytest.approx(1000.0 * 0.5)
    assert tr.offered_rps == pytest.approx(1000.0)
    # scalar rate splits evenly across destinations
    np.testing.assert_allclose(tr.arrivals.sum(axis=0),
                               1000.0 * 0.5 / 4)


def test_poisson_and_diurnal_traces_hit_target_rate():
    tr = poisson_trace(2000.0, 4000, 3, dt=1e-3, seed=0)
    assert tr.offered_rps == pytest.approx(2000.0, rel=0.05)
    dtr = diurnal_trace(2000.0, 4000, 3, dt=1e-3, depth=0.5, seed=0)
    assert dtr.offered_rps == pytest.approx(2000.0, rel=0.05)
    # the diurnal envelope actually modulates: peak half >> trough half
    per_tick = dtr.arrivals.sum(axis=1)
    assert per_tick[:2000].sum() > 1.5 * per_tick[2000:].sum()


def test_mmpp_trace_is_bursty():
    tr = mmpp_trace(200.0, 4000.0, 8000, 2, dt=1e-3, seed=3)
    per_tick = tr.arrivals.sum(axis=1)
    # burstiness: variance far above a Poisson of the same mean
    assert per_tick.var() > 2.0 * per_tick.mean()


def test_replay_trace_bins_requests():
    times = [0.0005, 0.0015, 0.0016, 0.0049, 0.1]
    dests = [0, 1, 1, 0, 1]
    tr = replay_trace(times, dests, 2, dt=1e-3, ticks=5)   # 0.1s falls out
    assert tr.arrivals.shape == (5, 2)
    assert tr.n_requests == 4
    assert tr.arrivals[0, 0] == 1 and tr.arrivals[1, 1] == 2
    assert tr.arrivals[4, 0] == 1


def test_superpose_and_with_total():
    a = constant_trace(100.0, 10, 2, dt=1e-3)
    b = constant_trace(300.0, 5, 2, dt=1e-3)
    s = superpose(a, b)
    assert s.ticks == 10
    assert s.n_requests == pytest.approx(a.n_requests + b.n_requests)
    t = with_total(s, 1234.0)
    assert t.n_requests == pytest.approx(1234.0)


# -------------------------------------------------------------- telemetry
def test_ring_buffer_wraps_chronologically():
    rb = RingBuffer(4, 2)
    for i in range(7):
        rb.append([i, 10 * i])
    assert len(rb) == 4
    assert rb.total_appended == 7
    np.testing.assert_allclose(rb.array()[:, 0], [3, 4, 5, 6])
    np.testing.assert_allclose(rb.last(), [6, 60])


def test_weighted_percentiles_match_expanded():
    rng = np.random.default_rng(0)
    vals = rng.random(50)
    wts = rng.integers(1, 20, 50)
    expanded = np.repeat(vals, wts)
    got = weighted_percentiles(vals, wts, (50.0, 99.0))
    want = np.percentile(expanded, [50, 99], method="inverted_cdf")
    np.testing.assert_allclose(got, want, atol=np.ptp(vals) * 0.05)


# --------------------------------------------- engine: parity conservation
def test_capacity_matches_scalar_perfmodel_exactly():
    plat = make_platform(5)
    eng = SimEngine(plat)
    cap = eng.capacity_rps()
    m = plat.model
    for i, name in enumerate(plat.names):
        wl = AccelWorkload("dfmul", 8.70, 1.1, replication=8)
        r, c = divmod(int(plat.pos_idx[i]), m.noc.cols)
        s = m.accel_throughput(wl, (r, c),
                               {"acc": 1.0, "noc_mem": 1.0, "tg": 1.0}, 2)
        assert cap[i] == pytest.approx(s / plat.req_mb[i], rel=1e-12)


def test_saturated_throughput_matches_static_prediction():
    """ISSUE acceptance: constant-rate trace, controllers disabled ->
    steady-state throughput within 5% of the static model (exact here)."""
    plat = make_platform(6)
    eng = SimEngine(plat, config=SimConfig(dynamic_contention=False))
    cap = eng.capacity_rps()
    tr = constant_trace(cap * 1.7, 2000, 6, dt=1e-3)    # saturate each tile
    r = eng.run(tr)
    assert r.throughput_rps == pytest.approx(cap.sum(), rel=1e-9)
    assert r.swaps == 0
    # conservation: every offered request is served, queued, or dropped
    assert (r.completed + r.residual + r.dropped
            == pytest.approx(r.offered, rel=1e-9))


def test_saturated_throughput_matches_grid_sweep_design_point():
    """The same parity through the DSE bridge: a grid_sweep survivor's
    static throughput is reproduced by replaying its SimPlatform."""
    m = SoCPerfModel()
    wls = [AccelWorkload("dfsin", 0.33, 60.0),
           AccelWorkload("gsm", 4.61, 12.0)]
    res = grid_sweep(m, wls, ks=(1, 2, 4), acc_rates=(0.6, 1.0),
                     noc_rates=(0.5, 1.0), n_tg=4)
    i = int(res.topk_indices(1)[0])
    dp = res.design_point(i)
    req_mb = 0.01
    plat = SimPlatform.from_design_point(m, dp, wls, req_mb=req_mb,
                                         n_tg=res.n_tg)
    eng = SimEngine(plat, config=SimConfig(dynamic_contention=False))
    cap = eng.capacity_rps()
    assert cap.sum() * req_mb == pytest.approx(dp.throughput, rel=1e-9)
    tr = constant_trace(cap * 2.0, 1500, 2, dt=1e-3)
    r = eng.run(tr)
    assert r.throughput_rps * req_mb == pytest.approx(dp.throughput,
                                                      rel=0.05)


def test_light_load_serves_everything_with_low_latency():
    plat = make_platform(6)
    eng = SimEngine(plat)
    cap = eng.capacity_rps()
    tr = constant_trace(cap * 0.2, 1000, 6, dt=1e-3)
    r = eng.run(tr)
    assert r.completed == pytest.approx(r.offered, rel=1e-9)
    assert r.residual == pytest.approx(0.0, abs=1e-6)
    assert r.p99_latency_s <= 2e-3          # drains within ~a tick
    assert r.energy_j > 0 and r.mean_power_w > 0


def test_max_queue_drops_overflow():
    plat = make_platform(3)
    eng = SimEngine(plat, config=SimConfig(max_queue=5.0,
                                           dynamic_contention=False))
    cap = eng.capacity_rps()
    tr = constant_trace(cap * 3.0, 800, 3, dt=1e-3)
    r = eng.run(tr)
    assert r.dropped > 0
    assert r.residual <= 5.0 * 3 + 1e-9
    assert (r.completed + r.residual + r.dropped
            == pytest.approx(r.offered, rel=1e-9))


def test_telemetry_records_and_exports_json(tmp_path):
    plat = make_platform(4)
    eng = SimEngine(plat, config=SimConfig(telemetry_interval=10,
                                           telemetry_capacity=16))
    cap = eng.capacity_rps()
    r = eng.run(constant_trace(cap * 0.5, 400, 4, dt=1e-3))
    telem = r.telemetry
    assert len(telem.scalars) == 16                 # ring capped
    assert telem.scalars.total_appended == 40
    thr = telem.series("throughput_rps")
    assert thr.shape == (16,)
    assert np.all(thr > 0)
    path = tmp_path / "telemetry.json"
    telem.to_json(str(path))
    import json
    doc = json.loads(path.read_text())
    assert doc["schema"]["tiles"] == list(plat.names)
    assert len(doc["scalars"]["throughput_rps"]) == 16


# ------------------------------------------------------------ controllers
def test_pid_policy_derates_idle_and_restores_overload():
    plat = make_platform(6)
    ctl = ControllerHarness(plat.islands, PIDRatePolicy(target=0.7),
                            queue_guard_ticks=3.0)
    eng = SimEngine(plat, config=SimConfig(control_interval=25),
                    controller=ctl)
    cap = eng.capacity_rps()
    # phase 1: near-idle -> PID should walk island rates down the ladder
    r = eng.run(constant_trace(cap * 0.05, 1500, 6, dt=1e-3))
    assert r.swaps >= 1
    live = ctl.live()
    accel_rates = [i.rate for i in live.islands if i.name != "noc_mem"]
    assert np.mean(accel_rates) < 0.6
    # phase 2: overload on the SAME controller -> rates restored upward
    r2 = eng.run(constant_trace(cap * 1.2, 1500, 6, dt=1e-3))
    live2 = ctl.live()
    rates2 = [i.rate for i in live2.islands if i.name != "noc_mem"]
    assert np.mean(rates2) > np.mean(accel_rates)


def test_queue_guard_overrides_energy_policy():
    plat = make_platform(4)
    # a policy that always asks for the floor rate — guard must win
    floor = lambda islands, telemetry: {
        i.name: 0.2 for i in islands.islands if not i.fixed}
    ctl = ControllerHarness(plat.islands, floor, queue_guard_ticks=2.0)
    eng = SimEngine(plat, config=SimConfig(control_interval=20),
                    controller=ctl)
    cap = eng.capacity_rps()
    eng.run(constant_trace(cap * 1.5, 1200, 4, dt=1e-3))
    assert any(a.guarded for a in ctl.actions)
    live = ctl.live()
    guarded_now = [i.rate for i in live.islands if i.name != "noc_mem"]
    assert max(guarded_now) == 1.0


def test_controller_noop_does_not_bump_version():
    plat = make_platform(3)
    ctl = ControllerHarness(plat.islands, lambda isl, t: {},
                            queue_guard_ticks=None)
    eng = SimEngine(plat, config=SimConfig(control_interval=10),
                    controller=ctl)
    cap = eng.capacity_rps()
    r = eng.run(constant_trace(cap * 0.3, 300, 3, dt=1e-3))
    assert r.swaps == 0
    assert ctl.live().version == plat.islands.version
    assert len(ctl.actions) == 30


def test_closed_loop_memory_bound_saves_energy_at_bounded_p99():
    """The headline claim (scaled down to stay tier-1 fast): Fig.-4 DFS
    under diurnal traffic cuts energy/request >= 10% vs fixed max
    frequency, with p99 within the same latency envelope."""
    plat = make_platform(12)
    cap = SimEngine(plat).capacity_rps()
    tr = diurnal_trace(cap * 0.3, 4000, 12, dt=1e-3, depth=0.5, seed=1)
    base = SimEngine(plat).run(tr)
    ctl = ControllerHarness(
        plat.islands,
        partial(policy_memory_bound, threshold=0.55, low_rate=0.5),
        queue_guard_ticks=3.0)
    dfs = SimEngine(plat, config=SimConfig(control_interval=25),
                    controller=ctl).run(tr)
    saving = 1.0 - dfs.energy_per_request_j / base.energy_per_request_j
    assert saving >= 0.10
    assert dfs.p99_latency_s <= max(2.0 * base.p99_latency_s, 5e-3)
    assert dfs.completed == pytest.approx(base.completed, rel=0.01)
    assert dfs.swaps >= 1


# -------------------------------------------------------------- DSE bridge
def test_closed_loop_score_reranks_survivors():
    m = SoCPerfModel()
    wls = [AccelWorkload("dfadd", 9.22, 0.9),
           AccelWorkload("dfmul", 8.70, 1.1)]
    res = grid_sweep(m, wls, ks=(1, 2, 4), acc_rates=(0.2, 0.6, 1.0),
                     noc_rates=(0.5, 1.0), n_tg=2)
    tr = diurnal_trace(2000.0, 800, 2, dt=1e-3, depth=0.4, seed=5)
    score = closed_loop_score(
        res, tr, model=m, top=4, p99_sla_s=0.05, req_mb=0.002,
        controller_factory=lambda p: ControllerHarness(
            p.islands, PIDRatePolicy(), queue_guard_ticks=3.0))
    assert score.indices.shape[0] == 4
    assert sorted(score.order.tolist()) == [0, 1, 2, 3]
    assert len(score.results) == 4
    assert np.all(score.energy_per_request_j > 0)
    # ranking is energy-ascending within the SLA-feasible prefix
    feas = score.p99_latency_s[score.order] <= 0.05
    if feas.any():
        e = score.energy_per_request_j[score.order][feas]
        assert np.all(np.diff(e) >= -1e-12)
    # every survivor came from the valid Pareto set
    assert np.all(res.valid[score.indices])


@pytest.mark.slow
def test_soak_million_request_diurnal_trace():
    """Opt-in soak (pytest -m slow): a ~1M-request diurnal day through the
    16-tile platform sustains >= 100k simulated requests/sec on CPU."""
    plat = make_platform(12)
    cap = SimEngine(plat).capacity_rps()
    tr = with_total(
        diurnal_trace(cap * 0.35, 12000, 12, dt=5e-3, depth=0.5, seed=7),
        1_000_000)
    ctl = ControllerHarness(plat.islands, PIDRatePolicy(),
                            queue_guard_ticks=3.0)
    r = SimEngine(plat, controller=ctl).run(tr)
    assert r.offered == pytest.approx(1_000_000, rel=1e-6)
    assert r.completed > 0.95 * r.offered
    assert r.requests_per_s_wall >= 100_000
