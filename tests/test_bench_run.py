"""The benchmark harness's ``--out`` schema guard (BENCH_*.json drift).

``benchmarks/run.py --json --out FILE`` emits a row list; the per-module
trajectory files (``BENCH_dse.json`` etc.) are keyed documents owned by
the individual benchmarks.  The guard must refuse to clobber anything
that is not its own schema — before any benchmark runs — and ``--force``
must override it.
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.run import (append_bench_row, amend_latest_row,  # noqa: E402
                            check_out_target, is_row_list, latest_row,
                            load_trajectory, main)


ROWS = [{"name": "x", "us_per_call": 1.0, "derived": "d"}]


def test_is_row_list_recognizes_own_schema():
    assert is_row_list(ROWS)
    assert is_row_list([])
    assert not is_row_list({"runs": {}})                # BENCH_* shape
    assert not is_row_list([{"name": "x"}])             # missing keys
    assert not is_row_list([{**ROWS[0], "extra": 1}])   # foreign keys
    assert not is_row_list("[]")
    assert not is_row_list(None)


def test_check_out_target_accepts_missing_empty_and_own(tmp_path):
    check_out_target(None)
    check_out_target(str(tmp_path / "new.json"))        # missing: fine
    empty = tmp_path / "empty.json"
    empty.write_text("")
    check_out_target(str(empty))                        # empty: fine
    own = tmp_path / "rows.json"
    own.write_text(json.dumps(ROWS))
    check_out_target(str(own))                          # re-emission: fine


@pytest.mark.parametrize("content", [
    json.dumps({"ticks": 400, "runs": {"sequential": {}}}),  # BENCH_* doc
    json.dumps({"walls": {}, "gate": {"pass": True}}),       # BENCH_observe
    json.dumps([{"name": "x"}]),                             # partial rows
    "not json at all",
])
def test_check_out_target_refuses_foreign_schema(tmp_path, content):
    target = tmp_path / "BENCH_sim_batch.json"
    target.write_text(content)
    with pytest.raises(SystemExit, match="refusing to overwrite"):
        check_out_target(str(target))
    check_out_target(str(target), force=True)           # --force overrides
    assert target.read_text() == content                # check never writes


def test_bench_observe_document_schema():
    """The committed BENCH_observe.json must carry the overhead-gate
    contract CI asserts on — in its *newest trajectory row*: per-engine
    walls and overheads, a gate block naming the gated engines with a
    passing verdict, and the metrics round-trip flag.  Catches schema
    drift between the benchmark and the CI step that parses it."""
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_observe.json")
    with open(path) as f:
        raw = json.load(f)
    assert isinstance(raw, list) and raw   # a trajectory, not a bare dict
    assert not is_row_list(raw)            # ...with foreign (gate) keys,
    doc = latest_row(path)                 # so --out still refuses it
    assert doc == raw[-1]
    gate = doc["gate"]
    assert set(gate["gated_engines"]) == {"batch_numpy", "batch_jax"}
    assert gate["max_overhead"] == pytest.approx(0.05)
    assert gate["pass"] is True
    for eng in gate["gated_engines"]:
        assert gate["counters_overhead"][eng] <= gate["max_overhead"]
        walls = doc["walls"][eng]
        assert {"off", "counters", "full"} <= set(walls)
        assert all(w > 0.0 for w in walls.values())
    assert doc["metrics_roundtrip_ok"] is True


def test_committed_bench_files_are_trajectories():
    """Every committed BENCH_*.json is a row-list trajectory (the PR 8
    migration) that the --out guard still refuses to clobber, and any
    row appended after the migration carries a recorded_utc stamp."""
    root = os.path.join(os.path.dirname(__file__), "..")
    names = [n for n in sorted(os.listdir(root))
             if n.startswith("BENCH_") and n.endswith(".json")]
    assert names, "no BENCH_*.json trajectories committed"
    for name in names:
        path = os.path.join(root, name)
        with open(path) as f:
            raw = json.load(f)
        assert isinstance(raw, list) and raw, name
        rows = load_trajectory(path)
        assert rows == raw, name
        # migrated legacy snapshots (row 0) may predate timestamping;
        # every post-migration append stamps recorded_utc
        for r in rows[1:]:
            assert "recorded_utc" in r, (name, sorted(r))
        with pytest.raises(SystemExit, match="refusing to overwrite"):
            check_out_target(path)


def test_trajectory_append_and_legacy_migration(tmp_path):
    """append_bench_row accretes timestamped rows; a legacy bare-dict
    snapshot reads as a one-row trajectory and the next append preserves
    it (the bench-trajectory bugfix: runs used to overwrite the file)."""
    path = str(tmp_path / "BENCH_x.json")
    assert load_trajectory(path) == []          # missing file
    assert latest_row(path) is None

    # legacy schema: one bare snapshot dict
    with open(path, "w") as f:
        json.dump({"runs": {"a": 1}}, f)
    assert load_trajectory(path) == [{"runs": {"a": 1}}]

    rows = append_bench_row(path, {"runs": {"a": 2}})
    assert len(rows) == 2
    assert rows[0] == {"runs": {"a": 1}}        # history preserved
    assert latest_row(path)["runs"] == {"a": 2}
    assert "recorded_utc" in latest_row(path)

    append_bench_row(path, {"runs": {"a": 3}})
    got = load_trajectory(path)
    assert len(got) == 3
    assert [r["runs"]["a"] for r in got] == [1, 2, 3]

    # amending folds into the newest row without growing the trajectory
    amend_latest_row(path, {"extra": True})
    got = load_trajectory(path)
    assert len(got) == 3 and got[-1]["extra"] is True
    assert "extra" not in got[0]

    # trajectory rows are not the harness's own --out schema
    assert not is_row_list(got)
    with pytest.raises(SystemExit, match="refusing to overwrite"):
        check_out_target(path)


def test_truncated_trajectory_salvages_complete_rows(tmp_path, capsys):
    """A partially-written file (crash mid-dump) no longer reads as an
    empty trajectory — the complete leading rows are salvaged with a
    stderr warning, so the next append preserves the history."""
    path = str(tmp_path / "BENCH_x.json")
    append_bench_row(path, {"runs": {"a": 1}})
    append_bench_row(path, {"runs": {"a": 2}})
    text = open(path).read()
    # truncate inside the SECOND row: only the first survives
    cut = text.index('"a": 2')
    with open(path, "w") as f:
        f.write(text[:cut])
    rows = load_trajectory(path)
    assert len(rows) == 1 and rows[0]["runs"] == {"a": 1}
    assert "salvaged 1 complete row" in capsys.readouterr().err
    # the append on top of the salvage keeps the surviving history
    rows = append_bench_row(path, {"runs": {"a": 3}})
    assert [r["runs"]["a"] for r in rows] == [1, 3]
    assert latest_row(path)["runs"] == {"a": 3}


def test_malformed_rows_skipped_with_warning(tmp_path, capsys):
    """Non-dict entries inside a valid JSON list are dropped (with a
    warning), not crashed on and not allowed to poison latest_row."""
    path = str(tmp_path / "BENCH_x.json")
    with open(path, "w") as f:
        json.dump([{"runs": {"a": 1}}, "garbage", 42,
                   {"runs": {"a": 2}}], f)
    rows = load_trajectory(path)
    assert [r["runs"]["a"] for r in rows] == [1, 2]
    assert "2 malformed" in capsys.readouterr().err
    assert latest_row(path)["runs"] == {"a": 2}
    # a non-list non-dict document reads as empty, with a warning
    with open(path, "w") as f:
        json.dump("whole document is a string", f)
    assert load_trajectory(path) == []
    assert "unrecognized trajectory schema" in capsys.readouterr().err


def test_append_is_atomic_write_then_rename(tmp_path, monkeypatch):
    """append_bench_row never writes the target in place: the dump goes
    to a temp file that is os.replace'd over the target, so a crash
    mid-serialization leaves the previous history intact."""
    path = str(tmp_path / "BENCH_x.json")
    append_bench_row(path, {"runs": {"a": 1}})
    before = open(path).read()

    class Boom(RuntimeError):
        pass

    def exploding_dump(*a, **k):
        raise Boom("crash mid-serialization")

    monkeypatch.setattr(json, "dump", exploding_dump)
    with pytest.raises(Boom):
        append_bench_row(path, {"runs": {"a": 2}})
    monkeypatch.undo()
    assert open(path).read() == before          # target untouched
    assert [p.name for p in tmp_path.iterdir()] == ["BENCH_x.json"]
    assert [r["runs"]["a"] for r in load_trajectory(path)] == [1]
    # amend_latest_row rides the same atomic writer
    amend_latest_row(path, {"extra": True})
    assert load_trajectory(path)[-1]["extra"] is True


def test_main_fails_fast_before_running_benchmarks(tmp_path):
    """A foreign --out target aborts in the argument phase — no benchmark
    module is imported, so the failure costs milliseconds."""
    target = tmp_path / "BENCH_dse.json"
    doc = json.dumps({"runs": {"soc_dse": {"points_per_sec": 1}}})
    target.write_text(doc)
    import benchmarks
    before = set(sys.modules)
    with pytest.raises(SystemExit, match="refusing to overwrite"):
        main(["--json", "--out", str(target)])
    assert target.read_text() == doc                    # untouched
    # the guard fired before any bench_* module was pulled in
    new_bench = [m for m in set(sys.modules) - before
                 if m.startswith("benchmarks.bench")]
    assert not new_bench, new_bench
