"""Run-time monitoring infrastructure (PR 7): the observability plane.

The contracts locked down here:

* **zero perturbation** — simulated numerics are bit-for-bit identical
  with monitoring on or off, on every engine, across control policies and
  fault schedules (the observer only *reads* what ``tick_step`` computed);
* **engine agreement** — the batched NumPy engine's counter plane at B=1
  equals the sequential engine's exactly; the jax backend's counters agree
  within float32-snapshot tolerance;
* **the trace schema** — registered kinds only, monotonic ticks, ring
  bounding, JSONL round-trip;
* **metrics export** — CounterPlane/trace/telemetry -> Prometheus text ->
  parse round-trips, and counter values match the engine's own histories;
* **the level knob** — ``off`` engages nothing, ``counters`` skips
  tracing, ``full`` records both; lazy counter materialization books its
  cost to the phase profiler, not the engine wall clock.
"""
from functools import partial

import numpy as np
import pytest

from repro.core.dfs import (BatchMemoryBoundPolicy, BatchPIDRatePolicy,
                            PIDRatePolicy, policy_memory_bound)
from repro.core.perfmodel import AccelWorkload, SoCPerfModel
from repro.sim import (LEVELS, TRACE_KINDS, BatchControllerHarness,
                       BatchSimEngine, BatchSimPlatform, ControllerHarness,
                       ControlTrace, CounterPlane, FaultSchedule,
                       MetricsRegistry, Observer, Profiler, SimConfig,
                       SimEngine, SimPlatform, SLOConfig, export_metrics,
                       parse_prometheus_text, poisson_trace, profiled)

T = 300
DT = 1e-3


def make_platform() -> SimPlatform:
    m = SoCPerfModel()
    pos = [(r, c) for r in range(4) for c in range(4)
           if (r, c) not in {(1, 0), (0, 0), (0, 3)}][:6]
    wls = [AccelWorkload("dfmul", 8.70, 1.1, replication=8) for _ in pos]
    return SimPlatform.build(m, wls, pos, n_tg=2, req_mb=0.005)


@pytest.fixture(scope="module")
def plat():
    return make_platform()


@pytest.fixture(scope="module")
def trace_():
    return poisson_trace(4000.0, T, 6, dt=DT, seed=11)


def seq_kwargs(plat, policy):
    if policy is None:
        return {}
    pol = (partial(policy_memory_bound, threshold=0.55, low_rate=0.5)
           if policy == "membound" else PIDRatePolicy(target=0.7))
    return dict(controller=ControllerHarness(plat.islands, pol,
                                             queue_guard_ticks=3.0))


def bat_kwargs(bplat, policy):
    if policy is None:
        return {}
    pol = (BatchMemoryBoundPolicy(threshold=0.55, low_rate=0.5)
           if policy == "membound" else BatchPIDRatePolicy(target=0.7))
    return dict(controller=BatchControllerHarness(
        bplat.islands, bplat.rates, pol, tile_names=bplat.names,
        queue_guard_ticks=3.0))


def fault_kwargs(plat, use_faults):
    if not use_faults:
        return {}
    return dict(faults=FaultSchedule().kill_tile(plat.names[2],
                                                 start=80, end=200),
                slo=SLOConfig(deadline_s=0.05, on_kill="respill",
                              max_retries=1))


# ----------------------------------------------------------- perturbation


@pytest.mark.parametrize("policy", [None, "membound", "pid"])
@pytest.mark.parametrize("use_faults", [False, True])
def test_sequential_monitoring_is_zero_perturbation(plat, trace_, policy,
                                                    use_faults):
    """Bit-for-bit: enabling full monitoring must not change a single
    simulated number on the sequential reference engine."""
    cfg = SimConfig(control_interval=25)
    fkw = fault_kwargs(plat, use_faults)
    r_off = SimEngine(plat, config=cfg, **seq_kwargs(plat, policy),
                      **fkw).run(trace_)
    eng = SimEngine(plat, config=cfg, observe="full",
                    **seq_kwargs(plat, policy), **fkw)
    r_on = eng.run(trace_)
    assert r_off.p99_latency_s == r_on.p99_latency_s
    assert r_off.energy_j == r_on.energy_j
    assert r_off.completed == r_on.completed
    assert eng.observer.counters is not None


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_batched_monitoring_is_zero_perturbation(plat, trace_, backend):
    """Same contract on both batched backends, under the PID controller
    with a mid-run tile kill (the hardest numeric path)."""
    cfg = SimConfig(control_interval=25)
    bplat = BatchSimPlatform.stack([plat] * 2)
    fkw = fault_kwargs(plat, True)
    r_off = BatchSimEngine(bplat, config=cfg, backend=backend,
                           **bat_kwargs(bplat, "pid"), **fkw).run(trace_)
    eng = BatchSimEngine(bplat, config=cfg, backend=backend,
                         observe="counters", **bat_kwargs(bplat, "pid"),
                         **fkw)
    r_on = eng.run(trace_)
    assert np.array_equal(r_off.p99_latency_s, r_on.p99_latency_s)
    assert np.array_equal(r_off.energy_j, r_on.energy_j)
    assert np.array_equal(r_off.completed, r_on.completed)
    assert eng.observer.counters is not None


# ------------------------------------------------------- engine agreement


def _planes(plat, trace_, policy, use_faults):
    cfg = SimConfig(control_interval=25)
    fkw = fault_kwargs(plat, use_faults)
    seq = SimEngine(plat, config=cfg, observe="counters",
                    **seq_kwargs(plat, policy), **fkw)
    seq.run(trace_)
    bplat = BatchSimPlatform.stack([plat])
    bat = BatchSimEngine(bplat, config=cfg, backend="numpy",
                         observe="counters", **bat_kwargs(bplat, policy),
                         **fkw)
    bat.run(trace_)
    return seq.observer.counters, bat.observer.counters


@pytest.mark.parametrize("policy,use_faults",
                         [(None, False), ("pid", False), ("pid", True),
                          ("membound", True)])
def test_batch_numpy_b1_counters_match_sequential_exactly(plat, trace_,
                                                          policy,
                                                          use_faults):
    seq_cp, bat_cp = _planes(plat, trace_, policy, use_faults)
    one = bat_cp.design(0)
    for group in ("tile", "link", "island"):
        mine, theirs = getattr(seq_cp, group), getattr(one, group)
        for k in mine:
            assert np.array_equal(mine[k], theirs[k]), (group, k)
    assert float(one.ticks) == float(seq_cp.ticks) == float(T)


@pytest.mark.parametrize("policy,use_faults", [("pid", True), (None, False)])
def test_jax_counters_match_numpy_within_f32_tolerance(plat, trace_, policy,
                                                       use_faults):
    """The scan emits float32 snapshots; every counter must land within
    f32 rounding of the float64 reference — including the integer-valued
    stall/offered channels, which must match exactly."""
    cfg = SimConfig(control_interval=25)
    fkw = fault_kwargs(plat, use_faults)
    seq = SimEngine(plat, config=cfg, observe="counters",
                    **seq_kwargs(plat, policy), **fkw)
    seq.run(trace_)
    sp = seq.observer.counters
    bplat = BatchSimPlatform.stack([plat])
    jx = BatchSimEngine(bplat, config=cfg, backend="jax",
                        observe="counters", **bat_kwargs(bplat, policy),
                        **fkw)
    jx.run(trace_)
    jp = jx.observer.counters.design(0)
    for group in ("tile", "link", "island"):
        mine, theirs = getattr(sp, group), getattr(jp, group)
        for k in mine:
            v, jv = np.asarray(mine[k]), np.asarray(theirs[k])
            tol = 2e-4 * np.maximum(np.abs(v), 1.0) + 1e-6
            assert (np.abs(jv - v) <= tol).all(), (group, k, v, jv)
    assert np.array_equal(sp.tile["stall_ticks"], jp.tile["stall_ticks"])


def test_counters_tie_back_to_engine_histories(plat, trace_):
    """offered/invocations are exactly the admitted/served column sums the
    engine itself kept; energy sums (within fp reassociation) to the
    result's energy integral."""
    eng = SimEngine(plat, observe="counters")
    res = eng.run(trace_)
    cp = eng.observer.counters
    admitted, served = eng.last_histories
    assert np.array_equal(cp.tile["offered"], admitted.sum(axis=0))
    assert np.array_equal(cp.tile["invocations"], served.sum(axis=0))
    assert cp.island["energy_j"].sum() == pytest.approx(res.energy_j,
                                                        rel=1e-9)
    s = cp.summary()
    assert s["ticks"] == T
    assert s["invocations"] == pytest.approx(served.sum())
    assert 0.0 < s["busy_frac"] <= 1.0
    assert s["peak_link_util"] > 0.0


# ---------------------------------------------------------- control trace


def test_trace_rejects_unknown_kind_and_backward_tick():
    tr = ControlTrace()
    tr.emit(5, "run_start", ticks=10)
    with pytest.raises(ValueError, match="unknown trace kind"):
        tr.emit(6, "made_up_kind")
    with pytest.raises(ValueError, match="non-monotonic"):
        tr.emit(4, "run_end")
    # equal ticks are fine (several events can share a tick)
    tr.emit(5, "dfs_commit", version=1)
    assert [e.kind for e in tr.events()] == ["run_start", "dfs_commit"]


def test_trace_ring_bound_and_jsonl_roundtrip():
    tr = ControlTrace(capacity=8)
    for t in range(20):
        tr.emit(t, "dfs_commit", version=t,
                rates=np.asarray([0.5, 1.0]))       # np payloads allowed
    assert len(tr) == 8 and tr.total_emitted == 20
    assert tr.events()[0].tick == 12                # oldest fell off
    back = ControlTrace.from_jsonl(tr.to_jsonl())
    assert [e.to_dict() for e in back.events()] == \
        [e.to_dict() for e in tr.events()]
    assert back.events()[-1].data["rates"] == [0.5, 1.0]


def test_trace_spans_and_counts():
    tr = ControlTrace()
    tr.emit(3, "slo_drop_start", tiles=["a"])
    tr.emit(9, "slo_drop_end", ticks=6)
    tr.emit(12, "slo_drop_start", tiles=["a"])
    tr.emit(15, "slo_drop_end", ticks=3)
    assert tr.spans("slo_drop_start", "slo_drop_end") == [(3, 9), (12, 15)]
    assert tr.counts() == {"slo_drop_start": 2, "slo_drop_end": 2}


def test_full_level_traces_control_and_fault_events(plat, trace_):
    """A PID + fault run at level=full must leave a machine-readable
    story: run_start/run_end bracket, DFS commits, the kill/revive pair —
    with monotonic ticks and registered kinds throughout."""
    eng = SimEngine(plat, config=SimConfig(control_interval=25),
                    observe="full", **seq_kwargs(plat, "pid"),
                    **fault_kwargs(plat, True))
    eng.run(trace_)
    tr = eng.observer.trace
    kinds = tr.counts()
    assert kinds.get("run_start") == 1 and kinds.get("run_end") == 1
    assert kinds.get("dfs_commit", 0) > 0
    assert kinds.get("fault_kill") == 1 and kinds.get("fault_revive") == 1
    ticks = [e.tick for e in tr.events()]
    assert ticks == sorted(ticks)
    assert all(e.kind in TRACE_KINDS for e in tr.events())
    kill = tr.events("fault_kill")[0]
    assert plat.names[2] in kill.subject
    # the whole trace survives a JSONL round trip
    assert len(ControlTrace.from_jsonl(tr.to_jsonl())) == len(tr)


def test_counters_level_skips_tracing(plat, trace_):
    eng = SimEngine(plat, config=SimConfig(control_interval=25),
                    observe="counters", **seq_kwargs(plat, "pid"))
    eng.run(trace_)
    assert len(eng.observer.trace) == 0
    assert eng.observer.counters is not None


# -------------------------------------------------------- observer facade


def test_observer_coercion_and_level_knob():
    assert Observer.coerce(None) is None
    assert Observer.coerce("off") is None
    ob = Observer.coerce("counters")
    assert ob.enabled and not ob.tracing
    assert Observer.coerce("full").tracing
    assert Observer.coerce(ob) is ob
    with pytest.raises(ValueError, match="level"):
        Observer(level="verbose")
    with pytest.raises(TypeError):
        Observer.coerce(3)
    assert LEVELS == ("off", "counters", "full")


def test_observer_reuse_across_runs_resets_trace(plat, trace_):
    """One observer driven through two runs: begin_run() must reset the
    monotonic-tick guard and each run's counters must replace the last
    (second run == fresh-observer second run, not an accumulation)."""
    ob = Observer("full")
    eng = SimEngine(plat, observe=ob)
    eng.run(trace_)
    first = ob.counters.snapshot()
    eng.run(trace_)                      # would raise if the guard leaked
    again = ob.counters
    assert ob.trace.counts().get("run_start") == 1
    assert float(again.ticks) == T
    fresh = SimEngine(plat, observe="counters")
    fresh.run(trace_)
    assert again.allclose(fresh.observer.counters)
    assert np.array_equal(first["tile"]["invocations"],
                          again.tile["invocations"])


def test_lazy_counters_materialize_on_first_read(plat, trace_):
    prof = Profiler()
    ob = Observer("counters", profiler=prof)
    eng = SimEngine(plat, observe=ob)
    eng.run(trace_)
    assert ob._counters is None and ob._counters_thunk is not None
    assert "counters_finalize" not in prof.phases
    cp = ob.counters
    assert isinstance(cp, CounterPlane)
    assert prof.phases["counters_finalize"][1] == 1
    assert ob.counters is cp            # second read: cached, not re-built
    assert prof.phases["counters_finalize"][1] == 1


# -------------------------------------------------------------- profiling


def test_profiler_phases_accumulate():
    prof = Profiler()
    with profiled("phase_a", prof):
        pass
    with profiled("phase_a", prof):
        pass
    with profiled("phase_b", prof):
        pass
    s = prof.summary()
    assert s["phase_a"]["count"] == 2
    assert s["phase_b"]["count"] == 1
    assert s["phase_a"]["total_s"] >= 0.0
    prof.reset()
    assert prof.summary() == {}


# -------------------------------------------------------- counter scoping


def test_counterplane_reset_scopes_like_manual_reset():
    cp = CounterPlane(3, 2, 2, tile_names=("a", "b", "c"))
    for k in cp.tile:
        cp.tile[k][:] = 7.0
    cp.link["flits"][:] = 5.0
    cp.island["energy_j"][:] = 2.0
    cp.ticks = np.asarray(9.0)
    cp.reset(kinds=["busy_ticks"], tiles=["b", 2])
    assert list(cp.tile["busy_ticks"]) == [7.0, 0.0, 0.0]
    assert (cp.tile["invocations"] == 7.0).all()    # untouched kind
    cp.reset(kinds=["flits"])
    assert (cp.link["flits"] == 0.0).all()
    assert (cp.island["energy_j"] == 2.0).all()
    with pytest.raises(ValueError, match="unknown counter kinds"):
        cp.reset(kinds=["made_up"])
    cp.reset()
    assert float(cp.ticks) == 0.0
    assert all((v == 0.0).all() for v in cp.tile.values())


# --------------------------------------------------------- metrics export


def test_metrics_registry_semantics_and_prometheus_roundtrip():
    reg = MetricsRegistry()
    reg.counter("x_total", "adds", labels={"t": "a"}, value=2.0)
    reg.counter("x_total", labels={"t": "a"}, value=3.0)
    reg.gauge("g", "sets", value=1.5)
    reg.gauge("g", value=2.5)
    reg.histogram("h_seconds", "obs", value=0.003)
    reg.histogram("h_seconds", "obs", value=4.2)
    assert reg.get("x_total", {"t": "a"}) == 5.0    # counter accumulates
    assert reg.get("g") == 2.5                      # gauge overwrites
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x_total")
    parsed = parse_prometheus_text(reg.render_prometheus())
    assert set(parsed) == {"x_total", "g", "h_seconds"}
    assert parsed["x_total"]["type"] == "counter"
    assert parsed["x_total"]["samples"] == [({"t": "a"}, 5.0)]
    assert parsed["g"]["samples"] == [({}, 2.5)]
    hist = parsed["h_seconds"]
    assert hist["type"] == "histogram"
    counts = [v for lb, v in hist["samples"]
              if lb.get("__sample__") == "count"]
    sums = [v for lb, v in hist["samples"] if lb.get("__sample__") == "sum"]
    assert counts == [2] and sums == [pytest.approx(4.203)]


def test_export_metrics_roundtrips_engine_counters(plat, trace_):
    eng = SimEngine(plat, config=SimConfig(control_interval=25),
                    observe="full", **seq_kwargs(plat, "pid"))
    res = eng.run(trace_)
    ob = eng.observer
    reg = export_metrics(counters=ob.counters, trace=ob.trace,
                         telemetry=res.telemetry)
    text = reg.render_prometheus()
    parsed = parse_prometheus_text(text)
    assert set(parsed) == set(reg.names()) and parsed
    # a per-tile counter round-trips to the exact engine-side value
    name = plat.names[0]
    served0 = float(eng.last_histories[1].sum(axis=0)[0])
    assert reg.get("sim_tile_invocations_total",
                   {"tile": name}) == pytest.approx(served0)
    got = [v for lb, v in parsed["sim_tile_invocations_total"]["samples"]
           if lb == {"tile": name}]
    assert got == [pytest.approx(served0)]
    # trace kinds surface as labeled event counters
    kinds = {lb["kind"] for lb, _ in
             parsed["sim_trace_events_total"]["samples"]}
    assert {"run_start", "run_end"} <= kinds
    # telemetry gauges carry the latest row
    assert reg.get("sim_telemetry_tick") is not None


# ------------------------------------------------- closed_loop_score hook


def test_closed_loop_score_observe_attaches_counters(plat):
    from repro.core.dse import closed_loop_score, grid_sweep
    from repro.sim import diurnal_trace
    m = SoCPerfModel()
    wls = [AccelWorkload("dfmul", 8.70, 1.1),
           AccelWorkload("fft2d", 145.0, 20.8)]
    res = grid_sweep(m, wls, ks=(1, 2), acc_rates=(0.5, 1.0),
                     noc_rates=(1.0,), tg_rates=(1.0,),
                     positions=((1, 1), (3, 3)), n_tg=2)
    trace = lambda seed: diurnal_trace(3000.0, 250, 2,     # noqa: E731
                                       dt=1e-3, seed=seed)
    base = closed_loop_score(res, trace, model=m, top=2)
    assert base.counters is None
    for kwargs in (dict(), dict(batch=False)):
        sc = closed_loop_score(res, trace, model=m, top=2,
                               observe="counters", **kwargs)
        assert sc.counters is not None and len(sc.counters) == 2
        for s in sc.counters:
            assert s["ticks"] == 250
            assert s["invocations"] > 0 and s["energy_j"] > 0
        # monitoring must not move the ranking
        assert np.array_equal(sc.ranked_indices(), base.ranked_indices())
        assert np.allclose(sc.p99_latency_s, base.p99_latency_s)
