"""Scalar <-> batched parity for the vectorized DSE engine.

Property-style tests (seeded rng always; hypothesis variants when it is
installed) asserting that

* ``accel_throughput_batch`` / ``memory_traffic_batch`` match the scalar
  methods across random Ks, rates, placements and NoC configs (incl. torus),
* the O(N log N) Pareto front matches the O(N^2) brute force, including
  tie-heavy integer-valued objectives,
* ``grid_sweep`` reproduces ``sweep_soc`` point for point,
* the batched NoC routing tables match per-call route walks.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.dfs import policy_energy_per_token_sweep
from repro.core.dse import (DesignPoint, grid_sweep, pareto_front,
                            pareto_front_bruteforce, pareto_front_indices,
                            sweep_soc)
from repro.core.islands import (IslandConfig, IslandSpec, NOC_LADDER,
                                TILE_LADDER)
from repro.core.noc import (Flow, NocConfig, NocModel, hops, hops_batch,
                            link_loads_batch, positions_to_indices,
                            route_max_utilization, routing_tables, xy_route)
from repro.core.perfmodel import AccelWorkload, SoCPerfModel, chip_power

NOCS = [NocConfig(4, 4), NocConfig(4, 4, torus=True),
        NocConfig(3, 5), NocConfig(5, 3, torus=True)]


def _rand_pos(rng, cfg):
    return (int(rng.integers(cfg.rows)), int(rng.integers(cfg.cols)))


# --------------------------------------------------------------- NoC tables
@pytest.mark.parametrize("cfg", NOCS, ids=lambda c: f"{c.rows}x{c.cols}"
                         + ("t" if c.torus else "m"))
def test_hop_matrix_matches_scalar_hops(cfg):
    t = routing_tables(cfg)
    n = cfg.rows * cfg.cols
    for s in range(n):
        for d in range(n):
            sp = (s // cfg.cols, s % cfg.cols)
            dp = (d // cfg.cols, d % cfg.cols)
            assert t.hop_matrix[s, d] == hops(cfg, sp, dp)
            assert t.hop_matrix[s, d] == len(xy_route(cfg, sp, dp))


@pytest.mark.parametrize("cfg", NOCS[:2], ids=["mesh", "torus"])
def test_link_loads_batch_matches_nocmodel(cfg):
    rng = np.random.default_rng(3)
    flows = [Flow(_rand_pos(rng, cfg), _rand_pos(rng, cfg),
                  float(rng.random())) for _ in range(64)]
    scalar = NocModel(cfg)
    for f in flows:
        scalar.add_flow(f)
    batched = NocModel(cfg)
    batched.add_flows(flows)
    t = routing_tables(cfg)
    loads = link_loads_batch(
        cfg, positions_to_indices(cfg, [f.src for f in flows]),
        positions_to_indices(cfg, [f.dst for f in flows]),
        [f.bytes_per_cycle for f in flows])
    for i, link in enumerate(t.links):
        assert loads[i] == pytest.approx(scalar.link_load.get(link, 0.0))
        assert batched.link_load.get(link, 0.0) == pytest.approx(
            scalar.link_load.get(link, 0.0))


@pytest.mark.parametrize("cfg", NOCS[:2], ids=["mesh", "torus"])
def test_slowdown_batch_matches_scalar(cfg):
    rng = np.random.default_rng(4)
    m = NocModel(cfg)
    m.add_flows([Flow(_rand_pos(rng, cfg), _rand_pos(rng, cfg),
                      float(rng.random())) for _ in range(32)])
    pairs = [(_rand_pos(rng, cfg), _rand_pos(rng, cfg)) for _ in range(40)]
    pairs.append(((1, 1), (1, 1)))                       # zero-hop route
    sb = m.slowdown_batch(
        positions_to_indices(cfg, [p[0] for p in pairs]),
        positions_to_indices(cfg, [p[1] for p in pairs]))
    for i, (s, d) in enumerate(pairs):
        assert sb[i] == pytest.approx(m.slowdown(s, d), rel=1e-12)


def test_xy_route_returns_fresh_list():
    cfg = NocConfig(4, 4)
    r1 = xy_route(cfg, (0, 0), (2, 2))
    r1.append("sentinel")
    assert "sentinel" not in xy_route(cfg, (0, 0), (2, 2))


# ------------------------------------------------------- perf-model parity
@pytest.mark.parametrize("torus", [False, True], ids=["mesh", "torus"])
def test_throughput_batch_matches_scalar_random(torus):
    rng = np.random.default_rng(5)
    m = SoCPerfModel(noc=NocConfig(4, 4, torus=torus))
    names = list(("adpcm", "dfadd", "dfmul", "dfsin", "gsm"))
    B = 300
    ks = rng.choice([1, 2, 4, 8], B)
    fa = rng.uniform(0.05, 1.0, B)
    fn = rng.uniform(0.05, 1.0, B)
    ft = rng.uniform(0.1, 1.0, B)
    ntg = rng.integers(0, 12, B)
    pos = np.stack([rng.integers(0, 4, B), rng.integers(0, 4, B)], axis=-1)
    for name in names:
        wl = AccelWorkload(name, 4.61, 12.0)
        batch = m.accel_throughput_batch(
            base_mbps=wl.base_mbps, wire_share=wl.wire_share, k=ks,
            f_acc=fa, f_noc=fn, f_tg=ft, n_tg=ntg,
            pos_idx=positions_to_indices(m.noc, pos))
        for i in range(0, B, 17):                        # spot-check sample
            w = AccelWorkload(name, wl.base_mbps, wl.ai,
                              replication=int(ks[i]))
            s = m.accel_throughput(
                w, (int(pos[i, 0]), int(pos[i, 1])),
                {"acc": float(fa[i]), "noc_mem": float(fn[i]),
                 "tg": float(ft[i])}, int(ntg[i]))
            assert batch[i] == pytest.approx(s, rel=1e-6)


def test_throughput_jax_backend_close_to_numpy():
    m = SoCPerfModel()
    ks = np.array([1.0, 2.0, 4.0])[:, None]
    fa = np.array([0.2, 0.6, 1.0])[None, :]
    a = m.accel_throughput_batch(base_mbps=4.61, wire_share=0.035, k=ks,
                                 f_acc=fa, f_noc=0.5, f_tg=1.0, n_tg=4,
                                 pos=(3, 3))
    b = m.accel_throughput_batch(base_mbps=4.61, wire_share=0.035, k=ks,
                                 f_acc=fa, f_noc=0.5, f_tg=1.0, n_tg=4,
                                 pos=(3, 3), backend="jax")
    # jax default precision is float32 unless jax_enable_x64
    np.testing.assert_allclose(b, a, rtol=1e-5)


def test_memory_traffic_batch_matches_scalar():
    m = SoCPerfModel()
    rng = np.random.default_rng(6)
    for _ in range(100):
        rates = {"acc": float(rng.uniform(0, 1)),
                 "noc_mem": float(rng.uniform(0.05, 1)),
                 "tg": float(rng.uniform(0, 1))}
        n_tg = int(rng.integers(0, 12))
        n_acc = int(rng.integers(0, 4))
        s = m.memory_traffic_mpkts(rates, n_tg, [(1, 1)] * n_acc)
        b = float(m.memory_traffic_batch(
            f_acc=rates["acc"], f_noc=rates["noc_mem"], f_tg=rates["tg"],
            n_tg=n_tg, n_accels=n_acc))
        assert b == pytest.approx(s, rel=1e-9)


# ------------------------------------------------------------ Pareto front
def _front_keys(points):
    return sorted((p.throughput, p.area, p.energy_per_unit) for p in points)


def test_pareto_fast_matches_bruteforce_ties():
    rng = np.random.default_rng(7)
    for _ in range(25):
        n = int(rng.integers(1, 400))
        # integer-quantized objectives force heavy ties and duplicates
        thr = rng.integers(0, 10, n).astype(float)
        area = rng.integers(0, 6, n).astype(float)
        en = rng.integers(0, 6, n).astype(float)
        pts = [DesignPoint({}, {}, {}, thr[i], area[i], en[i])
               for i in range(n)]
        bf = pareto_front_bruteforce(pts)
        idx = pareto_front_indices(thr, area, en)
        assert sorted(map(id, bf)) == sorted(id(pts[i]) for i in idx)


def test_pareto_fast_matches_bruteforce_continuous():
    rng = np.random.default_rng(8)
    n = 1000
    thr, area, en = rng.random(n), rng.random(n), rng.random(n)
    pts = [DesignPoint({}, {}, {}, thr[i], area[i], en[i]) for i in range(n)]
    bf = pareto_front_bruteforce(pts)
    idx = pareto_front_indices(thr, area, en)
    assert sorted(map(id, bf)) == sorted(id(pts[i]) for i in idx)


def test_pareto_public_api_uses_fast_path():
    m = SoCPerfModel()
    pts = sweep_soc(m, AccelWorkload("gsm", 4.61, 12.0), n_tg=4)
    assert {p.key() for p in pareto_front(pts)} == {
        p.key() for p in pareto_front_bruteforce(pts)}


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_pareto_fast_matches_bruteforce_hypothesis(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 120))
    thr = rng.integers(0, 8, n).astype(float)
    area = rng.integers(0, 5, n).astype(float)
    en = rng.integers(0, 5, n).astype(float)
    pts = [DesignPoint({}, {}, {}, thr[i], area[i], en[i]) for i in range(n)]
    bf = pareto_front_bruteforce(pts)
    idx = pareto_front_indices(thr, area, en)
    assert sorted(map(id, bf)) == sorted(id(pts[i]) for i in idx)


# -------------------------------------------------------------- grid sweep
@pytest.mark.parametrize("torus", [False, True], ids=["mesh", "torus"])
def test_grid_sweep_matches_sweep_soc(torus):
    m = SoCPerfModel(noc=NocConfig(4, 4, torus=torus))
    wl = AccelWorkload("dfmul", 8.70, 1.1)
    kw = dict(ks=(1, 2, 4), noc_rates=(0.1, 0.5, 1.0),
              acc_rates=(0.2, 0.6, 1.0), positions=((1, 1), (3, 3)))
    scalar = {p.key(): p for p in sweep_soc(m, wl, n_tg=4, **kw)}
    res = grid_sweep(m, wl, tg_rates=(1.0,), n_tg=4, **kw)
    assert len(res) == len(scalar)
    for i in range(len(res)):
        dp = res.design_point(i)
        sp = scalar[dp.key()]
        assert dp.throughput == pytest.approx(sp.throughput, rel=1e-6)
        assert dp.area == pytest.approx(sp.area, rel=1e-6)
        assert dp.energy_per_unit == pytest.approx(sp.energy_per_unit,
                                                   rel=1e-6)


def test_grid_sweep_joint_masks_collisions():
    m = SoCPerfModel()
    wls = [AccelWorkload("dfsin", 0.33, 60.0),
           AccelWorkload("gsm", 4.61, 12.0)]
    res = grid_sweep(m, wls, ks=(1, 2), acc_rates=(1.0,), noc_rates=(1.0,),
                     positions=((1, 1), (3, 3), (0, 2)), n_tg=0)
    assert len(res) == 2 * 2 * 3 * 3
    # exactly the same-position placements are invalid
    assert res.n_valid == 2 * 2 * (3 * 3 - 3)
    for i in res.pareto_indices():
        dp = res.design_point(int(i))
        assert dp.placement["dfsin"] != dp.placement["gsm"]
    # joint throughput == sum of per-accel scalar throughputs
    i = int(res.topk_indices(1)[0])
    dp = res.design_point(i)
    expect = sum(
        m.accel_throughput(
            AccelWorkload(w.name, w.base_mbps, w.ai,
                          replication=dp.replication[w.name]),
            dp.placement[w.name], dp.rates, 0)
        for w in wls)
    assert dp.throughput == pytest.approx(expect, rel=1e-6)


def test_grid_sweep_mem_traffic_matches_scalar():
    """SweepResult.mem_traffic reproduces the scalar Fig.-4 model at the
    axis values of each flat point."""
    m = SoCPerfModel()
    wls = [AccelWorkload("dfsin", 0.33, 60.0),
           AccelWorkload("gsm", 4.61, 12.0)]
    res = grid_sweep(m, wls, ks=(1, 2), acc_rates=(0.2, 1.0),
                     noc_rates=(0.5, 1.0), tg_rates=(0.5, 1.0),
                     positions=((1, 1), (3, 3)), n_tg=6)
    assert res.mem_traffic is not None
    assert res.mem_traffic.shape == res.throughput.shape
    rng = np.random.default_rng(11)
    for i in rng.integers(0, len(res), 40):
        av = res.axis_values(int(i))
        want = m.memory_traffic_mpkts(
            {"acc": av["f_acc"], "noc_mem": av["f_noc"], "tg": av["f_tg"]},
            res.n_tg, [(0, 0)] * len(wls))
        assert res.mem_traffic[int(i)] == pytest.approx(want, rel=1e-12)
    # usable as a topk objective like any other array
    low = res.topk_indices(3, objective="mem_traffic", maximize=False)
    assert res.mem_traffic[low][0] == res.mem_traffic[res.valid].min()


def test_grid_sweep_topk_sorted_and_valid():
    m = SoCPerfModel()
    res = grid_sweep(m, AccelWorkload("gsm", 4.61, 12.0),
                     ks=(1, 2, 4), acc_rates=TILE_LADDER.levels(),
                     noc_rates=NOC_LADDER.levels(), n_tg=2)
    top = res.topk_indices(20)
    vals = res.throughput[top]
    assert np.all(np.diff(vals) <= 1e-12)
    assert np.all(res.valid[top])
    assert vals[0] == res.throughput[res.valid].max()
    low = res.topk_indices(5, objective="energy_per_unit")
    assert res.energy_per_unit[low][0] == res.energy_per_unit[res.valid].min()


# ------------------------------------------------------------- DFS policy
def test_policy_energy_sweep_feasible_and_on_ladder():
    m = SoCPerfModel()
    wl = AccelWorkload("dfmul", 8.70, 1.1, replication=4)
    islands = IslandConfig((
        IslandSpec("acc", ("A2",), TILE_LADDER, 1.0),
        IslandSpec("noc_mem", ("NOC", "MEM"), NOC_LADDER, 1.0)))

    def eval_batch(rates):
        fa, fn = rates["acc"], rates["noc_mem"]
        tps = m.accel_throughput_batch(
            base_mbps=wl.base_mbps, wire_share=wl.wire_share,
            k=wl.replication, f_acc=fa, f_noc=fn, f_tg=1.0, n_tg=4,
            pos=(3, 3))
        watts = chip_power(fa, 1.0) + 0.3 * chip_power(fn, 1.0)
        return tps, np.broadcast_to(watts, np.shape(tps))

    best = policy_energy_per_token_sweep(islands, eval_batch, max_loss=0.3)
    assert set(best) == {"acc", "noc_mem"}
    assert best["acc"] in TILE_LADDER.levels()
    assert best["noc_mem"] in NOC_LADDER.levels()
    # constraint respected: chosen tps within 30% of all-max tps
    tps_best, _ = eval_batch({k: np.asarray([v]) for k, v in best.items()})
    tps_max, _ = eval_batch({"acc": np.asarray([1.0]),
                             "noc_mem": np.asarray([1.0])})
    assert float(tps_best[0]) >= 0.7 * float(tps_max[0])
