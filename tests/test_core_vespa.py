"""Vespa core invariants: tiles, islands, DFS, monitor, NoC, perf model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import repro.core as C
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core.noc import NocConfig, NocModel, Flow, hops, xy_route


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_default_plan_valid(arch):
    cfg = get_config(arch)
    plan = C.default_plan(cfg)
    C.validate_plan(plan, cfg)
    isl = C.default_islands(plan)
    C.validate_islands(isl, plan)


def test_mra_knob_does_not_touch_other_tiles():
    cfg = get_config("granite-8b")
    plan = C.default_plan(cfg)
    p2 = plan.with_replication("ffn", 4)
    assert p2.tile("ffn").replication == 4
    for t in plan.tiles:
        if t.name != "ffn":
            assert p2.tile(t.name) == t


def test_replication_model_matches_table_i():
    """Paper Table I: avg 1.92x @ K=2, 3.58x @ K=4."""
    assert abs(C.replication_throughput_model(2) - 1.92) < 0.05
    assert abs(C.replication_throughput_model(4) - 3.58) < 0.15
    assert C.replication_throughput_model(1) == 1.0


def test_replication_area_model_shape():
    """Weights x K per device; activations unchanged (paper: DSP ~K,
    LUT/FF/BRAM sub-K)."""
    a1 = C.replication_area_model(100, 50, 1)
    a4 = C.replication_area_model(100, 50, 4)
    assert a4["weight_bytes_per_dev"] == 4 * a1["weight_bytes_per_dev"]
    assert a4["act_bytes_per_dev"] == a1["act_bytes_per_dev"]
    assert a4["total_bytes_per_dev"] < 4 * a1["total_bytes_per_dev"]


def test_rate_ladder_matches_paper():
    assert C.TILE_LADDER.levels_mhz() == tuple(range(10, 51, 5))
    assert C.NOC_LADDER.levels_mhz() == tuple(range(10, 101, 5))
    assert C.TILE_LADDER.quantize(0.43) in C.TILE_LADDER.levels()


def test_dfs_actuator_hitless_swap():
    cfg = get_config("granite-8b")
    isl = C.default_islands(C.default_plan(cfg))
    act = C.DFSActuator(isl)
    v0 = act.live().version
    act.reconfigure({"noc_mem": 0.5})
    # live config untouched until commit (the master MMCM holds the clock)
    assert act.live().version == v0
    assert act.live().rate_of("noc") == 1.0
    live = act.commit()
    assert live.rate_of("noc") == 0.5 and live.version == v0 + 1
    # abort path: shadow never observed
    act.reconfigure({"noc_mem": 0.1})
    act.abort()
    assert act.commit().rate_of("noc") == 0.5


def test_islands_are_partition():
    cfg = get_config("zamba2-7b")
    plan = C.default_plan(cfg)
    isl = C.default_islands(plan)
    seen = [t for i in isl.islands for t in i.tiles]
    assert sorted(seen) == sorted(t.name for t in plan.tiles)


def test_resync_boundaries_mra():
    cfg = get_config("granite-8b")
    plan = C.default_plan(cfg).with_replication("ffn", 4)
    isl = C.default_islands(plan)
    bs = C.resync_boundaries(plan, isl)
    assert any(b.reason == "mra" for b in bs)


# ------------------------------------------------------------------- monitor
def test_counters_respect_enablement():
    cfg = get_config("granite-8b")
    plan = C.default_plan(cfg)
    ctr = C.init_counters(plan)
    assert "rtt" not in ctr["attn"]            # attn tile: 3 counters enabled
    assert "rtt" in ctr["mem"]
    ctr2 = C.charge(ctr, "attn", rtt=5.0)      # silently skipped
    assert "rtt" not in ctr2["attn"]


def test_counter_semantics_exec_replaces_pkts_accumulate():
    cfg = get_config("granite-8b")
    plan = C.default_plan(cfg)
    ctr = C.init_counters(plan)
    ctr = C.charge(ctr, "mem", pkts_in=10.0)
    ctr = C.charge(ctr, "mem", pkts_in=5.0)
    assert float(ctr["mem"]["pkts_in"]) == 15.0
    ctr = C.charge(ctr, "io", exec_time=3.0)
    ctr = C.charge(ctr, "io", exec_time=7.0)
    assert float(ctr["io"]["exec_time"]) == 7.0        # auto-reset semantics
    ctr = C.manual_reset(ctr)
    assert float(ctr["mem"]["pkts_in"]) == 0.0
    assert float(ctr["io"]["exec_time"]) == 7.0        # exec not reset


@settings(max_examples=20, deadline=None)
@given(bytes_list=st.lists(st.integers(0, 10_000), min_size=1, max_size=8))
def test_boundary_charges_equal_byte_sum(bytes_list):
    cfg = get_config("granite-8b")
    plan = C.default_plan(cfg)
    ctr = C.init_counters(plan)
    total = 0
    for n in bytes_list:
        payload = jnp.zeros((n,), jnp.uint8)
        ctr = C.charge_boundary(ctr, "attn", "mem", payload)
        total += n
    assert abs(float(ctr["mem"]["pkts_in"]) - total / C.PKT_BYTES) < 1e-4
    assert abs(float(ctr["attn"]["pkts_out"]) - total / C.PKT_BYTES) < 1e-4


# ----------------------------------------------------------------------- NoC
def test_xy_route_lengths():
    noc = NocConfig(4, 4)
    assert hops(noc, (0, 0), (0, 0)) == 0
    assert hops(noc, (0, 0), (3, 3)) == 6
    assert hops(noc, (1, 1), (1, 0)) == 1


def test_torus_wraps_shorter():
    noc = NocConfig(4, 4, torus=True)
    assert hops(noc, (0, 0), (0, 3)) == 1       # wrap
    assert hops(noc, (0, 0), (3, 3)) == 2


def test_contention_monotone():
    noc = NocModel(NocConfig(4, 4))
    s0 = noc.slowdown((3, 3), (1, 0))
    noc.add_flow(Flow((2, 2), (1, 0), 0.5))
    noc.add_flow(Flow((3, 1), (1, 0), 0.4))
    s1 = noc.slowdown((3, 3), (1, 0))
    assert s1 >= s0 >= 1.0


# -------------------------------------------------------------- DFS policies
def _telemetry(boundness, exec_time=1.0):
    return C.TileTelemetry(exec_time=exec_time, pkts_in=0, pkts_out=0,
                           rtt=0, boundness=boundness)


def test_policy_memory_bound_drops_bound_islands():
    cfg = get_config("granite-8b")
    plan = C.default_plan(cfg)
    isl = C.default_islands(plan)
    tel = {t.name: _telemetry(0.9) for t in plan.tiles}
    tel["ffn"] = _telemetry(0.1)
    rates = C.policy_memory_bound(isl, tel)
    assert rates["attn"] < 1.0                  # memory-bound -> derated
    assert rates["ffn"] == 1.0                  # compute-bound -> full rate
    assert "noc_mem" not in rates               # never derate the bottleneck


def test_policy_straggler_keeps_straggler_fast():
    cfg = get_config("granite-8b")
    plan = C.default_plan(cfg)
    isl = C.default_islands(plan)
    tel = {t.name: _telemetry(0.5, exec_time=1.0) for t in plan.tiles}
    tel["attn"] = _telemetry(0.5, exec_time=5.0)      # straggler
    rates = C.policy_straggler(isl, tel)
    assert rates["attn"] == 1.0
    assert all(v <= 1.0 for v in rates.values())


# ----------------------------------------------------------------- roofline
def test_roofline_terms_and_dominance():
    t = C.roofline_from_counts(flops=1e15, hbm_bytes=1e12,
                               collective_bytes=1e9, chips=256)
    assert t.t_compute > 0 and t.t_memory > 0 and t.t_collective > 0
    assert t.dominant in ("compute", "memory", "collective")
    assert 0 < t.roofline_fraction <= 1.0


def test_dfs_rate_scales_terms():
    t1 = C.roofline_from_counts(1e15, 1e12, 1e9, 256, f_comp=1.0)
    t2 = C.roofline_from_counts(1e15, 1e12, 1e9, 256, f_comp=0.5)
    assert abs(t2.t_compute - 2 * t1.t_compute) < 1e-12
