"""Validation of the paper's experimental claims (Table I, Fig. 3, Fig. 4)
against the vespa-jax perf model — the 'reproduce faithfully' gate."""
import numpy as np
import pytest

from repro.configs.vespa_soc import CHSTONE, TABLE_I, paper_soc
from repro.core.perfmodel import AccelWorkload, SoCPerfModel
from repro.core.replication import replication_throughput_model


def _wl(name, k=1):
    base, ai = CHSTONE[name]
    return AccelWorkload(name, base, ai, replication=k)


# ----------------------------------------------------------------- Table I
def test_table_i_throughput_gains_per_accel():
    """Every CHStone accelerator's measured 2x/4x gains are within 25% of
    the calibrated replication model."""
    for name, rows in TABLE_I.items():
        base = rows[1][4]
        for k in (2, 4):
            measured = rows[k][4] / base
            predicted = replication_throughput_model(k)
            assert 0.6 * predicted <= measured <= 1.45 * predicted, (
                name, k, measured, predicted)


def test_table_i_average_gains():
    """The paper states 1.92x / 3.58x average gains.  Recomputing from its
    own Table I data gives 1.89x / 3.41x (the paper's stated averages are
    slightly optimistic vs its table); we assert both within 6%."""
    gains2 = np.mean([rows[2][4] / rows[1][4] for rows in TABLE_I.values()])
    gains4 = np.mean([rows[4][4] / rows[1][4] for rows in TABLE_I.values()])
    assert abs(gains2 - 1.92) / 1.92 < 0.06
    assert abs(gains4 - 3.58) / 3.58 < 0.06


def test_table_i_area_sublinear():
    """LUT/FF grow sub-K (shared tile logic); DSP grows ~K (paper Sec III-A)."""
    for name, rows in TABLE_I.items():
        lut1, ff1, _, dsp1, _ = rows[1]
        lut4, ff4, _, dsp4, _ = rows[4]
        assert lut4 / lut1 < 4.0 and ff4 / ff1 < 4.0
        assert dsp4 == 4 * dsp1


def test_model_throughput_scaling_on_soc():
    """SoCPerfModel end-to-end: K=2/4 gains in the paper's measured band."""
    m = SoCPerfModel()
    rates = {"acc": 1.0, "noc_mem": 1.0, "tg": 1.0}
    thr = {k: m.accel_throughput(_wl("dfadd", k), (1, 1), rates, n_tg=0)
           for k in (1, 2, 4)}
    assert 1.5 <= thr[2] / thr[1] <= 2.0
    assert 2.5 <= thr[4] / thr[1] <= 4.0


# ------------------------------------------------------------------- Fig. 3
def test_fig3_compute_bound_flat_memory_bound_collapses():
    m = SoCPerfModel()
    rates = {"acc": 1.0, "noc_mem": 0.1, "tg": 1.0}   # paper: NoC at 10MHz
    adpcm = [m.accel_throughput(_wl("adpcm", 4), (3, 3), rates, n)
             for n in range(12)]
    dfmul = [m.accel_throughput(_wl("dfmul", 4), (3, 3), rates, n)
             for n in range(12)]
    # compute-bound: ~flat in the low-contention half (paper: 0..7 TGs)
    assert adpcm[4] >= 0.9 * adpcm[0]
    # memory-bound: collapses in the same range
    assert dfmul[7] <= 0.6 * dfmul[0]
    # both monotone non-increasing
    assert all(a >= b - 1e-9 for a, b in zip(adpcm, adpcm[1:]))
    assert all(a >= b - 1e-9 for a, b in zip(dfmul, dfmul[1:]))


# ------------------------------------------------------------------- Fig. 4
def test_fig4_accel_freq_negligible_tg_noc_dominant():
    m = SoCPerfModel()
    pos = [(1, 1), (3, 3)]
    base = {"acc": 1.0, "noc_mem": 1.0, "tg": 1.0}

    t_full = m.memory_traffic_mpkts(base, 11, pos)
    t_acc_low = m.memory_traffic_mpkts({**base, "acc": 0.2}, 11, pos)
    # accelerator-island frequency: negligible impact (memory-bound dfmul)
    assert abs(t_full - t_acc_low) / t_full < 0.25

    # TG frequency x NoC frequency dominates
    t_tg_low = m.memory_traffic_mpkts({**base, "tg": 0.2}, 11, pos)
    t_noc_low = m.memory_traffic_mpkts({**base, "noc_mem": 0.2}, 11, pos)
    assert t_tg_low < 0.6 * t_full
    assert t_noc_low < 0.6 * t_full


def test_paper_soc_instance():
    tiles, islands = paper_soc()
    assert len(tiles) == 16                     # 4x4
    assert len(islands) == 5                    # the paper's five islands
    assert sum(t.kind == "tg" for t in tiles) == 11
    noc = next(i for i in islands if i.name == "NOC_MEM")
    assert noc.f_max_mhz == 100 and noc.f_min_mhz == 10
