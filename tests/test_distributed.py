"""Distributed behaviour on an 8-device host mesh (subprocess-isolated so
the main pytest process keeps its single real device)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    """DP x TP sharded train step == unsharded step (same seed, same data)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.runtime.train import Trainer, TrainConfig
        from repro.models.layers import AttnOptions
        from repro.optim import adamw

        cfg = get_config('granite-8b').reduced()
        shape = ShapeConfig('tiny', 32, 4, 'train')
        tc = TrainConfig(log_every=1, opt=adamw.AdamWConfig(lr=1e-3,
                         warmup_steps=1, total_steps=50))
        kw = dict(lm_kwargs=dict(opts=AttnOptions(backend='naive'),
                                 remat=False), tc=tc)
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        # compat.set_mesh: jax.set_mesh doesn't exist on the pinned jax
        # 0.4.x (the seed failure mode of this test was an AttributeError
        # inside the subprocess, not loss drift); the Mesh context manager
        # installs the same ambient mesh there.  The residual sharded-vs-
        # single drift under it is ~2e-3 (f32 collective reduction order),
        # well inside the 2e-2 gate.
        from repro.compat import set_mesh
        with set_mesh(mesh):
            tr_m = Trainer(cfg, shape, mesh=mesh, **kw)
            h_m = tr_m.run(3)
        tr_1 = Trainer(cfg, shape, mesh=None, **kw)
        h_1 = tr_1.run(3)
        for (s1, m1), (s2, m2) in zip(h_m, h_1):
            assert abs(m1['loss'] - m2['loss']) < 2e-2, (m1['loss'], m2['loss'])
        print('SHARDED==SINGLE OK', h_m[-1][1]['loss'])
    """)
    assert "SHARDED==SINGLE OK" in out


def test_moe_shard_map_path_matches_local():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models.moe import moe_spec, moe_apply, _moe_ffn_local
        from repro.models.params import init_params

        cfg = get_config('granite-moe-1b-a400m').reduced()
        p = init_params(moe_spec(cfg), jax.random.PRNGKey(0))
        p = jax.tree_util.tree_map(lambda a: a.astype(jnp.float32), p)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))

        local, aux_l = _moe_ffn_local({k: v for k, v in p.items()
                                       if k != 'shared'},
                                      x.reshape(-1, cfg.d_model), cfg)
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        from repro.compat import set_mesh
        with set_mesh(mesh):
            out, aux = jax.jit(lambda p, x: moe_apply(p, cfg, x))(p, x)
        ref = local.reshape(x.shape)
        if 'shared' in p:
            sp = p['shared']
            g = jax.nn.silu(x @ sp['wi_gate'])
            ref = ref + (g * (x @ sp['wi_up'])) @ sp['wo']
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 2e-4, err
        # aux is the mean of per-data-shard losses (nonlinear in the token
        # split), so it only approximately equals the global-batch aux
        assert abs(float(aux) - float(aux_l)) < 0.15 * abs(float(aux_l))
        print('MOE SHARDMAP OK', err)
    """)
    assert "MOE SHARDMAP OK" in out


def test_compressed_allreduce_pod_axis():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.optim.compress import compressed_psum_leaf
        from repro.compat import shard_map
        from jax.sharding import PartitionSpec as P

        mesh = jax.make_mesh((2, 4), ('pod', 'data'))
        g = jax.random.normal(jax.random.PRNGKey(0), (2, 64))

        def body(x):
            return compressed_psum_leaf(x[0], 'pod')

        out = shard_map(body, mesh=mesh, in_specs=(P('pod', None),),
                        out_specs=P(None), check_vma=False)(g)
        exact = g.sum(0)
        rel = float(jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact))
        assert rel < 0.02, rel
        print('COMPRESSED ALLREDUCE OK', rel)
    """)
    assert "COMPRESSED ALLREDUCE OK" in out


def test_elastic_restore_across_meshes(tmp_path):
    """Checkpoint on a (2,4) mesh, restore onto (4,2) and (1,) meshes."""
    out = _run(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.store import CheckpointStore

        t = {{'w': jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        m1 = jax.make_mesh((2, 4), ('data', 'model'))
        t1 = {{'w': jax.device_put(t['w'], NamedSharding(m1, P('data', 'model')))}}
        store = CheckpointStore({str(tmp_path)!r})
        store.save(1, t1)

        m2 = jax.make_mesh((4, 2), ('data', 'model'))
        sh2 = {{'w': NamedSharding(m2, P('model', 'data'))}}
        out = store.restore(t, shardings=sh2)
        np.testing.assert_array_equal(np.asarray(out['w']), np.asarray(t['w']))
        assert out['w'].sharding == sh2['w']
        print('ELASTIC RESTORE OK')
    """)
    assert "ELASTIC RESTORE OK" in out


def test_mini_dryrun_mra_mesh():
    """K-factored MRA mesh compiles the same train step (paper C1 on 8 dev)."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.core.replication import make_mra_mesh, merged_rules
        from repro.core.tiles import default_plan
        from repro.configs import get_config
        from repro.models.transformer import LM
        from repro.models.layers import AttnOptions
        from repro.models.params import abstract_params, shardings_for

        cfg = get_config('granite-8b').reduced()
        lm = LM(cfg, opts=AttnOptions(backend='naive'), remat=False)
        plan = default_plan(cfg).with_replication('ffn', 2)
        mesh = jax.make_mesh((2, 2, 2), ('data', 'replica', 'shard'))
        rules = merged_rules(plan, mesh)
        assert rules['ff'] == 'shard'          # ffn tile: K=2 -> replicated
        assert rules['qkv'] == ('replica', 'shard')   # attn: K=1 -> full TP
        specs = lm.param_specs()
        sh = shardings_for(specs, rules, mesh)
        params = abstract_params(specs)
        toks = jax.ShapeDtypeStruct((4, 32), jnp.int32)
        from repro.compat import set_mesh
        with set_mesh(mesh):
            lowered = jax.jit(lambda p, t: lm.forward(p, tokens=t)[0],
                              in_shardings=(sh, None)).lower(params, toks)
            lowered.compile()
        print('MRA MESH OK')
    """)
    assert "MRA MESH OK" in out
