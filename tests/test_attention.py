"""Attention backends: chunked/folded flash-in-XLA vs the naive oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import layers as L

KEY = jax.random.PRNGKey(2)


def _mk(B, S, KV, G, hd, dtype=jnp.float32):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, KV, G, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return q, k, v, pos


@pytest.mark.parametrize("folded", [False, True])
@pytest.mark.parametrize("qb", [32, 64])
def test_chunked_matches_naive(folded, qb):
    q, k, v, pos = _mk(2, 256, 2, 3, 32)
    scale = 1 / np.sqrt(32)
    ref = L.attention_naive(q, k, v, pos, pos, 0, scale)
    opts = L.AttnOptions(q_block=qb, kv_block=qb, folded=folded)
    out = L.attention_chunked(q, k, v, pos, pos, 0, scale, opts)
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_chunked_sliding_window():
    q, k, v, pos = _mk(2, 256, 2, 2, 32)
    scale = 1 / np.sqrt(32)
    ref = L.attention_naive(q, k, v, pos, pos, 48, scale)
    out = L.attention_chunked(q, k, v, pos, pos, 48, scale,
                              L.AttnOptions(q_block=32, kv_block=32))
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_chunked_grad_matches_naive_grad():
    q, k, v, pos = _mk(1, 128, 1, 2, 16)
    scale = 1 / np.sqrt(16)

    def f_naive(q):
        return jnp.sum(L.attention_naive(q, k, v, pos, pos, 0, scale) ** 2)

    def f_chunk(q):
        return jnp.sum(L.attention_chunked(
            q, k, v, pos, pos, 0, scale,
            L.AttnOptions(q_block=32, kv_block=32, folded=True)) ** 2)

    g1, g2 = jax.grad(f_naive)(q), jax.grad(f_chunk)(q)
    np.testing.assert_allclose(g1, g2, atol=5e-4)


@settings(max_examples=15, deadline=None)
@given(
    S=st.sampled_from([64, 128]),
    KV=st.sampled_from([1, 2]),
    G=st.sampled_from([1, 3]),
    hd=st.sampled_from([8, 32]),
    window=st.sampled_from([0, 16, 100]),
)
def test_property_chunked_equals_naive(S, KV, G, hd, window):
    q, k, v, pos = _mk(1, S, KV, G, hd)
    scale = 1 / np.sqrt(hd)
    ref = L.attention_naive(q, k, v, pos, pos, window, scale)
    out = L.attention_chunked(q, k, v, pos, pos, window, scale,
                              L.AttnOptions(q_block=32, kv_block=32))
    np.testing.assert_allclose(out, ref, atol=3e-5)


def test_softmax_rows_sum_to_one_property():
    """Online softmax invariant: output is a convex combination of V rows."""
    q, k, v, pos = _mk(1, 64, 1, 1, 8)
    vmax = jnp.max(jnp.abs(v))
    out = L.attention_chunked(q, k, v, pos, pos, 0, 1.0,
                              L.AttnOptions(q_block=16, kv_block=16))
    assert float(jnp.max(jnp.abs(out))) <= float(vmax) + 1e-5


def test_rope_rotation_invariant():
    """RoPE: <rot(q,p), rot(k,p)> depends only on relative position."""
    hd = 16
    q = jax.random.normal(KEY, (1, 1, 1, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 1, 1, hd))
    def dot_at(pq, pk):
        qr = L.apply_rope(q, jnp.array([[pq]], jnp.int32), 10_000.0)
        kr = L.apply_rope(k, jnp.array([[pk]], jnp.int32), 10_000.0)
        return float(jnp.sum(qr * kr))
    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-4
    assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-6   # but not absolute-invariant
