"""Trace-generator statistics (ISSUE 5 satellite) + BatchTrace surface.

The generators were only exercised indirectly through simulation runs;
these tests pin their seeded statistical contracts directly: mean rates,
total request counts, per-destination splits, determinism per seed, and
the composition helpers.  Sampled means are checked against law-of-large
-numbers bounds wide enough to be deterministic for the pinned seeds.
"""
import numpy as np
import pytest

from repro.sim import (BatchTrace, Trace, constant_trace, diurnal_trace,
                       mmpp_trace, poisson_trace, replay_trace, superpose,
                       with_total)


# ----------------------------------------------------------- constant
def test_constant_trace_is_exact():
    tr = constant_trace(1200.0, 500, 4, dt=1e-3)
    assert tr.arrivals.shape == (500, 4)
    # a scalar rate is a TOTAL, split evenly over destinations
    np.testing.assert_allclose(tr.arrivals, 1200.0 / 4 * 1e-3)
    np.testing.assert_allclose(tr.n_requests, 1200.0 * 0.5)
    np.testing.assert_allclose(tr.offered_rps, 1200.0)
    # a vector rate is per-destination
    tr = constant_trace(np.asarray([100.0, 300.0]), 100, 2, dt=1e-3)
    np.testing.assert_allclose(tr.arrivals.sum(axis=0), [10.0, 30.0])


# ------------------------------------------------------------ poisson
@pytest.mark.parametrize("seed", [0, 7])
def test_poisson_trace_mean_rate_and_split(seed):
    rate, ticks, n, dt = 5000.0, 4000, 4, 1e-3
    tr = poisson_trace(rate, ticks, n, dt=dt, seed=seed)
    assert tr.arrivals.shape == (ticks, n)
    assert np.all(tr.arrivals >= 0)
    assert np.all(tr.arrivals == np.floor(tr.arrivals))   # integer counts
    # total-mean: N ~ Poisson(rate * T * dt), sd = sqrt(mean); 6 sigma
    mean = rate * ticks * dt
    assert abs(tr.n_requests - mean) < 6.0 * np.sqrt(mean)
    # even per-destination split, each a Poisson(mean / n)
    per = tr.arrivals.sum(axis=0)
    assert np.all(np.abs(per - mean / n) < 6.0 * np.sqrt(mean / n))
    # determinism per seed; different seed, different sample
    np.testing.assert_array_equal(
        tr.arrivals, poisson_trace(rate, ticks, n, dt=dt, seed=seed).arrivals)
    assert not np.array_equal(
        tr.arrivals,
        poisson_trace(rate, ticks, n, dt=dt, seed=seed + 1).arrivals)


# ------------------------------------------------------------ diurnal
@pytest.mark.parametrize("depth", [0.0, 0.6])
def test_diurnal_trace_mean_rate_and_modulation(depth):
    mean_rps, ticks, n, dt = 8000.0, 6000, 3, 1e-3
    tr = diurnal_trace(mean_rps, ticks, n, dt=dt, depth=depth, seed=3)
    # the sinusoid integrates to zero over a full period: total-mean is
    # mean_rps * duration (6 sigma of the Poisson total)
    mean = mean_rps * ticks * dt
    assert abs(tr.n_requests - mean) < 6.0 * np.sqrt(mean)
    if depth > 0:
        # peak/trough halves actually differ (the modulation is real):
        # first quarter is the rising peak, third quarter the trough
        q = ticks // 4
        peak = tr.arrivals[:q].sum()
        trough = tr.arrivals[2 * q:3 * q].sum()
        assert peak > trough * 1.5
    else:
        # depth=0 degrades to homogeneous Poisson
        q = ticks // 4
        assert abs(tr.arrivals[:q].sum()
                   - tr.arrivals[2 * q:3 * q].sum()) < 6.0 * np.sqrt(mean)


def test_diurnal_requires_valid_depth():
    with pytest.raises(AssertionError):
        diurnal_trace(100.0, 10, 1, depth=1.0)


# --------------------------------------------------------------- mmpp
def test_mmpp_trace_rate_between_states_and_burstiness():
    lo, hi, ticks, n, dt = 500.0, 20000.0, 8000, 2, 1e-3
    tr = mmpp_trace(lo, hi, ticks, n, dt=dt, seed=5,
                    p_low_to_high=0.01, p_high_to_low=0.05)
    # long-run state occupancy: pi_high = p_lh / (p_lh + p_hl) = 1/6 ->
    # expected rate = lo + (hi - lo)/6; allow generous chain noise
    exp_rate = lo + (hi - lo) * (0.01 / 0.06)
    got = tr.n_requests / tr.duration_s
    assert 0.5 * exp_rate < got < 1.8 * exp_rate, (got, exp_rate)
    # bursty: the per-tick variance far exceeds the Poisson mean
    per_tick = tr.arrivals.sum(axis=1)
    assert per_tick.var() > 3.0 * per_tick.mean()
    # determinism per seed
    np.testing.assert_array_equal(
        tr.arrivals,
        mmpp_trace(lo, hi, ticks, n, dt=dt, seed=5, p_low_to_high=0.01,
                   p_high_to_low=0.05).arrivals)


# ------------------------------------------------------------- replay
def test_replay_trace_bins_exactly():
    times = [0.0, 0.0004, 0.0012, 0.0029, 0.005, -1.0, 99.0]
    dests = [0, 1, 1, 0, 1, 0, 1]          # last two: out of range
    tr = replay_trace(times, dests, 2, dt=1e-3, ticks=6)
    assert tr.arrivals.shape == (6, 2)
    assert tr.n_requests == 5.0            # dropped the out-of-range pair
    np.testing.assert_array_equal(tr.arrivals[0], [1, 1])
    np.testing.assert_array_equal(tr.arrivals[1], [0, 1])
    np.testing.assert_array_equal(tr.arrivals[2], [1, 0])
    np.testing.assert_array_equal(tr.arrivals[5], [0, 1])


# -------------------------------------------------------- composition
def test_superpose_and_with_total():
    a = constant_trace(100.0, 50, 2, dt=1e-3)
    b = constant_trace(300.0, 30, 2, dt=1e-3)
    s = superpose(a, b)
    assert s.ticks == 50 and s.n_dests == 2
    np.testing.assert_allclose(s.n_requests,
                               a.n_requests + b.n_requests)
    t = with_total(s, 12345.0)
    np.testing.assert_allclose(t.n_requests, 12345.0)
    # shape preserved: scaling is uniform
    np.testing.assert_allclose(t.arrivals / s.arrivals.clip(min=1e-300),
                               12345.0 / s.n_requests)


# --------------------------------------------------------- BatchTrace
def test_batch_trace_broadcast_stack_design_scaled():
    base = poisson_trace(2000.0, 60, 3, dt=1e-3, seed=1)
    bc = BatchTrace.broadcast(base, 4)
    assert (bc.ticks, bc.n_designs, bc.n_dests) == (60, 4, 3)
    np.testing.assert_allclose(bc.n_requests,
                               np.full(4, base.n_requests))
    np.testing.assert_array_equal(bc.design(2).arrivals, base.arrivals)

    others = [poisson_trace(2000.0, 60, 3, dt=1e-3, seed=s)
              for s in (1, 2)]
    st = BatchTrace.stack(others)
    assert st.n_designs == 2
    np.testing.assert_array_equal(st.design(0).arrivals,
                                  others[0].arrivals)
    np.testing.assert_array_equal(st.design(1).arrivals,
                                  others[1].arrivals)
    assert st.design(0).dt == base.dt

    sc = st.scaled(np.asarray([1.0, 0.5]))
    np.testing.assert_allclose(sc.n_requests,
                               st.n_requests * np.asarray([1.0, 0.5]))
    with pytest.raises(AssertionError):
        BatchTrace.stack([others[0],
                          poisson_trace(2000.0, 61, 3, dt=1e-3, seed=3)])
