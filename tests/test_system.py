"""End-to-end system behaviour: the Vespa loop (train + monitor + DFS +
checkpoint + DSE) running together, as a deployment would."""
import numpy as np
import pytest

import repro.core as C
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.dfs import TileTelemetry
from repro.models.layers import AttnOptions
from repro.optim import adamw
from repro.runtime.fault import FaultSupervisor
from repro.runtime.train import TrainConfig, Trainer


def test_full_vespa_loop(tmp_path):
    """Train with monitoring, apply a DFS policy from telemetry, checkpoint,
    crash, recover, keep training — loss history stays consistent."""
    cfg = get_config("h2o-danube-1.8b").reduced()
    shape = ShapeConfig("tiny", 48, 4, "train")
    tc = TrainConfig(log_every=1, ckpt_every=3, ckpt_dir=str(tmp_path),
                     monitor_every=1,
                     opt=adamw.AdamWConfig(lr=5e-4, warmup_steps=2,
                                           total_steps=100))
    tr = Trainer(cfg, shape, tc=tc,
                 lm_kwargs=dict(opts=AttnOptions(backend="naive"),
                                remat=True))
    sup = FaultSupervisor(tr)

    hist = tr.run(6)
    assert len(tr.monitor.samples) >= 6

    # C3 -> C2: derive telemetry from counters, run the Fig.4 policy, commit
    sample = tr.monitor.samples[-1]
    tel = {}
    for t in tr.plan.tiles:
        row = sample.counters.get(t.name, {})
        tel[t.name] = TileTelemetry(
            exec_time=row.get("exec_time", 1.0) or 1.0,
            pkts_in=row.get("pkts_in", 0.0), pkts_out=row.get("pkts_out", 0.0),
            rtt=row.get("rtt", 0.0), boundness=0.9)
    rates = C.policy_memory_bound(tr.islands, tel)
    tr.actuator.reconfigure(rates)
    tr.run(1)                                  # hitless commit between steps
    assert tr.actuator.swaps >= 1

    # crash + recover
    tr.store().wait()
    before = tr.step
    tr.params = None                           # simulated total state loss
    sup.recover()
    assert tr.step <= before
    h2 = tr.run(2)
    assert np.isfinite(h2[-1][1]["loss"])


def test_dse_sweep_produces_pareto_front():
    from repro.core.dse import sweep_soc, pareto_front, summarize
    from repro.core.perfmodel import SoCPerfModel, AccelWorkload
    from repro.configs.vespa_soc import CHSTONE

    m = SoCPerfModel()
    base, ai = CHSTONE["gsm"]
    pts = sweep_soc(m, AccelWorkload("gsm", base, ai), n_tg=4)
    assert len(pts) == 3 * 3 * 3 * 2
    front = pareto_front(pts)
    assert 1 <= len(front) < len(pts)
    # placement matters: near-memory position dominates far for same config
    near = [p for p in pts if p.placement["gsm"] == (1, 1)]
    far = [p for p in pts if p.placement["gsm"] == (3, 3)]
    assert np.mean([p.throughput for p in near]) >= np.mean(
        [p.throughput for p in far])
    assert "Pareto" in summarize(pts)
