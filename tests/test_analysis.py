"""The analyzer analyzed: fixture snippets per rule (positive,
negative, noqa), baseline round-trip, the CLI self-check against the
committed baseline, and seeded mutation tests proving each rule still
fires on a known-bad snippet — including re-introducing PR 8's
dt-missing-from-the-jit-cache-key bug into the real ``sim/batch.py``
source, which RPR002 must catch.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (Finding, analyze_paths, load_baseline,
                            save_baseline)
from repro.analysis.findings import (extract_comments, fingerprint,
                                     parse_noqa)
from repro.analysis.rules import RULES, get_rules

ROOT = Path(__file__).resolve().parents[1]
BATCH_SRC = ROOT / "src" / "repro" / "sim" / "batch.py"


def run_on(tmp_path, sources, rules=None):
    """Write {relpath: source} under tmp_path and analyze them all."""
    paths = []
    for rel, src in sources.items():
        f = tmp_path / rel
        f.parent.mkdir(parents=True, exist_ok=True)
        f.write_text(src)
        paths.append(f)
    return analyze_paths(paths, root=tmp_path,
                         rules=get_rules(rules) if rules else None)


def by_rule(report, rule):
    return [f for f in report.new if f.rule == rule]


# ---------------------------------------------------------------- RPR001
RPR001_POS = """\
import jax
import jax.numpy as jnp

def step(x):
    if x > 0:
        return x
    return float(x) * jnp.ones(())

fast = jax.jit(step)
"""

RPR001_NEG = """\
import jax
import jax.numpy as jnp

def step(x, cfg, n: int, *, gain):
    if cfg.mode == "fast":          # config object: static
        x = x * gain                # kw-only: static
    if n > 2:                       # int-annotated: static
        x = x + 1
    if x.shape[0] > 1:              # shape read: static
        x = x.sum()
    if x is None:                   # identity check: static
        return jnp.zeros(())
    return jnp.where(x > 0, x, -x)  # traced branch done right

fast = jax.jit(step)
"""


def test_rpr001_fires_on_traced_branch_and_coercion(tmp_path):
    rep = run_on(tmp_path, {"snippet.py": RPR001_POS}, rules=["RPR001"])
    msgs = [f.message for f in by_rule(rep, "RPR001")]
    assert any("`if` on a traced value" in m for m in msgs)
    assert any("float() coerces" in m for m in msgs)


def test_rpr001_quiet_on_static_idioms(tmp_path):
    rep = run_on(tmp_path, {"snippet.py": RPR001_NEG}, rules=["RPR001"])
    assert by_rule(rep, "RPR001") == []


def test_rpr001_scan_body_reached_through_call_graph(tmp_path):
    src = (
        "from jax import lax\n"
        "def helper(c):\n"
        "    if c:\n"
        "        return c\n"
        "    return -c\n"
        "def step(carry, x):\n"
        "    return helper(carry), x\n"
        "def run(xs):\n"
        "    return lax.scan(step, 0.0, xs)\n")
    rep = run_on(tmp_path, {"snippet.py": src}, rules=["RPR001"])
    hits = by_rule(rep, "RPR001")
    assert len(hits) == 1 and "helper" in hits[0].message


def test_rpr001_traced_marker_opts_a_closure_in(tmp_path):
    body = ("def outer():\n"
            "    def inner(x):{marker}\n"
            "        if x > 0:\n"
            "            return x\n"
            "        return -x\n"
            "    return inner\n")
    quiet = run_on(tmp_path, {"s.py": body.format(marker="")},
                   rules=["RPR001"])
    assert by_rule(quiet, "RPR001") == []
    loud = run_on(tmp_path,
                  {"s.py": body.format(marker="  # repro: traced")},
                  rules=["RPR001"])
    assert len(by_rule(loud, "RPR001")) == 1


def test_rpr001_noqa_suppresses_with_justification(tmp_path):
    src = RPR001_POS.replace(
        "    if x > 0:",
        "    if x > 0:  # repro: noqa[RPR001] debug-only host branch")
    rep = run_on(tmp_path, {"snippet.py": src}, rules=["RPR001"])
    assert not any("`if` on a traced value" in f.message
                   for f in by_rule(rep, "RPR001"))
    sup = [f for f in rep.suppressed if f.rule == "RPR001"]
    assert sup and sup[0].justification == "debug-only host branch"


def test_noqa_without_justification_is_rpr000(tmp_path):
    src = RPR001_POS.replace(
        "    if x > 0:", "    if x > 0:  # repro: noqa[RPR001]")
    rep = run_on(tmp_path, {"snippet.py": src}, rules=["RPR001"])
    assert any(f.rule == "RPR000" and "justification" in f.message
               for f in rep.new)


def test_noqa_in_docstring_is_inert():
    comments = extract_comments(
        'def f():\n    """# repro: noqa[RPR001] not a comment"""\n'
        "    return 1  # repro: noqa[RPR003] real comment\n")
    assert list(comments) == [3]
    assert parse_noqa(comments[3]) == ({"RPR003"}, "real comment")


# ---------------------------------------------------------------- RPR002
CACHE_SNIPPET = """\
import jax

class Eng:
    def __init__(self):
        self._cache = {{}}

    def _cached_fn(self, sig, build):
        fn = self._cache.get(sig)
        if fn is None:
            fn = build()
            self._cache[sig] = fn
            while len(self._cache) > 4:
                self._cache.pop(next(iter(self._cache)))
        return fn

    def run(self, trace):
        T = trace.ticks
        dt = trace.dt

        def inner(x):
            return x * dt + T

        def build():
            return jax.jit(inner)

        sig = {sig}
        return self._cached_fn(sig, build)
"""


def test_rpr002_flags_param_derived_value_missing_from_key(tmp_path):
    rep = run_on(tmp_path,
                 {"s.py": CACHE_SNIPPET.format(sig='("scan", T)')},
                 rules=["RPR002"])
    hits = by_rule(rep, "RPR002")
    assert len(hits) == 1 and "`dt`" in hits[0].message


def test_rpr002_quiet_when_key_is_complete(tmp_path):
    rep = run_on(tmp_path,
                 {"s.py": CACHE_SNIPPET.format(sig='("scan", T, dt)')},
                 rules=["RPR002"])
    assert by_rule(rep, "RPR002") == []


def test_rpr002_helper_call_counts_as_keying_its_args(tmp_path):
    src = CACHE_SNIPPET.format(sig="self._sig(T=T, dt=dt)") + (
        "\n    def _sig(self, *, T, dt):\n"
        "        return (\"scan\", T, dt)\n")
    rep = run_on(tmp_path, {"s.py": src}, rules=["RPR002"])
    assert by_rule(rep, "RPR002") == []


def test_rpr002_lossy_derivation_does_not_count_as_keyed(tmp_path):
    # keying f(dt) is not keying dt: the derived value can collapse
    # distinct dt (the PR 8 bug shape: deadline_ticks=None erased dt)
    src = CACHE_SNIPPET.format(sig='("scan", T, ticks2)').replace(
        "        dt = trace.dt\n",
        "        dt = trace.dt\n        ticks2 = dt / 2 if T else None\n")
    rep = run_on(tmp_path, {"s.py": src}, rules=["RPR002"])
    assert any("`dt`" in f.message for f in by_rule(rep, "RPR002"))


def test_rpr002_mutation_real_batch_missing_dt_fires(tmp_path):
    """Re-introduce PR 8's dt-cache-collision bug into the real source:
    drop dt from the _scan_cache_sig call — RPR002 must catch it."""
    src = BATCH_SRC.read_text()
    assert "sig = self._scan_cache_sig(T=T, ci=ci, dt=dt," in src
    mut = src.replace("sig = self._scan_cache_sig(T=T, ci=ci, dt=dt,",
                      "sig = self._scan_cache_sig(T=T, ci=ci,")
    mut = mut.replace("def _scan_cache_sig(self, *, T, ci, dt, B,",
                      "def _scan_cache_sig(self, *, T, ci, dt=0.0, B=0,")
    rep = run_on(tmp_path, {"sim/batch.py": mut}, rules=["RPR002"])
    assert any("`dt`" in f.message for f in by_rule(rep, "RPR002"))


def test_rpr002_unmutated_batch_is_clean(tmp_path):
    rep = run_on(tmp_path, {"sim/batch.py": BATCH_SRC.read_text()},
                 rules=["RPR002"])
    assert by_rule(rep, "RPR002") == []


# ---------------------------------------------------------------- RPR003
def test_rpr003_unbounded_shapes_fire(tmp_path):
    src = (
        "import functools\n"
        "from functools import lru_cache\n"
        "_CACHE = {}\n"
        "def put(k, v):\n"
        "    _CACHE[k] = v\n"
        "@lru_cache(maxsize=None)\n"
        "def slow(x):\n"
        "    return x\n"
        "@functools.cache\n"
        "def slower(x):\n"
        "    return x\n"
        "class C:\n"
        "    def __init__(self):\n"
        "        self.memo = {}\n"
        "    def get(self, k):\n"
        "        if k not in self.memo:\n"
        "            self.memo[k] = k * 2\n"
        "        return self.memo[k]\n")
    rep = run_on(tmp_path, {"s.py": src}, rules=["RPR003"])
    msgs = " | ".join(f.message for f in by_rule(rep, "RPR003"))
    assert "_CACHE" in msgs
    assert "maxsize=None" in msgs
    assert "functools.cache" in msgs
    assert "self.memo" in msgs


def test_rpr003_bounded_shapes_pass(tmp_path):
    src = (
        "from functools import lru_cache\n"
        "from collections import OrderedDict\n"
        "_CACHE = {}\n"
        "def put(k, v):\n"
        "    _CACHE[k] = v\n"
        "    while len(_CACHE) > 8:\n"
        "        _CACHE.pop(next(iter(_CACHE)))\n"
        "@lru_cache(maxsize=32)\n"
        "def slow(x):\n"
        "    return x\n"
        "class C:\n"
        "    def __init__(self):\n"
        "        self.state = {}\n"
        "    def put(self, k, v):\n"
        "        self.state[k] = v   # plain bookkeeping, not a memo\n")
    rep = run_on(tmp_path, {"s.py": src}, rules=["RPR003"])
    assert by_rule(rep, "RPR003") == []


# ---------------------------------------------------------------- RPR004
def test_rpr004_f32_in_reference_scope_fires(tmp_path):
    src = ("import numpy as np\n"
           "def run():\n"
           "    return np.zeros(3, dtype=np.float32)\n")
    rep = run_on(tmp_path, {"sim/engine.py": src}, rules=["RPR004"])
    assert len(by_rule(rep, "RPR004")) == 1
    # same code outside the declared reference set: fine
    rep2 = run_on(tmp_path, {"other.py": src}, rules=["RPR004"])
    assert by_rule(rep2, "RPR004") == []


def test_rpr004_direct_f64_on_jax_path_fires(tmp_path):
    src = ("import numpy as np\n"
           "import jax.numpy as jnp\n"
           "def up(x):\n"
           "    return jnp.asarray(x, dtype=jnp.float64)\n"
           "def stage(x):\n"
           "    return np.asarray(x, dtype=np.float64)  # host: fine\n")
    rep = run_on(tmp_path, {"other.py": src}, rules=["RPR004"])
    hits = by_rule(rep, "RPR004")
    assert len(hits) == 1 and hits[0].line == 4


# ---------------------------------------------------------------- RPR005
PALLAS_BAD = """\
import numpy as np
import jax.numpy as jnp
from jax.experimental import pallas as pl

def make(x):
    table = jnp.arange(4.0)

    def kernel(ref, o_ref):
        v = ref[...]
        if v[0] > 0:
            o_ref[...] = v
        o_ref[...] = v + np.exp(1.0) + table[0]

    return pl.pallas_call(kernel, out_shape=x)
"""

PALLAS_OK = """\
import numpy as np
import jax.numpy as jnp
from jax.experimental import pallas as pl
import functools

def _kernel(*refs, n, extra_bool):
    dtp = refs[0].dtype
    if np.issubdtype(dtp, np.bool_):      # static metadata: fine
        pass
    for isb, ref in zip(extra_bool, refs[1:]):
        v = ref[...]
        o = (v > 0.5) if isb else v       # static selector: fine
        refs[-1][...] = jnp.where(o > 0, o, v)

def make(x, n):
    kernel = functools.partial(_kernel, n=n, extra_bool=(True,))
    return pl.pallas_call(kernel, out_shape=x)
"""


def test_rpr005_kernel_violations_fire(tmp_path):
    rep = run_on(tmp_path, {"s.py": PALLAS_BAD}, rules=["RPR005"])
    msgs = " | ".join(f.message for f in by_rule(rep, "RPR005"))
    assert "closes over array-valued `table`" in msgs
    assert "np.exp" in msgs
    assert "`if` on a traced value" in msgs


def test_rpr005_idiomatic_kernel_via_partial_passes(tmp_path):
    rep = run_on(tmp_path, {"s.py": PALLAS_OK}, rules=["RPR005"])
    assert by_rule(rep, "RPR005") == []


# ---------------------------------------------------------------- RPR006
FAKE_ENGINE = """\
class SimEngine:
    def __init__(self, platform, *, config=None, controller=None,
                 balancer=None, faults=None, slo=None, supervisor=None,
                 tech=None, observe=None):
        pass
"""

FAKE_BATCH = """\
class BatchSimEngine:
    def __init__(self, platform, *, config=None, controller=None,
                 balancer=None, backend="numpy", faults=None, slo=None,
                 observe=None, devices=None, tech=None):
        pass

    def _run_pallas(self):
        raise NotImplementedError("no fault schedules here")
        raise NotImplementedError("no SLO semantics here")
        raise NotImplementedError("no load balancer here")
        raise NotImplementedError("no observer plane here")
"""

FAKE_DSE = """\
def closed_loop_score(result, trace, *, model, backend="numpy",
                      flows=None, balancer_factory=None,
                      fault_schedule=None, slo=None, observe=None,
                      devices=None, tech=None):
    pass


def grid_sweep(model, *, backend="numpy", devices=None,
               tech_node=None, tech_variant=None):
    pass
"""


def _fake_surfaces():
    return {"sim/engine.py": FAKE_ENGINE, "sim/batch.py": FAKE_BATCH,
            "core/dse.py": FAKE_DSE}


def test_rpr006_parity_matrix_green_on_full_surfaces(tmp_path):
    rep = run_on(tmp_path, _fake_surfaces(), rules=["RPR006"])
    assert by_rule(rep, "RPR006") == []


def test_rpr006_desynced_surface_fires(tmp_path):
    srcs = _fake_surfaces()
    srcs["sim/engine.py"] = FAKE_ENGINE.replace("observe=None", "obs=None")
    rep = run_on(tmp_path, srcs, rules=["RPR006"])
    hits = by_rule(rep, "RPR006")
    assert any("must accept knob `observe`" in f.message for f in hits)


def test_rpr006_undeclared_knob_growth_fires(tmp_path):
    srcs = _fake_surfaces()
    srcs["sim/engine.py"] = FAKE_ENGINE.replace(
        "observe=None):", "observe=None, backend=None):")
    rep = run_on(tmp_path, srcs, rules=["RPR006"])
    assert any("declares absent" in f.message
               for f in by_rule(rep, "RPR006"))


def test_rpr006_missing_refusal_fires(tmp_path):
    srcs = _fake_surfaces()
    srcs["sim/batch.py"] = FAKE_BATCH.replace(
        '        raise NotImplementedError("no observer plane here")\n',
        "")
    rep = run_on(tmp_path, srcs, rules=["RPR006"])
    assert any("observer plane" in f.message
               for f in by_rule(rep, "RPR006"))


# ------------------------------------------------- fingerprints / baseline
def test_fingerprint_stable_across_line_shifts(tmp_path):
    rep1 = run_on(tmp_path, {"a.py": RPR001_POS}, rules=["RPR001"])
    shifted = "# a leading comment\nX = 1\n\n" + RPR001_POS
    rep2 = run_on(tmp_path, {"a.py": shifted}, rules=["RPR001"])
    fp1 = sorted(f.fingerprint for f in rep1.new)
    fp2 = sorted(f.fingerprint for f in rep2.new)
    assert fp1 == fp2 and all(fp1)


def test_baseline_round_trip(tmp_path):
    rep = run_on(tmp_path, {"a.py": RPR001_POS}, rules=["RPR001"])
    assert rep.new and rep.exit_code == 1
    bl = tmp_path / "baseline.json"
    save_baseline(bl, rep.findings)
    accepted = load_baseline(bl)
    assert accepted == {f.fingerprint for f in rep.new}
    rep2 = analyze_paths([tmp_path / "a.py"], root=tmp_path,
                         baseline=accepted, rules=get_rules(["RPR001"]))
    assert rep2.new == [] and rep2.baselined and rep2.exit_code == 0


def test_baseline_version_mismatch_raises(tmp_path):
    bl = tmp_path / "baseline.json"
    bl.write_text(json.dumps({"version": 99, "findings": []}))
    with pytest.raises(ValueError):
        load_baseline(bl)


# ------------------------------------------------------------ CLI / gate
def _cli(*args, cwd=ROOT):
    import os
    env = {**os.environ, "PYTHONPATH": str(ROOT / "src")}
    return subprocess.run([sys.executable, "-m", "repro.analysis", *args],
                          cwd=cwd, env=env, capture_output=True,
                          text=True, timeout=300)


def test_cli_self_check_repo_is_clean_against_committed_baseline():
    """`python -m repro.analysis src/repro` exits 0 for the repo as
    committed — the CI gate invariant."""
    proc = _cli("src/repro")
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_json_format_and_bench_gate():
    proc = _cli("--format", "json", "--bench", "src/repro")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    doc = json.loads(proc.stdout)
    assert doc["counts"]["new"] == 0
    assert isinstance(doc["bench"], list)
    assert doc["modules"] > 50


def test_cli_exits_nonzero_on_new_finding(tmp_path):
    (tmp_path / "bad.py").write_text(RPR001_POS)
    proc = _cli(str(tmp_path / "bad.py"), "--baseline", "none",
                cwd=ROOT)
    assert proc.returncode == 1
    assert "RPR001" in proc.stdout


def test_cli_changed_only_in_fresh_git_repo(tmp_path):
    try:
        subprocess.run(["git", "init", "-q"], cwd=tmp_path, check=True,
                       capture_output=True, timeout=60)
    except (OSError, subprocess.SubprocessError):
        pytest.skip("git unavailable")
    (tmp_path / "bad.py").write_text(RPR001_POS)
    proc = _cli("--changed-only", "--baseline", "none", cwd=tmp_path)
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "bad.py" in proc.stdout


# --------------------------------------------------- scan cache signature
def test_scan_cache_sig_enumerates_every_field():
    """SCAN_SIG_FIELDS is the authoritative slot list: the helper's
    tuple must have exactly these arity/slots, with the raw scalars in
    the positions the names claim."""
    from repro.core.perfmodel import AccelWorkload, SoCPerfModel
    from repro.sim import BatchSimEngine, BatchSimPlatform, SimPlatform
    from repro.sim.batch import SCAN_SIG_FIELDS

    m = SoCPerfModel()
    pos = [(0, 0), (0, 1), (1, 1), (2, 1)]
    wls = [AccelWorkload("dfmul", 8.70, 1.1, replication=8) for _ in pos]
    plat = SimPlatform.build(m, wls, pos)
    eng = BatchSimEngine(BatchSimPlatform.stack([plat]))

    fault_key = ("fk",)
    sig = eng._scan_cache_sig(T=64, ci=4, dt=1e-3, B=1, D=1,
                              arrivals_ndim=2, fault_key=fault_key,
                              plan={"kind": "none"}, slo=None)
    assert len(sig) == len(SCAN_SIG_FIELDS) == 14
    ix = {name: i for i, name in enumerate(SCAN_SIG_FIELDS)}
    assert sig[ix["tag"]] == "scan"
    assert sig[ix["T"]] == 64
    assert sig[ix["ci"]] == 4
    assert sig[ix["dt"]] == 1e-3
    assert sig[ix["B"]] == 1
    assert sig[ix["D"]] == 1
    assert sig[ix["arrivals_ndim"]] == 2
    assert sig[ix["fault_key"]] is fault_key
    assert sig[ix["policy_digest"]] == ("none",)
    assert sig[ix["balancer_digest"]] is None
    assert sig[ix["slo"]] is None
    # config / model slots key the scalars that retrace the scan
    cfg = eng.config
    assert sig[ix["config"]] == (cfg.max_queue, cfg.dynamic_contention,
                                 cfg.noc_power_share)
    mdl = sig[ix["model"]]
    assert mdl[0] == m.own_demand and mdl[-1] == plat.n_tg
    # tech slot: engine + controller tech identities (linear proxy here)
    assert sig[ix["tech"]] == (None, None)
    # distinct dt MUST produce a distinct signature (the PR 8 bug)
    sig2 = eng._scan_cache_sig(T=64, ci=4, dt=2e-3, B=1, D=1,
                               arrivals_ndim=2, fault_key=fault_key,
                               plan={"kind": "none"}, slo=None)
    assert sig != sig2


def test_every_rule_module_declares_id_and_summary():
    ids = [m.RULE_ID for m in RULES]
    assert ids == sorted(ids) and len(set(ids)) == len(ids)
    for m in RULES:
        assert m.RULE_ID.startswith("RPR") and m.SUMMARY
        assert callable(getattr(m, "check", None)) or \
            callable(getattr(m, "check_project", None))
    with pytest.raises(ValueError):
        get_rules(["RPR999"])
