"""Batched multi-design co-simulation: differential + property tests.

The load-bearing guarantees:

* **differential parity** — the batched engine at B=1 matches the
  sequential engine *bit-for-bit* (queues, monitor counters, energy,
  p50/p99, telemetry rows) across constant/Poisson/diurnal/MMPP traces,
  open-loop and with membound/PID DFS controllers in the loop; the
  ``jax.lax.scan`` backend matches the NumPy reference within float32
  tolerance on the same seeds,
* **invariants** — queue non-negativity, work conservation at every tick
  (arrivals == served + backlog), monotone completion curves, and
  ``weighted_percentiles`` ordering, fuzzed over random traces and
  island-rate schedules (hypothesis when available, seeded sweeps
  otherwise — both drive the same checkers),
* **the DSE acceptance** — ``closed_loop_score`` on >= 256 survivors runs
  as ONE batched replay, >= 10x faster than the sequential path with
  identical ranking output, and repeated scoring through an explicit
  trace seed is reproducible.
"""
import time
from functools import partial

import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.core.dfs import (BatchMemoryBoundPolicy, BatchPIDRatePolicy,
                            PIDRatePolicy, policy_memory_bound)
from repro.core.dse import closed_loop_score, grid_sweep
from repro.core.noc import pos_index, stacked_incidence
from repro.core.perfmodel import AccelWorkload, SoCPerfModel
from repro.sim import (BatchControllerHarness, BatchSimEngine,
                       BatchSimPlatform, ControllerHarness, SimConfig,
                       SimEngine, SimPlatform, constant_trace, diurnal_trace,
                       mmpp_trace, poisson_trace, weighted_percentiles)
from repro.sim.traffic import Trace


# --------------------------------------------------------------- fixtures
def make_platform(n_tiles=6, *, req_mb=0.005, noc_rate=1.0, n_tg=2, k=8,
                  island_groups=None):
    m = SoCPerfModel()
    pos = [(r, c) for r in range(4) for c in range(4)
           if (r, c) not in {(1, 0), (0, 0), (0, 3)}][:n_tiles]
    wls = [AccelWorkload("dfmul", 8.70, 1.1, replication=k) for _ in pos]
    return SimPlatform.build(m, wls, pos, noc_rate=noc_rate, n_tg=n_tg,
                             req_mb=req_mb, island_groups=island_groups)


def make_trace(kind, cap, ticks=900, n=6, seed=3):
    if kind == "constant":
        return constant_trace(cap * 0.6, ticks, n, dt=1e-3)
    if kind == "poisson":
        return poisson_trace(float(cap.sum()) * 0.5, ticks, n, dt=1e-3,
                             seed=seed)
    if kind == "diurnal":
        return diurnal_trace(cap * 0.4, ticks, n, dt=1e-3, depth=0.5,
                             seed=seed)
    if kind == "mmpp":
        return mmpp_trace(cap * 0.1, cap * 1.3, ticks, n, dt=1e-3,
                          seed=seed)
    raise ValueError(kind)


def batch_controller(bplat, policy, **kw):
    return BatchControllerHarness(bplat.islands, bplat.rates, policy,
                                  tile_names=bplat.names, **kw)


# ------------------------------------------------- stacked incidence export
def test_stacked_incidence_matches_engine_rows():
    """The dense (B, A, L) export equals the per-design incidence the
    sequential engine builds from the ragged routing tables."""
    plats = [make_platform(4), make_platform(5)]
    for plat in plats:
        m = plat.model
        inc = stacked_incidence(m.noc, plat.pos_idx,
                                pos_index(m.noc, m.mem_pos))
        np.testing.assert_array_equal(inc, SimEngine(plat)._inc)
    # broadcasting: a (B, A) position matrix stacks per-design tables
    b = BatchSimPlatform.stack(plats[:1] * 3)
    inc = stacked_incidence(b.model.noc, b.pos_idx,
                            pos_index(b.model.noc, b.model.mem_pos))
    assert inc.shape == (3, 4, inc.shape[-1])
    np.testing.assert_array_equal(inc[0], inc[2])
    # degenerate shapes: empty batch and scalar pair
    L = inc.shape[-1]
    empty = stacked_incidence(b.model.noc,
                              np.empty((0,), dtype=np.int64), 0)
    assert empty.shape == (0, L)
    self_route = stacked_incidence(b.model.noc, (1, 1), (1, 1))
    assert self_route.shape == (L,) and self_route.sum() == 0


# ------------------------------------------------------ differential: B=1
@pytest.mark.parametrize("kind", ["constant", "poisson", "diurnal", "mmpp"])
def test_batch_b1_matches_sequential_bitforbit_open_loop(kind):
    plat = make_platform()
    bplat = BatchSimPlatform.stack([plat])
    cap = SimEngine(plat).capacity_rps()
    tr = make_trace(kind, cap)
    cfg = SimConfig(telemetry_interval=20, telemetry_capacity=64)
    seq_eng = SimEngine(plat, config=cfg)
    seq = seq_eng.run(tr)
    bat_eng = BatchSimEngine(bplat, config=cfg)
    bat = bat_eng.run(tr)

    assert bat.completed[0] == seq.completed
    assert bat.residual[0] == seq.residual
    assert bat.energy_j[0] == seq.energy_j
    assert bat.p50_latency_s[0] == seq.p50_latency_s
    assert bat.p99_latency_s[0] == seq.p99_latency_s
    assert bat.throughput_rps[0] == seq.throughput_rps
    # full state: queues and monitor counters, elementwise exact
    for f in ("queue", "busy", "pkts_in", "pkts_out", "rtt_acc"):
        np.testing.assert_array_equal(
            getattr(bat_eng.last_state, f)[0],
            getattr(seq_eng.last_state, f), err_msg=f)
    # per-design telemetry rows == sequential telemetry rows
    d0 = bat.telemetry.design(0)
    np.testing.assert_array_equal(d0["queue_depth"],
                                  seq.telemetry.queue_depth.array())
    np.testing.assert_array_equal(d0["busy"], seq.telemetry.busy.array())
    for ch in ("throughput_rps", "power_w", "link_util_max",
               "latency_est_s"):
        np.testing.assert_array_equal(d0["scalars"][ch],
                                      seq.telemetry.series(ch), err_msg=ch)


@pytest.mark.parametrize("kind", ["constant", "diurnal", "mmpp"])
@pytest.mark.parametrize("policy", ["membound", "pid"])
def test_batch_b1_matches_sequential_bitforbit_controlled(kind, policy):
    plat = make_platform()
    bplat = BatchSimPlatform.stack([plat])
    cap = SimEngine(plat).capacity_rps()
    tr = make_trace(kind, cap)
    cfg = SimConfig(control_interval=25)
    if policy == "membound":
        s_pol = partial(policy_memory_bound, threshold=0.55, low_rate=0.5)
        b_pol = BatchMemoryBoundPolicy(threshold=0.55, low_rate=0.5)
    else:
        s_pol = PIDRatePolicy(target=0.7)
        b_pol = BatchPIDRatePolicy(target=0.7)
    s_ctl = ControllerHarness(plat.islands, s_pol, queue_guard_ticks=3.0)
    b_ctl = batch_controller(bplat, b_pol, queue_guard_ticks=3.0)
    seq = SimEngine(plat, config=cfg, controller=s_ctl).run(tr)
    bat = BatchSimEngine(bplat, config=cfg, controller=b_ctl).run(tr)

    assert bat.completed[0] == seq.completed
    assert bat.energy_j[0] == seq.energy_j
    assert bat.p99_latency_s[0] == seq.p99_latency_s
    assert int(bat.swaps[0]) == seq.swaps
    # the committed rate trajectories agree: final live rates identical
    seq_rates = np.asarray([i.rate for i in s_ctl.live().islands])
    np.testing.assert_array_equal(b_ctl.rates[0], seq_rates)
    assert int(b_ctl.versions[0]) == s_ctl.live().version


def test_batch_b1_parity_multi_tile_islands_and_drops():
    """Parity holds for multi-tile islands (island means over >1 tile)
    and with the admission guard dropping requests."""
    groups = {"left": ("dfmul0", "dfmul1"), "right": ("dfmul2", "dfmul3")}
    plat = make_platform(4, island_groups=groups)
    bplat = BatchSimPlatform.stack([plat])
    cap = SimEngine(plat).capacity_rps()
    tr = make_trace("mmpp", cap, n=4)
    cfg = SimConfig(control_interval=20, max_queue=40.0)
    s_ctl = ControllerHarness(plat.islands, PIDRatePolicy(target=0.6),
                              queue_guard_ticks=2.0)
    b_ctl = batch_controller(bplat, BatchPIDRatePolicy(target=0.6),
                             queue_guard_ticks=2.0)
    seq = SimEngine(plat, config=cfg, controller=s_ctl).run(tr)
    bat = BatchSimEngine(bplat, config=cfg, controller=b_ctl).run(tr)
    assert bat.dropped[0] == seq.dropped
    assert seq.dropped > 0          # the guard actually engaged
    assert bat.completed[0] == seq.completed
    assert bat.energy_j[0] == seq.energy_j
    assert int(bat.swaps[0]) == seq.swaps
    assert bat.p99_latency_s[0] == seq.p99_latency_s


def test_batch_rows_are_independent_and_order_invariant():
    """Stacking [d0, d0, d1] yields identical outputs for the duplicate
    rows and the same d1 outputs as stacking [d1] alone — designs cannot
    bleed into each other through the shared arrays."""
    d0 = make_platform(noc_rate=1.0)
    d1 = make_platform(noc_rate=0.5)
    cap = SimEngine(d0).capacity_rps()
    tr = make_trace("diurnal", cap)
    cfg = SimConfig(control_interval=25)

    def run(plats):
        b = BatchSimPlatform.stack(plats)
        ctl = batch_controller(b, BatchMemoryBoundPolicy(threshold=0.55,
                                                         low_rate=0.5),
                               queue_guard_ticks=3.0)
        eng = BatchSimEngine(b, config=cfg, controller=ctl)
        return eng.run(tr), eng

    mixed, eng_m = run([d0, d0, d1])
    solo, eng_s = run([d1])
    # the tick-by-tick simulation of each row is bit-identical whatever
    # else shares the batch (elementwise ops / trailing-axis reductions)
    adm_m, srv_m = eng_m.last_histories
    adm_s, srv_s = eng_s.last_histories
    np.testing.assert_array_equal(srv_m[:, 0], srv_m[:, 1])
    np.testing.assert_array_equal(srv_m[:, 2], srv_s[:, 0])
    np.testing.assert_array_equal(adm_m[:, 2], adm_s[:, 0])
    for f in ("energy_j", "p99_latency_s", "swaps", "residual"):
        v = getattr(mixed, f)
        assert v[0] == v[1], f
        assert v[2] == getattr(solo, f)[0], f
    # summary aggregates reduce (T, B, A) slabs in a different order than
    # (T, 1, A) ones — equal to float64 roundoff, not bit-for-bit
    assert mixed.completed[0] == mixed.completed[1]
    np.testing.assert_allclose(mixed.completed[2], solo.completed[0],
                               rtol=1e-12)


# ------------------------------------------------------- jax scan backend
@pytest.mark.parametrize("controlled", [False, True])
def test_jax_scan_backend_matches_numpy_reference(controlled):
    jax = pytest.importorskip("jax")
    plats = [make_platform(noc_rate=r) for r in (1.0, 0.8, 0.6)]
    bplat = BatchSimPlatform.stack(plats)
    cap = SimEngine(plats[0]).capacity_rps()
    tr = make_trace("diurnal", cap, ticks=700)
    cfg = SimConfig(control_interval=25)

    def ctl():
        if not controlled:
            return None
        return batch_controller(
            bplat, BatchMemoryBoundPolicy(threshold=0.55, low_rate=0.5),
            queue_guard_ticks=3.0)

    eng_n = BatchSimEngine(bplat, config=cfg, controller=ctl())
    rn = eng_n.run(tr)
    eng_j = BatchSimEngine(bplat, config=cfg, controller=ctl(),
                           backend="jax")
    rj = eng_j.run(tr)
    np.testing.assert_allclose(rj.completed, rn.completed, rtol=1e-3)
    # monitor counters survive the scan (incl. the accumulated RTT)
    np.testing.assert_allclose(eng_j.last_state.rtt_acc,
                               eng_n.last_state.rtt_acc, rtol=1e-3)
    np.testing.assert_allclose(eng_j.last_state.pkts_out,
                               eng_n.last_state.pkts_out, rtol=1e-3)
    np.testing.assert_allclose(rj.energy_j, rn.energy_j, rtol=1e-3)
    np.testing.assert_allclose(rj.residual, rn.residual,
                               rtol=1e-3, atol=1e-2)
    # tick-granular latency reconstruction: allow one tick of float32 slack
    np.testing.assert_allclose(rj.p99_latency_s, rn.p99_latency_s,
                               atol=2 * tr.dt, rtol=0.05)
    if controlled:
        np.testing.assert_array_equal(rj.swaps, rn.swaps)


# ------------------------------------------------------------- invariants
def check_sim_invariants(arrivals: np.ndarray, rates, *, n_tg=2,
                         max_queue=float("inf"), control=False) -> None:
    """Run a random trace / island-rate schedule through the batched
    engine and assert the fluid-queue invariants at every tick."""
    arrivals = np.asarray(arrivals, dtype=np.float64)
    assert arrivals.ndim == 2
    T, A = arrivals.shape
    plat = make_platform(A, n_tg=n_tg)
    rates = dict(rates or {})
    plats = [plat]
    if rates:
        plats = [SimPlatform.build(
            plat.model,
            [AccelWorkload("dfmul", 8.70, 1.1, replication=8)
             for _ in range(A)],
            [divmod(int(i), plat.model.noc.cols) for i in plat.pos_idx],
            names=plat.names, rates=rates, n_tg=n_tg, req_mb=0.005)]
    b = BatchSimPlatform.stack(plats)
    ctl = (batch_controller(b, BatchPIDRatePolicy(target=0.6),
                            queue_guard_ticks=2.0) if control else None)
    eng = BatchSimEngine(b, config=SimConfig(control_interval=10,
                                             max_queue=max_queue),
                         controller=ctl)
    r = eng.run(Trace(arrivals, 1e-3))
    admitted, served = eng.last_histories

    # queue non-negativity + work conservation at every tick:
    # cumulative admitted - cumulative served == backlog >= 0
    ca = np.cumsum(admitted, axis=0)
    cs = np.cumsum(served, axis=0)
    backlog = ca - cs
    assert np.all(backlog >= -1e-9)
    assert np.all(served >= -1e-12)
    # the final backlog is the reported residual
    np.testing.assert_allclose(backlog[-1].sum(axis=-1), r.residual,
                               rtol=1e-9, atol=1e-9)
    # global conservation incl. drops
    np.testing.assert_allclose(r.completed + r.residual + r.dropped,
                               r.offered, rtol=1e-9)
    # monotone completion curves
    assert np.all(np.diff(cs, axis=0) >= -1e-12)
    # served never exceeds what was ever admitted
    assert np.all(cs <= ca + 1e-9)


def check_percentile_ordering(values, weights) -> None:
    v = np.asarray(values, dtype=np.float64)
    w = np.asarray(weights, dtype=np.float64)
    if not np.any(w > 0):
        return
    qs = weighted_percentiles(v, w, (10.0, 50.0, 90.0, 99.0))
    assert np.all(np.diff(qs) >= 0)          # quantiles are ordered
    kept = v[w > 0]
    assert qs[0] >= kept.min() - 1e-12
    assert qs[-1] <= kept.max() + 1e-12


SEED_CASES = [
    (0, float("inf"), False), (1, float("inf"), True),
    (2, 25.0, False), (3, 25.0, True), (4, 10.0, True),
]


@pytest.mark.parametrize("seed,max_queue,control", SEED_CASES)
def test_sim_invariants_seeded(seed, max_queue, control):
    """Deterministic sweep through the same checker the hypothesis fuzz
    drives — guarantees coverage when hypothesis is not installed."""
    rng = np.random.default_rng(seed)
    T = int(rng.integers(20, 80))
    A = int(rng.integers(1, 7))
    arrivals = rng.gamma(1.5, 40.0, size=(T, A)) * rng.random((T, 1))
    rates = {}
    if seed % 2:
        levels = np.linspace(0.2, 1.0, 9)
        rates = {f"dfmul{i}": float(rng.choice(levels)) for i in range(A)}
        rates["noc_mem"] = float(rng.choice(levels))
    check_sim_invariants(arrivals, rates, max_queue=max_queue,
                         control=control)


@pytest.mark.parametrize("seed", range(6))
def test_percentile_ordering_seeded(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 60))
    check_percentile_ordering(rng.normal(5.0, 3.0, n),
                              rng.integers(0, 9, n))


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 6),
       st.integers(min_value=5, max_value=60),
       st.integers(min_value=1, max_value=6),
       st.floats(min_value=0.0, max_value=200.0),
       st.booleans(), st.booleans())
def test_sim_invariants_fuzzed(seed, ticks, n_tiles, scale, bounded,
                               control):
    """Property fuzz: arbitrary non-negative traces and random ladder
    rate schedules never violate queue/conservation invariants."""
    rng = np.random.default_rng(seed)
    arrivals = rng.gamma(1.2, max(scale, 1e-3),
                         size=(ticks, n_tiles)) * rng.random((ticks, 1))
    levels = np.linspace(0.2, 1.0, 9)
    rates = {f"dfmul{i}": float(rng.choice(levels))
             for i in range(n_tiles)}
    rates["noc_mem"] = float(rng.choice(levels))
    check_sim_invariants(arrivals, rates,
                         max_queue=(30.0 if bounded else float("inf")),
                         control=control)


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 6),
       st.integers(min_value=1, max_value=80))
def test_percentile_ordering_fuzzed(seed, n):
    rng = np.random.default_rng(seed)
    check_percentile_ordering(rng.normal(0.0, 10.0, n),
                              rng.integers(0, 7, n))


# ------------------------------------------------ DSE bridge: acceptance
def _acceptance_sweep():
    m = SoCPerfModel()
    wls = [AccelWorkload("dfadd", 9.22, 0.9),
           AccelWorkload("dfmul", 8.70, 1.1)]
    res = grid_sweep(m, wls, ks=(1, 2, 4, 8), acc_rates=(0.2, 0.6, 1.0),
                     noc_rates=(0.5, 1.0), n_tg=2)
    return m, res


def test_closed_loop_score_batched_beats_sequential_10x_identical_ranking():
    """ISSUE acceptance: >= 256 survivors scored as ONE batched replay,
    >= 10x faster than the sequential path, identical ranking output,
    identical per-point scores (the engines share one numeric core)."""
    m, res = _acceptance_sweep()
    idx = res.topk_indices(256)
    assert idx.shape[0] >= 256
    tr = diurnal_trace(2000.0, 250, 2, dt=1e-3, depth=0.4, seed=5)

    t0 = time.perf_counter()
    seq = closed_loop_score(res, tr, model=m, indices=idx, p99_sla_s=0.05,
                            req_mb=0.002, batch=False)
    t_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    bat = closed_loop_score(res, tr, model=m, indices=idx, p99_sla_s=0.05,
                            req_mb=0.002)
    t_bat = time.perf_counter() - t0

    np.testing.assert_array_equal(bat.ranked_indices(),
                                  seq.ranked_indices())
    np.testing.assert_array_equal(bat.p99_latency_s, seq.p99_latency_s)
    np.testing.assert_array_equal(bat.energy_per_request_j,
                                  seq.energy_per_request_j)
    assert len(bat.results) == 1            # one BatchSimResult
    assert bat.results[0].n_designs == 256
    assert t_seq / t_bat >= 10.0, (t_seq, t_bat)


def test_closed_loop_score_batched_with_controller():
    """Batched scoring with a vectorized DFS controller in the loop
    matches the sequential per-point controllers exactly."""
    m, res = _acceptance_sweep()
    idx = res.topk_indices(12)
    tr = diurnal_trace(2000.0, 400, 2, dt=1e-3, depth=0.4, seed=5)
    seq = closed_loop_score(
        res, tr, model=m, indices=idx, req_mb=0.002, batch=False,
        sim_config=SimConfig(control_interval=25),
        controller_factory=lambda p: ControllerHarness(
            p.islands,
            partial(policy_memory_bound, threshold=0.55, low_rate=0.5),
            queue_guard_ticks=3.0))
    bat = closed_loop_score(
        res, tr, model=m, indices=idx, req_mb=0.002,
        sim_config=SimConfig(control_interval=25),
        batch_controller_factory=lambda bp: BatchControllerHarness(
            bp.islands, bp.rates,
            BatchMemoryBoundPolicy(threshold=0.55, low_rate=0.5),
            tile_names=bp.names, queue_guard_ticks=3.0))
    np.testing.assert_array_equal(bat.p99_latency_s, seq.p99_latency_s)
    np.testing.assert_array_equal(bat.energy_per_request_j,
                                  seq.energy_per_request_j)
    np.testing.assert_array_equal(bat.ranked_indices(),
                                  seq.ranked_indices())
    assert int(bat.results[0].swaps.sum()) == sum(
        r.swaps for r in seq.results)
    assert bat.results[0].swaps.sum() > 0


def test_closed_loop_score_seeded_trace_is_reproducible():
    """Regression (ISSUE satellite): scoring the same survivors twice
    through a trace factory + explicit seed is bit-reproducible, and the
    seed actually matters."""
    m, res = _acceptance_sweep()
    idx = res.topk_indices(8)
    factory = lambda seed: diurnal_trace(2000.0, 300, 2, dt=1e-3,
                                         depth=0.4, seed=seed)
    a = closed_loop_score(res, factory, model=m, indices=idx,
                          req_mb=0.002, trace_seed=11)
    b = closed_loop_score(res, factory, model=m, indices=idx,
                          req_mb=0.002, trace_seed=11)
    np.testing.assert_array_equal(a.p99_latency_s, b.p99_latency_s)
    np.testing.assert_array_equal(a.energy_per_request_j,
                                  b.energy_per_request_j)
    np.testing.assert_array_equal(a.order, b.order)
    c = closed_loop_score(res, factory, model=m, indices=idx,
                          req_mb=0.002, trace_seed=12)
    assert not np.array_equal(a.p99_latency_s, c.p99_latency_s) or \
        not np.array_equal(a.energy_per_request_j, c.energy_per_request_j)


# ----------------------------------------------------------------- soaks
@pytest.mark.slow
def test_soak_b512_batched_replay():
    """Opt-in soak (--runslow): 512 stacked designs through a diurnal
    trace with PID DFS in the loop — conservation holds per design and
    the batch sustains >= 50 design-replays/s on CPU."""
    m, res = _acceptance_sweep()
    idx = np.resize(res.topk_indices(256), 512)
    tr = diurnal_trace(2000.0, 1000, 2, dt=1e-3, depth=0.5, seed=7)
    bplat = BatchSimPlatform.from_design_points(m, res, idx, req_mb=0.002)
    ctl = batch_controller(bplat, BatchPIDRatePolicy(target=0.7),
                           queue_guard_ticks=3.0)
    r = BatchSimEngine(bplat, config=SimConfig(control_interval=25),
                       controller=ctl).run(tr)
    assert r.n_designs == 512
    np.testing.assert_allclose(r.completed + r.residual + r.dropped,
                               r.offered, rtol=1e-9)
    assert r.designs_per_s_wall >= 50.0
