"""Trainer / ServeEngine / checkpoint / fault-tolerance integration tests."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models.layers import AttnOptions
from repro.optim import adamw
from repro.runtime.fault import FaultSupervisor
from repro.runtime.serve import Request, ServeEngine
from repro.runtime.train import TrainConfig, Trainer

SHAPE = ShapeConfig("tiny", 64, 4, "train")
LM_KW = dict(opts=AttnOptions(backend="naive"), remat=True)


def _trainer(tmp, arch="granite-moe-1b-a400m", **kw):
    cfg = get_config(arch).reduced()
    tc = TrainConfig(log_every=1, ckpt_every=kw.pop("ckpt_every", 0),
                     ckpt_dir=str(tmp), monitor_every=2,
                     opt=adamw.AdamWConfig(lr=1e-3, warmup_steps=2,
                                           total_steps=100))
    return Trainer(cfg, SHAPE, tc=tc, lm_kwargs=LM_KW)


def test_loss_decreases(tmp_path):
    tr = _trainer(tmp_path, arch="h2o-danube-1.8b")
    hist = tr.run(30)
    first = np.mean([m["loss"] for _, m in hist[:5]])
    last = np.mean([m["loss"] for _, m in hist[-5:]])
    assert last < first - 0.05, (first, last)


def test_checkpoint_resume_bitwise(tmp_path):
    tr = _trainer(tmp_path, ckpt_every=5)
    hist = tr.run(10)                          # saves at 5 and 10
    tr.store().wait()
    loss10 = [m["loss"] for s, m in hist if s == 10][0]

    tr2 = _trainer(tmp_path)
    tr2.restore(step=5)
    assert tr2.step == 5
    h2 = tr2.run(5)
    loss10b = [m["loss"] for s, m in h2 if s == 10][0]
    assert loss10 == loss10b                   # bitwise deterministic resume


def test_monitor_counters_progress(tmp_path):
    tr = _trainer(tmp_path)
    tr.run(4)
    s = tr.monitor.read(tr.counters, tr.step)
    assert s.counters["mem"]["pkts_in"] > 0
    assert s.counters["io"]["exec_time"] > 0


def test_dfs_commit_between_steps(tmp_path):
    tr = _trainer(tmp_path)
    tr.actuator.reconfigure({"noc_mem": 0.5})
    tr.run(1)                                  # commit happens between steps
    assert tr.islands.rate_of("noc") == 0.5
    assert tr.actuator.swaps == 1


def test_fault_supervisor_recovers_from_nan(tmp_path):
    tr = _trainer(tmp_path, ckpt_every=2)
    sup = FaultSupervisor(tr)
    tr.run(4)
    tr.store().wait()
    # inject a poisoned parameter tree (simulated chip corruption)
    tr.params = jax.tree_util.tree_map(
        lambda a: a * jnp.nan if a.dtype == jnp.bfloat16 else a, tr.params)
    kind = sup.check_metrics(5, {"loss": float("nan")})
    assert kind == "nan"
    resumed = sup.recover()
    assert resumed == 4                        # back to the last checkpoint
    h = tr.run(1)
    assert np.isfinite(h[-1][1]["loss"])


def test_straggler_mitigation_derates(tmp_path):
    from repro.core.dfs import TileTelemetry
    tr = _trainer(tmp_path)
    sup = FaultSupervisor(tr)
    tel = {t.name: TileTelemetry(1.0, 0, 0, 0, 0.5) for t in tr.plan.tiles}
    tel["attn"] = TileTelemetry(10.0, 0, 0, 0, 0.5)
    rates = sup.check_stragglers(tel, tr.islands, tr.actuator)
    assert rates is not None and rates["attn"] == 1.0
    assert tr.actuator.swaps == 1              # hitless commit happened
    assert any(e.kind == "straggler" for e in sup.events)


def test_serve_engine_continuous_batching():
    cfg = get_config("granite-8b").reduced()
    eng = ServeEngine(cfg, batch_slots=2, window=64,
                      lm_kwargs=dict(opts=AttnOptions(backend="naive"),
                                     remat=False))
    rng = np.random.default_rng(0)
    for i in range(5):
        eng.submit(Request(rid=i, max_new=6,
                           prompt=rng.integers(0, cfg.vocab_size,
                                               size=10).astype(np.int32)))
    eng.run(40)
    s = eng.stats()
    assert s["completed"] == 5.0
    # continuous batching: later requests waited for slots -> larger RTT
    rtts = [r.rtt for r in eng.done]
    assert max(rtts) > min(rtts)
    assert float(eng.counters["mem"]["rtt"]) > 0   # C3 RTT counter charged


def test_serve_decode_matches_offline_forward():
    """Engine greedy decode == offline argmax decode, per request."""
    cfg = get_config("musicgen-large").reduced()
    lm_kwargs = dict(opts=AttnOptions(backend="naive"), remat=False)
    eng = ServeEngine(cfg, batch_slots=2, window=32, lm_kwargs=lm_kwargs)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
    eng.submit(Request(rid=0, prompt=prompt, max_new=5))
    eng.run(10)
    got = eng.done[0].out

    # offline: prefill + greedy loop with the same params
    lm = eng.lm
    toks = jnp.asarray(prompt[None, :])
    lg, cache = lm.prefill(eng.params, tokens=toks, cache_len=32)
    exp = [int(jnp.argmax(lg, -1)[0])]
    for _ in range(4):
        nt = jnp.asarray([[exp[-1]]], jnp.int32)
        lg, cache = lm.decode_step(eng.params, cache, tokens=nt)
        exp.append(int(jnp.argmax(lg, -1)[0]))
    assert got == exp
