"""Graceful degradation when ``hypothesis`` is not installed.

Test modules import ``given``/``settings``/``st`` from here instead of from
``hypothesis`` directly.  When hypothesis is available this module re-exports
the real objects unchanged; when it is missing, ``@given(...)`` replaces the
test with a zero-argument stub that calls ``pytest.skip`` — so the rest of
the module's tests still collect and run instead of the whole file erroring
at import time.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            def _skipped():
                pytest.skip("hypothesis not installed")
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: every attribute is a
        callable returning None (the @given stub never invokes the test, so
        strategy objects are never used)."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
