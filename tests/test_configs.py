"""Config registry + parameter accounting."""
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, list_configs, shapes_for
from repro.configs.base import LM_SHAPES


def test_all_assigned_archs_registered():
    known = list_configs()
    for a in ASSIGNED_ARCHS:
        assert a in known
    assert len(ASSIGNED_ARCHS) == 10


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_config_sanity(arch):
    cfg = get_config(arch)
    assert cfg.n_layers > 0 and cfg.d_model > 0 and cfg.vocab_size > 0
    if cfg.family in ("dense", "moe"):
        assert cfg.n_heads * cfg.head_dim in (cfg.d_model,
                                              cfg.n_heads * cfg.head_dim)
        assert cfg.n_heads % max(cfg.n_kv_heads, 1) == 0
    if cfg.family == "moe":
        assert cfg.n_experts > 0 and cfg.top_k > 0
    if cfg.family in ("ssm", "hybrid"):
        assert cfg.ssm_state > 0
        assert cfg.d_inner % cfg.ssm_headdim == 0


# Published parameter counts (paper/hf tolerance: our count is within 20%).
EXPECTED_PARAMS = {
    "h2o-danube-1.8b": 1.8e9,
    "phi3-medium-14b": 14e9,
    "granite-8b": 8e9,
    "gemma-2b": 2.5e9,            # 2.5B incl. the 256k-vocab embeddings
    "deepseek-v2-lite-16b": 16e9,
    "granite-moe-1b-a400m": 1.3e9,
    "mamba2-370m": 0.37e9,
    "zamba2-7b": 7.4e9,
    "chameleon-34b": 34e9,
    "musicgen-large": 3.3e9,
}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_counts_match_published(arch):
    cfg = get_config(arch)
    got = cfg.n_params()
    exp = EXPECTED_PARAMS[arch]
    assert 0.75 * exp <= got <= 1.35 * exp, (arch, got, exp)


def test_moe_active_params_smaller():
    cfg = get_config("deepseek-v2-lite-16b")
    assert cfg.n_active_params() < 0.35 * cfg.n_params()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_shape_cells(arch):
    cfg = get_config(arch)
    cells = shapes_for(cfg)
    assert "train_4k" in cells and "decode_32k" in cells
    if cfg.supports_long_context:
        assert "long_500k" in cells
    else:
        assert "long_500k" not in cells


def test_total_cells():
    # 10x4 grid; long_500k applies to danube (SWA), mamba2, zamba2 only
    total = sum(len(shapes_for(get_config(a))) for a in ASSIGNED_ARCHS)
    assert total == 33


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_is_small_and_same_family(arch):
    cfg = get_config(arch)
    r = cfg.reduced()
    assert r.family == cfg.family
    assert r.d_model <= 128 and r.n_layers <= 4
    assert r.vocab_size <= 1024
