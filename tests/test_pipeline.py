"""Pipeline parallelism: pipelined == sequential, grads flow (subprocess
with a 4-stage device mesh)."""
import os
import subprocess
import sys
import textwrap

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_pipeline_matches_sequential_and_grads():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.pipeline import pipeline_apply, stack_layer_groups

        L, d, B, S_stages, M = 8, 16, 8, 4, 4
        key = jax.random.PRNGKey(0)
        W = jax.random.normal(key, (L, d, d)) * 0.3
        x = jax.random.normal(jax.random.fold_in(key, 1), (B, d))

        def seq(W, x):
            for i in range(L):
                x = jnp.tanh(x @ W[i])
            return x

        def stage_fn(w_group, x):           # (L/S, d, d)
            def body(x, w):
                return jnp.tanh(x @ w), None
            return jax.lax.scan(body, x, w_group)[0]

        mesh = jax.make_mesh((S_stages,), ("stage",))
        Wst = stack_layer_groups(W, S_stages)
        y_pipe = pipeline_apply(stage_fn, Wst, x, mesh=mesh,
                                axis="stage", n_micro=M)
        y_seq = seq(W, x)
        err = float(jnp.max(jnp.abs(y_pipe - y_seq)))
        assert err < 1e-5, err
        print("PIPE FWD OK", err)

        # gradient through the pipeline (autodiff through ppermute)
        def loss_pipe(Wst):
            return jnp.sum(pipeline_apply(stage_fn, Wst, x, mesh=mesh,
                                          axis="stage", n_micro=M) ** 2)
        def loss_seq(W):
            return jnp.sum(seq(W, x) ** 2)
        g_pipe = jax.grad(loss_pipe)(Wst).reshape(W.shape)
        g_seq = jax.grad(loss_seq)(W)
        gerr = float(jnp.max(jnp.abs(g_pipe - g_seq)))
        assert gerr < 1e-4, gerr
        print("PIPE GRAD OK", gerr)
    """)
    assert "PIPE FWD OK" in out and "PIPE GRAD OK" in out


def test_pipeline_bubble_accounting():
    """GPipe bubble fraction = (S-1)/(M+S-1): more microbatches -> smaller."""
    S = 4
    for M, expect in ((1, 3 / 4), (4, 3 / 7), (12, 3 / 15)):
        bubble = (S - 1) / (M + S - 1)
        assert abs(bubble - expect) < 1e-9
