"""Raw-speed PR: multi-device sharding + the Pallas fused-tick backend.

Four guarantee families:

* **Pallas differential parity** — the fused tick kernel
  (``repro.kernels.tick_sim``, interpret mode on CPU) matches the NumPy
  float64 reference engine within float32 tolerance and the
  ``jax.lax.scan`` backend bit-tightly, open-loop and with every
  controller the shared control lowering supports (membound / PID /
  custom ``jax_step`` policies) — swap counts exactly.  Fresh policy and
  platform instances per backend run: stateful policies (PID integral,
  EWMA) otherwise leak state across backends and fake a divergence.
* **Shard-count invariance** — 1 vs N virtual devices
  (``--xla_force_host_platform_device_count``, subprocess arms like
  ``test_distributed.py``) produce *identical* sweep Pareto fronts and
  bitwise-identical co-sim scores: ``shard_map`` only partitions
  per-design/per-point math.
* **jit-cache keying** — the batched engine's scan cache is keyed on an
  explicit signature (trace length, cadence, dt, fault class,
  policy/balancer digests, model scalars), so a changed dt or a retuned
  policy misses the cache instead of replaying a stale executable, and
  the cache is LRU-bounded at ``_SCAN_CACHE_MAX``.
* **bounded module caches** — the route/table caches in ``core.noc``,
  the jitted kernel cache in ``core.perfmodel``, the sharded evaluator
  cache in ``core.dse`` and the mesh cache in ``repro.shard`` all stay
  within their declared bounds under a 1k-distinct-config sweep.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro import shard
from repro.core.dfs import (BatchEWMAUtilizationPolicy,
                            BatchMemoryBoundPolicy, BatchPIDRatePolicy)
from repro.sim import (BatchSimEngine, BatchSimPlatform, FaultSchedule,
                       LoadBalancer, SimConfig, SimEngine, SLOConfig,
                       constant_trace)
from test_sim_batch import batch_controller, make_platform, make_trace

ROOT = os.path.join(os.path.dirname(__file__), "..")

POLICIES = {
    "open": None,
    "membound": lambda: BatchMemoryBoundPolicy(threshold=0.5, low_rate=0.3),
    "pid": lambda: BatchPIDRatePolicy(target=0.7),
    "ewma": lambda: BatchEWMAUtilizationPolicy(alpha=0.4, target=0.65),
}

RTOL, ATOL = 2e-3, 1e-2         # f32 kernel vs f64 reference


def _fresh_engine(backend, policy_key, *, B=3, ci=25):
    """A fresh platform + controller + engine per backend run — rates and
    policy state mutate in place during a run."""
    plats = [make_platform(4, k=k) for k in (2, 4, 8)][:B]
    bplat = BatchSimPlatform.stack(plats)
    pf = POLICIES[policy_key]
    ctl = (None if pf is None
           else batch_controller(bplat, pf(), queue_guard_ticks=3.0))
    return BatchSimEngine(bplat, config=SimConfig(control_interval=ci),
                          controller=ctl, backend=backend)


def _trace(kind="diurnal", ticks=300, seed=3):
    cap = SimEngine(make_platform(4, k=2)).capacity_rps()
    return make_trace(kind, cap, ticks=ticks, n=4, seed=seed)


def _check_close(a, b, label, rtol=RTOL, atol=ATOL):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol,
                               atol=atol, err_msg=label)


def _assert_parity(r, ref, *, rtol=RTOL, atol=ATOL):
    for f in ("completed", "energy_j", "p99_latency_s", "throughput_rps"):
        _check_close(getattr(r, f), getattr(ref, f), f, rtol, atol)
    _check_close(r.residual, ref.residual, "residual", rtol, max(atol, 1e-2))
    np.testing.assert_array_equal(np.asarray(r.swaps), np.asarray(ref.swaps))


# ------------------------------------------------ pallas: differential
@pytest.mark.parametrize("policy", list(POLICIES))
def test_pallas_matches_numpy_f64_reference(policy):
    tr = _trace()
    ref = _fresh_engine("numpy", policy).run(tr)
    r = _fresh_engine("pallas", policy).run(tr)
    _assert_parity(r, ref)


@pytest.mark.parametrize("policy", list(POLICIES))
def test_pallas_matches_jax_scan_backend(policy):
    """Same float32 math, two executions (scan vs fused kernel): much
    tighter than the f64 comparison."""
    tr = _trace()
    ref = _fresh_engine("jax", policy).run(tr)
    r = _fresh_engine("pallas", policy).run(tr)
    _assert_parity(r, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("kind", ["constant", "poisson", "diurnal", "mmpp"])
def test_pallas_b1_matches_sequential_engine(kind):
    """B=1 through the fused kernel vs the per-design sequential engine
    (the same reference chain the scan backend is validated against)."""
    plat = make_platform(4, k=4)
    cap = SimEngine(plat).capacity_rps()
    tr = make_trace(kind, cap, ticks=400, n=4)
    seq = SimEngine(plat).run(tr)
    bat = BatchSimEngine(BatchSimPlatform.stack([plat]),
                         backend="pallas").run(tr)
    _check_close(bat.completed[0], seq.completed, "completed")
    _check_close(bat.energy_j[0], seq.energy_j, "energy_j")
    _check_close(bat.residual[0], seq.residual, "residual")
    _check_close(bat.p99_latency_s[0], seq.p99_latency_s, "p99",
                 atol=2 * tr.dt)


def run_pallas_case(seed, ticks, kind, policy):
    """One fuzz case: a random short trace through the fused kernel must
    agree with the f64 reference and conserve work."""
    tr = _trace(kind, ticks=ticks, seed=seed % 97)
    ref = _fresh_engine("numpy", policy, B=2).run(tr)
    r = _fresh_engine("pallas", policy, B=2).run(tr)
    _assert_parity(r, ref)
    comp = np.asarray(r.completed)
    resid = np.asarray(r.residual)
    assert np.all(comp >= 0.0) and np.all(resid >= -1e-6)
    admitted = comp + resid
    _check_close(admitted, np.asarray(ref.completed) + np.asarray(ref.residual),
                 "conservation")


def test_pallas_differential_seeded():
    for seed, ticks, kind, policy in [(0, 60, "diurnal", "open"),
                                      (7, 90, "constant", "pid"),
                                      (23, 120, "diurnal", "pid"),
                                      (41, 45, "constant", "open")]:
        run_pallas_case(seed, ticks, kind, policy)


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(st.integers(min_value=0, max_value=10 ** 6),
           st.integers(min_value=40, max_value=120),
           st.sampled_from(["constant", "diurnal"]),
           st.sampled_from(["open", "pid"]))
    def test_pallas_differential_fuzzed(seed, ticks, kind, policy):
        run_pallas_case(seed, ticks, kind, policy)


def test_pallas_unsupported_features_raise():
    """Faults, SLO, balancer and the observer plane are scan-side
    bookkeeping the kernel does not carry — explicit refusal, not a
    silently wrong answer."""
    plat = make_platform(4)
    tr = _trace(ticks=50)
    mk = lambda **kw: BatchSimEngine(BatchSimPlatform.stack([plat]),  # noqa: E731
                                     backend="pallas", **kw)
    with pytest.raises(NotImplementedError, match="fault"):
        mk(faults=FaultSchedule().kill_tile(plat.names[0], start=10)).run(tr)
    with pytest.raises(NotImplementedError, match="SLO"):
        mk(slo=SLOConfig(deadline_s=0.05)).run(tr)
    with pytest.raises(NotImplementedError, match="balancer"):
        mk(balancer=LoadBalancer([(plat.names[0], plat.names[1])],
                                 plat.names)).run(tr)
    with pytest.raises(NotImplementedError, match="observer"):
        mk(observe="counters").run(tr)


# ------------------------------------------------ jit-cache keying
def test_jit_cache_distinct_dt_no_collision():
    """Two traces with the same tick count but different dt must compile
    (and answer) separately — dt is baked into the traced tick math, so
    a (T, ci)-only cache key replayed the first dt's executable."""
    eng = _fresh_engine("jax", "open", B=1)
    cap = SimEngine(make_platform(4, k=2)).capacity_rps()
    tr_a = constant_trace(cap * 0.6, 200, 4, dt=1e-3)
    tr_b = constant_trace(cap * 0.6, 200, 4, dt=2e-3)
    ra = eng.run(tr_a)
    rb = eng.run(tr_b)
    assert len(eng._jax_cache) == 2, "dt missing from the scan cache key"
    # the dt actually took effect: energy integrates power * dt
    ref_b = _fresh_engine("numpy", "open", B=1).run(tr_b)
    _check_close(rb.energy_j, ref_b.energy_j, "energy@dt2")
    assert not np.allclose(ra.energy_j, rb.energy_j, rtol=1e-3)


def test_jit_cache_policy_retune_misses():
    """Retuning a policy in place (same object, new gains) changes the
    compile-time constants the lowering baked in — the digest must miss."""
    eng = _fresh_engine("jax", "pid")
    tr = _trace(ticks=150)
    eng.run(tr)
    assert len(eng._jax_cache) == 1
    eng.controller.policy.kp *= 10.0
    eng.controller.policy.target = 0.5
    eng.run(tr)
    assert len(eng._jax_cache) == 2, "retuned policy hit a stale executable"

    # custom jax_step policies contribute via jax_cache_key()
    eng2 = _fresh_engine("jax", "ewma")
    eng2.run(tr)
    eng2.controller.policy.alpha = 0.9
    eng2.run(tr)
    assert len(eng2._jax_cache) == 2


def test_jit_cache_bounded_eviction():
    """> _SCAN_CACHE_MAX distinct signatures stay bounded (LRU)."""
    from repro.sim import batch as batch_mod
    eng = _fresh_engine("jax", "open", B=1)
    cap = SimEngine(make_platform(4, k=2)).capacity_rps()
    n_sigs = batch_mod._SCAN_CACHE_MAX + 3
    for i in range(n_sigs):
        eng.run(constant_trace(cap * 0.6, 40 + i, 4, dt=1e-3))
    assert len(eng._jax_cache) == batch_mod._SCAN_CACHE_MAX
    # and the newest signature is resident (a hit, not a rebuild)
    before = dict(eng._jax_cache)
    eng.run(constant_trace(cap * 0.6, 40 + n_sigs - 1, 4, dt=1e-3))
    assert dict(eng._jax_cache).keys() == before.keys()


# ------------------------------------------------ bounded module caches
def test_module_caches_bounded_over_1k_configs():
    from repro.core import dse as dse_mod
    from repro.core import noc as noc_mod
    from repro.core import perfmodel as pm

    # noc: a 1k-distinct-config stream through the table/route caches
    for i in range(1000):
        cfg = noc_mod.NocConfig(rows=2 + i % 5, cols=2 + (i // 5) % 7,
                                link_bw=1.0 + 0.001 * i)
        noc_mod.routing_tables(cfg)
        noc_mod.hops(cfg, (0, 0), (cfg.rows - 1, cfg.cols - 1))
    for fn in (noc_mod.routing_tables, noc_mod._xy_route_cached,
               noc_mod.hops):
        info = fn.cache_info()
        assert info.maxsize is not None and info.currsize <= info.maxsize, \
            (fn.__name__, info)
    assert noc_mod.routing_tables.cache_info().currsize \
        <= noc_mod._TABLE_CACHE_SIZE

    # perfmodel: 1k distinct model-constant tuples -> bounded jit cache
    for i in range(1000):
        pm._jitted_throughput_kernel(0.1 + i * 1e-4, 0.07, 1.0, 0.03, 2.0)
    info = pm._jitted_throughput_kernel.cache_info()
    assert info.currsize <= 32, info

    # dse: the sharded flat-point evaluator cache is scalar-keyed + bounded
    assert dse_mod._flat_point_evaluator.cache_info().maxsize == 8
    for i in range(20):
        dse_mod._flat_point_evaluator(1, 2, i, ((1.0, 0.1), (2.0, 0.01)),
                                      0.1, 0.07, 1.0, 0.03, 2.0, 8.0, 0.5)
    info = dse_mod._flat_point_evaluator.cache_info()
    assert info.currsize <= 8, info

    # shard: mesh cache is (count, axis-name)-keyed and explicitly bounded
    for i in range(100):
        shard.device_mesh(1, f"axis{i}")
    assert shard.mesh_cache_size() <= shard._MESH_CACHE_MAX


# ------------------------------------------------ shard helpers (local)
def test_shard_resolve_and_pad_helpers():
    assert shard.resolve_devices(None) == 1
    assert shard.resolve_devices("auto") == shard.device_count()
    assert shard.resolve_devices(64) <= shard.device_count()
    with pytest.raises(AssertionError):
        shard.resolve_devices(0)
    assert shard.shard_len(5, 4) == 8 and shard.shard_len(8, 4) == 8
    a = np.arange(12, dtype=np.float64).reshape(3, 4)
    p = shard.pad_axis(a, 4, axis=0)
    assert p.shape == (4, 4)
    np.testing.assert_array_equal(p[:3], a)
    np.testing.assert_array_equal(p[3], a[0])       # row-0 filler
    assert shard.pad_axis(a, 3, axis=0) is a        # already even


# ------------------------------------------------ shard-count invariance
def _run(code: str, devices: int = 4) -> str:
    """Subprocess arm with N virtual CPU devices (device count is fixed
    at the first jax import, so in-process tests can't flip it)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), os.path.dirname(__file__)])
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_shard_sweep_invariance_1_vs_4_devices():
    """grid_sweep(devices=4) == grid_sweep(devices=1): identical Pareto
    front, top-k survivors and tracked objective values (elementwise
    math, only partitioned)."""
    _run("""
        import numpy as np
        import jax
        assert len(jax.devices()) == 4, jax.devices()
        from repro.core.perfmodel import AccelWorkload, SoCPerfModel
        from repro.core.dse import grid_sweep

        model = SoCPerfModel()
        wls = (AccelWorkload("gsm", 4.61, 12.0),
               AccelWorkload("dfmul", 8.70, 1.1))
        kw = dict(ks=(1, 2, 4), acc_rates=(0.2, 0.6, 1.0),
                  noc_rates=(0.1, 0.5, 1.0), tg_rates=(0.5, 1.0), n_tg=2,
                  island_rates="independent",
                  chunk_points=700)     # not a device multiple: padding
        r1 = grid_sweep(model, wls, devices=1, **kw)
        r4 = grid_sweep(model, wls, devices=4, **kw)
        assert np.array_equal(r1.pareto, r4.pareto)
        assert np.array_equal(r1.cand_indices, r4.cand_indices)
        for o in r1.topk:
            assert np.array_equal(r1.topk[o], r4.topk[o]), o
        for o, v in r1.cand_values.items():
            assert np.array_equal(v, r4.cand_values[o]), o

        # dense (unchunked) path shards too
        d1 = grid_sweep(model, wls, devices=1, **{**kw, "chunk_points": None})
        d4 = grid_sweep(model, wls, devices=4, **{**kw, "chunk_points": None})
        for f in ("throughput", "energy_per_unit", "mem_traffic"):
            assert np.array_equal(getattr(d1, f), getattr(d4, f)), f
        assert np.array_equal(d1.pareto_indices(), d4.pareto_indices())
        print("sweep invariance ok", len(r1.pareto))
    """)


def test_shard_cosim_invariance_1_vs_4_devices():
    """BatchSimEngine(jax, devices=4) == devices=None bitwise across
    open-loop and controlled runs (B=5: padding to 8 is exercised)."""
    _run("""
        import numpy as np
        import jax
        assert len(jax.devices()) == 4, jax.devices()
        from repro.core.dfs import BatchMemoryBoundPolicy, BatchPIDRatePolicy
        from repro.sim import BatchSimEngine, BatchSimPlatform, SimConfig, SimEngine
        from test_sim_batch import batch_controller, make_platform, make_trace

        POL = {"open": None,
               "membound": lambda: BatchMemoryBoundPolicy(threshold=0.5),
               "pid": lambda: BatchPIDRatePolicy(target=0.7)}
        cap = SimEngine(make_platform(4, k=2)).capacity_rps()
        tr = make_trace("diurnal", cap, ticks=300, n=4)

        def run(devices, key):
            plats = [make_platform(4, k=k) for k in (2, 2, 4, 8, 8)]
            bplat = BatchSimPlatform.stack(plats)
            pf = POL[key]
            ctl = (None if pf is None else
                   batch_controller(bplat, pf(), queue_guard_ticks=3.0))
            eng = BatchSimEngine(bplat, config=SimConfig(control_interval=25),
                                 controller=ctl, backend="jax",
                                 devices=devices)
            return eng.run(tr)

        for key in POL:
            a, b = run(None, key), run(4, key)
            for f in ("completed", "energy_j", "residual", "swaps",
                      "p99_latency_s"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
                    err_msg=f"{key}:{f}")
        print("cosim invariance ok")
    """)


def test_shard_closed_loop_score_forwarding():
    """closed_loop_score(devices=) reaches the batched engine: sharded
    scoring reproduces single-device scoring bitwise."""
    _run("""
        import numpy as np
        import jax
        assert len(jax.devices()) == 4, jax.devices()
        from repro.core.dse import closed_loop_score, grid_sweep
        from repro.core.perfmodel import AccelWorkload, SoCPerfModel
        from repro.sim import diurnal_trace

        m = SoCPerfModel()
        wls = [AccelWorkload("dfadd", 9.22, 0.9),
               AccelWorkload("dfmul", 8.70, 1.1)]
        res = grid_sweep(m, wls, ks=(1, 2, 4), acc_rates=(0.2, 0.6, 1.0),
                         noc_rates=(0.5, 1.0), n_tg=2)
        idx = res.topk_indices(6)
        tr = diurnal_trace(2000.0, 250, 2, dt=1e-3, seed=5)
        kw = dict(model=m, indices=idx, req_mb=0.002, backend="jax")
        s1 = closed_loop_score(res, tr, devices=None, **kw)
        s4 = closed_loop_score(res, tr, devices=4, **kw)
        np.testing.assert_array_equal(s1.p99_latency_s, s4.p99_latency_s)
        np.testing.assert_array_equal(s1.energy_per_request_j,
                                      s4.energy_per_request_j)
        np.testing.assert_array_equal(s1.ranked_indices(),
                                      s4.ranked_indices())
        print("closed-loop forwarding ok")
    """)
