"""AdamW, gradient compression, data pipeline, checkpoint store."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint.store import CheckpointStore
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim import adamw
from repro.optim.compress import dequantize_int8, quantize_int8


# ------------------------------------------------------------------- AdamW
def test_adamw_converges_on_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                            total_steps=200, schedule="constant")
    params = {"w": jnp.array([3.0, -2.0])}
    st_ = adamw.init(params)
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum((p["w"] - 1.0) ** 2))(params)
        params, st_, _ = adamw.update(cfg, g, st_, params)
    np.testing.assert_allclose(params["w"], jnp.ones(2), atol=1e-2)


def test_grad_clip_bounds_norm():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(adamw.global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 100.0


def test_lr_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    assert float(adamw.lr_at(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(adamw.lr_at(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(adamw.lr_at(cfg, jnp.asarray(100))) == pytest.approx(0.1)


def test_opt_state_is_f32_regardless_of_param_dtype():
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    st_ = adamw.init(params)
    assert st_.mu["w"].dtype == jnp.float32


# ------------------------------------------------------ gradient compression
@settings(max_examples=25, deadline=None)
@given(scale=st.floats(1e-4, 1e3), n=st.integers(8, 512))
def test_int8_quantization_error_bound(scale, n):
    rng = np.random.default_rng(42)
    g = jnp.asarray(rng.standard_normal(n) * scale, jnp.float32)
    q, s = quantize_int8(g)
    back = dequantize_int8(q, s)
    # error bounded by half a quantization step
    assert float(jnp.max(jnp.abs(back - g))) <= float(s) * 0.5 + 1e-6
    # relative L2 error ~ 1/127 scale
    rel = float(jnp.linalg.norm(back - g) / (jnp.linalg.norm(g) + 1e-9))
    assert rel < 0.02


def test_int8_wire_bytes_4x_smaller():
    g = jnp.zeros((1024,), jnp.float32)
    q, s = quantize_int8(g)
    assert q.nbytes * 4 == g.nbytes


# ------------------------------------------------------------ data pipeline
def test_data_deterministic_and_resumable():
    p = SyntheticLM(DataConfig(seed=3, vocab_size=100, seq_len=17,
                               global_batch=4))
    a = p.batch_at(12)
    b = p.batch_at(12)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = p.batch_at(13)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_shards_disjoint_and_partition():
    p = SyntheticLM(DataConfig(seed=3, vocab_size=1000, seq_len=9,
                               global_batch=8))
    s0 = p.batch_at(5, shard=0, n_shards=2)
    s1 = p.batch_at(5, shard=1, n_shards=2)
    assert s0["tokens"].shape[0] == 4
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_data_labels_are_shifted_tokens():
    p = SyntheticLM(DataConfig(seed=0, vocab_size=50, seq_len=10,
                               global_batch=2))
    b = p.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ---------------------------------------------------------------- checkpoint
def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray(7, jnp.int32),
                  "d": jnp.ones((4,), jnp.bfloat16)}}


def test_checkpoint_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path))
    t = _tree()
    store.save(3, t)
    out = store.restore(t)
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_latest(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save_async(1, _tree())
    store.save_async(2, _tree())
    store.wait()
    assert store.latest_step() == 2


def test_checkpoint_gc_keeps_newest(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        store.save(s, _tree())
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_000003", "step_000004"]


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(9, _tree())
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_checkpoint_restore_casts_to_target_structure(tmp_path):
    """Elastic restore: target shardings re-lay-out leaves (single-device
    here, but the device_put path is the same code that re-shards)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    store = CheckpointStore(str(tmp_path))
    t = _tree()
    store.save(1, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree_util.tree_map(lambda a: NamedSharding(mesh, P()), t)
    out = store.restore(t, shardings=sh)
    assert out["a"].sharding == NamedSharding(mesh, P())
