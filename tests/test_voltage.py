"""Physical DVFS: the tech-node voltage model end to end.

The fidelity contract of ``repro.core.voltage`` and the energy sites it
feeds (paper Sec. DFS + Lumos scaling tables):

* **tables & bounds** — the ITRS/conservative scaling tables carry the
  lumos numbers; every node's legal DVFS range is ``[Vth/Vdd, 1.3]``
  with L strictly below U; the voltage maps are exact inverses,
* **tech=None parity** — with no tech model every energy site
  reproduces the legacy linear-proxy numbers *bit for bit*: the engines
  run the identical code path and ``grid_sweep`` grows no axis,
* **one constants block** — the static sweep and all three co-sim
  backends (numpy / jax scan / Pallas kernel) price a saturated design
  at exactly the ``chip_power`` closed form, with and without a tech
  model: no energy site can drift from ``core.perfmodel`` silently,
* **DVFS clamping** — DFS commits outside the node's legal ratio range
  are pushed to the nearest *legal* ladder level on every backend,
  surface as ``dfs_clamp`` trace events / ``last_clamped`` masks, and
  the scalar and batched controllers agree bit for bit,
* **monotonicity** — lower V,f on an underutilized island strictly
  lowers energy (served work held constant); per-node power ordering
  follows the scaling tables,
* **degenerate designs** — zero-completion runs report NaN energy per
  request and rank last in ``closed_loop_score``,
* **the scenario gate** — on the paper's 3-accel 4x4 SoC a per-island
  DVFS sweep under a tech node finds strictly better energy/request at
  matched p99 than the linear front re-scored under the V^2 f model.
"""
import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.core.dfs import PIDRatePolicy, BatchPIDRatePolicy
from repro.core.dse import _rank_scores, closed_loop_score, grid_sweep
from repro.core.islands import TILE_LADDER
from repro.core.perfmodel import (NOC_POWER_SHARE, P_DYN_W, P_STATIC_W,
                                  V_BASE, V_SLOPE, AccelWorkload,
                                  SoCPerfModel, chip_power,
                                  chip_power_coeffs)
from repro.core.voltage import (AREA_SCALE, DVFS_U_BOUND, POWER_SCALE,
                                TECH_NODES, TECH_VARIANTS, VDD_SCALE, VTH,
                                TechModel, dvfs_bounds, tech_axis_coeffs)
from repro.sim import (BatchControllerHarness, BatchSimEngine,
                       BatchSimPlatform, ControllerHarness, SimConfig,
                       SimEngine, SimPlatform, constant_trace, diurnal_trace)

ALL_TECHS = [(n, v) for v in TECH_VARIANTS for n in TECH_NODES]


# --------------------------------------------------------------- fixtures
def make_platform(n=4, *, f=1.0, k=8, noc_rate=1.0):
    m = SoCPerfModel()
    pos = [(1, 1), (3, 3), (0, 2), (2, 0), (1, 3), (3, 1)][:n]
    wls = [AccelWorkload("dfmul", 8.70, 1.1, replication=k) for _ in pos]
    rates = {f"dfmul{i}": f for i in range(n)}
    return SimPlatform.build(m, wls, pos, noc_rate=noc_rate, n_tg=0,
                             req_mb=0.005, rates=rates)


# ------------------------------------------------------- tables and bounds
def test_scaling_tables_cover_every_node_and_variant():
    for v in TECH_VARIANTS:
        assert set(VDD_SCALE[v]) == set(TECH_NODES)
        assert set(POWER_SCALE[v]) == set(TECH_NODES)
    assert set(VTH) == set(AREA_SCALE) == set(TECH_NODES)
    # supply voltage and power scale shrink monotonically with the node
    for v in TECH_VARIANTS:
        vdd = [VDD_SCALE[v][n] for n in TECH_NODES]
        pwr = [POWER_SCALE[v][n] for n in TECH_NODES]
        assert all(a >= b for a, b in zip(vdd, vdd[1:]))
        assert all(a > b for a, b in zip(pwr, pwr[1:]))
    area = [AREA_SCALE[n] for n in TECH_NODES]
    assert all(a == pytest.approx(2 * b) for a, b in zip(area, area[1:]))


def test_dvfs_bounds_are_vth_over_vdd():
    for n, v in ALL_TECHS:
        lo, hi = dvfs_bounds(n, v)
        assert lo == pytest.approx(VTH[n] / VDD_SCALE[v][n])
        assert hi == DVFS_U_BOUND
        assert 0.0 < lo < 1.0 < hi
    # the two anchors every clamp test below leans on
    assert dvfs_bounds(45, "itrs")[0] == pytest.approx(0.3201)
    assert dvfs_bounds(16, "cons")[0] == pytest.approx(0.2409 / 0.86)


def test_techmodel_coerce_and_identity():
    tm = TechModel(16, "cons")
    assert TechModel.coerce(None) is None
    assert TechModel.coerce(tm) is tm
    assert TechModel.coerce(16) == TechModel(16, "itrs")
    assert TechModel.coerce((16, "cons")) == tm
    assert TechModel.coerce([16, "cons"]) == tm
    assert tm.key == (16, "cons")
    assert hash(TechModel(16, "cons")) == hash(tm)
    # equality is the (node, variant) identity, not derived scalars
    assert TechModel(16, "itrs") != tm
    with pytest.raises(ValueError, match="unknown tech node"):
        TechModel(14)
    with pytest.raises(ValueError, match="unknown tech variant"):
        TechModel(16, "optimistic")
    with pytest.raises(TypeError, match="tech spec"):
        TechModel.coerce("16nm")


def test_voltage_maps_are_exact_inverses():
    f = np.linspace(0.1, 1.3, 37)
    for n, v in ALL_TECHS:
        tm = TechModel(n, v)
        np.testing.assert_allclose(tm.freq_ratio(tm.volt_ratio(f)), f,
                                   rtol=1e-12)
        # linear-over-threshold anchors: V(0)=Vth, V(1)=Vdd
        assert tm.volt_of_freq(0.0) == pytest.approx(tm.vth)
        assert tm.volt_of_freq(1.0) == pytest.approx(tm.vdd)
        # clamp + legality agree on the same [L, U]
        c = tm.clamp_ratio(f)
        assert tm.legal(c).all()
        assert (tm.legal(f) == (f == c)).all()
        # NaN "no request" passes through the clamp untouched
        assert np.isnan(tm.clamp_ratio(np.array([np.nan]))).all()


def test_ladder_voltage_coupling():
    """The per-island voltage ladder rides the frequency ladder: one
    voltage per level, legality mask matching the tech bounds."""
    tm = TechModel(45, "itrs")
    lv = np.asarray(TILE_LADDER.levels(), dtype=np.float64)
    volts = TILE_LADDER.voltages(tm)
    np.testing.assert_allclose(volts, tm.volt_of_freq(lv))
    legal = TILE_LADDER.legal_levels(tm)
    np.testing.assert_array_equal(legal, (lv >= tm.l_bound)
                                  & (lv <= tm.u_bound))
    # 0.3 sits under the 45nm threshold ratio (0.3201): illegal there,
    # legal at 16/cons where L = 0.280
    assert 0.3 in lv.tolist()
    assert not legal[lv.tolist().index(0.3)]
    assert TILE_LADDER.legal_levels(TechModel(16, "cons"))[
        lv.tolist().index(0.3)]
    plat = make_platform(2)
    vl = plat.islands.voltage_ladders(tm)
    assert set(vl) == {"dfmul0", "dfmul1", "noc_mem"}
    np.testing.assert_allclose(vl["dfmul0"], volts)


def test_tech_axis_coeffs_align_with_models():
    c = tech_axis_coeffs([(45, "itrs"), (16, "cons"), 32])
    for i, tm in enumerate([TechModel(45), TechModel(16, "cons"),
                            TechModel(32)]):
        assert (c["tech_ps"][i], c["tech_v0"][i], c["tech_v1"][i]) \
            == tm.power_coeffs
        assert tm.v0 + tm.v1 == pytest.approx(1.0)  # V(1) = Vdd


# --------------------------------------------------------- tech=None parity
def test_chip_power_tech_none_is_bitwise_legacy():
    f = np.linspace(0.0, 1.3, 53)
    legacy = P_STATIC_W + P_DYN_W * f * (V_BASE + V_SLOPE * f) ** 2 * 0.8
    np.testing.assert_array_equal(chip_power(f, 0.8), legacy)
    np.testing.assert_array_equal(chip_power(f, 0.8, tech=None), legacy)
    # the coefficient form with the proxy coefficients is the same math
    np.testing.assert_allclose(
        chip_power_coeffs(f, 0.8, V_BASE, V_SLOPE, 1.0), legacy, rtol=1e-15)
    # with a tech model: the documented p_scale * (static + dyn V^2 f)
    tm = TechModel(16, "cons")
    got = chip_power(f, 0.8, tech=tm)
    v = tm.v0 + tm.v1 * f
    np.testing.assert_array_equal(
        got, tm.power_scl * (P_STATIC_W + P_DYN_W * f * v * v * 0.8))


def test_engines_tech_none_bit_for_bit():
    """An engine constructed with ``tech=None`` is the engine constructed
    without the knob — same results to the last bit, sequential and
    batched, open-loop and controlled."""
    plat = make_platform()
    cap = SimEngine(plat).capacity_rps()
    tr = diurnal_trace(cap * 0.5, 300, 4, dt=1e-3, depth=0.5, seed=3)

    def run_seq(**kw):
        p = make_platform()
        ctl = ControllerHarness(p.islands, PIDRatePolicy(target=0.7),
                                queue_guard_ticks=3.0)
        return SimEngine(p, config=SimConfig(control_interval=25),
                         controller=ctl, **kw).run(tr)

    a, b = run_seq(), run_seq(tech=None)
    for f in ("completed", "energy_j", "p50_latency_s", "p99_latency_s",
              "energy_per_request_j", "mean_power_w", "swaps"):
        assert getattr(a, f) == getattr(b, f), f

    def run_bat(**kw):
        bplat = BatchSimPlatform.stack([make_platform()])
        ctl = BatchControllerHarness(bplat.islands, bplat.rates,
                                     BatchPIDRatePolicy(target=0.7),
                                     tile_names=bplat.names,
                                     queue_guard_ticks=3.0)
        return BatchSimEngine(bplat, config=SimConfig(control_interval=25),
                              controller=ctl, **kw).run(tr)

    a, b = run_bat(), run_bat(tech=None)
    for f in ("completed", "energy_j", "p99_latency_s",
              "energy_per_request_j", "swaps"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f), f)


def test_grid_sweep_without_tech_grows_no_axis():
    m = SoCPerfModel()
    wls = [AccelWorkload("dfmul", 8.70, 1.1)]
    kw = dict(ks=(1, 2), acc_rates=(0.4, 1.0), noc_rates=(1.0,), n_tg=0,
              positions=((1, 1),))
    res = grid_sweep(m, wls, **kw)
    assert all(name != "tech" for name, _ in res.axes)
    dp = res.design_point(int(res.topk_indices(1)[0]))
    assert dp.tech is None
    # the swept energies ARE the legacy closed form (throughput-scaled)
    both = grid_sweep(m, wls, **kw, tech_node=45)
    assert both.axes[-1] == ("tech", ((45, "itrs"),))
    assert both.shape == res.shape + (1,)
    np.testing.assert_array_equal(both.throughput.ravel(),
                                  res.throughput.ravel())


# ----------------------------------------- one constants block: drift test
@pytest.mark.parametrize("tech", [None, (45, "itrs"), (16, "cons")])
def test_saturated_power_matches_chip_power_closed_form(tech):
    """Cross-layer drift guard: a saturated static design's mean power
    equals the ``chip_power`` closed form on every backend — the sweep,
    the sequential engine and both batched backends all read the same
    ``core.perfmodel`` constants block.  A constant edited in one site
    but not the others fails here."""
    A = 4
    plat = make_platform(A)
    cap = SimEngine(plat).capacity_rps()
    tr = constant_trace(cap * 50.0, 300, A, dt=1e-3)   # busy pinned at 1
    tm = TechModel.coerce(tech)
    expect = (A * chip_power(1.0, 1.0, tech=tm)
              + NOC_POWER_SHARE * chip_power(1.0, 1.0, tech=tm))

    r = SimEngine(plat, tech=tech).run(tr)
    assert r.mean_power_w == pytest.approx(expect, rel=1e-9)
    for backend, rel in (("numpy", 1e-9), ("jax", 1e-4)):
        b = BatchSimEngine(BatchSimPlatform.stack([make_platform(A)]),
                           backend=backend, tech=tech).run(tr)
        assert b.mean_power_w[0] == pytest.approx(expect, rel=rel), backend

    # the static sweep prices the same design identically: implied
    # power = energy_per_unit * throughput at the all-nominal point
    m = plat.model
    wls = [AccelWorkload("dfmul", 8.70, 1.1, replication=8)
           for _ in range(A)]
    kw = dict(ks=(8,), acc_rates=(1.0,), noc_rates=(1.0,), n_tg=0,
              positions=[(1, 1), (3, 3), (0, 2), (2, 0)])
    if tech is None:
        res = grid_sweep(m, wls, **kw)
    else:
        res = grid_sweep(m, wls, **kw, tech_node=tech[0],
                         tech_variant=tech[1])
    implied = float(res.energy_per_unit.ravel()[0]
                    * res.throughput.ravel()[0])
    # the sweep normalizes tile power per accelerator (mean, not sum)
    sweep_expect = (chip_power(1.0, 1.0, tech=tm)
                    + NOC_POWER_SHARE * chip_power(1.0, 1.0, tech=tm))
    assert implied == pytest.approx(sweep_expect, rel=1e-9)


def test_pallas_saturated_power_matches_closed_form():
    pytest.importorskip("jax")
    A = 4
    plat = make_platform(A)
    cap = SimEngine(plat).capacity_rps()
    tr = constant_trace(cap * 50.0, 300, A, dt=1e-3)
    for tech in (None, (16, "cons")):
        tm = TechModel.coerce(tech)
        expect = (A * chip_power(1.0, 1.0, tech=tm)
                  + NOC_POWER_SHARE * chip_power(1.0, 1.0, tech=tm))
        b = BatchSimEngine(BatchSimPlatform.stack([make_platform(A)]),
                           backend="pallas", tech=tech).run(tr)
        assert b.mean_power_w[0] == pytest.approx(expect, rel=1e-3), tech


# ------------------------------------------------------------ DVFS clamping
def test_scalar_controller_clamps_to_legal_ladder_levels():
    """PID derating at 45nm: raw requests fall below L=0.3201; every
    commit lands on a *legal* ladder level (0.4, not the illegal 0.3
    the nearest-level quantizer would pick), the clamp is traced, and
    the ControlAction carries the pushed islands."""
    plat = make_platform()
    cap = SimEngine(plat).capacity_rps()
    ctl = ControllerHarness(plat.islands, PIDRatePolicy(target=0.7),
                            queue_guard_ticks=3.0)
    eng = SimEngine(plat, config=SimConfig(control_interval=25),
                    controller=ctl, observe="full", tech=(45, "itrs"))
    assert ctl.tech is eng.tech            # engine injects its model
    eng.run(constant_trace(cap * 0.05, 1200, 4, dt=1e-3))
    tm = TechModel(45, "itrs")
    for isl in ctl.live().islands:
        if isl.name == "noc_mem":
            continue
        lv = np.asarray(isl.ladder.levels(), dtype=np.float64)
        legal = lv[tm.legal(lv)]
        assert isl.rate == pytest.approx(0.4)      # floor of the legal set
        assert np.any(np.abs(legal - isl.rate) < 1e-12)
    ev = eng.observer.trace.events("dfs_clamp")
    assert ev, "derating below L must emit dfs_clamp trace events"
    for e in ev:
        assert set(e.data["islands"]) <= set(e.data["requested"])
        for n in e.data["islands"]:
            assert not tm.legal(e.data["requested"][n])
    acts = [a for a in ctl.actions if a.clamped]
    assert acts and all(set(a.clamped) <= set(a.requested) for a in acts)


def test_without_tech_the_ladder_floor_is_reachable():
    """Control: the identical derating run with no tech model walks the
    rates down to the raw ladder floor 0.3 — proving the 0.4 above is
    the clamp at work, not the PID's natural resting point."""
    plat = make_platform()
    cap = SimEngine(plat).capacity_rps()
    ctl = ControllerHarness(plat.islands, PIDRatePolicy(target=0.7),
                            queue_guard_ticks=3.0)
    eng = SimEngine(plat, config=SimConfig(control_interval=25),
                    controller=ctl)
    eng.run(constant_trace(cap * 0.05, 1200, 4, dt=1e-3))
    rates = {i.name: i.rate for i in ctl.live().islands
             if i.name != "noc_mem"}
    floor = min(TILE_LADDER.levels())
    tm = TechModel(45, "itrs")
    assert floor < tm.l_bound                  # the floor IS illegal there
    assert all(r == pytest.approx(floor) for r in rates.values()), rates


@pytest.mark.parametrize("tech,floor", [((45, "itrs"), 0.4),
                                        ((16, "cons"), 0.3)])
def test_batched_backends_clamp_identically(tech, floor):
    """All three batched backends push an aggressive derate to the same
    legal floor — 0.4 at 45nm (0.3 is under threshold), 0.3 at 16/cons
    (L=0.280 admits it) — and the numpy path flags ``last_clamped``."""
    tr = None
    finals = {}
    for backend in ("numpy", "jax", "pallas"):
        if backend != "numpy":
            pytest.importorskip("jax")
        bplat = BatchSimPlatform.stack([make_platform()])
        ctl = BatchControllerHarness(bplat.islands, bplat.rates,
                                     BatchPIDRatePolicy(target=0.7),
                                     tile_names=bplat.names,
                                     queue_guard_ticks=3.0)
        eng = BatchSimEngine(bplat, config=SimConfig(control_interval=25),
                             controller=ctl, backend=backend, tech=tech)
        if tr is None:
            cap = SimEngine(make_platform()).capacity_rps()
            tr = constant_trace(cap * 0.05, 1200, 4, dt=1e-3)
        eng.run(tr)
        rates = np.asarray(ctl.rates)[0]
        tiles = rates[:-1] if rates.shape[0] > 4 else rates
        finals[backend] = np.round(np.asarray(ctl.rates), 6)
        tm = TechModel.coerce(tech)
        live = np.asarray(ctl.rates).ravel()
        assert tm.legal(live).all(), (backend, live)
        if backend == "numpy":
            assert np.asarray(ctl.last_clamped).any() or floor == 0.3
            assert np.min(live) == pytest.approx(floor), (backend, live)
    ref = finals["numpy"]
    for backend, got in finals.items():
        np.testing.assert_array_equal(got, ref, err_msg=backend)


# ------------------------------------------------------------- monotonicity
@pytest.mark.parametrize("tech", [None, (16, "cons")])
def test_lower_vf_on_underutilized_islands_strictly_saves_energy(tech):
    """Served work held constant (the trace fits every rate), stepping
    the islands down the ladder strictly lowers total energy: the
    dynamic term scales as V(f)^2 per request."""
    cap = SimEngine(make_platform()).capacity_rps()
    tr = constant_trace(cap * 0.3, 400, 4, dt=1e-3)
    prev, completed = None, None
    for f in (1.3, 1.0, 0.7, 0.4):
        r = SimEngine(make_platform(f=f), tech=tech).run(tr)
        if completed is None:
            completed = r.completed
        assert r.completed == completed        # same served work
        if prev is not None:
            assert r.energy_j < prev, (tech, f)
        prev = r.energy_j


def test_power_ordering_follows_scaling_tables():
    for variant in TECH_VARIANTS:
        for f, busy in ((1.0, 1.0), (0.6, 0.8)):
            p = [chip_power(f, busy, tech=TechModel(n, variant))
                 for n in TECH_NODES]
            assert all(a > b for a, b in zip(p, p[1:])), (variant, f)


SEEDS = list(range(8))


def _check_power_properties(f, busy, node_i):
    node = TECH_NODES[node_i]
    for variant in TECH_VARIANTS:
        tm = TechModel(node, variant)
        base = chip_power(f, busy, tech=tm)
        assert base > 0.0
        # strictly increasing in f at fixed busy > 0
        assert chip_power(f + 0.05, busy, tech=tm) > base
        # the legacy proxy bounds nothing below static power
        assert chip_power(f, 0.0, tech=tm) \
            == pytest.approx(tm.power_scl * P_STATIC_W)
        # clamped ratios stay legal, and clamping is idempotent
        c = tm.clamp_ratio(f * 3.0 - 1.0)
        assert tm.legal(c)
        assert tm.clamp_ratio(c) == c


def test_power_properties_seeded():
    rng = np.random.default_rng(7)
    for _ in range(64):
        _check_power_properties(float(rng.uniform(0.05, 1.25)),
                                float(rng.uniform(0.05, 1.0)),
                                int(rng.integers(len(TECH_NODES))))


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(st.floats(0.05, 1.25), st.floats(0.05, 1.0),
           st.integers(0, len(TECH_NODES) - 1))
    def test_power_properties_fuzzed(f, busy, node_i):
        _check_power_properties(f, busy, node_i)


# -------------------------------------------------------- degenerate designs
def test_zero_completion_reports_nan_energy_per_request():
    plat = make_platform(2)
    tr = constant_trace(np.zeros(2), 50, 2, dt=1e-3)
    r = SimEngine(plat).run(tr)
    assert r.completed == 0 and np.isnan(r.energy_per_request_j)
    b = BatchSimEngine(BatchSimPlatform.stack([plat])).run(tr)
    assert np.isnan(b.energy_per_request_j).all()


def test_rank_scores_puts_degenerate_designs_last():
    p99 = np.array([0.01, np.nan, 0.02, 0.005])
    ept = np.array([1.0, np.nan, 0.5, np.nan])
    order = _rank_scores(p99, ept, None)
    assert set(order[-2:]) == {1, 3}            # NaN channels sink
    order = _rank_scores(p99, ept, 0.05)
    assert set(order[-2:]) == {1, 3}
    assert order[0] == 2                        # best energy among live


# ------------------------------------------------------ grid sweep tech axes
def _tech_sweep_inputs():
    m = SoCPerfModel()
    wls = [AccelWorkload("dfmul", 8.70, 1.1),
           AccelWorkload("fft", 5.90, 2.0)]
    kw = dict(ks=(1, 2), acc_rates=(0.4, 0.7, 1.0), noc_rates=(0.5, 1.0),
              n_tg=2, positions=((1, 1), (3, 3)))
    return m, wls, kw


def test_tech_axis_cross_product_and_invariants():
    m, wls, kw = _tech_sweep_inputs()
    res = grid_sweep(m, wls, tech_node=(45, 16), tech_variant="cons", **kw)
    assert res.axes[-1] == ("tech", ((45, "cons"), (16, "cons")))
    base = grid_sweep(m, wls, **kw)
    # throughput / area / mem_traffic are tech-invariant (the grid
    # anchors to the measured Table-I rates); energy moves with the node
    for obj in ("throughput", "area", "mem_traffic"):
        t = getattr(res, obj).reshape(-1, 2)
        np.testing.assert_array_equal(t[:, 0], getattr(base, obj).ravel())
        np.testing.assert_array_equal(t[:, 0], t[:, 1])
    e = res.energy_per_unit.reshape(-1, 2)
    v = res.valid.reshape(-1, 2)
    assert not np.array_equal(e[v[:, 0], 0], e[v[:, 1], 1])
    # 45nm is the normalization anchor: itrs == cons there, both == the
    # legacy energies scaled only through the voltage curve swap
    r45 = grid_sweep(m, wls, tech_node=45, tech_variant=("itrs", "cons"),
                     **kw)
    e45 = r45.energy_per_unit.reshape(-1, 2)
    np.testing.assert_array_equal(e45[:, 0], e45[:, 1])
    # design points carry their tech identity
    dp = res.design_point(int(res.topk_indices(1)[0]))
    assert dp.tech in ((45, "cons"), (16, "cons"))


def test_tech_axis_chunked_matches_dense_bitwise():
    m, wls, kw = _tech_sweep_inputs()
    dense = grid_sweep(m, wls, tech_node=(45, 16), tech_variant="cons",
                       **kw)
    ch = grid_sweep(m, wls, tech_node=(45, 16), tech_variant="cons", **kw,
                    chunk_points=23, topk_track=16)
    assert len(ch) == len(dense) and ch.n_valid == dense.n_valid
    assert np.array_equal(ch.pareto_indices(), dense.pareto_indices())
    pf = ch.pareto_indices()
    for obj in ("throughput", "energy_per_unit"):
        np.testing.assert_array_equal(ch.objective_values(obj, pf),
                                      dense.objective_values(obj, pf))
    i = int(ch.topk_indices(1)[0])
    assert ch.design_point(i) == dense.design_point(i)


def test_scalar_tech_node_defaults_to_itrs():
    m, wls, kw = _tech_sweep_inputs()
    res = grid_sweep(m, wls, tech_node=16, **kw)
    assert res.axes[-1] == ("tech", ((16, "itrs"),))


def test_closed_loop_score_tech_batch_matches_sequential():
    """The DSE bridge under a tech model: the batched replay scores
    every survivor exactly like the sequential reference engine — the
    physical power/clamp path stays inside the shared numeric core."""
    m, wls, kw = _tech_sweep_inputs()
    res = grid_sweep(m, wls, **kw)
    idx = res.topk_indices(4)
    tr = diurnal_trace(40.0, 200, 2, dt=1e-3, depth=0.4, seed=5)
    seq = closed_loop_score(res, tr, model=m, indices=idx, req_mb=0.002,
                            batch=False, tech=(16, "cons"))
    bat = closed_loop_score(res, tr, model=m, indices=idx, req_mb=0.002,
                            tech=(16, "cons"))
    np.testing.assert_array_equal(bat.energy_per_request_j,
                                  seq.energy_per_request_j)
    np.testing.assert_array_equal(bat.p99_latency_s, seq.p99_latency_s)
    np.testing.assert_array_equal(bat.ranked_indices(),
                                  seq.ranked_indices())
    # and the tech replay genuinely differs from the linear replay
    lin = closed_loop_score(res, tr, model=m, indices=idx, req_mb=0.002)
    assert not np.array_equal(lin.energy_per_request_j,
                              bat.energy_per_request_j)


# ------------------------------------------------------------- scenario gate
def test_physical_sweep_beats_linear_front_rescored():
    """ISSUE acceptance: on the paper's 3-accel 4x4 SoC, selecting
    survivors under the physical V^2 f model finds strictly better
    energy/request at matched p99 (all candidates meet the SLA) than
    the linear front re-scored under the same physical model — the
    linear proxy picks the wrong frequencies for the node."""
    m = SoCPerfModel()
    wls = [AccelWorkload("dfmul", 8.70, 1.1),
           AccelWorkload("interp", 20.94, 1.3),
           AccelWorkload("fft", 5.90, 2.0)]
    kw = dict(ks=(2, 4), acc_rates=(0.4, 0.7, 1.0, 1.3),
              noc_rates=(0.5, 1.0), n_tg=2,
              positions=((1, 1), (3, 3), (0, 2)),
              island_rates="independent")
    TECH = (16, "cons")
    lin = grid_sweep(m, wls, **kw)
    phys = grid_sweep(m, wls, **kw, tech_node=TECH[0],
                      tech_variant=TECH[1])
    # trailing tech axis of size 1: flat indices line up across grids
    assert phys.shape == lin.shape + (1,)

    def best_energy_picks(res, n=8):
        pf = res.pareto_indices()
        e = res.objective_values("energy_per_unit", pf)
        return pf[np.argsort(e, kind="stable")][:n]

    top_lin, top_phys = best_energy_picks(lin), best_energy_picks(phys)
    assert set(top_lin.tolist()) != set(top_phys.tolist())
    # static statement of the same gate: the physical model's own pick
    # strictly beats the linear pick *re-evaluated* under V^2 f
    e_phys = phys.energy_per_unit.ravel()
    assert e_phys[top_phys[0]] < e_phys[top_lin[0]]

    # closed loop at matched p99: replay both survivor sets under the
    # physical model; every candidate meets the SLA, and the best
    # energy/request among the physical picks strictly improves
    tr = diurnal_trace(200.0, 400, 3, dt=1e-3, depth=0.3, seed=7)
    sla = 0.05
    s_lin = closed_loop_score(lin, tr, model=m, indices=top_lin,
                              p99_sla_s=sla, req_mb=0.002, tech=TECH)
    s_phy = closed_loop_score(lin, tr, model=m, indices=top_phys,
                              p99_sla_s=sla, req_mb=0.002, tech=TECH)
    assert (s_lin.p99_latency_s <= sla).all()
    assert (s_phy.p99_latency_s <= sla).all()
    assert s_phy.energy_per_request_j.min() \
        < s_lin.energy_per_request_j.min()
    # and the ranking surfaces that winner first
    best = int(s_phy.ranked_indices()[0])
    assert s_phy.energy_per_request_j[
        list(s_phy.indices).index(best)] \
        == s_phy.energy_per_request_j.min()
