"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,KV,G,hdq,hdv,win", [
    (2, 256, 2, 2, 64, 64, 0),
    (1, 128, 1, 4, 32, 32, 0),        # MQA
    (2, 256, 2, 2, 64, 64, 48),       # sliding window
    (1, 128, 4, 1, 192, 128, 0),      # MLA dims (qk 192 / v 128)
    (1, 512, 1, 1, 8, 8, 0),
])
def test_flash_attention_sweep(B, S, KV, G, hdq, hdv, win, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, KV, G, hdq)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hdq)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hdv)).astype(dtype)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    scale = 1 / np.sqrt(hdq)
    out = ops.flash_attention(q, k, v, pos, pos, win, scale)
    exp = ref.flash_attention_ref(q, k, v, pos, pos, scale=scale, window=win)
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=atol)


def test_flash_attention_grad_matches_ref():
    B, S, KV, G, hd = 1, 128, 1, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, KV, G, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    g1 = jax.grad(lambda q: ops.flash_attention(
        q, k, v, pos, pos, 0, 0.25).sum())(q)
    g2 = jax.grad(lambda q: ref.flash_attention_ref(
        q, k, v, pos, pos, scale=0.25).sum())(q)
    np.testing.assert_allclose(g1, g2, atol=5e-5)


# ------------------------------------------------------------------- SSD scan
@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("B,L,nh,hd,st,chunk", [
    (2, 128, 3, 32, 16, 32),
    (1, 64, 1, 8, 8, 16),
    (1, 256, 2, 64, 128, 64),
    (3, 96, 4, 16, 32, 32),
])
def test_ssd_scan_sweep(B, L, nh, hd, st, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    xs = jax.random.normal(ks[0], (B, L, nh, hd), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, nh), dtype))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,), dtype) * 0.2)
    Bm = jax.random.normal(ks[3], (B, L, st), dtype)
    Cm = jax.random.normal(ks[4], (B, L, st), dtype)
    D = jnp.ones((nh,), dtype)
    y, h = ops.ssd_scan(xs, dt, A, Bm, Cm, D, chunk)
    ye, he = ref.ssd_scan_ref(xs, dt, A, Bm, Cm, D, chunk=chunk)
    np.testing.assert_allclose(y, ye, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h, he, atol=1e-4, rtol=1e-4)


def test_ssd_matches_sequential_recurrence():
    """Chunked SSD (any chunk) == token-by-token state recurrence."""
    B, L, nh, hd, st = 1, 48, 2, 8, 4
    ks = jax.random.split(KEY, 5)
    xs = jax.random.normal(ks[0], (B, L, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.2)
    Bm = jax.random.normal(ks[3], (B, L, st))
    Cm = jax.random.normal(ks[4], (B, L, st))
    D = jnp.zeros((nh,))

    # independent oracle: plain recurrence
    h = np.zeros((B, nh, st, hd))
    ys = []
    for t in range(L):
        a = np.exp(np.asarray(dt[:, t]) * np.asarray(A))        # (B,nh)
        upd = np.einsum("bn,bs,bnh->bnsh", np.asarray(dt[:, t]),
                        np.asarray(Bm[:, t]), np.asarray(xs[:, t]))
        h = h * a[:, :, None, None] + upd
        ys.append(np.einsum("bs,bnsh->bnh", np.asarray(Cm[:, t]), h))
    y_seq = np.stack(ys, axis=1)

    for chunk in (8, 16, 48):
        y, hf = ops.ssd_scan(xs, dt, A, Bm, Cm, D, chunk)
        np.testing.assert_allclose(y, y_seq, atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(hf, h, atol=1e-4, rtol=1e-4)


# ------------------------------------------------------------------ fused MLP
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", ["silu", "gelu"])
@pytest.mark.parametrize("N,d,F", [(64, 96, 128), (128, 64, 256)])
def test_fused_mlp_sweep(N, d, F, act, dtype):
    ks = jax.random.split(KEY, 4)
    x = (jax.random.normal(ks[0], (N, d)) * 0.5).astype(dtype)
    scale = jax.random.normal(ks[1], (d,)).astype(dtype) * 0.1
    wg = (jax.random.normal(ks[2], (d, F)) * 0.1).astype(dtype)
    wu = (jax.random.normal(ks[3], (d, F)) * 0.1).astype(dtype)
    out = ops.fused_rmsnorm_mlp(x, scale, wg, wu, act)
    exp = ref.fused_rmsnorm_mlp_ref(x, scale, wg, wu, act=act)
    atol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=atol)


@settings(max_examples=10, deadline=None)
@given(N=st.sampled_from([32, 64]), d=st.sampled_from([32, 64]),
       F=st.sampled_from([64, 128]))
def test_property_fused_mlp(N, d, F):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (N, d)) * 0.5
    scale = jnp.zeros((d,))
    wg = jax.random.normal(ks[2], (d, F)) * 0.1
    wu = jax.random.normal(ks[3], (d, F)) * 0.1
    out = ops.fused_rmsnorm_mlp(x, scale, wg, wu)
    exp = ref.fused_rmsnorm_mlp_ref(x, scale, wg, wu)
    np.testing.assert_allclose(out, exp, atol=3e-5)


# -------------------------------------------------------------- flash decode
@pytest.mark.parametrize("B,W,KV,G,hd,hdv,win,pos", [
    (2, 256, 2, 2, 64, 64, 0, 100),
    (1, 128, 1, 4, 32, 32, 0, 127),    # MQA, full cache
    (2, 256, 2, 2, 64, 64, 48, 200),   # sliding window (ring semantics)
    (1, 256, 4, 1, 192, 128, 0, 60),   # MLA dims
])
def test_flash_decode_sweep(B, W, KV, G, hd, hdv, win, pos):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd), jnp.float32)
    ck = jax.random.normal(ks[1], (B, W, KV, hd), jnp.float32)
    cv = jax.random.normal(ks[2], (B, W, KV, hdv), jnp.float32)
    qpos = jnp.full((B,), pos, jnp.int32)
    idx = jnp.arange(W, dtype=jnp.int32)
    # ring-buffer absolute positions: slots > pos%W hold older entries
    wraps = pos // W
    kpos = jnp.where(idx <= pos % W, wraps * W + idx, (wraps - 1) * W + idx)
    kpos = jnp.where(kpos >= 0, kpos, 10**9)
    kpos = jnp.broadcast_to(kpos[None], (B, W))
    scale = 1 / np.sqrt(hd)
    out = ops.flash_decode(q, ck, cv, qpos, kpos, win, scale, kv_block=64)
    exp = ref.flash_decode_ref(q, ck, cv, qpos, kpos, scale=scale, window=win)
    np.testing.assert_allclose(out, exp, atol=3e-5)
