"""DSE driver benchmark: the sweep Vespa exists to enable.

Sweeps (replication K x island rates x placement) for a CHStone accelerator
on the paper's SoC and reports the Pareto front; then ranks the §Perf pod
strategies for the three hillclimbed cells from dry-run artifacts.
"""
from __future__ import annotations

import glob
import json
import os
import time

from repro.configs.vespa_soc import CHSTONE
from repro.core.dse import pareto_front, sweep_soc
from repro.core.perfmodel import AccelWorkload, SoCPerfModel

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "dryrun")


def soc_dse():
    m = SoCPerfModel()
    base, ai = CHSTONE["gsm"]
    t0 = time.perf_counter_ns()
    pts = sweep_soc(m, AccelWorkload("gsm", base, ai), n_tg=4)
    front = pareto_front(pts)
    us = (time.perf_counter_ns() - t0) / 1e3
    best = max(front, key=lambda p: p.throughput)
    return [("dse_soc_gsm", us,
             f"points={len(pts)} pareto={len(front)} "
             f"best: K={list(best.replication.values())[0]} "
             f"pos={list(best.placement.values())[0]} thr={best.throughput:.2f}")]


def pod_strategy_ranking():
    rows = []
    for arch, shape in [("granite-8b", "train_4k"),
                        ("granite-moe-1b-a400m", "train_4k"),
                        ("deepseek-v2-lite-16b", "decode_32k")]:
        t0 = time.perf_counter_ns()
        cands = []
        for path in glob.glob(os.path.join(
                DRYRUN, f"{arch}__{shape}__pod1*.json")):
            with open(path) as f:
                d = json.load(f)
            chips = d["chips"]
            bound = max(d["jaxpr_flops_total"] / (chips * 197e12),
                        d["hbm_bytes_total"] / (chips * 819e9),
                        d.get("collective_bytes", 0) / 50e9)
            cands.append((bound, d.get("strategy", "tp")))
        cands.sort()
        us = (time.perf_counter_ns() - t0) / 1e3
        if cands:
            base = [b for b, s in cands if s == "tp"]
            gain = (base[0] / cands[0][0]) if base else float("nan")
            rows.append((f"dse_pod_{arch}_{shape}", us,
                         f"best={cands[0][1]} bound={cands[0][0]:.3e}s "
                         f"gain_vs_tp={gain:.2f}x of {len(cands)} points"))
    return rows


def run():
    return soc_dse() + pod_strategy_ranking()
