"""DSE driver benchmark: the sweep Vespa exists to enable.

Three parts:

1. ``soc_dse`` — the original small scalar sweep (kept as the reference
   and regression canary for the per-point path).
2. ``soc_dse_batch`` — the batched engine at scale: a joint two-accelerator
   sweep (K ladders x full island-rate ladders x all 4x4 placements,
   >= 1e6 design points) through ``grid_sweep``, reporting points/second,
   the O(N log N) Pareto front, and a scalar-parity spot check.  Emits
   ``BENCH_dse.json`` (machine-readable) so the perf trajectory is tracked
   across PRs.
3. ``pod_strategy_ranking`` — ranks §Perf pod strategies for the three
   hillclimbed cells from dry-run artifacts.
"""
from __future__ import annotations

import glob
import json
import os
import time

import numpy as np

from repro.configs.vespa_soc import CHSTONE
from repro.core.dse import grid_sweep, pareto_front, sweep_soc
from repro.core.islands import NOC_LADDER, TILE_LADDER
from repro.core.perfmodel import AccelWorkload, SoCPerfModel

ISLANDS_CHUNK = 2_000_000       # chunk size of the streaming islands row

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "dryrun")
BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_dse.json")


def soc_dse():
    m = SoCPerfModel()
    base, ai = CHSTONE["gsm"]
    t0 = time.perf_counter_ns()
    pts = sweep_soc(m, AccelWorkload("gsm", base, ai), n_tg=4)
    front = pareto_front(pts)
    us = (time.perf_counter_ns() - t0) / 1e3
    best = max(front, key=lambda p: p.throughput)
    return [("dse_soc_gsm", us,
             f"points={len(pts)} pareto={len(front)} "
             f"best: K={list(best.replication.values())[0]} "
             f"pos={list(best.placement.values())[0]} thr={best.throughput:.2f}")]


def _parity_spot_check(m, res, samples=200, seed=0):
    """Max relative error of the batched sweep vs the scalar path on a
    random sample of valid points."""
    rng = np.random.default_rng(seed)
    valid = np.nonzero(res.valid)[0]
    idx = rng.choice(valid, size=min(samples, valid.shape[0]), replace=False)
    worst = 0.0
    for i in idx:
        dp = res.design_point(int(i))
        total = 0.0
        for wl in res.workloads:
            w = AccelWorkload(wl.name, wl.base_mbps, wl.ai,
                              replication=dp.replication[wl.name])
            total += m.accel_throughput(w, dp.placement[wl.name], dp.rates,
                                        res.n_tg)
        worst = max(worst, abs(total - dp.throughput) / max(abs(total), 1e-12))
    return worst


def soc_dse_batch():
    m = SoCPerfModel()
    wls = [AccelWorkload("dfsin", *CHSTONE["dfsin"]),
           AccelWorkload("gsm", *CHSTONE["gsm"])]
    axes = dict(ks=(1, 2, 4), acc_rates=TILE_LADDER.levels(),
                noc_rates=NOC_LADDER.levels(),
                tg_rates=TILE_LADDER.levels()[::2], n_tg=4)

    t0 = time.perf_counter()
    res = grid_sweep(m, wls, **axes)
    sweep_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    front = res.pareto_indices()
    pareto_s = time.perf_counter() - t0
    best = res.design_point(int(res.topk_indices(1)[0]))
    parity = _parity_spot_check(m, res)

    rows = [("dse_grid_sweep", sweep_s * 1e6,
             f"points={len(res)} pps={len(res) / sweep_s:,.0f} "
             f"pareto={front.shape[0]}({pareto_s:.2f}s) "
             f"parity_rel_err={parity:.1e} "
             f"best: K={best.replication} pos={best.placement} "
             f"thr={best.throughput:.2f}")]

    # jax.jit path on the same grid (first call includes compilation)
    try:
        t0 = time.perf_counter()
        resj = grid_sweep(m, wls, **axes, backend="jax")
        jax_s = time.perf_counter() - t0
        dev = float(np.max(np.abs(resj.throughput - res.throughput)
                           / np.maximum(np.abs(res.throughput), 1e-12)))
        rows.append(("dse_grid_sweep_jax", jax_s * 1e6,
                     f"points={len(resj)} pps={len(resj) / jax_s:,.0f} "
                     f"max_rel_dev_vs_numpy={dev:.1e}"))
        jax_stats = {"seconds": jax_s, "points_per_sec": len(resj) / jax_s,
                     "max_rel_dev_vs_numpy": dev}
    except Exception as e:                                # pragma: no cover
        jax_stats = {"error": repr(e)}

    from benchmarks.run import append_bench_row
    append_bench_row(BENCH_JSON, {
        "points": len(res),
        "valid_points": res.n_valid,
        "sweep_seconds": sweep_s,
        "points_per_sec": len(res) / sweep_s,
        "pareto_seconds": pareto_s,
        "pareto_size": int(front.shape[0]),
        "parity_max_rel_err": parity,
        "backend": res.backend,
        "jax": jax_stats,
        "best": {"replication": best.replication,
                 "rates": best.rates,
                 "placement": {k: list(v)
                               for k, v in best.placement.items()},
                 "throughput": best.throughput,
                 "area": best.area,
                 "energy_per_unit": best.energy_per_unit},
    })
    return rows


def soc_dse_islands():
    """Independent-islands chunked/streaming sweep: one rate axis per
    accelerator island (paper C2), ~2e7 joint points evaluated in
    fixed-size blocks with a running Pareto/top-k merge.  Reports
    points/second + peak tracked block bytes, amended into the trajectory
    row :func:`soc_dse_batch` just appended to ``BENCH_dse.json``."""
    m = SoCPerfModel()
    wls = [AccelWorkload(n, *CHSTONE[n])
           for n in ("dfadd", "dfmul", "dfsin")]

    t0 = time.perf_counter()
    res = grid_sweep(m, wls, ks=(1, 2, 4), acc_rates=TILE_LADDER.levels(),
                     noc_rates=NOC_LADDER.levels(), tg_rates=(0.5, 1.0),
                     positions=((1, 1), (3, 3), (0, 2)), n_tg=4,
                     island_rates="independent",
                     chunk_points=ISLANDS_CHUNK)
    sweep_s = time.perf_counter() - t0
    front = res.pareto_indices()
    best = res.design_point(int(res.topk_indices(1)[0]))

    # scalar parity at per-island rates (the chunked path must reproduce
    # the scalar reference exactly like the dense path does)
    total = sum(
        m.accel_throughput(
            AccelWorkload(w.name, w.base_mbps, w.ai,
                          replication=best.replication[w.name]),
            best.placement[w.name],
            {"acc": best.rates[w.name],
             "noc_mem": best.rates["noc_mem"], "tg": best.rates["tg"]},
            res.n_tg)
        for w in wls)
    parity = abs(total - best.throughput) / max(abs(total), 1e-12)
    assert parity < 1e-9, parity

    stats = {
        "points": len(res),
        "valid_points": res.n_valid,
        "sweep_seconds": sweep_s,
        "points_per_sec": len(res) / sweep_s,
        "chunk_points": ISLANDS_CHUNK,
        "n_chunks": res.n_chunks,
        "peak_chunk_bytes": res.peak_chunk_bytes,
        "pareto_size": int(front.shape[0]),
        "parity_max_rel_err": parity,
        "best": {"replication": best.replication, "rates": best.rates,
                 "placement": {k: list(v)
                               for k, v in best.placement.items()},
                 "throughput": best.throughput},
    }
    from benchmarks.run import amend_latest_row
    amend_latest_row(BENCH_JSON, {"islands_independent_chunked": stats})

    return [("dse_islands_chunked", sweep_s * 1e6,
             f"points={len(res)} pps={len(res) / sweep_s:,.0f} "
             f"chunks={res.n_chunks} "
             f"peak_chunk_mb={res.peak_chunk_bytes / 1e6:.0f} "
             f"pareto={front.shape[0]} parity_rel_err={parity:.1e} "
             f"best_rates={ {k: round(v, 2) for k, v in best.rates.items()} }")]


def soc_dse_physical():
    """Physical-DVFS sweep throughput: the dense ``soc_dse_batch`` grid
    re-swept with a two-node tech axis (45/16 nm ITRS), timed against a
    back-to-back linear sweep of the same grid.  The V^2 f evaluation is
    three extra broadcast multiply-adds per point, so the gate —
    **enforced** in CI via the trajectory guard — requires the physical
    sweep to sustain >= 0.5x the linear sweep's points/second."""
    m = SoCPerfModel()
    wls = [AccelWorkload("dfsin", *CHSTONE["dfsin"]),
           AccelWorkload("gsm", *CHSTONE["gsm"])]
    axes = dict(ks=(1, 2, 4), acc_rates=TILE_LADDER.levels(),
                noc_rates=NOC_LADDER.levels(),
                tg_rates=TILE_LADDER.levels()[::2], n_tg=4)

    t0 = time.perf_counter()
    lin = grid_sweep(m, wls, **axes)
    lin_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = grid_sweep(m, wls, **axes, tech_node=(45, 16))
    phys_s = time.perf_counter() - t0

    pps_lin = len(lin) / lin_s
    pps_phys = len(res) / phys_s
    ratio = pps_phys / pps_lin
    best = res.design_point(int(res.topk_indices(1, "energy_per_unit")[0]))

    from benchmarks.run import amend_latest_row
    amend_latest_row(BENCH_JSON, {
        "physical_dvfs": {
            "tech_axis": [list(t) for _, ax in res.axes if _ == "tech"
                          for t in ax],
            "points": len(res),
            "sweep_seconds": phys_s,
            "points_per_sec": pps_phys,
            "linear_points_per_sec": pps_lin,
            "best_energy": {"tech": list(best.tech),
                            "rates": best.rates,
                            "energy_per_unit": best.energy_per_unit},
        },
        "gates": {
            "physical_dvfs_throughput": {
                "pass": bool(ratio >= 0.5),
                "ratio_vs_linear": ratio,
                "min_ratio": 0.5,
                "enforced": True,
            },
        },
    })
    return [("dse_grid_sweep_physical", phys_s * 1e6,
             f"points={len(res)} pps={pps_phys:,.0f} "
             f"ratio_vs_linear={ratio:.2f} "
             f"best_tech={best.tech} e={best.energy_per_unit:.3f}")]


def pod_strategy_ranking():
    rows = []
    for arch, shape in [("granite-8b", "train_4k"),
                        ("granite-moe-1b-a400m", "train_4k"),
                        ("deepseek-v2-lite-16b", "decode_32k")]:
        t0 = time.perf_counter_ns()
        cands = []
        for path in glob.glob(os.path.join(
                DRYRUN, f"{arch}__{shape}__pod1*.json")):
            with open(path) as f:
                d = json.load(f)
            chips = d["chips"]
            bound = max(d["jaxpr_flops_total"] / (chips * 197e12),
                        d["hbm_bytes_total"] / (chips * 819e9),
                        d.get("collective_bytes", 0) / 50e9)
            cands.append((bound, d.get("strategy", "tp")))
        cands.sort()
        us = (time.perf_counter_ns() - t0) / 1e3
        if cands:
            base = [b for b, s in cands if s == "tp"]
            gain = (base[0] / cands[0][0]) if base else float("nan")
            rows.append((f"dse_pod_{arch}_{shape}", us,
                         f"best={cands[0][1]} bound={cands[0][0]:.3e}s "
                         f"gain_vs_tp={gain:.2f}x of {len(cands)} points"))
    return rows


def run():
    return (soc_dse() + soc_dse_batch() + soc_dse_islands()
            + soc_dse_physical() + pod_strategy_ranking())
