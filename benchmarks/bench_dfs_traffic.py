"""Fig. 4 analogue: memory traffic while varying island rates at run time.

Replays the paper's experiment: A1+A2 both run memory-bound dfmul; the
frequency schedule sweeps (a) the accelerator island 10->30->50 MHz, (b) the
TG island, (c) the NoC+MEM island, while the monitor's pkts_in counter on
the MEM tile is differentiated into Mpkt/s.

Claims validated (tests/test_paper_claims.py::test_fig4*):
  * accelerator-island frequency has negligible impact (<25%) on memory
    traffic — memory-bound tiles saturate their stream path early;
  * TG x NoC frequency dominates traffic.

Also exercises the DFS energy policy: given the Fig. 4 telemetry, the
policy derates the accelerator islands and reports the modeled energy
saving at unchanged throughput.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.dfs import TileTelemetry, policy_memory_bound
from repro.core.islands import IslandConfig, IslandSpec, NOC_LADDER, TILE_LADDER
from repro.core.perfmodel import SoCPerfModel, chip_power


def fig4_schedule():
    """The paper's Fig. 4a schedule (normalized rates; 50 MHz tile max,
    100 MHz NoC max)."""
    steps = []
    for f_acc in (0.2, 0.6, 1.0):                  # 10 / 30 / 50 MHz
        steps.append({"acc": f_acc, "noc_mem": 1.0, "tg": 1.0})
    for f_tg in (0.2, 0.6, 1.0):
        steps.append({"acc": 1.0, "noc_mem": 1.0, "tg": f_tg})
    for f_noc in (0.1, 0.5, 1.0):                  # 10 / 50 / 100 MHz
        steps.append({"acc": 1.0, "noc_mem": f_noc, "tg": 1.0})
    return steps


def run():
    m = SoCPerfModel()
    pos = [(1, 1), (3, 3)]                          # A1 near, A2 far
    rows = []
    t0 = time.perf_counter_ns()
    traffic = [m.memory_traffic_mpkts(r, 11, pos) for r in fig4_schedule()]
    us = (time.perf_counter_ns() - t0) / 1e3
    acc_sweep, tg_sweep, noc_sweep = traffic[0:3], traffic[3:6], traffic[6:9]
    rows.append(("fig4_acc_sweep", us,
                 "/".join(f"{v:.2f}" for v in acc_sweep)
                 + f" delta={abs(acc_sweep[0]-acc_sweep[2])/acc_sweep[2]:.2f}"))
    rows.append(("fig4_tg_sweep", us,
                 "/".join(f"{v:.2f}" for v in tg_sweep)))
    rows.append(("fig4_noc_sweep", us,
                 "/".join(f"{v:.2f}" for v in noc_sweep)))

    # DFS energy policy on Fig.4 telemetry: memory-bound accels derated
    islands = IslandConfig((
        IslandSpec("A1", ("A1",), TILE_LADDER, 1.0),
        IslandSpec("A2", ("A2",), TILE_LADDER, 1.0),
        IslandSpec("noc_mem", ("NOC", "MEM"), NOC_LADDER, 1.0),
    ))
    tel = {"A1": TileTelemetry(1.0, 10, 10, 5, boundness=0.95),
           "A2": TileTelemetry(1.0, 10, 10, 9, boundness=0.95)}
    t0 = time.perf_counter_ns()
    rates = policy_memory_bound(islands, tel)
    p_before = 2 * chip_power(1.0, 1.0)
    p_after = sum(chip_power(rates.get(n, 1.0), 1.0) for n in ("A1", "A2"))
    us = (time.perf_counter_ns() - t0) / 1e3
    rows.append(("fig4_dfs_policy", us,
                 f"rates={rates} energy_saving={(1 - p_after/p_before)*100:.0f}%"
                 f" (throughput unchanged: tiles are memory-bound)"))
    return rows
