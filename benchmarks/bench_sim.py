"""Closed-loop simulation benchmark: the runtime story at traffic scale.

Replays a ~1M-request diurnal day through the 12-accelerator 4x4 SoC
(dfmul tiles, K=8, fine-grained per-tile islands) three ways — fixed max
frequency, Fig.-4 memory-bound DFS, PID utilization DFS — reporting
simulated ticks/sec and requests/sec (wall), p99 latency and energy per
request.  Emits ``BENCH_sim.json`` so the closed-loop perf/efficiency
trajectory is tracked across PRs, the sim counterpart of
``BENCH_dse.json``.
"""
from __future__ import annotations

import json
import os
import time
from functools import partial

import numpy as np

from repro.core.dfs import PIDRatePolicy, policy_memory_bound
from repro.core.perfmodel import AccelWorkload, SoCPerfModel
from repro.sim import (ControllerHarness, SimConfig, SimEngine, SimPlatform,
                       diurnal_trace, with_total)

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_sim.json")

N_REQUESTS = 1_000_000
TICKS = 8_700                # with_total pins 1M requests -> ~0.30 mean util
DT = 5e-3


def _platform() -> SimPlatform:
    m = SoCPerfModel()
    pos = [(r, c) for r in range(4) for c in range(4)
           if (r, c) not in {(1, 0), (0, 0), (0, 3)}][:12]
    wls = [AccelWorkload("dfmul", 8.70, 1.1, replication=8) for _ in pos]
    return SimPlatform.build(m, wls, pos, noc_rate=1.0, n_tg=2,
                             req_mb=0.005)


def _controllers(plat):
    return {
        "fixed": None,
        "membound": ControllerHarness(
            plat.islands,
            partial(policy_memory_bound, threshold=0.55, low_rate=0.5),
            queue_guard_ticks=3.0),
        "pid": ControllerHarness(plat.islands, PIDRatePolicy(target=0.7),
                                 queue_guard_ticks=3.0),
    }


def bench_sim():
    plat = _platform()
    cap = SimEngine(plat).capacity_rps()
    trace = with_total(
        diurnal_trace(cap * 0.35, TICKS, plat.n_tiles, dt=DT, depth=0.5,
                      seed=7),
        N_REQUESTS)

    rows = []
    stats = {}
    for name, ctl in _controllers(plat).items():
        eng = SimEngine(plat, config=SimConfig(control_interval=25),
                        controller=ctl)
        t0 = time.perf_counter()
        r = eng.run(trace)
        wall = time.perf_counter() - t0
        rows.append((f"sim_{name}", wall * 1e6,
                     f"reqs={r.completed:,.0f} ticks/s={r.ticks / wall:,.0f} "
                     f"reqs/s={r.completed / wall:,.0f} "
                     f"p99={r.p99_latency_s * 1e3:.1f}ms "
                     f"mJ/req={r.energy_per_request_j * 1e3:.2f} "
                     f"swaps={r.swaps}"))
        stats[name] = {
            "wall_seconds": wall,
            "ticks_per_sec": r.ticks / wall,
            "requests_per_sec": r.completed / wall,
            "completed": r.completed,
            "dropped": r.dropped,
            "p50_latency_s": r.p50_latency_s,
            "p99_latency_s": r.p99_latency_s,
            "energy_per_request_j": r.energy_per_request_j,
            "mean_power_w": r.mean_power_w,
            "dfs_swaps": r.swaps,
        }

    base = stats["fixed"]["energy_per_request_j"]
    for name in ("membound", "pid"):
        stats[name]["energy_saving_vs_fixed"] = (
            1.0 - stats[name]["energy_per_request_j"] / base)

    from benchmarks.run import append_bench_row
    append_bench_row(BENCH_JSON, {
        "n_requests": N_REQUESTS,
        "ticks": TICKS,
        "dt": DT,
        "n_tiles": plat.n_tiles,
        "capacity_rps_total": float(cap.sum()),
        "mean_utilization": float(
            trace.offered_rps / cap.sum()),
        "runs": stats,
    })
    return rows


def run():
    return bench_sim()
