"""Fig. 3 analogue: compute- vs memory-bound tiles under NoC contention.

The paper puts a 4x-replicated adpcm (compute-bound) and dfmul
(memory-bound) in the far-from-memory A2 tile, NoC at 10 MHz, accelerators
and TGs at 50 MHz, and sweeps 0..11 active traffic generators.  Expected
shape: adpcm ~flat through 7 TGs; dfmul collapses over the same range.

A pod-domain companion sweeps background all-gather streams against a
compute-bound (train) vs memory-bound (decode) cell using the roofline
terms (collective bandwidth share shrinks as background flows take links).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from repro.configs.vespa_soc import CHSTONE
from repro.core.perfmodel import AccelWorkload, SoCPerfModel

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "dryrun")


def fig3_curves():
    m = SoCPerfModel()
    rows = []
    for name in ("adpcm", "dfmul"):
        base, ai = CHSTONE[name]
        wl = AccelWorkload(name, base, ai, replication=4)
        t0 = time.perf_counter_ns()
        # the whole 0..11-TG curve in one batched call (n_tg is an axis);
        # paper conditions: NoC at 10 MHz, accelerators and TGs at 50 MHz
        curve = m.accel_throughput_batch(
            base_mbps=base, wire_share=wl.wire_share, k=wl.replication,
            f_acc=1.0, f_noc=0.1, f_tg=1.0, n_tg=np.arange(12), pos=(3, 3))
        us = (time.perf_counter_ns() - t0) / 1e3
        norm = [float(c) / float(curve[0]) for c in curve]
        rows.append((f"fig3_{name}", us,
                     "thr@tg=" + "/".join(f"{v:.2f}" for v in norm[::2])
                     + f" flat7={norm[7] >= 0.9}"))
    return rows


def pod_contention():
    """Background collective streams eat ICI bandwidth: how much background
    traffic before each cell's bound flips to collective?"""
    rows = []
    cells = [("granite-8b__train_4k__pod1__fsdp-folded-gradrs", "train-opt"),
             ("deepseek-v2-lite-16b__decode_32k__pod1__tp-kvint8",
              "decode-opt")]
    for tag, name in cells:
        path = os.path.join(DRYRUN, tag + ".json")
        if not os.path.exists(path):
            continue
        with open(path) as f:
            d = json.load(f)
        chips = d["chips"]
        t_comp = d["jaxpr_flops_total"] / (chips * 197e12)
        t_mem = d["hbm_bytes_total"] / (chips * 819e9)
        t0 = time.perf_counter_ns()
        pts = []
        for bg in (0.0, 0.25, 0.5, 0.75):     # fraction of ICI stolen
            t_coll = d["collective_bytes"] / (50e9 * (1 - bg))
            bound = max(t_comp, t_mem, t_coll)
            pts.append(f"{bg:.2f}:{bound:.2e}")
        us = (time.perf_counter_ns() - t0) / 1e3
        rows.append((f"contention_{name}", us, " ".join(pts)))
    return rows


def run():
    return fig3_curves() + pod_contention()
