"""Observability overhead benchmark: the monitoring plane must be ~free.

Runs the same closed-loop replay with monitoring off, at ``counters``
level, and at ``full`` level on all three engines — the sequential
reference, the batched NumPy engine (B designs as one array program) and
the jitted ``lax.scan`` backend — and reports the wall-clock overhead of
each level.  The *gate* is the counters-level overhead on the batched
paths (the ones ``closed_loop_score`` scales on): it must stay within
``MAX_OVERHEAD`` (5%).  The sequential engine's deferred capture is
reported honestly but not gated — per-tick Python cost there is two
preallocated slot writes, yet the baseline loop is itself Python, so the
ratio is noisier.

Also emits a metrics round-trip check (CounterPlane -> Prometheus text
-> parse) and the phase profiler's breakdown, all into
``BENCH_observe.json`` so overhead is tracked across PRs.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.perfmodel import AccelWorkload, SoCPerfModel
from repro.sim import (BatchSimEngine, BatchSimPlatform, MetricsRegistry,
                       SimConfig, SimEngine, SimPlatform,
                       export_metrics, get_profiler, parse_prometheus_text,
                       poisson_trace, profiled, reset_profiler)

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_observe.json")

SEQ_TICKS = 4_000
BATCH_TICKS = 1_500
B = 64
DT = 1e-3
REPEATS = 9
MAX_OVERHEAD = 0.05              # the counters-level gate (batched paths)
LEVELS = ("off", "counters", "full")


def _platform() -> SimPlatform:
    m = SoCPerfModel()
    pos = [(r, c) for r in range(4) for c in range(4)
           if (r, c) not in {(1, 0), (0, 0), (0, 3)}][:6]
    wls = [AccelWorkload("dfmul", 8.70, 1.1, replication=8) for _ in pos]
    return SimPlatform.build(m, wls, pos, n_tg=2, req_mb=0.005)


def _interleaved_rounds(fns: dict, repeats: int = REPEATS) -> dict:
    """Wall-clock per case per round, measured round-robin: each repeat
    round times every case once back-to-back, so slow drift (thermal,
    background load) hits all cases of a round alike."""
    times = {k: [] for k in fns}
    for _ in range(repeats):
        for k, fn in fns.items():
            t0 = time.perf_counter()
            fn()
            times[k].append(time.perf_counter() - t0)
    return times


def _overheads(times: dict) -> dict:
    """Median of the paired within-round ratios — pairing cancels load
    drift between rounds, the median sheds rounds where a background
    spike landed on one case of the pair."""
    return {lv: float(np.median([t / o - 1.0
                                 for t, o in zip(times[lv], times["off"])]))
            for lv in LEVELS[1:]}


def _seq_case(plat, tr, level):
    eng = SimEngine(plat, config=SimConfig(control_interval=25),
                    observe=None if level == "off" else level)
    return lambda: eng.run(tr)


def _batch_case(bplat, tr, level, backend):
    # one engine per level, reused across repeats: the jitted scan is
    # cached per engine instance, so steady-state runs are measured, not
    # recompiles
    eng = BatchSimEngine(bplat, config=SimConfig(control_interval=25),
                         backend=backend,
                         observe=None if level == "off" else level)
    return lambda: eng.run(tr)


def _roundtrip_ok(plat, tr) -> bool:
    """CounterPlane -> Prometheus text -> parse must preserve families."""
    eng = SimEngine(plat, observe="counters")
    eng.run(tr)
    reg = MetricsRegistry()
    export_metrics(counters=eng.observer.counters, registry=reg)
    parsed = parse_prometheus_text(reg.render_prometheus())
    return set(parsed) == set(reg.names()) and len(parsed) > 0


def bench_observe():
    with profiled("bench_setup"):
        plat = _platform()
        seq_tr = poisson_trace(4_000.0, SEQ_TICKS, 6, dt=DT, seed=7)
        bat_tr = poisson_trace(4_000.0, BATCH_TICKS, 6, dt=DT, seed=7)
        bplat = BatchSimPlatform.stack([plat] * B)

    walls = {}
    rows = []
    retries = {}
    engines = [("sequential", SEQ_TICKS,
                lambda lv: _seq_case(plat, seq_tr, lv)),
               ("batch_numpy", BATCH_TICKS,
                lambda lv: _batch_case(bplat, bat_tr, lv, "numpy")),
               ("batch_jax", BATCH_TICKS,
                lambda lv: _batch_case(bplat, bat_tr, lv, "jax"))]
    gated = ("batch_numpy", "batch_jax")
    for ename, ticks, case in engines:
        fns = {}
        for level in LEVELS:
            fn = case(level)
            if ename == "batch_jax":
                # `observing` is part of the jit cache key: each level
                # compiles its own scan.  Warm outside the timed region.
                with profiled("jax_warmup"):
                    fn()
            fns[level] = fn
        with profiled(f"run_{ename}"):
            times = _interleaved_rounds(fns)
        over = _overheads(times)
        if ename in gated and over["counters"] > MAX_OVERHEAD:
            # one re-measure before declaring a breach: on a shared box
            # a long background spike can still poison a whole batch of
            # rounds, and a real regression fails both batches anyway
            retries[ename] = 1
            with profiled(f"run_{ename}"):
                times2 = _interleaved_rounds(fns)
            over2 = _overheads(times2)
            if over2["counters"] < over["counters"]:
                times, over = times2, over2
        per = {k: min(v) for k, v in times.items()}
        walls[ename] = per
        walls[ename + "_overhead"] = over
        rows.append((f"observe_{ename}", per["counters"] * 1e6,
                     f"counters={over['counters']:+.1%} "
                     f"full={over['full']:+.1%} "
                     f"off={per['off'] * 1e3:.1f}ms"))

    gate = {
        "max_overhead": MAX_OVERHEAD,
        "gated_engines": list(gated),
        "retries": retries,
        "counters_overhead": {
            e: walls[e + "_overhead"]["counters"] for e in gated},
    }
    gate["pass"] = all(v <= MAX_OVERHEAD
                       for v in gate["counters_overhead"].values())

    roundtrip = _roundtrip_ok(plat, seq_tr)
    rows.append(("observe_roundtrip", 0.0,
                 f"prometheus_roundtrip={'ok' if roundtrip else 'FAIL'} "
                 f"gate={'pass' if gate['pass'] else 'FAIL'}"))

    doc = {
        "seq_ticks": SEQ_TICKS, "batch_ticks": BATCH_TICKS, "B": B,
        "dt": DT, "repeats": REPEATS,
        "walls": walls,
        "gate": gate,
        "metrics_roundtrip_ok": roundtrip,
        "profiler": get_profiler().summary(),
    }
    from benchmarks.run import append_bench_row
    append_bench_row(BENCH_JSON, doc)

    if not roundtrip:
        raise RuntimeError("Prometheus round-trip failed")
    if not gate["pass"]:
        raise RuntimeError(
            f"counters-level overhead gate (<= {MAX_OVERHEAD:.0%}) failed: "
            f"{gate['counters_overhead']}")
    return rows


def run():
    reset_profiler()
    return bench_observe()
