"""Fault-injection benchmark: robustness of the closed-loop co-sim.

Replays the PR's scenario gate at benchmark scale — a 3+3 replicated
pipeline under a 2x diurnal surge with a back-end replica killed for a
fifth of the run — three ways (no recovery, respill recovery, recovery
with the online detector in the loop), reporting soak throughput
(ticks/sec with the full fault/SLO/balancer machinery engaged vs the
fault-free loop) plus the recovery-time row: detection latency and
backlog-clear time after the revive.  Emits ``BENCH_sim_faults.json``
so robustness overhead and recovery latency are tracked across PRs.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.runtime.fault import SimFaultConfig, SimFaultSupervisor
from repro.sim import (FaultSchedule, FlowPattern, LoadBalancer, SimConfig,
                       SimEngine, SimPlatform, SLOConfig, diurnal_trace)
from repro.core.perfmodel import AccelWorkload, SoCPerfModel

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_sim_faults.json")

TICKS = 8_000
DT = 1e-3
KILL = (3_600, 5_200)            # a fifth of the run, straddling the peak
STAGE0 = ("fe0", "fe1", "fe2")
STAGE1 = ("be0", "be1", "be2")


def _platform() -> SimPlatform:
    m = SoCPerfModel()
    pos = [(r, c) for r in range(4) for c in range(4)
           if (r, c) not in {(1, 0), (0, 0), (0, 3)}][:6]
    wls = [AccelWorkload("dfmul", 8.70, 1.1, replication=8) for _ in pos]
    return SimPlatform.build(m, wls, pos, names=STAGE0 + STAGE1, n_tg=2,
                             req_mb=0.005,
                             flows=FlowPattern.chain(STAGE0, STAGE1))


def _trace(plat):
    cap = SimEngine(plat).capacity_rps()
    stage_cap = float(cap[:3].sum())
    mean = np.zeros(6)
    mean[:3] = 0.45 * stage_cap / 3.0
    return diurnal_trace(mean, TICKS, 6, dt=DT, depth=1.0 / 3.0, seed=11,
                         phase=-np.pi / 2.0)


def _run(plat, tr, *, faults=None, slo=None, supervisor=None):
    bal = (LoadBalancer((STAGE0, STAGE1), plat.names, mode="even")
           if faults is not None else None)
    eng = SimEngine(plat, config=SimConfig(control_interval=25),
                    faults=faults, slo=slo, balancer=bal,
                    supervisor=supervisor)
    t0 = time.perf_counter()
    r = eng.run(tr)
    return eng, r, time.perf_counter() - t0


def bench_sim_faults():
    plat = _platform()
    tr = _trace(plat)
    sched = FaultSchedule().kill_tile("be1", start=KILL[0], end=KILL[1])
    recover = SLOConfig(deadline_s=0.05, on_kill="respill", max_retries=1)
    norec = SLOConfig(deadline_s=0.05, on_kill="drop", max_retries=0)

    runs = {}
    rows = []
    _, r0, w0 = _run(plat, tr)                       # fault-free reference
    runs["fault_free"] = {"wall_seconds": w0, "ticks_per_sec": TICKS / w0,
                          "completed": r0.completed, "drop_rate": 0.0,
                          "p99_latency_s": r0.p99_latency_s}

    cases = [("no_recovery", dict(faults=sched, slo=norec)),
             ("recovery", dict(faults=sched, slo=recover)),
             ("recovery_detected",
              dict(faults=sched, slo=recover,
                   supervisor=SimFaultSupervisor(
                       SimFaultConfig(dead_ticks=3))))]
    for name, kw in cases:
        eng, r, wall = _run(plat, tr, **kw)
        runs[name] = {
            "wall_seconds": wall,
            "ticks_per_sec": TICKS / wall,
            "completed": r.completed,
            "dropped_slo": r.dropped_slo,
            "dropped_fault": r.dropped_fault,
            "retried": r.retried,
            "drop_rate": r.drop_rate,
            "p99_latency_s": r.p99_latency_s,
        }
        rows.append((f"sim_faults_{name}", wall * 1e6,
                     f"ticks/s={TICKS / wall:,.0f} "
                     f"drop={r.drop_rate:.2%} "
                     f"retried={r.retried:,.0f} "
                     f"p99={r.p99_latency_s * 1e3:.1f}ms"))

    # soak overhead of the fault machinery relative to the plain loop
    runs["soak_overhead_vs_fault_free"] = (
        runs["recovery"]["wall_seconds"] / runs["fault_free"]["wall_seconds"]
        - 1.0)

    # recovery-time row: detection latency (online detector) + ticks for
    # the total backlog to return to its pre-kill level after the revive
    sup = SimFaultSupervisor(SimFaultConfig(dead_ticks=3))
    eng, r, _ = _run(plat, tr, faults=sched, slo=recover, supervisor=sup)
    dead_evs = [e for e in sup.events if e["kind"] == "detected_dead"]
    detect_ticks = (dead_evs[0]["tick"] - KILL[0]) if dead_evs else -1
    qh = np.asarray(eng.last_fault_histories["queue"])
    pre = float(np.percentile(qh[KILL[0] - 500:KILL[0]], 95))
    after = np.nonzero(qh[KILL[1]:] <= pre + 1e-9)[0]
    clear_ticks = int(after[0]) if after.size else -1
    runs["recovery_time"] = {
        "detect_latency_ticks": detect_ticks,
        "detect_latency_s": detect_ticks * DT,
        "backlog_clear_ticks_after_revive": clear_ticks,
        "backlog_clear_s_after_revive": clear_ticks * DT,
    }
    rows.append(("sim_faults_recovery_time", detect_ticks * DT * 1e6,
                 f"detect={detect_ticks} ticks "
                 f"backlog_clear={clear_ticks} ticks after revive"))

    from benchmarks.run import append_bench_row
    append_bench_row(BENCH_JSON,
                     {"ticks": TICKS, "dt": DT, "kill_window": list(KILL),
                      "deadline_s": recover.deadline_s, "runs": runs})
    return rows


def run():
    return bench_sim_faults()
