"""Multi-device sharding benchmark: the sweep evaluator across devices.

Measures the sharded ``grid_sweep`` path at 1 vs N virtual CPU devices
(``XLA_FLAGS=--xla_force_host_platform_device_count``) and appends the
speedup trajectory to ``BENCH_shard.json``.  Device count is fixed at
the first jax import, so each arm runs in its own subprocess with its
own ``XLA_FLAGS`` — the same pattern ``tests/test_distributed.py`` uses.

Two numbers per arm:

* ``eval_seconds`` — steady-state wall time of the device-side flat-point
  evaluator (``repro.core.dse._flat_point_evaluator``) on a fixed synthetic
  point batch.  This is the computation ``shard_map`` actually partitions,
  so it is what the **>= 2x at 4 virtual devices** acceptance gate runs on.
* ``sweep_seconds`` — an end-to-end chunked ``grid_sweep(devices=N)``,
  which also pays the serial host-side gather/Pareto-merge work and is
  reported un-gated (Amdahl caps it below the evaluator speedup).

The gate is asserted only when the machine actually has >= ``GATE_DEVICES``
CPU cores (virtual devices on one core time-slice it — no speedup exists
to measure); below that the row records the measurement with
``"enforced": false``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_shard.json")
_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
_ROOT = os.path.join(os.path.dirname(__file__), "..")

GATE_DEVICES = 4
GATE_MIN_SPEEDUP = 2.0
EVAL_POINTS = 1 << 20           # per evaluator call; device-count multiple
EVAL_REPS = 5
SWEEP_CHUNK = 200_000

_ARM = """
import json, time
import numpy as np
import jax
from repro.core.dse import _flat_point_evaluator, grid_sweep
from repro.core.perfmodel import AccelWorkload, SoCPerfModel

n_dev = {n_dev}
assert len(jax.devices()) >= n_dev, (n_dev, jax.devices())
model = SoCPerfModel()
wls = (AccelWorkload("dfadd", 9.22, 0.9),
       AccelWorkload("dfmul", 8.70, 1.1),
       AccelWorkload("dfsin", 0.33, 60.0))

# --- device-side evaluator, fixed synthetic point batch ---
P, A = {points}, 3
rng = np.random.default_rng(0)
kA = rng.choice([1.0, 2.0, 4.0], size=(A, P))
faA = rng.uniform(0.2, 1.0, size=(A, P))
hopA = rng.integers(1, 6, size=(A, P)).astype(np.float64)
fn = rng.uniform(0.3, 1.0, size=P)
ft = rng.uniform(0.3, 1.0, size=P)
ev = _flat_point_evaluator(
    n_dev, A, 2,
    tuple((float(w.base_mbps), float(w.wire_share)) for w in wls),
    float(model.own_demand), float(model.tg_demand),
    float(model.noc.link_bw), float(model.hop_latency_share),
    float(model._ref_hops()), float(model.mem_service),
    float(model.tg_demand_fig4))
out = ev(kA, faA, hopA, fn, ft)          # compile + warm
for o in out:
    o.block_until_ready()
best = float("inf")
for _ in range({reps}):
    t0 = time.perf_counter()
    out = ev(kA, faA, hopA, fn, ft)
    for o in out:
        o.block_until_ready()
    best = min(best, time.perf_counter() - t0)

# --- end-to-end chunked sharded sweep ---
kw = dict(ks=(1, 2, 4), acc_rates=(0.2, 0.4, 0.6, 0.8, 1.0),
          noc_rates=(0.25, 0.5, 0.75, 1.0), tg_rates=(0.5, 1.0), n_tg=2,
          positions=((1, 1), (3, 3), (0, 2)),
          island_rates="independent", chunk_points={chunk})
grid_sweep(model, wls, devices=n_dev, **kw)      # compile + warm
t0 = time.perf_counter()
res = grid_sweep(model, wls, devices=n_dev, **kw)
sweep_s = time.perf_counter() - t0
print(json.dumps({{"eval_seconds": best, "eval_points": P,
                   "sweep_seconds": sweep_s,
                   "sweep_points": int(res.n_points)}}))
"""


def _run_arm(n_dev: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "").replace(
        "--xla_force_host_platform_device_count", "--ignored") + " "
        f"--xla_force_host_platform_device_count={n_dev}").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.abspath(_SRC), os.path.abspath(_ROOT),
         env.get("PYTHONPATH", "")])
    code = _ARM.format(n_dev=n_dev, points=EVAL_POINTS, reps=EVAL_REPS,
                       chunk=SWEEP_CHUNK)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def bench_shard():
    arms = {n: _run_arm(n) for n in (1, GATE_DEVICES)}
    eval_speedup = (arms[1]["eval_seconds"]
                    / max(arms[GATE_DEVICES]["eval_seconds"], 1e-12))
    sweep_speedup = (arms[1]["sweep_seconds"]
                     / max(arms[GATE_DEVICES]["sweep_seconds"], 1e-12))
    cores = os.cpu_count() or 1
    enforced = cores >= GATE_DEVICES
    gate = {"devices": GATE_DEVICES, "min_speedup": GATE_MIN_SPEEDUP,
            "cpu_cores": cores, "enforced": enforced,
            "eval_speedup": eval_speedup, "sweep_speedup": sweep_speedup,
            "pass": (not enforced) or eval_speedup >= GATE_MIN_SPEEDUP}

    from benchmarks.run import append_bench_row
    append_bench_row(BENCH_JSON, {
        "eval_points": EVAL_POINTS, "sweep_chunk_points": SWEEP_CHUNK,
        "arms": {str(k): v for k, v in arms.items()},
        "gate": gate,
    })

    rows = [("shard_eval_1dev", arms[1]["eval_seconds"] * 1e6,
             f"P={EVAL_POINTS} flat-point evaluator, 1 device"),
            (f"shard_eval_{GATE_DEVICES}dev",
             arms[GATE_DEVICES]["eval_seconds"] * 1e6,
             f"{eval_speedup:.2f}x vs 1 device "
             f"(gate {'>=%.1fx' % GATE_MIN_SPEEDUP if enforced else 'off'}"
             f" @ {cores} cores)"),
            (f"shard_sweep_{GATE_DEVICES}dev",
             arms[GATE_DEVICES]["sweep_seconds"] * 1e6,
             f"end-to-end chunked sweep {sweep_speedup:.2f}x vs 1 device "
             f"({arms[GATE_DEVICES]['sweep_points']} points)")]
    if enforced:
        assert eval_speedup >= GATE_MIN_SPEEDUP, \
            f"sharded evaluator speedup {eval_speedup:.2f}x < " \
            f"{GATE_MIN_SPEEDUP}x at {GATE_DEVICES} devices ({cores} cores)"
    return rows


def run():
    return bench_shard()


if __name__ == "__main__":
    # direct execution puts benchmarks/ (not the repo root) on sys.path
    root = os.path.abspath(_ROOT)
    if root not in sys.path:
        sys.path.insert(0, root)
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
