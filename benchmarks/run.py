"""Benchmark harness: one module per paper table/figure (+ kernels, DSE).

Prints ``name,us_per_call,derived`` CSV by default, as required.
``--json`` instead emits one machine-readable JSON document (a list of
``{"name", "us_per_call", "derived"}`` rows) so CI can diff benchmark
output across PRs; ``--out FILE`` writes it to a file as well.
Paper-claims benchmarks print the reproduced number next to the paper's
measured value.

``--out`` refuses to overwrite an existing file whose JSON schema it
does not recognize (anything that is not a row list) — the trajectory
files the individual benchmarks own (see :data:`TRAJECTORY_FILES`)
carry a different row schema, and a mistyped ``--out BENCH_dse.json``
used to silently clobber them.  Pass ``--force`` to overwrite anyway.

**Trajectory files**: each ``BENCH_*.json`` is a JSON *list* of
timestamped snapshot rows (newest last) — one row appended per benchmark
run via :func:`append_bench_row` — so the perf trajectory accretes
across PRs instead of being overwritten.  Each benchmark used to write a
single bare snapshot dict, so every run *replaced* the previous numbers
and the "trajectory tracked across PRs" the docstrings promised never
existed; :func:`load_trajectory` still reads those legacy single-dict
documents as one-row trajectories, and regression guards compare against
:func:`latest_row`.
"""
import argparse
import json
import os
import sys
import tempfile
from datetime import datetime, timezone

# `python benchmarks/run.py` puts benchmarks/ (not the repo root) on
# sys.path; add the root so `from benchmarks import ...` resolves both
# there and under `python -m benchmarks.run`.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

ROW_KEYS = {"name", "us_per_call", "derived"}

# The trajectory files the individual benchmarks own (append-only row
# lists, newest last).  This is the canonical schema constant: the
# static-analysis gate (``python -m repro.analysis --bench``) reads it
# to assert every file exists and its latest row still passes the
# enforced gates recorded inside it, so a regressed append cannot land
# silently.  Add new ``BENCH_*.json`` files HERE, not just in the
# benchmark module that writes them.
TRAJECTORY_FILES = ("BENCH_dse.json", "BENCH_sim.json",
                    "BENCH_sim_batch.json", "BENCH_sim_faults.json",
                    "BENCH_observe.json", "BENCH_shard.json")


def is_row_list(doc) -> bool:
    """True iff ``doc`` is this harness's own output schema: a list of
    row dicts each carrying exactly the ``ROW_KEYS`` channels."""
    return (isinstance(doc, list)
            and all(isinstance(r, dict) and set(r) == ROW_KEYS
                    for r in doc))


def _warn(msg):
    print(f"benchmarks/run.py: {msg}", file=sys.stderr)


def _salvage_rows(text):
    """Recover the complete row objects from a corrupt (typically
    truncated mid-write) trajectory document.

    Walks the text with ``JSONDecoder.raw_decode`` from the opening
    ``[``, collecting every complete dict until the first undecodable
    span — a half-written trailing row is dropped, everything before it
    survives.
    """
    dec = json.JSONDecoder()
    i = text.find("[")
    if i < 0:
        return []
    i += 1
    rows = []
    n = len(text)
    while True:
        while i < n and text[i] in " \t\r\n,]":
            i += 1
        if i >= n:
            break
        try:
            obj, i = dec.raw_decode(text, i)
        except ValueError:
            break
        if isinstance(obj, dict):
            rows.append(obj)
    return rows


def load_trajectory(path):
    """Read a ``BENCH_*.json`` trajectory as a list of snapshot rows.

    Missing/empty files read as an empty trajectory; a legacy bare-dict
    snapshot (the pre-trajectory schema) reads as a one-row trajectory
    so old committed files keep their history when the next run appends
    to them.  A corrupt/partially-written file does NOT read as empty —
    that used to silently drop the whole history on the next append —
    instead the complete leading rows are salvaged (and malformed
    non-dict rows skipped) with a warning on stderr.
    """
    try:
        with open(path) as f:
            text = f.read()
    except OSError:
        return []
    if not text.strip():
        return []
    try:
        doc = json.loads(text)
    except ValueError:
        rows = _salvage_rows(text)
        _warn(f"{path}: corrupt/partially-written trajectory; salvaged "
              f"{len(rows)} complete row(s), skipping the rest")
        return rows
    if isinstance(doc, dict):
        return [doc]
    if isinstance(doc, list):
        good = [r for r in doc if isinstance(r, dict)]
        if len(good) != len(doc):
            _warn(f"{path}: skipped {len(doc) - len(good)} malformed "
                  "(non-dict) trajectory row(s)")
        return good
    _warn(f"{path}: unrecognized trajectory schema "
          f"({type(doc).__name__}); reading as empty")
    return []


def latest_row(path):
    """The most recent snapshot row of a trajectory file (or ``None``).

    Regression guards compare against this instead of ``json.load``-ing
    the file as a dict — the read that silently broke once the files
    became row lists.
    """
    rows = load_trajectory(path)
    return rows[-1] if rows else None


def _write_trajectory(path, rows):
    """Write a trajectory atomically: serialize to a temp file in the
    same directory, then ``os.replace`` over the target.  A crash (or a
    concurrent reader) mid-write can no longer leave a truncated file
    in place of the whole history."""
    d = os.path.dirname(os.path.abspath(path)) or "."
    fd, tmp = tempfile.mkstemp(
        dir=d, prefix=os.path.basename(path) + ".", suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(rows, f, indent=2)
            f.write("\n")
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def append_bench_row(path, snapshot):
    """Append one snapshot row (stamped ``recorded_utc``) to ``path``.

    Returns the full trajectory after the append.  This is the only
    writer the individual benchmarks use — replacing the ``json.dump``
    of a bare dict that used to overwrite the whole history each run.
    The write is atomic (temp file + rename).
    """
    rows = load_trajectory(path)
    row = dict(snapshot)
    row.setdefault("recorded_utc",
                   datetime.now(timezone.utc).isoformat(timespec="seconds"))
    rows.append(row)
    _write_trajectory(path, rows)
    return rows


def amend_latest_row(path, extra):
    """Merge ``extra`` keys into the newest row of a trajectory file.

    For multi-part benchmarks (``bench_dse``) whose later sections fold
    stats into the snapshot the earlier section just appended — an amend
    of the current run's row, never a new row.  Atomic like
    :func:`append_bench_row`.
    """
    rows = load_trajectory(path)
    assert rows, f"amend_latest_row({path!r}): no trajectory to amend"
    rows[-1].update(extra)
    _write_trajectory(path, rows)
    return rows


def check_out_target(path, *, force: bool = False) -> None:
    """Refuse to clobber an existing ``--out`` file we did not write.

    A missing file, an empty file, or a previous row-list emission are
    fine; any other schema (e.g. the ``BENCH_*.json`` trajectory files,
    whose snapshot rows carry benchmark-specific keys rather than exactly
    ``ROW_KEYS``) raises ``SystemExit`` unless ``force``.  Runs BEFORE
    the benchmarks so a bad target fails in milliseconds, not after
    minutes of measurement.
    """
    if force or path is None or not os.path.exists(path):
        return
    with open(path) as f:
        text = f.read()
    if not text.strip():
        return
    try:
        doc = json.loads(text)
    except ValueError:
        doc = None
    if not is_row_list(doc):
        raise SystemExit(
            f"refusing to overwrite {path}: existing file is not a "
            f"benchmark row list (keys {sorted(ROW_KEYS)}); it looks like "
            "a file owned by another writer (e.g. a BENCH_*.json "
            "trajectory document). Pass --force to overwrite anyway.")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="emit a JSON row list instead of CSV")
    ap.add_argument("--out", default=None,
                    help="also write the (JSON) output to this file")
    ap.add_argument("--force", action="store_true",
                    help="overwrite --out even if its schema is foreign")
    args = ap.parse_args(argv)
    check_out_target(args.out, force=args.force)

    from benchmarks import (bench_contention, bench_dfs_traffic, bench_dse,
                            bench_kernels, bench_observe, bench_replication,
                            bench_shard, bench_sim, bench_sim_batch,
                            bench_sim_faults)
    mods = [("replication(TableI)", bench_replication),
            ("contention(Fig3)", bench_contention),
            ("dfs_traffic(Fig4)", bench_dfs_traffic),
            ("dse", bench_dse),
            ("sim(closed-loop)", bench_sim),
            ("sim_batch(multi-design)", bench_sim_batch),
            ("sim_faults(robustness)", bench_sim_faults),
            ("observe(monitoring)", bench_observe),
            ("shard(multi-device)", bench_shard),
            ("kernels", bench_kernels)]
    rows = []
    failures = 0
    for label, mod in mods:
        try:
            for name, us, derived in mod.run():
                rows.append({"name": name, "us_per_call": round(us, 1),
                             "derived": derived})
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{label},0,ERROR:{e!r}", file=sys.stderr)

    if args.json:
        doc = json.dumps(rows, indent=2)
        print(doc)
        if args.out:
            with open(args.out, "w") as f:
                f.write(doc + "\n")
    else:
        print("name,us_per_call,derived")
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
