"""Benchmark harness: one module per paper table/figure (+ kernels, DSE).

Prints ``name,us_per_call,derived`` CSV, as required.  Paper-claims
benchmarks print the reproduced number next to the paper's measured value.
"""
import sys


def main() -> None:
    from benchmarks import (bench_contention, bench_dfs_traffic, bench_dse,
                            bench_kernels, bench_replication)
    mods = [("replication(TableI)", bench_replication),
            ("contention(Fig3)", bench_contention),
            ("dfs_traffic(Fig4)", bench_dfs_traffic),
            ("dse", bench_dse),
            ("kernels", bench_kernels)]
    print("name,us_per_call,derived")
    failures = 0
    for label, mod in mods:
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{label},0,ERROR:{e!r}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
