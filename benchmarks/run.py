"""Benchmark harness: one module per paper table/figure (+ kernels, DSE).

Prints ``name,us_per_call,derived`` CSV by default, as required.
``--json`` instead emits one machine-readable JSON document (a list of
``{"name", "us_per_call", "derived"}`` rows) so CI can diff benchmark
output across PRs; ``--out FILE`` writes it to a file as well.
Paper-claims benchmarks print the reproduced number next to the paper's
measured value.

``--out`` refuses to overwrite an existing file whose JSON schema it
does not recognize (anything that is not a row list) — the trajectory
files the individual benchmarks own (``BENCH_dse.json``,
``BENCH_sim.json``, ``BENCH_sim_batch.json``, ``BENCH_observe.json``)
are keyed documents, and a
mistyped ``--out BENCH_dse.json`` used to silently clobber them.  Pass
``--force`` to overwrite anyway.
"""
import argparse
import json
import os
import sys

# `python benchmarks/run.py` puts benchmarks/ (not the repo root) on
# sys.path; add the root so `from benchmarks import ...` resolves both
# there and under `python -m benchmarks.run`.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

ROW_KEYS = {"name", "us_per_call", "derived"}


def is_row_list(doc) -> bool:
    """True iff ``doc`` is this harness's own output schema: a list of
    row dicts each carrying exactly the ``ROW_KEYS`` channels."""
    return (isinstance(doc, list)
            and all(isinstance(r, dict) and set(r) == ROW_KEYS
                    for r in doc))


def check_out_target(path, *, force: bool = False) -> None:
    """Refuse to clobber an existing ``--out`` file we did not write.

    A missing file, an empty file, or a previous row-list emission are
    fine; any other schema (e.g. the keyed ``BENCH_*.json`` trajectory
    documents, which individual benchmarks own) raises ``SystemExit``
    unless ``force``.  Runs BEFORE the benchmarks so a bad target fails
    in milliseconds, not after minutes of measurement.
    """
    if force or path is None or not os.path.exists(path):
        return
    with open(path) as f:
        text = f.read()
    if not text.strip():
        return
    try:
        doc = json.loads(text)
    except ValueError:
        doc = None
    if not is_row_list(doc):
        raise SystemExit(
            f"refusing to overwrite {path}: existing file is not a "
            f"benchmark row list (keys {sorted(ROW_KEYS)}); it looks like "
            "a file owned by another writer (e.g. a BENCH_*.json "
            "trajectory document). Pass --force to overwrite anyway.")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="emit a JSON row list instead of CSV")
    ap.add_argument("--out", default=None,
                    help="also write the (JSON) output to this file")
    ap.add_argument("--force", action="store_true",
                    help="overwrite --out even if its schema is foreign")
    args = ap.parse_args(argv)
    check_out_target(args.out, force=args.force)

    from benchmarks import (bench_contention, bench_dfs_traffic, bench_dse,
                            bench_kernels, bench_observe, bench_replication,
                            bench_sim, bench_sim_batch, bench_sim_faults)
    mods = [("replication(TableI)", bench_replication),
            ("contention(Fig3)", bench_contention),
            ("dfs_traffic(Fig4)", bench_dfs_traffic),
            ("dse", bench_dse),
            ("sim(closed-loop)", bench_sim),
            ("sim_batch(multi-design)", bench_sim_batch),
            ("sim_faults(robustness)", bench_sim_faults),
            ("observe(monitoring)", bench_observe),
            ("kernels", bench_kernels)]
    rows = []
    failures = 0
    for label, mod in mods:
        try:
            for name, us, derived in mod.run():
                rows.append({"name": name, "us_per_call": round(us, 1),
                             "derived": derived})
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{label},0,ERROR:{e!r}", file=sys.stderr)

    if args.json:
        doc = json.dumps(rows, indent=2)
        print(doc)
        if args.out:
            with open(args.out, "w") as f:
                f.write(doc + "\n")
    else:
        print("name,us_per_call,derived")
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
