"""Roofline table generator: dry-run JSONs -> EXPERIMENTS.md §Roofline rows.

Three terms per (arch x shape x mesh) cell (v5e constants):
  compute    = FLOPs_total      / (chips · 197e12 · f_comp)
  memory     = HBM_bytes_total  / (chips · 819e9  · f_noc)
  collective = wire_bytes/dev   / (50e9 · f_noc)

FLOPs are the scan-aware jaxpr totals; HBM bytes the analytic traffic
model; collective bytes the while-aware per-device HLO parse
(launch/costing.py — XLA's own cost_analysis counts loop bodies once and
is reported only as an auxiliary column).

MODEL_FLOPS uses the 6·N·D (train) / 2·N_active·D (inference) convention;
the ratio MODEL_FLOPS / FLOPs_total exposes remat/causal-masking waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.perfmodel import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                  RooflineTerms, roofline_from_counts)

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

ARCH_ORDER = [
    "h2o-danube-1.8b", "phi3-medium-14b", "granite-8b", "gemma-2b",
    "deepseek-v2-lite-16b", "granite-moe-1b-a400m", "mamba2-370m",
    "zamba2-7b", "chameleon-34b", "musicgen-large",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_cells(pattern: str = "*.json") -> List[Dict[str, Any]]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def model_flops_for(cell: Dict[str, Any]) -> float:
    n = cell["n_active_params"]
    toks = cell["tokens"]
    mult = 6.0 if cell["kind"] == "train" else 2.0
    return mult * n * toks


def terms_for(cell: Dict[str, Any]) -> RooflineTerms:
    return roofline_from_counts(
        flops=cell["jaxpr_flops_total"],
        hbm_bytes=cell["hbm_bytes_total"],
        collective_bytes=cell.get("collective_bytes", 0.0),
        chips=cell["chips"])


def suggestion(cell: Dict[str, Any], t: RooflineTerms) -> str:
    dom = t.dominant
    kind = cell["kind"]
    if dom == "collective":
        return ("shrink TP span (MRA K>1) or overlap grad reduce"
                if kind == "train" else "MRA-replicate the tile: smaller "
                "collective group per replica")
    if dom == "memory":
        if kind == "decode":
            return ("KV/state sweep bound: quantize cache or batch more "
                    "requests per sweep")
        return "increase arithmetic intensity: fuse ops, larger microbatch"
    if kind == "train":
        return "cut remat/causal waste (folded schedule, selective remat)"
    return "compute-bound: near roofline; tune kernel block shapes"


def fmt_row(cell: Dict[str, Any]) -> str:
    t = terms_for(cell)
    mf = model_flops_for(cell)
    ratio = mf / max(cell["jaxpr_flops_total"], 1.0)
    return (f"| {cell['arch']} | {cell['shape']} | {cell['chips']} "
            f"| {t.t_compute:.3e} | {t.t_memory:.3e} | {t.t_collective:.3e} "
            f"| {t.dominant} | {t.roofline_fraction:.2f} "
            f"| {mf:.2e} | {ratio:.2f} | {suggestion(cell, t)} |")


HEADER = ("| arch | shape | chips | t_comp (s) | t_mem (s) | t_coll (s) "
          "| bound | frac | MODEL_FLOPS | MF/HLO | next lever |\n"
          "|---|---|---|---|---|---|---|---|---|---|---|")


def table(multi_pod: bool = False) -> str:
    cells = load_cells()
    rows = [HEADER]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for c in cells:
                if (c["arch"] == arch and c["shape"] == shape
                        and c.get("multi_pod", False) == multi_pod
                        and c.get("strategy", "tp") == "tp"):
                    rows.append(fmt_row(c))
    return "\n".join(rows)


def summary() -> Dict[str, Any]:
    cells = [c for c in load_cells() if not c.get("multi_pod", False)
             and c.get("strategy", "tp") == "tp"]
    doms: Dict[str, int] = {}
    worst = None
    most_coll = None
    for c in cells:
        t = terms_for(c)
        doms[t.dominant] = doms.get(t.dominant, 0) + 1
        frac_coll = t.t_collective / max(t.t_bound, 1e-30)
        if worst is None or t.roofline_fraction < worst[1]:
            worst = (f"{c['arch']}/{c['shape']}", t.roofline_fraction)
        if most_coll is None or frac_coll > most_coll[1]:
            most_coll = (f"{c['arch']}/{c['shape']}", frac_coll)
    return {"cells": len(cells), "dominant_counts": doms,
            "worst_fraction": worst, "most_collective": most_coll}


def main() -> None:
    print("## Single-pod (16x16 = 256 chips)\n")
    print(table(multi_pod=False))
    print("\n## Multi-pod (2x16x16 = 512 chips)\n")
    print(table(multi_pod=True))
    print("\n## Summary\n")
    print(json.dumps(summary(), indent=1))


if __name__ == "__main__":
    main()
