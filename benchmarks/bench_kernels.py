"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle wall-time and
— more meaningfully on this CPU container — the ANALYTIC VMEM working set
and MXU utilization the BlockSpecs claim on TPU."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter_ns()
    for _ in range(reps):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter_ns() - t0) / reps / 1e3


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    # flash attention: VMEM working set per grid step
    B, S, KV, G, hd = 1, 1024, 2, 2, 64
    QB = KB = 512
    q = jax.random.normal(key, (B, S, KV, G, hd), jnp.float32)
    k = jax.random.normal(key, (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(key, (B, S, KV, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    us_ref = _time(lambda *a: ref.flash_attention_ref(
        *a, scale=0.125, window=0), q, k, v, pos, pos)
    us_pal = _time(lambda *a: ops.flash_attention(*a, 0, 0.125),
                   q, k, v, pos, pos)
    vmem = (QB * hd + 2 * KB * hd + QB * hd + QB * 2) * 4
    rows.append(("flash_attention_1k", us_pal,
                 f"interp_vs_ref={us_pal/us_ref:.1f}x "
                 f"vmem_per_step={vmem/1024:.0f}KiB "
                 f"mxu_tile={QB}x{KB} causal_skip=on"))
    # SSD scan
    Bb, L, nh, hd2, st = 1, 512, 4, 64, 64
    xs = jax.random.normal(key, (Bb, L, nh, hd2))
    dt = jax.nn.softplus(jax.random.normal(key, (Bb, L, nh)))
    A = -jnp.exp(jax.random.normal(key, (nh,)) * 0.2)
    Bm = jax.random.normal(key, (Bb, L, st))
    Cm = jax.random.normal(key, (Bb, L, st))
    D = jnp.ones((nh,))
    us_ref = _time(lambda *a: ref.ssd_scan_ref(*a, chunk=128),
                   xs, dt, A, Bm, Cm, D)
    us_pal = _time(lambda *a: ops.ssd_scan(*a, 128), xs, dt, A, Bm, Cm, D)
    vmem = (st * hd2 + 128 * hd2 + 2 * 128 * st + 128 * 128) * 4
    rows.append(("ssd_scan_512", us_pal,
                 f"interp_vs_ref={us_pal/us_ref:.1f}x "
                 f"vmem_per_step={vmem/1024:.0f}KiB state_carry={st}x{hd2}"))
    # fused MLP
    N, d, F = 512, 1024, 2048
    x = jax.random.normal(key, (N, d)) * 0.3
    sc = jnp.zeros((d,))
    wg = jax.random.normal(key, (d, F)) * 0.05
    wu = jax.random.normal(key, (d, F)) * 0.05
    us_ref = _time(lambda *a: ref.fused_rmsnorm_mlp_ref(*a), x, sc, wg, wu)
    us_pal = _time(lambda *a: ops.fused_rmsnorm_mlp(*a), x, sc, wg, wu)
    hbm_saved = 3 * N * d * 2
    rows.append(("fused_mlp_512x1024", us_pal,
                 f"interp_vs_ref={us_pal/us_ref:.1f}x "
                 f"hbm_saved_vs_unfused={hbm_saved/2**20:.1f}MiB/call"))
    return rows
