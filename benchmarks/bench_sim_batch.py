"""Batched multi-design co-simulation benchmark: survivors/second.

Scores grid_sweep survivors by closed-loop replay three ways — the
sequential per-point engine (the reference), the batched NumPy engine at
B in {1, 64, 512}, and the batched jax.lax.scan backend — reporting
design-replays per second of wall clock.  Emits ``BENCH_sim_batch.json``
so the runtime-validation throughput trajectory is tracked across PRs
next to ``BENCH_dse.json`` (static sweep) and ``BENCH_sim.json``
(single-design closed loop).

Asserted here (the ISSUE acceptance): batched B=512 beats the sequential
path by >= 10x on CPU at identical ranking output.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.dfs import BatchPIDRatePolicy
from repro.core.dse import closed_loop_score, grid_sweep
from repro.core.perfmodel import AccelWorkload, SoCPerfModel
from repro.sim import (BatchControllerHarness, BatchSimEngine,
                       BatchSimPlatform, FlowPattern, LoadBalancer,
                       diurnal_trace, poisson_trace, SimConfig)

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_sim_batch.json")

TICKS = 400
DT = 1e-3
REQ_MB = 0.002
SEQ_SAMPLE = 64             # sequential reference measured on this many


def _sweep():
    m = SoCPerfModel()
    wls = [AccelWorkload("dfadd", 9.22, 0.9),
           AccelWorkload("dfmul", 8.70, 1.1)]
    res = grid_sweep(m, wls, ks=(1, 2, 4, 8), acc_rates=(0.2, 0.6, 1.0),
                     noc_rates=(0.5, 1.0), n_tg=2)
    return m, res


def bench_sim_batch():
    m, res = _sweep()
    survivors = res.topk_indices(512)
    survivors = np.resize(survivors, 512)       # pad if the sweep is small
    trace = diurnal_trace(2000.0, TICKS, 2, dt=DT, depth=0.4, seed=5)

    rows = []
    stats = {}

    # sequential reference (per-point SimEngine loop)
    idx = survivors[:SEQ_SAMPLE]
    t0 = time.perf_counter()
    seq = closed_loop_score(res, trace, model=m, indices=idx,
                            req_mb=REQ_MB, batch=False)
    seq_wall = time.perf_counter() - t0
    seq_rate = SEQ_SAMPLE / seq_wall
    stats["sequential"] = {"designs": SEQ_SAMPLE, "wall_seconds": seq_wall,
                           "survivors_per_sec": seq_rate}
    rows.append(("sim_batch_sequential", seq_wall / SEQ_SAMPLE * 1e6,
                 f"B={SEQ_SAMPLE} {seq_rate:,.1f} survivors/s"))

    for B in (1, 64, 512):
        idx = survivors[:B]
        t0 = time.perf_counter()
        bat = closed_loop_score(res, trace, model=m, indices=idx,
                                req_mb=REQ_MB)
        wall = time.perf_counter() - t0
        rate = B / wall
        stats[f"batch_numpy_{B}"] = {
            "designs": B, "wall_seconds": wall, "survivors_per_sec": rate,
            "speedup_vs_sequential": rate / seq_rate}
        rows.append((f"sim_batch_numpy_B{B}", wall / B * 1e6,
                     f"{rate:,.1f} survivors/s "
                     f"({rate / seq_rate:.1f}x sequential)"))
        if B == SEQ_SAMPLE:
            assert np.array_equal(bat.ranked_indices(),
                                  seq.ranked_indices()), \
                "batched ranking diverged from sequential"

    # acceptance: batched B=512 >= 10x the sequential path on CPU
    speedup = stats["batch_numpy_512"]["survivors_per_sec"] / seq_rate
    assert speedup >= 10.0, f"batched speedup {speedup:.1f}x < 10x"
    stats["acceptance_b512_speedup"] = speedup

    # ---- per-island (independent) sweep through the batched engine ----
    # The heterogeneous (B, I) rate plumbing must not regress the batched
    # replay: guarded against this run's own shared-rate B=512 rate and
    # against the previously recorded islands row (if any).
    from benchmarks.run import latest_row
    try:
        prev_islands = latest_row(BENCH_JSON)["runs"][
            "batch_numpy_islands_512"]["survivors_per_sec"]
    except Exception:
        prev_islands = None

    mi = SoCPerfModel()
    wls3 = [AccelWorkload("dfadd", 9.22, 0.9),
            AccelWorkload("dfmul", 8.70, 1.1),
            AccelWorkload("dfsin", 0.33, 60.0)]
    ires = grid_sweep(mi, wls3, ks=(1, 2), acc_rates=(0.2, 0.6, 1.0),
                      noc_rates=(0.5, 1.0), n_tg=2,
                      island_rates="independent", chunk_points=50_000)
    isurv = np.resize(ires.topk_indices(64), 512)
    itrace = diurnal_trace(2000.0, TICKS, 3, dt=DT, depth=0.4, seed=5)

    # micro-assert: tile->island lookups on the sim hot path are memoized
    bplat = BatchSimPlatform.from_design_points(mi, ires, isurv,
                                                req_mb=REQ_MB)
    BatchSimEngine(bplat)   # engine assembly resolves tile->island maps
    assert "_tile_index_cache" in bplat.islands.__dict__, \
        "island_of memo not built during engine assembly"
    t0 = time.perf_counter()
    for _ in range(20_000):
        for n in bplat.names:
            bplat.islands.island_of(n)
    lookup_ns = (time.perf_counter() - t0) / (20_000 * len(bplat.names)) * 1e9
    assert lookup_ns < 5_000, f"island_of lookup {lookup_ns:.0f}ns"

    t0 = time.perf_counter()
    closed_loop_score(ires, itrace, model=mi, indices=isurv, req_mb=REQ_MB)
    iwall = time.perf_counter() - t0
    irate = 512 / iwall
    shared_rate = stats["batch_numpy_512"]["survivors_per_sec"]
    # A=3 tiles vs 2 -> ~1.5x work per design; 0.4x is the regression gate
    assert irate >= 0.4 * shared_rate, \
        f"per-island replay {irate:,.0f}/s < 0.4x shared {shared_rate:,.0f}/s"
    if prev_islands is not None:
        assert irate >= 0.3 * prev_islands, \
            f"per-island replay regressed vs BENCH_sim_batch.json: " \
            f"{irate:,.0f}/s vs {prev_islands:,.0f}/s"
    stats["batch_numpy_islands_512"] = {
        "designs": 512, "wall_seconds": iwall, "survivors_per_sec": irate,
        "island_of_lookup_ns": lookup_ns,
        "ratio_vs_shared_b512": irate / shared_rate}
    rows.append(("sim_batch_numpy_islands_B512", iwall / 512 * 1e6,
                 f"{irate:,.1f} survivors/s (per-island rates, "
                 f"{irate / shared_rate:.2f}x shared-rate row, "
                 f"island_of {lookup_ns:.0f}ns)"))

    # ---- pipeline workload (tile-to-tile chain + load balancer) ----
    # ISSUE 5 acceptance: scoring survivors under a FlowPattern chain
    # (dfadd completions feed dfmul, balancer in the loop) keeps the
    # batched path >= 10x the sequential one at B=512.
    pipe = FlowPattern.chain(("dfadd",), ("dfmul",))
    ptrace = poisson_trace(np.asarray([2000.0, 0.0]), TICKS, 2, dt=DT,
                           seed=7)
    pipe_kw = dict(model=m, req_mb=REQ_MB, flows=pipe,
                   balancer_factory=lambda p: LoadBalancer(
                       [("dfadd",), ("dfmul",)], p.names))

    idx = survivors[:SEQ_SAMPLE]
    t0 = time.perf_counter()
    pseq = closed_loop_score(res, ptrace, indices=idx, batch=False,
                             **pipe_kw)
    pseq_wall = time.perf_counter() - t0
    pseq_rate = SEQ_SAMPLE / pseq_wall
    rows.append(("sim_batch_pipeline_sequential",
                 pseq_wall / SEQ_SAMPLE * 1e6,
                 f"B={SEQ_SAMPLE} {pseq_rate:,.1f} survivors/s"))
    stats["pipeline_sequential"] = {
        "designs": SEQ_SAMPLE, "wall_seconds": pseq_wall,
        "survivors_per_sec": pseq_rate}

    t0 = time.perf_counter()
    pbat = closed_loop_score(res, ptrace, indices=survivors[:512],
                             **pipe_kw)
    pwall = time.perf_counter() - t0
    prate = 512 / pwall
    pspeed = prate / pseq_rate
    assert pspeed >= 10.0, \
        f"batched pipeline speedup {pspeed:.1f}x < 10x"
    # (batch==sequential ranking parity for the pipeline workload is
    # asserted bit-exactly in tests/test_sim_flows.py)
    assert pbat.results[0].n_designs == 512
    stats["batch_numpy_pipeline_512"] = {
        "designs": 512, "wall_seconds": pwall, "survivors_per_sec": prate,
        "speedup_vs_sequential": pspeed}
    rows.append(("sim_batch_numpy_pipeline_B512", pwall / 512 * 1e6,
                 f"{prate:,.1f} survivors/s ({pspeed:.1f}x sequential, "
                 f"chain+balancer workload)"))

    # jax.lax.scan backend (compile once, report steady-state)
    try:
        idx = survivors[:512]
        bplat = BatchSimPlatform.from_design_points(m, res, idx,
                                                    req_mb=REQ_MB)
        ctl = BatchControllerHarness(bplat.islands, bplat.rates,
                                     BatchPIDRatePolicy(target=0.7),
                                     tile_names=bplat.names,
                                     queue_guard_ticks=3.0)
        eng = BatchSimEngine(bplat, config=SimConfig(control_interval=25),
                             controller=ctl, backend="jax")
        t0 = time.perf_counter()
        eng.run(trace)
        compile_wall = time.perf_counter() - t0
        t0 = time.perf_counter()
        eng.run(trace)
        wall = time.perf_counter() - t0
        rate = 512 / wall
        stats["batch_jax_512"] = {
            "designs": 512, "wall_seconds": wall,
            "compile_plus_run_seconds": compile_wall,
            "survivors_per_sec": rate,
            "speedup_vs_sequential": rate / seq_rate}
        rows.append(("sim_batch_jax_B512", wall / 512 * 1e6,
                     f"{rate:,.1f} survivors/s "
                     f"({rate / seq_rate:.1f}x sequential, "
                     f"compile {compile_wall:.1f}s)"))
    except Exception as e:  # pragma: no cover - jax optional at bench time
        stats["batch_jax_512"] = {"error": repr(e)}
        rows.append(("sim_batch_jax_B512", 0.0, f"SKIPPED:{e!r}"))

    # Pallas fused-tick backend (interpret mode on CPU): a validation
    # row, not a speed row — interpret mode runs the kernel body under
    # the Pallas interpreter, so B is kept small and the interesting
    # number is agreement with the numpy reference, which the engine's
    # differential tests assert bit-tightly.
    try:
        PB = 64
        idx = survivors[:PB]
        bplat = BatchSimPlatform.from_design_points(m, res, idx,
                                                    req_mb=REQ_MB)
        ctl = BatchControllerHarness(bplat.islands, bplat.rates,
                                     BatchPIDRatePolicy(target=0.7),
                                     tile_names=bplat.names,
                                     queue_guard_ticks=3.0)
        eng = BatchSimEngine(bplat, config=SimConfig(control_interval=25),
                             controller=ctl, backend="pallas")
        t0 = time.perf_counter()
        rp = eng.run(trace)
        pallas_wall = time.perf_counter() - t0
        stats["batch_pallas_64"] = {
            "designs": PB, "wall_seconds": pallas_wall,
            "survivors_per_sec": PB / pallas_wall,
            "mode": "interpret",
            "completed_total": float(np.sum(rp.completed))}
        rows.append(("sim_batch_pallas_B64", pallas_wall / PB * 1e6,
                     f"{PB / pallas_wall:,.1f} survivors/s "
                     f"(fused tick kernel, interpret mode)"))
    except Exception as e:  # pragma: no cover - pallas optional at bench
        stats["batch_pallas_64"] = {"error": repr(e)}
        rows.append(("sim_batch_pallas_B64", 0.0, f"SKIPPED:{e!r}"))

    from benchmarks.run import append_bench_row
    append_bench_row(BENCH_JSON, {
        "ticks": TICKS, "dt": DT, "req_mb": REQ_MB,
        "n_requests_per_design": float(trace.n_requests),
        "runs": stats,
    })
    return rows


def run():
    return bench_sim_batch()
