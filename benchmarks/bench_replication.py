"""Table I analogue: multi-replica tile area vs throughput.

Reproduces the paper's replication study twice:

1. **Paper domain** — the SoCPerfModel on the five CHStone accelerators at
   K in {1,2,4}: throughput gain + the Table I measured numbers side by
   side (validates the model against the paper's data).
2. **Pod domain**  — the MRA dry-run artifacts for deepseek decode_32k at
   K in {1,2,4,8}: per-device weight bytes ("area") vs collective wire
   bytes (the stream the paper's AXI bridge multiplexes).

CSV columns: name,us_per_call,derived.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
import time

import numpy as np

from repro.configs.vespa_soc import CHSTONE, TABLE_I
from repro.core.perfmodel import AccelWorkload, SoCPerfModel
from repro.core.replication import (replication_area_model,
                                    replication_throughput_model)

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "dryrun")


def paper_domain():
    m = SoCPerfModel()
    ks = np.array([1, 2, 4])
    rows = []
    for name, (base, ai) in CHSTONE.items():
        wl = AccelWorkload(name, base, ai)
        t0 = time.perf_counter_ns()
        # all three K points in one batched call (the DSE fast path)
        t = m.accel_throughput_batch(
            base_mbps=base, wire_share=wl.wire_share, k=ks,
            f_acc=1.0, f_noc=1.0, f_tg=1.0, n_tg=0, pos=(1, 1))
        thr = {int(k): float(v) for k, v in zip(ks, t)}
        us = (time.perf_counter_ns() - t0) / 1e3
        meas = {k: TABLE_I[name][k][4] / TABLE_I[name][1][4] for k in (2, 4)}
        rows.append((f"tableI_{name}", us,
                     f"gain2={thr[2]/thr[1]:.2f}(paper {meas[2]:.2f}) "
                     f"gain4={thr[4]/thr[1]:.2f}(paper {meas[4]:.2f})"))
    t0 = time.perf_counter_ns()
    g2, g4 = replication_throughput_model(2), replication_throughput_model(4)
    us = (time.perf_counter_ns() - t0) / 1e3
    rows.append(("tableI_avg_model", us,
                 f"gain2={g2:.2f}(paper 1.92) gain4={g4:.2f}(paper 3.58)"))
    return rows


def pod_domain():
    rows = []
    for k in (1, 2, 4, 8):
        tag = ("deepseek-v2-lite-16b__decode_32k__pod1"
               + (f"__mra{k}" if k > 1 else ""))
        path = os.path.join(DRYRUN, tag + ".json")
        if not os.path.exists(path):
            continue
        with open(path) as f:
            d = json.load(f)
        t0 = time.perf_counter_ns()
        area = replication_area_model(d["n_params"] * 2, 0, k)
        us = (time.perf_counter_ns() - t0) / 1e3
        rows.append((f"mra_pod_K{k}", us,
                     f"coll_bytes={d['collective_bytes']:.3e} "
                     f"weightB/dev={area['weight_bytes_per_dev']:.3e} "
                     f"t_mem={d['hbm_bytes_total']/(256*819e9):.3e}s"))
    return rows


def run():
    return paper_domain() + pod_domain()
