"""Deterministic, resumable, sharded synthetic data pipeline (the IO tile).

Production framing without external deps: a counter-based PRNG token stream
(threefry on (seed, step, shard)) means batch ``i`` is a pure function of
the config — any host can regenerate any step, so

* resume-after-failure is exact (no data-order drift),
* elastic rescaling re-partitions future steps with no coordination,
* every data-parallel shard draws a disjoint stream slice.

A real deployment swaps :class:`SyntheticLM` for a tokenized corpus reader
with the same ``batch_at(step)`` contract; everything downstream (trainer,
checkpoint metadata, fault recovery) only relies on the contract.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 32_000
    seq_len: int = 1024
    global_batch: int = 8
    modality: str = "text"        # text | vision | audio
    d_model: int = 0              # for embedding-input modalities


class SyntheticLM:
    """Markov-ish synthetic LM stream: tokens have local structure (so the
    loss actually decreases) but are cheap to generate on the fly."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int, *, shard: int = 0, n_shards: int = 1
                 ) -> Dict[str, np.ndarray]:
        """The canonical contract: batch for ``step``, host-shard ``shard``."""
        cfg = self.cfg
        assert cfg.global_batch % n_shards == 0
        b_local = cfg.global_batch // n_shards
        rng = np.random.default_rng(
            np.uint64(cfg.seed) * np.uint64(1_000_003)
            + np.uint64(step) * np.uint64(65_537) + np.uint64(shard))
        # structured stream: token_{t+1} = (a*token_t + noise) % V
        base = rng.integers(0, cfg.vocab_size, size=(b_local, 1))
        steps = rng.integers(0, 17, size=(b_local, cfg.seq_len))
        toks = (base + np.cumsum(steps, axis=1)) % cfg.vocab_size
        toks = toks.astype(np.int32)
        out: Dict[str, np.ndarray] = {
            "tokens": toks[:, :-1].copy() if cfg.seq_len > 1 else toks,
            "labels": toks[:, 1:].copy() if cfg.seq_len > 1 else toks,
        }
        if cfg.modality in ("vision", "audio") and cfg.d_model:
            # stub frontend: precomputed patch/frame embeddings (assignment)
            emb = rng.standard_normal(
                (b_local, out["tokens"].shape[1], cfg.d_model)).astype(np.float32)
            out["embeds"] = (emb * 0.02).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def for_arch(arch: ArchConfig, shape: ShapeConfig, seed: int = 0
             ) -> SyntheticLM:
    return SyntheticLM(DataConfig(
        seed=seed, vocab_size=arch.vocab_size,
        seq_len=shape.seq_len + 1, global_batch=shape.global_batch,
        modality=arch.modality, d_model=arch.d_model))


def device_put_batch(batch: Dict[str, np.ndarray], mesh, data_axes
                     ) -> Dict[str, jax.Array]:
    """Place a host batch sharded over the data axes of the mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    out = {}
    for k, v in batch.items():
        spec = P(data_axes) if v.ndim >= 1 else P()
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out
