"""Zamba2-7B — Mamba-2 backbone with shared attention blocks (hybrid).

81 Mamba-2 layers d_model=3584 ssm_state=64, a *shared* transformer block
(32H MHA kv=32, d_ff=14336) applied every 6 backbone layers.  vocab=32000.
[arXiv:2411.15242; unverified]
"""
from repro.configs.base import ArchConfig, register


@register("zamba2-7b")
def zamba2_7b() -> ArchConfig:
    return ArchConfig(
        name="zamba2-7b",
        family="hybrid",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        head_dim=3584 // 32,        # 112
        d_ff=14_336,
        vocab_size=32_000,
        act="gelu",
        rope_theta=10_000.0,
        ssm_state=64,
        ssm_expand=2,
        ssm_headdim=64,
        ssm_conv=4,
        ssm_ngroups=1,
        ssm_chunk=256,
        shared_attn_every=6,
        source="arXiv:2411.15242; unverified",
    )
