"""Chameleon-34B — early-fusion VLM backbone over VQ image tokens.

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.  The VQ-VAE image
tokenizer is a STUB per assignment: ``input_specs`` provides precomputed
token/patch embeddings; the backbone is the deliverable.
[arXiv:2405.09818; unverified]
"""
from repro.configs.base import ArchConfig, register


@register("chameleon-34b")
def chameleon_34b() -> ArchConfig:
    return ArchConfig(
        name="chameleon-34b",
        family="dense",
        modality="vision",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=8192 // 64,        # 128
        d_ff=22_016,
        vocab_size=65_536,
        act="silu",
        rope_theta=10_000.0,
        source="arXiv:2405.09818; unverified",
    )
