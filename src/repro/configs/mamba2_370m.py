"""Mamba-2 370M — attention-free SSD (state-space duality).

48L d_model=1024, d_state=128, expand=2 (d_inner=2048, headdim=64 -> 32 heads),
vocab=50280.
[arXiv:2405.21060; unverified]
"""
from repro.configs.base import ArchConfig, register


@register("mamba2-370m")
def mamba2_370m() -> ArchConfig:
    return ArchConfig(
        name="mamba2-370m",
        family="ssm",
        n_layers=48,
        d_model=1024,
        n_heads=0,
        n_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50_280,
        attn_type="none",
        tie_embeddings=True,
        ssm_state=128,
        ssm_expand=2,
        ssm_headdim=64,
        ssm_conv=4,
        ssm_ngroups=1,
        ssm_chunk=256,
        source="arXiv:2405.21060; unverified",
    )
