"""Gemma-2B — GeGLU, head_dim=256, MQA (kv=1), huge vocab, tied embeddings.

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000.
[arXiv:2403.08295; hf]
"""
from repro.configs.base import ArchConfig, register


@register("gemma-2b")
def gemma_2b() -> ArchConfig:
    return ArchConfig(
        name="gemma-2b",
        family="dense",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,               # MQA
        head_dim=256,               # 8 * 256 = 2048
        d_ff=16_384,
        vocab_size=256_000,
        act="gelu",                  # GeGLU
        rope_theta=10_000.0,
        tie_embeddings=True,
        source="arXiv:2403.08295; hf",
    )
