"""IBM Granite-3.0-1B-A400M — 32-expert top-8 MoE.

24L d_model=1024 16H (GQA kv=8) d_ff(expert)=512 vocab=49155, MoE 32e top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import ArchConfig, register


@register("granite-moe-1b-a400m")
def granite_moe_1b_a400m() -> ArchConfig:
    return ArchConfig(
        name="granite-moe-1b-a400m",
        family="moe",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        head_dim=1024 // 16,        # 64
        d_ff=512,                    # expert width (all layers MoE)
        vocab_size=49_155,
        act="silu",
        rope_theta=10_000.0,
        tie_embeddings=True,
        n_experts=32,
        n_shared_experts=0,
        top_k=8,
        d_ff_expert=512,
        n_dense_layers=0,
        source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
    )
