"""MusicGen-large — decoder-only transformer over EnCodec audio tokens.

48L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=2048.  The EnCodec frontend
is a STUB per assignment: ``input_specs`` provides precomputed frame
embeddings; the backbone is the deliverable.
[arXiv:2306.05284; hf]
"""
from repro.configs.base import ArchConfig, register


@register("musicgen-large")
def musicgen_large() -> ArchConfig:
    return ArchConfig(
        name="musicgen-large",
        family="dense",
        modality="audio",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=2048 // 32,        # 64
        d_ff=8192,
        vocab_size=2048,
        act="gelu",
        rope_theta=10_000.0,
        source="arXiv:2306.05284; hf",
    )
