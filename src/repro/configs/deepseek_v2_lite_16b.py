"""DeepSeek-V2-Lite (16B, 2.4B active) — MLA + fine-grained MoE.

27L d_model=2048 16H d_ff(expert)=1408 vocab=102400,
MLA kv_lora_rank=512 (qk_rope=64, qk_nope=128, v_head=128),
MoE: 64 routed experts top-6 + 2 shared, first layer dense (d_ff=10944).
[arXiv:2405.04434; hf]
"""
from repro.configs.base import ArchConfig, register


@register("deepseek-v2-lite-16b")
def deepseek_v2_lite_16b() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=10_944,                 # dense (first) layer FFN width
        vocab_size=102_400,
        act="silu",
        rope_theta=10_000.0,
        attn_type="mla",
        kv_lora_rank=512,
        qk_rope_dim=64,
        qk_nope_dim=128,
        v_head_dim=128,
        n_experts=64,
        n_shared_experts=2,
        top_k=6,
        d_ff_expert=1408,
        n_dense_layers=1,
        source="arXiv:2405.04434; hf",
    )
