"""Architecture & shape configuration system for vespa-jax.

Every assigned architecture is a frozen :class:`ArchConfig`; input shapes are
:class:`ShapeConfig`.  A registry maps ``--arch <id>`` strings to configs, and
``reduced()`` produces a CPU-smoke-testable config of the same family.

Vespa-specific design-time knobs (the paper's contributions) live in
:class:`TilePlan` / island assignment, which wrap an ArchConfig without
modifying it — mirroring how the paper replicates third-party accelerators
without touching their RTL.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    """A complete decoder-LM architecture description.

    Families: ``dense`` (pure transformer), ``moe`` (mixture-of-experts FFN),
    ``ssm`` (attention-free Mamba-2), ``hybrid`` (Mamba-2 backbone + shared
    attention tile, Zamba-2 style).
    """

    name: str
    family: str                     # dense | moe | ssm | hybrid
    modality: str = "text"          # text | vision | audio

    # Transformer core ------------------------------------------------------
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    act: str = "silu"               # silu -> SwiGLU, gelu -> GeGLU
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    sliding_window: int = 0         # 0 = full attention
    tie_embeddings: bool = False

    # Attention variant -----------------------------------------------------
    attn_type: str = "gqa"          # gqa | mla | none
    # MLA (DeepSeek-V2) params
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 0

    # MoE -------------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_dense_layers: int = 0         # leading layers that stay dense (DeepSeek)
    capacity_factor: float = 1.25

    # SSM (Mamba-2 / SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_ngroups: int = 1
    ssm_chunk: int = 256

    # Hybrid (Zamba-2) ------------------------------------------------------
    shared_attn_every: int = 0      # shared attention block every N ssm blocks

    dtype: str = "bfloat16"
    source: str = ""                # provenance [arXiv/hf; tier]

    # ------------------------------------------------------------------ utils
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_headdim else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode path exists (SSM state or sliding window)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def n_params(self) -> int:
        """Analytic parameter count (embedding + per-layer), for 6ND maths."""
        d, L, V = self.d_model, self.n_layers, self.vocab_size
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe"):
            per_layer += self._attn_params()
            per_layer += self._ffn_params()
            per_layer += 2 * d  # two RMSNorm scales
        elif self.family == "ssm":
            per_layer += self._ssm_params() + d
        elif self.family == "hybrid":
            per_layer += self._ssm_params() + d
        total = emb + L * per_layer
        if self.family == "hybrid" and self.shared_attn_every:
            # one shared attention+MLP tile reused across the depth
            total += self._attn_params() + 3 * self.d_model * self.d_ff + 2 * d
        return total

    def n_active_params(self) -> int:
        """Active (per-token) parameters — differs for MoE."""
        if self.family != "moe":
            return self.n_params()
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = self._attn_params() + 2 * d
        active_experts = self.top_k + self.n_shared_experts
        moe_ffn = 3 * d * self.d_ff_expert * active_experts
        dense_ffn = 3 * d * self.d_ff if self.d_ff else moe_ffn
        n_moe = L - self.n_dense_layers
        return emb + L * per_layer + n_moe * moe_ffn + self.n_dense_layers * dense_ffn

    def _attn_params(self) -> int:
        d, H, KV, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        if self.attn_type == "mla":
            rope, nope, vh = self.qk_rope_dim, self.qk_nope_dim, self.v_head_dim
            q = d * H * (nope + rope)
            kv_down = d * (self.kv_lora_rank + rope)
            kv_up = self.kv_lora_rank * H * (nope + vh)
            o = H * vh * d
            return q + kv_down + kv_up + o
        if self.attn_type == "none":
            return 0
        return d * H * hd + 2 * d * KV * hd + H * hd * d

    def _ffn_params(self) -> int:
        d = self.d_model
        if self.family == "moe":
            n_moe = self.n_layers - self.n_dense_layers
            per = 3 * d * self.d_ff_expert * (self.n_experts + self.n_shared_experts)
            per += d * self.n_experts  # router
            dense = 3 * d * self.d_ff
            # average per layer (approximation used only for reporting)
            return (n_moe * per + self.n_dense_layers * dense) // max(self.n_layers, 1)
        return 3 * d * self.d_ff

    def _ssm_params(self) -> int:
        d, di, st = self.d_model, self.d_inner, self.ssm_state
        nh, g = self.n_ssm_heads, self.ssm_ngroups
        in_proj = d * (2 * di + 2 * g * st + nh)
        conv = self.ssm_conv * (di + 2 * g * st)
        out = di * d
        return in_proj + conv + nh + nh + out  # + A_log + D

    def reduced(self) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        kw: Dict = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=64,
            d_ff=128,
            vocab_size=256,
        )
        if self.attn_type != "none":
            kw.update(n_heads=4, n_kv_heads=min(self.n_kv_heads, 2) or 2, head_dim=16)
        if self.attn_type == "mla":
            kw.update(kv_lora_rank=32, qk_rope_dim=8, qk_nope_dim=16, v_head_dim=16,
                      n_heads=4, head_dim=16)
        if self.family == "moe":
            kw.update(n_experts=4, top_k=2, d_ff_expert=64,
                      n_shared_experts=min(self.n_shared_experts, 1),
                      n_dense_layers=min(self.n_dense_layers, 1))
        if self.family in ("ssm", "hybrid"):
            kw.update(ssm_state=16, ssm_headdim=16, ssm_chunk=32)
        if self.family == "hybrid":
            kw.update(shared_attn_every=2, n_layers=4, n_heads=4, n_kv_heads=4,
                      head_dim=16, d_ff=128)
        if self.sliding_window:
            kw.update(sliding_window=32)
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Input-shape configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str               # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


LM_SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shapes_for(cfg: ArchConfig) -> Dict[str, ShapeConfig]:
    """The shape cells applicable to an architecture.

    ``long_500k`` needs a sub-quadratic decode path (SSM state or SWA window);
    pure full-attention archs skip it (recorded in DESIGN.md).
    """
    out = dict(LM_SHAPES)
    if not cfg.supports_long_context:
        out.pop("long_500k")
    return out


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ArchConfig]] = {}  # repro: noqa[RPR003] registry, not a cache: one entry per @register decorator in source, bounded at import time


def register(name: str):
    def deco(fn: Callable[[], ArchConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        # import side-effect registration
        from repro import configs as _c  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_configs() -> List[str]:
    from repro import configs as _c  # noqa: F401
    return sorted(_REGISTRY)
