"""The paper's own evaluation configuration, mapped to vespa-jax terms.

The ICCD'24 paper evaluates 4x4 tile-based SoCs: 1 CVA6 CPU tile, 1 DDR MEM
tile, 1 auxiliary I/O tile, 11 traffic-generator (TG, dfadd) tiles, and 2
accelerator tiles A1 (near memory) / A2 (far from memory), split into 5
frequency islands (A1, A2, NoC+MEM, TG, CPU+I/O... the paper lists: A1, A2,
NoC interconnect + memory controller, TG cores, CPU, I/O as five islands).

The NoC island DFS range is 10-100 MHz; the other islands 10-50 MHz, in
5 MHz steps.  We keep those numbers verbatim: the perf model treats them as
normalized rate ladders (f / f_max).

This config drives the paper-claims benchmarks (Table I / Fig. 3 / Fig. 4).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple


@dataclass(frozen=True)
class SoCTile:
    name: str
    kind: str                   # cpu | mem | io | tg | acc
    pos: Tuple[int, int]        # 4x4 grid position
    workload: str = ""          # adpcm | dfadd | dfmul | dfsin | gsm
    replication: int = 1        # the paper's K


@dataclass(frozen=True)
class SoCIsland:
    name: str
    tiles: Tuple[str, ...]
    f_min_mhz: int
    f_max_mhz: int
    f_step_mhz: int = 5


# CHStone accelerator characterization used by the perf model.  Arithmetic
# intensity (flops/byte proxy) distinguishes compute-bound (adpcm, dfsin)
# from memory-bound (dfadd, dfmul) accelerators, matching the paper's
# empirical observation; baseline throughputs are Table I's MB/s.
CHSTONE = {
    # name: (baseline_mbps, arithmetic_intensity)
    "adpcm": (1.40, 24.0),     # compute-bound
    "dfadd": (9.22, 0.9),      # memory-bound (paper: empirically memory-bound)
    "dfmul": (8.70, 1.1),      # memory-bound
    "dfsin": (0.33, 60.0),     # strongly compute-bound
    "gsm":   (4.61, 12.0),
}

# Table I resource/throughput data (for validating the replication model).
TABLE_I = {
    # accel: {K: (LUT, FF, BRAM, DSP, thr_mbps)}
    "adpcm": {1: (10899, 11720, 25, 81, 1.40), 2: (16455, 15158, 48, 162, 2.76), 4: (27313, 21780, 94, 324, 5.41)},
    "dfadd": {1: (11268, 11199, 2, 9, 9.22), 2: (16988, 14090, 2, 18, 16.88), 4: (28599, 19614, 2, 36, 26.06)},
    "dfmul": {1: (8435, 10222, 2, 25, 8.70), 2: (11352, 12136, 2, 50, 15.07), 4: (17382, 15706, 2, 100, 26.06)},
    "dfsin": {1: (16627, 14997, 2, 52, 0.33), 2: (27770, 21686, 2, 104, 0.65), 4: (50043, 34804, 2, 208, 1.24)},
    "gsm":   {1: (9900, 11418, 18, 62, 4.61), 2: (14304, 14520, 34, 124, 8.90), 4: (22927, 20473, 66, 248, 16.67)},
}


def paper_soc(replication_a: int = 4) -> Tuple[List[SoCTile], List[SoCIsland]]:
    """The paper's 4x4 SoC instance (Fig. 2 floorplan, Sec. III)."""
    tiles: List[SoCTile] = [
        SoCTile("CPU", "cpu", (0, 0)),
        SoCTile("MEM", "mem", (1, 0)),
        SoCTile("IO", "io", (0, 3)),
        SoCTile("A1", "acc", (1, 1), workload="dfsin", replication=replication_a),
        SoCTile("A2", "acc", (3, 3), workload="gsm", replication=replication_a),
    ]
    # 11 TG tiles (dfadd, memory-bound) fill the remaining positions.
    taken = {t.pos for t in tiles}
    i = 0
    for r in range(4):
        for c in range(4):
            if (r, c) in taken:
                continue
            tiles.append(SoCTile(f"TG{i}", "tg", (r, c), workload="dfadd"))
            i += 1
    islands = [
        SoCIsland("A1", ("A1",), 10, 50),
        SoCIsland("A2", ("A2",), 10, 50),
        SoCIsland("NOC_MEM", ("NOC", "MEM"), 10, 100),
        SoCIsland("TG", tuple(f"TG{j}" for j in range(11)), 10, 50),
        SoCIsland("CPU_IO", ("CPU", "IO"), 10, 50),
    ]
    return tiles, islands
