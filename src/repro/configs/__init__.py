"""Config registry: importing this package registers all assigned archs."""
from repro.configs.base import (  # noqa: F401
    ArchConfig,
    ShapeConfig,
    LM_SHAPES,
    shapes_for,
    get_config,
    list_configs,
    register,
)

# Assigned architectures (registration side effects).
from repro.configs import (  # noqa: F401
    h2o_danube_1_8b,
    phi3_medium_14b,
    granite_8b,
    gemma_2b,
    deepseek_v2_lite_16b,
    granite_moe_1b_a400m,
    mamba2_370m,
    zamba2_7b,
    chameleon_34b,
    musicgen_large,
)

ASSIGNED_ARCHS = [
    "h2o-danube-1.8b",
    "phi3-medium-14b",
    "granite-8b",
    "gemma-2b",
    "deepseek-v2-lite-16b",
    "granite-moe-1b-a400m",
    "mamba2-370m",
    "zamba2-7b",
    "chameleon-34b",
    "musicgen-large",
]
