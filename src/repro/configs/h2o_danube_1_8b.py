"""H2O-Danube-1.8B — llama+mistral mix with sliding-window attention.

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, SWA.
[arXiv:2401.16818; hf]
"""
from repro.configs.base import ArchConfig, register


@register("h2o-danube-1.8b")
def h2o_danube_1_8b() -> ArchConfig:
    return ArchConfig(
        name="h2o-danube-1.8b",
        family="dense",
        n_layers=24,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        head_dim=2560 // 32,        # 80
        d_ff=6912,
        vocab_size=32_000,
        act="silu",
        rope_theta=10_000.0,
        sliding_window=4_096,        # mistral-style SWA -> long_500k runnable
        source="arXiv:2401.16818; hf",
    )
