"""IBM Granite-8B (code) — llama-architecture dense transformer.

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.
[arXiv:2405.04324; hf]
"""
from repro.configs.base import ArchConfig, register


@register("granite-8b")
def granite_8b() -> ArchConfig:
    return ArchConfig(
        name="granite-8b",
        family="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=4096 // 32,        # 128
        d_ff=14_336,
        vocab_size=49_152,
        act="silu",
        rope_theta=10_000.0,
        source="arXiv:2405.04324; hf",
    )
