"""BENCH001 — benchmark trajectory guard.

The repo tracks performance as append-only trajectory files
(``BENCH_*.json`` row lists, see :mod:`benchmarks.run`).  This check —
run as part of the static-analysis CI gate — asserts that the *latest*
row of every known trajectory still passes the gates recorded inside
it: each ``gates``/``gate`` entry whose dict carries
``enforced: true`` must also carry ``pass: true`` (or
``ok``/``passed``).  A regression someone appended but did not fix
fails the gate exactly like a new lint finding.

The list of trajectory files is the linter-checked schema constant
``benchmarks.run.TRAJECTORY_FILES``; when ``benchmarks/`` is not
importable (installed package, trimmed checkout) a glob fallback over
``BENCH_*.json`` keeps the check meaningful.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from repro.analysis.findings import Finding

RULE_ID = "BENCH001"
SUMMARY = "latest BENCH_*.json rows must pass their enforced gates"

_FALLBACK_GLOB = "BENCH_*.json"


def _trajectory_files(repo_root: Path) -> List[Path]:
    run_py = repo_root / "benchmarks" / "run.py"
    if run_py.exists():
        try:
            import importlib.util
            spec = importlib.util.spec_from_file_location(
                "_repro_bench_run", run_py)
            mod = importlib.util.module_from_spec(spec)
            spec.loader.exec_module(mod)
            names = getattr(mod, "TRAJECTORY_FILES", None)
            if names:
                return [repo_root / n for n in names]
        except Exception:
            pass
    return sorted(repo_root.glob(_FALLBACK_GLOB))


def _latest_row(path: Path) -> Optional[Dict]:
    try:
        rows = json.loads(path.read_text())
    except (json.JSONDecodeError, OSError):
        return None
    if isinstance(rows, list) and rows and \
            all(isinstance(r, dict) for r in rows):
        return rows[-1]
    return None


def _gate_entries(row: Dict) -> Dict[str, Dict]:
    """Gate dicts of a snapshot row.

    Both shapes in the wild are accepted: ``row["gate"]`` as a single
    gate dict carrying ``pass`` (BENCH_observe/BENCH_shard), and
    ``row["gates"]`` as a name->gate mapping.
    """
    out: Dict[str, Dict] = {}
    g = row.get("gate")
    if isinstance(g, dict):
        if "pass" in g or "ok" in g or "passed" in g:
            out["gate"] = g
        else:
            for name, entry in g.items():
                if isinstance(entry, dict):
                    out[name] = entry
    gs = row.get("gates")
    if isinstance(gs, dict):
        for name, entry in gs.items():
            if isinstance(entry, dict):
                out[name] = entry
    return out


def _gate_ok(entry: Dict) -> Optional[bool]:
    for key in ("pass", "ok", "passed"):
        if key in entry:
            return bool(entry[key])
    return None


def check_trajectories(repo_root: Path) -> List[Finding]:
    findings: List[Finding] = []
    files = _trajectory_files(repo_root)
    for path in files:
        rel = path.name
        if not path.exists():
            findings.append(Finding(
                RULE_ID, rel, 1,
                f"trajectory file {rel} listed in TRAJECTORY_FILES is "
                "missing — regenerate it or update the constant"))
            continue
        row = _latest_row(path)
        if row is None:
            findings.append(Finding(
                RULE_ID, rel, 1,
                f"{rel} is not a row-list trajectory (see "
                "benchmarks/run.py schema)"))
            continue
        for name, entry in _gate_entries(row).items():
            # a gate without an `enforced` field is enforced by default
            # (BENCH_observe); `enforced: false` is advisory-only
            if not entry.get("enforced", True):
                continue
            ok = _gate_ok(entry)
            if ok is False:
                findings.append(Finding(
                    RULE_ID, rel, 1,
                    f"latest row of {rel}: enforced gate `{name}` is "
                    "failing — the last appended benchmark regressed"))
    return findings
