"""``repro.analysis`` — AST-based invariant linter for this codebase.

The last three PRs each paid a manual tax to the same bug classes:
jit-cache collisions (dt/policy-retune reusing stale compiled scans),
tracer leaks breaking the zero-perturbation ``observe=`` contract,
Pallas kernels silently closing over array constants, unbounded module
caches, and backend keyword surfaces drifting apart so a knob added to
one engine silently no-ops on another.  This package enforces those
invariants mechanically — the way an agile hardware flow relies on
automated design-rule checking rather than reviewer vigilance.

Rule passes (one module each under :mod:`repro.analysis.rules`):

=======  ==============================================================
RPR001   tracer leak: Python ``if``/``while``/``bool()``/``float()``/
         ``.item()``/``np.*`` applied to traced values inside functions
         reached by ``jax.jit`` / ``lax.scan`` / ``pallas_call``
RPR002   jit-cache-key completeness: hand-rolled jit caches must key on
         every non-tensor value baked into the traced closure
RPR003   unbounded caches: ``lru_cache(maxsize=None)``, ``@cache``,
         module/instance dict caches with inserts but no eviction
RPR004   dtype discipline: no f32 literals on the declared f64
         reference paths; no silent f64 upcasts on jax paths
RPR005   Pallas kernel rules: no array-valued closures, no ``np.*``
         calls, no Python branches on ref-derived values
RPR006   backend-surface parity: the engines' keyword surfaces for
         shared knobs agree or explicitly raise NotImplementedError
=======  ==============================================================

CLI::

    python -m repro.analysis [--format text|json] [--baseline FILE]
                             [--changed-only] [--bench] [paths...]

Findings carry ``file:line``, rule id, rationale, and a stable
fingerprint.  Pre-existing accepted findings live in the checked-in
baseline (``analysis/baseline.json``) so they don't block CI while any
NEW finding fails it.  Inline suppression::

    offending_line  # repro: noqa[RPR003] justification text (required)

and an opt-in ``# repro: traced`` marker on a ``def`` line forces the
jit-boundary inference to treat that function as traced (for closures
handed across call boundaries the call-graph cannot follow).
"""
from repro.analysis.engine import (AnalysisReport, ModuleContext,
                                   analyze_paths, iter_python_files)
from repro.analysis.findings import (Finding, load_baseline, save_baseline)
from repro.analysis.rules import RULES, get_rules

__all__ = [
    "AnalysisReport", "ModuleContext", "analyze_paths",
    "iter_python_files", "Finding", "load_baseline", "save_baseline",
    "RULES", "get_rules",
]
