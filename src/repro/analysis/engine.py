"""Analysis driver: parse modules once, run every rule, collect findings.

Each rule module exposes ``RULE_ID``, ``SUMMARY`` and
``check(ctx: ModuleContext) -> list[Finding]`` plus optionally
``check_project(ctxs: list[ModuleContext]) -> list[Finding]`` for
cross-module rules (RPR006 parity needs several files at once).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence

from repro.analysis import astutil
from repro.analysis.findings import (Finding, apply_noqa,
                                     assign_fingerprints,
                                     extract_comments,
                                     split_by_baseline)

_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", "build", "dist",
              ".eggs", "node_modules"}


def iter_python_files(paths: Sequence[Path]) -> Iterator[Path]:
    seen = set()
    for p in paths:
        if p.is_file() and p.suffix == ".py":
            rp = p.resolve()
            if rp not in seen:
                seen.add(rp)
                yield p
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if any(part in _SKIP_DIRS for part in f.parts):
                    continue
                rf = f.resolve()
                if rf not in seen:
                    seen.add(rf)
                    yield f


@dataclass
class ModuleContext:
    """One parsed module plus the shared per-module indices rules use."""
    path: Path                # as given (absolute or relative)
    relpath: str              # repo-relative posix path used in findings
    source: str
    tree: ast.Module
    lines: List[str]
    imports: astutil.ImportMap
    funcindex: astutil.FunctionIndex
    _trace: Optional[astutil.TraceIndex] = field(default=None, repr=False)

    @property
    def traceindex(self) -> astutil.TraceIndex:
        if self._trace is None:
            self._trace = astutil.TraceIndex(
                self.tree, self.imports, self.funcindex, self.lines)
        return self._trace

    def finding(self, rule: str, node_or_line, message: str) -> Finding:
        line = (node_or_line if isinstance(node_or_line, int)
                else getattr(node_or_line, "lineno", 1))
        return Finding(rule=rule, path=self.relpath, line=line,
                       message=message)


def load_module(path: Path, root: Path) -> Optional[ModuleContext]:
    try:
        source = path.read_text()
        tree = ast.parse(source, filename=str(path))
    except (SyntaxError, UnicodeDecodeError, OSError):
        return None
    try:
        rel = path.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        rel = path.as_posix()
    return ModuleContext(
        path=path, relpath=rel, source=source, tree=tree,
        lines=source.splitlines(),
        imports=astutil.ImportMap(tree),
        funcindex=astutil.FunctionIndex(tree))


@dataclass
class AnalysisReport:
    findings: List[Finding]          # fingerprinted, noqa applied
    new: List[Finding]               # not in baseline, not suppressed
    baselined: List[Finding]
    suppressed: List[Finding]
    modules: int

    @property
    def exit_code(self) -> int:
        return 1 if self.new else 0

    def to_dict(self) -> Dict[str, object]:
        return {
            "modules": self.modules,
            "counts": {
                "new": len(self.new),
                "baselined": len(self.baselined),
                "suppressed": len(self.suppressed),
            },
            "new": [f.to_dict() for f in self.new],
            "baselined": [f.to_dict() for f in self.baselined],
            "suppressed": [
                dict(f.to_dict(), justification=f.justification)
                for f in self.suppressed
            ],
        }


def analyze_paths(paths: Sequence[Path], *, root: Optional[Path] = None,
                  baseline: Optional[Iterable[str]] = None,
                  rules: Optional[Sequence[object]] = None,
                  ) -> AnalysisReport:
    from repro.analysis.rules import get_rules

    root = root or Path.cwd()
    active = list(rules) if rules is not None else get_rules()
    ctxs: List[ModuleContext] = []
    for f in iter_python_files(list(paths)):
        ctx = load_module(f, root)
        if ctx is not None:
            ctxs.append(ctx)

    raw: List[Finding] = []
    for rule in active:
        per_module = getattr(rule, "check", None)
        if per_module is not None:
            for ctx in ctxs:
                raw.extend(per_module(ctx))
        project_wide = getattr(rule, "check_project", None)
        if project_wide is not None:
            raw.extend(project_wide(ctxs))

    lines_by_path = {c.relpath: c.lines for c in ctxs}
    comments_by_path = {c.relpath: extract_comments(c.source)
                        for c in ctxs}
    findings = assign_fingerprints(raw, lines_by_path)
    findings = apply_noqa(findings, comments_by_path)
    # RPR000 meta findings produced by apply_noqa need fingerprints too
    findings = assign_fingerprints(findings, lines_by_path)

    accepted = set(baseline or ())
    new, old = split_by_baseline(findings, accepted)
    suppressed = [f for f in findings if f.suppressed]
    return AnalysisReport(findings=findings, new=new, baselined=old,
                          suppressed=suppressed, modules=len(ctxs))
