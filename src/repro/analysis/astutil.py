"""Shared AST machinery for the rule passes.

Three reusable layers:

* **Scope/import maps** — per-module parent links, import-alias
  normalization (``pl`` -> ``jax.experimental.pallas``), and a function
  index with lexical scope-chain lookup, so rules resolve ``Name`` call
  targets the way Python's own scoping does.
* **Jit-boundary inference** (:class:`TraceIndex`) — which functions in
  a module end up *traced*: direct entries (``jax.jit(f)``, decorator
  forms, ``lax.scan(step, ...)``, ``pallas_call(kernel, ...)``,
  ``shard_map``/``vmap``/``cond``/``while_loop``; ``functools.partial``
  indirection is followed), plus the transitive closure over
  locally-resolvable call edges, plus the explicit ``# repro: traced``
  source marker for closures handed across call boundaries the static
  call graph cannot follow.
* **Value taint** (:func:`taint_function`) — which local names of a
  traced function (transitively) derive from its traced positional
  parameters or from ``jnp``/``lax``/``pl`` results.  Keyword-only
  parameters are treated as static configuration (the idiom this
  codebase uses for ``functools.partial``-bound kernel scalars), as are
  ``static_argnames``/``static_argnums`` of a ``jax.jit`` entry.
  ``x is None`` checks, ``len()``/``isinstance()`` and
  ``.shape``/``.ndim``/``.dtype`` reads do not propagate taint (they
  yield Python values under tracing).  ``zip``/``enumerate`` loop
  targets are tainted element-wise so mixed static/traced iteration
  does not smear.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

FuncDef = (ast.FunctionDef, ast.AsyncFunctionDef)

# call-entry table: last dotted component -> positional indices of the
# function-valued arguments it traces
ENTRY_ARG_POSITIONS: Dict[str, Tuple[int, ...]] = {
    "jit": (0,), "vmap": (0,), "pmap": (0,), "grad": (0,),
    "value_and_grad": (0,), "checkpoint": (0,), "remat": (0,),
    "scan": (0,), "pallas_call": (0,), "shard_map": (0,),
    "while_loop": (0, 1), "fori_loop": (2,), "cond": (1, 2),
    "custom_vjp": (0,), "custom_jvp": (0,),
}
# dotted prefixes that mark a callable as "traces its argument" — a bare
# last-component match alone is not enough for common words like "scan"
_JAXISH_ROOTS = ("jax", "jax.numpy", "jax.lax", "jax.experimental",
                 "repro.compat", "functools.partial")
# last components accepted even without a jax-ish root (their names are
# unambiguous in this codebase)
_ALWAYS_ENTRY = {"pallas_call", "shard_map"}

# namespaces whose call results are traced values
TRACER_ROOTS = ("jax", "jax.numpy", "jax.lax", "jax.experimental")

# attribute reads that yield static Python values even on tracers
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding"}
# positional parameters treated as static configuration by name — this
# codebase threads config objects/selectors positionally (cfg, opts,
# plan) and they are never traced values
STATIC_PARAM_NAMES = {"self", "cls", "cfg", "config", "opts", "options",
                      "plan", "spec", "mode", "kind", "backend", "name"}
# annotations that mark a parameter as a static Python value
_STATIC_ANNOTATION_NAMES = {"int", "float", "bool", "str", "bytes",
                            "complex"}
_STATIC_ANNOTATION_SUFFIXES = ("Config", "Options", "Spec", "Plan",
                               "Policy")
# builtins whose results are static Python values under tracing
_STATIC_CALLS = {"len", "isinstance", "issubclass", "getattr", "hasattr",
                 "type", "id", "repr", "str", "format", "range", "max",
                 "min", "sorted", "tuple", "list", "dict", "set", "zip",
                 "enumerate"}
# NOTE: max/min on tracers DO leak, but the leak surfaces as the flagged
# comparison/branch downstream; treating them static here avoids
# tainting `max(ci, 1)`-style config arithmetic.  bool/int/float are
# deliberately NOT here — they are the flagged coercions.


def parse_module(source: str, filename: str = "<module>") -> ast.Module:
    return ast.parse(source, filename=filename)


def build_parent_map(tree: ast.AST) -> Dict[ast.AST, ast.AST]:
    parents: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class ImportMap:
    """alias -> fully dotted origin (``pl`` ->
    ``jax.experimental.pallas``, ``_smap`` -> ``repro.compat.shard_map``,
    ``np`` -> ``numpy``)."""

    def __init__(self, tree: ast.Module):
        self.alias: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.alias[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.alias[a.asname or a.name] = \
                        f"{node.module}.{a.name}"

    def normalize(self, dotted: Optional[str]) -> Optional[str]:
        """Rewrite the leading alias of a dotted path to its origin."""
        if not dotted:
            return dotted
        head, _, rest = dotted.partition(".")
        origin = self.alias.get(head)
        if origin is None:
            return dotted
        return f"{origin}.{rest}" if rest else origin


@dataclass(eq=False)            # identity semantics: usable as dict key
class FunctionRecord:
    node: ast.AST                       # FunctionDef / AsyncFunctionDef
    qualname: str
    parent: Optional["FunctionRecord"]  # lexically enclosing function
    children: Dict[str, "FunctionRecord"] = field(default_factory=dict)

    @property
    def name(self) -> str:
        return self.node.name

    @property
    def lineno(self) -> int:
        return self.node.lineno

    def positional_params(self) -> List[str]:
        a = self.node.args
        names = [p.arg for p in a.posonlyargs + a.args]
        if a.vararg:
            names.append(a.vararg.arg)
        return names

    def kwonly_params(self) -> List[str]:
        a = self.node.args
        names = [p.arg for p in a.kwonlyargs]
        if a.kwarg:
            names.append(a.kwarg.arg)
        return names

    def all_params(self) -> List[str]:
        return self.positional_params() + self.kwonly_params()


class FunctionIndex:
    """Every function def in a module, with lexical scope-chain lookup."""

    def __init__(self, tree: ast.Module):
        self.records: List[FunctionRecord] = []
        self.module_scope: Dict[str, FunctionRecord] = {}
        self._by_node: Dict[ast.AST, FunctionRecord] = {}
        self._collect(tree, parent=None, prefix="")

    def _collect(self, node: ast.AST, parent: Optional[FunctionRecord],
                 prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, FuncDef):
                qual = f"{prefix}{child.name}"
                rec = FunctionRecord(child, qual, parent)
                self.records.append(rec)
                self._by_node[child] = rec
                if parent is None:
                    self.module_scope[child.name] = rec
                else:
                    parent.children[child.name] = rec
                self._collect(child, rec, prefix=f"{qual}.")
            elif isinstance(child, ast.ClassDef):
                self._collect(child, parent, prefix=f"{prefix}{child.name}.")
            else:
                self._collect(child, parent, prefix=prefix)

    def record_for(self, node: ast.AST) -> Optional[FunctionRecord]:
        return self._by_node.get(node)

    def lookup(self, scope: Optional[FunctionRecord],
               name: str) -> Optional[FunctionRecord]:
        """Resolve ``name`` as Python scoping would: the scope's own
        nested defs, then enclosing functions' defs, then module defs."""
        cur = scope
        while cur is not None:
            if name in cur.children:
                return cur.children[name]
            cur = cur.parent
        return self.module_scope.get(name)


def _static_argnames(call: ast.Call) -> Set[str]:
    """static_argnames= of a jit call (string / tuple-of-strings)."""
    out: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                out.add(v.value)
            elif isinstance(v, (ast.Tuple, ast.List)):
                out.update(e.value for e in v.elts
                           if isinstance(e, ast.Constant)
                           and isinstance(e.value, str))
    return out


def _static_argnums(call: ast.Call) -> Set[int]:
    out: Set[int] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                out.add(v.value)
            elif isinstance(v, (ast.Tuple, ast.List)):
                out.update(e.value for e in v.elts
                           if isinstance(e, ast.Constant)
                           and isinstance(e.value, int))
    return out


@dataclass
class TraceInfo:
    kind: str                     # "jit"|"scan"|"pallas_call"|...|"called"|"marker"
    origin_line: int              # where the entry/edge was seen
    static_names: Set[str] = field(default_factory=set)
    via: str = ""                 # human-readable provenance


class TraceIndex:
    """Which functions of a module are traced, and how."""

    def __init__(self, tree: ast.Module, imports: ImportMap,
                 funcindex: FunctionIndex, source_lines: Sequence[str]):
        self.traced: Dict[FunctionRecord, TraceInfo] = {}  # repro: noqa[RPR003] result map bounded by the module's function count, built once per parse
        self._tree = tree
        self._imports = imports
        self._index = funcindex
        self._parents = build_parent_map(tree)
        self._lines = source_lines
        self._find_direct_entries()
        self._find_markers()
        self._close_over_calls()

    # ---------------------------------------------------------- helpers
    def _entry_kind(self, callee: Optional[str]) -> Optional[str]:
        """'jit'/'scan'/... when the callee traces its fn arguments."""
        if not callee:
            return None
        last = callee.rsplit(".", 1)[-1]
        if last not in ENTRY_ARG_POSITIONS:
            return None
        if last in _ALWAYS_ENTRY:
            return last
        if any(callee == root or callee.startswith(root + ".")
               for root in _JAXISH_ROOTS) or callee == last:
            # bare `jit(f)` resolves through the import map to jax.jit;
            # an unnormalized bare name means a local helper — only
            # accept it when the import map mapped it (callee != last
            # after normalize) or it IS jax-ish.
            if callee == last and self._imports.normalize(last) == last:
                return None
            return last
        return None

    def _enclosing_function(self, node: ast.AST) -> Optional[FunctionRecord]:
        cur = self._parents.get(node)
        while cur is not None:
            rec = self._index.record_for(cur)
            if rec is not None:
                return rec
            cur = self._parents.get(cur)
        return None

    def _resolve_fn_arg(self, arg: ast.AST,
                        scope: Optional[FunctionRecord]
                        ) -> Optional[FunctionRecord]:
        """Resolve a function-valued argument: Name -> local def,
        following one level of ``x = functools.partial(f, ...)`` /
        ``x = f`` aliasing inside ``scope``."""
        if isinstance(arg, ast.Call):
            # partial(f, ...) / jax.jit(f) nested inline
            callee = self._imports.normalize(dotted_name(arg.func))
            if callee in ("functools.partial", "partial") or \
                    self._entry_kind(callee):
                if arg.args:
                    return self._resolve_fn_arg(arg.args[0], scope)
            return None
        if not isinstance(arg, ast.Name):
            return None
        rec = self._index.lookup(scope, arg.id)
        if rec is not None:
            return rec
        # alias assigned in the same scope: x = partial(f, ...) | x = f
        body_owner = scope.node if scope is not None else self._tree
        for stmt in ast.walk(body_owner):
            if isinstance(stmt, ast.Assign) and \
                    any(isinstance(t, ast.Name) and t.id == arg.id
                        for t in stmt.targets):
                v = stmt.value
                if isinstance(v, ast.Call):
                    callee = self._imports.normalize(dotted_name(v.func))
                    if callee in ("functools.partial", "partial") and v.args:
                        return self._resolve_fn_arg(v.args[0], scope)
                elif isinstance(v, ast.Name):
                    return self._index.lookup(scope, v.id)
        return None

    def _mark(self, rec: FunctionRecord, info: TraceInfo) -> None:
        if rec not in self.traced:
            self.traced[rec] = info

    # ----------------------------------------------------- entry finding
    def _find_direct_entries(self) -> None:
        # decorator forms
        for rec in self._index.records:
            for dec in rec.node.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                callee = self._imports.normalize(dotted_name(target))
                kind = self._entry_kind(callee)
                if callee in ("functools.partial", "partial") and \
                        isinstance(dec, ast.Call) and dec.args:
                    inner = self._imports.normalize(
                        dotted_name(dec.args[0]))
                    kind = self._entry_kind(inner)
                    if kind:
                        self._mark(rec, TraceInfo(
                            kind, dec.lineno,
                            static_names=_static_argnames(dec),
                            via=f"@partial({inner}, ...)"))
                    continue
                if kind:
                    statics = (_static_argnames(dec)
                               if isinstance(dec, ast.Call) else set())
                    if isinstance(dec, ast.Call):
                        pos = rec.positional_params()
                        statics |= {pos[i] for i in _static_argnums(dec)
                                    if i < len(pos)}
                    self._mark(rec, TraceInfo(kind, dec.lineno,
                                              static_names=statics,
                                              via=f"@{callee}"))
        # call forms: jit(f), lax.scan(step, ...), pallas_call(kernel)
        for node in ast.walk(self._tree):
            if not isinstance(node, ast.Call):
                continue
            callee = self._imports.normalize(dotted_name(node.func))
            kind = self._entry_kind(callee)
            if not kind:
                continue
            scope = self._enclosing_function(node)
            statics = _static_argnames(node)
            nums = _static_argnums(node)
            for pos in ENTRY_ARG_POSITIONS[kind]:
                if pos < len(node.args):
                    rec = self._resolve_fn_arg(node.args[pos], scope)
                    if rec is not None:
                        st = set(statics)
                        ppos = rec.positional_params()
                        st |= {ppos[i] for i in nums if i < len(ppos)}
                        self._mark(rec, TraceInfo(
                            kind, node.lineno, static_names=st,
                            via=f"{callee}({rec.name}, ...)"))

    def _find_markers(self) -> None:
        """Opt-in ``# repro: traced`` comment on a def line."""
        for rec in self._index.records:
            line = ""
            if 0 < rec.lineno <= len(self._lines):
                line = self._lines[rec.lineno - 1]
            if "#" in line and "repro: traced" in line.split("#", 1)[1]:
                self._mark(rec, TraceInfo("marker", rec.lineno,
                                          via="# repro: traced"))

    def _close_over_calls(self) -> None:
        """Transitively trace locally-resolvable callees of traced fns."""
        work = list(self.traced.items())
        while work:
            rec, info = work.pop()
            for node in ast.walk(rec.node):
                if isinstance(node, ast.Call) and \
                        isinstance(node.func, ast.Name):
                    callee = self._index.lookup(rec, node.func.id)
                    if callee is not None and callee not in self.traced \
                            and callee is not rec:
                        sub = TraceInfo("called", node.lineno,
                                        via=f"called from {rec.name} "
                                            f"({info.kind})")
                        self.traced[callee] = sub
                        work.append((callee, sub))


# ---------------------------------------------------------------------------
# Taint
# ---------------------------------------------------------------------------


@dataclass
class TaintFlag:
    node: ast.AST
    reason: str                         # "branch"|"coerce"|"np-call"|"assert"
    detail: str


def _annotation_is_static(ann: Optional[ast.AST]) -> bool:
    """Annotated int/float/bool/str/... or *Config/*Options/... types
    are static Python values under tracing."""
    if ann is None:
        return False
    if isinstance(ann, ast.Subscript):        # Optional[int] etc.
        name = dotted_name(ann.value)
        if name and name.rsplit(".", 1)[-1] in ("Optional", "Union"):
            return _annotation_is_static(ann.slice)
        return False
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        last = ann.value.rsplit(".", 1)[-1]
    else:
        name = dotted_name(ann)
        if not name:
            return False
        last = name.rsplit(".", 1)[-1]
    return (last in _STATIC_ANNOTATION_NAMES
            or last.endswith(_STATIC_ANNOTATION_SUFFIXES))


def static_params(rec: FunctionRecord, info: TraceInfo) -> Set[str]:
    """Positional params NOT treated as traced: explicit static_arg*,
    config-by-name, and scalar/config-annotated parameters."""
    out = set(info.static_names) | STATIC_PARAM_NAMES
    a = rec.node.args
    for p in a.posonlyargs + a.args:
        if _annotation_is_static(p.annotation):
            out.add(p.arg)
    return out


class _TaintWalker:
    def __init__(self, rec: FunctionRecord, info: TraceInfo,
                 imports: ImportMap):
        self.rec = rec
        self.imports = imports
        statics = static_params(rec, info)
        self.tainted: Set[str] = set(
            p for p in rec.positional_params() if p not in statics)
        self.flags: List[TaintFlag] = []

    # -------------------------------------------------- expression taint
    def _call_is_tracer(self, callee: Optional[str]) -> bool:
        return bool(callee) and any(
            callee == root or callee.startswith(root + ".")
            for root in TRACER_ROOTS)

    def expr_tainted(self, e: Optional[ast.AST]) -> bool:
        if e is None or isinstance(e, ast.Constant):
            return False
        if isinstance(e, ast.Name):
            return e.id in self.tainted
        if isinstance(e, ast.Attribute):
            if e.attr in _STATIC_ATTRS:
                return False
            return self.expr_tainted(e.value)
        if isinstance(e, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in e.ops):
                return False                  # `x is (not) None` — Python bool
            return (self.expr_tainted(e.left)
                    or any(self.expr_tainted(c) for c in e.comparators))
        if isinstance(e, ast.Call):
            callee = self.imports.normalize(dotted_name(e.func))
            if callee in _STATIC_CALLS:
                return False
            if self._call_is_tracer(callee):
                return True
            return (self.expr_tainted(e.func)
                    or any(self.expr_tainted(a) for a in e.args)
                    or any(self.expr_tainted(k.value) for k in e.keywords))
        if isinstance(e, ast.IfExp):
            return (self.expr_tainted(e.test) or self.expr_tainted(e.body)
                    or self.expr_tainted(e.orelse))
        if isinstance(e, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                          ast.DictComp)):
            for gen in e.generators:
                self._bind_loop_target(gen.target, gen.iter)
            parts = ([e.key, e.value] if isinstance(e, ast.DictComp)
                     else [e.elt])
            # element IfExp tests inside comprehensions are checked here
            for p in parts:
                self._scan_expr_for_flags(p)
            return any(self.expr_tainted(p) for p in parts)
        return any(self.expr_tainted(c) for c in ast.iter_child_nodes(e))

    # ------------------------------------------------------- assignment
    def _taint_target(self, target: ast.AST) -> None:
        if isinstance(target, ast.Name):
            self.tainted.add(target.id)
        elif isinstance(target, ast.Starred):
            self._taint_target(target.value)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._taint_target(el)
        elif isinstance(target, (ast.Attribute, ast.Subscript)):
            # storing into x[...] / x.attr taints the container name
            root = target
            while isinstance(root, (ast.Attribute, ast.Subscript)):
                root = root.value
            if isinstance(root, ast.Name) and root.id != "self":
                self.tainted.add(root.id)

    def _bind_loop_target(self, target: ast.AST, it: ast.AST) -> None:
        """zip/enumerate-aware element-wise loop-target tainting."""
        callee = self.imports.normalize(dotted_name(it.func)) \
            if isinstance(it, ast.Call) else None
        if callee == "zip" and isinstance(target, (ast.Tuple, ast.List)) \
                and isinstance(it, ast.Call) \
                and len(it.args) == len(target.elts):
            for el, arg in zip(target.elts, it.args):
                if self.expr_tainted(arg):
                    self._taint_target(el)
            return
        if callee == "enumerate" and isinstance(target,
                                                (ast.Tuple, ast.List)) \
                and isinstance(it, ast.Call) and it.args \
                and len(target.elts) == 2:
            if self.expr_tainted(it.args[0]):
                self._taint_target(target.elts[1])
            return
        if self.expr_tainted(it):
            self._taint_target(target)

    # ---------------------------------------------------------- flagging
    def _flag_call(self, call: ast.Call) -> None:
        callee = self.imports.normalize(dotted_name(call.func))
        if callee in ("bool", "int", "float", "complex") and call.args \
                and self.expr_tainted(call.args[0]):
            self.flags.append(TaintFlag(
                call, "coerce",
                f"{callee}() coerces a traced value to a Python scalar"))
            return
        if callee and (callee == "numpy" or callee.startswith("numpy.")):
            fn = callee.rsplit(".", 1)[-1]
            if fn not in ("issubdtype", "ndim", "result_type", "dtype",
                          "bool_", "float32", "float64", "int32",
                          "int64") and (
                    any(self.expr_tainted(a) for a in call.args)
                    or any(self.expr_tainted(k.value)
                           for k in call.keywords)):
                self.flags.append(TaintFlag(
                    call, "np-call",
                    f"np.{fn}() applied to a traced value materializes "
                    "the tracer host-side"))
            return
        if isinstance(call.func, ast.Attribute) and \
                call.func.attr in ("item", "tolist", "__bool__",
                                   "__float__") and \
                self.expr_tainted(call.func.value):
            self.flags.append(TaintFlag(
                call, "coerce",
                f".{call.func.attr}() forces a traced value to host"))

    def _scan_expr_for_flags(self, e: Optional[ast.AST]) -> None:
        if e is None:
            return
        for node in ast.walk(e):
            if isinstance(node, ast.Call):
                self._flag_call(node)
            elif isinstance(node, ast.IfExp) and \
                    self.expr_tainted(node.test):
                self.flags.append(TaintFlag(
                    node, "branch",
                    "conditional expression branches on a traced value "
                    "(use jnp.where / lax.select)"))

    # ------------------------------------------------------- statements
    def run(self) -> None:
        self._walk_body(self.rec.node.body)

    def _walk_body(self, body: Sequence[ast.stmt]) -> None:
        for stmt in body:
            self._walk_stmt(stmt)

    def _walk_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, FuncDef):
            return                       # nested defs analyzed separately
        if isinstance(stmt, ast.Assign):
            self._scan_expr_for_flags(stmt.value)
            if self.expr_tainted(stmt.value):
                if len(stmt.targets) == 1 and \
                        isinstance(stmt.targets[0], (ast.Tuple, ast.List)) \
                        and isinstance(stmt.value, (ast.Tuple, ast.List)) \
                        and len(stmt.targets[0].elts) == \
                        len(stmt.value.elts):
                    for el, v in zip(stmt.targets[0].elts,
                                     stmt.value.elts):
                        if self.expr_tainted(v):
                            self._taint_target(el)
                else:
                    for t in stmt.targets:
                        self._taint_target(t)
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            self._scan_expr_for_flags(stmt.value)
            src_tainted = self.expr_tainted(stmt.value)
            if isinstance(stmt, ast.AugAssign):
                src_tainted = src_tainted or self.expr_tainted(stmt.target)
            if src_tainted:
                self._taint_target(stmt.target)
        elif isinstance(stmt, ast.If):
            self._scan_expr_for_flags(stmt.test)
            if self.expr_tainted(stmt.test):
                self.flags.append(TaintFlag(
                    stmt, "branch",
                    "Python `if` on a traced value bakes one branch into "
                    "the trace (use jnp.where / lax.cond)"))
            self._walk_body(stmt.body)
            self._walk_body(stmt.orelse)
        elif isinstance(stmt, ast.While):
            self._scan_expr_for_flags(stmt.test)
            if self.expr_tainted(stmt.test):
                self.flags.append(TaintFlag(
                    stmt, "branch",
                    "Python `while` on a traced value cannot be traced "
                    "(use lax.while_loop)"))
            self._walk_body(stmt.body)
            self._walk_body(stmt.orelse)
        elif isinstance(stmt, ast.Assert):
            self._scan_expr_for_flags(stmt.test)
            if self.expr_tainted(stmt.test):
                self.flags.append(TaintFlag(
                    stmt, "assert",
                    "assert on a traced value forces host sync "
                    "(use checkify or move outside the traced region)"))
        elif isinstance(stmt, ast.For):
            self._scan_expr_for_flags(stmt.iter)
            self._bind_loop_target(stmt.target, stmt.iter)
            self._walk_body(stmt.body)
            self._walk_body(stmt.orelse)
        elif isinstance(stmt, ast.With):
            for item in stmt.items:
                self._scan_expr_for_flags(item.context_expr)
                if item.optional_vars is not None and \
                        self.expr_tainted(item.context_expr):
                    self._taint_target(item.optional_vars)
            self._walk_body(stmt.body)
        elif isinstance(stmt, (ast.Try,)):
            self._walk_body(stmt.body)
            for h in stmt.handlers:
                self._walk_body(h.body)
            self._walk_body(stmt.orelse)
            self._walk_body(stmt.finalbody)
        elif isinstance(stmt, (ast.Expr, ast.Return)):
            self._scan_expr_for_flags(stmt.value)
        elif isinstance(stmt, ast.Raise):
            pass
        else:
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    self._scan_expr_for_flags(child)


def taint_function(rec: FunctionRecord, info: TraceInfo,
                   imports: ImportMap) -> Tuple[Set[str], List[TaintFlag]]:
    """Taint a traced function; returns (tainted names, flags)."""
    w = _TaintWalker(rec, info, imports)
    w.run()
    return w.tainted, w.flags


# ---------------------------------------------------------------------------
# Free variables / derivation roots (RPR002, RPR005)
# ---------------------------------------------------------------------------


def bound_names(rec: FunctionRecord) -> Set[str]:
    """Names bound inside a function: params, assignments, loop targets,
    nested defs, imports, withitems, comprehension targets."""
    out: Set[str] = set(rec.all_params())
    for node in ast.walk(rec.node):
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                for leaf in ast.walk(t):
                    if isinstance(leaf, ast.Name):
                        out.add(leaf.id)
        elif isinstance(node, ast.For):
            for leaf in ast.walk(node.target):
                if isinstance(leaf, ast.Name):
                    out.add(leaf.id)
        elif isinstance(node, ast.comprehension):
            for leaf in ast.walk(node.target):
                if isinstance(leaf, ast.Name):
                    out.add(leaf.id)
        elif isinstance(node, FuncDef) and node is not rec.node:
            out.add(node.name)
        elif isinstance(node, ast.withitem) and node.optional_vars:
            for leaf in ast.walk(node.optional_vars):
                if isinstance(leaf, ast.Name):
                    out.add(leaf.id)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for a in node.names:
                out.add(a.asname or a.name.split(".")[0])
        elif isinstance(node, ast.ExceptHandler) and node.name:
            out.add(node.name)
    return out


def free_names(rec: FunctionRecord) -> Set[str]:
    """Name loads in a function body not bound within the function."""
    bound = bound_names(rec)
    frees: Set[str] = set()
    for node in ast.walk(rec.node):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load) \
                and node.id not in bound:
            frees.add(node.id)
    return frees


def assignments_of(func_node: ast.AST) -> Dict[str, List[ast.expr]]:
    """name -> list of RHS expressions assigned to it, shallow walk of
    one function body (nested defs excluded)."""
    out: Dict[str, List[ast.expr]] = {}

    def visit(body):
        for stmt in body:
            if isinstance(stmt, FuncDef):
                continue
            if isinstance(stmt, ast.Assign):
                # element-wise for `a, b = x, y` so a's derivation roots
                # do not smear into b's (matters for RPR002 coverage)
                if len(stmt.targets) == 1 and \
                        isinstance(stmt.targets[0], (ast.Tuple, ast.List)) \
                        and isinstance(stmt.value, (ast.Tuple, ast.List)) \
                        and len(stmt.targets[0].elts) == \
                        len(stmt.value.elts):
                    for t, v in zip(stmt.targets[0].elts,
                                    stmt.value.elts):
                        for leaf in ast.walk(t):
                            if isinstance(leaf, ast.Name):
                                out.setdefault(leaf.id, []).append(v)
                    continue
                for t in stmt.targets:
                    for leaf in ast.walk(t):
                        if isinstance(leaf, ast.Name):
                            out.setdefault(leaf.id, []).append(stmt.value)
            elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)) and \
                    isinstance(stmt.target, ast.Name) and \
                    stmt.value is not None:
                out.setdefault(stmt.target.id, []).append(stmt.value)
            elif isinstance(stmt, ast.For):
                for leaf in ast.walk(stmt.target):
                    if isinstance(leaf, ast.Name):
                        out.setdefault(leaf.id, []).append(stmt.iter)
                visit(stmt.body)
                visit(stmt.orelse)
            elif isinstance(stmt, (ast.If, ast.While)):
                visit(stmt.body)
                visit(stmt.orelse)
            elif isinstance(stmt, ast.With):
                visit(stmt.body)
            elif isinstance(stmt, ast.Try):
                visit(stmt.body)
                for h in stmt.handlers:
                    visit(h.body)
                visit(stmt.orelse)
                visit(stmt.finalbody)

    visit(func_node.body)
    return out


def name_loads(e: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(e)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}
