"""RPR004 — dtype discipline.

The repo's correctness story is differential: the NumPy ``SimEngine``
and the DSE scoring path (``_eval_grid`` / ``pareto_front``) are the
float64 *reference*; jax/Pallas backends run float32 and are validated
against it at f32 tolerance.  Two drifts break that story silently:

* an f32 literal/cast sneaking into the f64 reference set narrows the
  reference itself, so the tolerance check compares f32 against f32
  and stops catching backend bugs;
* a float64 constant fed **directly** to a ``jnp``/``jax``/``lax`` op
  on an accelerator path either upcasts the whole computation (2x
  memory/bandwidth on the serving target) or is silently truncated
  under default ``jax_enable_x64=False`` — either way the author's
  intent is not what runs.

Host-side staging like ``np.asarray(x, dtype=np.float64)`` before a
device put is fine and not flagged; ``.astype(jnp.float64)`` and
``jnp.zeros(..., dtype=jnp.float64)`` are.

The f64 reference set is declared in :data:`F64_REFERENCE` —
(path-suffix, function-qualname-or-None-for-whole-module) pairs.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Tuple

from repro.analysis import astutil
from repro.analysis.engine import ModuleContext
from repro.analysis.findings import Finding

RULE_ID = "RPR004"
SUMMARY = ("no f32 in the f64 reference set; no silent f64 on "
           "jnp/jax/lax call paths")

# (relpath suffix, qualname prefix or None = entire module)
F64_REFERENCE: Tuple[Tuple[str, Optional[str]], ...] = (
    ("sim/engine.py", None),
    ("core/dse.py", "_eval_grid"),
    ("core/dse.py", "pareto_front"),
)

_F32_TOKENS = {"float32"}
_F64_TOKENS = {"float64"}
_JAX_ROOTS = ("jax", "jax.numpy", "jax.lax")


def _dtype_token(node: ast.AST, imports: astutil.ImportMap,
                 ) -> Optional[str]:
    """'float32'/'float64' if the node names that dtype, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        if node.value in _F32_TOKENS | _F64_TOKENS:
            return node.value
        return None
    dotted = imports.normalize(astutil.dotted_name(node))
    if dotted:
        last = dotted.rsplit(".", 1)[-1]
        if last in _F32_TOKENS | _F64_TOKENS:
            return last
    return None


def _reference_scope(ctx: ModuleContext, node: ast.AST) -> Optional[str]:
    """Qualname of the f64 reference scope containing node, or None."""
    for suffix, qual in F64_REFERENCE:
        if not ctx.relpath.endswith(suffix):
            continue
        if qual is None:
            return f"module {ctx.relpath}"
        rec = ctx.traceindex._enclosing_function(node)
        while rec is not None:
            if rec.qualname == qual or \
                    rec.qualname.startswith(qual + "."):
                return qual
            rec = rec.parent
    return None


def check(ctx: ModuleContext) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        dtype_args = [a for a in list(node.args)
                      + [kw.value for kw in node.keywords]
                      if _dtype_token(a, ctx.imports) is not None]
        if not dtype_args:
            continue
        tokens = {_dtype_token(a, ctx.imports) for a in dtype_args}
        ref = _reference_scope(ctx, node)
        if ref is not None:
            if tokens & _F32_TOKENS:
                out.append(ctx.finding(
                    RULE_ID, node,
                    f"float32 introduced inside the f64 reference "
                    f"scope ({ref}) — the reference must stay float64 "
                    "so differential tolerance checks keep meaning"))
            continue
        if tokens & _F64_TOKENS:
            callee = ctx.imports.normalize(
                astutil.dotted_name(node.func))
            is_jax_call = bool(callee) and any(
                callee == r or callee.startswith(r + ".")
                for r in _JAX_ROOTS)
            is_astype = (isinstance(node.func, ast.Attribute)
                         and node.func.attr == "astype"
                         and any(
                             (astutil.dotted_name(a) or "").split(".")[0]
                             in ("jnp", "jax")
                             or (ctx.imports.normalize(
                                 astutil.dotted_name(a)) or ""
                                 ).startswith("jax")
                             for a in dtype_args))
            if is_jax_call or is_astype:
                out.append(ctx.finding(
                    RULE_ID, node,
                    f"float64 requested directly in "
                    f"`{callee or '.astype'}` on a jax path — upcasts "
                    "the accelerator computation (or is silently "
                    "truncated without jax_enable_x64); stage "
                    "host-side with np.asarray instead"))
    return out
