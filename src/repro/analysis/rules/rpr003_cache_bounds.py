"""RPR003 — unbounded caches.

Long-lived engines (the ROADMAP's serving target) must not pin memory
per configuration ever seen.  PR 8 bounded five module caches by hand;
this rule keeps the property mechanical.  Flagged shapes:

* ``@functools.lru_cache(maxsize=None)`` and bare ``@functools.cache``
  — memoization without eviction.
* A module-level ``dict``/``list`` that some function inserts into
  (``d[k] = v``, ``d.setdefault``, ``d.append``, ``d.update``) with no
  eviction site anywhere in the module (``pop``/``popitem``/``clear``/
  ``del d[...]``/reassignment) and no explicit bound check
  (``len(d)`` comparison).
* An instance dict initialized in ``__init__`` (``self.x = {}``) whose
  inserts use the memo idiom — ``setdefault(...)`` or an
  ``if k not in self.x:`` guard — with no eviction in the class.
  Plain state dicts (unconditional ``self.x[k] = v`` bookkeeping) are
  not flagged; the memo idiom is what marks a growing cache.

Intentional registries are suppressed in place::

    _REGISTRY = {}  # repro: noqa[RPR003] process-lifetime registry, bounded by source

``deque(maxlen=...)``, ``lru_cache(n)`` and the OrderedDict-LRU idiom
(insert followed by ``popitem``) all pass.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis import astutil
from repro.analysis.engine import ModuleContext
from repro.analysis.findings import Finding

RULE_ID = "RPR003"
SUMMARY = "caches must be bounded (lru maxsize, LRU eviction, or maxlen)"

_EVICT_METHODS = {"pop", "popitem", "clear"}
_INSERT_METHODS = {"setdefault", "update", "append", "extend", "add"}
_DICTISH = {"dict", "OrderedDict", "defaultdict", "list"}


def _is_fresh_container(rhs: ast.AST) -> bool:
    if isinstance(rhs, (ast.Dict, ast.List)) and not (
            getattr(rhs, "keys", None) or getattr(rhs, "elts", None)):
        return True
    if isinstance(rhs, ast.Call) and not rhs.args and not rhs.keywords:
        callee = astutil.dotted_name(rhs.func)
        return bool(callee) and callee.rsplit(".", 1)[-1] in _DICTISH
    return False


def _name_usage(tree: ast.AST, name: str,
                attr_of_self: bool) -> Tuple[Set[str], bool, bool]:
    """(method names used on the target, subscript-store?, evicted?)."""
    methods: Set[str] = set()
    sub_store = False
    evicted = False

    def is_target(node: ast.AST) -> bool:
        if attr_of_self:
            return (isinstance(node, ast.Attribute) and node.attr == name
                    and isinstance(node.value, ast.Name)
                    and node.value.id == "self")
        return isinstance(node, ast.Name) and node.id == name

    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                is_target(node.func.value):
            methods.add(node.func.attr)
            if node.func.attr in _EVICT_METHODS:
                evicted = True
        elif isinstance(node, ast.Subscript) and is_target(node.value):
            if isinstance(node.ctx, ast.Store):
                sub_store = True
            elif isinstance(node.ctx, ast.Del):
                evicted = True
        elif isinstance(node, ast.Call):
            callee = astutil.dotted_name(node.func)
            if callee == "len" and node.args and is_target(node.args[0]):
                evicted = True           # len() guard implies a bound
    return methods, sub_store, evicted


def _memo_guard_on(tree: ast.AST, name: str, attr_of_self: bool) -> bool:
    """``if k not in <target>:`` / ``if k in <target>`` guard present?"""
    for node in ast.walk(tree):
        if isinstance(node, (ast.If, ast.IfExp)) and \
                isinstance(node.test, ast.Compare) and \
                any(isinstance(op, (ast.In, ast.NotIn))
                    for op in node.test.ops):
            for comp in node.test.comparators:
                if attr_of_self:
                    if isinstance(comp, ast.Attribute) and \
                            comp.attr == name and \
                            isinstance(comp.value, ast.Name) and \
                            comp.value.id == "self":
                        return True
                elif isinstance(comp, ast.Name) and comp.id == name:
                    return True
    return False


def _check_lru_decorators(ctx: ModuleContext) -> List[Finding]:
    out: List[Finding] = []
    for rec in ctx.funcindex.records:
        for dec in rec.node.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            callee = ctx.imports.normalize(astutil.dotted_name(target))
            if not callee:
                continue
            last = callee.rsplit(".", 1)[-1]
            if last == "cache" and callee.startswith("functools"):
                out.append(ctx.finding(
                    RULE_ID, dec,
                    f"`@functools.cache` on `{rec.qualname}` never "
                    "evicts — use lru_cache(maxsize=N)"))
            elif last == "lru_cache" and isinstance(dec, ast.Call):
                for kw in dec.keywords:
                    if kw.arg == "maxsize" and \
                            isinstance(kw.value, ast.Constant) and \
                            kw.value.value is None:
                        out.append(ctx.finding(
                            RULE_ID, dec,
                            f"`lru_cache(maxsize=None)` on "
                            f"`{rec.qualname}` never evicts — give it "
                            "a finite maxsize"))
                if dec.args and isinstance(dec.args[0], ast.Constant) \
                        and dec.args[0].value is None:
                    out.append(ctx.finding(
                        RULE_ID, dec,
                        f"`lru_cache(None)` on `{rec.qualname}` never "
                        "evicts — give it a finite maxsize"))
    return out


def _check_module_dicts(ctx: ModuleContext) -> List[Finding]:
    out: List[Finding] = []
    for stmt in ctx.tree.body:
        targets: List[Tuple[str, ast.AST]] = []
        if isinstance(stmt, ast.Assign) and _is_fresh_container(stmt.value):
            targets = [(t.id, stmt) for t in stmt.targets
                       if isinstance(t, ast.Name)]
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None \
                and isinstance(stmt.target, ast.Name) and \
                _is_fresh_container(stmt.value):
            targets = [(stmt.target.id, stmt)]
        for name, node in targets:
            methods, sub_store, evicted = _name_usage(
                ctx.tree, name, attr_of_self=False)
            inserts = sub_store or bool(methods & _INSERT_METHODS)
            if inserts and not evicted:
                out.append(ctx.finding(
                    RULE_ID, node,
                    f"module-level cache `{name}` grows without "
                    "eviction (inserts but no pop/popitem/clear/del/"
                    "len-bound) — bound it or mark the registry "
                    "intent with a justified noqa"))
    return out


def _check_instance_dicts(ctx: ModuleContext) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        init = next((n for n in node.body
                     if isinstance(n, ast.FunctionDef)
                     and n.name == "__init__"), None)
        if init is None:
            continue
        attrs: List[Tuple[str, ast.AST]] = []
        for stmt in ast.walk(init):
            if isinstance(stmt, (ast.Assign, ast.AnnAssign)):
                tgts = (stmt.targets if isinstance(stmt, ast.Assign)
                        else [stmt.target])
                val = stmt.value
                if val is None or not _is_fresh_container(val):
                    continue
                for t in tgts:
                    if isinstance(t, ast.Attribute) and \
                            isinstance(t.value, ast.Name) and \
                            t.value.id == "self":
                        attrs.append((t.attr, stmt))
        for name, site in attrs:
            methods, sub_store, evicted = _name_usage(
                node, name, attr_of_self=True)
            if evicted:
                continue
            memo_style = ("setdefault" in methods or
                          _memo_guard_on(node, name, attr_of_self=True))
            inserts = sub_store or bool(methods & _INSERT_METHODS)
            if memo_style and inserts:
                out.append(ctx.finding(
                    RULE_ID, site,
                    f"instance memo-cache `self.{name}` in "
                    f"`{node.name}` grows without eviction — bound it "
                    "(LRU / maxlen) for long-lived instances"))
    return out


def check(ctx: ModuleContext) -> List[Finding]:
    return (_check_lru_decorators(ctx) + _check_module_dicts(ctx)
            + _check_instance_dicts(ctx))
