"""Rule registry.  Each rule module exposes ``RULE_ID``, ``SUMMARY``
and ``check(ctx)`` and/or ``check_project(ctxs)``; register new rules
here and they are picked up by the CLI, the baseline machinery and the
docs table alike."""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.analysis.rules import (rpr001_tracer_leak, rpr002_cache_key,
                                  rpr003_cache_bounds, rpr004_dtype,
                                  rpr005_pallas, rpr006_parity)

RULES = (rpr001_tracer_leak, rpr002_cache_key, rpr003_cache_bounds,
         rpr004_dtype, rpr005_pallas, rpr006_parity)


def get_rules(only: Optional[Sequence[str]] = None) -> List[object]:
    """All rules, or the subset whose RULE_ID is in ``only``."""
    if only is None:
        return list(RULES)
    wanted = {r.upper() for r in only}
    out = [m for m in RULES if m.RULE_ID in wanted]
    unknown = wanted - {m.RULE_ID for m in out}
    if unknown:
        raise ValueError(f"unknown rule id(s): {sorted(unknown)}")
    return out
