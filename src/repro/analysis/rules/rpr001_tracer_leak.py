"""RPR001 — tracer leak.

Inside a function reached by ``jax.jit`` / ``lax.scan`` / ``vmap`` /
``shard_map`` (see :class:`repro.analysis.astutil.TraceIndex`), values
derived from the traced positional arguments are *tracers*: Python
``if``/``while`` on them bakes one branch into the compiled artifact
(or raises ``TracerBoolConversionError``), ``bool()``/``int()``/
``float()``/``.item()`` force a device sync, and ``np.*`` calls
materialize the tracer host-side and silently constant-fold it.

Why it matters here: PR 7's zero-perturbation contract — ``observe=``
must never change simulated dynamics — holds only if observation code
inside the scan never branches on traced state; a single host branch
also retraces per Python value, defeating the PR 8 jit cache.

Keyword-only parameters are treated as static (this codebase binds
compile-time scalars through ``functools.partial`` keywords), as are
``static_argnames``/``static_argnums``.  Functions entered via
``pallas_call`` are excluded — RPR005 owns kernel bodies.

Opt-in: mark closures the call graph cannot follow with
``# repro: traced`` on the ``def`` line.  Suppress a deliberate host
read with ``# repro: noqa[RPR001] <why>``.
"""
from __future__ import annotations

from typing import List

from repro.analysis import astutil
from repro.analysis.engine import ModuleContext
from repro.analysis.findings import Finding

RULE_ID = "RPR001"
SUMMARY = ("Python control flow / host coercion on traced values inside "
           "jit/scan-reached functions")


def check(ctx: ModuleContext) -> List[Finding]:
    out: List[Finding] = []
    for rec, info in ctx.traceindex.traced.items():
        if info.kind == "pallas_call":
            continue                    # RPR005 owns kernel bodies
        _, flags = astutil.taint_function(rec, info, ctx.imports)
        for flag in flags:
            out.append(ctx.finding(
                RULE_ID, flag.node,
                f"in `{rec.qualname}` (traced via {info.via}): "
                f"{flag.detail}"))
    return out
