"""RPR005 — Pallas kernel rules.

``kernels/tick_sim.py`` works because its author remembered three
non-obvious Pallas constraints; this rule remembers them for everyone
else.  For every function passed (possibly through
``functools.partial``) as the kernel of a ``pallas_call``:

* **No array-valued closures.**  A kernel body cannot capture a traced
  or array value from an enclosing scope — arrays must travel as
  kernel operands (this repo's idiom: replicated "extras" inputs).
  Flagged: any free variable of the kernel assigned in an enclosing
  function from an array-producing call (``jnp.*``, ``np.asarray`` /
  ``array`` / ``zeros`` / ``ones`` / ``arange``, …).  Python scalars
  bound through ``partial`` keywords are fine and idiomatic.
* **No ``np.*`` calls in the body.**  NumPy executes host-side at trace
  time; inside a kernel that silently constant-folds (or crashes on a
  ref).  Exempt: dtype introspection — ``np.issubdtype``, dtype
  constructors, ``np.ndim`` on static metadata.
* **No Python branching on ref-derived values.**  ``if``/``while`` on
  data loaded from a ref must become ``pl.when`` / ``jnp.where``;
  branching on static metadata (``.shape``/``.dtype``, keyword-only
  partial params like ``max_q``) is fine and untainted.
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis import astutil
from repro.analysis.engine import ModuleContext
from repro.analysis.findings import Finding

RULE_ID = "RPR005"
SUMMARY = ("pallas kernels: no array closures, no np.* in body, no "
           "Python branches on refs")

_NP_WHITELIST = {"issubdtype", "ndim", "result_type", "dtype", "bool_",
                 "float16", "float32", "float64", "int8", "int16",
                 "int32", "int64", "uint8", "uint32", "shape"}

_ARRAY_PRODUCERS = {"asarray", "array", "zeros", "ones", "arange",
                    "full", "empty", "linspace", "stack", "concatenate",
                    "eye", "zeros_like", "ones_like", "full_like",
                    "broadcast_to"}


def _array_valued(rhs: ast.AST, imports: astutil.ImportMap) -> bool:
    if isinstance(rhs, ast.Call):
        callee = imports.normalize(astutil.dotted_name(rhs.func))
        if not callee:
            return False
        root = callee.split(".")[0]
        last = callee.rsplit(".", 1)[-1]
        if root in ("jax",) and last not in ("jit",):
            return True
        if callee.startswith("jax.numpy") or callee.startswith("numpy"):
            return last in _ARRAY_PRODUCERS
    return False


def check(ctx: ModuleContext) -> List[Finding]:
    out: List[Finding] = []
    kernels = [(rec, info) for rec, info in ctx.traceindex.traced.items()
               if info.kind == "pallas_call"]
    for rec, info in kernels:
        # ---- array-valued closures
        enclosing = rec.parent
        if enclosing is not None:
            assigns = astutil.assignments_of(enclosing.node)
            for free in sorted(astutil.free_names(rec)):
                for rhs in assigns.get(free, ()):
                    if _array_valued(rhs, ctx.imports):
                        out.append(ctx.finding(
                            RULE_ID, rec.node,
                            f"kernel `{rec.qualname}` closes over "
                            f"array-valued `{free}` (assigned at line "
                            f"{rhs.lineno}) — pass it as a kernel "
                            "operand (replicated input) instead"))
                        break

        # ---- np.* calls in body
        for node in ast.walk(rec.node):
            if not isinstance(node, ast.Call):
                continue
            callee = ctx.imports.normalize(
                astutil.dotted_name(node.func))
            if callee and (callee == "numpy"
                           or callee.startswith("numpy.")):
                fn = callee.rsplit(".", 1)[-1]
                if fn not in _NP_WHITELIST:
                    out.append(ctx.finding(
                        RULE_ID, node,
                        f"`np.{fn}` inside kernel `{rec.qualname}` "
                        "executes host-side at trace time — use jnp "
                        "or hoist out of the kernel"))

        # ---- Python branches / coercions on ref-derived values
        _, flags = astutil.taint_function(rec, info, ctx.imports)
        for flag in flags:
            if flag.reason in ("branch", "coerce", "assert"):
                out.append(ctx.finding(
                    RULE_ID, flag.node,
                    f"in kernel `{rec.qualname}`: {flag.detail} — "
                    "use pl.when / jnp.where"))
    return out
