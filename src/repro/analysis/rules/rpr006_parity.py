"""RPR006 — backend-surface parity.

Three entry points drive the same co-simulation through different
engines: ``SimEngine`` (NumPy f64 reference), ``BatchSimEngine``
(numpy/jax/pallas backends) and ``core.dse.closed_loop_score`` (the DSE
bridge).  DS3-style multi-engine trust requires their *keyword
surfaces* for shared knobs to agree: a knob added to one surface and
forgotten on another silently no-ops — the sweep "runs with faults"
that the engine never simulated.

The contract is the :data:`PARITY` matrix below.  For each canonical
knob each surface is declared:

* ``accept`` — the signature must expose one of the listed parameter
  aliases (``faults`` / ``fault_schedule`` name the same knob);
* ``absent`` — the signature must NOT expose it (e.g. ``backend=`` on
  the reference engine is meaningless); adding the parameter without
  updating the matrix (and thinking about the other surfaces) is a
  finding in itself;
* ``refuse:<substring>`` — the surface's module must contain an
  explicit ``raise NotImplementedError`` whose message mentions the
  substring (the pallas path's loud refusals of faults/SLO/balancer/
  observer).

Drift in either direction is flagged.  Surfaces whose module is not
among the analyzed files are skipped, so single-file runs stay quiet.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis import astutil
from repro.analysis.engine import ModuleContext
from repro.analysis.findings import Finding

RULE_ID = "RPR006"
SUMMARY = ("engine keyword surfaces for shared knobs must agree or "
           "explicitly refuse")

KNOB_ALIASES: Dict[str, Tuple[str, ...]] = {
    "observe": ("observe",),
    "devices": ("devices",),
    "flows": ("flows",),
    "balancer": ("balancer", "balancer_factory"),
    "faults": ("faults", "fault_schedule"),
    "slo": ("slo",),
    "backend": ("backend",),
    # physical DVFS: engines take one `tech` spec (node or (node,
    # variant) pair); the sweep grid splits it into two axes
    "tech": ("tech", "tech_node"),
    "tech_variant": ("tech_variant",),
}

# (module suffix, qualname, {knob: "accept" | "absent" | "refuse:<sub>"})
PARITY: Tuple[Tuple[str, str, Dict[str, str]], ...] = (
    ("sim/engine.py", "SimEngine.__init__", {
        "observe": "accept",
        "balancer": "accept",
        "faults": "accept",
        "slo": "accept",
        "tech": "accept",
        # single-design host reference: sharding/backend selection and
        # flow synthesis are meaningless here by design; the scaling
        # variant rides inside the (node, variant) `tech` spec
        "devices": "absent",
        "flows": "absent",
        "backend": "absent",
        "tech_variant": "absent",
    }),
    ("sim/batch.py", "BatchSimEngine.__init__", {
        "observe": "accept",
        "balancer": "accept",
        "faults": "accept",
        "slo": "accept",
        "devices": "accept",
        "backend": "accept",
        "tech": "accept",
        # flow topology arrives through the platform, not per-run;
        # the variant rides inside the (node, variant) `tech` spec
        "flows": "absent",
        "tech_variant": "absent",
    }),
    ("core/dse.py", "closed_loop_score", {
        "observe": "accept",
        "balancer": "accept",
        "faults": "accept",
        "slo": "accept",
        "devices": "accept",
        "backend": "accept",
        "flows": "accept",
        "tech": "accept",
        "tech_variant": "absent",
    }),
    # the sweep grid is the one surface where node and variant are
    # separate AXES (cross-product knobs), not a single spec
    ("core/dse.py", "grid_sweep", {
        "tech": "accept",
        "tech_variant": "accept",
        "devices": "accept",
        "backend": "accept",
    }),
)

# loud refusals the pallas path must keep: (module suffix, message
# substring of a `raise NotImplementedError`)
REQUIRED_REFUSALS: Tuple[Tuple[str, str], ...] = (
    ("sim/batch.py", "fault schedules"),
    ("sim/batch.py", "SLO semantics"),
    ("sim/batch.py", "load balancer"),
    ("sim/batch.py", "observer plane"),
)


def _find_def(ctx: ModuleContext, qualname: str) -> Optional[ast.AST]:
    for rec in ctx.funcindex.records:
        if rec.qualname == qualname:
            return rec.node
    return None


def _param_names(node: ast.AST) -> List[str]:
    a = node.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def _refusal_strings(ctx: ModuleContext) -> List[str]:
    out: List[str] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Raise) and node.exc is not None:
            exc = node.exc
            name = None
            if isinstance(exc, ast.Call):
                name = astutil.dotted_name(exc.func)
                parts = [a.value for a in ast.walk(exc)
                         if isinstance(a, ast.Constant)
                         and isinstance(a.value, str)]
                msg = " ".join(parts)
            else:
                name = astutil.dotted_name(exc)
                msg = ""
            if name and name.rsplit(".", 1)[-1] == "NotImplementedError":
                out.append(msg)
    return out


def check_project(ctxs: Sequence[ModuleContext]) -> List[Finding]:
    out: List[Finding] = []
    by_suffix: Dict[str, ModuleContext] = {}
    for suffix, _, _ in PARITY:
        for ctx in ctxs:
            if ctx.relpath.endswith(suffix):
                by_suffix[suffix] = ctx
    for suffix, _sub in REQUIRED_REFUSALS:
        for ctx in ctxs:
            if ctx.relpath.endswith(suffix):
                by_suffix.setdefault(suffix, ctx)

    for suffix, qualname, spec in PARITY:
        ctx = by_suffix.get(suffix)
        if ctx is None:
            continue
        node = _find_def(ctx, qualname)
        if node is None:
            out.append(Finding(
                RULE_ID, ctx.relpath, 1,
                f"parity surface `{qualname}` not found in {suffix} — "
                "update the PARITY matrix in rpr006_parity.py"))
            continue
        params = set(_param_names(node))
        for knob, status in spec.items():
            aliases = KNOB_ALIASES[knob]
            present = [a for a in aliases if a in params]
            if status == "accept" and not present:
                out.append(Finding(
                    RULE_ID, ctx.relpath, node.lineno,
                    f"`{qualname}` must accept knob `{knob}` (one of "
                    f"{', '.join(aliases)}) to stay in parity with the "
                    "other engines — or declare it absent/refused in "
                    "the PARITY matrix"))
            elif status == "absent" and present:
                out.append(Finding(
                    RULE_ID, ctx.relpath, node.lineno,
                    f"`{qualname}` grew knob `{present[0]}` that the "
                    "parity matrix declares absent — update the PARITY "
                    "matrix and decide what the other surfaces do "
                    "with it"))
        # knobs present in the signature but missing from the spec row
        for knob, aliases in KNOB_ALIASES.items():
            if knob in spec:
                continue
            present = [a for a in aliases if a in params]
            if present:
                out.append(Finding(
                    RULE_ID, ctx.relpath, node.lineno,
                    f"`{qualname}` exposes shared knob "
                    f"`{present[0]}` that is not declared in the "
                    "PARITY matrix — declare it for every surface"))

    for suffix, substring in REQUIRED_REFUSALS:
        ctx = by_suffix.get(suffix)
        if ctx is None:
            continue
        if not any(substring in msg for msg in _refusal_strings(ctx)):
            out.append(Finding(
                RULE_ID, ctx.relpath, 1,
                f"expected an explicit `raise NotImplementedError` "
                f"mentioning '{substring}' in {suffix} — the pallas "
                "path must refuse unsupported knobs loudly, not "
                "silently ignore them"))
    return out
