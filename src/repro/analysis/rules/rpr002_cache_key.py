"""RPR002 — jit-cache-key completeness.

A hand-rolled jit cache — a lookup like ``self._cached_scan(sig,
build)`` where ``build`` returns a jitted closure — is only sound if
``sig`` keys **every** Python-level value the traced function bakes in.
PR 8 root-caused exactly this bug: the original key was ``(T, ci,
fault_flag)`` and collided on ``dt``, controller tuning, balancer
layout, SLO mode and config scalars, silently reusing stale compiled
scans.

The check, per cache call site:

1. *Key closure* — names reachable from the key expression.  Expansion
   follows tuple/list literals (keying a tuple keys its elements),
   helper calls (passing ``x`` to a ``*_sig``/digest helper counts as
   keying ``x``) and plain aliases, but **stops at lossy expressions**:
   keying ``deadline_ticks = slo.deadline_s / dt`` does not key ``dt``
   (the ``None`` arm would erase it — the PR 8 bug shape).
2. *Required set* — free variables of the traced function the builder
   returns (nested defs included; frees that resolve to sibling local
   defs are expanded recursively).
3. A free is satisfied if it is in the key closure, or every
   derivation root is ``self`` / a module-level constant / itself
   satisfied.  ``self``-rooted values are exempt because the cache
   dict is per-instance and every mutable ``self`` ingredient must be
   digested explicitly (``_policy_digest`` / ``_balancer_digest`` are
   in the key); values rooted in a non-``self`` parameter of the
   enclosing function (``trace`` → ``dt``) must appear in the key.

Cache call sites are recognized by name: a call whose callee's last
component contains ``cache`` (``_cached_scan``, ``cache_lookup``, …)
with one argument resolving to a local builder function and another
being the key expression.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis import astutil
from repro.analysis.engine import ModuleContext
from repro.analysis.findings import Finding

RULE_ID = "RPR002"
SUMMARY = ("hand-rolled jit caches must key every non-tensor value "
           "reaching the traced function")


def _key_closure(expr: ast.AST, assigns: Dict[str, List[ast.expr]],
                 ) -> Set[str]:
    """Names keyed by ``expr`` (transitive through injective shapes)."""
    keyed: Set[str] = set()
    work: List[ast.AST] = [expr]
    seen_names: Set[str] = set()
    while work:
        e = work.pop()
        if isinstance(e, ast.Name):
            if e.id in seen_names:
                continue
            seen_names.add(e.id)
            keyed.add(e.id)
            for rhs in assigns.get(e.id, ()):
                work.append(rhs)
        elif isinstance(e, (ast.Tuple, ast.List, ast.Set)):
            work.extend(e.elts)
        elif isinstance(e, ast.Dict):
            work.extend(k for k in e.keys if k is not None)
            work.extend(e.values)
        elif isinstance(e, ast.Call):
            # digest/helper semantics: every argument fed to the helper
            # is considered keyed (the helper exists to fold it in)
            work.extend(e.args)
            work.extend(kw.value for kw in e.keywords)
        elif isinstance(e, ast.Starred):
            work.append(e.value)
        elif isinstance(e, ast.IfExp):
            # both arms of a conditional ALIAS (x if c else y) are keyed,
            # but the test is not necessarily recoverable — treat as
            # lossy for the test, injective for the arms only when both
            # are names/containers; simplest sound choice: stop here.
            pass
        # every other expression shape (BinOp, Attribute, Subscript,
        # Compare, Constant, ...) is lossy: stop.
    return keyed


def _covered(name: str, keyed: Set[str],
             assigns: Dict[str, List[ast.expr]], params: Set[str],
             memo: Dict[str, bool], visiting: Set[str]) -> bool:
    if name in keyed or name == "self":
        return True
    if name in memo:
        return memo[name]
    if name in visiting:
        return True                      # cycle: optimistic
    if name in params:
        memo[name] = False               # un-keyed non-self parameter
        return False
    rhss = assigns.get(name)
    if not rhss:
        memo[name] = True                # module-level / import / builtin
        return True
    visiting.add(name)
    ok = all(
        _covered(r, keyed, assigns, params, memo, visiting)
        for rhs in rhss for r in sorted(astutil.name_loads(rhs)))
    visiting.discard(name)
    memo[name] = ok
    return ok


def _uncovered_roots(name: str, assigns: Dict[str, List[ast.expr]],
                     params: Set[str], keyed: Set[str]) -> Set[str]:
    """Human-readable culprit roots for the finding message."""
    bad: Set[str] = set()
    seen: Set[str] = set()
    work = [name]
    while work:
        n = work.pop()
        if n in seen or n in keyed or n == "self":
            continue
        seen.add(n)
        if n in params:
            bad.add(n)
            continue
        for rhs in assigns.get(n, ()):
            work.extend(astutil.name_loads(rhs))
    return bad


def _resolve_builder(arg: ast.AST, scope, index: astutil.FunctionIndex,
                     ) -> Optional[astutil.FunctionRecord]:
    if isinstance(arg, ast.Name):
        rec = index.lookup(scope, arg.id)
        if rec is not None:
            return rec
    return None


def _traced_from_builder(builder: astutil.FunctionRecord,
                         ctx: ModuleContext,
                         ) -> List[astutil.FunctionRecord]:
    """Functions the builder's return statements jit-wrap."""
    trace = ctx.traceindex
    out: List[astutil.FunctionRecord] = []
    for node in ast.walk(builder.node):
        if isinstance(node, ast.Return) and node.value is not None:
            rec = trace._resolve_fn_arg(node.value, builder)
            if rec is not None and rec not in out:
                out.append(rec)
    return out


def check(ctx: ModuleContext) -> List[Finding]:
    out: List[Finding] = []
    index = ctx.funcindex
    for call in ast.walk(ctx.tree):
        if not isinstance(call, ast.Call) or len(call.args) < 2:
            continue
        callee = astutil.dotted_name(call.func)
        if not callee or "cache" not in callee.rsplit(".", 1)[-1].lower():
            continue
        scope = ctx.traceindex._enclosing_function(call)
        if scope is None:
            continue
        builder = None
        key_expr = None
        for arg in call.args:
            rec = _resolve_builder(arg, scope, index)
            if rec is not None and builder is None and \
                    _traced_from_builder(rec, ctx):
                builder = rec
            elif key_expr is None:
                key_expr = arg
        if builder is None or key_expr is None:
            continue

        assigns = astutil.assignments_of(scope.node)
        params = set(scope.all_params()) - {"self", "cls"}
        keyed = _key_closure(key_expr, assigns)

        # required frees: traced fns returned by the builder, expanding
        # frees that resolve to sibling local defs (lb_split, voltage2)
        required: Set[str] = set()
        work = list(_traced_from_builder(builder, ctx))
        seen_fns = set()
        while work:
            fn = work.pop()
            if fn in seen_fns:
                continue
            seen_fns.add(fn)
            for free in astutil.free_names(fn):
                sub = index.lookup(scope, free)
                if sub is not None and sub.parent is scope:
                    work.append(sub)
                else:
                    required.add(free)

        memo: Dict[str, bool] = {}
        for free in sorted(required):
            if not _covered(free, keyed, assigns, params, memo, set()):
                roots = _uncovered_roots(free, assigns, params, keyed)
                via = (f" (derived from parameter "
                       f"{', '.join(sorted(roots))})" if roots else "")
                out.append(ctx.finding(
                    RULE_ID, call,
                    f"`{free}` is baked into the traced function built "
                    f"by `{builder.name}` but missing from the cache "
                    f"key{via} — stale compilations will be reused"))
    return out
