"""Findings, fingerprints, noqa suppression, and baseline I/O.

A finding's *fingerprint* is content-addressed, not line-addressed:
``sha1(rule | relpath | stripped source line | occurrence index)``.
Inserting code above a baselined finding therefore does not invalidate
the baseline; editing the offending line does — which is exactly when a
human should re-look.

Inline suppression::

    something_flagged()  # repro: noqa[RPR003] registry by design

The justification text after the bracket is mandatory: a bare
``# repro: noqa[RPR003]`` is itself reported as RPR000 so suppressions
stay auditable.
"""
from __future__ import annotations

import hashlib
import io
import json
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

NOQA_RE = re.compile(
    r"#\s*repro:\s*noqa\[(?P<rules>RPR\d{3}(?:\s*,\s*RPR\d{3})*)\]"
    r"(?P<just>.*)$")


@dataclass(frozen=True)
class Finding:
    rule: str                # "RPR001" ... "RPR006", "RPR000", "BENCH001"
    path: str                # repo-relative, posix separators
    line: int                # 1-based
    message: str
    fingerprint: str = ""
    suppressed: bool = False
    justification: str = ""

    def location(self) -> str:
        return f"{self.path}:{self.line}"

    def to_dict(self) -> Dict[str, object]:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "message": self.message,
            "fingerprint": self.fingerprint,
        }


def fingerprint(rule: str, relpath: str, line_text: str,
                occurrence: int) -> str:
    payload = f"{rule}|{relpath}|{line_text.strip()}|{occurrence}"
    return hashlib.sha1(payload.encode("utf-8")).hexdigest()[:16]


def assign_fingerprints(findings: Sequence[Finding],
                        source_lines_by_path: Dict[str, Sequence[str]],
                        ) -> List[Finding]:
    """Fill the fingerprint field, disambiguating identical lines by
    occurrence order within (rule, path, stripped-line-text)."""
    counts: Dict[Tuple[str, str, str], int] = {}
    out: List[Finding] = []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule)):
        lines = source_lines_by_path.get(f.path, [])
        text = lines[f.line - 1] if 0 < f.line <= len(lines) else ""
        key = (f.rule, f.path, text.strip())
        occ = counts.get(key, 0)
        counts[key] = occ + 1
        out.append(Finding(
            rule=f.rule, path=f.path, line=f.line, message=f.message,
            fingerprint=fingerprint(f.rule, f.path, text, occ),
            suppressed=f.suppressed, justification=f.justification))
    return out


def extract_comments(source: str) -> Dict[int, str]:
    """line number -> comment text (``#`` included) for *real* comment
    tokens only — noqa syntax quoted inside docstrings (e.g. this
    package's own documentation) must not act as a suppression."""
    out: Dict[int, str] = {}
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                out[tok.start[0]] = tok.string
    except (tokenize.TokenError, IndentationError, SyntaxError):
        pass
    return out


def parse_noqa(comment: str) -> Optional[Tuple[Set[str], str]]:
    """``(rule ids, justification)`` for a ``# repro: noqa[...]``
    comment, or None.  Empty justification is returned as "" (caller
    flags it)."""
    m = NOQA_RE.search(comment)
    if not m:
        return None
    rules = {r.strip() for r in m.group("rules").split(",")}
    return rules, m.group("just").strip()


def apply_noqa(findings: Sequence[Finding],
               comments_by_path: Dict[str, Dict[int, str]],
               ) -> List[Finding]:
    """Mark suppressed findings; emit RPR000 for justification-less or
    unused-rule noqa comments so suppressions stay honest."""
    out: List[Finding] = []
    used: Set[Tuple[str, int, str]] = set()
    for f in findings:
        comment = comments_by_path.get(f.path, {}).get(f.line, "")
        parsed = parse_noqa(comment)
        if parsed and f.rule in parsed[0]:
            used.add((f.path, f.line, f.rule))
            out.append(Finding(
                rule=f.rule, path=f.path, line=f.line, message=f.message,
                fingerprint=f.fingerprint, suppressed=True,
                justification=parsed[1]))
        else:
            out.append(f)
    # audit the noqa comments themselves
    for path, comments in comments_by_path.items():
        for i, comment in sorted(comments.items()):
            parsed = parse_noqa(comment)
            if not parsed:
                continue
            rules, just = parsed
            if not just:
                out.append(Finding(
                    rule="RPR000", path=path, line=i,
                    message="`# repro: noqa[...]` requires a "
                            "justification after the bracket"))
            for r in sorted(rules):
                if (path, i, r) not in used and not any(
                        f.path == path and f.line == i and f.rule == r
                        for f in findings):
                    out.append(Finding(
                        rule="RPR000", path=path, line=i,
                        message=f"noqa[{r}] suppresses nothing on this "
                                "line — remove or fix the rule id"))
    return out


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------

BASELINE_VERSION = 1


def load_baseline(path: Path) -> Set[str]:
    """Fingerprints accepted by the checked-in baseline."""
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(
            f"baseline {path} has version {data.get('version')!r}, "
            f"expected {BASELINE_VERSION}")
    return {f["fingerprint"] for f in data.get("findings", [])}


def save_baseline(path: Path, findings: Iterable[Finding]) -> None:
    rows = [f.to_dict() for f in findings if not f.suppressed]
    rows.sort(key=lambda r: (r["path"], r["line"], r["rule"]))
    path.write_text(json.dumps(
        {"version": BASELINE_VERSION, "findings": rows},
        indent=2, sort_keys=False) + "\n")


def split_by_baseline(findings: Sequence[Finding], accepted: Set[str],
                      ) -> Tuple[List[Finding], List[Finding]]:
    """(new, baselined) — suppressed findings are excluded from both."""
    new: List[Finding] = []
    old: List[Finding] = []
    for f in findings:
        if f.suppressed:
            continue
        (old if f.fingerprint and f.fingerprint in accepted
         else new).append(f)
    return new, old
