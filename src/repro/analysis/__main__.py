"""CLI entry point: ``python -m repro.analysis``.

Exit code 0 when every finding is baselined or suppressed; 1 when new
findings exist (this is what the CI gate keys on); 2 on usage errors.

Common invocations::

    python -m repro.analysis src/repro                 # full run
    python -m repro.analysis --format json --bench     # CI gate
    python -m repro.analysis --changed-only            # fast local loop
    python -m repro.analysis --write-baseline src/repro  # accept current
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path
from typing import List, Optional

from repro.analysis.engine import analyze_paths
from repro.analysis.findings import Finding, load_baseline, save_baseline

DEFAULT_BASELINE = "analysis/baseline.json"
DEFAULT_PATHS = ("src/repro",)


def _repo_root(start: Path) -> Path:
    cur = start.resolve()
    for cand in (cur, *cur.parents):
        if (cand / ".git").exists() or \
                (cand / DEFAULT_BASELINE).exists():
            return cand
    return start


def _changed_files(root: Path) -> Optional[List[Path]]:
    """Python files changed vs. HEAD (staged + unstaged + untracked)."""
    try:
        out = subprocess.run(
            ["git", "-C", str(root), "status", "--porcelain"],
            capture_output=True, text=True, check=True, timeout=30)
    except (OSError, subprocess.SubprocessError):
        return None
    files: List[Path] = []
    for line in out.stdout.splitlines():
        name = line[3:].split(" -> ")[-1].strip().strip('"')
        p = root / name
        # untracked directories surface as one `?? dir/` entry — pass
        # them through whole; iter_python_files expands directories
        if (name.endswith(".py") or name.endswith("/")) and p.exists():
            files.append(p)
    return files


def _print_text(report, *, bench_findings: List[Finding]) -> None:
    def show(f: Finding, tag: str) -> None:
        print(f"{f.location()}: {f.rule} [{tag}] {f.message}")

    for f in report.new:
        show(f, "new")
    for f in bench_findings:
        show(f, "new")
    if report.baselined:
        print(f"-- {len(report.baselined)} baselined finding(s) "
              "(see analysis/baseline.json)")
    if report.suppressed:
        print(f"-- {len(report.suppressed)} suppressed via "
              "# repro: noqa")
    total_new = len(report.new) + len(bench_findings)
    print(f"{report.modules} module(s) analyzed, {total_new} new, "
          f"{len(report.baselined)} baselined, "
          f"{len(report.suppressed)} suppressed")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST-based JAX/Pallas invariant linter "
                    "(rules RPR001-RPR006; see repro.analysis docs)")
    ap.add_argument("paths", nargs="*",
                    help=f"files/dirs to analyze (default: "
                         f"{' '.join(DEFAULT_PATHS)} under the repo "
                         "root)")
    ap.add_argument("--format", choices=("text", "json"),
                    default="text")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline file (default: {DEFAULT_BASELINE} "
                         "under the repo root; 'none' disables)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept all current findings into the "
                         "baseline file and exit 0")
    ap.add_argument("--changed-only", action="store_true",
                    help="analyze only files changed vs. git HEAD "
                         "(fast local loop; RPR006 parity checks run "
                         "only over the changed set)")
    ap.add_argument("--bench", action="store_true",
                    help="also run the BENCH001 trajectory gate over "
                         "the repo's BENCH_*.json files")
    ap.add_argument("--out", default=None,
                    help="write the (JSON) report to this file as well")
    ap.add_argument("--root", default=None,
                    help="repo root override (defaults to the nearest "
                         "ancestor with .git or the baseline file)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run "
                         "(e.g. RPR003,RPR006)")
    args = ap.parse_args(argv)

    root = Path(args.root).resolve() if args.root else \
        _repo_root(Path.cwd())

    if args.changed_only:
        changed = _changed_files(root)
        if changed is None:
            print("--changed-only: git unavailable, analyzing default "
                  "paths", file=sys.stderr)
            paths = [root / p for p in DEFAULT_PATHS]
        elif not changed:
            print("--changed-only: no changed python files")
            return 0
        else:
            paths = changed
    elif args.paths:
        paths = [Path(p) for p in args.paths]
    else:
        paths = [root / p for p in DEFAULT_PATHS]
    missing = [p for p in paths if not p.exists()]
    if missing:
        print(f"error: no such path(s): "
              f"{', '.join(str(p) for p in missing)}", file=sys.stderr)
        return 2

    rules = None
    if args.rules:
        from repro.analysis.rules import get_rules
        try:
            rules = get_rules(args.rules.split(","))
        except ValueError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2

    baseline_path = None
    accepted = set()
    if args.baseline != "none":
        baseline_path = (Path(args.baseline) if args.baseline
                         else root / DEFAULT_BASELINE)
        try:
            accepted = load_baseline(baseline_path)
        except ValueError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2

    report = analyze_paths(paths, root=root, baseline=accepted,
                           rules=rules)

    if args.write_baseline:
        if baseline_path is None:
            print("error: --write-baseline with --baseline none",
                  file=sys.stderr)
            return 2
        baseline_path.parent.mkdir(parents=True, exist_ok=True)
        keep = [f for f in report.findings if not f.suppressed]
        save_baseline(baseline_path, keep)
        print(f"wrote {len(keep)} finding(s) to {baseline_path}")
        return 0

    bench_findings: List[Finding] = []
    if args.bench:
        from repro.analysis.bench import check_trajectories
        bench_findings = check_trajectories(root)

    if args.format == "json" or args.out:
        doc = report.to_dict()
        doc["bench"] = [f.to_dict() for f in bench_findings]
        doc["counts"]["new"] += len(bench_findings)
        payload = json.dumps(doc, indent=2)
        if args.format == "json":
            print(payload)
        if args.out:
            Path(args.out).write_text(payload + "\n")
    if args.format == "text":
        _print_text(report, bench_findings=bench_findings)

    return 1 if (report.new or bench_findings) else 0


if __name__ == "__main__":
    sys.exit(main())
