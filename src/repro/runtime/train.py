"""Training runtime: jitted train step + the Trainer driver loop.

The step function is THE artifact the multi-pod dry-run lowers, so all
sharding decisions live here:

* params/opt-state shardings come from the TilePlan via core.replication
  (MRA-aware rules),
* batch enters sharded over the data axes,
* C3 monitor counters ride through the step as donated state,
* remat (scan-body checkpointing) keeps train_4k activation memory flat in
  depth,
* microbatch gradient accumulation (``accum``) trades step latency for
  memory and overlaps the per-microbatch gradient reduce with the next
  microbatch's compute (scan-carried partial sums).

The Trainer wires in the Vespa runtime loop: monitor reads, DFS actuator
commits between steps (hitless reconfig), async checkpoints, fault hooks.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import monitor as mon
from repro.core.dfs import DFSActuator
from repro.core.islands import IslandConfig, default_islands
from repro.core.replication import data_axes, merged_rules
from repro.core.tiles import TilePlan, default_plan
from repro.data.pipeline import SyntheticLM, for_arch
from repro.models.params import pspecs_for, shardings_for
from repro.models.transformer import LM
from repro.optim import adamw


@dataclass
class TrainConfig:
    accum: int = 1                     # microbatch accumulation factor
    log_every: int = 10
    ckpt_every: int = 0                # 0 = disabled
    ckpt_dir: str = "/tmp/vespa_ckpt"
    monitor_every: int = 10
    grad_reduce_dtype: str = ""        # "bf16": cast grads before the
                                       # cross-device reduce (2x wire bytes)
    opt: adamw.AdamWConfig = field(default_factory=adamw.AdamWConfig)


def _batch_pspec(batch_tree, dp) -> Any:
    return jax.tree_util.tree_map(
        lambda v: P(dp) if getattr(v, "ndim", 0) >= 1 else P(), batch_tree)


def make_train_step(lm: LM, plan: TilePlan, mesh: Optional[Mesh],
                    tc: TrainConfig, grad_pspecs=None) -> Callable:
    """Build the (un-jitted) train step; the caller jits with shardings.

    ``grad_pspecs``: PartitionSpec tree matching params — constraining each
    gradient leaf to its parameter's sharding makes GSPMD reduce-scatter
    gradients to their shards instead of all-reducing the full tensors
    (§Perf lever; ~2x wire bytes on the grad reduction, 4x with bf16).
    """
    cfg = lm.cfg

    def _treat_grads(grads):
        if tc.grad_reduce_dtype == "bf16":
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.bfloat16), grads)
        if grad_pspecs is not None:
            grads = jax.tree_util.tree_map(
                lambda g, ps: jax.lax.with_sharding_constraint(g, ps),
                grads, grad_pspecs)
        return grads

    def loss_of(params, microbatch):
        loss, parts = lm.loss_fn(params, microbatch)
        return loss, parts

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    # static per-step NoC/mem traffic (charged to C3 counters)
    def charge_counters(counters, batch, gnorm):
        toks = np.prod(batch["labels"].shape)
        n_params = cfg.n_params()
        # DP gradient ring all-reduce bytes per device (bf16)
        from repro.core.noc import collective_bytes_ring_allreduce
        dp_sz = 1
        if mesh is not None:
            for a in ("pod", "data"):
                if a in mesh.axis_names:
                    dp_sz *= mesh.shape[a]
        grad_bytes = collective_bytes_ring_allreduce(2.0 * n_params, dp_sz)
        counters = mon.charge(counters, "noc",
                              pkts_in=mon.pkts(grad_bytes),
                              pkts_out=mon.pkts(grad_bytes))
        # optimizer reads params+m+v, writes params+m+v (f32 m/v, bf16 p)
        opt_bytes = n_params * (2 + 4 + 4) * 2
        counters = mon.charge(counters, "mem",
                              pkts_in=mon.pkts(opt_bytes / 2),
                              pkts_out=mon.pkts(opt_bytes / 2))
        counters = mon.charge(counters, "io", exec_time=jnp.asarray(toks, jnp.float32))
        for t in plan.tiles:
            if t.kind in ("attn", "ffn", "moe", "ssm", "shared_attn"):
                counters = mon.charge(counters, t.name, exec_time=gnorm * 0 + 1.0)
        return counters

    def train_step(params, opt_state, batch, counters):
        if tc.accum <= 1:
            (loss, parts), grads = grad_fn(params, batch)
            grads = _treat_grads(grads)
        else:
            # split batch into microbatches along the batch dim and scan;
            # the per-microbatch grad psum overlaps the next microbatch
            def micro(carry, mb):
                acc, = carry
                (l, p), g = grad_fn(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return (acc,), (l, p)

            def split(v):
                b = v.shape[0]
                return v.reshape((tc.accum, b // tc.accum) + v.shape[1:])
            mbs = jax.tree_util.tree_map(split, batch)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum,), (ls, parts_all) = jax.lax.scan(micro, (zero,), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / tc.accum, gsum)
            loss = jnp.mean(ls)
            parts = jax.tree_util.tree_map(jnp.mean, parts_all)

        new_params, new_opt, om = adamw.update(tc.opt, grads, opt_state, params)
        counters = charge_counters(counters, batch, om["grad_norm"])
        metrics = {"loss": loss, **parts, **om}
        return new_params, new_opt, counters, metrics

    return train_step


class Trainer:
    """End-to-end training driver (examples/ use this)."""

    def __init__(self, cfg: ArchConfig, shape: ShapeConfig, *,
                 mesh: Optional[Mesh] = None, tc: Optional[TrainConfig] = None,
                 plan: Optional[TilePlan] = None,
                 islands: Optional[IslandConfig] = None,
                 lm_kwargs: Optional[Dict] = None, seed: int = 0):
        self.cfg = cfg
        self.shape = shape
        self.mesh = mesh
        self.tc = tc or TrainConfig()
        self.plan = plan or default_plan(cfg)
        self.islands = islands or default_islands(self.plan)
        self.actuator = DFSActuator(self.islands)
        self.monitor = mon.MonitorClient()
        self.lm = LM(cfg, **(lm_kwargs or {}))
        self.data = for_arch(cfg, shape, seed=seed)
        self.step = 0
        self._store = None

        key = jax.random.PRNGKey(seed)
        specs = self.lm.param_specs()
        if mesh is not None:
            rules = merged_rules(self.plan, mesh)
            self.param_sh = shardings_for(specs, rules, mesh)
            init_fn = jax.jit(self.lm.init, out_shardings=self.param_sh)
            self.params = init_fn(key)
        else:
            self.param_sh = None
            self.params = self.lm.init(key)
        self.opt_state = adamw.init(self.params)
        self.counters = mon.init_counters(self.plan)
        # abstract template so restore works even after total state loss
        self._template = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
            self.state_tree())
        self._dp = data_axes(mesh, self.plan) if mesh is not None else ()

        step_fn = make_train_step(self.lm, self.plan, mesh, self.tc)
        if mesh is not None:
            self._step = jax.jit(step_fn, donate_argnums=(0, 1, 3))
        else:
            self._step = jax.jit(step_fn, donate_argnums=(0, 1, 3))

    # ------------------------------------------------------------------ ckpt
    def store(self):
        from repro.checkpoint.store import CheckpointStore
        if self._store is None:
            self._store = CheckpointStore(self.tc.ckpt_dir)
        return self._store

    def state_tree(self):
        return {"params": self.params, "opt": self.opt_state,
                "step": jnp.asarray(self.step, jnp.int32)}

    def save(self, async_: bool = True):
        t = self.state_tree()
        (self.store().save_async if async_ else self.store().save)(self.step, t)

    def restore(self, step: Optional[int] = None):
        """Elastic restore: target shardings come from the CURRENT mesh/plan,
        which may differ from the one that saved (Vespa reconfig path)."""
        like = self._template
        shardings = None
        if self.param_sh is not None:
            opt_sh = adamw.AdamWState(
                step=NamedSharding(self.mesh, P()),
                mu=self.param_sh, nu=self.param_sh)
            shardings = {"params": self.param_sh, "opt": opt_sh,
                         "step": NamedSharding(self.mesh, P())}
        t = self.store().restore(like, step=step, shardings=shardings)
        self.params, self.opt_state = t["params"], t["opt"]
        self.step = int(t["step"])

    # ------------------------------------------------------------------ loop
    def place_batch(self, np_batch):
        if self.mesh is None:
            return {k: jnp.asarray(v) for k, v in np_batch.items()}
        from repro.data.pipeline import device_put_batch
        return device_put_batch(np_batch, self.mesh, self._dp)

    def run(self, steps: int, on_metrics: Optional[Callable] = None):
        history = []
        for _ in range(steps):
            nb = self.data.batch_at(self.step)
            batch = self.place_batch(nb)
            self.params, self.opt_state, self.counters, m = self._step(
                self.params, self.opt_state, batch, self.counters)
            self.step += 1
            if self.tc.monitor_every and self.step % self.tc.monitor_every == 0:
                self.monitor.read(self.counters, self.step)
            if self.tc.ckpt_every and self.step % self.tc.ckpt_every == 0:
                self.save()
            # DFS hitless commit point: between steps, never mid-step
            self.islands = self.actuator.commit()
            if self.tc.log_every and self.step % self.tc.log_every == 0:
                mm = {k: float(v) for k, v in m.items()}
                history.append((self.step, mm))
                if on_metrics:
                    on_metrics(self.step, mm)
        if self._store is not None:
            self._store.wait()
        return history
