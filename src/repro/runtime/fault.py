"""Fault tolerance: detection, restart, stragglers, elastic rescale.

At 1000+-node scale the failure model is: a host dies (lose its devices), a
step hangs (network partition / straggler), or the numerics blow up.  The
responses, all built on substrate already in this repo:

* **checkpoint/restart** — deterministic data pipeline + CheckpointStore
  restore make recovery exact: ``recover()`` reloads the latest complete
  checkpoint and replays from its step counter.  Tested by killing a
  Trainer mid-run and asserting bitwise-equal loss curves.
* **straggler mitigation** — C3 exec-time telemetry feeds
  ``core.dfs.policy_straggler``; the actuator derates healthy islands (or
  the scheduler reroutes microbatches) without a global stop, via the
  dual-buffer hitless commit.
* **elastic rescale** — a checkpoint saved on mesh A restores onto mesh B
  (CheckpointStore.restore(shardings=...)); the pipeline's counter-based
  batches repartition with no coordination.  Losing a DP replica is a
  rescale from (pod=2) to (pod=1).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.dfs import DFSActuator, TileTelemetry, policy_straggler
from repro.core.islands import IslandConfig


@dataclass
class FaultConfig:
    step_timeout_s: float = 300.0
    nan_tolerance: int = 0           # consecutive NaN losses allowed
    straggler_slack: float = 1.3
    max_restarts: int = 5


@dataclass
class FaultEvent:
    step: int
    kind: str                        # timeout | nan | node_loss | straggler
    detail: str = ""


class FaultSupervisor:
    """Wraps a Trainer-like object with detection + recovery."""

    def __init__(self, trainer, fc: Optional[FaultConfig] = None):
        self.trainer = trainer
        self.fc = fc or FaultConfig()
        self.events: List[FaultEvent] = []
        self._nan_streak = 0
        self.restarts = 0

    # -------------------------------------------------------------- detect
    def check_metrics(self, step: int, metrics: Dict[str, float]) -> Optional[str]:
        loss = metrics.get("loss", 0.0)
        if not math.isfinite(loss):
            self._nan_streak += 1
            if self._nan_streak > self.fc.nan_tolerance:
                return "nan"
        else:
            self._nan_streak = 0
        return None

    def check_stragglers(self, telemetry: Dict[str, TileTelemetry],
                         islands: IslandConfig, actuator: DFSActuator
                         ) -> Optional[Dict[str, float]]:
        """Derate-to-match policy; returns the applied rates (or None)."""
        if not telemetry:
            return None
        times = [t.exec_time for t in telemetry.values()]
        med = float(np.median(times))
        if med <= 0 or max(times) <= self.fc.straggler_slack * med:
            return None
        rates = policy_straggler(islands, telemetry,
                                 slack=self.fc.straggler_slack)
        actuator.reconfigure(rates)          # shadow buffer
        actuator.commit()                    # hitless swap between steps
        self.events.append(FaultEvent(
            getattr(self.trainer, "step", -1), "straggler", str(rates)))
        return rates

    # -------------------------------------------------------------- recover
    def recover(self) -> int:
        """Restore the latest complete checkpoint; returns the resume step."""
        if self.restarts >= self.fc.max_restarts:
            raise RuntimeError("restart budget exhausted")
        self.restarts += 1
        self.trainer.restore()
        self.events.append(FaultEvent(self.trainer.step, "restart"))
        return self.trainer.step

    def run_supervised(self, steps: int) -> List[Tuple[int, Dict[str, float]]]:
        """Training loop with NaN/timeout detection and auto-restart."""
        done = 0
        history: List[Tuple[int, Dict[str, float]]] = []
        while done < steps:
            try:
                hist = self.trainer.run(1)
            except FloatingPointError as e:   # pragma: no cover
                self.events.append(FaultEvent(self.trainer.step, "nan", str(e)))
                self.recover()
                continue
            done += 1
            for s, m in hist:
                history.append((s, m))
                kind = self.check_metrics(s, m)
                if kind:
                    self.events.append(FaultEvent(s, kind))
                    self.recover()
        return history
