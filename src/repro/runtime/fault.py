"""Fault tolerance: detection, restart, stragglers, elastic rescale.

At 1000+-node scale the failure model is: a host dies (lose its devices), a
step hangs (network partition / straggler), or the numerics blow up.  The
responses, all built on substrate already in this repo:

* **checkpoint/restart** — deterministic data pipeline + CheckpointStore
  restore make recovery exact: ``recover()`` reloads the latest complete
  checkpoint and replays from its step counter.  Tested by killing a
  Trainer mid-run and asserting bitwise-equal loss curves.
* **straggler mitigation** — C3 exec-time telemetry feeds
  ``core.dfs.policy_straggler``; the actuator derates healthy islands (or
  the scheduler reroutes microbatches) without a global stop, via the
  dual-buffer hitless commit.
* **elastic rescale** — a checkpoint saved on mesh A restores onto mesh B
  (CheckpointStore.restore(shardings=...)); the pipeline's counter-based
  batches repartition with no coordination.  Losing a DP replica is a
  rescale from (pod=2) to (pod=1).

The second half of this module is the *co-sim* side of the same story:
:class:`SimFaultSupervisor` watches the closed-loop simulator's per-tick
observables (served work, backlog, masked capacity) through an
:class:`OnlineFaultDetector` and maintains a **believed** availability
mask — the sequential ``SimEngine`` routes recovery traffic on the
supervisor's *detected* state rather than the injected oracle mask, so
detection latency (a few ticks of mis-routed work) is part of what the
scenario gates measure.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.dfs import DFSActuator, TileTelemetry, policy_straggler
from repro.core.islands import IslandConfig


@dataclass
class FaultConfig:
    step_timeout_s: float = 300.0
    nan_tolerance: int = 0           # consecutive NaN losses allowed
    straggler_slack: float = 1.3
    max_restarts: int = 5


@dataclass
class FaultEvent:
    step: int
    kind: str                        # timeout | nan | node_loss | straggler
    detail: str = ""


class FaultSupervisor:
    """Wraps a Trainer-like object with detection + recovery."""

    def __init__(self, trainer, fc: Optional[FaultConfig] = None):
        self.trainer = trainer
        self.fc = fc or FaultConfig()
        self.events: List[FaultEvent] = []
        self._nan_streak = 0
        self.restarts = 0

    # -------------------------------------------------------------- detect
    def check_metrics(self, step: int, metrics: Dict[str, float]) -> Optional[str]:
        loss = metrics.get("loss", 0.0)
        if not math.isfinite(loss):
            self._nan_streak += 1
            if self._nan_streak > self.fc.nan_tolerance:
                return "nan"
        else:
            self._nan_streak = 0
        return None

    def check_stragglers(self, telemetry: Dict[str, TileTelemetry],
                         islands: IslandConfig, actuator: DFSActuator
                         ) -> Optional[Dict[str, float]]:
        """Derate-to-match policy; returns the applied rates (or None)."""
        if not telemetry:
            return None
        times = [t.exec_time for t in telemetry.values()]
        med = float(np.median(times))
        if med <= 0 or max(times) <= self.fc.straggler_slack * med:
            return None
        rates = policy_straggler(islands, telemetry,
                                 slack=self.fc.straggler_slack)
        actuator.reconfigure(rates)          # shadow buffer
        actuator.commit()                    # hitless swap between steps
        self.events.append(FaultEvent(
            getattr(self.trainer, "step", -1), "straggler", str(rates)))
        return rates

    # -------------------------------------------------------------- recover
    def recover(self) -> int:
        """Restore the latest complete checkpoint; returns the resume step."""
        if self.restarts >= self.fc.max_restarts:
            raise RuntimeError("restart budget exhausted")
        self.restarts += 1
        self.trainer.restore()
        self.events.append(FaultEvent(self.trainer.step, "restart"))
        return self.trainer.step

    def run_supervised(self, steps: int) -> List[Tuple[int, Dict[str, float]]]:
        """Training loop with NaN/timeout detection and auto-restart."""
        done = 0
        history: List[Tuple[int, Dict[str, float]]] = []
        while done < steps:
            try:
                hist = self.trainer.run(1)
            except FloatingPointError as e:   # pragma: no cover
                self.events.append(FaultEvent(self.trainer.step, "nan", str(e)))
                self.recover()
                continue
            done += 1
            for s, m in hist:
                history.append((s, m))
                kind = self.check_metrics(s, m)
                if kind:
                    self.events.append(FaultEvent(s, kind))
                    self.recover()
        return history


# ---------------------------------------------------------------------------
# Online fault detection for the closed-loop co-sim
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SimFaultConfig:
    """Detector thresholds for :class:`OnlineFaultDetector`.

    ``dead_ticks`` consecutive ticks of (zero capacity + standing backlog
    + nothing served) declare a tile dead; recovery (capacity observed
    again) clears the belief immediately.  ``min_backlog`` filters idle
    tiles — a healthy tile with no work also serves nothing, and must not
    be declared dead.  ``straggler_slack`` mirrors :class:`FaultConfig`
    for busy-skew flagging (advisory events, no mask change); a tile must
    hold the skew for ``straggler_ticks`` consecutive ticks before it is
    flagged, so per-tick Poisson flicker never reaches the event log."""
    dead_ticks: int = 3
    min_backlog: float = 1e-9
    straggler_slack: float = 1.3
    straggler_ticks: int = 25


class OnlineFaultDetector:
    """Vectorized dead-tile detection from per-tick sim observables.

    Pure observation: never sees the injected schedule.  A tile is
    *suspected* while ``cap <= 0`` and ``queue > min_backlog`` and
    ``served <= 0``; ``dead_ticks`` consecutive suspect ticks latch the
    dead belief, and any tick with observable capacity clears it (the
    revive probe — a revived tile's nominal capacity is visible even
    before traffic is routed back to it)."""

    def __init__(self, n_tiles: int, config: Optional[SimFaultConfig] = None):
        self.config = config or SimFaultConfig()
        self._streak = np.zeros(n_tiles, dtype=np.int64)
        self._dead = np.zeros(n_tiles, dtype=bool)

    @property
    def believed_dead(self) -> np.ndarray:
        return self._dead.copy()

    def observe(self, served: np.ndarray, queue: np.ndarray,
                cap: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """One tick's observables -> (newly_dead, newly_alive) masks."""
        c = self.config
        suspect = (np.asarray(cap) <= 0.0) & \
                  (np.asarray(queue) > c.min_backlog) & \
                  (np.asarray(served) <= 0.0)
        self._streak = np.where(suspect, self._streak + 1, 0)
        has_cap = np.asarray(cap) > 0.0
        dead_now = (self._dead | (self._streak >= c.dead_ticks)) & ~has_cap
        newly_dead = dead_now & ~self._dead
        newly_alive = self._dead & ~dead_now
        self._dead = dead_now
        return newly_dead, newly_alive


class SimFaultSupervisor:
    """Online detection/recovery harness for the sequential sim engine.

    Pass as ``SimEngine(..., supervisor=...)``: each tick the engine
    feeds the detector and routes re-spill/splits on ``believed_alive``
    instead of the oracle mask — stranded work keeps flowing to a
    dead replica for the detector's latency window and is only then
    re-spilled, which is exactly the fidelity gap an offline mask-based
    recovery model hides.  Also flags busy-skew stragglers (advisory
    telemetry events, mirroring the trainer-side supervisor's policy)."""

    def __init__(self, config: Optional[SimFaultConfig] = None):
        self.config = config or SimFaultConfig()
        self.detector: Optional[OnlineFaultDetector] = None
        self.events: List[Dict[str, object]] = []
        self._names: Tuple[str, ...] = ()
        self._last_skew: frozenset = frozenset()
        self._skew_streak: Optional[np.ndarray] = None

    def begin_run(self, names) -> None:
        self._names = tuple(names)
        self.detector = OnlineFaultDetector(len(self._names), self.config)
        self.events = []
        self._last_skew = frozenset()
        self._skew_streak = np.zeros(len(self._names), dtype=np.int64)

    @property
    def believed_alive(self) -> np.ndarray:
        assert self.detector is not None, "begin_run not called"
        return 1.0 - self.detector.believed_dead.astype(np.float64)

    def observe(self, tick: int, *, served, queue, cap,
                busy=None) -> List[Dict[str, object]]:
        """One tick's observables; returns event dicts (also retained on
        ``self.events``) for the engine to forward into telemetry."""
        assert self.detector is not None, "begin_run not called"
        newly_dead, newly_alive = self.detector.observe(served, queue, cap)
        out: List[Dict[str, object]] = []
        for mask, kind in ((newly_dead, "detected_dead"),
                           (newly_alive, "detected_alive")):
            if mask.any():
                tiles = [self._names[i] for i in np.nonzero(mask)[0]]
                out.append({
                    "tick": int(tick), "kind": kind,
                    "subject": ",".join(tiles), "tiles": tiles})
        if busy is not None:
            b = np.asarray(busy, dtype=np.float64)
            live = ~self.detector.believed_dead
            if live.sum() >= 2:
                med = float(np.median(b[live]))
                raw = (med > 0) & live & (b > self.config.straggler_slack
                                          * max(med, 1e-9))
                self._skew_streak = np.where(raw, self._skew_streak + 1, 0)
                persist = self._skew_streak >= self.config.straggler_ticks
                cur = frozenset(np.nonzero(persist)[0].tolist())
                # emit only persistent skew, and only on set changes —
                # per-tick Poisson flicker would flood a long soak's log
                if cur and cur != self._last_skew:
                    tiles = [self._names[i] for i in sorted(cur)]
                    out.append({
                        "tick": int(tick), "kind": "straggler_suspect",
                        "subject": ",".join(tiles), "tiles": tiles})
                self._last_skew = cur
        self.events.extend(out)
        return out
