"""Serving runtime: prefill/decode engine + request scheduler with RTT.

``serve_step`` (one new token against a KV cache of ``seq_len``) is the
artifact the ``decode_*`` / ``long_*`` dry-run cells lower.  The engine adds
a slot-based continuous-batching scheduler whose per-request dispatch→
completion time feeds the C3 ``rtt`` counter — the direct analogue of the
paper's DMA round-trip counter (request for data → arrival at accelerator).

Slots are independent vmap lanes: every cache leaf (including the position
counter) carries a leading slot axis, so requests admitted at different
ticks decode against their own positions — continuous batching without
cache repacking.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import monitor as mon
from repro.core.tiles import TilePlan, default_plan
from repro.models.transformer import LM


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S,) int32
    max_new: int = 16
    submitted_tick: int = 0
    first_token_tick: Optional[int] = None
    done_tick: Optional[int] = None
    out: List[int] = field(default_factory=list)

    @property
    def rtt(self) -> Optional[int]:
        """Dispatch->first-data ticks (the paper's round-trip-time)."""
        if self.first_token_tick is None:
            return None
        return self.first_token_tick - self.submitted_tick


class ServeEngine:
    """Batched decode over fixed slots (continuous-batching-lite)."""

    def __init__(self, cfg: ArchConfig, *, batch_slots: int = 4,
                 window: int = 256, lm_kwargs: Optional[Dict] = None,
                 plan: Optional[TilePlan] = None, seed: int = 0):
        self.cfg = cfg
        self.lm = LM(cfg, **(lm_kwargs or {}))
        self.plan = plan or default_plan(cfg)
        self.counters = mon.init_counters(self.plan)
        self.slots = batch_slots
        self.window = window
        self.params = self.lm.init(jax.random.PRNGKey(seed))

        lm = self.lm

        def decode_all(params, cache_stack, tokens):
            # vmap over the slot axis of every cache leaf + token lane
            def one(cache, tok):
                return lm.decode_step(params, cache, tokens=tok)
            return jax.vmap(one, in_axes=(0, 0))(cache_stack, tokens)

        self._decode = jax.jit(decode_all)
        self._prefill = jax.jit(
            lambda p, t: lm.prefill(p, tokens=t, cache_len=window))

        self.tick = 0
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}      # slot -> request
        # per-slot cache stack: leading slot axis on every leaf, B=1 inside
        one = self.lm.init_cache(1, window)
        self.cache = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (self.slots,) + a.shape
                                       ).astype(a.dtype)
            if hasattr(a, "ndim") else a, one)
        self.tokens = jnp.zeros((self.slots, 1, 1), jnp.int32)
        self.done: List[Request] = []

    # ------------------------------------------------------------- lifecycle
    def submit(self, req: Request) -> None:
        req.submitted_tick = self.tick
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.slots):
            if slot in self.active or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = jnp.asarray(req.prompt[None, :], jnp.int32)
            logits, cache1 = self._prefill(self.params, prompt)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)   # (1,)
            req.out.append(int(tok[0]))
            req.first_token_tick = self.tick + 1
            self.counters = mon.charge(
                self.counters, "mem",
                rtt=jnp.asarray(self.tick + 1 - req.submitted_tick,
                                jnp.float32))
            self.cache = jax.tree_util.tree_map(
                lambda stack, new: stack.at[slot].set(new.astype(stack.dtype))
                if hasattr(stack, "ndim") else new,
                self.cache, cache1)
            self.tokens = self.tokens.at[slot, 0, 0].set(tok[0])
            self.active[slot] = req

    def step(self) -> None:
        """One decode tick for every occupied slot."""
        self.tick += 1
        self._admit()
        if not self.active:
            return
        (logits, self.cache) = self._decode(self.params, self.cache,
                                            self.tokens)
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)   # (slots, 1)
        self.tokens = next_tok[:, :, None]
        ntok_host = np.asarray(next_tok)
        self.counters = mon.charge(
            self.counters, "io",
            exec_time=jnp.asarray(len(self.active), jnp.float32))
        for slot, req in list(self.active.items()):
            req.out.append(int(ntok_host[slot, 0]))
            if len(req.out) >= req.max_new:
                req.done_tick = self.tick
                self.done.append(req)
                del self.active[slot]

    def run(self, ticks: int) -> List[Request]:
        for _ in range(ticks):
            self.step()
        return self.done

    # -------------------------------------------------------------- metrics
    def stats(self) -> Dict[str, float]:
        rtts = [r.rtt for r in self.done if r.rtt is not None]
        lat = [r.done_tick - r.submitted_tick for r in self.done
               if r.done_tick is not None]
        toks = sum(len(r.out) for r in self.done)
        return {
            "completed": float(len(self.done)),
            "tokens": float(toks),
            "mean_rtt_ticks": float(np.mean(rtts)) if rtts else 0.0,
            "mean_latency_ticks": float(np.mean(lat)) if lat else 0.0,
            "tokens_per_tick": toks / max(self.tick, 1),
        }
