"""Pipeline parallelism: GPipe-schedule microbatch pipeline over a
``stage`` mesh axis, built from shard_map + lax.ppermute.

Vespa mapping: pipeline stages are frequency islands in series — each
stage is a tile group on its own sub-mesh, and the stage boundary is a
resynchronizer (one ppermute per clock tick).  The DFS straggler policy
derates early stages to the slowest stage's rate instead of letting
bubbles idle-burn (core/dfs.policy_straggler).

Schedule: fill-drain (GPipe).  With M microbatches and S stages the bubble
fraction is (S-1)/(M+S-1); the backward pass is derived by autodiff
(ppermute transposes to the reverse permute), which makes this a correct —
if not 1F1B-scheduled — pipeline.  1F1B is a scheduling refinement on the
same substrate, recorded as future work.

Usage (inside or outside jit):

    y = pipeline_apply(stage_fn, stage_params, x, mesh=mesh,
                       axis="stage", n_micro=8)

* ``stage_params``: pytree whose leaves have a leading ``n_stages`` dim
  (stage s uses leaf[s]).
* ``stage_fn(params_slice, x_mb) -> y_mb`` must keep the microbatch shape
  (homogeneous stages — reshape layers into equal groups).
* ``x``: (batch, ...) — split into ``n_micro`` microbatches on axis 0.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.compat import shard_map as _shard_map

P = jax.sharding.PartitionSpec


def pipeline_apply(stage_fn: Callable, stage_params: Any, x: jax.Array,
                   *, mesh, axis: str = "stage", n_micro: int = 4
                   ) -> jax.Array:
    """Run ``x`` through ``n_stages`` sequential stages, pipelined."""
    n_stages = mesh.shape[axis]
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    mb = B // n_micro
    xm = x.reshape((n_micro, mb) + x.shape[1:])

    def body(params_local, xm_local):
        # params_local: stage slice (leading dim 1) ; xm_local: full (M, mb, ...)
        params_local = jax.tree_util.tree_map(lambda a: a[0], params_local)
        s = jax.lax.axis_index(axis)
        S = n_stages            # static (jax.lax.axis_size is newer jax)
        M = xm_local.shape[0]
        T = M + S - 1
        fwd = [(i, (i + 1) % S) for i in range(S)]   # ring step (wraps; the
        #        wrapped value is masked out by the validity window below)

        def step(carry, t):
            buf, outs = carry                          # buf: (mb, ...)
            mb_idx = jnp.clip(t - s, 0, M - 1)
            valid = (t >= s) & (t - s < M)
            inp = jnp.where(s == 0,
                            xm_local[mb_idx].astype(buf.dtype), buf)
            out = stage_fn(params_local, inp)
            out = jnp.where(valid, out, 0.0)
            # last stage banks its result; others forward it
            outs = jnp.where(
                valid & (s == S - 1),
                jax.lax.dynamic_update_index_in_dim(
                    outs, out.astype(outs.dtype), mb_idx, 0),
                outs)
            buf_next = jax.lax.ppermute(out, axis, fwd)
            return (buf_next, outs), None

        buf0 = jnp.zeros(xm_local.shape[1:], jnp.float32)
        outs0 = jnp.zeros_like(xm_local, dtype=jnp.float32)
        (_, outs), _ = jax.lax.scan(step, (buf0, outs0), jnp.arange(T))
        # every device returns outs; only the last stage's is real — psum
        # after masking (cheap: it is exact for S-1 zero contributions)
        outs = jnp.where(s == S - 1, outs, 0.0)
        return jax.lax.psum(outs, axis)

    params_specs = jax.tree_util.tree_map(
        lambda a: P(axis), stage_params)
    out = _shard_map(
        body, mesh=mesh,
        in_specs=(params_specs, P()),
        out_specs=P(),
        check_vma=False,
    )(stage_params, xm)
    return out.reshape((B,) + out.shape[2:]).astype(x.dtype)


def stack_layer_groups(stacked_params: Any, n_stages: int) -> Any:
    """(L, ...) stacked layer params -> (S, L/S, ...) stage-stacked."""
    def one(a):
        L = a.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return a.reshape((n_stages, L // n_stages) + a.shape[1:])
    return jax.tree_util.tree_map(one, stacked_params)
