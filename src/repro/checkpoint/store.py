"""Sharded, async, elastic checkpointing — msgpack + zstd, no external deps.

Layout (one directory per step)::

    <dir>/step_000120/
        manifest.msgpack     # tree structure, shapes, dtypes, shard map
        shard_00000.bin.zst  # concatenated leaf chunks owned by host 0
        ...

* **Sharded**: each host writes only the leaf chunks it owns (here: one
  host, but the manifest format carries (host, offset, length) per leaf so
  a multi-host fleet writes disjoint files).
* **Async**: ``save_async`` snapshots device arrays to host memory
  synchronously (cheap) and does serialization + IO on a worker thread —
  the train loop keeps stepping while bytes hit disk (compute/IO overlap).
* **Elastic**: ``restore`` takes target shardings; leaves are re-laid-out
  via ``jax.device_put``, so a checkpoint taken on one mesh restores onto
  another (different device count / MRA factoring) — the Vespa hitless
  reconfiguration path across restarts.
* **Atomic**: writes go to ``<dir>.tmp`` then ``os.rename`` — a crash
  mid-save never corrupts the latest complete checkpoint.
"""
from __future__ import annotations

import os
import shutil
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:                       # container without zstandard:
    zstandard = None                      # fall back to stdlib zlib
import zlib

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _compress(data: bytes, level: int) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=level).compress(data)
    return zlib.compress(data, min(level, 9))


def _decompress(blob: bytes) -> bytes:
    if blob[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise RuntimeError(
                "checkpoint was written with zstd but zstandard is not "
                "installed; pip install zstandard to restore it")
        return zstandard.ZstdDecompressor().decompress(blob)
    return zlib.decompress(blob)


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [l for _, l in flat]
    return paths, leaves, treedef


@dataclass
class SaveResult:
    step: int
    path: str
    seconds: float
    nbytes: int


class CheckpointStore:
    def __init__(self, root: str, *, keep: int = 3, zstd_level: int = 3):
        self.root = root
        self.keep = keep
        self.zstd_level = zstd_level
        self._thread: Optional[threading.Thread] = None
        self._last: Optional[SaveResult] = None
        self._err: Optional[BaseException] = None
        os.makedirs(root, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any) -> SaveResult:
        """Synchronous save (used by save_async's worker)."""
        t0 = time.monotonic()
        paths, leaves, _ = _flatten_with_paths(tree)
        host = [np.asarray(l) for l in leaves]      # device->host snapshot
        final = self._step_dir(step)
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest: Dict[str, Any] = {"step": step, "leaves": []}
        offset = 0
        chunks: List[bytes] = []
        for p, a in zip(paths, host):
            raw = np.ascontiguousarray(a).tobytes()
            manifest["leaves"].append({
                "path": p, "shape": list(a.shape), "dtype": str(a.dtype),
                "host": 0, "offset": offset, "length": len(raw)})
            chunks.append(raw)
            offset += len(raw)
        blob = _compress(b"".join(chunks), self.zstd_level)
        with open(os.path.join(tmp, "shard_00000.bin.zst"), "wb") as f:
            f.write(blob)
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()
        res = SaveResult(step, final, time.monotonic() - t0, offset)
        self._last = res
        return res

    def save_async(self, step: int, tree: Any) -> None:
        """Snapshot now, write in the background (overlaps the next steps)."""
        self.wait()                                  # one in flight at a time
        paths, leaves, treedef = _flatten_with_paths(tree)
        host = [np.asarray(l) for l in leaves]       # sync snapshot
        snap = jax.tree_util.tree_unflatten(treedef, host)

        def work():
            try:
                self.save(step, snap)
            except BaseException as e:                # pragma: no cover
                self._err = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> Optional[SaveResult]:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err
        return self._last

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name[5:]))
                except ValueError:
                    pass
        return max(steps) if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``like``; optional target shardings
        re-lay-out every leaf (elastic restore onto a different mesh)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
            manifest = msgpack.unpackb(f.read())
        with open(os.path.join(d, "shard_00000.bin.zst"), "rb") as f:
            blob = _decompress(f.read())
        by_path = {l["path"]: l for l in manifest["leaves"]}

        paths, leaves, treedef = _flatten_with_paths(like)
        sh_leaves = (jax.tree_util.tree_leaves(shardings)
                     if shardings is not None else [None] * len(leaves))
        out = []
        for p, leaf, sh in zip(paths, leaves, sh_leaves):
            meta = by_path[p]
            arr = np.frombuffer(
                blob, dtype=np.dtype(meta["dtype"]),
                count=int(np.prod(meta["shape"]) or 1),
                offset=meta["offset"]).reshape(meta["shape"])
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------------ misc
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:06d}")

    def _gc(self) -> None:
        steps = sorted(s for s in (self.latest_step(),) if s is not None)
        all_steps = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    all_steps.append(int(name[5:]))
                except ValueError:
                    pass
        for s in sorted(all_steps)[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
