"""Multi-device sharding helpers for the sweep and co-sim hot paths.

The chunked ``grid_sweep`` evaluator and the ``BatchSimEngine`` design
batch are embarrassingly parallel along one axis (flat design points,
the B design axis).  This module owns the small amount of mesh plumbing
both need to run that axis through ``shard_map`` via the version shims
in :mod:`repro.compat`:

* :func:`resolve_devices` — turn a ``devices=`` knob (``None`` / int /
  ``"auto"``) into a concrete device count, clamped to what the jax
  runtime actually exposes.  Multi-device CPU runs come from
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before the
  first jax import; the distributed tests spawn subprocesses for this).
* :func:`device_mesh` — a cached 1-D :class:`jax.sharding.Mesh` over the
  first N devices.  The cache is keyed on ``(N, axis_name)`` only — a
  bounded, device-count-indexed dict (there are at most a handful of
  distinct counts per process), never on array-backed objects, so it
  cannot grow with sweep configurations (the PR 8 cache-growth audit).
* :func:`pad_axis` / :func:`shard_len` — pad an array so an axis splits
  evenly across devices (padded tail rows are computed and discarded —
  every sharded caller slices results back to the true length).

Correctness contract: sharding only *partitions* an elementwise (or
per-design-independent) computation, so any device count — including 1 —
produces identical floats; the single-device unsharded code path stays
the bit-for-bit ground truth and the sharded path is differentially
tested against it (``tests/test_shard_pallas.py``).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Union

import numpy as np

DEFAULT_AXIS = "shard"

# bounded by construction: one entry per (device count, axis name) pair
# actually used in this process — never keyed on arrays or configs
_MESH_CACHE: Dict[Tuple[int, str], object] = {}
_MESH_CACHE_MAX = 32


def device_count() -> int:
    """Number of addressable jax devices (1 without XLA_FLAGS overrides)."""
    import jax
    return len(jax.devices())


def resolve_devices(devices: Union[None, int, str]) -> int:
    """Normalize a ``devices=`` knob to a concrete count.

    ``None`` -> 1 (sharding off, the ground-truth single-device path);
    ``"auto"`` -> every visible device; an int is clamped to the visible
    device count (asking for 8 on a 1-device runtime runs unsharded
    rather than failing — the knob expresses intent, the runtime decides).
    """
    if devices is None:
        return 1
    n = device_count()
    if devices == "auto":
        return n
    d = int(devices)
    assert d >= 1, f"devices={devices!r}"
    return min(d, n)


def device_mesh(n_devices: int, axis_name: str = DEFAULT_AXIS):
    """A (cached) 1-D mesh of the first ``n_devices`` devices."""
    import jax
    from jax.sharding import Mesh
    key = (int(n_devices), axis_name)
    mesh = _MESH_CACHE.get(key)
    if mesh is None:
        if len(_MESH_CACHE) >= _MESH_CACHE_MAX:    # pragma: no cover
            _MESH_CACHE.pop(next(iter(_MESH_CACHE)))
        devs = jax.devices()
        assert n_devices <= len(devs), (n_devices, len(devs))
        mesh = Mesh(np.asarray(devs[:n_devices]), (axis_name,))
        _MESH_CACHE[key] = mesh
    return mesh


def mesh_cache_size() -> int:
    """Current mesh-cache population (asserted bounded in tests)."""
    return len(_MESH_CACHE)


def shard_len(n: int, n_devices: int) -> int:
    """``n`` rounded up to a multiple of ``n_devices``."""
    return -(-n // n_devices) * n_devices


def pad_axis(a: np.ndarray, n_devices: int, axis: int = 0) -> np.ndarray:
    """Pad ``axis`` of ``a`` (edge-replicating row 0's shape class: zeros
    would do — padded rows are dropped after the gather — but repeating
    the first row keeps every lane on realistic values, avoiding
    divide-by-zero warnings inside masked expressions)."""
    n = a.shape[axis]
    target = shard_len(n, n_devices)
    if target == n:
        return a
    pad = target - n
    idx = [slice(None)] * a.ndim
    idx[axis] = slice(0, 1)
    filler = np.broadcast_to(
        a[tuple(idx)],
        a.shape[:axis] + (pad,) + a.shape[axis + 1:])
    return np.concatenate([a, filler], axis=axis)
