"""Metrics export for the co-sim observability plane.

A small, dependency-free metrics facility in the Prometheus data model:

- :class:`MetricsRegistry` holds named counter / gauge / histogram series,
  each keyed by a frozen label set.
- :func:`MetricsRegistry.render_prometheus` emits the Prometheus text
  exposition format (``# HELP`` / ``# TYPE`` / ``name{label="x"} value``).
- :func:`parse_prometheus_text` parses that format back into plain dicts —
  used by the round-trip tests and the CI bench gate, and handy for
  scraping ``BENCH_*`` artifacts without a Prometheus server.
- :func:`telemetry_timeseries` converts a :class:`repro.sim.telemetry.Telemetry`
  (or ``BatchTelemetry`` design view) ring into a JSON-safe timeseries doc.

Everything here only *reads* simulation state; nothing in this module is
allowed to touch engine numerics.
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

__all__ = [
    "Metric",
    "MetricsRegistry",
    "parse_prometheus_text",
    "telemetry_timeseries",
]

_VALID_TYPES = ("counter", "gauge", "histogram")

# Default histogram buckets: log-spaced, generic for latencies in seconds
# and utilizations alike.  Callers can pass their own.
DEFAULT_BUCKETS = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)

LabelSet = Tuple[Tuple[str, str], ...]


def _labelset(labels: Optional[Mapping[str, str]]) -> LabelSet:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_value(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _escape_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _render_labels(ls: LabelSet, extra: Optional[Sequence[Tuple[str, str]]] = None) -> str:
    parts = [f'{k}="{_escape_label(v)}"' for k, v in ls]
    if extra:
        parts += [f'{k}="{_escape_label(v)}"' for k, v in extra]
    if not parts:
        return ""
    return "{" + ",".join(parts) + "}"


@dataclass
class _Histogram:
    buckets: Tuple[float, ...]
    counts: List[int] = field(default_factory=list)
    total: float = 0.0
    n: int = 0

    def __post_init__(self) -> None:
        if not self.counts:
            self.counts = [0] * (len(self.buckets) + 1)  # +1 for +Inf

    def observe(self, value: float) -> None:
        v = float(value)
        self.total += v
        self.n += 1
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def cumulative(self) -> List[Tuple[float, int]]:
        out: List[Tuple[float, int]] = []
        running = 0
        for b, c in zip(self.buckets, self.counts[:-1]):
            running += c
            out.append((b, running))
        running += self.counts[-1]
        out.append((math.inf, running))
        return out


@dataclass
class Metric:
    """One metric family: a name, type, help string, and labeled series."""

    name: str
    kind: str
    help: str = ""
    series: Dict[LabelSet, object] = field(default_factory=dict)
    buckets: Tuple[float, ...] = DEFAULT_BUCKETS

    def _get_scalar(self, ls: LabelSet) -> float:
        return float(self.series.get(ls, 0.0))  # type: ignore[arg-type]


class MetricsRegistry:
    """A registry of counter/gauge/histogram metrics with label support.

    Write API::

        reg = MetricsRegistry()
        reg.counter("sim_invocations_total", "Total served invocations",
                    labels={"tile": "acc0"}, value=123.0)
        reg.gauge("sim_link_util", "Instantaneous link utilization",
                  labels={"link": "3"}, value=0.41)
        reg.histogram("sim_latency_seconds", "Request latency",
                      labels={"stage": "fe"}, value=0.0031)

    ``counter`` adds (monotonic increments); ``gauge`` sets; ``histogram``
    observes one sample per call.
    """

    def __init__(self) -> None:
        self._metrics: Dict[str, Metric] = {}

    # -- registration / write -------------------------------------------
    def _family(self, name: str, kind: str, help: str, buckets: Optional[Sequence[float]]) -> Metric:
        if kind not in _VALID_TYPES:
            raise ValueError(f"unknown metric type {kind!r}; expected one of {_VALID_TYPES}")
        m = self._metrics.get(name)
        if m is None:
            m = Metric(name=name, kind=kind, help=help,
                       buckets=tuple(buckets) if buckets else DEFAULT_BUCKETS)
            self._metrics[name] = m
        elif m.kind != kind:
            raise ValueError(f"metric {name!r} already registered as {m.kind}, not {kind}")
        if help and not m.help:
            m.help = help
        return m

    def counter(self, name: str, help: str = "", *,
                labels: Optional[Mapping[str, str]] = None, value: float = 1.0) -> None:
        m = self._family(name, "counter", help, None)
        ls = _labelset(labels)
        m.series[ls] = float(m.series.get(ls, 0.0)) + float(value)  # type: ignore[arg-type]

    def gauge(self, name: str, help: str = "", *,
              labels: Optional[Mapping[str, str]] = None, value: float = 0.0) -> None:
        m = self._family(name, "gauge", help, None)
        m.series[_labelset(labels)] = float(value)

    def histogram(self, name: str, help: str = "", *,
                  labels: Optional[Mapping[str, str]] = None, value: float = 0.0,
                  buckets: Optional[Sequence[float]] = None) -> None:
        m = self._family(name, "histogram", help, buckets)
        ls = _labelset(labels)
        h = m.series.get(ls)
        if h is None:
            h = _Histogram(buckets=m.buckets)
            m.series[ls] = h
        h.observe(value)  # type: ignore[union-attr]

    # -- read ------------------------------------------------------------
    def get(self, name: str, labels: Optional[Mapping[str, str]] = None) -> Optional[float]:
        m = self._metrics.get(name)
        if m is None:
            return None
        ls = _labelset(labels)
        v = m.series.get(ls)
        if v is None:
            return None
        if isinstance(v, _Histogram):
            return v.total
        return float(v)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    # -- render ----------------------------------------------------------
    def render_prometheus(self) -> str:
        """Render the Prometheus text exposition format (v0.0.4)."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            for ls in sorted(m.series):
                v = m.series[ls]
                if m.kind == "histogram":
                    h = v  # type: _Histogram
                    for bound, cum in h.cumulative():  # type: ignore[union-attr]
                        le = "+Inf" if math.isinf(bound) else _fmt_value(bound)
                        lines.append(
                            f"{name}_bucket{_render_labels(ls, [('le', le)])} {cum}")
                    lines.append(f"{name}_sum{_render_labels(ls)} {_fmt_value(h.total)}")  # type: ignore[union-attr]
                    lines.append(f"{name}_count{_render_labels(ls)} {h.n}")  # type: ignore[union-attr]
                else:
                    lines.append(f"{name}{_render_labels(ls)} {_fmt_value(float(v))}")  # type: ignore[arg-type]
        return "\n".join(lines) + ("\n" if lines else "")

    def to_dict(self) -> Dict[str, object]:
        """JSON-safe dump: {name: {type, help, series: [{labels, value}...]}}."""
        out: Dict[str, object] = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            series = []
            for ls in sorted(m.series):
                v = m.series[ls]
                if isinstance(v, _Histogram):
                    series.append({
                        "labels": dict(ls),
                        "sum": v.total,
                        "count": v.n,
                        "buckets": [[("+Inf" if math.isinf(b) else b), c]
                                    for b, c in v.cumulative()],
                    })
                else:
                    series.append({"labels": dict(ls), "value": float(v)})  # type: ignore[arg-type]
            out[name] = {"type": m.kind, "help": m.help, "series": series}
        return out

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent)


def parse_prometheus_text(text: str) -> Dict[str, Dict[str, object]]:
    """Parse Prometheus text format into ``{name: {type, help, samples}}``.

    ``samples`` is a list of ``(labels_dict, value)`` tuples, with the raw
    sample name (e.g. ``foo_bucket``) folded back under its family when a
    ``# TYPE`` line announced a histogram.  Sufficient for round-trip tests
    and CI gates; not a general Prometheus client.
    """
    out: Dict[str, Dict[str, object]] = {}
    current_family: Optional[str] = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.startswith("# HELP "):
            rest = line[len("# HELP "):]
            name, _, help_text = rest.partition(" ")
            fam = out.setdefault(name, {"type": None, "help": "", "samples": []})
            fam["help"] = help_text
            current_family = name
            continue
        if line.startswith("# TYPE "):
            rest = line[len("# TYPE "):]
            name, _, kind = rest.partition(" ")
            fam = out.setdefault(name, {"type": None, "help": "", "samples": []})
            fam["type"] = kind.strip()
            current_family = name
            continue
        if line.startswith("#"):
            continue
        # sample line: name{labels} value  |  name value
        if "{" in line:
            name, _, rest = line.partition("{")
            labels_raw, _, val_raw = rest.rpartition("} ")
            labels: Dict[str, str] = {}
            if labels_raw:
                for item in _split_labels(labels_raw):
                    k, _, v = item.partition("=")
                    labels[k] = v.strip('"').replace('\\"', '"').replace("\\\\", "\\")
        else:
            name, _, val_raw = line.partition(" ")
            labels = {}
        val_raw = val_raw.strip()
        if val_raw == "+Inf":
            value = math.inf
        elif val_raw == "-Inf":
            value = -math.inf
        else:
            value = float(val_raw)
        family = name
        for suffix in ("_bucket", "_sum", "_count"):
            base = name[: -len(suffix)] if name.endswith(suffix) else None
            if base and base in out and out[base].get("type") == "histogram":
                family = base
                labels["__sample__"] = name[len(base) + 1:]
                break
        fam = out.setdefault(family, {"type": None, "help": "", "samples": []})
        fam["samples"].append((labels, value))  # type: ignore[union-attr]
        current_family = family
    return out


def _split_labels(raw: str) -> List[str]:
    """Split ``a="x",b="y"`` on commas outside quotes."""
    items: List[str] = []
    buf: List[str] = []
    in_quote = False
    escape = False
    for ch in raw:
        if escape:
            buf.append(ch)
            escape = False
            continue
        if ch == "\\":
            buf.append(ch)
            escape = True
            continue
        if ch == '"':
            in_quote = not in_quote
            buf.append(ch)
            continue
        if ch == "," and not in_quote:
            items.append("".join(buf))
            buf = []
            continue
        buf.append(ch)
    if buf:
        items.append("".join(buf))
    return items


def telemetry_timeseries(telemetry, *, design: Optional[int] = None) -> Dict[str, object]:
    """Convert a Telemetry/BatchTelemetry ring into a JSON-safe timeseries doc.

    Returns ``{"scalars": {name: [..]}, "islands": [...], "tiles": [...],
    "island_rates": [[..]], "queue_depth": [[..]], "events": [...]}``.
    For a ``BatchTelemetry`` pass ``design=`` to select one design's view.
    """
    t = telemetry.design(design) if design is not None else telemetry
    doc = t.to_dict()
    doc["kind"] = "telemetry_timeseries"
    return doc
