"""Vectorized tick-based closed-loop SoC simulation engine.

Replays a request :class:`~repro.sim.traffic.Trace` through one concrete
SoC design (accelerator tiles with replication K placed on the NoC grid,
partitioned into frequency islands) while an online DFS controller runs in
the loop.  The run-time analogue of ``core/dse.py:grid_sweep``: the sweep
answers "which design?", the engine answers "how does that design behave
under *this* traffic with *this* controller?".

Hot-path design — everything is flat (A,)-shaped arrays over accelerator
tiles, advanced one tick at a time; requests are fluid counts, never
Python objects:

* service rates come from the same kernel as the static model
  (:meth:`SoCPerfModel.service_time_terms_batch`, the decomposed form of
  ``accel_throughput_batch``) and are **cached per island-config
  version** — they are only recomputed when the DFS actuator commits,
  exactly like the cached compiled executables behind the dual-buffer
  actuator;
* NoC contention uses the precomputed routing tables: each tile's
  route-to-MEM link incidence is one static (A, L) 0/1 matrix, so
  per-tick link loads are a single matvec and the worst-link utilization
  per route one masked max.  The resulting M/D/1 slowdown scales the
  *wire* term of the service time only (the compute term never queues in
  the fabric; the static kernel's own TG-saturation factor stays as-is,
  so nothing is double counted);
* monitor counters follow ``core/monitor.py`` semantics vectorized:
  ``exec_time`` holds the latest busy fraction (auto-reset), pkts/rtt
  accumulate until the controller's windowed read differences them.

Latency is reconstructed exactly (at tick granularity) after the run from
the cumulative arrival/service curves of each FIFO fluid queue: the
mid-rank of every tick's admitted batch is looked up in the cumulative
service curve with one ``searchsorted`` per tile, giving per-batch
sojourn times whose request-count-weighted percentiles are the reported
p50/p99 — no per-request bookkeeping at any point.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.islands import (IslandConfig, IslandSpec, NOC_LADDER,
                                TILE_LADDER)
from repro.core.noc import contention_slowdown, pos_index
from repro.core.perfmodel import (AccelWorkload, NOC_POWER_SHARE,
                                  SoCPerfModel, chip_power)
from repro.core.voltage import TechModel
from repro.sim.faults import (CompiledFaults, FaultSchedule, SLOConfig,
                              compile_faults, respill_stranded)
from repro.sim.flows import FlowPattern, compile_flows
from repro.sim.observe import Observer
from repro.sim.telemetry import (Telemetry, TelemetrySchema,
                                 weighted_percentiles)
from repro.sim.traffic import Trace

PKT_BYTES = 512.0        # matches core/monitor.py (kept numeric here so the
                         # engine hot path never imports the jax-side module)


# ---------------------------------------------------------------------------
# Platform: one concrete design, in array form
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SimPlatform:
    """A simulatable SoC instance: per-accelerator-tile arrays + islands.

    Tile order is the trace's destination order.  ``islands`` is the
    *initial* island partition/rates; the controller (if any) evolves it
    through its actuator at run time.  ``flows`` is an optional
    :class:`~repro.sim.flows.FlowPattern` naming tile-to-tile streams and
    accelerator chains; ``None`` keeps the legacy tile->MEM workload.
    """
    model: SoCPerfModel
    islands: IslandConfig
    names: Tuple[str, ...]
    base_mbps: np.ndarray           # (A,)
    wire_share: np.ndarray          # (A,)
    k: np.ndarray                   # (A,)
    pos_idx: np.ndarray             # (A,) flat NoC node indices
    req_mb: np.ndarray              # (A,) MB of stream payload per request
    n_tg: int = 0
    f_tg: float = 1.0
    flows: Optional["FlowPattern"] = None

    @property
    def n_tiles(self) -> int:
        return len(self.names)

    @classmethod
    def build(cls, model: SoCPerfModel,
              workloads: Sequence[AccelWorkload],
              positions: Sequence[Tuple[int, int]],
              *, names: Optional[Sequence[str]] = None,
              island_groups: Optional[Dict[str, Sequence[str]]] = None,
              rates: Optional[Dict[str, float]] = None,
              noc_rate: float = 1.0, req_mb: float = 0.1,
              n_tg: int = 0, f_tg: float = 1.0,
              flows: Optional["FlowPattern"] = None) -> "SimPlatform":
        """Assemble a platform from parallel workload/position lists.

        ``island_groups`` maps island name -> tile names (default: every
        tile is its own island — the paper's finest-grained DFS); a
        ``noc_mem`` island is always appended.  ``rates`` presets island
        rates (default 1.0).
        """
        assert len(workloads) == len(positions)
        if names is None:
            names = []
            for i, wl in enumerate(workloads):
                names.append(f"{wl.name}{i}")
        names = tuple(names)
        assert len(set(names)) == len(names), "duplicate tile names"
        taken = set()
        for p in positions:
            assert tuple(p) != tuple(model.mem_pos), "tile placed on MEM"
            assert tuple(p) not in taken, f"tile collision at {p}"
            taken.add(tuple(p))
        if island_groups is None:
            island_groups = {n: (n,) for n in names}
        rates = dict(rates or {})
        specs = [IslandSpec(iname, tuple(tiles), TILE_LADDER,
                            rate=float(rates.get(iname, 1.0)))
                 for iname, tiles in island_groups.items()]
        specs.append(IslandSpec("noc_mem", ("NOC", "MEM"), NOC_LADDER,
                                rate=float(rates.get("noc_mem", noc_rate))))
        return cls(
            model=model, islands=IslandConfig(tuple(specs)), names=names,
            base_mbps=np.asarray([w.base_mbps for w in workloads], float),
            wire_share=np.asarray([w.wire_share for w in workloads], float),
            k=np.asarray([float(w.replication) for w in workloads]),
            pos_idx=np.asarray([pos_index(model.noc, tuple(p))
                                for p in positions], dtype=np.int64),
            req_mb=np.full(len(names), float(req_mb)),
            n_tg=int(n_tg), f_tg=float(f_tg), flows=flows)

    @classmethod
    def from_design_point(cls, model: SoCPerfModel, dp,
                          workloads: Sequence[AccelWorkload],
                          *, req_mb: float = 0.1, n_tg: int = 0,
                          flows: Optional["FlowPattern"] = None
                          ) -> "SimPlatform":
        """Bridge from the DSE layer: instantiate a ``grid_sweep``
        survivor (a :class:`~repro.core.dse.DesignPoint`) for replay —
        replication/placement from the point, island rates from its rate
        assignment.  Shared-rate points carry one ``acc`` rate; per-island
        points (``grid_sweep(island_rates="independent")``) carry one rate
        per accelerator island keyed by tile name, which maps 1:1 onto the
        per-tile islands this platform builds."""
        wls = [AccelWorkload(w.name, w.base_mbps, w.ai,
                             replication=int(dp.replication[w.name]))
               for w in workloads]
        shared = float(dp.rates.get("acc", 1.0))
        return cls.build(
            model, wls, [dp.placement[w.name] for w in workloads],
            names=[w.name for w in workloads],
            rates={**{w.name: float(dp.rates.get(w.name, shared))
                      for w in workloads},
                   "noc_mem": float(dp.rates.get("noc_mem", 1.0))},
            req_mb=req_mb, n_tg=n_tg, f_tg=float(dp.rates.get("tg", 1.0)),
            flows=flows)


# ---------------------------------------------------------------------------
# The per-tick step, factored out so every engine shares ONE numeric core
# ---------------------------------------------------------------------------
#
# The sequential engine advances (A,)-shaped state; the batched engine
# (sim/batch.py) advances (B, A)-shaped state — same expressions, same
# reduction axes (always the trailing ones), so a batch row computes
# bit-for-bit the floats the sequential engine computes (numpy elementwise
# ops and last-axis reductions are shape-independent; the link-load
# contraction uses einsum, whose accumulation order over the contracted
# axis is sequential for both layouts, unlike BLAS matvec vs matmul).


@dataclass
class TickState:
    """Mutable fluid-queue + counter state, leading batch axes allowed.

    All per-tile arrays are ``(..., A)``; ``dropped``/``energy`` reduce the
    tile axis away and are ``(...)`` (0-d for the sequential engine).
    """
    queue: np.ndarray
    busy: np.ndarray
    pkts_in: np.ndarray         # accumulate (monitor semantics)
    pkts_out: np.ndarray        # accumulate
    rtt_acc: np.ndarray         # accumulate
    dropped: np.ndarray
    energy: np.ndarray
    # fault/SLO extensions (zeros and untouched on fault-free runs)
    retry_q: Optional[np.ndarray] = None        # (..., A) re-queued work
    dropped_slo: Optional[np.ndarray] = None    # (...) deadline drops
    dropped_fault: Optional[np.ndarray] = None  # (...) stranded drops
    retried: Optional[np.ndarray] = None        # (...) re-spilled work

    @classmethod
    def zeros(cls, shape: Tuple[int, ...]) -> "TickState":
        lead = shape[:-1]
        return cls(queue=np.zeros(shape), busy=np.zeros(shape),
                   pkts_in=np.zeros(shape), pkts_out=np.zeros(shape),
                   rtt_acc=np.zeros(shape), dropped=np.zeros(lead),
                   energy=np.zeros(lead), retry_q=np.zeros(shape),
                   dropped_slo=np.zeros(lead), dropped_fault=np.zeros(lead),
                   retried=np.zeros(lead))


@dataclass(frozen=True)
class StepConsts:
    """Per-run constants of :func:`tick_step` (platform + config digest).

    ``own_demand`` is the bytes/cycle each tile's output stream offers
    while busy — a scalar for the legacy uniform-demand MEM pattern, an
    ``(A,)`` vector under a :class:`~repro.sim.flows.FlowPattern` with
    per-flow demands.  ``forward`` is the optional ``(A, A)`` chain
    coupling (stage completions -> next stage's queue); ``None`` keeps
    the tick numerically identical to the chain-free engine.
    """
    base_mbps: np.ndarray       # (..., A)
    req_mb: np.ndarray          # (..., A)
    hop_counts: np.ndarray      # (..., A)
    inc: np.ndarray             # (..., A, L) route->link incidence
    own_demand: object          # float or (A,) per-flow bytes/cycle
    link_bw: float
    max_slow: float
    hop_latency: float
    noc_power_share: float
    dt: float
    max_queue: float
    dynamic_contention: bool
    forward: Optional[np.ndarray] = None    # (A, A) chain coupling
    deadline_ticks: float = float("inf")    # SLO deadline in ticks
    tech: Optional[TechModel] = None        # physical DVFS model (None =
                                            # linear voltage proxy)


@dataclass(frozen=True)
class TickOut:
    """Per-tick outputs the surrounding loop needs (histories, telemetry,
    controller inputs); the persistent state lives in :class:`TickState`."""
    admitted: np.ndarray        # (..., A)
    served: np.ndarray          # (..., A)
    cap_tick: np.ndarray        # (..., A) requests servable this tick
    rho: np.ndarray             # (..., A) worst-link utilization per route
    dyn: np.ndarray             # (..., A) contention slowdown on the wire
    tile_power: np.ndarray      # (...)
    noc_power: np.ndarray       # (...)
    forwarded: Optional[np.ndarray] = None  # (..., A) chained completions
                                            # to enqueue NEXT tick
    slo_drop: Optional[np.ndarray] = None   # (..., A) deadline drops
    link_loads: Optional[np.ndarray] = None  # (..., L) offered link loads
                                             # (None without contention)


def tick_step(st: TickState, arr_t: np.ndarray, svc: Dict[str, np.ndarray],
              c: StepConsts, *, alive: Optional[np.ndarray] = None,
              link_scale: Optional[np.ndarray] = None,
              retry_in: Optional[np.ndarray] = None) -> TickOut:
    """Advance the fluid queues by one tick (mutates ``st`` in place).

    ``svc`` is the cached service-term dict (``t_comp``/``t_wire``/
    ``t_ref`` shaped ``(..., A)``, ``f_tile`` ``(..., A)``, ``f_noc``
    scalar or ``(...)``) — recomputed by the caller only when a DFS commit
    changes island rates.

    Fault hooks (every one ``None``-gated, so fault-free runs execute the
    exact legacy expressions): ``alive`` is this tick's (A,) availability
    row (dead tiles have zero capacity and are power-gated), ``link_scale``
    the (L,) link-bandwidth scale row (degraded links saturate earlier),
    ``retry_in`` this tick's re-spilled arrivals, tracked as a second
    fluid class inside the queue so a bounded-retry drop policy needs no
    per-request bookkeeping.  The SLO deadline (``c.deadline_ticks``)
    drops backlog exceeding ``nominal capacity x deadline`` explicitly —
    nominal, not masked, so a dead tile's backlog is re-spilled by the
    recovery path before the deadline reaper sees it.
    """
    q = st.queue + arr_t
    adm = arr_t
    if retry_in is not None:
        q0 = q                      # retry-class mixing denominator
        st.retry_q = st.retry_q + retry_in
    if c.max_queue != float("inf"):
        over = np.maximum(q - c.max_queue, 0.0)
        q = q - over
        adm = adm - over
        st.dropped += over.sum(axis=-1)
    f_noc = np.asarray(svc["f_noc"], dtype=np.float64)
    if c.dynamic_contention:
        # live accel->MEM flows onto links: one contraction + masked max;
        # link capacity is f_noc-scaled like the static kernel's
        # saturation term (C2: island rate scales links)
        loads = np.einsum("...a,...al->...l", c.own_demand * st.busy, c.inc)
        if link_scale is not None:
            loads = loads / link_scale
        rho = ((c.inc * loads[..., None, :]).max(axis=-1)
               / (c.link_bw * f_noc[..., None]))
        dyn = contention_slowdown(rho, c.max_slow)
    else:
        loads = None
        rho = np.zeros_like(q)
        dyn = np.ones_like(q)
    cap_tick = (c.base_mbps * svc["t_ref"]
                / (svc["t_comp"] + svc["t_wire"] * dyn)
                / c.req_mb) * c.dt
    if alive is None:
        served = np.minimum(q, cap_tick)
        st.queue = q - served
        st.busy = served / cap_tick
    else:
        cap_nominal = cap_tick
        cap_tick = cap_tick * alive
        served = np.minimum(q, cap_tick)
        st.queue = q - served
        st.busy = np.where(cap_tick > 0.0,
                           served / np.where(cap_tick > 0.0, cap_tick, 1.0),
                           0.0)
    slo_drop = None
    if c.deadline_ticks != float("inf"):
        horizon = ((cap_tick if alive is None else cap_nominal)
                   * c.deadline_ticks)
        slo_drop = np.maximum(st.queue - horizon, 0.0)
        st.queue = st.queue - slo_drop
        st.dropped_slo = st.dropped_slo + slo_drop.sum(axis=-1)
    if retry_in is not None:
        # proportional class mixing: the retry class shrinks by the same
        # factor the whole queue did (FIFO fluid — classes are blended)
        st.retry_q = st.retry_q * np.where(
            q0 > 0.0, st.queue / np.where(q0 > 0.0, q0, 1.0), 0.0)

    # counters: pkts accumulate; exec_time (busy) auto-resets
    st.pkts_in += adm * c.req_mb * 1e6 / PKT_BYTES
    st.pkts_out += served * c.req_mb * 1e6 / PKT_BYTES
    st.rtt_acc += c.hop_counts * dyn * c.hop_latency

    if alive is None:
        tile_power = np.sum(chip_power(svc["f_tile"], st.busy, tech=c.tech),
                            axis=-1)
    else:                           # dead tiles are power-gated
        tile_power = np.sum(
            chip_power(svc["f_tile"], st.busy, tech=c.tech) * alive,
            axis=-1)
    noc_power = c.noc_power_share * chip_power(f_noc, 1.0, tech=c.tech)
    st.energy += (tile_power + noc_power) * c.dt
    # chain coupling: a share of each stage's completions becomes next
    # tick's arrivals at the following stage (einsum keeps the contracted
    # accumulation order identical for (A,) and (B, A) layouts)
    forwarded = (np.einsum("...a,aj->...j", served, c.forward)
                 if c.forward is not None else None)
    return TickOut(admitted=adm, served=served, cap_tick=cap_tick, rho=rho,
                   dyn=dyn, tile_power=tile_power, noc_power=noc_power,
                   forwarded=forwarded, slo_drop=slo_drop, link_loads=loads)


def percentile_samples(admitted: np.ndarray, served: np.ndarray,
                       dt: float, queue_drops: Optional[np.ndarray] = None
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """(latency values, request weights) of one design's run, from the
    cumulative arrival/service curves of its FIFO fluid queues (tick
    granularity): the mid-rank of every tick's admitted batch is looked up
    in the cumulative service curve with one ``searchsorted`` per tile.

    ``queue_drops`` (T, A), when given, holds work that left the queue
    *without* being served (SLO deadline drops, stranded-work drains) —
    it joins the exit curve so later arrivals' ranks still resolve; the
    reconstruction reduces exactly to the legacy one when it is zero."""
    T, A = admitted.shape
    ticks = np.arange(T, dtype=np.float64)
    vals: List[np.ndarray] = []
    wts: List[np.ndarray] = []
    for a in range(A):
        ca = np.cumsum(admitted[:, a])
        exits = (served[:, a] if queue_drops is None
                 else served[:, a] + queue_drops[:, a])
        cs = np.cumsum(exits)
        n = admitted[:, a]
        mid = ca - 0.5 * n          # mid-rank of each tick's batch
        depart = np.searchsorted(cs, mid, side="left")
        done = (depart < T) & (n > 0)
        lat = (depart - ticks + 0.5) * dt
        vals.append(lat[done])
        wts.append(n[done])
    if not vals:
        return np.empty(0), np.empty(0)
    return np.concatenate(vals), np.concatenate(wts)


def latency_percentiles(admitted: np.ndarray, served: np.ndarray,
                        dt: float, queue_drops: Optional[np.ndarray] = None
                        ) -> Tuple[float, float]:
    """Request-weighted p50/p99 sojourn time for one design's (T, A)
    admitted/served histories."""
    if admitted.shape[0] == 0:
        return float("nan"), float("nan")
    v, w = percentile_samples(admitted, served, dt, queue_drops)
    if v.size == 0 or w.sum() <= 0:
        return float("nan"), float("nan")
    p50, p99 = weighted_percentiles(v, w, (50.0, 99.0))
    return float(p50), float(p99)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SimConfig:
    control_interval: int = 50          # ticks between controller samples
    telemetry_interval: int = 20        # ticks between telemetry rows
    telemetry_capacity: int = 4096      # ring-buffer rows kept
    dynamic_contention: bool = True     # live NoC queueing on the wire term
    max_queue: float = float("inf")     # requests/tile before drops
    noc_power_share: float = NOC_POWER_SHARE   # the one shared energy model
                                        # constant (core/perfmodel.py)


@dataclass
class SimResult:
    ticks: int
    dt: float
    offered: float                      # external requests from the trace
    completed: float                    # requests served; under a chained
                                        # FlowPattern only EXIT-stage
                                        # services count (each external
                                        # request completes once, not once
                                        # per stage)
    dropped: float                      # admission drops (max_queue)
    residual: float                     # still queued when the trace ended
    throughput_rps: float               # completed / simulated seconds
    p50_latency_s: float
    p99_latency_s: float
    energy_j: float
    energy_per_request_j: float
    mean_power_w: float
    swaps: int                          # actuator commits during the run
    elapsed_wall_s: float
    telemetry: Telemetry
    dropped_slo: float = 0.0            # explicit SLO-deadline drops
    dropped_fault: float = 0.0          # stranded on dead replicas
    retried: float = 0.0                # re-spilled to surviving replicas

    @property
    def ticks_per_s_wall(self) -> float:
        return self.ticks / self.elapsed_wall_s if self.elapsed_wall_s else 0.0

    @property
    def requests_per_s_wall(self) -> float:
        return (self.completed / self.elapsed_wall_s
                if self.elapsed_wall_s else 0.0)

    @property
    def dropped_total(self) -> float:
        """All explicit drops: admission + SLO deadline + fault-stranded."""
        return self.dropped + self.dropped_slo + self.dropped_fault

    @property
    def drop_rate(self) -> float:
        """Fraction of offered requests explicitly dropped."""
        return self.dropped_total / self.offered if self.offered > 0 else 0.0

    def summary(self) -> str:
        s = (f"{self.ticks} ticks ({self.ticks * self.dt:.1f}s sim, "
             f"{self.elapsed_wall_s:.2f}s wall, "
             f"{self.requests_per_s_wall:,.0f} req/s wall): "
             f"completed {self.completed:,.0f}/{self.offered:,.0f} "
             f"({self.throughput_rps:,.0f} rps), "
             f"p50 {self.p50_latency_s * 1e3:.2f}ms "
             f"p99 {self.p99_latency_s * 1e3:.2f}ms, "
             f"{self.energy_per_request_j * 1e3:.3f} mJ/req, "
             f"{self.swaps} DFS swaps")
        if self.dropped_total > 0:
            s += (f", dropped {self.dropped_total:,.0f} "
                  f"({self.drop_rate:.2%}: slo {self.dropped_slo:,.0f} "
                  f"fault {self.dropped_fault:,.0f}), "
                  f"retried {self.retried:,.0f}")
        return s


class SimEngine:
    """Ticks a :class:`SimPlatform` through a trace, controller in loop."""

    def __init__(self, platform: SimPlatform, *,
                 config: SimConfig = SimConfig(), controller=None,
                 balancer=None, faults: Optional[FaultSchedule] = None,
                 slo: Optional[SLOConfig] = None, supervisor=None,
                 observe=None, tech=None):
        self.platform = platform
        self.config = config
        self.controller = controller    # a control.ControllerHarness or None
        # physical DVFS model (core/voltage.py): charges tick energy as
        # power_scl * (P_static + P_dyn f V̂(f)^2) and clamps DFS commits
        # to the node's legal [L, U] ratio range; None keeps the linear
        # voltage proxy bit for bit
        self.tech = TechModel.coerce(tech)
        if self.tech is not None and controller is not None \
                and getattr(controller, "tech", None) is None:
            # single clamping source: the engine's tech model governs the
            # harness unless the harness was built with its own
            controller.tech = self.tech
        self.balancer = balancer        # a control.LoadBalancer or None
        self.faults = faults            # a faults.FaultSchedule or None
        self.slo = slo                  # a faults.SLOConfig or None
        # run-time monitoring: an observe.Observer (or level string) —
        # zero-perturbation by construction (it only READS tick outputs)
        self.observer = Observer.coerce(observe)
        # online detection: a runtime.fault.SimFaultSupervisor, which sees
        # only sim telemetry (served/queue/capacity) — routing and respill
        # then act on its BELIEVED availability while the true masks gate
        # what the hardware actually serves
        self.supervisor = supervisor
        self.last_state: Optional[TickState] = None          # set by run()
        self.last_histories = None      # (admitted, served) (T, A) arrays
        self.last_fault_histories = None  # per-tick drop/ledger arrays
        m = platform.model
        # static route->link incidence of each tile's output stream
        # (tile->MEM unless the platform carries a FlowPattern):
        # inc[a, l] == 1 iff tile a's XY route to its destination uses l
        cf = compile_flows(m, platform.names, platform.pos_idx,
                           platform.flows)
        self._compiled_flows = cf
        self._inc = cf.inc
        self._hop_counts = cf.hop_counts
        self._flow_demand = cf.demand
        self._forward = cf.forward
        # compute term at the reference rate f_acc=1 (boundness baseline)
        self._t_comp_ref = (1.0 - platform.wire_share) / platform.k
        # tile -> island index (stable across with_rates: order preserved)
        isl_names = platform.islands.names()
        self._island_of_tile = np.asarray(
            [isl_names.index(platform.islands.island_of(n).name)
             for n in platform.names], dtype=np.int64)
        try:
            self._noc_island = isl_names.index("noc_mem")
        except ValueError:
            self._noc_island = -1

    # ------------------------------------------------------------ service
    def _rates(self, cfg: IslandConfig,
               rate_override: Optional[np.ndarray] = None
               ) -> Tuple[np.ndarray, float, np.ndarray]:
        """(per-tile f, f_noc, per-island rate vector) for one config.

        ``rate_override`` is the (I,) stuck-actuator hardware row (NaN =
        island follows software): it shapes the *effective* frequencies
        only — the returned ``island_rates`` stay the software view, so
        telemetry and the controller keep seeing what software committed.
        """
        island_rates = np.asarray([i.rate for i in cfg.islands])
        eff = island_rates
        if rate_override is not None:
            eff = np.where(np.isnan(rate_override), island_rates,
                           rate_override)
        f_tile = eff[self._island_of_tile]
        f_noc = (float(eff[self._noc_island])
                 if self._noc_island >= 0 else 1.0)
        return f_tile, f_noc, island_rates

    def _service(self, cfg: IslandConfig,
                 rate_override: Optional[np.ndarray] = None
                 ) -> Dict[str, np.ndarray]:
        """Static service-time terms for one island config (cached by the
        caller per config version — the analogue of the actuator's cached
        compiled executables)."""
        p = self.platform
        f_tile, f_noc, island_rates = self._rates(cfg, rate_override)
        t_comp, t_wire, t_ref = p.model.service_time_terms_batch(
            wire_share=p.wire_share, k=p.k, f_acc=f_tile, f_noc=f_noc,
            f_tg=p.f_tg, n_tg=p.n_tg, hop_counts=self._hop_counts)
        return {"t_comp": np.broadcast_to(t_comp, (p.n_tiles,)),
                "t_wire": np.broadcast_to(t_wire, (p.n_tiles,)),
                "t_ref": np.broadcast_to(np.asarray(t_ref, float),
                                         (p.n_tiles,)),
                "f_tile": f_tile, "f_noc": f_noc,
                "island_rates": island_rates, "version": cfg.version}

    def capacity_rps(self, cfg: Optional[IslandConfig] = None) -> np.ndarray:
        """Uncontended per-tile service capacity (requests/s) — exactly
        ``accel_throughput_batch / req_mb`` for the given config."""
        svc = self._service(cfg or self.platform.islands)
        thr = self.platform.base_mbps * svc["t_ref"] / (
            svc["t_comp"] + svc["t_wire"])
        return thr / self.platform.req_mb

    def step_consts(self, dt: float) -> StepConsts:
        """The :func:`tick_step` constants of this platform + config for a
        trace with tick length ``dt`` seconds."""
        p, cfg = self.platform, self.config
        return StepConsts(
            base_mbps=p.base_mbps, req_mb=p.req_mb,
            hop_counts=self._hop_counts, inc=self._inc,
            own_demand=self._flow_demand, link_bw=p.model.noc.link_bw,
            max_slow=p.model.noc.max_slowdown,
            hop_latency=p.model.noc.hop_latency,
            noc_power_share=cfg.noc_power_share, dt=dt,
            max_queue=cfg.max_queue,
            dynamic_contention=cfg.dynamic_contention,
            forward=self._forward, tech=self.tech)

    # ---------------------------------------------------------------- run
    def _compile_faults(self, T: int) -> Optional[CompiledFaults]:
        if self.faults is None or not self.faults:
            return None
        p = self.platform
        return compile_faults(self.faults, ticks=T, names=p.names,
                              islands=p.islands, noc=p.model.noc)

    def run(self, trace: Trace) -> SimResult:
        p, cfg = self.platform, self.config
        A, T, dt = p.n_tiles, trace.ticks, trace.dt
        assert trace.n_dests == A, (trace.n_dests, A)
        arrivals = trace.arrivals

        if self.controller is not None:
            self.controller.begin_run()     # counter baselines reset per run
            live = self.controller.live()
        else:
            live = p.islands
        cur_cfg = live

        # ---- fault/SLO compilation.  Everything below is None-gated so a
        # fault-free, SLO-free run executes the exact legacy tick loop.
        cf = self._compile_faults(T)
        slo = self.slo
        if slo is None and cf is not None:
            slo = SLOConfig()               # default kill semantics
        deadline = slo is not None and slo.deadline_s is not None
        has_tile = cf is not None and cf.has_tile
        has_link = cf is not None and cf.has_link
        has_stuck_rate = cf is not None and cf.has_stuck_rate
        recover = has_tile and slo.recovers and self.balancer is not None
        track = has_tile or deadline
        ev_by_tick = cf.events_by_tick() if cf is not None else {}
        applied_stuck = None                # last applied hardware row
        sup = self.supervisor
        if sup is not None:
            assert has_tile, "a fault supervisor needs tile faults to watch"
            sup.begin_run(p.names)

        svc = self._service(cur_cfg)
        st = TickState.zeros((A,))
        consts = self.step_consts(dt)
        if deadline:
            consts = replace(consts, deadline_ticks=slo.deadline_s / dt)
        # chain state: completions forwarded into the NEXT tick's queues
        carry = np.zeros(A) if consts.forward is not None else None
        # the balancer redistributes on last tick's capacity (init: the
        # uncontended capacity of the starting config)
        prev_cap = (self.capacity_rps(live) * dt
                    if self.balancer is not None else None)
        admitted_hist = np.zeros((T, A))
        served_hist = np.zeros((T, A))
        # per-tick work ledger under faults/SLOs (conservation tests,
        # latency under drops); None on legacy runs
        qdrop_hist = np.zeros((T, A)) if track else None
        fh = ({k: np.zeros(T) for k in
               ("dropped", "dropped_slo", "dropped_fault", "retried",
                "queue", "carry")} if track else None)
        # controller/telemetry window accumulators
        win_busy = np.zeros(A)
        win_served = 0.0
        win_ticks = 0
        ctl_busy = np.zeros(A)
        ctl_ticks = 0
        swaps0 = (self.controller.actuator.swaps
                  if self.controller is not None else 0)

        telem = Telemetry(
            TelemetrySchema(islands=live.names(), tiles=p.names),
            capacity=cfg.telemetry_capacity)

        # ---- monitoring (zero-perturbation: the capture only READS tick
        # outputs; per tick it costs two preallocated slot writes, the
        # full counter plane is reconstructed vectorized after the loop)
        ob = self.observer
        ocap = None
        slo_span = None                 # open SLO-drop span accumulator
        guard_prev: Tuple[str, ...] = ()
        if ob is not None and ob.enabled:
            ocap = ob.capture_sequential(
                T=T, consts=consts, island_of_tile=self._island_of_tile,
                noc_island=self._noc_island, n_links=self._inc.shape[-1],
                n_islands=len(live.names()),
                tile_alive=cf.tile_alive if has_tile else None,
                link_scale=cf.link_scale if has_link else None,
                tile_names=p.names, island_names=live.names())
            ocap.on_service(0, svc)
            ob.begin_run()
            ob.emit(0, "run_start", subject="sequential", ticks=T, dt=dt,
                    level=ob.level)

        wall0 = time.perf_counter()
        for t_i in range(T):
            for ev in ev_by_tick.get(t_i, ()):
                telem.event(t_i, ev["kind"],
                            **{k: v for k, v in ev.items()
                               if k not in ("tick", "kind")})
                if ob is not None:
                    ob.emit_event_dict(t_i, ev)
            alive = cf.tile_alive[t_i] if has_tile else None
            lscale = cf.link_scale[t_i] if has_link else None
            if has_stuck_rate:
                row = cf.stuck_rate[t_i]
                if applied_stuck is None or not np.array_equal(
                        row, applied_stuck, equal_nan=True):
                    applied_stuck = row     # hardware override (service only)
                    svc = self._service(cur_cfg, rate_override=applied_stuck)
                    if ocap is not None:
                        ocap.on_service(t_i, svc)
            # routing acts on the BELIEVED availability (the supervisor's
            # detection state when online detection is in the loop, else
            # the oracle mask); the true mask still gates the hardware
            route_alive = (sup.believed_alive if sup is not None else alive)

            respill = stranded_exit = None
            if has_tile and slo.on_kill != "wait":
                st.queue, st.retry_q, respill, fdrop = respill_stranded(
                    st.queue, st.retry_q, route_alive,
                    self.balancer if recover else None)
                st.dropped_fault = st.dropped_fault + fdrop.sum(axis=-1)
                if recover:
                    st.retried = st.retried + respill.sum(axis=-1)
                stranded_exit = respill + fdrop

            arr = arrivals[t_i]
            if carry is not None:
                arr = arr + carry
            retry_arr = None
            if self.balancer is not None:
                arr = self.balancer.split(
                    arr, st.queue, prev_cap,
                    alive=route_alive if recover else None)
                if recover:
                    retry_arr = self.balancer.split(respill, st.queue,
                                                    prev_cap,
                                                    alive=route_alive)
                    arr = arr + retry_arr
            out = tick_step(st, arr, svc, consts, alive=alive,
                            link_scale=lscale, retry_in=retry_arr)
            if ocap is not None:
                ocap.on_tick(t_i, out)
                if ob.tracing and out.slo_drop is not None:
                    drop_amt = float(out.slo_drop.sum())
                    if drop_amt > 0.0 and slo_span is None:
                        hit = np.nonzero(out.slo_drop > 0.0)[0]
                        slo_span = [t_i, 0.0, 0]
                        ob.emit(t_i, "slo_drop_start",
                                tiles=[p.names[a] for a in hit])
                    if slo_span is not None:
                        if drop_amt > 0.0:
                            slo_span[1] += drop_amt
                            slo_span[2] += 1
                        else:
                            ob.emit(t_i, "slo_drop_end",
                                    ticks=slo_span[2], dropped=slo_span[1])
                            slo_span = None
            if carry is not None:
                carry = out.forwarded
            if self.balancer is not None:
                prev_cap = out.cap_tick
            admitted_hist[t_i] = out.admitted
            served_hist[t_i] = out.served
            if track:
                qd = qdrop_hist[t_i]
                if stranded_exit is not None:
                    qd += stranded_exit
                if out.slo_drop is not None:
                    qd += out.slo_drop
                fh["dropped"][t_i] = st.dropped
                fh["dropped_slo"][t_i] = st.dropped_slo
                fh["dropped_fault"][t_i] = st.dropped_fault
                fh["retried"][t_i] = st.retried
                fh["queue"][t_i] = st.queue.sum()
                fh["carry"][t_i] = carry.sum() if carry is not None else 0.0

            if sup is not None:
                for ev in sup.observe(t_i, served=out.served, queue=st.queue,
                                      cap=out.cap_tick, busy=st.busy):
                    telem.event(t_i, ev["kind"],
                                **{k: v for k, v in ev.items()
                                   if k not in ("tick", "kind")})
                    if ob is not None:
                        ob.emit_event_dict(t_i, ev)

            win_busy += st.busy
            win_served += float(out.served.sum())
            win_ticks += 1
            ctl_busy += st.busy
            ctl_ticks += 1

            if cfg.telemetry_interval and (t_i + 1) % cfg.telemetry_interval == 0:
                cap_rps_now = out.cap_tick / dt
                telem.record(
                    tick=t_i, f_noc=svc["f_noc"],
                    island_rates=svc["island_rates"],
                    queue_depth=st.queue, busy=win_busy / win_ticks,
                    throughput_rps=win_served / (win_ticks * dt),
                    power_w=float(out.tile_power + out.noc_power),
                    link_util_max=float(out.rho.max(initial=0.0)),
                    link_util_mean=float(out.rho.mean()) if A else 0.0,
                    latency_est_s=float(
                        np.sum(st.queue) / max(np.sum(cap_rps_now), 1e-9)),
                    dropped=float(st.dropped),
                    dropped_slo=float(st.dropped_slo),
                    dropped_fault=float(st.dropped_fault),
                    retried=float(st.retried))
                if (ob is not None and ob.tracing
                        and self.balancer is not None):
                    w = self.balancer.weights(st.queue, prev_cap)
                    ob.emit(t_i, "lb_split", subject=self.balancer.mode,
                            mode=self.balancer.mode,
                            weights=np.round(w, 6).tolist())
                win_busy = np.zeros(A)
                win_served = 0.0
                win_ticks = 0

            if (self.controller is not None and cfg.control_interval
                    and (t_i + 1) % cfg.control_interval == 0):
                # Stream-boundness is classified against the tile's
                # *reference-rate* compute term (f_acc = 1): Fig. 4 asks
                # "is this tile's throughput set by the NoC/MEM path?",
                # and evaluating it at the currently-derated rate would
                # make the classification chase the actuator (flapping).
                t_wire_now = svc["t_wire"] * out.dyn
                new_cfg = self.controller.step(
                    tick=t_i,
                    names=p.names,
                    busy=ctl_busy / max(ctl_ticks, 1),
                    boundness=t_wire_now / (self._t_comp_ref + t_wire_now),
                    pkts_in=st.pkts_in, pkts_out=st.pkts_out,
                    rtt=st.rtt_acc,
                    queue_ticks=st.queue / np.maximum(out.cap_tick, 1e-12),
                    dead=cf.island_dead[t_i] if has_tile else None,
                    stuck=(cf.stuck[t_i]
                           if cf is not None and cf.has_stuck else None))
                ctl_busy = np.zeros(A)
                ctl_ticks = 0
                if ob is not None and ob.tracing and self.controller.actions:
                    act = self.controller.actions[-1]
                    if act.tick == t_i and getattr(act, "clamped", ()):
                        # requests pushed back into the tech node's legal
                        # DVFS ratio range before quantization
                        ob.emit(t_i, "dfs_clamp",
                                subject=",".join(act.clamped),
                                islands=list(act.clamped),
                                requested={i: act.requested[i]
                                           for i in act.clamped})
                    if act.tick == t_i and act.guarded != guard_prev:
                        if act.guarded:
                            ob.emit(t_i, "dfs_guard",
                                    subject=",".join(act.guarded),
                                    islands=list(act.guarded),
                                    requested={i: act.requested[i]
                                               for i in act.guarded})
                        guard_prev = act.guarded
                if new_cfg is not None:
                    cur_cfg = new_cfg
                    svc = self._service(cur_cfg,
                                        rate_override=applied_stuck)
                    if ocap is not None:
                        # the new rates take effect at the NEXT tick
                        ocap.on_service(t_i + 1, svc)
                        ob.emit(t_i, "dfs_commit",
                                subject=f"v{new_cfg.version}",
                                version=new_cfg.version,
                                rates={i.name: i.rate
                                       for i in new_cfg.islands})
                    telem.event(t_i, "dfs_commit",
                                version=new_cfg.version,
                                rates={i.name: i.rate
                                       for i in new_cfg.islands})
        elapsed = time.perf_counter() - wall0

        # kept for post-run analysis and the differential test suite
        self.last_state = st
        self.last_histories = (admitted_hist, served_hist)
        self.last_fault_histories = (
            None if fh is None else {**fh, "queue_drops": qdrop_hist})

        # chained patterns complete a request ONCE, at its exit stage;
        # the chain-free expression is kept verbatim (bit-for-bit)
        completed = (float(served_hist.sum()) if self._forward is None
                     else float((served_hist
                                 * self._compiled_flows.exit_mask).sum()))
        offered = float(arrivals.sum())
        if ocap is not None:
            # lazy: the vectorized reconstruction runs on the first
            # observer.counters read, not inside the engine's wall clock
            ob.attach_lazy(lambda: ocap.finalize(admitted_hist, served_hist,
                                                 qdrop_hist))
            if slo_span is not None:        # span still open at run end
                ob.emit(max(T - 1, 0), "slo_drop_end",
                        ticks=slo_span[2], dropped=slo_span[1])
            ob.emit(max(T - 1, 0), "run_end", subject="sequential",
                    completed=completed, offered=offered,
                    dropped=float(st.dropped),
                    swaps=(self.controller.actuator.swaps - swaps0
                           if self.controller is not None else 0))
        p50, p99 = latency_percentiles(admitted_hist, served_hist, dt,
                                       queue_drops=qdrop_hist)
        sim_seconds = T * dt
        return SimResult(
            ticks=T, dt=dt, offered=offered, completed=completed,
            dropped=float(st.dropped), residual=float(st.queue.sum()),
            throughput_rps=completed / sim_seconds if sim_seconds else 0.0,
            p50_latency_s=p50, p99_latency_s=p99,
            energy_j=float(st.energy),
            # zero-completion (all-dropped) runs have no meaningful energy
            # per request: signal NaN explicitly instead of an
            # astronomically large finite number (rankers mask it)
            energy_per_request_j=(float(st.energy) / completed
                                  if completed > 0 else float("nan")),
            mean_power_w=float(st.energy) / sim_seconds if sim_seconds else 0.0,
            swaps=(self.controller.actuator.swaps - swaps0
                   if self.controller is not None else 0),
            elapsed_wall_s=elapsed, telemetry=telem,
            dropped_slo=float(st.dropped_slo),
            dropped_fault=float(st.dropped_fault),
            retried=float(st.retried))

    @staticmethod
    def _latency_percentiles(admitted: np.ndarray, served: np.ndarray,
                             dt: float) -> Tuple[float, float]:
        return latency_percentiles(admitted, served, dt)
