"""Run-time monitoring infrastructure for the co-sim engines.

The paper's third pillar (next to accelerator replication and per-island
DFS) is a dedicated monitoring subsystem exposing "a variety of statistics
related to the traffic on the interconnect and the accelerators'
performance at run time".  This module is that subsystem for the
reproduction, shared by all three engines (sequential ``engine.py``,
batched NumPy ``batch.py``, and the jitted ``lax.scan`` backend):

* :class:`CounterPlane` — the hardware-counter plane: per-accelerator
  performance counters (invocations, busy/stall ticks, offered work,
  effective-vs-nominal capacity, hop-weighted traffic, contention
  exposure), per-link NoC counters (flit traffic, utilization integral,
  peak utilization), and the per-island energy integral.  Counters are
  windowed via :meth:`CounterPlane.reset`, which mirrors the
  ``manual_reset(counters, tiles=, kinds=)`` scoping semantics
  ``core/monitor.py`` established for the C3 monitor.
* :class:`ControlTrace` + :class:`TraceEvent` — structured control-plane
  tracing: schema'd, monotonically tick-stamped events for DFS
  commits/guard discards, load-balancer splits, fault transitions,
  detector belief flips, and SLO-drop spans, in a ring-bounded store with
  JSONL export (replacing the ad-hoc ``Telemetry.event`` dict soup).
* :class:`Observer` — the engine-facing façade with the ``level=`` knob
  (``"off"`` / ``"counters"`` / ``"full"``) so ``closed_loop_score`` can
  run thousands of designs with counters on and tracing off.
* :class:`Profiler` / :func:`profiled` — wall-clock phase profiling for
  sweep chunks, tick loops, and scan compilation, feeding per-phase
  breakdowns into ``BENCH_*`` rows.

Zero-perturbation contract: everything here only *reads* the arrays
``tick_step`` already computes.  The sequential engine uses the
:class:`DeferredCapture` (two preallocated slot-writes per tick, full
vectorized reconstruction after the run); the batched NumPy engine uses
the :class:`IncrementalCapture` (per-tick adds, cheap next to its
``(B, A, L)`` einsum); the jax backend carries plain accumulators through
the scan and builds the plane post-hoc via :meth:`CounterPlane.from_arrays`.
Simulated numerics are bit-for-bit identical with monitoring on or off.
"""

from __future__ import annotations

import json
import time
from collections import deque
from contextlib import ContextDecorator
from dataclasses import dataclass, field
from typing import Deque, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.perfmodel import chip_power
from repro.sim.telemetry import _json_safe

__all__ = [
    "LEVELS",
    "TRACE_KINDS",
    "TraceEvent",
    "ControlTrace",
    "CounterPlane",
    "DeferredCapture",
    "IncrementalCapture",
    "Observer",
    "Profiler",
    "profiled",
    "get_profiler",
    "reset_profiler",
    "export_metrics",
]

LEVELS = ("off", "counters", "full")

PKT_BYTES = 512.0   # matches engine.py / core/monitor.py

# ---------------------------------------------------------------------------
# Control-plane trace
# ---------------------------------------------------------------------------

#: The trace schema: every event kind the control plane can emit, with the
#: payload keys it carries.  ``emit`` rejects unknown kinds so the trace
#: stays machine-readable (the whole point over ``Telemetry.event``).
TRACE_KINDS: Dict[str, str] = {
    "run_start": "engine run begins (ticks, dt, level)",
    "run_end": "engine run ends (completed, dropped, swaps)",
    "dfs_commit": "DFS actuator committed new island rates (version, rates)",
    "dfs_guard": "DFS guard discarded a requested move (islands, requested)",
    "dfs_clamp": "DFS request clamped to the tech node's legal DVFS "
                 "range (islands, requested)",
    "lb_split": "LoadBalancer split decision snapshot (mode, weights)",
    "slo_drop_start": "SLO deadline drops began (tiles)",
    "slo_drop_end": "SLO deadline drop span ended (ticks, dropped)",
    "fault_kill": "tile(s) killed (tiles)",
    "fault_revive": "tile(s) revived (tiles)",
    "fault_link_degrade": "link bandwidth degraded (a, b, scale)",
    "fault_link_restore": "link bandwidth restored (a, b)",
    "fault_stuck": "island actuator stuck at a hardware rate (island, rate)",
    "fault_unstuck": "island actuator released (island)",
    "detected_dead": "online detector believes tile(s) dead (tiles)",
    "detected_alive": "online detector believes tile(s) recovered (tiles)",
    "straggler_suspect": "online detector flags straggler tile(s) (tiles)",
}


@dataclass(frozen=True)
class TraceEvent:
    """One schema'd control-plane event: monotonic tick, registered kind,
    a short human subject (tile/island/link names), structured payload."""
    tick: int
    kind: str
    subject: str = ""
    data: Dict[str, object] = field(default_factory=dict)

    def to_dict(self) -> Dict[str, object]:
        return {"tick": self.tick, "kind": self.kind,
                "subject": self.subject, "data": _json_safe(self.data)}

    @classmethod
    def from_dict(cls, d: Mapping[str, object]) -> "TraceEvent":
        return cls(tick=int(d["tick"]), kind=str(d["kind"]),
                   subject=str(d.get("subject", "")),
                   data=dict(d.get("data", {})))


def _subject_of(kind: str, payload: Mapping[str, object]) -> str:
    """Derive a stable, human-readable subject from a payload dict."""
    if "tiles" in payload:
        tiles = payload["tiles"]
        if isinstance(tiles, (list, tuple)):
            return ",".join(str(t) for t in tiles)
        return str(tiles)
    if "island" in payload:
        return str(payload["island"])
    if "a" in payload and "b" in payload:
        return f"{payload['a']}-{payload['b']}"
    if "domain" in payload:
        return str(payload["domain"])
    return ""


class ControlTrace:
    """Ring-bounded store of :class:`TraceEvent` with JSONL export.

    Enforces the schema (``kind`` must be registered in :data:`TRACE_KINDS`)
    and monotonic tick stamps; bounded by ``capacity`` like every other
    long-soak store in the repo (oldest events fall off first).
    """

    def __init__(self, capacity: int = 4096):
        self.capacity = int(capacity)
        self._events: Deque[TraceEvent] = deque(maxlen=self.capacity)
        self._last_tick = -1
        self.total_emitted = 0

    def __len__(self) -> int:
        return len(self._events)

    def emit(self, tick: int, kind: str, subject: str = "",
             **data: object) -> TraceEvent:
        if kind not in TRACE_KINDS:
            raise ValueError(
                f"unknown trace kind {kind!r}; registered kinds: "
                f"{sorted(TRACE_KINDS)}")
        tick = int(tick)
        if tick < self._last_tick:
            raise ValueError(
                f"non-monotonic trace tick {tick} after {self._last_tick}")
        self._last_tick = tick
        if not subject:
            subject = _subject_of(kind, data)
        ev = TraceEvent(tick=tick, kind=kind, subject=subject,
                        data=_json_safe(data))
        self._events.append(ev)
        self.total_emitted += 1
        return ev

    def events(self, kind: Optional[str] = None) -> List[TraceEvent]:
        if kind is None:
            return list(self._events)
        return [e for e in self._events if e.kind == kind]

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self._events:
            out[e.kind] = out.get(e.kind, 0) + 1
        return out

    def spans(self, start_kind: str, end_kind: str) -> List[Tuple[int, int]]:
        """(start_tick, end_tick) pairs for edge-triggered span events."""
        out: List[Tuple[int, int]] = []
        open_tick: Optional[int] = None
        for e in self._events:
            if e.kind == start_kind and open_tick is None:
                open_tick = e.tick
            elif e.kind == end_kind and open_tick is not None:
                out.append((open_tick, e.tick))
                open_tick = None
        return out

    # -- JSONL round trip ------------------------------------------------
    def to_jsonl(self) -> str:
        return "\n".join(json.dumps(e.to_dict()) for e in self._events) + (
            "\n" if self._events else "")

    @classmethod
    def from_jsonl(cls, text: str, capacity: int = 4096) -> "ControlTrace":
        tr = cls(capacity=capacity)
        for line in text.splitlines():
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            ev = TraceEvent.from_dict(d)
            tr._events.append(ev)
            tr._last_tick = max(tr._last_tick, ev.tick)
            tr.total_emitted += 1
        return tr


# ---------------------------------------------------------------------------
# Hardware-counter plane
# ---------------------------------------------------------------------------

TILE_KINDS = ("offered", "invocations", "busy_ticks", "stall_ticks",
              "cap_sum", "hop_flits", "slowdown_sum")
LINK_KINDS = ("flits", "util_sum", "peak_util")
ISLAND_KINDS = ("energy_j",)
STALL_EPS = 1e-9    # queue threshold distinguishing exact-0 from cumsum dust


class CounterPlane:
    """The hardware-counter plane: per-tile / per-link / per-island
    accumulators with optional leading batch axes.

    Per-tile (``lead + (A,)``):

    - ``offered``       — Σ admitted requests
    - ``invocations``   — Σ served requests (accelerator invocations)
    - ``busy_ticks``    — Σ busy fraction (tick-integral of utilization)
    - ``stall_ticks``   — Σ 1[queue backlog after the tick > ε]
    - ``cap_sum``       — Σ per-tick capacity (nominal work the tile could
      have served; ``invocations / cap_sum`` is effective vs. nominal rate)
    - ``hop_flits``     — Σ served · pkts/req · hop count (hop-weighted
      traffic the tile's stream put on the fabric)
    - ``slowdown_sum``  — Σ (contention slowdown − 1) (exposure integral)

    Per-link (``lead + (L,)``):

    - ``flits``     — Σ offered link load / flit size
    - ``util_sum``  — Σ per-tick link utilization (load / f_noc-scaled bw)
    - ``peak_util`` — max-latched per-tick link utilization

    Per-island (``lead + (I,)``): ``energy_j`` — the energy integral, NoC
    share booked to the ``noc_mem`` island.

    :meth:`reset` mirrors ``core/monitor.py:manual_reset`` scoping —
    ``kinds=`` selects which counters clear (default: all), ``tiles=``
    restricts tile-kind clears to named/indexed tiles.
    """

    def __init__(self, n_tiles: int, n_links: int, n_islands: int, *,
                 lead: Tuple[int, ...] = (),
                 tile_names: Sequence[str] = (),
                 island_names: Sequence[str] = ()):
        self.n_tiles = int(n_tiles)
        self.n_links = int(n_links)
        self.n_islands = int(n_islands)
        self.lead = tuple(int(x) for x in lead)
        self.tile_names = tuple(tile_names)
        self.island_names = tuple(island_names)
        self.tile = {k: np.zeros(self.lead + (self.n_tiles,))
                     for k in TILE_KINDS}
        self.link = {k: np.zeros(self.lead + (self.n_links,))
                     for k in LINK_KINDS}
        self.island = {k: np.zeros(self.lead + (self.n_islands,))
                       for k in ISLAND_KINDS}
        self.ticks = np.zeros(self.lead)

    # -- construction ----------------------------------------------------
    @classmethod
    def from_arrays(cls, *, tile: Mapping[str, np.ndarray],
                    link: Mapping[str, np.ndarray],
                    island: Mapping[str, np.ndarray],
                    ticks, lead: Tuple[int, ...] = (),
                    tile_names: Sequence[str] = (),
                    island_names: Sequence[str] = ()) -> "CounterPlane":
        """Build a plane from already-accumulated arrays (the jax backend
        hands its scan-carry accumulators over through this)."""
        any_tile = next(iter(tile.values()))
        any_link = next(iter(link.values())) if link else np.zeros(lead + (0,))
        any_isl = next(iter(island.values())) if island else np.zeros(lead + (0,))
        cp = cls(any_tile.shape[-1], any_link.shape[-1], any_isl.shape[-1],
                 lead=lead, tile_names=tile_names, island_names=island_names)
        for k in TILE_KINDS:
            if k in tile:
                cp.tile[k] = np.asarray(tile[k], dtype=np.float64)
        for k in LINK_KINDS:
            if k in link:
                cp.link[k] = np.asarray(link[k], dtype=np.float64)
        for k in ISLAND_KINDS:
            if k in island:
                cp.island[k] = np.asarray(island[k], dtype=np.float64)
        cp.ticks = np.asarray(ticks, dtype=np.float64)
        return cp

    # -- windowing -------------------------------------------------------
    def reset(self, kinds: Optional[Sequence[str]] = None,
              tiles: Optional[Sequence] = None) -> None:
        """Clear counters, ``manual_reset``-style.

        ``kinds`` — counter names to clear (default: every counter);
        ``tiles`` — restrict *tile-kind* clears to these tiles (names or
        indices); link/island kinds ignore the tile scope, as the monitor's
        per-tile scoping did for its per-tile counters.
        """
        if kinds is None:
            kinds = TILE_KINDS + LINK_KINDS + ISLAND_KINDS + ("ticks",)
        unknown = [k for k in kinds
                   if k not in TILE_KINDS + LINK_KINDS + ISLAND_KINDS
                   and k != "ticks"]
        if unknown:
            raise ValueError(f"unknown counter kinds {unknown}")
        idx = None
        if tiles is not None:
            idx = [self.tile_names.index(t) if isinstance(t, str) else int(t)
                   for t in tiles]
        for k in kinds:
            if k in TILE_KINDS:
                if idx is None:
                    self.tile[k][...] = 0.0
                else:
                    self.tile[k][..., idx] = 0.0
            elif k in LINK_KINDS:
                self.link[k][...] = 0.0
            elif k in ISLAND_KINDS:
                self.island[k][...] = 0.0
            elif k == "ticks" and idx is None:
                self.ticks = np.zeros(self.lead)

    # -- views -----------------------------------------------------------
    def design(self, b: int) -> "CounterPlane":
        """One design's scalar-lead view of a batched plane (copies)."""
        if not self.lead:
            raise ValueError("design() needs a batched (lead-axis) plane")
        cp = CounterPlane(self.n_tiles, self.n_links, self.n_islands,
                          lead=self.lead[1:], tile_names=self.tile_names,
                          island_names=self.island_names)
        for k in TILE_KINDS:
            cp.tile[k] = self.tile[k][b].copy()
        for k in LINK_KINDS:
            cp.link[k] = self.link[k][b].copy()
        for k in ISLAND_KINDS:
            cp.island[k] = self.island[k][b].copy()
        cp.ticks = np.asarray(self.ticks)[b].copy()
        return cp

    def snapshot(self) -> Dict[str, object]:
        return {
            "ticks": np.asarray(self.ticks).copy(),
            "tile": {k: v.copy() for k, v in self.tile.items()},
            "link": {k: v.copy() for k, v in self.link.items()},
            "island": {k: v.copy() for k, v in self.island.items()},
            "tile_names": self.tile_names,
            "island_names": self.island_names,
        }

    # -- derived rates ---------------------------------------------------
    def _per_tick(self, x: np.ndarray) -> np.ndarray:
        t = np.maximum(np.asarray(self.ticks, dtype=np.float64), 1.0)
        return x / t[..., None] if x.ndim > np.ndim(t) else x / t

    def effective_rate(self) -> np.ndarray:
        """Served / nominal-capacity per tile — the paper's effective vs.
        nominal accelerator rate."""
        cap = self.tile["cap_sum"]
        return np.where(cap > 0.0, self.tile["invocations"]
                        / np.where(cap > 0.0, cap, 1.0), 0.0)

    def mean_busy(self) -> np.ndarray:
        return self._per_tick(self.tile["busy_ticks"])

    def stall_frac(self) -> np.ndarray:
        return self._per_tick(self.tile["stall_ticks"])

    def mean_slowdown(self) -> np.ndarray:
        return 1.0 + self._per_tick(self.tile["slowdown_sum"])

    def link_utilization(self) -> np.ndarray:
        return self._per_tick(self.link["util_sum"])

    def summary(self) -> Dict[str, float]:
        """Scalar roll-up (per-design when lead axes are present this
        reduces over them too) — what ``closed_loop_score`` attaches to
        each survivor."""
        inv = self.tile["invocations"]
        return {
            "ticks": float(np.asarray(self.ticks).max(initial=0.0)),
            "offered": float(self.tile["offered"].sum()),
            "invocations": float(inv.sum()),
            "busy_frac": float(self.mean_busy().mean()) if inv.size else 0.0,
            "stall_frac": float(self.stall_frac().mean()) if inv.size else 0.0,
            "effective_rate": float(self.effective_rate().mean())
            if inv.size else 0.0,
            "hop_flits": float(self.tile["hop_flits"].sum()),
            "mean_slowdown": float(self.mean_slowdown().mean())
            if inv.size else 1.0,
            "link_flits": float(self.link["flits"].sum()),
            "peak_link_util": float(self.link["peak_util"].max(initial=0.0)),
            "mean_link_util": float(self.link_utilization().mean())
            if self.link["util_sum"].size else 0.0,
            "energy_j": float(self.island["energy_j"].sum()),
        }

    def allclose(self, other: "CounterPlane", *, rtol: float = 1e-9,
                 atol: float = 1e-9) -> bool:
        for mine, theirs in ((self.tile, other.tile),
                             (self.link, other.link),
                             (self.island, other.island)):
            for k in mine:
                if not np.allclose(mine[k], theirs[k], rtol=rtol, atol=atol):
                    return False
        return bool(np.allclose(self.ticks, other.ticks,
                                rtol=rtol, atol=atol))


# ---------------------------------------------------------------------------
# Capture strategies
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CaptureContext:
    """Everything a capture needs from the engine, read-only: the
    ``StepConsts`` digest plus the tile->island map."""
    base_mbps: np.ndarray
    req_mb: np.ndarray
    hop_counts: np.ndarray
    link_bw: float
    noc_power_share: float
    dt: float
    island_of_tile: np.ndarray      # (A,) -> island index
    noc_island: int
    n_links: int
    n_islands: int
    dynamic_contention: bool = True
    own_demand: Optional[np.ndarray] = None     # (..., A) flow MB/s
    inc: Optional[np.ndarray] = None            # (..., A, L) incidence

    @classmethod
    def from_consts(cls, consts, *, island_of_tile: np.ndarray,
                    noc_island: int, n_links: int,
                    n_islands: int) -> "CaptureContext":
        return cls(base_mbps=np.asarray(consts.base_mbps, float),
                   req_mb=np.asarray(consts.req_mb, float),
                   hop_counts=np.asarray(consts.hop_counts, float),
                   link_bw=float(consts.link_bw),
                   noc_power_share=float(consts.noc_power_share),
                   dt=float(consts.dt),
                   island_of_tile=np.asarray(island_of_tile, np.int64),
                   noc_island=int(noc_island), n_links=int(n_links),
                   n_islands=int(n_islands),
                   dynamic_contention=bool(consts.dynamic_contention),
                   own_demand=(None if consts.own_demand is None
                               else np.asarray(consts.own_demand, float)),
                   inc=(None if consts.inc is None
                        else np.asarray(consts.inc, float)))

    def island_onehot(self) -> np.ndarray:
        """(A, I) membership used to scatter per-tile power to islands."""
        A = self.island_of_tile.shape[0]
        oh = np.zeros((A, self.n_islands))
        oh[np.arange(A), self.island_of_tile] = 1.0
        return oh


class DeferredCapture:
    """Deferred capture for the Python tick loops (sequential engine and
    the batched NumPy engine, via ``lead=(B,)``): the per-tick hot path
    is ONE store of the ``dyn`` row — a reference append for the
    sequential loop, a preallocated slot copy for the batched one — plus
    piecewise-constant service segments recorded at each recompute.
    Everything else, the link loads included, is reconstructed
    vectorized at :meth:`finalize` from the histories the engine already
    keeps: the wire load at tick ``t`` is a pure function of the
    *previous* tick's busy fractions (``tick_step`` contracts
    ``own_demand * busy`` over the incidence before updating ``busy``),
    and busy itself replays exactly as ``served / cap``."""

    def __init__(self, ctx: CaptureContext, T: int, *,
                 lead: Tuple[int, ...] = (),
                 tile_alive: Optional[np.ndarray] = None,
                 link_scale: Optional[np.ndarray] = None,
                 tile_names: Sequence[str] = (),
                 island_names: Sequence[str] = ()):
        self.ctx = ctx
        self.T = int(T)
        self.lead = tuple(int(x) for x in lead)
        A = ctx.base_mbps.shape[-1]
        # batched runs copy each (B, A) dyn row into a preallocated
        # history (keeping B-wide rows alive would defeat the allocator's
        # buffer recycling); the sequential loop's rows are a few dozen
        # bytes, so a plain reference append is both safe and ~10x
        # cheaper than a numpy slot write there
        if self.lead:
            self._dyn_buf: Optional[np.ndarray] = np.empty(
                (self.T,) + self.lead + (A,))
            self._dyn_list: Optional[List[np.ndarray]] = None
        else:
            self._dyn_buf = None
            self._dyn_list = []
        self._segments: List[Tuple[int, Dict[str, np.ndarray]]] = []
        self._tile_alive = tile_alive            # (T, A) or None
        self._link_scale = link_scale            # (T, L) or None
        self.tile_names = tuple(tile_names)
        self.island_names = tuple(island_names)
        self.plane: Optional[CounterPlane] = None

    # hot path -----------------------------------------------------------
    def on_service(self, start_tick: int, svc: Mapping[str, object]) -> None:
        """Record a service-term segment starting at ``start_tick``
        (run start, stuck-actuator apply, or the tick after a commit)."""
        self._segments.append((int(start_tick), {
            "t_comp": np.array(svc["t_comp"], dtype=np.float64, copy=True),
            "t_wire": np.array(svc["t_wire"], dtype=np.float64, copy=True),
            "t_ref": np.array(svc["t_ref"], dtype=np.float64, copy=True),
            "f_tile": np.array(svc["f_tile"], dtype=np.float64, copy=True),
            "f_noc": np.array(svc["f_noc"], dtype=np.float64, copy=True),
        }))

    def on_tick(self, t_i: int, out) -> None:
        if self._dyn_list is not None:
            self._dyn_list.append(out.dyn)
        else:
            self._dyn_buf[t_i] = out.dyn

    # reconstruction -----------------------------------------------------
    def finalize(self, admitted: np.ndarray, served: np.ndarray,
                 queue_drops: Optional[np.ndarray] = None) -> CounterPlane:
        """Rebuild the full counter plane from ``(T,) + lead + (A,)``
        histories + the captured dyn/load rows.  Capacity is recomputed
        segment-by-segment with the *identical* float expression
        ``tick_step`` used, so ``busy = served / cap`` reconstructs the
        exact per-tick busy fractions the engine produced."""
        ctx, T, lead = self.ctx, self.T, self.lead
        A = ctx.base_mbps.shape[-1]
        cp = CounterPlane(A, ctx.n_links, ctx.n_islands, lead=lead,
                          tile_names=self.tile_names,
                          island_names=self.island_names)
        if T == 0:
            self.plane = cp
            return cp
        segs = sorted(self._segments, key=lambda s: s[0])
        assert segs and segs[0][0] == 0, "on_service(0, svc) never recorded"
        bounds = [s[0] for s in segs] + [T]

        dyn_all = (self._dyn_buf if self._dyn_buf is not None
                   else np.stack(self._dyn_list))

        cap = np.empty((T,) + lead + (A,))
        f_tile = np.empty((T,) + lead + (A,))
        f_noc = np.empty((T,) + lead)
        for (s, svc), e in zip(segs, bounds[1:]):
            if e <= s:
                continue
            dyn = dyn_all[s:e]
            # identical op order to tick_step's cap_tick expression
            cap[s:e] = (ctx.base_mbps * svc["t_ref"]
                        / (svc["t_comp"] + svc["t_wire"] * dyn)
                        / ctx.req_mb) * ctx.dt
            f_tile[s:e] = svc["f_tile"]
            f_noc[s:e] = svc["f_noc"]

        alive = self._tile_alive
        if alive is not None and lead:
            # the shared (T, A) fault mask broadcast against lead axes
            alive = np.asarray(alive)[
                (slice(None),) + (None,) * len(lead) + (slice(None),)]
        if alive is None:
            cap_eff = cap
            busy = served / cap
        else:
            cap_eff = cap * alive[:T]
            busy = np.where(cap_eff > 0.0,
                            served / np.where(cap_eff > 0.0, cap_eff, 1.0),
                            0.0)

        # queue after each tick (per tile): cumulative admitted − exits.
        exits = served if queue_drops is None else served + queue_drops
        queue_after = np.cumsum(admitted - exits, axis=0)

        pkt = ctx.req_mb * 1e6 / PKT_BYTES
        cp.tile["offered"] = admitted.sum(axis=0)
        cp.tile["invocations"] = served.sum(axis=0)
        cp.tile["busy_ticks"] = busy.sum(axis=0)
        cp.tile["stall_ticks"] = (queue_after > STALL_EPS).sum(axis=0).astype(float)
        cp.tile["cap_sum"] = cap_eff.sum(axis=0)
        cp.tile["hop_flits"] = (served * pkt * ctx.hop_counts).sum(axis=0)
        cp.tile["slowdown_sum"] = (dyn_all - 1.0).sum(axis=0)

        if ctx.dynamic_contention and ctx.own_demand is not None \
                and ctx.inc is not None:
            # replay the wire loads with tick_step's own contraction: the
            # load at tick t is driven by the busy fractions of tick t-1
            # (busy starts the run at zero), then per-segment reductions
            # divide by the piecewise-constant NoC frequency AFTER the
            # tickwise sum/max — division by a positive constant is
            # monotonic, so the maximum commutes with it
            busy_prev = np.concatenate(
                [np.zeros((1,) + lead + (A,)), busy[:-1]], axis=0)
            loads = np.einsum("...a,...al->...l",
                              ctx.own_demand * busy_prev, ctx.inc)
            if self._link_scale is not None:
                lscale = np.asarray(self._link_scale)[:T]
                if lead:
                    lscale = lscale[(slice(None),) + (None,) * len(lead)
                                    + (slice(None),)]
                loads = loads / lscale
            flit_sum = np.zeros(lead + (ctx.n_links,))
            util_sum = np.zeros(lead + (ctx.n_links,))
            peak = np.zeros(lead + (ctx.n_links,))
            for (s, svc), e in zip(segs, bounds[1:]):
                if e <= s:
                    continue
                seg_sum = loads[s:e].sum(axis=0)
                seg_max = loads[s:e].max(axis=0, initial=0.0)
                denom = ctx.link_bw * svc["f_noc"][..., None]
                flit_sum += seg_sum
                util_sum += seg_sum / denom
                np.maximum(peak, seg_max / denom, out=peak)
            cp.link["flits"] = flit_sum / PKT_BYTES
            cp.link["util_sum"] = util_sum
            cp.link["peak_util"] = peak

        power = chip_power(f_tile, busy)
        if alive is not None:
            power = power * alive[:T]
        onehot = ctx.island_onehot()
        energy = (power.sum(axis=0) * ctx.dt) @ onehot
        if ctx.noc_island >= 0:
            noc_energy = (ctx.noc_power_share
                          * chip_power(f_noc, 1.0)).sum(axis=0) * ctx.dt
            energy[..., ctx.noc_island] += noc_energy
        cp.island["energy_j"] = energy
        cp.ticks = np.full(lead, float(T))
        self.plane = cp
        return cp


class IncrementalCapture:
    """Batched-NumPy capture: straight per-tick accumulation into a
    ``lead=(B,)`` plane.  The adds are O(B·(A+L)) elementwise work per
    tick — small next to the engine's (B, A, L) link contraction — and
    keep memory bounded at large B (no (T, B, L) buffers)."""

    def __init__(self, ctx: CaptureContext, *, lead: Tuple[int, ...],
                 tile_names: Sequence[str] = (),
                 island_names: Sequence[str] = ()):
        self.ctx = ctx
        A = ctx.base_mbps.shape[-1]
        self.plane = CounterPlane(A, ctx.n_links, ctx.n_islands, lead=lead,
                                  tile_names=tile_names,
                                  island_names=island_names)
        self._onehot = ctx.island_onehot()
        self._pkt = ctx.req_mb * 1e6 / PKT_BYTES

    def on_tick(self, out, *, queue: np.ndarray, busy: np.ndarray,
                svc: Mapping[str, object],
                alive: Optional[np.ndarray] = None) -> None:
        ctx, cp = self.ctx, self.plane
        t = cp.tile
        t["offered"] += out.admitted
        t["invocations"] += out.served
        t["busy_ticks"] += busy
        t["stall_ticks"] += (queue > STALL_EPS)
        t["cap_sum"] += out.cap_tick
        t["hop_flits"] += out.served * self._pkt * ctx.hop_counts
        t["slowdown_sum"] += out.dyn - 1.0
        if ctx.dynamic_contention and out.link_loads is not None:
            f_noc = np.asarray(svc["f_noc"], dtype=np.float64)
            util = out.link_loads / (ctx.link_bw * f_noc[..., None])
            ln = cp.link
            ln["flits"] += out.link_loads / PKT_BYTES
            ln["util_sum"] += util
            np.maximum(ln["peak_util"], util, out=ln["peak_util"])
        power = chip_power(np.asarray(svc["f_tile"], dtype=np.float64), busy)
        if alive is not None:
            power = power * alive
        cp.island["energy_j"] += (power @ self._onehot) * ctx.dt
        if ctx.noc_island >= 0:
            noc_p = ctx.noc_power_share * chip_power(
                np.asarray(svc["f_noc"], dtype=np.float64), 1.0)
            cp.island["energy_j"][..., ctx.noc_island] += noc_p * ctx.dt
        cp.ticks = cp.ticks + 1.0


# ---------------------------------------------------------------------------
# Profiler
# ---------------------------------------------------------------------------


class Profiler:
    """Wall-clock phase accumulator: ``with prof.profile("scan_compile"):``
    around a code region books its elapsed time under that phase name."""

    def __init__(self) -> None:
        self.phases: Dict[str, List[float]] = {}   # name -> [total_s, count]

    def record(self, name: str, seconds: float) -> None:
        slot = self.phases.setdefault(name, [0.0, 0])
        slot[0] += float(seconds)
        slot[1] += 1

    def profile(self, name: str) -> "_PhaseTimer":
        return _PhaseTimer(self, name)

    def summary(self) -> Dict[str, Dict[str, float]]:
        return {name: {"total_s": total, "count": count,
                       "mean_s": total / count if count else 0.0}
                for name, (total, count) in sorted(self.phases.items())}

    def reset(self) -> None:
        self.phases.clear()


class _PhaseTimer(ContextDecorator):
    def __init__(self, profiler: Profiler, name: str):
        self.profiler = profiler
        self.name = name
        self._t0 = 0.0

    def __enter__(self) -> "_PhaseTimer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self.profiler.record(self.name, time.perf_counter() - self._t0)
        return False


_GLOBAL_PROFILER = Profiler()


def get_profiler() -> Profiler:
    """The process-global phase profiler (what :func:`profiled` books to
    when no explicit profiler is given)."""
    return _GLOBAL_PROFILER


def reset_profiler() -> None:
    _GLOBAL_PROFILER.reset()


def profiled(name: str, profiler: Optional[Profiler] = None) -> _PhaseTimer:
    """Context manager / decorator timing a phase into ``profiler`` (the
    global one by default)::

        with observe.profiled("sweep_chunk"):
            evaluate(chunk)
    """
    return _PhaseTimer(profiler or _GLOBAL_PROFILER, name)


# ---------------------------------------------------------------------------
# Observer façade
# ---------------------------------------------------------------------------


class Observer:
    """Engine-facing monitoring façade with the ``level=`` knob.

    - ``"off"``       — no counters, no tracing (the engines skip every hook)
    - ``"counters"``  — hardware-counter plane only (the cheap mode the
      DSE loop runs at scale; also what the jax backend supports)
    - ``"full"``      — counters + control-plane tracing (+ SLO spans,
      balancer snapshots)

    One observer instance is bound to one engine; after a run,
    ``observer.counters`` holds the :class:`CounterPlane` and
    ``observer.trace`` the :class:`ControlTrace`.
    """

    def __init__(self, level: str = "counters", *,
                 trace_capacity: int = 4096,
                 profiler: Optional[Profiler] = None):
        if level not in LEVELS:
            raise ValueError(f"level must be one of {LEVELS}, got {level!r}")
        self.level = level
        self.trace = ControlTrace(capacity=trace_capacity)
        self._counters: Optional[CounterPlane] = None
        self._counters_thunk = None
        self.profiler = profiler or get_profiler()

    @property
    def counters(self) -> Optional[CounterPlane]:
        """The last run's :class:`CounterPlane` — materialized lazily on
        first read.  The engines hand over a finalize thunk instead of a
        built plane (:meth:`attach_lazy`), so the hot tick loop never
        pays the vectorized reconstruction; it is booked to the phase
        profiler here, at read time."""
        if self._counters is None and self._counters_thunk is not None:
            thunk, self._counters_thunk = self._counters_thunk, None
            with self.profiler.profile("counters_finalize"):
                self._counters = thunk()
        return self._counters

    # -- coercion --------------------------------------------------------
    @classmethod
    def coerce(cls, observe) -> Optional["Observer"]:
        """Normalize an engine's ``observe=`` argument: ``None``/``"off"``
        -> no observer; a level string -> fresh observer; an
        :class:`Observer` -> itself."""
        if observe is None or observe == "off":
            return None
        if isinstance(observe, Observer):
            return observe if observe.enabled else None
        if isinstance(observe, str):
            return cls(level=observe)
        raise TypeError(f"observe= expects None, a level string in {LEVELS},"
                        f" or an Observer; got {type(observe).__name__}")

    @property
    def enabled(self) -> bool:
        return self.level != "off"

    @property
    def tracing(self) -> bool:
        return self.level == "full"

    def begin_run(self) -> None:
        """Reset per-run state (engines call this at run start): each run
        gets a fresh trace — mirroring :meth:`attach`, which replaces the
        counter plane — so a reused observer never trips the trace's
        monotonic-tick guard on the next run's tick 0."""
        self.trace = ControlTrace(capacity=self.trace.capacity)

    # -- tracing ---------------------------------------------------------
    def emit(self, tick: int, kind: str, subject: str = "",
             **data: object) -> None:
        if self.tracing:
            self.trace.emit(tick, kind, subject, **data)

    def emit_event_dict(self, tick: int, ev: Mapping[str, object]) -> None:
        """Adapter for the compiled-fault / supervisor event dicts: maps
        their ``kind`` + payload onto the trace schema."""
        if not self.tracing:
            return
        kind = str(ev["kind"])
        if kind not in TRACE_KINDS:
            return                      # foreign event kinds stay in telemetry
        payload = {k: v for k, v in ev.items() if k not in ("tick", "kind")}
        self.trace.emit(tick, kind, **payload)

    # -- capture construction -------------------------------------------
    def capture_sequential(self, *, T: int, consts, island_of_tile,
                           noc_island: int, n_links: int, n_islands: int,
                           lead=(), tile_alive=None, link_scale=None,
                           tile_names=(), island_names=()
                           ) -> DeferredCapture:
        """Deferred capture for the Python tick loops — the sequential
        engine (``lead=()``) and the batched NumPy engine
        (``lead=(B,)``); both pay one slot-write per tick."""
        ctx = CaptureContext.from_consts(
            consts, island_of_tile=island_of_tile, noc_island=noc_island,
            n_links=n_links, n_islands=n_islands)
        return DeferredCapture(ctx, T, lead=tuple(lead),
                               tile_alive=tile_alive,
                               link_scale=link_scale,
                               tile_names=tile_names,
                               island_names=island_names)

    def capture_incremental(self, *, lead, consts, island_of_tile,
                            noc_island: int, n_links: int, n_islands: int,
                            tile_names=(), island_names=()
                            ) -> IncrementalCapture:
        ctx = CaptureContext.from_consts(
            consts, island_of_tile=island_of_tile, noc_island=noc_island,
            n_links=n_links, n_islands=n_islands)
        return IncrementalCapture(ctx, lead=tuple(lead),
                                  tile_names=tile_names,
                                  island_names=island_names)

    def attach(self, plane: CounterPlane) -> CounterPlane:
        """Install a finished counter plane (accumulating across runs is
        the caller's concern; each run replaces the plane)."""
        self._counters = plane
        self._counters_thunk = None
        return plane

    def attach_lazy(self, thunk) -> None:
        """Install a zero-argument callable producing the run's
        :class:`CounterPlane`; it is invoked (once) on the first
        ``observer.counters`` read.  The captured histories are the
        engine's own run buffers — freshly allocated each run — so the
        thunk stays valid until the next run replaces it."""
        self._counters = None
        self._counters_thunk = thunk


# ---------------------------------------------------------------------------
# Metrics-export bridge
# ---------------------------------------------------------------------------


def export_metrics(*, telemetry=None, counters: Optional[CounterPlane] = None,
                   trace: Optional[ControlTrace] = None,
                   registry=None, prefix: str = "sim"):
    """Render telemetry + the counter plane + the trace into a
    :class:`~repro.sim.metrics.MetricsRegistry` (Prometheus-ready).

    Counter-plane series carry ``tile=`` / ``link=`` / ``island=`` labels;
    telemetry scalars become gauges of their latest row; trace kinds
    become an event counter."""
    from repro.sim.metrics import MetricsRegistry
    reg = registry if registry is not None else MetricsRegistry()

    if counters is not None:
        cp = counters
        tnames = (cp.tile_names if len(cp.tile_names) == cp.n_tiles
                  else tuple(str(i) for i in range(cp.n_tiles)))
        inames = (cp.island_names if len(cp.island_names) == cp.n_islands
                  else tuple(str(i) for i in range(cp.n_islands)))
        for k in TILE_KINDS:
            arr = np.asarray(cp.tile[k], dtype=np.float64)
            flat = arr.reshape(-1, cp.n_tiles).sum(axis=0)
            for a, name in enumerate(tnames):
                reg.counter(f"{prefix}_tile_{k}_total",
                            f"counter plane: per-tile {k}",
                            labels={"tile": name}, value=float(flat[a]))
        link_arr = np.asarray(cp.link["flits"], dtype=np.float64)
        for k in LINK_KINDS:
            arr = np.asarray(cp.link[k], dtype=np.float64)
            flat = (arr.reshape(-1, cp.n_links).max(axis=0)
                    if k == "peak_util"
                    else arr.reshape(-1, cp.n_links).sum(axis=0))
            metric = (reg.gauge if k == "peak_util" else reg.counter)
            for l in range(cp.n_links):
                metric(f"{prefix}_link_{k}" +
                       ("" if k == "peak_util" else "_total"),
                       f"counter plane: per-link {k}",
                       labels={"link": str(l)}, value=float(flat[l]))
        for k in ISLAND_KINDS:
            arr = np.asarray(cp.island[k], dtype=np.float64)
            flat = arr.reshape(-1, cp.n_islands).sum(axis=0)
            for i, name in enumerate(inames):
                reg.counter(f"{prefix}_island_{k}_total",
                            f"counter plane: per-island {k}",
                            labels={"island": name}, value=float(flat[i]))
        reg.gauge(f"{prefix}_observed_ticks",
                  "ticks accumulated into the counter plane",
                  value=float(np.asarray(cp.ticks).max(initial=0.0)))

    if telemetry is not None:
        doc = telemetry.to_dict()
        for name, series in doc.get("scalars", {}).items():
            if series:
                reg.gauge(f"{prefix}_telemetry_{name}",
                          f"latest telemetry {name}",
                          value=float(series[-1]))

    if trace is not None:
        for kind, n in sorted(trace.counts().items()):
            reg.counter(f"{prefix}_trace_events_total",
                        "control-plane trace events by kind",
                        labels={"kind": kind}, value=float(n))

    return reg
