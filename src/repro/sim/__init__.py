"""Closed-loop SoC simulation: vectorized traffic replay + online DFS.

The run-time counterpart of the static DSE engine — replays request
traces through one concrete design while monitor-driven DFS controllers
retune island rates in the loop:

engine.py    — tick-based batched event loop (flat arrays, no per-request
               Python objects; service rates from the perfmodel kernel,
               contention from the NoC routing tables)
traffic.py   — composable arrival-trace generators (constant, Poisson,
               diurnal, MMPP-bursty, replay) scaling to millions of
               requests
control.py   — controller harness: windowed C3 counter samples -> dfs
               policies -> dual-buffer actuator commits
telemetry.py — ring-buffer time series + JSON export

DSE bridge: ``core/dse.py:closed_loop_score`` re-ranks ``grid_sweep``
Pareto survivors by simulated tail latency and energy under dynamic
traffic.
"""
from repro.sim.engine import (  # noqa: F401
    SimConfig, SimEngine, SimPlatform, SimResult)
from repro.sim.control import ControlAction, ControllerHarness  # noqa: F401
from repro.sim.telemetry import (  # noqa: F401
    RingBuffer, Telemetry, TelemetrySchema, weighted_percentiles)
from repro.sim.traffic import (  # noqa: F401
    Trace, constant_trace, diurnal_trace, mmpp_trace, poisson_trace,
    replay_trace, superpose, with_total)
