"""Closed-loop SoC simulation: vectorized traffic replay + online DFS.

The run-time counterpart of the static DSE engine — replays request
traces through one concrete design while monitor-driven DFS controllers
retune island rates in the loop:

engine.py    — tick-based batched event loop (flat arrays, no per-request
               Python objects; service rates from the perfmodel kernel,
               contention from the NoC routing tables); the shared
               tick_step/TickState numeric core every engine runs
batch.py     — B design points co-simulated as ONE array program
               ((B, A) state, stacked incidence, vectorized DFS commits;
               numpy reference + jax.lax.scan backend; shared Trace or
               per-design BatchTrace arrival tensors)
flows.py     — FlowPattern: tile-to-tile streams + accelerator chains
               (stage completions feed the next stage), compiled per
               design into the incidence/hop/forward arrays the tick
               loop consumes (None == the legacy tile->MEM pattern)
traffic.py   — composable arrival-trace generators (constant, Poisson,
               diurnal, MMPP-bursty, replay) scaling to millions of
               requests; BatchTrace stacks/broadcasts per-design tensors
control.py   — controller harness: windowed C3 counter samples -> dfs
               policies -> dual-buffer actuator commits (scalar + the
               vectorized multi-design BatchControllerHarness) and the
               LoadBalancer admission policy for replicated islands
faults.py    — FaultSchedule (tile/island kills, link degradation, stuck
               actuators) compiled to per-tick availability/scale masks
               the tick loop consumes, plus SLOConfig (deadline drops,
               bounded retry of stranded work) — all three backends
               replay one schedule, bit-for-bit at B=1
telemetry.py — ring-buffer time series + JSON export (per-design rings
               for the batched engine), incl. drop/retry fault counters
observe.py   — run-time monitoring: the per-tile/per-link/per-island
               hardware-counter plane (CounterPlane), schema'd
               control-plane tracing (ControlTrace/TraceEvent), the
               Observer level= knob (off/counters/full) every engine
               accepts via observe=, and wall-clock phase profiling
metrics.py   — MetricsRegistry (counter/gauge/histogram) rendering
               Prometheus text + JSON timeseries from telemetry and the
               counter plane

DSE bridge: ``core/dse.py:closed_loop_score`` re-ranks ``grid_sweep``
Pareto survivors by simulated tail latency and energy under dynamic
traffic — one batched replay for all survivors.
"""
from repro.sim.engine import (  # noqa: F401
    SimConfig, SimEngine, SimPlatform, SimResult, StepConsts, TickState,
    latency_percentiles, tick_step)
from repro.sim.batch import (  # noqa: F401
    BatchSimEngine, BatchSimPlatform, BatchSimResult)
from repro.sim.control import (  # noqa: F401
    BatchControllerHarness, BatchSample, ControlAction, ControllerHarness,
    IslandTopology, LoadBalancer)
from repro.sim.faults import (  # noqa: F401
    CompiledFaults, FaultSchedule, IslandKill, LinkDegrade, SLOConfig,
    StuckRate, TileKill, compile_faults, respill_stranded)
from repro.sim.flows import (  # noqa: F401
    CompiledFlows, FlowPattern, compile_flows)
from repro.sim.metrics import (  # noqa: F401
    MetricsRegistry, parse_prometheus_text, telemetry_timeseries)
from repro.sim.observe import (  # noqa: F401
    LEVELS, TRACE_KINDS, ControlTrace, CounterPlane, Observer, Profiler,
    TraceEvent, export_metrics, get_profiler, profiled, reset_profiler)
from repro.sim.telemetry import (  # noqa: F401
    BatchTelemetry, RingBuffer, Telemetry, TelemetrySchema,
    weighted_percentiles)
from repro.sim.traffic import (  # noqa: F401
    BatchTrace, Trace, constant_trace, diurnal_trace, mmpp_trace,
    poisson_trace, replay_trace, superpose, with_total)
