"""Closed-loop SoC simulation: vectorized traffic replay + online DFS.

The run-time counterpart of the static DSE engine — replays request
traces through one concrete design while monitor-driven DFS controllers
retune island rates in the loop:

engine.py    — tick-based batched event loop (flat arrays, no per-request
               Python objects; service rates from the perfmodel kernel,
               contention from the NoC routing tables); the shared
               tick_step/TickState numeric core every engine runs
batch.py     — B design points co-simulated as ONE array program
               ((B, A) state, stacked incidence, vectorized DFS commits;
               numpy reference + jax.lax.scan backend)
traffic.py   — composable arrival-trace generators (constant, Poisson,
               diurnal, MMPP-bursty, replay) scaling to millions of
               requests
control.py   — controller harness: windowed C3 counter samples -> dfs
               policies -> dual-buffer actuator commits (scalar + the
               vectorized multi-design BatchControllerHarness)
telemetry.py — ring-buffer time series + JSON export (per-design rings
               for the batched engine)

DSE bridge: ``core/dse.py:closed_loop_score`` re-ranks ``grid_sweep``
Pareto survivors by simulated tail latency and energy under dynamic
traffic — one batched replay for all survivors.
"""
from repro.sim.engine import (  # noqa: F401
    SimConfig, SimEngine, SimPlatform, SimResult, StepConsts, TickState,
    latency_percentiles, tick_step)
from repro.sim.batch import (  # noqa: F401
    BatchSimEngine, BatchSimPlatform, BatchSimResult)
from repro.sim.control import (  # noqa: F401
    BatchControllerHarness, BatchSample, ControlAction, ControllerHarness,
    IslandTopology)
from repro.sim.telemetry import (  # noqa: F401
    BatchTelemetry, RingBuffer, Telemetry, TelemetrySchema,
    weighted_percentiles)
from repro.sim.traffic import (  # noqa: F401
    Trace, constant_trace, diurnal_trace, mmpp_trace, poisson_trace,
    replay_trace, superpose, with_total)
