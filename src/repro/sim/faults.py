"""Fault injection + SLO semantics for the closed-loop co-sim.

A :class:`FaultSchedule` is a declarative list of run-time events — tile
kills/revives, whole-island kills, NoC link degradation, stuck-frequency
actuator faults — compiled once per run (:func:`compile_faults`) into
dense per-tick masks the tick loop consumes:

* ``tile_alive``  (T, A) float 0/1 — multiplied into the tick capacity
  (a dead tile serves nothing, burns nothing: power-gated);
* ``link_scale``  (T, L) float in (0, 1] — divides the per-link loads of
  the contention model, so a degraded link saturates proportionally
  earlier (the ESP socket's credit-starved hop);
* ``stuck``/``stuck_rate`` (T, I) — islands whose DFS actuator cannot
  commit during the window; with an explicit ``rate`` the hardware also
  runs at that rate regardless of the software's island config (the
  software state is deliberately NOT mutated — the controller keeps
  requesting, the silicon ignores it, and service recovers to the
  software view when the fault clears);
* ``island_dead`` (T, I) bool — islands whose every sampled tile is dead
  (the controller skips guard latching and commits for these).

The masks are plain trailing-axis array ops, so the sequential ``(A,)``
engine, the batched ``(B, A)`` engine and the jitted ``lax.scan`` backend
consume the *same* compiled schedule and stay bit-for-bit comparable at
B=1 — faults extend the differential surface instead of forking it.

SLO semantics (:class:`SLOConfig`) ride on top: a per-request deadline
turns unserveable backlog into *explicit* ``dropped_slo`` counts, and
``on_kill`` decides what happens to work stranded in a dead replica's
queue — re-spill to surviving replicas through the LoadBalancer (bounded
by ``max_retries``), drop immediately, or wait for a revive.  Work is
conserved every tick: arrivals == completions + explicit drops + queued.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.islands import IslandConfig
from repro.core.noc import NocConfig, routing_tables


# ---------------------------------------------------------------------------
# Fault events (declarative; ticks are half-open [start, end) windows)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TileKill:
    """Tile ``tile`` serves nothing during ``[start, end)``; ``end=None``
    means it never revives within the run."""
    tile: str
    start: int
    end: Optional[int] = None


@dataclass(frozen=True)
class IslandKill:
    """Every tile of island ``island`` dies during ``[start, end)`` —
    the PDN/clock-tree failure domain of the paper's island partition."""
    island: str
    start: int
    end: Optional[int] = None


@dataclass(frozen=True)
class LinkDegrade:
    """Both directed NoC links between adjacent nodes ``a`` and ``b``
    keep only ``scale`` of their bandwidth during ``[start, end)``."""
    a: Tuple[int, int]
    b: Tuple[int, int]
    scale: float
    start: int
    end: Optional[int] = None


@dataclass(frozen=True)
class StuckRate:
    """Island ``island``'s DFS actuator is stuck during ``[start, end)``:
    commits are rejected (the dual buffer never swaps).  With an explicit
    ``rate`` the hardware additionally runs at that rate regardless of
    the software's live config; ``rate=None`` freezes at whatever rate
    was committed last."""
    island: str
    start: int
    end: Optional[int] = None
    rate: Optional[float] = None


FaultEventT = (TileKill, IslandKill, LinkDegrade, StuckRate)


@dataclass(frozen=True)
class FaultSchedule:
    """An immutable, order-independent set of fault events.

    Builder style (each helper returns a new schedule)::

        faults = (FaultSchedule()
                  .kill_tile("be1", start=2500)
                  .degrade_link((1, 1), (1, 2), 0.25, start=100, end=900)
                  .stick_island("fe0", start=0, rate=0.4))
    """
    events: Tuple[object, ...] = ()

    def __post_init__(self):
        for ev in self.events:
            assert isinstance(ev, FaultEventT), ev

    def __len__(self) -> int:
        return len(self.events)

    def __bool__(self) -> bool:
        return bool(self.events)

    def add(self, *events) -> "FaultSchedule":
        return FaultSchedule(self.events + tuple(events))

    def kill_tile(self, tile: str, *, start: int,
                  end: Optional[int] = None) -> "FaultSchedule":
        return self.add(TileKill(tile, start, end))

    def kill_island(self, island: str, *, start: int,
                    end: Optional[int] = None) -> "FaultSchedule":
        return self.add(IslandKill(island, start, end))

    def degrade_link(self, a, b, scale: float, *, start: int,
                     end: Optional[int] = None) -> "FaultSchedule":
        return self.add(LinkDegrade(tuple(a), tuple(b), float(scale),
                                    start, end))

    def stick_island(self, island: str, *, start: int,
                     end: Optional[int] = None,
                     rate: Optional[float] = None) -> "FaultSchedule":
        return self.add(StuckRate(island, start, end, rate))


# ---------------------------------------------------------------------------
# SLO knobs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SLOConfig:
    """Service-level semantics layered on the fluid queues.

    ``deadline_s``   — requests whose remaining queueing time (backlog /
                       nominal capacity) exceeds the deadline are dropped
                       *explicitly* (``dropped_slo``).  The nominal
                       (unmasked) capacity is used so a dead tile's
                       backlog is not instantly mass-dropped before the
                       recovery path can re-spill it.
    ``on_kill``      — work stranded in a dead tile's queue: ``"respill"``
                       re-offers it to surviving replicas through the
                       LoadBalancer (default), ``"drop"`` discards it
                       (``dropped_fault``), ``"wait"`` leaves it queued
                       until a revive.
    ``max_retries``  — how many times a stranded request may be
                       re-queued before it is dropped (fluid two-class
                       tracking supports 0 or 1).
    """
    ON_KILL = ("respill", "drop", "wait")

    deadline_s: Optional[float] = None
    on_kill: str = "respill"
    max_retries: int = 1

    def __post_init__(self):
        assert self.on_kill in self.ON_KILL, self.on_kill
        assert self.max_retries in (0, 1), \
            "fluid retry tracking supports max_retries 0 or 1"
        assert self.deadline_s is None or self.deadline_s > 0.0

    @property
    def recovers(self) -> bool:
        """True iff stranded work is re-spilled (needs a LoadBalancer)."""
        return self.on_kill == "respill" and self.max_retries > 0


# ---------------------------------------------------------------------------
# Compilation: events -> per-tick masks
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CompiledFaults:
    """Dense per-tick fault state for one run of ``ticks`` ticks."""
    tile_alive: np.ndarray          # (T, A) float64 0/1
    link_scale: np.ndarray          # (T, L) float64 in (0, 1]
    stuck: np.ndarray               # (T, I) bool — commits rejected
    stuck_rate: np.ndarray          # (T, I) float64, NaN = hold last rate
    island_dead: np.ndarray         # (T, I) bool — all sampled tiles dead
    events: Tuple[Dict[str, object], ...]   # telemetry transitions

    @property
    def has_tile(self) -> bool:
        return bool((self.tile_alive < 1.0).any())

    @property
    def has_link(self) -> bool:
        return bool((self.link_scale < 1.0).any())

    @property
    def has_stuck(self) -> bool:
        return bool(self.stuck.any())

    @property
    def has_stuck_rate(self) -> bool:
        return bool(np.isfinite(self.stuck_rate).any())

    def events_by_tick(self) -> Dict[int, List[Dict[str, object]]]:
        by: Dict[int, List[Dict[str, object]]] = {}
        for ev in self.events:
            by.setdefault(int(ev["tick"]), []).append(ev)
        return by


def compile_faults(schedule: FaultSchedule, *, ticks: int,
                   names, islands: IslandConfig,
                   noc: NocConfig) -> CompiledFaults:
    """Compile a :class:`FaultSchedule` into per-tick masks.

    ``names`` is the platform's tile order (the mask column order),
    ``islands`` its island structure; link faults resolve against the
    shared mesh's directed link table (``routing_tables``), so they are
    placement-independent — every design of a batched run replaying the
    same schedule degrades the same physical links.
    """
    T = int(ticks)
    names = tuple(names)
    A = len(names)
    name_idx = {n: i for i, n in enumerate(names)}
    isl_names = islands.names()
    I = len(isl_names)
    rt = routing_tables(noc)
    L = len(rt.links)

    tile_alive = np.ones((T, A), dtype=np.float64)
    link_scale = np.ones((T, L), dtype=np.float64)
    stuck = np.zeros((T, I), dtype=bool)
    stuck_rate = np.full((T, I), np.nan)
    events: List[Dict[str, object]] = []

    def window(start, end):
        s = min(max(int(start), 0), T)
        e = T if end is None else min(max(int(end), s), T)
        return s, e

    def mark(tick, kind, **payload):
        if 0 <= tick < T:
            # a stable human-readable subject (tile list / island / link
            # endpoints) so the events map 1:1 onto observe.TraceEvent
            if "tiles" in payload:
                subject = ",".join(str(t) for t in payload["tiles"])
            elif "island" in payload:
                subject = str(payload["island"])
            elif "a" in payload and "b" in payload:
                subject = f"{payload['a']}-{payload['b']}"
            else:
                subject = ""
            events.append({"tick": int(tick), "kind": kind,
                           "subject": subject, **payload})

    def kill_tiles(tiles, s, e, domain):
        cols = [name_idx[t] for t in tiles]
        tile_alive[s:e, cols] = 0.0
        mark(s, "fault_kill", tiles=list(tiles), domain=domain)
        if e < T:
            mark(e, "fault_revive", tiles=list(tiles), domain=domain)

    for ev in schedule.events:
        if isinstance(ev, TileKill):
            assert ev.tile in name_idx, f"unknown tile {ev.tile!r}"
            s, e = window(ev.start, ev.end)
            kill_tiles((ev.tile,), s, e, "tile")
        elif isinstance(ev, IslandKill):
            assert ev.island in isl_names, f"unknown island {ev.island!r}"
            spec = islands.islands[isl_names.index(ev.island)]
            tiles = tuple(t for t in spec.tiles if t in name_idx)
            assert tiles, f"island {ev.island!r} has no sampled tiles"
            s, e = window(ev.start, ev.end)
            kill_tiles(tiles, s, e, "island")
        elif isinstance(ev, LinkDegrade):
            assert 0.0 < ev.scale <= 1.0, ev.scale
            s, e = window(ev.start, ev.end)
            hit = 0
            for u, v in ((tuple(ev.a), tuple(ev.b)),
                         (tuple(ev.b), tuple(ev.a))):
                li = rt.link_index.get((u, v))
                if li is not None:
                    link_scale[s:e, li] *= ev.scale
                    hit += 1
            assert hit, (f"no NoC link between {ev.a} and {ev.b} "
                         "(nodes must be mesh-adjacent)")
            mark(s, "fault_link_degrade", a=list(ev.a), b=list(ev.b),
                 scale=ev.scale)
            if e < T:
                mark(e, "fault_link_restore", a=list(ev.a), b=list(ev.b))
        elif isinstance(ev, StuckRate):
            assert ev.island in isl_names, f"unknown island {ev.island!r}"
            i = isl_names.index(ev.island)
            s, e = window(ev.start, ev.end)
            stuck[s:e, i] = True
            if ev.rate is not None:
                stuck_rate[s:e, i] = float(ev.rate)
            mark(s, "fault_stuck", island=ev.island, rate=ev.rate)
            if e < T:
                mark(e, "fault_unstuck", island=ev.island)

    # an island is dead iff it has sampled tiles and they are ALL dead
    mem = np.zeros((I, A), dtype=np.float64)
    for i, spec in enumerate(islands.islands):
        for t in spec.tiles:
            if t in name_idx:
                mem[i, name_idx[t]] = 1.0
    counts = mem.sum(axis=1)
    alive_count = tile_alive @ mem.T                        # (T, I)
    island_dead = (counts[None, :] > 0) & (alive_count <= 0.0)

    np.maximum(link_scale, 1e-6, out=link_scale)
    events.sort(key=lambda d: d["tick"])
    return CompiledFaults(tile_alive=tile_alive, link_scale=link_scale,
                          stuck=stuck, stuck_rate=stuck_rate,
                          island_dead=island_dead, events=tuple(events))


# ---------------------------------------------------------------------------
# Recovery: drain work stranded on dead replicas
# ---------------------------------------------------------------------------


def respill_stranded(queue: np.ndarray, retry_q: np.ndarray,
                     alive: np.ndarray, balancer
                     ) -> Tuple[np.ndarray, np.ndarray,
                                np.ndarray, np.ndarray]:
    """Drain queues of dead tiles at the start of a tick.

    Returns ``(queue, retry_q, respill, dropped_fault)`` — all per-tile
    ``(..., A)`` arrays.  Fresh stranded work is returned in ``respill``
    still sitting at its (dead) source column; the caller re-splits it
    over the group's survivors through the balancer and feeds it back as
    this tick's retry arrivals.  Work that already retried once — and
    any work whose replica group has no survivor, no balancer, or no
    retry budget — is returned in ``dropped_fault``.  Shape-agnostic
    trailing-axis ops only, so sequential and B=1 batch runs compute the
    same floats; ``alive`` is the shared ``(A,)`` mask row.
    """
    dead = 1.0 - alive
    stranded = queue * dead
    s_retry = retry_q * dead
    queue = queue - stranded
    retry_q = retry_q - s_retry
    if balancer is None:
        return queue, retry_q, np.zeros_like(stranded), stranded
    surv = np.einsum("a,ga->g", np.asarray(alive, dtype=np.float64),
                     balancer.membership) > 0.0
    can = balancer.covered & surv[balancer.group_of]        # (A,) bool
    respill = np.where(can, stranded - s_retry, 0.0)
    return queue, retry_q, respill, stranded - respill
