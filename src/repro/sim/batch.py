"""Batched multi-design closed-loop co-simulation: B SoCs as one array
program.

``core/dse.py:grid_sweep`` evaluates millions of *static* design points
per second, but runtime validation (``closed_loop_score``) used to
re-simulate Pareto survivors one at a time — the static sweep scaled, the
closed loop didn't.  This module stacks B concrete designs (replication
counts, placements, island rates) into one platform whose tick loop
advances ``(B, A)`` arrays:

* per-tile queue/busy/counter state gains a leading design axis and is
  advanced by the SAME :func:`~repro.sim.engine.tick_step` the sequential
  engine runs — elementwise ops and trailing-axis reductions are
  shape-independent, so a B=1 batch run reproduces the sequential engine
  bit-for-bit (differential-tested);
* service rates come from ``service_time_terms_batch`` broadcast over the
  design axis (per-design ``f_acc``/``f_noc``/``f_tg``/K/placement);
* NoC contention uses per-design route->link incidence stacked into one
  dense ``(B, A, L)`` table (:func:`~repro.core.noc.stacked_incidence`:
  every route padded out to the full link-vector width, so per-tick link
  loads are a single einsum — the memory cost is ``B*A*L`` floats, fine
  for SoC-size fabrics);
* DFS controllers run vectorized: policy decisions on ``(B, I)`` counter
  windows, dual-buffer commits as masked array swaps
  (:class:`~repro.sim.control.BatchControllerHarness`);
* the workload may be a shared :class:`~repro.sim.traffic.Trace` or a
  per-design ``(T, B, A)`` :class:`~repro.sim.traffic.BatchTrace`
  (broadcasting a shared trace reproduces it bit-for-bit), shaped by an
  optional :class:`~repro.sim.flows.FlowPattern` (tile-to-tile streams,
  chained stages) with a :class:`~repro.sim.control.LoadBalancer`
  splitting arrivals across replica groups.

Three backends: ``"numpy"`` (float64, the ground-truth reference),
``"jax"`` — the tick loop as one ``jax.lax.scan`` (jit-compiled; float32
unless ``jax_enable_x64``), so the whole grid_sweep -> Pareto -> batched
co-sim pipeline can run jitted end to end — and ``"pallas"``, the
queue-update/service/forward tick sequence fused into one Pallas kernel
(:mod:`repro.kernels.tick_sim`; ``interpret=True`` everywhere a real
TPU is absent).  The jax backend supports open-loop replay, the
vectorized membound/PID policies (+ queue guard), *custom* jax-side
batch policies (any policy exposing the ``jax_step`` protocol — see
:meth:`BatchSimEngine._control_plan`), flow patterns, per-design traces
and the balancer; it records no telemetry rings (latency percentiles
are still reconstructed exactly from the returned histories).  With
``devices=`` the jax backend shards the design axis across devices via
``shard_map`` (``repro.shard`` + the ``repro.compat`` shims): the
per-design rows are fully independent, so any device count returns the
single-device floats exactly (differentially tested) — spin up virtual
CPU devices with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.islands import (IslandConfig, IslandSpec, NOC_LADDER,
                                TILE_LADDER)
from repro.core.noc import pos_index, positions_to_indices
from repro.core.perfmodel import SoCPerfModel
from repro.core.voltage import TechModel
from repro.sim.control import BatchControllerHarness, LoadBalancer
from repro.sim.engine import (PKT_BYTES, SimConfig, SimPlatform, StepConsts,
                              TickState, latency_percentiles, tick_step)
from repro.sim.faults import (CompiledFaults, FaultSchedule, SLOConfig,
                              compile_faults, respill_stranded)
from repro.sim.flows import FlowPattern, compile_flows
from repro.sim.observe import STALL_EPS, CounterPlane, Observer
from repro.sim.telemetry import BatchTelemetry, TelemetrySchema
from repro.sim.traffic import BatchTrace, Trace

# jitted-scan LRU bound: one compiled executable per distinct
# (trace shape, cadence, fault class, policy/balancer/config digest);
# long-lived engines swept through many configurations stay bounded
_SCAN_CACHE_MAX = 8

# slot names of the tuple ``BatchSimEngine._scan_cache_sig`` returns,
# in order.  A knob that retraces the scan must claim a slot (or join
# an existing digest slot); tests/test_analysis.py enumerates these and
# the RPR002 rule pass checks the construction stays complete.
SCAN_SIG_FIELDS = ("tag", "T", "ci", "dt", "B", "D", "arrivals_ndim",
                   "fault_key", "policy_digest", "balancer_digest",
                   "config", "model", "slo", "tech")


# ---------------------------------------------------------------------------
# Platform: B concrete designs, stacked
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BatchSimPlatform:
    """B simulatable SoC instances sharing one NoC/model and one island
    *structure* (names, tile partition, ladders); everything that varies
    across designs — replication, placement, island rates, TG rate — is a
    leading-``B``-axis array.  ``islands`` is the structural template; the
    live per-design rates live in ``rates`` (and evolve through a
    :class:`BatchControllerHarness` at run time).
    """
    model: SoCPerfModel
    islands: IslandConfig               # structure template (rates ignored)
    names: Tuple[str, ...]
    base_mbps: np.ndarray               # (B, A)
    wire_share: np.ndarray              # (B, A)
    k: np.ndarray                       # (B, A)
    pos_idx: np.ndarray                 # (B, A)
    req_mb: np.ndarray                  # (B, A)
    rates: np.ndarray                   # (B, I) initial island rates
    f_tg: np.ndarray                    # (B,)
    n_tg: int = 0
    flows: Optional[FlowPattern] = None  # shared tile-to-tile pattern

    @property
    def n_designs(self) -> int:
        return int(self.k.shape[0])

    @property
    def n_tiles(self) -> int:
        return len(self.names)

    @classmethod
    def stack(cls, platforms: Sequence[SimPlatform]) -> "BatchSimPlatform":
        """Stack B :class:`SimPlatform` instances (same model, tile names
        and island structure; per-design arrays may differ)."""
        assert platforms, "need at least one platform"
        p0 = platforms[0]
        isl_names = p0.islands.names()
        isl_tiles = tuple(i.tiles for i in p0.islands.islands)
        for p in platforms[1:]:
            assert p.model is p0.model or p.model == p0.model, \
                "platforms must share one SoCPerfModel"
            assert p.names == p0.names, "tile name mismatch"
            assert p.islands.names() == isl_names, "island structure mismatch"
            assert tuple(i.tiles for i in p.islands.islands) == isl_tiles
            assert p.n_tg == p0.n_tg, "n_tg mismatch"
            assert p.flows == p0.flows, "flow-pattern mismatch"
        return cls(
            flows=p0.flows,
            model=p0.model, islands=p0.islands, names=p0.names,
            base_mbps=np.stack([p.base_mbps for p in platforms]),
            wire_share=np.stack([p.wire_share for p in platforms]),
            k=np.stack([p.k for p in platforms]),
            pos_idx=np.stack([p.pos_idx for p in platforms]),
            req_mb=np.stack([p.req_mb for p in platforms]),
            rates=np.asarray([[i.rate for i in p.islands.islands]
                              for p in platforms], dtype=np.float64),
            f_tg=np.asarray([p.f_tg for p in platforms], dtype=np.float64),
            n_tg=p0.n_tg)

    @classmethod
    def from_design_points(cls, model: SoCPerfModel, result, indices,
                           *, req_mb: float = 0.1,
                           n_tg: Optional[int] = None,
                           flows: Optional[FlowPattern] = None
                           ) -> "BatchSimPlatform":
        """Bridge from the DSE layer: stack ``grid_sweep`` survivors (flat
        :class:`~repro.core.dse.SweepResult` /
        :class:`~repro.core.dse.ChunkedSweepResult` indices) for one
        batched replay.

        Vectorized: the per-design ``(B, A)`` replication/placement arrays
        and the ``(B, I)`` per-island rate matrix come straight from one
        ``result.design_arrays`` decode of the flat indices — per-island
        independent rates included — without materializing B DesignPoints
        or SimPlatforms (bit-identical to stacking
        ``SimPlatform.from_design_point`` per index, tested)."""
        n_tg = result.n_tg if n_tg is None else n_tg
        idx = np.asarray(indices, dtype=np.int64)
        wls = tuple(result.workloads)
        names = tuple(w.name for w in wls)
        assert len(set(names)) == len(names), "duplicate tile names"
        da = result.design_arrays(idx)
        B, A = da["k"].shape
        pos_idx = positions_to_indices(model.noc, da["pos"])
        mem_idx = pos_index(model.noc, model.mem_pos)
        assert not np.any(pos_idx == mem_idx), "tile placed on MEM"
        for a in range(A):
            for b in range(a + 1, A):
                assert not np.any(pos_idx[:, a] == pos_idx[:, b]), \
                    "tile collision (invalid sweep point selected)"
        specs = tuple(IslandSpec(n, (n,), TILE_LADDER, 1.0)
                      for n in names)
        specs += (IslandSpec("noc_mem", ("NOC", "MEM"), NOC_LADDER, 1.0),)

        def tile_const(vals):
            return np.broadcast_to(
                np.asarray(vals, dtype=np.float64), (B, A)).copy()

        return cls(
            model=model, islands=IslandConfig(specs), names=names,
            base_mbps=tile_const([w.base_mbps for w in wls]),
            wire_share=tile_const([w.wire_share for w in wls]),
            k=da["k"], pos_idx=pos_idx.astype(np.int64),
            req_mb=np.full((B, A), float(req_mb)),
            rates=da["rates"], f_tg=da["f_tg"], n_tg=int(n_tg),
            flows=flows)

    def design(self, b: int) -> SimPlatform:
        """Materialize design ``b`` as a sequential :class:`SimPlatform`
        (the differential-test / drill-down path)."""
        specs = tuple(dataclasses.replace(spec, rate=float(self.rates[b, i]))
                      for i, spec in enumerate(self.islands.islands))
        return SimPlatform(
            model=self.model,
            islands=dataclasses.replace(self.islands, islands=specs),
            names=self.names, base_mbps=self.base_mbps[b].copy(),
            wire_share=self.wire_share[b].copy(), k=self.k[b].copy(),
            pos_idx=self.pos_idx[b].copy(), req_mb=self.req_mb[b].copy(),
            n_tg=self.n_tg, f_tg=float(self.f_tg[b]), flows=self.flows)


# ---------------------------------------------------------------------------
# Result
# ---------------------------------------------------------------------------


@dataclass
class BatchSimResult:
    """Per-design outcome arrays of one batched replay (all ``(B,)``)."""
    n_designs: int
    ticks: int
    dt: float
    offered: object                     # float (shared trace) or (B,)
                                        # per-design totals (BatchTrace)
    completed: np.ndarray               # exit-stage services under a
                                        # chained FlowPattern (each
                                        # external request once)
    dropped: np.ndarray
    residual: np.ndarray
    throughput_rps: np.ndarray
    p50_latency_s: np.ndarray
    p99_latency_s: np.ndarray
    energy_j: np.ndarray
    energy_per_request_j: np.ndarray
    mean_power_w: np.ndarray
    swaps: np.ndarray                   # (B,) int64 actuator commits
    elapsed_wall_s: float               # whole batch, one clock
    backend: str = "numpy"
    telemetry: Optional[BatchTelemetry] = None   # None on the jax backend
    # fault/SLO ledgers, (B,) each (None on legacy constructions)
    dropped_slo: Optional[np.ndarray] = None
    dropped_fault: Optional[np.ndarray] = None
    retried: Optional[np.ndarray] = None

    @property
    def dropped_total(self) -> np.ndarray:
        """(B,) admission + SLO + stranded drops."""
        tot = np.asarray(self.dropped, dtype=np.float64).copy()
        if self.dropped_slo is not None:
            tot = tot + self.dropped_slo
        if self.dropped_fault is not None:
            tot = tot + self.dropped_fault
        return tot

    @property
    def drop_rate(self) -> np.ndarray:
        """(B,) dropped fraction of offered load (0 when nothing offered).
        Per-design floats match the sequential ``SimResult.drop_rate``."""
        off = np.asarray(self.offered, dtype=np.float64)
        tot = self.dropped_total
        return np.where(off > 0.0, tot / np.where(off > 0.0, off, 1.0), 0.0)

    @property
    def designs_per_s_wall(self) -> float:
        return (self.n_designs / self.elapsed_wall_s
                if self.elapsed_wall_s else 0.0)

    @property
    def requests_per_s_wall(self) -> float:
        return (float(self.completed.sum()) / self.elapsed_wall_s
                if self.elapsed_wall_s else 0.0)

    def summary(self) -> str:
        return (f"{self.n_designs} designs x {self.ticks} ticks "
                f"({self.backend}, {self.elapsed_wall_s:.2f}s wall, "
                f"{self.designs_per_s_wall:,.1f} designs/s): "
                f"p99 [{self.p99_latency_s.min() * 1e3:.2f}, "
                f"{self.p99_latency_s.max() * 1e3:.2f}]ms, "
                f"mJ/req [{self.energy_per_request_j.min() * 1e3:.3f}, "
                f"{self.energy_per_request_j.max() * 1e3:.3f}], "
                f"{int(self.swaps.sum())} DFS swaps")


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


class BatchSimEngine:
    """Ticks B stacked designs through one trace, controllers in loop."""

    def __init__(self, platform: BatchSimPlatform, *,
                 config: SimConfig = SimConfig(),
                 controller: Optional[BatchControllerHarness] = None,
                 balancer: Optional[LoadBalancer] = None,
                 backend: str = "numpy",
                 faults: Optional[FaultSchedule] = None,
                 slo: Optional[SLOConfig] = None, observe=None,
                 devices=None, tech=None):
        assert backend in ("numpy", "jax", "pallas"), backend
        self.platform = platform
        # devices: None (single-device ground truth), an int, or "auto" —
        # the jax backend shards the design axis across this many devices
        self.devices = devices
        self.config = config
        self.controller = controller
        # physical DVFS model (core/voltage.py): tick energy becomes
        # power_scl * (P_static + P_dyn f V̂(f)^2) on every backend, and
        # the harness clamps commits to the node's legal [L, U] range;
        # None keeps the linear voltage proxy bit for bit
        self.tech = TechModel.coerce(tech)
        if self.tech is not None and controller is not None \
                and getattr(controller, "tech", None) is None:
            controller.tech = self.tech
        self.balancer = balancer
        self.backend = backend
        self.faults = faults
        self.slo = slo
        # run-time monitoring (observe.Observer or level string): the
        # numpy path accumulates the counter plane incrementally per tick,
        # the jax path carries accumulators through the scan (counters
        # level; full-trace tracing needs the Python-loop engines)
        self.observer = Observer.coerce(observe)
        self.last_state: Optional[TickState] = None
        self.last_histories = None      # (admitted, served) (T, B, A)
        self.last_fault_histories = None
        m = platform.model
        # per-design route->link incidence, stacked dense: (B, A, L) —
        # per-design routes of the (shared, name-keyed) flow pattern
        # against each design's own placement (tile->MEM when flows=None)
        cf = compile_flows(m, platform.names, platform.pos_idx,
                           platform.flows)
        self._compiled_flows = cf
        self._inc = cf.inc
        self._hop_counts = cf.hop_counts
        self._flow_demand = cf.demand
        self._forward = cf.forward
        self._t_comp_ref = (1.0 - platform.wire_share) / platform.k
        isl_names = platform.islands.names()
        self._island_of_tile = np.asarray(
            [isl_names.index(platform.islands.island_of(n).name)
             for n in platform.names], dtype=np.int64)
        try:
            self._noc_island = isl_names.index("noc_mem")
        except ValueError:
            self._noc_island = -1
        # compiled-scan cache: full run signature -> jitted scan.  The
        # key is EXPLICIT about everything the trace bakes in as a
        # constant (dt, controller plan, balancer layout, SLO semantics,
        # config scalars, device count) — two configurations that differ
        # in any baked constant MUST NOT share one executable (the PR 8
        # jit-cache collision bugfix; regression-tested).  Bounded LRU.
        self._jax_cache: "OrderedDict" = OrderedDict()

    # ------------------------------------------------------------ service
    def _service(self, rates: np.ndarray,
                 rate_override: Optional[np.ndarray] = None
                 ) -> Dict[str, np.ndarray]:
        """Service-time terms for a (B, I) rate matrix — the stacked
        analogue of ``SimEngine._service`` (recomputed only on commits).

        ``rate_override`` is the stuck-actuator hardware view: an (I,)
        row, NaN = follow the software rate.  It affects only the terms
        computed here — the caller's ``rates`` matrix (what telemetry
        records and the controller reasons about) stays the software
        view, exactly like the sequential engine."""
        p = self.platform
        B, A = p.n_designs, p.n_tiles
        if rate_override is not None:
            rates = np.where(np.isnan(rate_override), rates, rate_override)
        f_tile = rates[:, self._island_of_tile]              # (B, A)
        f_noc = (rates[:, self._noc_island] if self._noc_island >= 0
                 else np.ones(B))
        t_comp, t_wire, t_ref = p.model.service_time_terms_batch(
            wire_share=p.wire_share, k=p.k, f_acc=f_tile,
            f_noc=f_noc[:, None], f_tg=p.f_tg[:, None], n_tg=p.n_tg,
            hop_counts=self._hop_counts)
        return {"t_comp": np.broadcast_to(t_comp, (B, A)),
                "t_wire": np.broadcast_to(t_wire, (B, A)),
                "t_ref": np.broadcast_to(np.asarray(t_ref, float), (B, A)),
                "f_tile": f_tile, "f_noc": f_noc}

    def capacity_rps(self, rates: Optional[np.ndarray] = None) -> np.ndarray:
        """(B, A) uncontended per-tile service capacity (requests/s)."""
        svc = self._service(self.platform.rates if rates is None else rates)
        thr = self.platform.base_mbps * svc["t_ref"] / (
            svc["t_comp"] + svc["t_wire"])
        return thr / self.platform.req_mb

    def step_consts(self, dt: float) -> StepConsts:
        p, cfg = self.platform, self.config
        return StepConsts(
            base_mbps=p.base_mbps, req_mb=p.req_mb,
            hop_counts=self._hop_counts, inc=self._inc,
            own_demand=self._flow_demand, link_bw=p.model.noc.link_bw,
            max_slow=p.model.noc.max_slowdown,
            hop_latency=p.model.noc.hop_latency,
            noc_power_share=cfg.noc_power_share, dt=dt,
            max_queue=cfg.max_queue,
            dynamic_contention=cfg.dynamic_contention,
            forward=self._forward, tech=self.tech)

    def _check_trace(self, trace) -> None:
        p = self.platform
        assert trace.n_dests == p.n_tiles, (trace.n_dests, p.n_tiles)
        if isinstance(trace, BatchTrace):
            assert trace.n_designs == p.n_designs, \
                (trace.n_designs, p.n_designs)

    def _compile_faults(self, T: int) -> Optional[CompiledFaults]:
        if self.faults is None or not self.faults:
            return None
        p = self.platform
        return compile_faults(self.faults, ticks=T, names=p.names,
                              islands=p.islands, noc=p.model.noc)

    @staticmethod
    def _offered(trace):
        """External offered load: one float for a shared trace, per-design
        (B,) totals for a :class:`BatchTrace`."""
        if isinstance(trace, BatchTrace):
            return trace.n_requests
        return float(trace.arrivals.sum())

    def _completed(self, served_hist: np.ndarray) -> np.ndarray:
        """(B,) external completions.  Chained patterns count only
        exit-stage services (each request once); the chain-free
        expression is kept verbatim (bit-for-bit)."""
        if self._forward is None:
            return served_hist.sum(axis=(0, 2))
        return (served_hist
                * self._compiled_flows.exit_mask).sum(axis=(0, 2))

    # ---------------------------------------------------------------- run
    def run(self, trace) -> BatchSimResult:
        """Replay a shared :class:`Trace` (every design sees the same
        (T, A) arrivals) or a per-design :class:`BatchTrace` (T, B, A)."""
        if self.backend == "jax":
            return self._run_jax(trace)
        if self.backend == "pallas":
            return self._run_pallas(trace)
        return self._run_numpy(trace)

    def _run_numpy(self, trace) -> BatchSimResult:
        p, cfg = self.platform, self.config
        B, A, T, dt = p.n_designs, p.n_tiles, trace.ticks, trace.dt
        self._check_trace(trace)
        arrivals = trace.arrivals

        if self.controller is not None:
            assert self.controller.n_designs == B
            self.controller.begin_run()
            rates = self.controller.live_rates()
            swaps0 = self.controller.swaps.copy()
        else:
            rates = p.rates
            swaps0 = np.zeros(B, dtype=np.int64)

        # ---- fault/SLO compilation: one shared schedule drives all B
        # designs (faults are a property of the scenario, not the design);
        # every hook below is None-gated — a fault-free run is the exact
        # legacy loop, and a B=1 faulted run mirrors the sequential engine
        # tick for tick (same expressions, trailing-axis reductions).
        cf = self._compile_faults(T)
        slo = self.slo
        if slo is None and cf is not None:
            slo = SLOConfig()
        deadline = slo is not None and slo.deadline_s is not None
        has_tile = cf is not None and cf.has_tile
        has_link = cf is not None and cf.has_link
        has_stuck_rate = cf is not None and cf.has_stuck_rate
        recover = has_tile and slo.recovers and self.balancer is not None
        track = has_tile or deadline
        ev_by_tick = cf.events_by_tick() if cf is not None else {}
        applied_stuck = None
        svc = self._service(rates)

        st = TickState.zeros((B, A))
        consts = self.step_consts(dt)
        if deadline:
            consts = dataclasses.replace(
                consts, deadline_ticks=slo.deadline_s / dt)
        carry = np.zeros((B, A)) if consts.forward is not None else None
        prev_cap = (self.capacity_rps(rates) * dt
                    if self.balancer is not None else None)
        admitted_hist = np.zeros((T, B, A))
        served_hist = np.zeros((T, B, A))
        qdrop_hist = np.zeros((T, B, A)) if track else None
        fh = ({k: np.zeros((T, B)) for k in
               ("dropped", "dropped_slo", "dropped_fault", "retried",
                "queue", "carry")} if track else None)
        win_busy = np.zeros((B, A))
        win_served = np.zeros(B)
        win_ticks = 0
        ctl_busy = np.zeros((B, A))
        ctl_ticks = 0

        telem = BatchTelemetry(
            TelemetrySchema(islands=p.islands.names(), tiles=p.names),
            B, capacity=cfg.telemetry_capacity)

        # ---- monitoring (read-only; per tick the deferred capture costs
        # two preallocated slot writes — dyn row, link-load row — and the
        # counters are reconstructed vectorized from the histories the
        # loop already keeps, exactly like the sequential engine)
        ob = self.observer
        ocap = None
        if ob is not None and ob.enabled:
            ocap = ob.capture_sequential(
                T=T, consts=consts, lead=(B,),
                island_of_tile=self._island_of_tile,
                noc_island=self._noc_island, n_links=self._inc.shape[-1],
                n_islands=len(p.islands.names()),
                tile_alive=cf.tile_alive if has_tile else None,
                link_scale=cf.link_scale if has_link else None,
                tile_names=p.names, island_names=p.islands.names())
            ocap.on_service(0, svc)
            ob.begin_run()
            ob.emit(0, "run_start", subject="batch-numpy", ticks=T, dt=dt,
                    designs=B, level=ob.level)

        wall0 = time.perf_counter()
        for t_i in range(T):
            for ev in ev_by_tick.get(t_i, ()):
                telem.event(t_i, ev["kind"],
                            **{k: v for k, v in ev.items()
                               if k not in ("tick", "kind")})
                if ob is not None:
                    ob.emit_event_dict(t_i, ev)
            alive = cf.tile_alive[t_i] if has_tile else None
            lscale = cf.link_scale[t_i] if has_link else None
            if has_stuck_rate:
                row = cf.stuck_rate[t_i]
                if applied_stuck is None or not np.array_equal(
                        row, applied_stuck, equal_nan=True):
                    applied_stuck = row
                    svc = self._service(rates, rate_override=applied_stuck)
                    if ocap is not None:
                        ocap.on_service(t_i, svc)

            respill = stranded_exit = None
            if has_tile and slo.on_kill != "wait":
                st.queue, st.retry_q, respill, fdrop = respill_stranded(
                    st.queue, st.retry_q, alive,
                    self.balancer if recover else None)
                st.dropped_fault = st.dropped_fault + fdrop.sum(axis=-1)
                if recover:
                    st.retried = st.retried + respill.sum(axis=-1)
                stranded_exit = respill + fdrop

            arr = arrivals[t_i]
            if carry is not None:
                arr = arr + carry
            retry_arr = None
            if self.balancer is not None:
                arr = self.balancer.split(
                    arr, st.queue, prev_cap,
                    alive=alive if recover else None)
                if recover:
                    retry_arr = self.balancer.split(respill, st.queue,
                                                    prev_cap, alive=alive)
                    arr = arr + retry_arr
            out = tick_step(st, arr, svc, consts, alive=alive,
                            link_scale=lscale, retry_in=retry_arr)
            if ocap is not None:
                ocap.on_tick(t_i, out)
            if carry is not None:
                carry = out.forwarded
            if self.balancer is not None:
                prev_cap = out.cap_tick
            admitted_hist[t_i] = out.admitted
            served_hist[t_i] = out.served
            if track:
                qd = qdrop_hist[t_i]
                if stranded_exit is not None:
                    qd += stranded_exit
                if out.slo_drop is not None:
                    qd += out.slo_drop
                fh["dropped"][t_i] = st.dropped
                fh["dropped_slo"][t_i] = st.dropped_slo
                fh["dropped_fault"][t_i] = st.dropped_fault
                fh["retried"][t_i] = st.retried
                fh["queue"][t_i] = st.queue.sum(axis=-1)
                fh["carry"][t_i] = (carry.sum(axis=-1)
                                    if carry is not None else 0.0)

            win_busy += st.busy
            win_served += out.served.sum(axis=-1)
            win_ticks += 1
            ctl_busy += st.busy
            ctl_ticks += 1

            if cfg.telemetry_interval and (t_i + 1) % cfg.telemetry_interval == 0:
                cap_rps_now = out.cap_tick / dt
                telem.record(
                    tick=t_i, f_noc=svc["f_noc"], island_rates=rates,
                    queue_depth=st.queue, busy=win_busy / win_ticks,
                    throughput_rps=win_served / (win_ticks * dt),
                    power_w=out.tile_power + out.noc_power,
                    link_util_max=out.rho.max(axis=-1, initial=0.0),
                    link_util_mean=out.rho.mean(axis=-1),
                    latency_est_s=(st.queue.sum(axis=-1)
                                   / np.maximum(cap_rps_now.sum(axis=-1),
                                                1e-9)),
                    dropped=st.dropped, dropped_slo=st.dropped_slo,
                    dropped_fault=st.dropped_fault, retried=st.retried)
                win_busy = np.zeros((B, A))
                win_served = np.zeros(B)
                win_ticks = 0

            if (self.controller is not None and cfg.control_interval
                    and (t_i + 1) % cfg.control_interval == 0):
                t_wire_now = svc["t_wire"] * out.dyn
                new_rates = self.controller.step(
                    tick=t_i,
                    busy=ctl_busy / max(ctl_ticks, 1),
                    boundness=t_wire_now / (self._t_comp_ref + t_wire_now),
                    pkts_in=st.pkts_in, pkts_out=st.pkts_out,
                    rtt=st.rtt_acc,
                    queue_ticks=st.queue / np.maximum(out.cap_tick, 1e-12),
                    dead=cf.island_dead[t_i] if has_tile else None,
                    stuck=(cf.stuck[t_i]
                           if cf is not None and cf.has_stuck else None))
                ctl_busy = np.zeros((B, A))
                ctl_ticks = 0
                if new_rates is not None:
                    rates = new_rates
                    svc = self._service(rates, rate_override=applied_stuck)
                    if ocap is not None:
                        ocap.on_service(t_i + 1, svc)
                    committed = np.nonzero(
                        self.controller.last_committed)[0].tolist()
                    telem.event(t_i, "dfs_commit", designs=committed)
                    if ob is not None:
                        ob.emit(t_i, "dfs_commit", subject="batch",
                                designs=committed)
        elapsed = time.perf_counter() - wall0
        if ocap is not None:
            # lazy: the vectorized reconstruction runs on the first
            # observer.counters read, not inside the engine's wall clock
            ob.attach_lazy(lambda: ocap.finalize(admitted_hist, served_hist,
                                                 qdrop_hist))
            ob.emit(max(T - 1, 0), "run_end", subject="batch-numpy",
                    designs=B)

        self.last_state = st
        self.last_histories = (admitted_hist, served_hist)
        self.last_fault_histories = (
            None if fh is None else {**fh, "queue_drops": qdrop_hist})
        return self._result(trace, admitted_hist, served_hist,
                            completed=self._completed(served_hist),
                            dropped=np.asarray(st.dropped, dtype=np.float64),
                            residual=st.queue.sum(axis=-1),
                            energy=np.asarray(st.energy, dtype=np.float64),
                            swaps=(self.controller.swaps - swaps0
                                   if self.controller is not None
                                   else np.zeros(B, dtype=np.int64)),
                            elapsed=elapsed, backend="numpy", telem=telem,
                            dropped_slo=np.asarray(st.dropped_slo,
                                                   dtype=np.float64),
                            dropped_fault=np.asarray(st.dropped_fault,
                                                     dtype=np.float64),
                            retried=np.asarray(st.retried,
                                               dtype=np.float64),
                            qdrops=qdrop_hist)

    def _result(self, trace, admitted_hist, served_hist, *, completed,
                dropped, residual, energy, swaps, elapsed, backend,
                telem, dropped_slo=None, dropped_fault=None, retried=None,
                qdrops=None) -> BatchSimResult:
        B, T, dt = self.platform.n_designs, trace.ticks, trace.dt
        p50 = np.empty(B)
        p99 = np.empty(B)
        for b in range(B):
            p50[b], p99[b] = latency_percentiles(
                admitted_hist[:, b], served_hist[:, b], dt,
                queue_drops=None if qdrops is None else qdrops[:, b])
        sim_seconds = T * dt
        return BatchSimResult(
            n_designs=B, ticks=T, dt=dt,
            offered=self._offered(trace),
            completed=completed, dropped=dropped, residual=residual,
            throughput_rps=(completed / sim_seconds if sim_seconds
                            else np.zeros(B)),
            p50_latency_s=p50, p99_latency_s=p99, energy_j=energy,
            energy_per_request_j=np.where(
                completed > 0, energy / np.maximum(completed, 1e-9),
                np.nan),
            mean_power_w=(energy / sim_seconds if sim_seconds
                          else np.zeros(B)),
            swaps=np.asarray(swaps, dtype=np.int64),
            elapsed_wall_s=elapsed, backend=backend, telemetry=telem,
            dropped_slo=dropped_slo, dropped_fault=dropped_fault,
            retried=retried)

    # ------------------------------------------------------------- jax
    def _control_plan(self):
        """Digest the (optional) controller into static arrays/params the
        traced scan can close over.  Supported in the jax backend: no
        controller, guard-only, and the vectorized membound/PID policies."""
        ctl = self.controller
        if ctl is None:
            return {"kind": "none"}
        from repro.core.dfs import BatchMemoryBoundPolicy, BatchPIDRatePolicy
        topo = ctl.topo
        names = np.asarray(topo.names)
        plan = {
            "topo": topo,
            "guard": ctl.queue_guard_ticks,
            "guard_release": ctl.guard_release_ticks,
            "guard_rate": ctl.guard_rate,
        }
        if ctl.policy is None:
            plan["kind"] = "guard"
        elif isinstance(ctl.policy, BatchMemoryBoundPolicy):
            plan["kind"] = "membound"
            plan["threshold"] = ctl.policy.threshold
            plan["low_rate"] = ctl.policy.low_rate
            plan["skip"] = (topo.fixed | (topo.counts == 0)
                            | (names == "noc_mem"))
        elif isinstance(ctl.policy, BatchPIDRatePolicy):
            pol = ctl.policy
            plan["kind"] = "pid"
            plan.update(target=pol.target, kp=pol.kp, ki=pol.ki, kd=pol.kd,
                        min_rate=pol.min_rate,
                        integral_clamp=pol.integral_clamp)
            plan["skip"] = (topo.fixed | (topo.counts == 0)
                            | np.isin(names, pol.skip))
        elif hasattr(ctl.policy, "jax_step"):
            # custom BatchPolicy lowered into the scan/kernel carry: the
            # policy ships its own jax-side step (see core/dfs.py
            # BatchJaxPolicy protocol) and the harness semantics —
            # guard latch, ladder quantization, masked dual-buffer
            # commit — stay in the shared control lowering
            pol = ctl.policy
            plan["kind"] = "custom"
            plan["policy"] = pol
            skip = (pol.skip_islands(topo)
                    if hasattr(pol, "skip_islands")
                    else (topo.fixed | (topo.counts == 0)))
            plan["skip"] = np.asarray(skip, dtype=bool)
        else:
            raise NotImplementedError(
                "jax backend supports controller=None, guard-only, "
                "BatchMemoryBoundPolicy, BatchPIDRatePolicy, or any "
                "policy implementing the jax_step protocol; got "
                f"{type(ctl.policy).__name__}")
        return plan

    # ------------------------------------------------- jax control plane
    def _jax_control(self, plan, ci: int, B: int):
        """Lower the digested controller plan to ONE jax-traceable control
        function shared by the ``lax.scan`` backend and the Pallas kernel
        (so the two fast paths cannot drift).

        Returns ``(control, pol_state0)``: ``control(rates, guard,
        pol_state, ctl_flag, obs, dead=None, stuck=None)`` applies the
        policy + guard latch + ladder quantization + masked dual-buffer
        commit and returns ``(rates, guard, pol_state, committed)``;
        ``pol_state0`` is the tuple of per-design policy-state arrays
        threaded through the carry (PID integral/prev-err, or whatever a
        custom ``jax_step`` policy declares via ``jax_state``).  ``obs``
        carries per-TILE signals (``util``, ``bound``, ``qt`` — each
        ``(B, A)``); island aggregation happens here so both backends
        share it.  ``control`` is None for an open-loop run.
        """
        import jax
        import jax.numpy as jnp
        kind = plan["kind"]
        if kind == "none":
            return None, (), None
        topo = plan["topo"]
        # numpy, not jnp: the Pallas backend must feed these through
        # kernel inputs (captured array constants are rejected), so the
        # closure converts lazily (or takes a ``consts=`` override)
        cst = {"membership": np.asarray(topo.membership),       # (I, A)
               "counts_safe": np.where(topo.counts > 0,
                                       topo.counts, 1.0),
               "counts_pos": np.asarray(topo.counts > 0),
               "fixed": np.asarray(topo.fixed),
               "levels": np.asarray(topo.ladder_levels),        # (I, Lmax)
               "skip": np.asarray(plan.get(
                   "skip", np.ones(len(topo.names), dtype=bool)))}
        I = len(topo.names)
        pol = plan.get("policy")
        # Physical DVFS: the harness's tech model (injected by the engine
        # at construction when it has one) supplies the legal [L, U]
        # ratio range; baked as compile-time floats, keyed in the jit
        # cache via the _scan_cache_sig tech slot.
        tech = getattr(self.controller, "tech", None)
        tech_lo = None if tech is None else float(tech.l_bound)
        tech_hi = None if tech is None else float(tech.u_bound)
        if tech is not None:
            # (I, Lmax) mask of ladder levels inside [L, U]: quantization
            # snaps clamped requests to the nearest LEGAL level (the +inf
            # ladder padding is illegal by construction); islands whose
            # ladder lies fully outside fall back to every real level
            lvq = cst["levels"]
            legal = (lvq >= tech_lo) & (lvq <= tech_hi)
            cst["tech_legal"] = np.where(
                legal.any(axis=-1, keepdims=True), legal,
                np.isfinite(lvq))

        if kind == "pid":
            ctlp = self.controller.policy
            if ctlp._integral is not None:
                pol_state0 = (np.asarray(ctlp._integral),
                              np.asarray(ctlp._prev_err),
                              np.ones((B, 1), dtype=bool))
            else:
                pol_state0 = (np.zeros((B, I)), np.zeros((B, I)),
                              np.zeros((B, 1), dtype=bool))
        elif kind == "custom":
            pol_state0 = tuple(np.asarray(s) for s in pol.jax_state(B, I))
        else:
            pol_state0 = ()

        def control(rates, guard, pol_state, ctl_flag, obs,  # repro: traced
                    dead=None, stuck=None, consts=None):
            c = (consts if consts is not None
                 else {kk: jnp.asarray(vv) for kk, vv in cst.items()})
            membership = c["membership"]
            counts_safe = c["counts_safe"]
            counts_pos = c["counts_pos"]
            fixed = c["fixed"]
            levels = c["levels"]
            skip = c["skip"]
            util_i = (obs["util"] @ membership.T) / counts_safe
            bound_i = (obs["bound"] @ membership.T) / counts_safe
            qt = obs["qt"]
            qt_i = jnp.where(membership[None, :, :] > 0,
                             qt[:, None, :], -jnp.inf).max(axis=-1)
            qt_i = jnp.where(counts_pos, qt_i, 0.0)

            valid = jnp.zeros(rates.shape, dtype=bool)
            req = rates
            if kind == "membound":
                req = jnp.where(bound_i >= plan["threshold"],
                                plan["low_rate"], 1.0)
                valid = ~skip[None, :] & jnp.ones_like(valid)
            elif kind == "pid":
                pid_i, pid_prev, pid_has = pol_state
                err = jnp.where(skip[None, :], 0.0,
                                util_i - plan["target"])
                i_term = jnp.clip(pid_i + err,
                                  -plan["integral_clamp"],
                                  plan["integral_clamp"])
                d_term = jnp.where(pid_has, err - pid_prev, 0.0)
                new = (rates + plan["kp"] * err + plan["ki"] * i_term
                       + plan["kd"] * d_term)
                req = jnp.clip(new, plan["min_rate"], 1.0)
                valid = ~skip[None, :] & jnp.ones_like(valid)
                pol_state = (jnp.where(ctl_flag, i_term, pid_i),
                             jnp.where(ctl_flag, err, pid_prev),
                             pid_has | ctl_flag)
            elif kind == "custom":
                obs_i = {"util": util_i, "boundness": bound_i,
                         "queue_ticks": qt_i}
                req_raw, new_state = pol.jax_step(rates, obs_i,
                                                  tuple(pol_state))
                # NaN = "no request" (the numpy BatchPolicy contract)
                req_raw = jnp.where(skip[None, :], jnp.nan, req_raw)
                valid = ~jnp.isnan(req_raw)
                req = jnp.where(valid, req_raw, rates)
                pol_state = tuple(
                    jax.tree_util.tree_map(
                        lambda n, o: jnp.where(ctl_flag, n, o),
                        tuple(new_state), tuple(pol_state)))

            if plan["guard"] is not None:
                latch = jnp.where(
                    qt_i > plan["guard"], True,
                    jnp.where(qt_i < plan["guard_release"], False,
                              guard))
                latch = latch & ~fixed[None, :]
                if dead is not None:     # dead islands drop out of latch
                    latch = latch & ~dead[None, :]
                req = jnp.where(latch, plan["guard_rate"], req)
                valid = valid | latch
                guard = jnp.where(ctl_flag, latch, guard)

            if tech_lo is not None:
                # clamp commits into the node's legal DVFS ratio range
                # (NaN "no request" entries pass through jnp.clip)
                req = jnp.clip(req, tech_lo, tech_hi)

            d = jnp.abs(levels[None, :, :] - req[:, :, None])
            if tech_lo is not None:     # illegal levels can't win argmin
                d = jnp.where(c["tech_legal"][None, :, :], d, jnp.inf)
            idx = jnp.argmin(d, axis=-1)
            qz = jnp.take_along_axis(
                jnp.broadcast_to(levels, (req.shape[0],) + levels.shape),
                idx[:, :, None], axis=-1)[:, :, 0]
            changed = valid & ~fixed[None, :] & (qz != rates) & ctl_flag
            if dead is not None:        # no hardware to commit to
                changed = changed & ~dead[None, :]
            if stuck is not None:       # actuator write never lands
                changed = changed & ~stuck[None, :]
            rates = jnp.where(changed, qz, rates)
            committed = jnp.where(ctl_flag, changed.any(axis=-1), False)
            return rates, guard, pol_state, committed

        return control, pol_state0, cst

    def _control_writeback(self, plan, ratesF, guardF, swapsF, polF,
                           swaps_before):
        """Push the scan/kernel's evolved controller state back into the
        Python-side harness/policy objects (shared by jax and pallas)."""
        ctl = self.controller
        if ctl is None:
            return
        ctl.rates = np.asarray(ratesF, dtype=np.float64)
        ctl._guard_active = np.asarray(guardF, dtype=bool)
        ctl.swaps = swaps_before + np.asarray(swapsF).astype(np.int64)
        ctl.versions = ctl.versions + np.asarray(swapsF).astype(np.int64)
        if plan["kind"] == "pid":
            ctl.policy._integral = np.asarray(polF[0], dtype=np.float64)
            ctl.policy._prev_err = np.asarray(polF[1], dtype=np.float64)
        elif plan["kind"] == "custom" and hasattr(plan["policy"],
                                                 "jax_sync"):
            plan["policy"].jax_sync(tuple(np.asarray(s) for s in polF))

    # --------------------------------------------- jit-cache bookkeeping
    def _policy_digest(self, plan):
        """Hashable digest of everything the control lowering bakes into
        the traced function as a compile-time constant."""
        kind = plan["kind"]
        if kind == "none":
            return ("none",)
        topo = plan["topo"]
        items = [kind, plan["guard"], plan["guard_release"],
                 plan["guard_rate"],
                 np.asarray(topo.membership).tobytes(),
                 np.asarray(topo.counts).tobytes(),
                 np.asarray(topo.fixed).tobytes(),
                 np.asarray(topo.ladder_levels).tobytes(),
                 np.asarray(plan.get("skip", ())).tobytes()]
        if kind == "membound":
            items += [plan["threshold"], plan["low_rate"]]
        elif kind == "pid":
            items += [plan[kk] for kk in ("target", "kp", "ki", "kd",
                                          "min_rate", "integral_clamp")]
        elif kind == "custom":
            pol = plan["policy"]
            if hasattr(pol, "jax_cache_key"):
                items.append(pol.jax_cache_key())
            else:
                # identity + scalar attrs: a retuned policy (same object,
                # new gains) must miss the cache
                items.append((type(pol).__module__,
                              type(pol).__qualname__, id(pol)))
                items.append(tuple(sorted(
                    (kk, vv) for kk, vv in vars(pol).items()
                    if isinstance(vv, (bool, int, float, str)))))
        return tuple(items)

    def _balancer_digest(self):
        lb = self.balancer
        if lb is None:
            return None
        return (lb.mode, np.asarray(lb.membership).tobytes(),
                np.asarray(lb.group_of).tobytes(),
                np.asarray(lb.covered).tobytes())

    def _scan_cache_sig(self, *, T, ci, dt, B, D, arrivals_ndim,
                        fault_key, plan, slo):
        """The ONE canonical scan-jit cache signature.

        Every Python-level constant the traced ``run_scan`` closure
        bakes in must be keyed here (``SCAN_SIG_FIELDS`` names the
        slots; ``tests/test_analysis.py`` enumerates them and the
        RPR002 rule pass checks completeness statically).  Keeping the
        construction in a single helper means a future knob added to
        the scan cannot be forgotten at one of several call sites."""
        p, cfg, m = self.platform, self.config, self.platform.model
        return ("scan", T, ci, dt, B, D, arrivals_ndim, fault_key,
                self._policy_digest(plan), self._balancer_digest(),
                (cfg.max_queue, cfg.dynamic_contention,
                 cfg.noc_power_share),
                (m.own_demand, m.tg_demand, m.noc.link_bw,
                 m.noc.max_slowdown, m.noc.hop_latency,
                 m.hop_latency_share,
                 1.0 + m.hop_latency_share * m._ref_hops(), p.n_tg),
                None if slo is None else (slo.on_kill, slo.recovers,
                                          slo.deadline_s),
                (None if self.tech is None else self.tech.key,
                 None if getattr(self.controller, "tech", None) is None
                 else self.controller.tech.key))

    def _cached_scan(self, sig, build):
        """Look up / build the jitted scan for an explicit signature.
        Bounded LRU (``_SCAN_CACHE_MAX``): long-lived engines driven
        through many trace lengths / schedules can't pin one executable
        per configuration forever."""
        fn = self._jax_cache.get(sig)
        if fn is not None:
            self._jax_cache.move_to_end(sig)
            return fn
        fn = build()
        self._jax_cache[sig] = fn
        while len(self._jax_cache) > _SCAN_CACHE_MAX:
            self._jax_cache.popitem(last=False)
        return fn

    def _run_jax(self, trace) -> BatchSimResult:
        import jax
        import jax.numpy as jnp
        from jax import lax
        from repro import shard as shard_mod
        from repro.core.perfmodel import (P_DYN_W, P_STATIC_W, V_BASE,
                                          V_SLOPE)

        p, cfg = self.platform, self.config
        B, A, T, dt = p.n_designs, p.n_tiles, trace.ticks, trace.dt
        self._check_trace(trace)
        m = p.model
        plan = self._control_plan()
        kind = plan["kind"]
        ctl = self.controller
        ci = cfg.control_interval if (ctl is not None
                                      and cfg.control_interval) else 0
        is_ctl = np.zeros(T, dtype=bool)
        if ci:
            is_ctl[ci - 1::ci] = True
        D = shard_mod.resolve_devices(self.devices)
        Bp = shard_mod.shard_len(B, D)

        # ----- replicated statics (shared across designs; safe to close
        # over even under shard_map) — per-DESIGN arrays travel through
        # the ``pd`` argument instead so the design axis can shard
        island_of_tile = jnp.asarray(self._island_of_tile)
        noc_idx = self._noc_island
        own = m.own_demand                  # static TG-saturation term
        demand = jnp.asarray(np.asarray(self._flow_demand,
                                        dtype=np.float64))  # live link loads
        has_fwd = self._forward is not None
        fwdM = jnp.asarray(self._forward) if has_fwd else None
        lb = self.balancer
        if lb is not None:
            lbM = jnp.asarray(lb.membership)
            lb_gof = jnp.asarray(lb.group_of)
            lb_cov = jnp.asarray(lb.covered)
            lb_mode = lb.mode

            def lb_split(arr, queue, cap, alive=None):
                if lb_mode == "even":
                    w = jnp.ones_like(arr)
                elif lb_mode == "capacity":
                    w = cap
                else:
                    w = cap / (1.0 + queue)
                # sanitize + dead-replica masking, as LoadBalancer.split
                w = jnp.where(jnp.isfinite(w) & (w > 0.0), w, 0.0)
                if alive is not None:
                    w = w * alive
                tot = jnp.einsum("ba,ga->bg", arr, lbM)
                wsum = jnp.einsum("ba,ga->bg", w, lbM)
                # all-zero weight groups fall back to an even split,
                # mirroring LoadBalancer.split
                w = jnp.where((wsum <= 0.0)[:, lb_gof], 1.0, w)
                wsum = jnp.einsum("ba,ga->bg", w, lbM)
                shared = tot[:, lb_gof] * (w / wsum[:, lb_gof])
                return jnp.where(lb_cov, shared, arr)
        tgd = m.tg_demand
        link_bw = m.noc.link_bw
        max_slow = m.noc.max_slowdown
        hop_lat = m.noc.hop_latency
        hop_share = m.hop_latency_share
        hopf0 = 1.0 + m.hop_latency_share * m._ref_hops()
        n_tg = p.n_tg
        dyn_on = cfg.dynamic_contention
        max_q = cfg.max_queue
        # monitoring statics: a Python bool baked into the trace (part of
        # the jit cache key) — level=off scans emit no extra ys and stay
        # byte-identical to the pre-observability trace.
        ob = self.observer
        observing = ob is not None and ob.enabled
        n_islands = len(p.islands.names())
        n_links = int(self._inc.shape[-1])

        # ----- fault/SLO statics: presence flags are Python bools baked
        # into the trace (part of the jit cache key); the per-tick mask
        # VALUES ride through the scanned xs pytree, so editing a schedule
        # of the same shape class never retraces
        cf = self._compile_faults(T)
        slo = self.slo
        if slo is None and cf is not None:
            slo = SLOConfig()
        deadline = slo is not None and slo.deadline_s is not None
        deadline_ticks = slo.deadline_s / dt if deadline else None
        has_tile = cf is not None and cf.has_tile
        has_link = cf is not None and cf.has_link
        has_stuck = cf is not None and cf.has_stuck
        has_stuck_rate = cf is not None and cf.has_stuck_rate
        recover = has_tile and slo.recovers and lb is not None
        drain = has_tile and slo.on_kill != "wait"
        track = has_tile or deadline

        control, pol0, _cctl = self._jax_control(plan, ci, B)

        def voltage2(f):
            v = V_BASE + V_SLOPE * f
            return v * v

        # Physical DVFS: the tech model's three coefficients bake in as
        # compile-time Python floats (keyed by the _scan_cache_sig tech
        # slot); tech=None keeps the legacy linear-proxy expressions
        # bit for bit.
        if self.tech is None:
            def _pw(f, busy):
                return (P_STATIC_W
                        + P_DYN_W * f * voltage2(f) * busy)
        else:
            t_ps, t_v0, t_v1 = self.tech.power_coeffs

            def _pw(f, busy):
                v = t_v0 + t_v1 * f
                return t_ps * (P_STATIC_W
                               + P_DYN_W * f * v * v * busy)

        def run_scan(pd, xs0, init):
            # per-design constants arrive as (possibly sharded) arguments
            inc = pd["inc"]
            hop_counts = pd["hop"]
            base_mbps = pd["base"]
            req_mb = pd["req"]
            w = pd["w"]
            k = pd["k"]
            t_comp_ref = pd["tcr"]
            f_tg = pd["ftg"]
            hopf = 1.0 + hop_share * hop_counts
            t_ref = (1.0 - w) + w * max(1.0, own) * hopf0

            def service(rates):
                f_tile = rates[:, island_of_tile]               # (B, A)
                f_noc = (rates[:, noc_idx] if noc_idx >= 0
                         else jnp.ones(rates.shape[0]))
                fa = jnp.maximum(f_tile, 1e-3)
                fn = jnp.maximum(f_noc, 1e-3)[:, None]
                load = own + tgd * f_tg[:, None] * n_tg
                slow = jnp.maximum(1.0, load / (link_bw * fn))
                t_comp = (1.0 - w) / (k * fa)
                t_wire = w * slow * hopf / fn
                return t_comp, t_wire, f_tile, f_noc

            def step(carry, xs):
                arr_t, ctl_flag = xs["arr"], xs["ctl"]
                (queue, busy, rtt, rates, guard, pol_state, ctl_busy,
                 dropped, energy, swaps, carry_fwd, prev_cap,
                 retry_q, dslo, dfault, retried) = carry
                alive_t = xs["alive"] if has_tile else None
                if has_stuck_rate:
                    srate_t = xs["srate"]      # (I,) NaN = follow software
                    rates_eff = jnp.where(jnp.isnan(srate_t)[None, :],
                                          rates, srate_t[None, :])
                else:
                    rates_eff = rates
                t_comp, t_wire, f_tile, f_noc = service(rates_eff)

                # drain work stranded on dead replicas BEFORE the split,
                # so the re-spill weights see the post-drain queues (as
                # the numpy engines do)
                respill = stranded_exit = None
                if drain:
                    dead_m = 1.0 - alive_t
                    stranded = queue * dead_m
                    s_retry = retry_q * dead_m
                    queue = queue - stranded
                    retry_q = retry_q - s_retry
                    if recover:
                        surv = jnp.einsum("a,ga->g", alive_t, lbM) > 0.0
                        can = lb_cov & surv[lb_gof]
                        respill = jnp.where(can, stranded - s_retry, 0.0)
                        fdrop = stranded - respill
                        retried = retried + respill.sum(axis=-1)
                        stranded_exit = respill + fdrop
                    else:
                        fdrop = stranded
                        stranded_exit = stranded
                    dfault = dfault + fdrop.sum(axis=-1)

                arr_eff = jnp.broadcast_to(arr_t, queue.shape)
                if has_fwd:
                    arr_eff = arr_eff + carry_fwd
                retry_arr = None
                if lb is not None:
                    arr_eff = lb_split(arr_eff, queue, prev_cap,
                                       alive=alive_t if recover else None)
                    if recover:
                        retry_arr = lb_split(respill, queue, prev_cap,
                                             alive=alive_t)
                        arr_eff = arr_eff + retry_arr
                q = queue + arr_eff
                adm = arr_eff
                if recover:
                    q0 = q              # retry-class mixing denominator
                    retry_q = retry_q + retry_arr
                if max_q != float("inf"):
                    over = jnp.maximum(q - max_q, 0.0)
                    q = q - over
                    adm = adm - over
                    dropped = dropped + over.sum(axis=-1)
                if dyn_on:
                    loads = jnp.einsum("ba,bal->bl", demand * busy, inc)
                    if has_link:
                        loads = loads / xs["lscale"]
                    rho = ((inc * loads[:, None, :]).max(axis=-1)
                           / (link_bw * f_noc[:, None]))
                    r = jnp.minimum(rho, 0.999)
                    dyn = jnp.minimum(1.0 + r / (2.0 * (1.0 - r)),
                                      max_slow)
                else:
                    loads = None
                    dyn = jnp.ones_like(q)
                cap = (base_mbps * t_ref / (t_comp + t_wire * dyn)
                       / req_mb) * dt
                if has_tile:
                    cap_nominal = cap
                    cap = cap * alive_t
                    served = jnp.minimum(q, cap)
                    queue = q - served
                    busy = jnp.where(cap > 0.0,
                                     served / jnp.where(cap > 0.0, cap,
                                                        1.0),
                                     0.0)
                else:
                    served = jnp.minimum(q, cap)
                    queue = q - served
                    busy = served / cap
                slo_drop = None
                if deadline:
                    horizon = ((cap if not has_tile else cap_nominal)
                               * deadline_ticks)
                    slo_drop = jnp.maximum(queue - horizon, 0.0)
                    queue = queue - slo_drop
                    dslo = dslo + slo_drop.sum(axis=-1)
                if recover:
                    retry_q = retry_q * jnp.where(
                        q0 > 0.0, queue / jnp.where(q0 > 0.0, q0, 1.0),
                        0.0)
                rtt = rtt + hop_counts * dyn * hop_lat
                if has_fwd:
                    carry_fwd = jnp.einsum("ba,aj->bj", served, fwdM)
                if lb is not None:
                    prev_cap = cap

                tp = _pw(f_tile, busy)
                if has_tile:            # dead tiles are power-gated
                    tp = tp * alive_t
                tile_power = jnp.sum(tp, axis=-1)
                noc_power = cfg.noc_power_share * _pw(f_noc, 1.0)
                energy = energy + (tile_power + noc_power) * dt
                ctl_busy = ctl_busy + busy

                if control is not None:
                    t_wire_now = t_wire * dyn
                    obs = {"util": ctl_busy / max(ci, 1),
                           "bound": t_wire_now / (t_comp_ref
                                                  + t_wire_now),
                           "qt": queue / jnp.maximum(cap, 1e-12)}
                    rates, guard, pol_state, committed = control(
                        rates, guard, pol_state, ctl_flag, obs,
                        dead=xs["dead"] if has_tile else None,
                        stuck=xs["stuck_m"] if has_stuck else None)
                    swaps = swaps + committed
                ctl_busy = jnp.where(ctl_flag, 0.0, ctl_busy)
                carry = (queue, busy, rtt, rates, guard, pol_state,
                         ctl_busy, dropped, energy, swaps, carry_fwd,
                         prev_cap, retry_q, dslo, dfault, retried)
                if track:
                    qdrop_t = jnp.zeros_like(queue)
                    if stranded_exit is not None:
                        qdrop_t = qdrop_t + stranded_exit
                    if slo_drop is not None:
                        qdrop_t = qdrop_t + slo_drop
                    ys = (adm, served, qdrop_t)
                else:
                    ys = (adm, served)
                if observing:
                    # pure reads of the step's arrays, never fed back
                    # into the dynamics above; narrow float32 snapshots
                    obs_ys = {"cap": cap.astype(jnp.float32),
                              "dyn": dyn.astype(jnp.float32),
                              "stall": queue > STALL_EPS,
                              "rates": rates_eff.astype(jnp.float32)}
                    ys = ys + (obs_ys,)
                return carry, ys

            Bb = k.shape[0]
            zBA = jnp.zeros((Bb, A))
            zB = jnp.zeros(Bb)
            carry0 = (zBA, zBA, zBA, init["rates"], init["guard"],
                      tuple(init["pol"]), zBA, zB, zB,
                      jnp.zeros(Bb, dtype=jnp.int32), zBA, init["cap"],
                      zBA, zB, zB, zB)
            return lax.scan(step, carry0, xs0)

        if ctl is not None:
            ctl.begin_run()
            rates0 = ctl.live_rates()
            guard0 = ctl._guard_active
            swaps_before = ctl.swaps.copy()
        else:
            rates0 = p.rates
            guard0 = np.zeros((B, n_islands), dtype=bool)
            swaps_before = None
        cap0 = (self.capacity_rps(rates0) * dt if lb is not None
                else np.zeros((B, A)))

        arrivals = np.asarray(trace.arrivals)
        xs0 = {"arr": arrivals, "ctl": is_ctl}
        if has_tile:
            xs0["alive"] = np.asarray(cf.tile_alive)
            xs0["dead"] = np.asarray(cf.island_dead)
        if has_link:
            xs0["lscale"] = np.asarray(cf.link_scale)
        if has_stuck:
            xs0["stuck_m"] = np.asarray(cf.stuck)
        if has_stuck_rate:
            xs0["srate"] = np.asarray(cf.stuck_rate)
        pd = {"inc": np.asarray(self._inc),
              "hop": np.asarray(self._hop_counts, dtype=np.float64),
              "base": p.base_mbps, "req": p.req_mb, "w": p.wire_share,
              "k": p.k, "tcr": self._t_comp_ref, "ftg": p.f_tg}
        init = {"rates": np.asarray(rates0), "guard": np.asarray(guard0),
                "cap": np.asarray(cap0), "pol": tuple(pol0)}
        if Bp != B:
            # pad the design axis to a device multiple with copies of
            # design 0 (computed, then discarded — sliced off below)
            pad = lambda a: shard_mod.pad_axis(np.asarray(a), D)  # noqa
            pd = {kk: pad(vv) for kk, vv in pd.items()}
            init = {"rates": pad(init["rates"]),
                    "guard": pad(init["guard"]), "cap": pad(init["cap"]),
                    "pol": tuple(pad(s) for s in init["pol"])}
            if arrivals.ndim == 3:
                xs0["arr"] = shard_mod.pad_axis(arrivals, D, axis=1)

        # ----- explicit jit-cache key: every Python-level constant the
        # traced function bakes in (the (T, ci, fault-flag) key of the
        # original implementation collided on dt, controller tuning,
        # balancer layout, SLO mode and config scalars)
        fault_key = (has_tile, has_link, has_stuck, has_stuck_rate,
                     recover, drain, track, deadline_ticks, observing)
        sig = self._scan_cache_sig(T=T, ci=ci, dt=dt, B=B, D=D,
                                   arrivals_ndim=arrivals.ndim,
                                   fault_key=fault_key, plan=plan,
                                   slo=slo)

        def build():
            if D <= 1:
                return jax.jit(run_scan)
            from jax.sharding import PartitionSpec
            from repro.compat import shard_map as _smap
            mesh = shard_mod.device_mesh(D, "designs")

            def lead(a):
                return PartitionSpec(
                    *(("designs",) + (None,) * (np.ndim(a) - 1)))

            def rep(a):
                return PartitionSpec(*((None,) * np.ndim(a)))

            def timed(a):
                nd = np.ndim(a)
                if nd >= 3:             # (T, B, ...) per-design axis
                    return PartitionSpec(
                        *((None, "designs") + (None,) * (nd - 2)))
                return rep(a)

            in_specs = (
                jax.tree_util.tree_map(lead, pd),
                {kk: (timed(vv) if kk == "arr" else rep(vv))
                 for kk, vv in xs0.items()},
                jax.tree_util.tree_map(lead, init))
            out_sh = jax.eval_shape(run_scan, pd, xs0, init)
            out_specs = (
                jax.tree_util.tree_map(
                    lambda s: PartitionSpec(
                        *(("designs",) + (None,) * (len(s.shape) - 1))),
                    out_sh[0]),
                jax.tree_util.tree_map(
                    lambda s: PartitionSpec(
                        *((None, "designs")
                          + (None,) * (len(s.shape) - 2))),
                    out_sh[1]))
            return jax.jit(_smap(run_scan, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False))

        fn = self._cached_scan(sig, build)

        wall0 = time.perf_counter()
        carryF, ys = fn(pd, xs0, init)
        obs_ys = None
        if observing:
            *ys, obs_ys = ys
        if track:
            admitted, served, qdropT = ys
            qdrops = np.asarray(qdropT, dtype=np.float64)[:, :B]
        else:
            admitted, served = ys
            qdrops = None
        (queueF, busyF, rttF, ratesF, guardF, polF, _ctlb, droppedF,
         energyF, swapsF, _fwdF, _capF, retryqF, dsloF, dfaultF,
         retriedF) = carryF
        polF = tuple(np.asarray(s)[:B] for s in polF)
        queueF, busyF, rttF, ratesF, guardF = [
            np.asarray(x)[:B]
            for x in (queueF, busyF, rttF, ratesF, guardF)]
        droppedF, energyF, swapsF, retryqF, dsloF, dfaultF, retriedF = [
            np.asarray(x)[:B]
            for x in (droppedF, energyF, swapsF, retryqF, dsloF,
                      dfaultF, retriedF)]
        admitted = np.asarray(admitted, dtype=np.float64)[:, :B]
        served = np.asarray(served, dtype=np.float64)[:, :B]
        elapsed = time.perf_counter() - wall0

        if obs_ys is not None:
            # lazy reconstruction from the raw per-tick ys on the first
            # counters read — the scan itself only paid the ys memcpys.
            # busy, the link loads and the power integral are replayed
            # host-side with the scan's own expressions (float64 over the
            # float32 snapshots, so they land within f32 rounding of the
            # numpy engine's counters)
            obs_ys = {kk: np.asarray(vv)[:, :B]
                      for kk, vv in obs_ys.items()}
            tile_alive_np = (np.asarray(cf.tile_alive, dtype=np.float64)
                             if has_tile else None)
            lscale_np = (np.asarray(cf.link_scale, dtype=np.float64)
                         if has_link else None)
            demand_np = np.asarray(self._flow_demand, dtype=np.float64)
            inc_np = np.asarray(self._inc, dtype=np.float64)
            iot_np = np.asarray(self._island_of_tile)

            def _jax_plane(o=obs_ys, admitted=admitted, served=served):
                stall = np.asarray(o["stall"])
                cap_t = np.asarray(o["cap"], dtype=np.float64)
                dyn_t = np.asarray(o["dyn"], dtype=np.float64)
                rates_t = np.asarray(o["rates"], dtype=np.float64)
                f_tile = rates_t[:, :, iot_np]                 # (T, B, A)
                f_noc = (rates_t[:, :, noc_idx] if noc_idx >= 0
                         else np.ones(rates_t.shape[:2]))      # (T, B)
                busy = np.where(cap_t > 0.0,
                                served / np.where(cap_t > 0.0, cap_t,
                                                  1.0),
                                0.0)
                pktf = np.asarray(p.req_mb) * 1e6 / PKT_BYTES
                hopc = np.asarray(self._hop_counts, dtype=np.float64)
                oh = np.zeros((A, n_islands))
                oh[np.arange(A), iot_np] = 1.0
                tile = {
                    "offered": admitted.sum(axis=0),
                    "invocations": served.sum(axis=0),
                    "busy_ticks": busy.sum(axis=0),
                    "stall_ticks": stall.sum(axis=0).astype(float),
                    "cap_sum": cap_t.sum(axis=0),
                    "hop_flits": (served * pktf * hopc).sum(axis=0),
                    "slowdown_sum": (dyn_t - 1.0).sum(axis=0)}
                if dyn_on:
                    # the wire load at tick t is driven by busy[t-1], as
                    # in the scan (busy starts the run at zero)
                    busy_prev = np.concatenate(
                        [np.zeros((1, B, A)), busy[:-1]], axis=0)
                    loads = np.einsum("tba,bal->tbl",
                                      demand_np * busy_prev, inc_np)
                    if lscale_np is not None:
                        loads = loads / lscale_np[:, None, :]
                    util = loads / (link_bw * f_noc[..., None])
                    link = {"flits": loads.sum(axis=0) / PKT_BYTES,
                            "util_sum": util.sum(axis=0),
                            "peak_util": util.max(axis=0, initial=0.0)}
                else:
                    link = {kk: np.zeros((B, n_links))
                            for kk in ("flits", "util_sum", "peak_util")}
                tp = _pw(f_tile, busy)
                if tile_alive_np is not None:
                    tp = tp * tile_alive_np[:, None, :]
                noc_p = cfg.noc_power_share * _pw(f_noc, 1.0)
                en = (tp.sum(axis=0) * dt) @ oh
                if noc_idx >= 0:
                    en[:, noc_idx] += noc_p.sum(axis=0) * dt
                return CounterPlane.from_arrays(
                    tile=tile, link=link, island={"energy_j": en},
                    ticks=np.full(B, float(T)), lead=(B,),
                    tile_names=p.names, island_names=p.islands.names())
            ob.attach_lazy(_jax_plane)

        self._control_writeback(plan, ratesF, guardF, swapsF, polF,
                                swaps_before)
        self.last_state = TickState(
            queue=queueF.astype(np.float64), busy=busyF.astype(np.float64),
            pkts_in=(admitted.sum(axis=0) * np.asarray(p.req_mb)
                     * 1e6 / PKT_BYTES),
            pkts_out=(served.sum(axis=0) * np.asarray(p.req_mb)
                      * 1e6 / PKT_BYTES),
            rtt_acc=rttF.astype(np.float64),
            dropped=droppedF.astype(np.float64),
            energy=energyF.astype(np.float64),
            retry_q=retryqF.astype(np.float64),
            dropped_slo=dsloF.astype(np.float64),
            dropped_fault=dfaultF.astype(np.float64),
            retried=retriedF.astype(np.float64))
        self.last_histories = (admitted, served)
        self.last_fault_histories = (
            None if qdrops is None else {"queue_drops": qdrops})
        return self._result(
            trace, admitted, served,
            completed=self._completed(served),
            dropped=droppedF.astype(np.float64),
            residual=queueF.astype(np.float64).sum(axis=-1),
            energy=energyF.astype(np.float64),
            swaps=swapsF.astype(np.int64), elapsed=elapsed,
            backend="jax", telem=None,
            dropped_slo=dsloF.astype(np.float64),
            dropped_fault=dfaultF.astype(np.float64),
            retried=retriedF.astype(np.float64),
            qdrops=qdrops)

    # ------------------------------------------------------------ pallas
    def _run_pallas(self, trace) -> BatchSimResult:
        """The fused-kernel backend: the whole queue-update / contention /
        service / forward / control tick as ONE Pallas kernel
        (:func:`repro.kernels.tick_sim.fused_tick_sim`), T grid steps
        deep, per-tile state held in VMEM scratch between ticks.

        Scope: open-loop replay + every controller the jax backend's
        control lowering supports (membound / PID / guard / custom
        ``jax_step`` policies).  Faults, SLO semantics, the load
        balancer and the observer plane need scan-side bookkeeping this
        kernel does not carry — those runs raise ``NotImplementedError``
        and belong on ``backend="jax"``.  Differentially validated
        against the NumPy float64 engine (f32 tolerance) and the scan
        backend."""
        p, cfg = self.platform, self.config
        B, A, T, dt = p.n_designs, p.n_tiles, trace.ticks, trace.dt
        self._check_trace(trace)
        if self._compile_faults(T) is not None:
            raise NotImplementedError(
                "pallas backend does not simulate fault schedules; "
                "use backend='jax'")
        if self.slo is not None:
            raise NotImplementedError(
                "pallas backend does not apply SLO semantics; "
                "use backend='jax'")
        if self.balancer is not None:
            raise NotImplementedError(
                "pallas backend does not run the load balancer; "
                "use backend='jax'")
        if self.observer is not None and self.observer.enabled:
            raise NotImplementedError(
                "pallas backend records no observer plane; "
                "use backend='jax' or 'numpy'")
        from repro.kernels.tick_sim import fused_tick_sim

        m = p.model
        plan = self._control_plan()
        ctl = self.controller
        ci = cfg.control_interval if (ctl is not None
                                      and cfg.control_interval) else 0
        control, pol0, cctl = self._jax_control(plan, ci, B)
        if ctl is not None:
            ctl.begin_run()
            rates0 = ctl.live_rates()
            guard0 = ctl._guard_active
            swaps_before = ctl.swaps.copy()
        else:
            rates0 = p.rates
            guard0 = np.zeros((B, len(p.islands.names())), dtype=bool)
            swaps_before = None

        arr = np.asarray(trace.arrivals)
        if arr.ndim == 2:               # shared trace -> (T, B, A)
            arr = np.broadcast_to(arr[:, None, :], (T, B, A))
        is_ctl = np.zeros(T, dtype=bool)
        if ci:
            is_ctl[ci - 1::ci] = True

        consts = {"base": p.base_mbps, "req": p.req_mb,
                  "w": p.wire_share, "k": p.k,
                  "hop": np.asarray(self._hop_counts, dtype=np.float64),
                  "tcr": self._t_comp_ref, "inc": np.asarray(self._inc),
                  "ftg": np.asarray(p.f_tg)[:, None]}
        scalars = {"dt": dt, "own": m.own_demand, "tgd": m.tg_demand,
                   "link_bw": m.noc.link_bw,
                   "max_slow": m.noc.max_slowdown,
                   "hop_lat": m.noc.hop_latency,
                   "hop_share": m.hop_latency_share,
                   "hopf0": 1.0 + m.hop_latency_share * m._ref_hops(),
                   "noc_share": cfg.noc_power_share, "n_tg": p.n_tg,
                   "dyn_on": cfg.dynamic_contention,
                   "max_q": cfg.max_queue, "ci": ci,
                   "noc_idx": self._noc_island,
                   "iot": np.asarray(self._island_of_tile),
                   "demand": np.asarray(self._flow_demand,
                                        dtype=np.float64),
                   "forward": (np.asarray(self._forward)
                               if self._forward is not None else None)}
        if self.tech is not None:
            # physical DVFS: bake the node's three power coefficients
            scalars["tech_on"] = True
            (scalars["t_ps"], scalars["t_v0"],
             scalars["t_v1"]) = self.tech.power_coeffs
        init = {"rates": np.asarray(rates0), "guard": np.asarray(guard0),
                "pol": tuple(pol0)}

        wall0 = time.perf_counter()
        out = fused_tick_sim(arr, is_ctl, consts, scalars, init,
                             control_fn=control, control_consts=cctl,
                             interpret=True)
        admitted = np.asarray(out["adm"], dtype=np.float64)
        served = np.asarray(out["served"], dtype=np.float64)
        queueF = np.asarray(out["queue"], dtype=np.float64)
        droppedF = np.asarray(out["dropped"], dtype=np.float64)
        energyF = np.asarray(out["energy"], dtype=np.float64)
        swapsF = np.asarray(np.rint(out["swaps"]), dtype=np.int64)
        elapsed = time.perf_counter() - wall0

        self._control_writeback(plan, out["rates"], out["guard"],
                                swapsF, out["pol"], swaps_before)
        zB = np.zeros(B)
        self.last_state = TickState(
            queue=queueF, busy=np.asarray(out["busy"], dtype=np.float64),
            pkts_in=(admitted.sum(axis=0) * np.asarray(p.req_mb)
                     * 1e6 / PKT_BYTES),
            pkts_out=(served.sum(axis=0) * np.asarray(p.req_mb)
                      * 1e6 / PKT_BYTES),
            rtt_acc=np.asarray(out["rtt"], dtype=np.float64),
            dropped=droppedF, energy=energyF,
            retry_q=np.zeros((B, A)), dropped_slo=zB.copy(),
            dropped_fault=zB.copy(), retried=zB.copy())
        self.last_histories = (admitted, served)
        self.last_fault_histories = None
        return self._result(
            trace, admitted, served,
            completed=self._completed(served),
            dropped=droppedF,
            residual=queueF.sum(axis=-1),
            energy=energyF, swaps=swapsF, elapsed=elapsed,
            backend="pallas", telem=None,
            dropped_slo=zB.copy(), dropped_fault=zB.copy(),
            retried=zB.copy())
