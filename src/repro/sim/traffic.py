"""Arrival-trace generators for the closed-loop SoC simulation.

A :class:`Trace` is the whole workload of a simulation run as one dense
``(ticks, n_dests)`` array of request arrivals per tick per destination
accelerator tile — the tick-aggregated form the vectorized engine consumes
directly (no per-request Python objects, so a million-request trace is a
few MB of float64).  Counts are *fluid* (fractional requests are fine);
generators that sample a point process produce integer counts.

Generators compose: every one returns a :class:`Trace`, and
:func:`superpose` / :meth:`Trace.scaled` / :func:`with_total` combine or
rescale them, so "diurnal baseline + bursty hotspot on tile 3, normalized
to exactly 1M requests" is three calls.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Sequence

import numpy as np


@dataclass(frozen=True)
class Trace:
    """Tick-aggregated arrivals: ``arrivals[t, a]`` requests arrive at
    destination tile ``a`` during tick ``t``; one tick is ``dt`` seconds."""
    arrivals: np.ndarray            # (ticks, n_dests) float64, >= 0
    dt: float                       # seconds per tick

    def __post_init__(self):
        a = np.asarray(self.arrivals, dtype=np.float64)
        assert a.ndim == 2, "arrivals must be (ticks, n_dests)"
        object.__setattr__(self, "arrivals", a)

    @property
    def ticks(self) -> int:
        return int(self.arrivals.shape[0])

    @property
    def n_dests(self) -> int:
        return int(self.arrivals.shape[1])

    @property
    def n_requests(self) -> float:
        return float(self.arrivals.sum())

    @property
    def duration_s(self) -> float:
        return self.ticks * self.dt

    @property
    def offered_rps(self) -> float:
        """Mean offered load over the whole trace, requests/second."""
        return self.n_requests / self.duration_s if self.ticks else 0.0

    def scaled(self, factor: float) -> "Trace":
        return replace(self, arrivals=self.arrivals * float(factor))

    def window(self, start: int, stop: int) -> "Trace":
        return replace(self, arrivals=self.arrivals[start:stop])


@dataclass(frozen=True)
class BatchTrace:
    """Per-design arrival tensor for the batched co-sim engine.

    ``arrivals[t, b, a]`` requests arrive at tile ``a`` of design ``b``
    during tick ``t`` — every stacked design can replay its *own*
    workload in the one batched run (heterogeneous trace seeds, per-design
    rate scaling, recorded logs per candidate).  :meth:`broadcast` lifts a
    shared :class:`Trace` to the batch shape as a zero-copy view; the
    engine's elementwise tick math makes the broadcast replay bit-for-bit
    identical to passing the shared trace directly (tested).
    """
    arrivals: np.ndarray            # (ticks, n_designs, n_dests) >= 0
    dt: float

    def __post_init__(self):
        a = np.asarray(self.arrivals, dtype=np.float64)
        assert a.ndim == 3, "arrivals must be (ticks, n_designs, n_dests)"
        object.__setattr__(self, "arrivals", a)

    @property
    def ticks(self) -> int:
        return int(self.arrivals.shape[0])

    @property
    def n_designs(self) -> int:
        return int(self.arrivals.shape[1])

    @property
    def n_dests(self) -> int:
        return int(self.arrivals.shape[2])

    @property
    def duration_s(self) -> float:
        return self.ticks * self.dt

    @property
    def n_requests(self) -> np.ndarray:
        """Per-design offered totals, shape ``(n_designs,)``."""
        return self.arrivals.sum(axis=(0, 2))

    @classmethod
    def broadcast(cls, trace: Trace, n_designs: int) -> "BatchTrace":
        """Share one (T, A) trace across B designs (no copy)."""
        a = np.broadcast_to(trace.arrivals[:, None, :],
                            (trace.ticks, int(n_designs), trace.n_dests))
        return cls(a, trace.dt)

    @classmethod
    def stack(cls, traces: Sequence[Trace]) -> "BatchTrace":
        """One per-design trace each (same dt/ticks/destinations)."""
        assert traces, "need at least one trace"
        t0 = traces[0]
        for t in traces[1:]:
            assert abs(t.dt - t0.dt) < 1e-12, "dt mismatch"
            assert t.arrivals.shape == t0.arrivals.shape, "shape mismatch"
        return cls(np.stack([t.arrivals for t in traces], axis=1), t0.dt)

    def design(self, b: int) -> Trace:
        """Design ``b``'s own (T, A) trace (the differential-test path)."""
        return Trace(self.arrivals[:, b, :].copy(), self.dt)

    def scaled(self, factor) -> "BatchTrace":
        """Scale by a scalar or per-design ``(n_designs,)`` factor."""
        f = np.asarray(factor, dtype=np.float64)
        if f.ndim == 1:
            f = f[None, :, None]
        return replace(self, arrivals=self.arrivals * f)


def _per_dest_rate(rate_rps, n_dests: int) -> np.ndarray:
    """Broadcast a scalar (total, split evenly) or per-dest rate vector."""
    r = np.asarray(rate_rps, dtype=np.float64)
    if r.ndim == 0:
        return np.full(n_dests, float(r) / n_dests)
    assert r.shape == (n_dests,), (r.shape, n_dests)
    return r


def constant_trace(rate_rps, ticks: int, n_dests: int,
                   *, dt: float = 1e-3) -> Trace:
    """Deterministic constant-rate fluid arrivals (the parity workload:
    no sampling noise, so steady-state throughput is exactly comparable
    to the static perf-model prediction)."""
    per = _per_dest_rate(rate_rps, n_dests) * dt
    return Trace(np.broadcast_to(per, (ticks, n_dests)).copy(), dt)


def poisson_trace(rate_rps, ticks: int, n_dests: int, *, dt: float = 1e-3,
                  seed: int = 0) -> Trace:
    """Homogeneous Poisson arrivals, sampled per (tick, dest)."""
    rng = np.random.default_rng(seed)
    lam = np.broadcast_to(_per_dest_rate(rate_rps, n_dests) * dt,
                          (ticks, n_dests))
    return Trace(rng.poisson(lam).astype(np.float64), dt)


def diurnal_trace(mean_rps, ticks: int, n_dests: int, *, dt: float = 1e-3,
                  period_ticks: Optional[int] = None, depth: float = 0.6,
                  phase: float = 0.0, seed: int = 0) -> Trace:
    """Sinusoid-modulated Poisson arrivals — the "millions of users" daily
    load curve.  Rate swings between ``mean*(1-depth)`` and
    ``mean*(1+depth)`` over ``period_ticks`` (default: the whole trace is
    one day)."""
    assert 0.0 <= depth < 1.0
    rng = np.random.default_rng(seed)
    period = period_ticks or ticks
    t = np.arange(ticks, dtype=np.float64)
    mod = 1.0 + depth * np.sin(2.0 * np.pi * t / period + phase)
    lam = mod[:, None] * _per_dest_rate(mean_rps, n_dests)[None, :] * dt
    return Trace(rng.poisson(lam).astype(np.float64), dt)


def mmpp_trace(low_rps, high_rps, ticks: int, n_dests: int, *,
               dt: float = 1e-3, p_low_to_high: float = 0.01,
               p_high_to_low: float = 0.05, seed: int = 0) -> Trace:
    """Bursty arrivals: a two-state Markov-modulated Poisson process.

    The modulating chain flips between a low-rate and a high-rate state
    with per-tick switch probabilities; dwell times are geometric, so the
    trace alternates quiet stretches with request storms — the tail-latency
    stress test a sinusoid can't provide."""
    rng = np.random.default_rng(seed)
    # sample alternating geometric run lengths until the horizon is covered
    state = np.empty(ticks, dtype=bool)          # True = high
    pos, cur = 0, False
    while pos < ticks:
        p = p_low_to_high if not cur else p_high_to_low
        run = int(rng.geometric(min(max(p, 1e-9), 1.0)))
        state[pos:pos + run] = cur
        pos += run
        cur = not cur
    lo = _per_dest_rate(low_rps, n_dests)
    hi = _per_dest_rate(high_rps, n_dests)
    lam = np.where(state[:, None], hi[None, :], lo[None, :]) * dt
    return Trace(rng.poisson(lam).astype(np.float64), dt)


def replay_trace(arrival_times_s: Sequence[float], dest_ids: Sequence[int],
                 n_dests: int, *, dt: float = 1e-3,
                 ticks: Optional[int] = None) -> Trace:
    """Bin a recorded request log (per-request timestamps + destination
    ids) into the tick grid: one ``bincount`` — millions of log lines
    collapse to the dense (ticks, n_dests) form with no Python loop."""
    t = np.asarray(arrival_times_s, dtype=np.float64)
    d = np.asarray(dest_ids, dtype=np.int64)
    assert t.shape == d.shape
    tick = np.floor(t / dt).astype(np.int64)
    T = int(ticks if ticks is not None else (tick.max() + 1 if t.size else 0))
    keep = (tick >= 0) & (tick < T) & (d >= 0) & (d < n_dests)
    flat = tick[keep] * n_dests + d[keep]
    counts = np.bincount(flat, minlength=T * n_dests).astype(np.float64)
    return Trace(counts.reshape(T, n_dests), dt)


def superpose(*traces: Trace) -> Trace:
    """Sum several traces (same dt; shorter ones are zero-padded)."""
    assert traces
    dt = traces[0].dt
    assert all(abs(tr.dt - dt) < 1e-12 for tr in traces), "dt mismatch"
    n_dests = max(tr.n_dests for tr in traces)
    ticks = max(tr.ticks for tr in traces)
    out = np.zeros((ticks, n_dests))
    for tr in traces:
        out[:tr.ticks, :tr.n_dests] += tr.arrivals
    return Trace(out, dt)


def with_total(trace: Trace, n_requests: float) -> Trace:
    """Rescale a trace so its total request count is exactly
    ``n_requests`` (fluid counts; shape of the load curve is preserved)."""
    total = trace.n_requests
    assert total > 0, "cannot rescale an empty trace"
    return trace.scaled(float(n_requests) / total)
