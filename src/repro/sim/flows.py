"""Tile-to-tile flow patterns for the closed-loop simulator.

The original engine hard-coded the paper's monitoring workload: every
accelerator tile streams to the MEM tile.  Real SoC workloads are richer —
ESP-style accelerator-to-accelerator pipelines and DS3-style
domain-specific task chains route traffic between arbitrary tiles, with
one stage's completions feeding the next stage's queue.  A
:class:`FlowPattern` describes that structure *by tile name* (so one
pattern serves every design point of a sweep, whatever its placement),
and :func:`compile_flows` lowers it once per design into the dense array
artifacts the tick loop consumes:

* ``dst_idx``   — the flat NoC node each tile's output stream targets
  (default: MEM, exactly the legacy pattern),
* ``inc``       — route->link incidence of each stream
  (:func:`repro.core.noc.flow_incidence` over the precomputed routing
  tables; shape ``(..., A, L)``, stacking over leading design axes),
* ``hop_counts``— per-stream hop counts (RTT + wire-term hop factor),
* ``demand``    — bytes/cycle each stream offers onto its route while the
  tile is busy (default: the model's ``own_demand``),
* ``forward``   — an ``(A, A)`` coupling matrix: ``forward[i, j]`` is the
  share of tile ``i``'s completions enqueued at tile ``j`` on the *next*
  tick (chain stages split uniformly over the following stage's replicas;
  a run-time :class:`~repro.sim.control.LoadBalancer` may redistribute
  within the receiving group).  ``None`` when the pattern has no chains —
  the engines then skip the contraction entirely, keeping the legacy
  stream workload bit-for-bit unchanged.

The compiled arrays drop into the same einsum contractions
``engine.py:tick_step`` already runs, so the sequential engine, the
batched ``(B, A)`` engine and the jax ``lax.scan`` backend all consume a
pattern without new per-tick code paths.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.noc import flow_incidence, pos_index

MEM = "MEM"                     # destination sentinel: the memory tile


@dataclass(frozen=True)
class FlowPattern:
    """A named tile-to-tile traffic structure.

    ``stages`` is an optional accelerator chain: a sequence of disjoint
    tile-name groups where stage ``i``'s completions feed stage ``i+1``'s
    queues (the last stage's completions leave the SoC through MEM).
    Replicated stages are plain multi-tile groups.  ``dests`` overrides
    the wire destination of individual tiles (tile name or ``"MEM"``);
    by default a chained tile streams to its assigned next-stage replica
    (member ``j`` of stage ``i`` to member ``j mod len(stage i+1)``) and
    every other tile streams to MEM.  ``demand`` overrides bytes/cycle a
    tile's stream offers onto the NoC (default: the model's
    ``own_demand``).  Mappings may be passed as dicts; they are frozen to
    sorted tuples so patterns compare/hash structurally.
    """
    stages: Tuple[Tuple[str, ...], ...] = ()
    dests: Tuple[Tuple[str, str], ...] = ()
    demand: Tuple[Tuple[str, float], ...] = ()

    def __post_init__(self):
        stages = tuple(tuple(str(t) for t in s) for s in self.stages)
        object.__setattr__(self, "stages", stages)
        d = self.dests.items() if isinstance(self.dests, dict) else self.dests
        dests = tuple(sorted((str(a), str(b)) for a, b in d))
        assert len({a for a, _ in dests}) == len(dests), \
            "contradictory dests: a tile appears as source twice"
        object.__setattr__(self, "dests", dests)
        dm = (self.demand.items() if isinstance(self.demand, dict)
              else self.demand)
        demand = tuple(sorted((str(a), float(v)) for a, v in dm))
        assert len({a for a, _ in demand}) == len(demand), \
            "contradictory demand: a tile appears twice"
        object.__setattr__(self, "demand", demand)
        seen: set = set()
        for s in stages:
            assert s, "empty chain stage"
            for t in s:
                assert t not in seen, f"tile {t!r} appears in two stages"
                seen.add(t)

    @classmethod
    def chain(cls, *stages, dests=(), demand=()) -> "FlowPattern":
        """Convenience constructor for a pure pipeline: each positional
        argument is one stage (a tile name or a group of names)."""
        norm = tuple((s,) if isinstance(s, str) else tuple(s)
                     for s in stages)
        return cls(stages=norm, dests=dests, demand=demand)

    # ------------------------------------------------------------ resolve
    def dest_map(self) -> Dict[str, str]:
        """tile -> destination tile name (or ``MEM``), chain defaults
        applied then explicit ``dests`` overrides."""
        out: Dict[str, str] = {}
        for i in range(len(self.stages) - 1):
            nxt = self.stages[i + 1]
            for j, t in enumerate(self.stages[i]):
                out[t] = nxt[j % len(nxt)]
        out.update(dict(self.dests))
        return out

    def demand_map(self) -> Dict[str, float]:
        return dict(self.demand)


@dataclass(frozen=True)
class CompiledFlows:
    """One design's flow pattern lowered to tick-loop arrays.

    Leading axes of ``dst_idx``/``inc``/``hop_counts`` follow the
    ``pos_idx`` the pattern was compiled against: ``(A,)`` rows for the
    sequential engine, ``(B, A)`` stacks for the batched one.  ``demand``
    is a plain float for the legacy MEM-stream pattern (bit-for-bit with
    the scalar ``own_demand`` constant) or an ``(A,)`` vector otherwise.
    ``stage_of`` maps each tile to its chain stage (-1 when unchained).
    """
    dst_idx: np.ndarray                 # (..., A) int64 flat node indices
    inc: np.ndarray                     # (..., A, L) 0/1 float64
    hop_counts: np.ndarray              # (..., A) int
    demand: object                      # float, or (A,) float64
    forward: Optional[np.ndarray]       # (A, A) float64, or None
    stage_of: np.ndarray = field(
        default_factory=lambda: np.empty(0, dtype=np.int64))
    # 1.0 where a tile's completions LEAVE the SoC (no outgoing chain
    # coupling) — the engines count only exit services as "completed", so
    # a request traversing an N-stage chain is completed once, not N times
    exit_mask: np.ndarray = field(
        default_factory=lambda: np.empty(0, dtype=np.float64))

    @property
    def chained(self) -> bool:
        return self.forward is not None


def compile_flows(model, names, pos_idx,
                  pattern: Optional[FlowPattern] = None) -> CompiledFlows:
    """Lower a :class:`FlowPattern` against one (or B stacked) concrete
    placements.

    ``names`` are the tile names in trace-destination order; ``pos_idx``
    their flat NoC node indices, shaped ``(A,)`` or ``(B, A)``.  With
    ``pattern=None`` this reproduces the legacy accelerator->MEM stream
    workload exactly (same incidence/hop tables, scalar demand, no
    forward coupling).
    """
    names = tuple(names)
    A = len(names)
    cfg = model.noc
    pos_idx = np.asarray(pos_idx, dtype=np.int64)
    assert pos_idx.shape[-1] == A, (pos_idx.shape, A)
    mem_idx = pos_index(cfg, model.mem_pos)
    stage_of = np.full(A, -1, dtype=np.int64)

    if pattern is None:
        dst_idx = np.full(pos_idx.shape, mem_idx, dtype=np.int64)
        inc, hop_counts = flow_incidence(cfg, pos_idx, dst_idx)
        return CompiledFlows(dst_idx=dst_idx, inc=inc,
                             hop_counts=hop_counts,
                             demand=float(model.own_demand), forward=None,
                             stage_of=stage_of, exit_mask=np.ones(A))

    col = {n: i for i, n in enumerate(names)}
    for s in pattern.stages:
        for t in s:
            assert t in col, f"chain stage tile {t!r} not on this platform"
    for i, s in enumerate(pattern.stages):
        for t in s:
            stage_of[col[t]] = i

    # wire destinations: chain defaults + explicit overrides, MEM otherwise
    dst_col = np.full(A, -1, dtype=np.int64)          # -1 -> MEM
    for src, dst in pattern.dest_map().items():
        assert src in col, f"flow source {src!r} not on this platform"
        if dst == MEM:
            continue
        assert dst in col, f"flow destination {dst!r} not on this platform"
        assert dst != src, f"tile {src!r} cannot stream to itself"
        dst_col[col[src]] = col[dst]
    dst_idx = np.where(dst_col >= 0,
                       np.take(pos_idx, np.maximum(dst_col, 0), axis=-1),
                       mem_idx).astype(np.int64)
    inc, hop_counts = flow_incidence(cfg, pos_idx, dst_idx)

    dm = pattern.demand_map()
    for t in dm:
        assert t in col, f"demand override for unknown tile {t!r}"
    demand = np.asarray([dm.get(n, model.own_demand) for n in names],
                        dtype=np.float64)

    forward: Optional[np.ndarray] = None
    exit_mask = np.ones(A)
    if len(pattern.stages) >= 2:
        forward = np.zeros((A, A), dtype=np.float64)
        for i in range(len(pattern.stages) - 1):
            nxt = pattern.stages[i + 1]
            share = 1.0 / len(nxt)
            for t in pattern.stages[i]:
                for u in nxt:
                    forward[col[t], col[u]] = share
        exit_mask = (forward.sum(axis=1) == 0.0).astype(np.float64)
    return CompiledFlows(dst_idx=dst_idx, inc=inc, hop_counts=hop_counts,
                         demand=demand, forward=forward, stage_of=stage_of,
                         exit_mask=exit_mask)
