"""Ring-buffer time series for the closed-loop simulation.

The engine records one row per telemetry interval into fixed-capacity
numpy ring buffers (no unbounded growth on million-tick soaks, mirroring
the bounded ``ActuatorState.history``): per-island frequency, per-tile
queue depth, busy fraction, worst/mean link utilization, completion
throughput, instantaneous power and a windowed latency estimate.  The
whole recording can be exported as JSON for offline plotting/CI diffing.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def _json_safe(obj):
    """Recursively convert NumPy scalars/arrays (and tuples/sets) into
    plain JSON-serializable Python values.  Event payloads routinely carry
    ``np.float64``/``np.int64`` leaves (island rates, drop totals), which
    ``json.dumps`` rejects — every export path routes through this."""
    if isinstance(obj, dict):
        return {str(k): _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple, set)):
        return [_json_safe(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return _json_safe(obj.tolist())
    if isinstance(obj, np.generic):        # np.float64, np.int64, np.bool_
        return obj.item()
    return obj


class RingBuffer:
    """Fixed-capacity append-only buffer of fixed-shape float rows.

    ``width`` is an int for the classic ``(width,)`` rows, or a shape
    tuple — the batched telemetry stores ``(B, width)`` rows, one slice
    per design point.  ``array()`` returns rows in chronological order;
    once more than ``capacity`` rows have been appended the oldest are
    overwritten.
    """

    def __init__(self, capacity: int, width=1):
        row_shape = (int(width),) if np.isscalar(width) else tuple(
            int(w) for w in width)
        assert capacity > 0 and all(w > 0 for w in row_shape)
        self._buf = np.zeros((capacity, *row_shape), dtype=np.float64)
        self._n = 0                     # total rows ever appended

    @property
    def capacity(self) -> int:
        return self._buf.shape[0]

    @property
    def width(self) -> int:
        return self._buf.shape[-1]

    @property
    def row_shape(self) -> Tuple[int, ...]:
        return self._buf.shape[1:]

    @property
    def total_appended(self) -> int:
        return self._n

    def __len__(self) -> int:
        return min(self._n, self.capacity)

    def append(self, row) -> None:
        self._buf[self._n % self.capacity] = row
        self._n += 1

    def array(self) -> np.ndarray:
        """(len, width) rows, oldest first (copies out of the ring)."""
        cap = self.capacity
        if self._n <= cap:
            return self._buf[:self._n].copy()
        cut = self._n % cap
        return np.concatenate([self._buf[cut:], self._buf[:cut]], axis=0)

    def last(self) -> np.ndarray:
        assert self._n > 0, "empty ring buffer"
        return self._buf[(self._n - 1) % self.capacity].copy()


@dataclass(frozen=True)
class TelemetrySchema:
    """Names giving meaning to the vector channels."""
    islands: Tuple[str, ...]
    tiles: Tuple[str, ...]


class Telemetry:
    """The engine's flight recorder: one row per telemetry interval."""

    SCALARS = ("tick", "f_noc", "throughput_rps", "power_w",
               "link_util_max", "link_util_mean", "latency_est_s",
               "dropped", "dropped_slo", "dropped_fault", "retried")

    def __init__(self, schema: TelemetrySchema, *, capacity: int = 4096):
        self.schema = schema
        self.scalars = RingBuffer(capacity, len(self.SCALARS))
        self.island_rates = RingBuffer(capacity, len(schema.islands))
        self.queue_depth = RingBuffer(capacity, len(schema.tiles))
        self.busy = RingBuffer(capacity, len(schema.tiles))
        self.events: List[Dict[str, object]] = []   # controller commits etc.

    def record(self, *, tick: int, f_noc: float, island_rates,
               queue_depth, busy, throughput_rps: float, power_w: float,
               link_util_max: float, link_util_mean: float,
               latency_est_s: float, dropped: float = 0.0,
               dropped_slo: float = 0.0, dropped_fault: float = 0.0,
               retried: float = 0.0) -> None:
        """One interval's row; the drop/retry channels are *cumulative*
        run totals at recording time (fault-free runs record zeros)."""
        self.scalars.append([tick, f_noc, throughput_rps, power_w,
                             link_util_max, link_util_mean, latency_est_s,
                             dropped, dropped_slo, dropped_fault, retried])
        self.island_rates.append(island_rates)
        self.queue_depth.append(queue_depth)
        self.busy.append(busy)

    def event(self, tick: int, kind: str, **payload) -> None:
        self.events.append({"tick": int(tick), "kind": kind, **payload})

    # ---------------------------------------------------------- accessors
    def series(self, name: str) -> np.ndarray:
        """One scalar channel as a 1-D chronological array."""
        return self.scalars.array()[:, self.SCALARS.index(name)]

    def island_rate_series(self, island: str) -> np.ndarray:
        return self.island_rates.array()[:, self.schema.islands.index(island)]

    def queue_series(self, tile: str) -> np.ndarray:
        return self.queue_depth.array()[:, self.schema.tiles.index(tile)]

    # ------------------------------------------------------------- export
    def to_dict(self) -> Dict[str, object]:
        sc = self.scalars.array()
        return {
            "schema": {"islands": list(self.schema.islands),
                       "tiles": list(self.schema.tiles)},
            "scalars": {n: sc[:, i].tolist()
                        for i, n in enumerate(self.SCALARS)},
            "island_rates": self.island_rates.array().tolist(),
            "queue_depth": self.queue_depth.array().tolist(),
            "busy": self.busy.array().tolist(),
            "events": _json_safe(self.events),
            "rows_recorded": self.scalars.total_appended,
        }

    def to_json(self, path: Optional[str] = None, *, indent: int = 2) -> str:
        doc = json.dumps(self.to_dict(), indent=indent)
        if path is not None:
            with open(path, "w") as f:
                f.write(doc + "\n")
        return doc

    def summary(self) -> str:
        if len(self.scalars) == 0:
            return "(no telemetry)"
        sc = self.scalars.array()
        thr = sc[:, self.SCALARS.index("throughput_rps")]
        pw = sc[:, self.SCALARS.index("power_w")]
        lu = sc[:, self.SCALARS.index("link_util_max")]
        return (f"{len(self.scalars)} samples "
                f"(of {self.scalars.total_appended} recorded): "
                f"thr mean {thr.mean():,.0f} rps, power mean {pw.mean():.0f} W, "
                f"worst link util p99 {np.percentile(lu, 99):.2f}, "
                f"{len(self.events)} events")


class BatchTelemetry:
    """Per-design flight recorder for the batched co-sim engine.

    Mirrors :class:`Telemetry`, but every channel carries a leading
    design axis: one ``record()`` appends a ``(B, ...)`` row per ring, so
    B design points share one set of fixed-capacity buffers instead of B
    Python-object recorders.  ``design(b)`` slices out one design's view
    with the same array layout the sequential :class:`Telemetry` exposes
    (the B=1 differential tests compare them elementwise).
    """

    SCALARS = Telemetry.SCALARS

    def __init__(self, schema: TelemetrySchema, n_designs: int, *,
                 capacity: int = 4096):
        assert n_designs > 0
        self.schema = schema
        self.n_designs = int(n_designs)
        self.scalars = RingBuffer(capacity, (n_designs, len(self.SCALARS)))
        self.island_rates = RingBuffer(capacity,
                                       (n_designs, len(schema.islands)))
        self.queue_depth = RingBuffer(capacity, (n_designs, len(schema.tiles)))
        self.busy = RingBuffer(capacity, (n_designs, len(schema.tiles)))
        self.events: List[Dict[str, object]] = []

    def record(self, *, tick: int, f_noc, island_rates, queue_depth, busy,
               throughput_rps, power_w, link_util_max, link_util_mean,
               latency_est_s, dropped=0.0, dropped_slo=0.0,
               dropped_fault=0.0, retried=0.0) -> None:
        """One telemetry interval: scalar channels are (B,) arrays (or
        scalars, broadcast), vector channels (B, I)/(B, A).  Drop/retry
        channels are cumulative per-design run totals, as sequential."""
        B = self.n_designs
        row = np.empty((B, len(self.SCALARS)))
        for i, ch in enumerate((tick, f_noc, throughput_rps, power_w,
                                link_util_max, link_util_mean,
                                latency_est_s, dropped, dropped_slo,
                                dropped_fault, retried)):
            row[:, i] = ch
        self.scalars.append(row)
        self.island_rates.append(np.broadcast_to(
            island_rates, self.island_rates.row_shape))
        self.queue_depth.append(np.broadcast_to(
            queue_depth, self.queue_depth.row_shape))
        self.busy.append(np.broadcast_to(busy, self.busy.row_shape))

    def event(self, tick: int, kind: str, **payload) -> None:
        self.events.append({"tick": int(tick), "kind": kind, **payload})

    # ---------------------------------------------------------- accessors
    def series(self, name: str) -> np.ndarray:
        """One scalar channel as a (rows, B) chronological array."""
        return self.scalars.array()[..., self.SCALARS.index(name)]

    def design(self, b: int) -> Dict[str, np.ndarray]:
        """One design's recording, keyed like :meth:`Telemetry.to_dict`'s
        array channels (chronological, design axis sliced away)."""
        sc = self.scalars.array()[:, b, :]
        return {
            "scalars": {n: sc[:, i] for i, n in enumerate(self.SCALARS)},
            "island_rates": self.island_rates.array()[:, b, :],
            "queue_depth": self.queue_depth.array()[:, b, :],
            "busy": self.busy.array()[:, b, :],
        }

    # ------------------------------------------------------------- export
    def to_dict(self) -> Dict[str, object]:
        sc = self.scalars.array()
        return {
            "schema": {"islands": list(self.schema.islands),
                       "tiles": list(self.schema.tiles),
                       "n_designs": self.n_designs},
            "scalars": {n: sc[..., i].tolist()
                        for i, n in enumerate(self.SCALARS)},
            "island_rates": self.island_rates.array().tolist(),
            "queue_depth": self.queue_depth.array().tolist(),
            "busy": self.busy.array().tolist(),
            "events": _json_safe(self.events),
            "rows_recorded": self.scalars.total_appended,
        }

    def to_json(self, path: Optional[str] = None, *, indent: int = 2) -> str:
        doc = json.dumps(self.to_dict(), indent=indent)
        if path is not None:
            with open(path, "w") as f:
                f.write(doc + "\n")
        return doc

    def summary(self) -> str:
        if len(self.scalars) == 0:
            return "(no telemetry)"
        thr = self.series("throughput_rps")
        pw = self.series("power_w")
        return (f"{len(self.scalars)} samples x {self.n_designs} designs "
                f"(of {self.scalars.total_appended} recorded): "
                f"thr mean {thr.mean():,.0f} rps, "
                f"power mean {pw.mean():.0f} W, "
                f"{len(self.events)} events")


def weighted_percentiles(values: np.ndarray, weights: np.ndarray,
                         qs: Sequence[float]) -> np.ndarray:
    """Percentiles of a weighted sample (weights = request counts per
    latency bin) — how per-tick aggregated latencies become request-level
    p50/p99 without expanding to one entry per request."""
    v = np.ravel(np.asarray(values, dtype=np.float64))
    w = np.ravel(np.asarray(weights, dtype=np.float64))
    keep = w > 0
    v, w = v[keep], w[keep]
    if v.size == 0:
        return np.full(len(qs), np.nan)
    order = np.argsort(v, kind="stable")
    v, w = v[order], w[order]
    cum = np.cumsum(w)
    targets = np.asarray(qs, dtype=np.float64) / 100.0 * cum[-1]
    idx = np.searchsorted(cum, targets, side="left")
    return v[np.minimum(idx, v.size - 1)]
