"""Online DFS control harness for the simulation loop.

Bridges the vectorized engine to the scalar policy world of
``core/dfs.py``: every ``control_interval`` ticks the engine hands the
harness a windowed counter sample (busy fraction, stream-boundness,
accumulated pkts/rtt — the C3 monitor, vectorized); the harness

1. differences the accumulating counters against its previous sample
   (the host-side *manual reset* of ``core/monitor.py``, without ever
   zeroing the device counters),
2. rebuilds the per-tile :class:`~repro.core.dfs.TileTelemetry` digests
   the policies consume,
3. invokes the policy (``policy_memory_bound``, ``policy_straggler``,
   :class:`~repro.core.dfs.PIDRatePolicy`, or any callable with the same
   signature),
4. applies the *backpressure guard*: any non-fixed island whose tiles
   have more than ``queue_guard_ticks`` ticks of backlog is forced to
   ``guard_rate`` regardless of what the policy said — energy policies
   must never starve a growing queue, the closed-loop counterpart of the
   paper's "negligible throughput loss" proviso,
5. commits the changed rates through the dual-buffer
   :class:`~repro.core.dfs.DFSActuator` (no commit — and no config
   version bump, so the engine keeps its cached service rates — when the
   quantized rates are all unchanged).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.dfs import DFSActuator, TileTelemetry
from repro.core.islands import IslandConfig
from repro.core.voltage import TechModel

Policy = Callable[[IslandConfig, Dict[str, TileTelemetry]], Dict[str, float]]


@dataclass
class ControlAction:
    """One controller decision, for post-run inspection."""
    tick: int
    requested: Dict[str, float]          # raw policy output
    guarded: Tuple[str, ...]             # islands overridden by the guard
    committed: Optional[int]             # new config version, or None
    clamped: Tuple[str, ...] = ()        # islands pushed into the tech
                                         # node's legal DVFS range


class ControllerHarness:
    """Samples counters, runs a DFS policy, commits through the actuator."""

    def __init__(self, initial: IslandConfig, policy: Optional[Policy],
                 *, queue_guard_ticks: Optional[float] = 4.0,
                 guard_release_ticks: Optional[float] = None,
                 guard_rate: float = 1.0, history_maxlen: int = 256,
                 actions_maxlen: int = 1024, tech=None):
        self.actuator = DFSActuator(initial, history_maxlen=history_maxlen)
        self.policy = policy
        # physical DVFS bounds (core/voltage.py): requested rates are
        # clamped into the node's legal [L, U] ratio range before
        # quantization; None = unconstrained (the engine injects its own
        # tech model here when the harness was built without one)
        self.tech = TechModel.coerce(tech)
        self.queue_guard_ticks = queue_guard_ticks
        # hysteresis: an island stays guarded until its backlog drains
        # below the (lower) release threshold — without it the guard and
        # an energy policy flap against each other every interval at peak
        self.guard_release_ticks = (
            guard_release_ticks if guard_release_ticks is not None
            else (queue_guard_ticks / 4.0
                  if queue_guard_ticks is not None else None))
        self.guard_rate = guard_rate
        self._guard_active: set = set()
        # bounded like ActuatorState.history: million-tick soaks commit
        # thousands of intervals, only a recent window is ever inspected
        self.actions: Deque[ControlAction] = deque(maxlen=actions_maxlen)
        self._prev_pkts_in: Optional[np.ndarray] = None
        self._prev_pkts_out: Optional[np.ndarray] = None
        self._prev_rtt: Optional[np.ndarray] = None

    def live(self) -> IslandConfig:
        return self.actuator.live()

    def begin_run(self) -> None:
        """Called by the engine at the start of each run: the engine's
        accumulating counters restart from zero, so the differencing
        baselines must too (policy state — PID integrals, guard latches —
        deliberately survives across runs)."""
        self._prev_pkts_in = None
        self._prev_pkts_out = None
        self._prev_rtt = None

    # ------------------------------------------------------------ sampling
    def _window_sample(self, names, busy, boundness, pkts_in, pkts_out,
                       rtt) -> Dict[str, TileTelemetry]:
        """Accumulating counters are differenced against the previous
        sample; exec_time/boundness are already per-window values."""
        zero = np.zeros_like(pkts_in)
        d_in = pkts_in - (self._prev_pkts_in if self._prev_pkts_in is not None
                          else zero)
        d_out = pkts_out - (self._prev_pkts_out
                            if self._prev_pkts_out is not None else zero)
        d_rtt = rtt - (self._prev_rtt if self._prev_rtt is not None else zero)
        self._prev_pkts_in = np.array(pkts_in)
        self._prev_pkts_out = np.array(pkts_out)
        self._prev_rtt = np.array(rtt)
        return {
            n: TileTelemetry(
                exec_time=float(busy[i]), pkts_in=float(d_in[i]),
                pkts_out=float(d_out[i]), rtt=float(d_rtt[i]),
                boundness=float(boundness[i]))
            for i, n in enumerate(names)}

    # ---------------------------------------------------------------- step
    def step(self, *, tick: int, names, busy, boundness, pkts_in, pkts_out,
             rtt, queue_ticks, dead=None,
             stuck=None) -> Optional[IslandConfig]:
        """One control interval: sample -> policy -> guard -> commit.

        ``dead``/``stuck`` are optional ``(I,)`` boolean masks in island
        order: a dead island has no hardware to actuate (its guard latch
        is cleared so it re-arms cleanly on revival, and any requested
        change is dropped); a stuck island keeps sampling and latching
        but its commit is blocked — the actuator write never lands.

        Returns the new live :class:`IslandConfig` if a swap happened,
        else ``None`` (the engine keeps its cached service rates)."""
        telemetry = self._window_sample(names, busy, boundness,
                                        pkts_in, pkts_out, rtt)
        live = self.actuator.live()
        requested: Dict[str, float] = {}
        if self.policy is not None:
            requested = dict(self.policy(live, telemetry) or {})

        guarded: List[str] = []
        if self.queue_guard_ticks is not None:
            backlog = {n: float(queue_ticks[i]) for i, n in enumerate(names)}
            for ii, isl in enumerate(live.islands):
                if isl.fixed:
                    continue
                if dead is not None and dead[ii]:
                    self._guard_active.discard(isl.name)
                    continue
                worst = max((backlog.get(t, 0.0) for t in isl.tiles),
                            default=0.0)
                if worst > self.queue_guard_ticks:
                    self._guard_active.add(isl.name)
                elif worst < self.guard_release_ticks:
                    self._guard_active.discard(isl.name)
                if isl.name in self._guard_active:
                    requested[isl.name] = self.guard_rate
                    guarded.append(isl.name)

        # DVFS-bound clamp: with a tech model in the loop, requests
        # outside the node's legal [L, U] ratio range are pushed back in
        # before quantization (the ControlAction keeps the raw request so
        # the rejection is traceable)
        clamped: List[str] = []
        applied = requested
        if self.tech is not None:
            lo, hi = self.tech.l_bound, self.tech.u_bound
            ladders = {i.name: i.ladder for i in live.islands}
            applied = {}
            for n, r in requested.items():
                c = min(max(float(r), lo), hi)
                hit = c != r
                lad = ladders.get(n)
                if lad is not None:
                    lv = np.asarray(lad.levels(), dtype=np.float64)
                    legal = lv[self.tech.legal(lv)]
                    if legal.size:
                        # nearest LEGAL ladder level: plain quantization
                        # of a clamped request could snap back below L
                        q = float(legal[int(np.argmin(np.abs(legal - c)))])
                        hit = hit or q != lad.quantize(r)
                        c = q
                if hit:
                    clamped.append(n)
                applied[n] = c

        # drop no-op rate changes so the config version only bumps on a
        # real swap (ladder-quantized comparison, as with_rates would do)
        changes: Dict[str, float] = {}
        for ii, isl in enumerate(live.islands):
            if isl.name not in applied or isl.fixed:
                continue
            if dead is not None and dead[ii]:
                continue
            if stuck is not None and stuck[ii]:
                continue
            if isl.ladder.quantize(applied[isl.name]) != isl.rate:
                changes[isl.name] = applied[isl.name]

        committed = None
        if changes:
            self.actuator.reconfigure(changes)
            committed = self.actuator.commit().version
        self.actions.append(ControlAction(
            tick=tick, requested=requested, guarded=tuple(guarded),
            committed=committed, clamped=tuple(clamped)))
        return self.actuator.live() if committed is not None else None


# ---------------------------------------------------------------------------
# Admission / load balancing across replicated accelerator groups
# ---------------------------------------------------------------------------


class LoadBalancer:
    """Vectorized admission policy: redistribute each tick's incoming
    requests across groups of interchangeable accelerator tiles.

    The trace (and any chained stage's forwarded completions) addresses
    *logical* destinations; when a destination is replicated across an
    island group, a front-end balancer decides which replica actually
    enqueues the request.  Modes:

    * ``"even"``     — uniform split (the static baseline),
    * ``"capacity"`` — proportional to each replica's current service
      capacity, so a DFS-derated island automatically sheds load to its
      faster peers (the co-action the scenario gate measures),
    * ``"adaptive"`` — capacity divided by (1 + backlog): capacity-aware
      *and* backlog-draining, the default.

    Shape-agnostic: ``split`` operates on the trailing tile axis with any
    leading axes, and all contractions are einsum (sequential contracted
    accumulation), so the sequential engine and a B=1 batch row run the
    exact same floats — the balancer is part of the differential surface.
    Requests for tiles outside every group pass through untouched, and
    each group's split sums to its offered load by construction.
    """

    MODES = ("even", "capacity", "adaptive")

    def __init__(self, groups, tile_names, *, mode: str = "adaptive"):
        assert mode in self.MODES, f"mode {mode!r} not in {self.MODES}"
        self.mode = mode
        tile_names = tuple(tile_names)
        A = len(tile_names)
        if isinstance(groups, dict):
            groups = list(groups.values())
        idx: List[np.ndarray] = []
        taken: set = set()
        for g in groups:
            g = tuple(g)
            assert g, "empty balancer group"
            for t in g:
                assert t in tile_names, f"unknown tile {t!r} in group"
                assert t not in taken, f"tile {t!r} in two balancer groups"
                taken.add(t)
            idx.append(np.asarray([tile_names.index(t) for t in g],
                                  dtype=np.int64))
        G = len(idx)
        self.membership = np.zeros((G, A), dtype=np.float64)
        for gi, ids in enumerate(idx):
            self.membership[gi, ids] = 1.0
        self.covered = self.membership.sum(axis=0) > 0          # (A,) bool
        # tile -> its group (0 where uncovered; masked by ``covered``)
        self.group_of = np.zeros(A, dtype=np.int64)
        for gi, ids in enumerate(idx):
            self.group_of[ids] = gi

    def weights(self, queue: np.ndarray, cap: np.ndarray) -> np.ndarray:
        """Per-tile split weight (strictly positive for live tiles)."""
        if self.mode == "even":
            return np.ones_like(np.asarray(queue, dtype=np.float64))
        if self.mode == "capacity":
            return np.asarray(cap, dtype=np.float64)
        return np.asarray(cap, dtype=np.float64) / (1.0 + queue)

    def split(self, arr: np.ndarray, queue: np.ndarray, cap: np.ndarray,
              alive: Optional[np.ndarray] = None) -> np.ndarray:
        """Redistribute one tick's arrivals within each group.

        ``arr``/``queue``/``cap`` are ``(..., A)``; returns a new
        ``(..., A)`` array whose per-group sums equal ``arr``'s.
        ``alive`` (optional ``(..., A)`` 0/1 mask) zeroes dead replicas'
        weights so their share re-spills to surviving peers; a group with
        no survivors still falls back to an even split (work is never
        silently discarded here — the fault ledger accounts for it).
        """
        if not self.covered.any():
            return np.asarray(arr, dtype=np.float64)
        arr = np.asarray(arr, dtype=np.float64)
        w = self.weights(queue, cap)
        # a NaN or negative weight (0/0 capacity ratios from zero-capacity
        # replicas) must weigh *nothing*, not poison its group's einsum
        w = np.where(np.isfinite(w) & (w > 0.0), w, 0.0)
        if alive is not None:
            w = w * alive
        tot = np.einsum("...a,ga->...g", arr, self.membership)
        wsum = np.einsum("...a,ga->...g", w, self.membership)
        # a group whose every replica weighs 0 (e.g. cap forced to 0)
        # falls back to an even split — requests are never discarded
        w = np.where((wsum <= 0.0)[..., self.group_of], 1.0, w)
        wsum = np.einsum("...a,ga->...g", w, self.membership)
        shared = tot[..., self.group_of] * (w / wsum[..., self.group_of])
        return np.where(self.covered, shared, arr)


# ---------------------------------------------------------------------------
# Batched (multi-design) harness — sim/batch.py's controller
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class IslandTopology:
    """Array form of one island partition, shared by all B designs.

    ``membership[i, a]`` is 1.0 iff tile ``a`` belongs to island ``i``;
    ``ladder_levels`` stacks each island's quantization ladder, padded
    with +inf (padding can never win the nearest-level argmin, so the
    tie-breaking matches the scalar ``RateLadder.quantize`` exactly).
    """
    names: Tuple[str, ...]
    membership: np.ndarray              # (I, A) float64 0/1
    fixed: np.ndarray                   # (I,) bool
    ladder_levels: np.ndarray           # (I, L_max) float64, +inf padded
    counts: np.ndarray                  # (I,) tiles per island (sampled)

    @classmethod
    def from_config(cls, islands: IslandConfig,
                    tile_names) -> "IslandTopology":
        tile_names = tuple(tile_names)
        I = len(islands.islands)
        A = len(tile_names)
        mem = np.zeros((I, A), dtype=np.float64)
        for i, isl in enumerate(islands.islands):
            for t in isl.tiles:
                if t in tile_names:
                    mem[i, tile_names.index(t)] = 1.0
        ladders = [np.asarray(isl.ladder.levels(), dtype=np.float64)
                   for isl in islands.islands]
        lmax = max(lv.shape[0] for lv in ladders)
        levels = np.full((I, lmax), np.inf)
        for i, lv in enumerate(ladders):
            levels[i, :lv.shape[0]] = lv
        return cls(names=islands.names(), membership=mem,
                   fixed=np.asarray([isl.fixed for isl in islands.islands]),
                   ladder_levels=levels, counts=mem.sum(axis=1))

    def quantize(self, rates: np.ndarray,
                 legal: Optional[np.ndarray] = None) -> np.ndarray:
        """Nearest ladder level per (design, island); NaN passes through.
        ``legal``: optional (I, L_max) mask restricting the candidate
        levels (the physical-DVFS bound — illegal levels can't win)."""
        r = np.asarray(rates, dtype=np.float64)
        d = np.abs(self.ladder_levels[None, :, :] - r[..., None])
        if legal is not None:
            d = np.where(legal[None, :, :], d, np.inf)
        idx = np.argmin(np.where(np.isnan(d), np.inf, d), axis=-1)
        q = self.ladder_levels[np.arange(len(self.names))[None, :], idx]
        return np.where(np.isnan(r), np.nan, q)

    def island_mean(self, x: np.ndarray) -> np.ndarray:
        """(B, A) per-tile values -> (B, I) island means (NaN if empty).

        The contraction is an einsum (sequential accumulation over the
        tile axis), so a one- or two-tile island's mean is bit-identical
        to the scalar harness's ``np.mean([...])`` over the same tiles."""
        s = np.einsum("ba,ia->bi", np.asarray(x, dtype=np.float64),
                      self.membership)
        with np.errstate(invalid="ignore", divide="ignore"):
            return s / np.where(self.counts > 0, self.counts, np.nan)

    def island_max(self, x: np.ndarray, default: float = 0.0) -> np.ndarray:
        """(B, A) -> (B, I) masked max over member tiles (``default`` for
        empty islands, matching the scalar guard's ``max(..., default)``)."""
        masked = np.where(self.membership[None, :, :] > 0,
                          np.asarray(x)[:, None, :], -np.inf)
        out = masked.max(axis=-1)
        return np.where(self.counts[None, :] > 0, out, default)


@dataclass(frozen=True)
class BatchSample:
    """One windowed counter sample across B designs — what batch policies
    consume (``core/dfs.py:BatchMemoryBoundPolicy`` etc.).  Accumulating
    counters arrive already differenced against the previous window."""
    busy: np.ndarray                    # (B, A) window busy fraction
    boundness: np.ndarray               # (B, A)
    pkts_in: np.ndarray                 # (B, A) window delta
    pkts_out: np.ndarray                # (B, A) window delta
    rtt: np.ndarray                     # (B, A) window delta
    queue_ticks: np.ndarray             # (B, A) backlog in ticks
    topo: IslandTopology

    @property
    def island_names(self) -> Tuple[str, ...]:
        return self.topo.names

    @property
    def fixed(self) -> np.ndarray:
        return self.topo.fixed

    @property
    def counts(self) -> np.ndarray:
        return self.topo.counts

    def island_mean(self, x: np.ndarray) -> np.ndarray:
        return self.topo.island_mean(x)


BatchPolicy = Callable[[np.ndarray, BatchSample], np.ndarray]


class BatchControllerHarness:
    """The :class:`ControllerHarness` for B stacked designs.

    State is arrays instead of actuator objects: live rates are a (B, I)
    matrix, the dual-buffer commit is one masked swap
    (``where(changed, quantized, live)``), config versions and swap
    counts are (B,) integer vectors bumped by a boolean mask — the whole
    sample -> policy -> guard -> quantize -> commit pipeline runs once
    per control interval for every design simultaneously.  Semantics
    mirror the scalar harness exactly (differential-tested at B=1):
    no-op commits are suppressed per design, the backpressure guard
    latches with the same hysteresis, counters difference against the
    previous window without zeroing.
    """

    def __init__(self, islands: IslandConfig, rates0: np.ndarray,
                 policy: Optional[BatchPolicy], *, tile_names,
                 queue_guard_ticks: Optional[float] = 4.0,
                 guard_release_ticks: Optional[float] = None,
                 guard_rate: float = 1.0, tech=None):
        self.topo = IslandTopology.from_config(islands, tile_names)
        rates0 = np.asarray(rates0, dtype=np.float64)
        assert rates0.ndim == 2 and rates0.shape[1] == len(self.topo.names)
        self.rates = rates0.copy()
        B = rates0.shape[0]
        self.versions = np.full(B, islands.version, dtype=np.int64)
        self.swaps = np.zeros(B, dtype=np.int64)
        self.policy = policy
        # physical DVFS bounds, mirroring the scalar harness: requests
        # outside the tech node's legal [L, U] range are clamped before
        # quantization (``last_clamped`` holds the per-(design, island)
        # mask of the most recent step)
        self.tech = TechModel.coerce(tech)
        self.last_clamped = np.zeros((B, len(self.topo.names)), dtype=bool)
        self.queue_guard_ticks = queue_guard_ticks
        self.guard_release_ticks = (
            guard_release_ticks if guard_release_ticks is not None
            else (queue_guard_ticks / 4.0
                  if queue_guard_ticks is not None else None))
        self.guard_rate = guard_rate
        self._guard_active = np.zeros((B, len(self.topo.names)), dtype=bool)
        self._prev_pkts_in: Optional[np.ndarray] = None
        self._prev_pkts_out: Optional[np.ndarray] = None
        self._prev_rtt: Optional[np.ndarray] = None

    @property
    def n_designs(self) -> int:
        return self.rates.shape[0]

    def live_rates(self) -> np.ndarray:
        return self.rates.copy()

    def begin_run(self) -> None:
        """Engine counters restart per run -> differencing baselines too
        (policy state — PID integrals, guard latches — survives)."""
        self._prev_pkts_in = None
        self._prev_pkts_out = None
        self._prev_rtt = None

    # ---------------------------------------------------------------- step
    def step(self, *, tick: int, busy, boundness, pkts_in, pkts_out, rtt,
             queue_ticks, dead=None, stuck=None) -> Optional[np.ndarray]:
        """One control interval over all designs.

        ``dead``/``stuck`` are optional ``(I,)`` boolean masks shared by
        every design (faults are a property of the schedule, not the
        design): dead islands drop out of the guard latch and never
        commit, stuck islands keep latching but their commits are
        blocked — mirroring the scalar harness bit-for-bit at B=1.

        Returns the new (B, I) live-rate matrix if ANY design committed
        (``last_committed`` holds the per-design mask), else ``None`` —
        the engine keeps its cached service terms."""
        zero = np.zeros_like(np.asarray(pkts_in, dtype=np.float64))
        d_in = pkts_in - (self._prev_pkts_in
                          if self._prev_pkts_in is not None else zero)
        d_out = pkts_out - (self._prev_pkts_out
                            if self._prev_pkts_out is not None else zero)
        d_rtt = rtt - (self._prev_rtt
                       if self._prev_rtt is not None else zero)
        self._prev_pkts_in = np.array(pkts_in)
        self._prev_pkts_out = np.array(pkts_out)
        self._prev_rtt = np.array(rtt)

        sample = BatchSample(
            busy=np.asarray(busy, dtype=np.float64),
            boundness=np.asarray(boundness, dtype=np.float64),
            pkts_in=d_in, pkts_out=d_out, rtt=d_rtt,
            queue_ticks=np.asarray(queue_ticks, dtype=np.float64),
            topo=self.topo)

        B, I = self.rates.shape
        requested = np.full((B, I), np.nan)
        if self.policy is not None:
            requested = np.asarray(self.policy(self.rates, sample),
                                   dtype=np.float64)

        if self.queue_guard_ticks is not None:
            worst = self.topo.island_max(sample.queue_ticks)    # (B, I)
            # the scalar harness's if/elif hysteresis, vectorized
            latch = np.where(
                worst > self.queue_guard_ticks, True,
                np.where(worst < self.guard_release_ticks, False,
                         self._guard_active))
            latch &= ~self.topo.fixed[None, :]      # fixed islands excluded
            if dead is not None:
                latch = latch & ~np.asarray(dead, dtype=bool)
            self._guard_active = latch
            requested = np.where(latch, self.guard_rate, requested)

        # DVFS-bound clamp before quantization (NaN "no request" entries
        # pass through np.clip untouched); quantization then snaps to
        # the nearest LEGAL ladder level, so a clamped request cannot
        # quantize back outside [L, U]
        self.last_clamped = np.zeros_like(self._guard_active)
        legal = None
        if self.tech is not None:
            clamped_r = np.clip(requested, self.tech.l_bound,
                                self.tech.u_bound)
            lv = self.topo.ladder_levels
            legal = ((lv >= self.tech.l_bound)
                     & (lv <= self.tech.u_bound))
            legal = np.where(legal.any(axis=-1, keepdims=True),
                             legal, np.isfinite(lv))
            self.last_clamped = (
                ~np.isnan(requested)
                & ((clamped_r != requested)
                   | (self.topo.quantize(clamped_r, legal=legal)
                      != self.topo.quantize(requested))))
            requested = clamped_r

        # drop no-op rate changes so versions only bump on a real swap
        quantized = self.topo.quantize(requested, legal=legal)
        changed = (~np.isnan(requested) & ~self.topo.fixed[None, :]
                   & (quantized != self.rates))
        if dead is not None:
            changed = changed & ~np.asarray(dead, dtype=bool)
        if stuck is not None:
            changed = changed & ~np.asarray(stuck, dtype=bool)
        committed = changed.any(axis=1)                          # (B,)
        self.last_committed = committed
        if not committed.any():
            return None
        self.rates = np.where(changed, quantized, self.rates)
        self.versions = self.versions + committed
        self.swaps = self.swaps + committed
        return self.rates
