"""Online DFS control harness for the simulation loop.

Bridges the vectorized engine to the scalar policy world of
``core/dfs.py``: every ``control_interval`` ticks the engine hands the
harness a windowed counter sample (busy fraction, stream-boundness,
accumulated pkts/rtt — the C3 monitor, vectorized); the harness

1. differences the accumulating counters against its previous sample
   (the host-side *manual reset* of ``core/monitor.py``, without ever
   zeroing the device counters),
2. rebuilds the per-tile :class:`~repro.core.dfs.TileTelemetry` digests
   the policies consume,
3. invokes the policy (``policy_memory_bound``, ``policy_straggler``,
   :class:`~repro.core.dfs.PIDRatePolicy`, or any callable with the same
   signature),
4. applies the *backpressure guard*: any non-fixed island whose tiles
   have more than ``queue_guard_ticks`` ticks of backlog is forced to
   ``guard_rate`` regardless of what the policy said — energy policies
   must never starve a growing queue, the closed-loop counterpart of the
   paper's "negligible throughput loss" proviso,
5. commits the changed rates through the dual-buffer
   :class:`~repro.core.dfs.DFSActuator` (no commit — and no config
   version bump, so the engine keeps its cached service rates — when the
   quantized rates are all unchanged).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.dfs import DFSActuator, TileTelemetry
from repro.core.islands import IslandConfig

Policy = Callable[[IslandConfig, Dict[str, TileTelemetry]], Dict[str, float]]


@dataclass
class ControlAction:
    """One controller decision, for post-run inspection."""
    tick: int
    requested: Dict[str, float]          # raw policy output
    guarded: Tuple[str, ...]             # islands overridden by the guard
    committed: Optional[int]             # new config version, or None


class ControllerHarness:
    """Samples counters, runs a DFS policy, commits through the actuator."""

    def __init__(self, initial: IslandConfig, policy: Optional[Policy],
                 *, queue_guard_ticks: Optional[float] = 4.0,
                 guard_release_ticks: Optional[float] = None,
                 guard_rate: float = 1.0, history_maxlen: int = 256,
                 actions_maxlen: int = 1024):
        self.actuator = DFSActuator(initial, history_maxlen=history_maxlen)
        self.policy = policy
        self.queue_guard_ticks = queue_guard_ticks
        # hysteresis: an island stays guarded until its backlog drains
        # below the (lower) release threshold — without it the guard and
        # an energy policy flap against each other every interval at peak
        self.guard_release_ticks = (
            guard_release_ticks if guard_release_ticks is not None
            else (queue_guard_ticks / 4.0
                  if queue_guard_ticks is not None else None))
        self.guard_rate = guard_rate
        self._guard_active: set = set()
        # bounded like ActuatorState.history: million-tick soaks commit
        # thousands of intervals, only a recent window is ever inspected
        self.actions: Deque[ControlAction] = deque(maxlen=actions_maxlen)
        self._prev_pkts_in: Optional[np.ndarray] = None
        self._prev_pkts_out: Optional[np.ndarray] = None
        self._prev_rtt: Optional[np.ndarray] = None

    def live(self) -> IslandConfig:
        return self.actuator.live()

    def begin_run(self) -> None:
        """Called by the engine at the start of each run: the engine's
        accumulating counters restart from zero, so the differencing
        baselines must too (policy state — PID integrals, guard latches —
        deliberately survives across runs)."""
        self._prev_pkts_in = None
        self._prev_pkts_out = None
        self._prev_rtt = None

    # ------------------------------------------------------------ sampling
    def _window_sample(self, names, busy, boundness, pkts_in, pkts_out,
                       rtt) -> Dict[str, TileTelemetry]:
        """Accumulating counters are differenced against the previous
        sample; exec_time/boundness are already per-window values."""
        zero = np.zeros_like(pkts_in)
        d_in = pkts_in - (self._prev_pkts_in if self._prev_pkts_in is not None
                          else zero)
        d_out = pkts_out - (self._prev_pkts_out
                            if self._prev_pkts_out is not None else zero)
        d_rtt = rtt - (self._prev_rtt if self._prev_rtt is not None else zero)
        self._prev_pkts_in = np.array(pkts_in)
        self._prev_pkts_out = np.array(pkts_out)
        self._prev_rtt = np.array(rtt)
        return {
            n: TileTelemetry(
                exec_time=float(busy[i]), pkts_in=float(d_in[i]),
                pkts_out=float(d_out[i]), rtt=float(d_rtt[i]),
                boundness=float(boundness[i]))
            for i, n in enumerate(names)}

    # ---------------------------------------------------------------- step
    def step(self, *, tick: int, names, busy, boundness, pkts_in, pkts_out,
             rtt, queue_ticks) -> Optional[IslandConfig]:
        """One control interval: sample -> policy -> guard -> commit.

        Returns the new live :class:`IslandConfig` if a swap happened,
        else ``None`` (the engine keeps its cached service rates)."""
        telemetry = self._window_sample(names, busy, boundness,
                                        pkts_in, pkts_out, rtt)
        live = self.actuator.live()
        requested: Dict[str, float] = {}
        if self.policy is not None:
            requested = dict(self.policy(live, telemetry) or {})

        guarded: List[str] = []
        if self.queue_guard_ticks is not None:
            backlog = {n: float(queue_ticks[i]) for i, n in enumerate(names)}
            for isl in live.islands:
                if isl.fixed:
                    continue
                worst = max((backlog.get(t, 0.0) for t in isl.tiles),
                            default=0.0)
                if worst > self.queue_guard_ticks:
                    self._guard_active.add(isl.name)
                elif worst < self.guard_release_ticks:
                    self._guard_active.discard(isl.name)
                if isl.name in self._guard_active:
                    requested[isl.name] = self.guard_rate
                    guarded.append(isl.name)

        # drop no-op rate changes so the config version only bumps on a
        # real swap (ladder-quantized comparison, as with_rates would do)
        changes: Dict[str, float] = {}
        for isl in live.islands:
            if isl.name not in requested or isl.fixed:
                continue
            if isl.ladder.quantize(requested[isl.name]) != isl.rate:
                changes[isl.name] = requested[isl.name]

        committed = None
        if changes:
            self.actuator.reconfigure(changes)
            committed = self.actuator.commit().version
        self.actions.append(ControlAction(
            tick=tick, requested=requested, guarded=tuple(guarded),
            committed=committed))
        return self.actuator.live() if committed is not None else None
