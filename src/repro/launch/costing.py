"""Scan-aware cost accounting for the roofline analysis.

Why this exists: XLA's ``compiled.cost_analysis()`` counts a while-loop body
ONCE, so any model driven by ``lax.scan`` over layers under-reports FLOPs
and bytes by ~n_layers (verified in tests/test_costing.py).  The dry-run
therefore derives:

* **FLOPs** — from the jaxpr, recursively, multiplying scan bodies by trip
  count.  dot_general/ragged_dot get exact 2·M·N·K math; element-wise ops
  count one flop per output element.  Tracing the *grad* function includes
  the remat recompute, so the MODEL_FLOPS/HLO_FLOPs ratio in §Roofline
  honestly shows rematerialization waste.
* **collective bytes** — from the partitioned HLO text, with a computation
  call-graph that multiplies collectives inside while bodies by the trip
  count recovered from the loop condition's comparison constant.
* **HBM bytes** — analytic per cell kind (weights/optimizer/activations/KV
  traffic), the standard roofline convention; raw cost_analysis bytes are
  reported alongside as ``hlo_bytes_per_device(body-once)``.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax import core as jcore

# ---------------------------------------------------------------------------
# jaxpr FLOP counting
# ---------------------------------------------------------------------------


def _dot_flops(eqn) -> float:
    (lhs, rhs) = (eqn.invars[0].aval, eqn.invars[1].aval)
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    batch = int(np.prod([lhs.shape[i] for i in lb]) or 1)
    contract = int(np.prod([lhs.shape[i] for i in lc]) or 1)
    m = int(np.prod([lhs.shape[i] for i in range(len(lhs.shape))
                     if i not in lc and i not in lb]) or 1)
    n = int(np.prod([rhs.shape[i] for i in range(len(rhs.shape))
                     if i not in rc and i not in rb]) or 1)
    return 2.0 * batch * m * n * contract


def _ragged_dot_flops(eqn) -> float:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    # lhs (M, K), rhs (G, K, N): every row multiplies one expert slice
    m, k = lhs.shape[-2], lhs.shape[-1]
    n = rhs.shape[-1]
    return 2.0 * m * k * n


_SUBJAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "body_jaxpr",
                    "cond_jaxpr")


def flops_of_jaxpr(jaxpr, mult: float = 1.0) -> float:
    total = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            total += mult * _dot_flops(eqn)
        elif prim == "ragged_dot":
            total += mult * _ragged_dot_flops(eqn)
        elif prim == "scan":
            body = eqn.params["jaxpr"]
            length = eqn.params["length"]
            total += flops_of_jaxpr(body.jaxpr, mult * length)
        elif prim == "while":
            body = eqn.params["body_jaxpr"]
            total += flops_of_jaxpr(body.jaxpr, mult)     # trip unknown: 1x
        elif prim == "cond":
            branches = eqn.params["branches"]
            total += max(flops_of_jaxpr(b.jaxpr, mult) for b in branches)
        elif prim in ("pjit", "closed_call", "core_call", "remat_call",
                      "custom_jvp_call", "custom_vjp_call",
                      "custom_vjp_call_jaxpr", "checkpoint", "remat",
                      "remat2", "shard_map", "custom_partitioning"):
            sub = None
            for k in _SUBJAXPR_PARAMS:
                if k in eqn.params:
                    sub = eqn.params[k]
                    break
            if sub is not None:
                sj = sub.jaxpr if hasattr(sub, "jaxpr") else sub
                total += flops_of_jaxpr(sj, mult)
        else:
            # element-wise / reduction: ~1 flop per output element
            for ov in eqn.outvars:
                aval = getattr(ov, "aval", None)
                if aval is not None and hasattr(aval, "shape"):
                    total += mult * float(np.prod(aval.shape) or 1)
    return total


def flops_of_fn(fn, *abstract_args) -> float:
    jx = jax.make_jaxpr(fn)(*abstract_args)
    return flops_of_jaxpr(jx.jaxpr)


# ---------------------------------------------------------------------------
# While-aware HLO collective accounting
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
    "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(%?[\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_COMP_SIMPLE_RE = re.compile(r"^(%?[\w\.\-]+)\s+\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=([%\w\.\-]+).*?body=([%\w\.\-]+)")
_CALL_RE = re.compile(r"(?:to_apply|calls)=([%\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COLL_RE = re.compile(
    r"=\s+(.+?)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_SET_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


def _bytes_of_type(expr: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(expr):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_SET_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(len(ids), 1)
    return default


def _wire_bytes(op: str, result_bytes: int, n: int) -> float:
    """Per-device ring-algorithm wire bytes, from the op's RESULT bytes."""
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (n - 1) / n * result_bytes
    if op == "all-gather":
        return (n - 1) / n * result_bytes
    if op == "reduce-scatter":
        return (n - 1) * result_bytes          # operand = n x result
    if op == "all-to-all":
        return (n - 1) / n * result_bytes
    if op == "collective-permute":
        return float(result_bytes)
    return 0.0


_HDR_RE = re.compile(r"^(ENTRY\s+)?(%?[\w\.\-]+)\s*\(")


def _split_computations(hlo: str) -> Tuple[Dict[str, List[str]], Optional[str]]:
    """Computation name -> instruction lines, plus the ENTRY name.

    HLO computation headers are top-level lines ending in '{' of the form
    ``[ENTRY] %name (args...) -> type {`` where args may contain nested
    parens, so match only the leading name token.
    """
    comps: Dict[str, List[str]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in hlo.splitlines():
        s = line.strip()
        if cur is None:
            if not s.endswith("{"):
                continue
            m = _HDR_RE.match(s)
            if m and "=" not in s.split("(", 1)[0]:
                cur = m.group(2).lstrip("%")
                comps[cur] = []
                if m.group(1):
                    entry = cur
            continue
        if s.startswith("}"):
            cur = None
            continue
        comps[cur].append(s)
    return comps, entry


_NAMED_CONST_RE = re.compile(r"(%[\w\.\-]+)\s*=\s*\S+\s+constant\((\d+)\)")
_COMPARE_RE = re.compile(r"compare\(([^)]*)\)")


def _trip_count(cond_lines: List[str]) -> int:
    """Loop trip count: the constant actually used in the condition's
    compare (taking the max over all constants grabs unrelated dimension
    constants and inflates multipliers by orders of magnitude)."""
    consts: Dict[str, int] = {}
    inline: List[int] = []
    for l in cond_lines:
        for name, val in _NAMED_CONST_RE.findall(l):
            consts[name] = int(val)
    for l in cond_lines:
        m = _COMPARE_RE.search(l)
        if not m:
            continue
        for arg in m.group(1).split(","):
            arg = arg.strip().split(" ")[-1]
            if arg in consts:
                inline.append(consts[arg])
            cm = _CONST_RE.search(arg)
            if cm:
                inline.append(int(cm.group(1)))
    if inline:
        return max(inline)
    return max(consts.values()) if consts else 1


def _multipliers(comps: Dict[str, List[str]], entry: str) -> Dict[str, float]:
    """Propagate execution-count multipliers through the call graph."""
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    if entry not in mult:
        # heuristic: entry = computation containing 'ENTRY' marker fallback
        entry = next(iter(comps))
    mult[entry] = 1.0
    # iterate to fixpoint (call graph is a DAG; few passes suffice)
    for _ in range(len(comps)):
        changed = False
        for name, lines in comps.items():
            m = mult.get(name, 0.0)
            if m == 0.0:
                continue
            for l in lines:
                w = _WHILE_RE.search(l)
                if w:
                    cond = w.group(1).lstrip("%")
                    body = w.group(2).lstrip("%")
                    trips = _trip_count(comps.get(cond, []))
                    for tgt, k in ((body, m * trips), (cond, m * (trips + 1))):
                        if tgt in mult and mult[tgt] < k:
                            mult[tgt] = k
                            changed = True
                for c in _CALL_RE.findall(l):
                    tgt = c.lstrip("%")
                    if tgt in mult and mult[tgt] < m:
                        mult[tgt] = m
                        changed = True
        if not changed:
            break
    return mult


def collective_stats(hlo_text: str, default_group: int) -> Dict[str, Any]:
    """While-aware per-device collective wire bytes from partitioned HLO."""
    comps, entry = _split_computations(hlo_text)
    if entry is None or entry not in comps:
        # fall back: the computation with the most instructions
        entry = max(comps, key=lambda c: len(comps[c])) if comps else ""
    mult = _multipliers(comps, entry)

    per_op: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    total = 0.0
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            m = 1.0 if name == entry else 0.0
        for line in lines:
            c = _COLL_RE.search(line)
            if c is None:
                continue
            expr, op = c.group(1), c.group(2)
            rb = _bytes_of_type(expr)
            n = _group_size(line, default_group)
            wb = _wire_bytes(op, rb, n) * m
            per_op[op] = per_op.get(op, 0.0) + wb
            counts[op] = counts.get(op, 0) + int(m)
            total += wb
    return {"collective_bytes": total, "per_op_bytes": per_op,
            "op_counts": counts}


# ---------------------------------------------------------------------------
# Analytic HBM traffic (roofline memory term)
# ---------------------------------------------------------------------------


def hbm_bytes(cfg, shape, *, remat: bool = True, mra_k: int = 1,
              kv_int8: bool = False) -> float:
    """Whole-step HBM traffic estimate across all chips (bytes).

    train  : params read (fwd+bwd) + grads + AdamW m/v read+write + param
             write + activation residual traffic under full remat.
    prefill: params read + activation stream + KV-cache write.
    decode : params read + full KV/state read + small writes.
    """
    P = cfg.n_params()
    Pa = cfg.n_active_params()
    B, S = shape.global_batch, shape.seq_len
    d, L = cfg.d_model, cfg.n_layers
    tok = B * S

    if shape.kind == "train":
        w = 2 * Pa * 2 + P * 2          # fwd+bwd reads (bf16) active; + grads
        opt = P * (4 + 4) * 2 + P * 2   # m,v read+write (f32) + param write
        act = 6 * L * tok * d * 2       # residual save + bwd read + recompute
        emb = 3 * tok * d * 2
        return float(w + opt + act + emb)
    if shape.kind == "prefill":
        w = Pa * 2
        act = 4 * L * tok * d * 2
        if cfg.family in ("ssm", "hybrid"):
            kv = ssm_state_bytes(cfg, B)
            if cfg.family == "hybrid" and cfg.shared_attn_every:
                napps = -(-L // cfg.shared_attn_every)
                kv += napps * tok * 2 * cfg.n_kv_heads * cfg.head_dim * 2
        else:
            kv = _kv_bytes_per_pos(cfg) * tok
        return float(w + act + kv)
    # decode: one token, full cache/state sweep (read + write-back).
    # MoE at batch >= E/top_k touches essentially every expert, so decode
    # reads the FULL weight set; MRA replication multiplies resident weight
    # reads by K (each replica group sweeps its own copy) — the paper's
    # area<->throughput trade, visible in the memory term.
    w = (P if (cfg.family == "moe"
               and shape.global_batch * cfg.top_k >= cfg.n_experts)
         else Pa) * 2 * max(mra_k, 1)
    if cfg.family in ("ssm", "hybrid"):
        kv = 2 * ssm_state_bytes(cfg, B)          # state read + write
        if cfg.family == "hybrid" and cfg.shared_attn_every:
            napps = -(-cfg.n_layers // cfg.shared_attn_every)
            win = min(S, 4096)                    # windowed shared-attn cache
            kv += napps * B * win * 2 * cfg.n_kv_heads * cfg.head_dim * 2
    else:
        kv = _kv_bytes_per_pos(cfg) * B * _ctx_len(cfg, S)
    if kv_int8:
        kv *= 0.5                       # int8 cache vs bf16
    act = 4 * L * B * d * 2
    return float(w + kv + act)


def _ctx_len(cfg, S: int) -> int:
    if cfg.sliding_window:
        return min(S, cfg.sliding_window)
    return S


def _kv_bytes_per_pos(cfg) -> float:
    """KV cache bytes per cached position, whole layer stack."""
    if cfg.attn_type == "mla":
        return cfg.n_layers * (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2
    return cfg.n_layers * 2 * cfg.n_kv_heads * cfg.head_dim * 2


def ssm_state_bytes(cfg, batch: int) -> float:
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0
    nh, st, hd = cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_headdim
    conv = 3 * cfg.ssm_conv * (cfg.d_inner + 2 * cfg.ssm_state)
    return float(cfg.n_layers * batch * (nh * st * hd * 4 + conv))
