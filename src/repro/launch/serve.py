"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Boots the continuous-batching ServeEngine, feeds it synthetic request
traffic at a configurable arrival rate, and reports throughput + RTT
percentiles (C3 monitoring end-to-end).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models.layers import AttnOptions
from repro.runtime.serve import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="musicgen-large",
                    choices=ASSIGNED_ARCHS)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--window", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--arrival-every", type=int, default=2,
                    help="submit one request every N ticks")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    eng = ServeEngine(cfg, batch_slots=args.slots, window=args.window,
                      lm_kwargs=dict(opts=AttnOptions(backend="naive"),
                                     remat=False))
    rng = np.random.default_rng(0)
    submitted = 0
    tick_budget = args.requests * args.arrival_every + args.requests * (
        args.max_new + 4)
    for t in range(tick_budget):
        if submitted < args.requests and t % args.arrival_every == 0:
            eng.submit(Request(
                rid=submitted, max_new=args.max_new,
                prompt=rng.integers(0, cfg.vocab_size,
                                    size=args.prompt_len).astype(np.int32)))
            submitted += 1
        eng.step()
        if len(eng.done) >= args.requests:
            break

    s = eng.stats()
    rtts = sorted(r.rtt for r in eng.done if r.rtt is not None)
    p50 = rtts[len(rtts) // 2] if rtts else 0
    p99 = rtts[min(len(rtts) - 1, int(len(rtts) * 0.99))] if rtts else 0
    print(f"served {int(s['completed'])}/{args.requests} requests "
          f"({int(s['tokens'])} tokens) in {eng.tick} ticks")
    print(f"throughput {s['tokens_per_tick']:.2f} tok/tick; "
          f"RTT p50={p50} p99={p99} ticks")
    print(f"C3 counters: mem.rtt={float(eng.counters['mem']['rtt']):.0f} "
          f"io.exec={float(eng.counters['io']['exec_time']):.0f}")


if __name__ == "__main__":
    main()
