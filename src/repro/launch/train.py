"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs a real end-to-end training job on the available devices (CPU-sized
reduced configs by default; pass --full to use the published config, which
is only practical on a real pod).  Wires the whole Vespa loop: data
pipeline -> jitted step -> monitor -> DFS actuator -> async checkpoints ->
fault supervisor.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.base import ShapeConfig
from repro.models.layers import AttnOptions
from repro.optim import adamw
from repro.runtime.fault import FaultSupervisor
from repro.runtime.train import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b",
                    choices=ASSIGNED_ARCHS)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/vespa_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (pod-scale!)")
    ap.add_argument("--mesh", default="none",
                    help="'none' (local) or 'host' (all local devices as DP)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    mesh = None
    if args.mesh == "host":
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()

    shape = ShapeConfig("cli", args.seq_len, args.batch, "train")
    tc = TrainConfig(log_every=10, ckpt_every=args.ckpt_every,
                     ckpt_dir=args.ckpt_dir, monitor_every=10,
                     opt=adamw.AdamWConfig(lr=args.lr, warmup_steps=10,
                                           total_steps=args.steps))
    tr = Trainer(cfg, shape, mesh=mesh, tc=tc,
                 lm_kwargs=dict(opts=AttnOptions(backend="chunked",
                                                 q_block=64, kv_block=64),
                                remat=True))
    sup = FaultSupervisor(tr)
    if args.resume and tr.store().latest_step() is not None:
        tr.restore()
        print(f"resumed from step {tr.step}")

    print(f"training {args.arch} ({cfg.n_params()/1e6:.1f}M params) "
          f"for {args.steps} steps on {len(jax.devices())} device(s)")
    sup.run_supervised(max(args.steps - tr.step, 0))
    tr.save(async_=False)
    print(tr.monitor.table())
    print(f"done at step {tr.step}; checkpoint in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
