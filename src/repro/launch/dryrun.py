import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init); everything else in this module assumes 512 host
placeholder devices standing in for 2 pods x 256 chips.

For each cell this produces, from the compiled artifact:
  * memory_analysis()      — proof the cell fits per-device HBM,
  * cost_analysis()        — HLO FLOPs / bytes for §Roofline,
  * collective wire bytes  — parsed from the partitioned HLO text
                             (all-reduce / all-gather / reduce-scatter /
                              all-to-all / collective-permute),
and writes one JSON per cell under experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
"""
import argparse
import json
import re
import time
import traceback
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config, shapes_for
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.replication import make_mra_mesh
from repro.core.tiles import default_plan
from repro.launch import specs as SP
from repro.compat import set_mesh
from repro.launch.mesh import make_production_mesh
from repro.models.layers import AttnOptions
from repro.models.params import abstract_params
from repro.models.transformer import LM
from repro.optim import adamw
from repro.runtime.train import TrainConfig, make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

from repro.launch.costing import (collective_stats, flops_of_jaxpr,
                                  hbm_bytes)

# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------


from dataclasses import dataclass, field as _field
from repro.models.params import set_batch_axes, get_batch_axes


@dataclass(frozen=True)
class CellOptions:
    """One §Perf design point for a cell.

    strategy: 'tp' (paper-faithful baseline: 16-way tensor parallel over
    the model axis), 'fsdp' (batch also sharded over the model axis ->
    GSPMD gathers weights per layer instead of all-reducing activations),
    'mra<K>' (Vespa C1: K-factored mesh, replicated tiles, stream split
    over the replica axis).
    """
    strategy: str = "tp"
    folded: bool = False           # folded-triangle causal schedule
    onehot_loss: bool = False      # vocab-parallel gold extraction
    grad_rs: bool = False          # bf16 grads + reduce-scatter to shards
    kv_int8: bool = False          # quantized decode cache (MLA)
    remat: bool = True
    accum: int = 1
    q_block: int = 512

    @property
    def ep(self) -> bool:
        return "ep" in re.split(r"[-_]", self.strategy)

    @property
    def mra_k(self) -> int:
        m = re.search(r"mra(\d+)", self.strategy)
        return int(m.group(1)) if m else 0

    @property
    def mra_attn_only(self) -> bool:
        return "attn" in self.strategy

    def tag(self) -> str:
        parts = [self.strategy]
        if self.folded:
            parts.append("folded")
        if self.onehot_loss:
            parts.append("vploss")
        if self.grad_rs:
            parts.append("gradrs")
        if self.kv_int8:
            parts.append("kvint8")
        if not self.remat:
            parts.append("noremat")
        if self.accum > 1:
            parts.append(f"acc{self.accum}")
        return "-".join(parts)


def build_lm(cfg: ArchConfig, co: CellOptions, mesh=None, plan=None) -> LM:
    opts = AttnOptions(backend="chunked", q_block=co.q_block,
                       kv_block=co.q_block, folded=co.folded)
    block_pspecs = None
    if co.grad_rs and mesh is not None:
        # per-layer use-site constraints: stacked specs minus the layer dim
        from jax.sharding import PartitionSpec as _P
        from repro.core.replication import merged_rules
        from repro.models.params import pspecs_for
        lm0 = LM(cfg, opts=opts, remat=co.remat)
        stacked = pspecs_for(lm0.param_specs(),
                             merged_rules(plan or default_plan(cfg), mesh),
                             mesh)["blocks"]
        block_pspecs = jax.tree_util.tree_map(
            lambda ps: _P(*tuple(ps)[1:]), stacked,
            is_leaf=lambda x: isinstance(x, _P))
    moe_axes = None
    if co.mra_k and co.mra_attn_only:
        moe_axes = ("replica", "shard")     # experts keep full 16-way TP
    kv_dtype = jnp.int8 if co.kv_int8 else None
    return LM(cfg, opts=opts, remat=co.remat, onehot_loss=co.onehot_loss,
              moe_ep=co.ep, moe_axes=moe_axes, kv_cache_dtype=kv_dtype,
              block_pspecs=block_pspecs)


def make_cell_mesh(co: CellOptions, multi_pod: bool):
    if co.mra_k:
        return make_mra_mesh(co.mra_k, multi_pod=multi_pod)
    return make_production_mesh(multi_pod=multi_pod)


def lower_cell(arch: str, shape_name: str, mesh, *,
               co: CellOptions = CellOptions()):
    """Returns (lowered, meta) for one cell on the given mesh."""
    cfg = get_config(arch)
    shape = shapes_for(cfg)[shape_name]
    plan = default_plan(cfg)
    if co.mra_k:
        kinds = (("attn", "shared_attn") if co.mra_attn_only
                 else ("attn", "ffn", "moe", "ssm", "shared_attn"))
        for t in plan.tiles:
            if t.kind in kinds:
                plan = plan.with_replication(t.name, co.mra_k)
    lm = build_lm(cfg, co, mesh=mesh, plan=plan)
    rules_override = {"experts": "model", "expert_ff": None} if co.ep else None
    param_sh = SP.param_shardings(lm, mesh, plan, rules_override)
    params_abs = abstract_params(lm.param_specs())

    extra = ("model",) if "fsdp" in re.split(r"[-_]", co.strategy) else ()
    prev_axes = get_batch_axes()
    batch_axes = tuple(a for a in ("pod", "data", "replica") + extra
                       if a in mesh.axis_names)
    set_batch_axes(batch_axes)
    try:
        if shape.kind == "train":
            tc = TrainConfig(accum=co.accum,
                             grad_reduce_dtype="bf16" if co.grad_rs else "")
            gps = None
            if co.grad_rs:
                from repro.core.replication import merged_rules
                from repro.models.params import pspecs_for
                gps = pspecs_for(lm.param_specs(),
                                 merged_rules(plan, mesh), mesh)
            step = make_train_step(lm, plan, mesh, tc, grad_pspecs=gps)
            opt_abs = SP.abstract_opt_state(params_abs)
            batch_abs = SP.abstract_batch(cfg, shape)
            ctr_abs = SP.abstract_counters(plan)
            in_sh = (param_sh, SP.opt_shardings(param_sh, mesh),
                     SP.batch_shardings(batch_abs, mesh, extra),
                     SP.counter_shardings(ctr_abs, mesh))
            fn = jax.jit(step, in_shardings=in_sh, donate_argnums=(0, 1, 3))
            with set_mesh(mesh):
                lowered = fn.lower(params_abs, opt_abs, batch_abs, ctr_abs)
        elif shape.kind == "prefill":
            tok_abs = SP.abstract_prefill_tokens(shape)
            fn = jax.jit(lambda p, t: lm.prefill(p, tokens=t),
                         in_shardings=(param_sh,
                                       SP.batch_shardings(tok_abs, mesh,
                                                          extra)))
            with set_mesh(mesh):
                lowered = fn.lower(params_abs, tok_abs)
        else:  # decode
            cache_abs, tok_abs = SP.abstract_decode_inputs(lm, shape)
            cache_sh = SP.cache_shardings(lm, cache_abs, mesh)
            fn = jax.jit(lambda p, c, t: lm.decode_step(p, c, tokens=t),
                         in_shardings=(param_sh, cache_sh,
                                       SP.batch_shardings(tok_abs, mesh)),
                         donate_argnums=(1,))
            with set_mesh(mesh):
                lowered = fn.lower(params_abs, cache_abs, tok_abs)

        meta = {"arch": arch, "shape": shape_name, "kind": shape.kind,
                "mesh": dict(mesh.shape), "n_params": cfg.n_params(),
                "n_active_params": cfg.n_active_params(),
                "strategy": co.tag(),
                "tokens": shape.global_batch * (shape.seq_len
                                                if shape.kind != "decode"
                                                else 1)}
        # scan-aware total FLOPs from the jaxpr (cost_analysis counts loop
        # bodies once — see launch/costing.py) + analytic HBM traffic
        meta["jaxpr_flops_total"] = _jaxpr_flops_for(lm, plan, cfg, shape,
                                                     accum=co.accum)
        meta["hbm_bytes_total"] = hbm_bytes(cfg, shape,
                                            mra_k=max(co.mra_k, 1),
                                            kv_int8=co.kv_int8)
        meta["mra_k"] = max(co.mra_k, 1)
    finally:
        set_batch_axes(prev_axes)
    return lowered, meta


def _jaxpr_flops_for(lm, plan, cfg, shape, *, accum: int = 1) -> float:
    """Trace the same step abstractly (no mesh needed) and count FLOPs."""
    import dataclasses as _dc
    lm = _dc.replace(lm, block_pspecs=None)    # constraints need a mesh
    params_abs = abstract_params(lm.param_specs())
    if shape.kind == "train":
        step = make_train_step(lm, plan, None, TrainConfig(accum=accum))
        args = (params_abs, SP.abstract_opt_state(params_abs),
                SP.abstract_batch(cfg, shape),
                SP.abstract_counters(default_plan(cfg)))
        jx = jax.make_jaxpr(step)(*args)
    elif shape.kind == "prefill":
        jx = jax.make_jaxpr(lambda p, t: lm.prefill(p, tokens=t))(
            params_abs, SP.abstract_prefill_tokens(shape))
    else:
        cache_abs, tok_abs = SP.abstract_decode_inputs(lm, shape)
        jx = jax.make_jaxpr(lambda p, c, t: lm.decode_step(p, c, tokens=t))(
            params_abs, cache_abs, tok_abs)
    return flops_of_jaxpr(jx.jaxpr)


def analyze(lowered, meta, *, parse_collectives: bool = True) -> Dict[str, Any]:
    t0 = time.monotonic()
    compiled = lowered.compile()
    compile_s = time.monotonic() - t0
    out = dict(meta)
    out["compile_seconds"] = round(compile_s, 2)
    chips = int(np.prod(list(meta["mesh"].values())))
    out["chips"] = chips

    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        # NOTE body-once: XLA counts while-loop bodies a single time, so
        # these two under-report for scan-over-layers models; the roofline
        # uses jaxpr_flops_total / hbm_bytes_total instead (costing.py).
        out["hlo_flops_per_device_bodyonce"] = float(ca.get("flops", 0.0))
        out["hlo_bytes_per_device_bodyonce"] = float(
            ca.get("bytes accessed", 0.0))
    except Exception as e:                              # pragma: no cover
        out["cost_analysis_error"] = repr(e)

    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                v = getattr(ma, k, None)
                if v is not None:
                    out[k] = int(v)
    except Exception as e:                              # pragma: no cover
        out["memory_analysis_error"] = repr(e)

    if parse_collectives:
        try:
            txt = compiled.as_text()
            out.update(collective_stats(txt, default_group=chips))
            out["hlo_chars"] = len(txt)
        except Exception as e:                          # pragma: no cover
            out["collective_parse_error"] = repr(e)
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             co: CellOptions = CellOptions(),
             save: bool = True) -> Dict[str, Any]:
    mesh = make_cell_mesh(co, multi_pod)
    t0 = time.monotonic()
    lowered, meta = lower_cell(arch, shape_name, mesh, co=co)
    meta["lower_seconds"] = round(time.monotonic() - t0, 2)
    meta["multi_pod"] = multi_pod
    meta["folded"] = co.folded
    res = analyze(lowered, meta)
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        tag = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
        if co.tag() != "tp":
            tag += "__" + co.tag()
        with open(os.path.join(OUT_DIR, tag + ".json"), "w") as f:
            json.dump(res, f, indent=1, sort_keys=True)
    return res


def iter_cells():
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape_name in shapes_for(cfg):
            yield arch, shape_name


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--folded", action="store_true")
    ap.add_argument("--onehot-loss", action="store_true")
    ap.add_argument("--strategy", default="tp")
    ap.add_argument("--grad-rs", action="store_true")
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    args = ap.parse_args()
    co = CellOptions(strategy=args.strategy, folded=args.folded,
                     onehot_loss=args.onehot_loss, grad_rs=args.grad_rs,
                     kv_int8=args.kv_int8,
                     remat=not args.no_remat, accum=args.accum)

    pods = []
    if args.multi_pod or not args.single_pod:
        pods.append(True)
    if args.single_pod or not args.multi_pod:
        pods.append(False)
    pods = sorted(set(pods))       # False (single) first

    cells = list(iter_cells()) if args.all else [(args.arch, args.shape)]
    failures = []
    for arch, shape_name in cells:
        for mp in pods:
            tag = f"{arch} x {shape_name} x {'2-pod(512)' if mp else '1-pod(256)'}"
            try:
                r = run_cell(arch, shape_name, multi_pod=mp, co=co)
                print(f"OK   {tag}: compile={r['compile_seconds']}s "
                      f"flops={r.get('jaxpr_flops_total', 0):.3e} "
                      f"coll={r.get('collective_bytes', 0):.3e}B", flush=True)
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"FAIL {tag}: {e!r}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES")
        raise SystemExit(1)
    print("\nALL CELLS PASSED")


if __name__ == "__main__":
    main()
