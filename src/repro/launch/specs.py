"""Abstract input/state specs + shardings for every dry-run cell.

``input_specs(arch, shape)`` returns ShapeDtypeStruct stand-ins for every
input of the cell's step function — weak-type-correct, shardable, zero
allocation.  ``cell_shardings`` mirrors each tree with NamedShardings.

All cells feed discrete tokens: the [vlm]/[audio] archs (chameleon,
musicgen) are early-fusion models over VQ/EnCodec *tokens*, so the modality
frontend stub is exactly "tokens arrive from an external tokenizer"
(DESIGN.md §Arch-applicability; the continuous-``embeds`` path exists in
the LM API and is exercised by unit tests).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.replication import data_axes, merged_rules
from repro.core.tiles import TilePlan, default_plan
from repro.models.params import abstract_params, pspecs_for
from repro.models.transformer import LM
from repro.optim import adamw

SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# Abstract trees
# ---------------------------------------------------------------------------


def abstract_opt_state(params_abs):
    f32 = lambda p: SDS(p.shape, jnp.float32)
    return adamw.AdamWState(step=SDS((), jnp.int32),
                            mu=jax.tree_util.tree_map(f32, params_abs),
                            nu=jax.tree_util.tree_map(f32, params_abs))


def abstract_batch(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    return {"tokens": SDS((B, S), jnp.int32),
            "labels": SDS((B, S), jnp.int32)}


def abstract_decode_inputs(lm: LM, shape: ShapeConfig):
    """(cache, tokens) for one serve_step against a seq_len context."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: lm.init_cache(B, S))
    tokens = SDS((B, 1), jnp.int32)
    return cache, tokens


def abstract_prefill_tokens(shape: ShapeConfig):
    return SDS((shape.global_batch, shape.seq_len), jnp.int32)


def abstract_counters(plan: TilePlan):
    from repro.core.monitor import init_counters
    return jax.eval_shape(lambda: init_counters(plan))


# ---------------------------------------------------------------------------
# Shardings
# ---------------------------------------------------------------------------


def _dp(mesh: Mesh, extra: Tuple[str, ...] = ()) -> Tuple[str, ...]:
    """Batch axes: (pod, data) [+ replica on an MRA mesh: the AXI bridge
    splits the stream across tile replicas] [+ any strategy extras]."""
    base = ("pod", "data", "replica") + tuple(extra)
    return tuple(a for a in base if a in mesh.axis_names)


def _model_axis(mesh: Mesh):
    """Axis for model-dim sharding of activations/caches.  On an MRA mesh
    'replica' carries the batch stream (AXI bridge), so only 'shard' is
    available for the model dims."""
    names = mesh.axis_names
    if "model" in names:
        return "model"
    if "shard" in names:           # MRA-factored mesh
        return "shard"
    return None


def _axsize(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        return n
    return mesh.shape[ax]


def batch_shardings(batch_abs, mesh: Mesh, extra: Tuple[str, ...] = ()):
    dp = _dp(mesh, extra)

    def one(v):
        if getattr(v, "ndim", 0) < 1:
            return NamedSharding(mesh, P())
        # drop trailing axes until the batch dim divides (e.g. multi-pod
        # FSDP with global_batch < chips falls back to DP(pod,data) + TP)
        axes = list(dp)
        while axes:
            sz = int(np.prod([mesh.shape[a] for a in axes]))
            if v.shape[0] % sz == 0:
                return NamedSharding(mesh, P(tuple(axes)))
            axes.pop()
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map(one, batch_abs)


def cache_shardings(lm: LM, cache_abs, mesh: Mesh):
    """Explicit shardings mirroring LM.init_cache structure.

    Policy: batch over (pod,data) when divisible; the KV window (sequence)
    axis over model (sequence-parallel decode attention — flash-decoding's
    layout); SSM state heads over model.
    """
    cfg = lm.cfg
    dp = _dp(mesh)
    dp_sz = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    mdl = _model_axis(mesh)
    m_sz = _axsize(mesh, mdl)

    def attn_cache_spec(a, stacked_axes: int):
        # (*stack, B, W, *tail)
        b_ax, w_ax = stacked_axes, stacked_axes + 1
        ent = [None] * a.ndim
        if dp and a.shape[b_ax] % dp_sz == 0 and a.shape[b_ax] > 1:
            ent[b_ax] = dp
        if mdl and a.shape[w_ax] % m_sz == 0:
            ent[w_ax] = mdl
        return NamedSharding(mesh, P(*ent))

    def ssm_cache_spec(a, key: str):
        # conv_*: (L,B,c-1,ch)   state: (L,B,nh,st,hd)
        ent = [None] * a.ndim
        if dp and a.shape[1] % dp_sz == 0 and a.shape[1] > 1:
            ent[1] = dp
        if key == "state":
            if mdl and a.shape[2] % m_sz == 0:
                ent[2] = mdl
        else:
            if mdl and a.shape[-1] % m_sz == 0:
                ent[-1] = mdl
        return NamedSharding(mesh, P(*ent))

    out: Dict[str, Any] = {}
    for k, v in cache_abs.items():
        if k == "pos":
            out[k] = NamedSharding(mesh, P())
        elif k == "prelude":
            out[k] = [tuple(attn_cache_spec(a, 0) for a in pair) for pair in v]
        elif k == "shared_attn":
            out[k] = jax.tree_util.tree_map(
                lambda a: attn_cache_spec(a, 1), v)
        elif k == "blocks":
            if cfg.family in ("ssm", "hybrid"):
                out[k] = {kk: ssm_cache_spec(a, kk) for kk, a in v.items()}
            else:
                out[k] = tuple(attn_cache_spec(a, 1) for a in v)
        else:                                            # pragma: no cover
            out[k] = jax.tree_util.tree_map(
                lambda a: NamedSharding(mesh, P()), v)
    return out


def param_shardings(lm: LM, mesh: Mesh, plan: Optional[TilePlan] = None,
                    rules_override: Optional[Dict] = None):
    plan = plan or default_plan(lm.cfg)
    rules = merged_rules(plan, mesh)
    if rules_override:
        rules.update(rules_override)
    specs = lm.param_specs()
    pspecs = pspecs_for(specs, rules, mesh)
    return jax.tree_util.tree_map(
        lambda ps: NamedSharding(mesh, ps), pspecs,
        is_leaf=lambda x: isinstance(x, P))


def opt_shardings(param_sh, mesh: Mesh):
    return adamw.AdamWState(step=NamedSharding(mesh, P()),
                            mu=param_sh, nu=param_sh)


def counter_shardings(counters_abs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda a: NamedSharding(mesh, P()), counters_abs)
