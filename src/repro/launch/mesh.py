"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
must set XLA_FLAGS before any jax initialization.

Baseline production meshes (the assignment's):
  single-pod: (data=16, model=16)           = 256 chips (one v5e pod)
  multi-pod : (pod=2, data=16, model=16)    = 512 chips

MRA-factored meshes (paper C1; same devices, model axis split K-ways) live
in core/replication.make_mra_mesh.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist right now, as a 1D (data,) mesh — for local
    examples and tests that want a real (non-dry-run) mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
