"""jit'd public wrappers for the Pallas kernels.

Forward runs the kernel; backward (where models train through these ops)
falls back to the autodiff of the pure-jnp oracle via ``jax.custom_vjp`` —
correct gradients today, swap in hand-written backward kernels without
touching call sites.

``interpret`` defaults to True because this container is CPU-only; a TPU
deployment flips `INTERPRET` (or passes interpret=False) and the same
BlockSpecs compile to Mosaic.
"""
from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref as REF
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.fused_mlp import fused_rmsnorm_mlp_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

INTERPRET = True      # CPU container: validate kernels in interpret mode


# ----------------------------------------------------------------- attention
@partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def flash_attention(q, k, v, qpos, kpos, window: int = 0,
                    scale: float = 1.0):
    return flash_attention_pallas(q, k, v, qpos, kpos, scale=scale,
                                  window=window, interpret=INTERPRET)


def _fa_fwd(q, k, v, qpos, kpos, window, scale):
    out = flash_attention(q, k, v, qpos, kpos, window, scale)
    return out, (q, k, v, qpos, kpos)


def _fa_bwd(window, scale, res, g):
    q, k, v, qpos, kpos = res
    _, vjp = jax.vjp(
        lambda q, k, v: REF.flash_attention_ref(q, k, v, qpos, kpos,
                                                scale=scale, window=window),
        q, k, v)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, None, None


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# ----------------------------------------------------------------- SSD scan
@partial(jax.custom_vjp, nondiff_argnums=(6,))
def ssd_scan(xs, dt, A, Bm, Cm, D, chunk: int = 256):
    return ssd_scan_pallas(xs, dt, A, Bm, Cm, D, chunk=chunk,
                           interpret=INTERPRET)


def _ssd_fwd(xs, dt, A, Bm, Cm, D, chunk):
    out = ssd_scan(xs, dt, A, Bm, Cm, D, chunk)
    return out, (xs, dt, A, Bm, Cm, D)


def _ssd_bwd(chunk, res, g):
    xs, dt, A, Bm, Cm, D = res
    _, vjp = jax.vjp(
        lambda *a: REF.ssd_scan_ref(*a, chunk=chunk), xs, dt, A, Bm, Cm, D)
    return vjp(g)


ssd_scan.defvjp(_ssd_fwd, _ssd_bwd)


# ----------------------------------------------------------------- fused MLP
@partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def fused_rmsnorm_mlp(x, scale, wg, wu, act: str = "silu",
                      eps: float = 1e-5):
    return fused_rmsnorm_mlp_pallas(x, scale, wg, wu, act=act, eps=eps,
                                    interpret=INTERPRET)


def _fm_fwd(x, scale, wg, wu, act, eps):
    return fused_rmsnorm_mlp(x, scale, wg, wu, act, eps), (x, scale, wg, wu)


def _fm_bwd(act, eps, res, g):
    x, scale, wg, wu = res
    _, vjp = jax.vjp(
        lambda *a: REF.fused_rmsnorm_mlp_ref(*a, act=act, eps=eps),
        x, scale, wg, wu)
    return vjp(g)


fused_rmsnorm_mlp.defvjp(_fm_fwd, _fm_bwd)


# ---------------------------------------------------------------- decode
def flash_decode(q, cache_k, cache_v, qpos, kpos, window: int = 0,
                 scale: float = 1.0, kv_block: int = 512):
    """Split-KV decode attention (forward-only: serving path)."""
    return flash_decode_pallas(q, cache_k, cache_v, qpos, kpos, scale=scale,
                               window=window, kv_block=kv_block,
                               interpret=INTERPRET)
