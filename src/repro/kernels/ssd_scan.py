"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

TPU-native layout (DESIGN.md hardware-adaptation): the GPU SSD kernel
(Dao & Gu) uses warp-level scans; the TPU form is block matmuls + a
sequential chunk walk:

* grid = (batch, heads, chunks) with the chunk dim innermost; the running
  state h (st x hd) lives in VMEM scratch across chunk steps — HBM sees
  each token tile exactly once.
* the intra-chunk quadratic term is (Q x Q) x (Q x hd) MXU matmuls with
  Q = 128/256 (lane-aligned); decay matrices are built from within-chunk
  cumulative sums in f32.
* the inter-chunk recurrence h <- h * exp(sum log a) + S_c is elementwise
  in VMEM — the serialized fraction is O(st*hd) per chunk vs O(Q^2*hd)
  parallel work, i.e. MXU utilization grows with Q.

Outputs both y and the final state (prefill needs the state for the decode
cache).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, y_ref, hout_ref,
                h_ref, *, nc: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)       # (Q, hd)
    dt = dt_ref[0, :, 0].astype(jnp.float32)        # (Q,)
    A = A_ref[0]                                    # scalar
    Bm = B_ref[0].astype(jnp.float32)               # (Q, st)
    Cm = C_ref[0].astype(jnp.float32)               # (Q, st)
    D = D_ref[0]

    log_a = dt * A                                  # (Q,) <= 0
    la = jnp.cumsum(log_a)                          # within-chunk
    la_last = la[-1]

    # intra-chunk: att[i,j] = (C_i . B_j) * exp(la_i - la_j) * dt_j, i >= j
    Q = x.shape[0]
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    diff = la[:, None] - la[None, :]
    causal = jnp.tril(jnp.ones((Q, Q), jnp.bool_))
    att = jnp.where(causal, scores * jnp.exp(diff) * dt[None, :], 0.0)
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state to each position
    h = h_ref[...]                                  # (st, hd) f32
    y += jnp.exp(la)[:, None] * jax.lax.dot_general(
        Cm, h, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    y_ref[0, :, 0, :] = (y + x * D).astype(y_ref.dtype)

    # state update: h <- h * exp(la_last) + sum_j w_j B_j x_j^T
    w = jnp.exp(la_last - la) * dt                  # (Q,)
    S_c = jax.lax.dot_general(Bm * w[:, None], x, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    h_ref[...] = h * jnp.exp(la_last) + S_c

    @pl.when(c == nc - 1)
    def _finish():
        hout_ref[0, 0] = h_ref[...].astype(hout_ref.dtype)


def ssd_scan_pallas(xs, dt, A, Bm, Cm, D, *, chunk: int = 256,
                    interpret: bool = True):
    """xs:(B,L,nh,hd) f32; dt:(B,L,nh) f32 (post-softplus); A:(nh,) f32;
    Bm/Cm:(B,L,st) f32 (g=1); D:(nh,).
    Returns (y:(B,L,nh,hd) f32, h_final:(B,nh,st,hd) f32)."""
    B, L, nh, hd = xs.shape
    st = Bm.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q

    kernel = functools.partial(_ssd_kernel, nc=nc)
    y, hout = pl.pallas_call(
        kernel,
        grid=(B, nh, nc),
        in_specs=[
            pl.BlockSpec((1, Q, 1, hd), lambda b, h, c: (b, c, h, 0)),  # x
            pl.BlockSpec((1, Q, 1), lambda b, h, c: (b, c, h)),         # dt
            pl.BlockSpec((1,), lambda b, h, c: (h,)),                   # A
            pl.BlockSpec((1, Q, st), lambda b, h, c: (b, c, 0)),        # B
            pl.BlockSpec((1, Q, st), lambda b, h, c: (b, c, 0)),        # C
            pl.BlockSpec((1,), lambda b, h, c: (h,)),                   # D
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, hd), lambda b, h, c: (b, c, h, 0)),  # y
            pl.BlockSpec((1, 1, st, hd), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, nh, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, nh, st, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((st, hd), jnp.float32)],
        interpret=interpret,
    )(xs, dt, A, Bm, Cm, D)
    return y, hout
