"""Pallas kernel for the fused batched co-sim tick.

The ``lax.scan`` backend in :mod:`repro.sim.batch` lowers each simulated
tick to a dozen separate XLA ops (queue update, link-contention einsum,
service, forward coupling, power integral, control) with the ``(B, A)``
state arrays round-tripping through HBM between them.  This kernel fuses
the whole tick into ONE Pallas body:

* grid = ``(nb, T)`` with the tick dim innermost — Pallas iterates the
  last grid dim sequentially, so the per-tile simulator state (queue,
  busy, rtt, rates, guard, policy state, accumulators) lives in VMEM
  scratch across all ``T`` steps of a design block and HBM sees each
  arrival tile exactly once (the flash-attention/ssd-scan block idiom).
* per-design constants (``base``, ``req``, ``k``, ``inc``...) stream in
  as ``(bB, ...)`` blocks indexed by the design-block grid dim; shared
  per-tick scalars (the control-cadence flag) ride a ``(T, 1)`` input.
* Pallas kernels cannot close over array constants ("captures constants
  ... pass them as inputs"), so every design-independent array — the
  tile→island one-hot, a vector flow demand, the forward coupling
  matrix, and the controller's island topology tables — travels through
  a replicated *extras* input group (full-shape blocks, zero index map).
* the control step is NOT reimplemented here: the caller passes the same
  ``control(rates, guard, pol_state, ctl_flag, obs)`` closure the scan
  backend uses (built by ``BatchSimEngine._jax_control``), with its
  topology constants injected back through the ``consts=`` kwarg — so
  the two fast backends share one control lowering and cannot drift.
  Guard and policy state are carried in float32 scratch and converted
  at the call boundary.

Scope matches ``backend="pallas"``: open-loop replay plus the full
controller family (membound / PID / guard / custom ``jax_step``
policies).  Faults, SLO drops, and the load balancer stay on the scan
backend.  Everything here computes in float32 (the scan backend's dtype
under jax's default x64-off config); differential tests compare against
both the scan backend (tight f32 tolerance) and the NumPy float64 engine
(looser tolerance).

CPU path: ``interpret=True`` (the default) runs the kernel through the
Pallas interpreter so the differential suite runs everywhere.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.perfmodel import P_DYN_W, P_STATIC_W, V_BASE, V_SLOPE

_N_IN_FIXED = 13   # arr, isctl, base, req, w, k, hop, tcr, inc, ftg,
#                    iotM, rates0, guard0


def _v2(f):
    v = V_BASE + V_SLOPE * f
    return v * v


def _tick_kernel(*refs, n_pol, n_extra, extra_keys, extra_bool,
                 pol_dtypes, control_fn, dt, own, tgd, link_bw, max_slow,
                 hop_lat, hop_share, hopf0, noc_share, n_tg, dyn_on,
                 max_q, ci, noc_idx, demand_scalar, has_fwd,
                 tech_on, t_ps, t_v0, t_v1):
    (arr_ref, isctl_ref, base_ref, req_ref, w_ref, k_ref, hop_ref,
     tcr_ref, inc_ref, ftg_ref, iotM_ref, rates0_ref,
     guard0_ref) = refs[:_N_IN_FIXED]
    pol0_refs = refs[_N_IN_FIXED:_N_IN_FIXED + n_pol]
    e = _N_IN_FIXED + n_pol
    extra_refs = refs[e:e + n_extra]
    o = e + n_extra
    (adm_ref, served_ref, queue_ref, busy_ref, rtt_ref, ratesf_ref,
     guardf_ref, dropped_ref, energy_ref, swaps_ref) = refs[o:o + 10]
    polf_refs = refs[o + 10:o + 10 + n_pol]
    s = o + 10 + n_pol
    (q_s, b_s, rt_s, ra_s, g_s, cb_s, dr_s, en_s, sw_s, fw_s) = \
        refs[s:s + 10]
    pol_s = refs[s + 10:s + 10 + n_pol]

    ex = {}
    for key, isb, ref in zip(extra_keys, extra_bool, extra_refs):
        v = ref[...]
        ex[key] = (v > 0.5) if isb else v
    demand = ex.pop("__demand", demand_scalar)
    fwd = ex.pop("__fwd", None)

    t = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(t == 0)
    def _init():
        q_s[...] = jnp.zeros_like(q_s)
        b_s[...] = jnp.zeros_like(b_s)
        rt_s[...] = jnp.zeros_like(rt_s)
        cb_s[...] = jnp.zeros_like(cb_s)
        dr_s[...] = jnp.zeros_like(dr_s)
        en_s[...] = jnp.zeros_like(en_s)
        sw_s[...] = jnp.zeros_like(sw_s)
        fw_s[...] = jnp.zeros_like(fw_s)
        ra_s[...] = rates0_ref[...]
        g_s[...] = guard0_ref[...]
        for p0_ref, p_s in zip(pol0_refs, pol_s):
            p_s[...] = p0_ref[...]

    rates = ra_s[...]                                       # (bB, I)
    f_tile = rates @ iotM_ref[...]                          # (bB, A)
    f_noc = (rates[:, noc_idx] if noc_idx >= 0
             else jnp.ones(rates.shape[0], rates.dtype))
    fa = jnp.maximum(f_tile, 1e-3)
    fn = jnp.maximum(f_noc, 1e-3)[:, None]
    w = w_ref[...]
    hopf = 1.0 + hop_share * hop_ref[...]
    load = own + tgd * ftg_ref[...] * n_tg
    slow = jnp.maximum(1.0, load / (link_bw * fn))
    t_comp = (1.0 - w) / (k_ref[...] * fa)
    t_wire = w * slow * hopf / fn
    t_ref = (1.0 - w) + w * max(1.0, own) * hopf0

    arr_eff = arr_ref[0]                                    # (bB, A)
    if has_fwd:
        arr_eff = arr_eff + fw_s[...]
    q = q_s[...] + arr_eff
    adm = arr_eff
    if max_q != float("inf"):
        over = jnp.maximum(q - max_q, 0.0)
        q = q - over
        adm = adm - over
        dr_s[...] += over.sum(axis=-1, keepdims=True)

    busy_prev = b_s[...]
    if dyn_on:
        inc = inc_ref[...]                                  # (bB, A, L)
        loads = jnp.einsum("ba,bal->bl", demand * busy_prev, inc)
        rho = (inc * loads[:, None, :]).max(axis=-1) / (link_bw * fn)
        r = jnp.minimum(rho, 0.999)
        dyn = jnp.minimum(1.0 + r / (2.0 * (1.0 - r)), max_slow)
    else:
        dyn = jnp.ones_like(q)
    cap = (base_ref[...] * t_ref / (t_comp + t_wire * dyn)
           / req_ref[...]) * dt
    served = jnp.minimum(q, cap)
    queue = q - served
    busy = served / cap
    rt_s[...] += hop_ref[...] * dyn * hop_lat
    if has_fwd:
        fw_s[...] = jnp.einsum("ba,aj->bj", served, fwd)

    fnr = f_noc[:, None]                # unclamped, as the scan backend
    if tech_on:
        # physical DVFS: three baked scalars, as the scan backend
        vt = t_v0 + t_v1 * f_tile
        tp = t_ps * (P_STATIC_W + P_DYN_W * f_tile * vt * vt * busy)
        vn = t_v0 + t_v1 * fnr
        noc_p = noc_share * (
            t_ps * (P_STATIC_W + P_DYN_W * fnr * vn * vn))
    else:
        tp = P_STATIC_W + P_DYN_W * f_tile * _v2(f_tile) * busy
        noc_p = noc_share * (P_STATIC_W + P_DYN_W * fnr * _v2(fnr))
    en_s[...] += (tp.sum(axis=-1, keepdims=True) + noc_p) * dt
    ctl_busy = cb_s[...] + busy

    ctl_flag = isctl_ref[0, 0] > 0.5
    if control_fn is not None:
        t_wire_now = t_wire * dyn
        obs = {"util": ctl_busy / max(ci, 1),
               "bound": t_wire_now / (tcr_ref[...] + t_wire_now),
               "qt": queue / jnp.maximum(cap, 1e-12)}
        guard_b = g_s[...] > 0.5
        pol_state = tuple(
            (p_s[...] > 0.5) if np.issubdtype(dtp, np.bool_)
            else p_s[...]
            for p_s, dtp in zip(pol_s, pol_dtypes))
        rates, guard_b, pol_state, committed = control_fn(
            rates, guard_b, pol_state, ctl_flag, obs, consts=ex)
        sw_s[...] += jnp.where(committed, 1.0, 0.0)[:, None]
        ra_s[...] = rates
        g_s[...] = guard_b.astype(g_s.dtype)
        for p_s, ps in zip(pol_s, pol_state):
            p_s[...] = ps.astype(p_s.dtype)
    ctl_busy = jnp.where(ctl_flag, jnp.zeros_like(ctl_busy), ctl_busy)

    q_s[...] = queue
    b_s[...] = busy
    cb_s[...] = ctl_busy
    adm_ref[0] = adm
    served_ref[0] = served

    @pl.when(t == nt - 1)
    def _finish():
        queue_ref[...] = q_s[...]
        busy_ref[...] = b_s[...]
        rtt_ref[...] = rt_s[...]
        ratesf_ref[...] = ra_s[...]
        guardf_ref[...] = g_s[...]
        dropped_ref[...] = dr_s[...]
        energy_ref[...] = en_s[...]
        swaps_ref[...] = sw_s[...]
        for pf_ref, p_s in zip(polf_refs, pol_s):
            pf_ref[...] = p_s[...]


def fused_tick_sim(arrivals, is_ctl, consts, scalars, init, *,
                   control_fn: Optional[Callable] = None,
                   control_consts=None,
                   block_b: Optional[int] = None,
                   interpret: bool = True):
    """Run ``T`` fused simulator ticks over a ``(T, B, A)`` arrival tensor.

    ``consts``: per-design arrays — ``base``/``req``/``w``/``k``/``hop``/
    ``tcr`` ``(B, A)``, ``inc`` ``(B, A, L)``, ``ftg`` ``(B, 1)``.
    ``scalars``: python-level model/config constants (baked into the
    kernel), including ``iot``/``noc_idx``/``demand``/``forward``.
    ``init``: ``rates``/``guard`` ``(B, I)`` plus a ``pol`` tuple of
    B-leading 2-D policy-state arrays.  ``control_consts``: the numpy
    topology tables the control lowering needs (re-injected through its
    ``consts=`` kwarg; required when ``control_fn`` is set).  Returns a
    dict of f32 outputs (``adm``/``served`` histories, final state,
    accumulators, evolved control state) sliced back to the true ``B``.
    """
    arrivals = np.asarray(arrivals, dtype=np.float32)
    T, B, A = arrivals.shape
    I = init["rates"].shape[1]
    bB = int(block_b) if block_b else min(B, 128)
    Bp = -(-B // bB) * bB
    pol0 = tuple(np.asarray(p) for p in init["pol"])
    pol_dtypes = tuple(p.dtype for p in pol0)
    for p in pol0:
        assert p.ndim == 2 and p.shape[0] == B, (
            "policy state arrays must be 2-D and B-leading; got "
            f"{p.shape}")

    def padded(a, axis=0):
        a = np.asarray(a, dtype=np.float32)
        if Bp == B:
            return a
        reps = [1] * a.ndim
        idx = [slice(None)] * a.ndim
        idx[axis] = slice(0, 1)
        reps[axis] = Bp - B
        return np.concatenate([a, np.tile(a[tuple(idx)], reps)],
                              axis=axis)

    iot = np.asarray(scalars["iot"])
    iotM = np.zeros((I, A), dtype=np.float32)               # island→tile
    iotM[iot, np.arange(A)] = 1.0

    # extras: design-independent arrays replicated to every block (Pallas
    # forbids captured array constants)
    extra_np = []                                           # (key, arr, bool)
    if np.ndim(scalars["demand"]) > 0:
        extra_np.append(("__demand",
                         np.asarray(scalars["demand"], np.float32), False))
    fwd = scalars.get("forward")
    if fwd is not None:
        extra_np.append(("__fwd", np.asarray(fwd, np.float32), False))
    if control_fn is not None:
        assert control_consts is not None, \
            "control_fn requires its topology tables (control_consts)"
        for key in sorted(control_consts):
            a = np.asarray(control_consts[key])
            extra_np.append((key, a.astype(np.float32),
                             np.issubdtype(a.dtype, np.bool_)))

    inputs = [
        padded(arrivals, axis=1),
        np.asarray(is_ctl, dtype=np.float32).reshape(T, 1),
        padded(consts["base"]), padded(consts["req"]),
        padded(consts["w"]), padded(consts["k"]),
        padded(consts["hop"]), padded(consts["tcr"]),
        padded(consts["inc"]), padded(consts["ftg"]),
        iotM,
        padded(init["rates"]), padded(init["guard"]),
    ] + [padded(p) for p in pol0] + [a for _, a, _ in extra_np]
    L = int(consts["inc"].shape[-1])
    nb = Bp // bB

    def blk(shape, imap):
        return pl.BlockSpec(shape, imap)

    def full_blk(a):
        nd = a.ndim
        return blk(a.shape, lambda b, t, nd=nd: (0,) * nd)

    in_specs = [
        blk((1, bB, A), lambda b, t: (t, b, 0)),        # arr
        blk((1, 1), lambda b, t: (t, 0)),               # isctl
    ] + [blk((bB, A), lambda b, t: (b, 0))] * 6 + [     # base..tcr
        blk((bB, A, L), lambda b, t: (b, 0, 0)),        # inc
        blk((bB, 1), lambda b, t: (b, 0)),              # ftg
        blk((I, A), lambda b, t: (0, 0)),               # iotM
        blk((bB, I), lambda b, t: (b, 0)),              # rates0
        blk((bB, I), lambda b, t: (b, 0)),              # guard0
    ] + [blk((bB, p.shape[1]), lambda b, t: (b, 0)) for p in pol0] \
      + [full_blk(a) for _, a, _ in extra_np]

    out_specs = [
        blk((1, bB, A), lambda b, t: (t, b, 0)),        # adm
        blk((1, bB, A), lambda b, t: (t, b, 0)),        # served
        blk((bB, A), lambda b, t: (b, 0)),              # queue
        blk((bB, A), lambda b, t: (b, 0)),              # busy
        blk((bB, A), lambda b, t: (b, 0)),              # rtt
        blk((bB, I), lambda b, t: (b, 0)),              # rates
        blk((bB, I), lambda b, t: (b, 0)),              # guard
        blk((bB, 1), lambda b, t: (b, 0)),              # dropped
        blk((bB, 1), lambda b, t: (b, 0)),              # energy
        blk((bB, 1), lambda b, t: (b, 0)),              # swaps
    ] + [blk((bB, p.shape[1]), lambda b, t: (b, 0)) for p in pol0]
    out_shape = [
        jax.ShapeDtypeStruct((T, Bp, A), jnp.float32),
        jax.ShapeDtypeStruct((T, Bp, A), jnp.float32),
    ] + [jax.ShapeDtypeStruct((Bp, A), jnp.float32)] * 3 + [
        jax.ShapeDtypeStruct((Bp, I), jnp.float32),
        jax.ShapeDtypeStruct((Bp, I), jnp.float32),
    ] + [jax.ShapeDtypeStruct((Bp, 1), jnp.float32)] * 3 + [
        jax.ShapeDtypeStruct((Bp, p.shape[1]), jnp.float32)
        for p in pol0]
    scratch = ([pltpu.VMEM((bB, A), jnp.float32)] * 3       # q, busy, rtt
               + [pltpu.VMEM((bB, I), jnp.float32)] * 2     # rates, guard
               + [pltpu.VMEM((bB, A), jnp.float32)]         # ctl_busy
               + [pltpu.VMEM((bB, 1), jnp.float32)] * 3     # dr, en, sw
               + [pltpu.VMEM((bB, A), jnp.float32)]         # fwd carry
               + [pltpu.VMEM((bB, p.shape[1]), jnp.float32)
                  for p in pol0])

    kernel = functools.partial(
        _tick_kernel, n_pol=len(pol0), n_extra=len(extra_np),
        extra_keys=tuple(k for k, _, _ in extra_np),
        extra_bool=tuple(bl for _, _, bl in extra_np),
        pol_dtypes=pol_dtypes, control_fn=control_fn,
        dt=float(scalars["dt"]), own=float(scalars["own"]),
        tgd=float(scalars["tgd"]), link_bw=float(scalars["link_bw"]),
        max_slow=float(scalars["max_slow"]),
        hop_lat=float(scalars["hop_lat"]),
        hop_share=float(scalars["hop_share"]),
        hopf0=float(scalars["hopf0"]),
        noc_share=float(scalars["noc_share"]),
        n_tg=float(scalars["n_tg"]), dyn_on=bool(scalars["dyn_on"]),
        max_q=float(scalars["max_q"]), ci=int(scalars["ci"]),
        noc_idx=int(scalars["noc_idx"]),
        demand_scalar=(float(scalars["demand"])
                       if np.ndim(scalars["demand"]) == 0 else None),
        has_fwd=fwd is not None,
        tech_on=bool(scalars.get("tech_on", False)),
        t_ps=float(scalars.get("t_ps", 1.0)),
        t_v0=float(scalars.get("t_v0", V_BASE)),
        t_v1=float(scalars.get("t_v1", V_SLOPE)))
    outs = pl.pallas_call(
        kernel, grid=(nb, T), in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, scratch_shapes=scratch,
        interpret=interpret)(*inputs)

    (adm, served, queue, busy, rtt, rates, guard, dropped, energy,
     swaps) = outs[:10]
    polF = tuple(
        (np.asarray(p)[:B] > 0.5) if np.issubdtype(dtp, np.bool_)
        else np.asarray(p)[:B].astype(dtp)
        for p, dtp in zip(outs[10:], pol_dtypes))
    return {
        "adm": np.asarray(adm)[:, :B],
        "served": np.asarray(served)[:, :B],
        "queue": np.asarray(queue)[:B],
        "busy": np.asarray(busy)[:B],
        "rtt": np.asarray(rtt)[:B],
        "rates": np.asarray(rates)[:B],
        "guard": np.asarray(guard)[:B] > 0.5,
        "dropped": np.asarray(dropped)[:B, 0],
        "energy": np.asarray(energy)[:B, 0],
        "swaps": np.asarray(swaps)[:B, 0],
        "pol": polF,
    }
