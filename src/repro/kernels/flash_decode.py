"""Pallas TPU flash-decode: split-KV single-token attention.

The decode cells are memory-bound on the KV sweep (§Roofline): one query
token attends a W-long cache.  Flash-decoding parallelizes the SWEEP:

* grid = (batch·kv_heads, kv_splits); each step streams one (KB, hd) cache
  tile HBM→VMEM exactly once and maintains online-softmax partials in VMEM
  scratch across splits — on TPU the grid's last dim iterates sequentially
  per core, so the scratch carry is free, and multiple (b, h) programs fill
  the cores.
* the G query heads of a kv group ride along in VREGs ((G, hd) q tile) —
  the cache tile is read once for all G heads (GQA's memory win realized).
* masking: positions beyond ``pos`` (unwritten ring slots) are dropped via
  the kpos tile, same contract as the prefill kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, scale: float, window: int,
                   nk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    qpos = qpos_ref[0]                        # scalar-ish (1,) i32
    kpos = kpos_ref[0]                        # (KB,) i32
    q = q_ref[0].astype(jnp.float32)          # (G, hd)
    k = k_ref[0].astype(jnp.float32)          # (KB, hd)
    v = v_ref[0].astype(jnp.float32)          # (KB, hd_v)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = kpos[None, :] <= qpos[0]           # (G, KB) broadcast
    if window:
        mask = mask & ((qpos[0] - kpos[None, :]) < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
    l_ref[:, 0] = l_ref[:, 0] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[:, 0] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_decode_pallas(q, cache_k, cache_v, qpos, kpos, *, scale: float,
                        window: int = 0, kv_block: int = 512,
                        interpret: bool = True):
    """q: (B,KV,G,hd); cache_k: (B,W,KV,hd); cache_v: (B,W,KV,hd_v);
    qpos: (B,) i32 current positions; kpos: (B,W) i32 absolute slot
    positions (future/unwritten slots must exceed qpos).
    Returns (B,KV,G,hd_v)."""
    B, KV, G, hd = q.shape
    W = cache_k.shape[1]
    hd_v = cache_v.shape[-1]
    KB = min(kv_block, W)
    assert W % KB == 0, (W, KB)
    nk = W // KB

    qf = q.reshape(B * KV, G, hd)
    kf = cache_k.transpose(0, 2, 1, 3).reshape(B * KV, W, hd)
    vf = cache_v.transpose(0, 2, 1, 3).reshape(B * KV, W, hd_v)
    qpe = jnp.repeat(qpos, KV).reshape(B * KV, 1)
    kpe = jnp.repeat(kpos[:, None, :], KV, 1).reshape(B * KV, W)

    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * KV, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda h, j: (h, 0)),          # qpos
            pl.BlockSpec((1, KB), lambda h, j: (h, j)),         # kpos
            pl.BlockSpec((1, G, hd), lambda h, j: (h, 0, 0)),   # q
            pl.BlockSpec((1, KB, hd), lambda h, j: (h, j, 0)),  # k tile
            pl.BlockSpec((1, KB, hd_v), lambda h, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd_v), lambda h, j: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, G, hd_v), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, hd_v), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qpe, kpe, qf, kf, vf)
    return out.reshape(B, KV, G, hd_v)
