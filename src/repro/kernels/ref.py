"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba2 as M


def flash_attention_ref(q, k, v, qpos, kpos, *, scale: float,
                        window: int = 0):
    """Same contract as kernels.flash_attention.flash_attention_pallas."""
    return L.attention_naive(q, k, v, qpos, kpos, window, scale)


def ssd_scan_ref(xs, dt, A, Bm, Cm, D, *, chunk: int = 256):
    """Same contract as kernels.ssd_scan.ssd_scan_pallas."""
    return M.ssd_scan_ref(xs, dt, A, Bm, Cm, D, chunk)


def fused_rmsnorm_mlp_ref(x, scale, wg, wu, *, act: str = "silu",
                          eps: float = 1e-5):
    xn = L.rms_norm(x, scale, eps)
    g = xn.astype(jnp.float32) @ wg.astype(jnp.float32)
    u = xn.astype(jnp.float32) @ wu.astype(jnp.float32)
    g = jax.nn.gelu(g, approximate=True) if act == "gelu" else jax.nn.silu(g)
    return (g * u).astype(x.dtype)


def flash_decode_ref(q, cache_k, cache_v, qpos, kpos, *, scale: float,
                     window: int = 0):
    """Oracle for the split-KV decode kernel via attention_naive."""
    B, KV, G, hd = q.shape
    q5 = q[:, None, :, :, :]                      # (B,1,KV,G,hd)
    qp = qpos[:, None]
    out = L.attention_naive(q5, cache_k, cache_v, qp, kpos, window, scale)
    return out[:, 0]                              # (B,KV,G,hd_v)
