"""Pallas TPU kernel: fused RMSNorm -> gated-MLP first half.

Computes ``h = act(rmsnorm(x) @ Wg) * (rmsnorm(x) @ Wu)`` in one pass:

* grid = (token_tiles, ff_tiles); each step loads one (TB, d) token tile
  and one (d, FB) slice of each weight — the normalized activations never
  round-trip to HBM between the norm and the two matmuls (on an unfused
  path that's 3x the activation traffic).
* the norm is recomputed per ff tile — O(TB·d) VPU work traded against
  O(TB·d) HBM writes + reads, a >10x win at the HBM/VPU speed ratio.
* both matmuls hit the MXU with d as the (128-aligned) contraction dim.

The down-projection (h @ Wo) stays outside: XLA already fuses it with the
residual add, and keeping it out keeps the kernel's VMEM footprint at
TB·d + 2·d·FB + TB·FB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fused_kernel(x_ref, scale_ref, wg_ref, wu_ref, o_ref, *, act: str,
                  eps: float):
    x = x_ref[...].astype(jnp.float32)                     # (TB, d)
    scale = scale_ref[...].astype(jnp.float32)             # (d,)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    xn = x * jax.lax.rsqrt(var + eps) * (1.0 + scale)[None, :]
    wg = wg_ref[...].astype(jnp.float32)                   # (d, FB)
    wu = wu_ref[...].astype(jnp.float32)
    g = jax.lax.dot_general(xn, wg, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    u = jax.lax.dot_general(xn, wu, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if act == "gelu":
        g = jax.nn.gelu(g, approximate=True)
    else:
        g = jax.nn.silu(g)
    o_ref[...] = (g * u).astype(o_ref.dtype)


def fused_rmsnorm_mlp_pallas(x, scale, wg, wu, *, act: str = "silu",
                             eps: float = 1e-5, token_block: int = 256,
                             ff_block: int = 512, interpret: bool = True):
    """x: (N, d); scale: (d,); wg/wu: (d, F).  Returns (N, F) = gated h."""
    N, d = x.shape
    F = wg.shape[-1]
    TB = min(token_block, N)
    FB = min(ff_block, F)
    assert N % TB == 0 and F % FB == 0, (N, TB, F, FB)

    kernel = functools.partial(_fused_kernel, act=act, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(N // TB, F // FB),
        in_specs=[
            pl.BlockSpec((TB, d), lambda t, f: (t, 0)),
            pl.BlockSpec((d,), lambda t, f: (0,)),
            pl.BlockSpec((d, FB), lambda t, f: (0, f)),
            pl.BlockSpec((d, FB), lambda t, f: (0, f)),
        ],
        out_specs=pl.BlockSpec((TB, FB), lambda t, f: (t, f)),
        out_shape=jax.ShapeDtypeStruct((N, F), x.dtype),
        interpret=interpret,
    )(x, scale, wg, wu)
