"""Pallas TPU flash attention (GQA + causal + sliding window + MLA dims).

TPU-native design (DESIGN.md hardware-adaptation):

* grid = (batch·q_heads, q_blocks, kv_blocks); the kv dimension iterates
  innermost so the online-softmax accumulators live in VMEM scratch across
  kv steps — the HBM→VMEM working set is one (QB,hd) q tile + one (KB,hd)
  k/v tile at a time.
* block shapes default to 512x512 tiles: QK^T runs on the MXU with
  lane-aligned (multiple-of-128) contraction dims; f32 accumulation.
* causal/SWA block skipping: fully-masked (q_blk, kv_blk) tiles are skipped
  with ``pl.when`` — the triangle costs ~half the rectangle, which is the
  same win the folded-XLA schedule gets, but without the select overhead.
* GQA: query head h reads kv head h // G via the k/v index_map — no KV
  duplication in HBM or VMEM.
* MLA: separate qk head_dim (192) and v head_dim (128) are supported.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, scale: float, window: int,
                  nk: int, causal_skip: bool):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    qpos = qpos_ref[0]                       # (QB,) i32
    kpos = kpos_ref[0]                       # (KB,) i32

    def body():
        q = q_ref[0].astype(jnp.float32)     # (QB, hd_qk)
        k = k_ref[0].astype(jnp.float32)     # (KB, hd_qk)
        v = v_ref[0].astype(jnp.float32)     # (KB, hd_v)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = kpos[None, :] <= qpos[:, None]
        if window:
            mask &= (qpos[:, None] - kpos[None, :]) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, 0]                 # (QB,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
        l_ref[:, 0] = l_ref[:, 0] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[:, 0] = m_new

    if causal_skip:
        # skip tiles with no live (q, kv) pair: entirely above the causal
        # diagonal, or entirely evicted by the sliding window
        pred = kpos[0] <= qpos[-1]
        if window:
            pred &= kpos[-1] > qpos[0] - window
        pl.when(pred)(body)
    else:
        body()

    @pl.when(kb == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, qpos, kpos, *, scale: float,
                           window: int = 0, q_block: int = 512,
                           kv_block: int = 512, causal_skip: bool = True,
                           interpret: bool = True):
    """q: (B,Sq,KV,G,hd_qk); k: (B,Sk,KV,hd_qk); v: (B,Sk,KV,hd_v);
    qpos: (B,Sq); kpos: (B,Sk) int32.  Returns (B,Sq,KV,G,hd_v).

    ``interpret=True`` validates on CPU; on a real TPU pass False.
    """
    B, Sq, KV, G, hd_qk = q.shape
    hd_v = v.shape[-1]
    Sk = k.shape[1]
    QB = min(q_block, Sq)
    KB = min(kv_block, Sk)
    assert Sq % QB == 0 and Sk % KB == 0, (Sq, QB, Sk, KB)
    nq, nk = Sq // QB, Sk // KB
    H = KV * G

    # fold heads: q (B*H, Sq, hd); k/v (B*KV, Sk, hd)
    qf = q.transpose(0, 2, 3, 1, 4).reshape(B * H, Sq, hd_qk)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd_qk)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd_v)

    kernel = functools.partial(_flash_kernel, scale=scale, window=window,
                               nk=nk, causal_skip=causal_skip)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, QB), lambda h, i, j: (h // H, i)),      # qpos
            pl.BlockSpec((1, KB), lambda h, i, j: (h // H, j)),      # kpos
            pl.BlockSpec((1, QB, hd_qk), lambda h, i, j: (h, i, 0)),  # q
            pl.BlockSpec((1, KB, hd_qk),
                         lambda h, i, j: (h // G, j, 0)),             # k
            pl.BlockSpec((1, KB, hd_v),
                         lambda h, i, j: (h // G, j, 0)),             # v
        ],
        out_specs=pl.BlockSpec((1, QB, hd_v), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd_v), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((QB, hd_v), jnp.float32),   # acc
            pltpu.VMEM((QB, 1), jnp.float32),      # m (2-D for TPU layout)
            pltpu.VMEM((QB, 1), jnp.float32),      # l
        ],
        interpret=interpret,
    )(qpos, kpos, qf, kf, vf)
    return out.reshape(B, KV, G, Sq, hd_v).transpose(0, 3, 1, 2, 4)
