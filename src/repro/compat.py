"""jax surface-API compatibility shims.

The baked-in toolchain pins jax 0.4.37, while parts of the codebase (and
its distributed tests) target the newer mesh/shard_map surface.  Every
version-sensitive call goes through this module so call sites stay on the
modern spelling and run unchanged on either version:

* :func:`set_mesh` — ambient-mesh context manager.  ``jax.set_mesh`` where
  it exists; on 0.4.x the :class:`~jax.sharding.Mesh` object itself is the
  context manager that installs the ambient mesh.
* :func:`get_abstract_mesh` — the ambient mesh (or ``None``).  New jax
  exposes ``jax.sharding.get_abstract_mesh``; 0.4.x keeps the ambient
  physical mesh in ``thread_resources``.
* :func:`shard_map` — accepts the new ``check_vma`` knob and translates it
  to 0.4.x's ``check_rep``.
* :func:`abstract_mesh` — ``AbstractMesh(axis_shapes, axis_names)`` on any
  version (0.4.x takes a tuple of (name, size) pairs instead).
"""
from __future__ import annotations

import inspect

import jax

try:  # jax>=0.6 moved shard_map to jax.shard_map
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover - jax<0.6
    from jax.experimental.shard_map import shard_map as _shard_map

_SM_PARAMS = inspect.signature(_shard_map).parameters


def set_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh            # Mesh is itself a context manager on 0.4.x


def get_abstract_mesh():
    """The ambient mesh, or ``None`` when no mesh context is active."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src import mesh as mesh_lib                # 0.4.x fallback
    env = getattr(mesh_lib, "thread_resources", None)
    if env is None:                                      # pragma: no cover
        return None
    physical = env.env.physical_mesh
    return None if physical.empty else physical


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kw):
    """``jax.shard_map`` with the modern signature on every version."""
    if "check_vma" in _SM_PARAMS:
        kw["check_vma"] = check_vma
    elif "check_rep" in _SM_PARAMS:
        kw["check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)


def abstract_mesh(axis_shapes, axis_names):
    """``jax.sharding.AbstractMesh`` with the modern two-argument form."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axis_shapes), tuple(axis_names))
    except TypeError:      # 0.4.x: one tuple of (name, size) pairs
        return AbstractMesh(tuple(zip(axis_names, axis_shapes)))
