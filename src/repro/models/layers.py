"""Core transformer layers: norms, RoPE, GQA/MQA/MLA attention, gated MLPs.

All functions are pure; parameters are dict pytrees built from
:mod:`repro.models.params` specs.  Attention exposes three backends:

* ``naive``   — full score matrix (small shapes, oracle for tests),
* ``chunked`` — lax.scan online-softmax flash (bounded memory, XLA-only),
* ``pallas``  — the Pallas flash kernel from :mod:`repro.kernels`.

The chunked backend has two schedules (paper-faithful baseline vs the
"folded-triangle" beyond-paper optimization that halves causal FLOPs) —
selected by ``AttnOptions.folded``; §Perf in EXPERIMENTS.md measures both.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.params import spec, shard_activation

DATA = ("pod", "data")     # batch sharding axes (filtered to the live mesh)
MODEL = "model"            # intra-tile model fabric ("shard" on MRA meshes)
MODEL_FULL = "__model_full__"   # full model fabric (K=1 tiles, e.g. vocab)

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rms_norm_spec(d: int):
    return spec((d,), ("norm",), init="zeros")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                         # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., None, :]                               # broadcast over heads
    cos = cos[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def mlp_spec(d: int, d_ff: int):
    return {
        "wi_gate": spec((d, d_ff), ("embed", "ff")),
        "wi_up": spec((d, d_ff), ("embed", "ff")),
        "wo": spec((d_ff, d), ("ff", "embed"), init="small"),
    }


def _act(x: jax.Array, kind: str) -> jax.Array:
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.silu(x)


def mlp_apply(p: Dict, x: jax.Array, act: str) -> jax.Array:
    gate = _act(x @ p["wi_gate"], act)
    h = gate * (x @ p["wi_up"])
    h = shard_activation(h, DATA, None, MODEL)
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# Attention options & masking
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttnOptions:
    backend: str = "chunked"     # naive | chunked | pallas
    q_block: int = 512
    kv_block: int = 512
    folded: bool = False         # folded-triangle causal schedule (beyond-paper)


def _window_mask(qpos: jax.Array, kpos: jax.Array, window: int) -> jax.Array:
    """Causal (+ optional sliding window) mask: (..., Sq, Sk) boolean."""
    m = kpos[..., None, :] <= qpos[..., :, None]
    if window:
        m &= (qpos[..., :, None] - kpos[..., None, :]) < window
    return m


# ---------------------------------------------------------------------------
# Score computation (GQA-aware)
# ---------------------------------------------------------------------------


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B,Sq,KV,G,hd), k: (B,Sk,KV,hd) -> (B,KV,G,Sq,Sk)."""
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                      preferred_element_type=jnp.float32)


def _gqa_out(w: jax.Array, v: jax.Array) -> jax.Array:
    """w: (B,KV,G,Sq,Sk), v: (B,Sk,KV,hd) -> (B,Sq,KV,G,hd)."""
    return jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))


def attention_naive(q, k, v, qpos, kpos, window: int, scale: float) -> jax.Array:
    """Oracle attention.  q:(B,Sq,KV,G,hd) k,v:(B,Sk,KV,hd)."""
    s = _gqa_scores(q, k) * scale
    mask = _window_mask(qpos, kpos, window)               # (B,Sq,Sk)
    s = jnp.where(mask[:, None, None, :, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return _gqa_out(w, v).astype(q.dtype)


def _online_block(carry, qb, kb, vb, mask, scale):
    """One online-softmax accumulation step.

    carry = (acc (B,KV,G,Tq,hd) f32, m (B,KV,G,Tq) f32, l (B,KV,G,Tq) f32)
    """
    acc, m, l = carry
    mb = mask[:, None, None, :, :]
    s = _gqa_scores(qb, kb) * scale                       # (B,KV,G,Tq,Tk) f32
    s = jnp.where(mb, s, -1e30)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    # zero fully-masked entries explicitly: exp(-1e30 - (-1e30)) == 1 trap
    p = jnp.where(mb, jnp.exp(s - m_new[..., None]), 0.0)
    corr = jnp.exp(jnp.minimum(m - m_new, 0.0))
    l = l * corr + jnp.sum(p, axis=-1)
    # accumulate in (B,KV,G,Tq,hd) layout (NOT attention_naive's output order)
    pv = jnp.einsum("bkgqs,bskh->bkgqh", p, vb.astype(jnp.float32))
    acc = acc * corr[..., None] + pv
    return (acc, m_new, l)


def attention_chunked(q, k, v, qpos, kpos, window: int, scale: float,
                      opts: AttnOptions) -> jax.Array:
    """Flash-style attention via lax.scan with online softmax.

    Baseline schedule: every (q-block, kv-block) rectangle is computed and
    masked (the paper-faithful analogue of a streaming accelerator that does
    not skip work).  Folded schedule (opts.folded): q-blocks are paired
    (i, T-1-i) so each scan step does exactly one useful block — causal FLOPs
    drop ~2x (beyond-paper optimization, §Perf).
    """
    B, Sq, KV, G, hd_q = q.shape
    hd = v.shape[-1]                      # accumulator dim (MLA: vh != qk hd)
    hd_k = k.shape[-1]
    Sk = k.shape[1]
    QB = min(opts.q_block, Sq)
    KB = min(opts.kv_block, Sk)
    nq, nk = Sq // QB, Sk // KB
    assert Sq % QB == 0 and Sk % KB == 0, (Sq, QB, Sk, KB)

    qr = q.reshape(B, nq, QB, KV, G, hd_q)
    kr = k.reshape(B, nk, KB, KV, hd_k)
    vr = v.reshape(B, nk, KB, KV, hd)
    qpr = qpos.reshape(B, nq, QB)
    kpr = kpos.reshape(B, nk, KB)

    def init_carry():
        return (jnp.zeros((B, KV, G, QB, hd), jnp.float32),
                jnp.full((B, KV, G, QB), -1e30, jnp.float32),
                jnp.zeros((B, KV, G, QB), jnp.float32))

    if not opts.folded:
        def q_step(_, qi):
            qb, qp = qi

            def kv_step(carry, ki):
                kb, vb, kp = ki
                mask = _window_mask(qp, kp, window)
                return _online_block(carry, qb, kb, vb, mask, scale), None

            (acc, m, l), _ = jax.lax.scan(
                kv_step, init_carry(),
                (kr.swapaxes(0, 1), vr.swapaxes(0, 1), kpr.swapaxes(0, 1)))
            out = (acc / jnp.maximum(l[..., None], 1e-30))
            return None, out

        _, outs = jax.lax.scan(q_step, None,
                               (qr.swapaxes(0, 1), qpr.swapaxes(0, 1)))
        # outs: (nq, B, KV, G, QB, hd) -> (B, Sq, KV, G, hd)
        out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, KV, G, hd)
        return out.astype(q.dtype)

    # ---- folded-triangle schedule (requires pure causal, Sq == Sk grid) ----
    assert nq == nk and nq % 2 == 0, "folded schedule needs even block grid"
    half = nq // 2

    def pair_step(_, pi):
        i = pi                                   # low index; high = nq-1-i
        qlo = jax.lax.dynamic_index_in_dim(qr, i, 1, keepdims=False)
        qhi = jax.lax.dynamic_index_in_dim(qr, nq - 1 - i, 1, keepdims=False)
        plo = jax.lax.dynamic_index_in_dim(qpr, i, 1, keepdims=False)
        phi = jax.lax.dynamic_index_in_dim(qpr, nq - 1 - i, 1, keepdims=False)

        def kv_step(carry, j):
            (clo, chi) = carry
            # low q-block consumes kv blocks 0..i (i+1 of them);
            # high q-block consumes kv blocks 0..nq-1-i.  Step j in
            # 0..nq serves low while j<=i else high at kv index j-(i+1)... —
            # simpler equivalent: steps 0..i -> low@j ; steps i+1..nq -> high@(j-?)
            serve_low = j <= i
            kv_idx = jnp.where(serve_low, j, j - (i + 1))
            kb = jax.lax.dynamic_index_in_dim(kr, kv_idx, 1, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vr, kv_idx, 1, keepdims=False)
            kp = jax.lax.dynamic_index_in_dim(kpr, kv_idx, 1, keepdims=False)
            qb = jnp.where(serve_low, qlo, qhi)
            qp = jnp.where(serve_low, plo, phi)
            mask = _window_mask(qp, kp, window)
            merged = jax.tree_util.tree_map(
                lambda a, b: jnp.where(serve_low, a, b), clo, chi)
            merged = _online_block(merged, qb, kb, vb, mask, scale)
            clo = jax.tree_util.tree_map(
                lambda a, b: jnp.where(serve_low, b, a), clo, merged)
            chi = jax.tree_util.tree_map(
                lambda a, b: jnp.where(serve_low, a, b), chi, merged)
            return (clo, chi), None

        n_steps = nq + 1
        (clo, chi), _ = jax.lax.scan(kv_step, (init_carry(), init_carry()),
                                     jnp.arange(n_steps))
        olo = clo[0] / jnp.maximum(clo[2][..., None], 1e-30)
        ohi = chi[0] / jnp.maximum(chi[2][..., None], 1e-30)
        return None, (olo, ohi)

    _, (olos, ohis) = jax.lax.scan(pair_step, None, jnp.arange(half))
    # olos: (half, B, KV, G, QB, hd) for q-blocks 0..half-1
    # ohis: (half, B, KV, G, QB, hd) for q-blocks nq-1..half (descending)
    outs = jnp.concatenate([olos, ohis[::-1]], axis=0)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, KV, G, hd)
    return out.astype(q.dtype)


def attention_core(q, k, v, qpos, kpos, window: int, opts: AttnOptions,
                   scale: Optional[float] = None) -> jax.Array:
    """Dispatch over attention backends.  Shapes as in attention_naive."""
    hd = q.shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    if opts.backend == "pallas":
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, qpos, kpos, window=window,
                                    scale=scale)
    if opts.backend == "chunked" and q.shape[1] > opts.q_block:
        return attention_chunked(q, k, v, qpos, kpos, window, scale, opts)
    return attention_naive(q, k, v, qpos, kpos, window, scale)


# ---------------------------------------------------------------------------
# GQA attention block (projections + rope + cache)
# ---------------------------------------------------------------------------


def gqa_spec(cfg: ArchConfig):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": spec((d, H * hd), ("embed", "qkv")),
        "wk": spec((d, KV * hd), ("embed", "kv")),
        "wv": spec((d, KV * hd), ("embed", "kv")),
        "wo": spec((H * hd, d), ("qkv", "embed"), init="small"),
    }


def gqa_project(p: Dict, cfg: ArchConfig, x: jax.Array, positions: jax.Array):
    """Project to rotated q,k and v.  x: (B,S,d) -> q:(B,S,KV,G,hd), k/v:(B,S,KV,hd)."""
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // KV
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, KV, hd)
    v = (x @ p["wv"]).reshape(B, S, KV, hd)
    q = apply_rope(q, positions, cfg.rope_theta).reshape(B, S, KV, G, hd)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_apply(p: Dict, cfg: ArchConfig, x: jax.Array, positions: jax.Array,
              opts: AttnOptions, return_cache: bool = False):
    """Full-sequence (train/prefill) GQA attention."""
    B, S, _ = x.shape
    q, k, v = gqa_project(p, cfg, x, positions)
    q = shard_activation(q, DATA, None, MODEL)
    k = shard_activation(k, DATA, None, MODEL)
    v = shard_activation(v, DATA, None, MODEL)
    out = attention_core(q, k, v, positions, positions, cfg.sliding_window, opts)
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    out = out @ p["wo"]
    if return_cache:
        return out, (k, v)
    return out


def gqa_decode(p: Dict, cfg: ArchConfig, x: jax.Array, cache_k: jax.Array,
               cache_v: jax.Array, pos: jax.Array,
               opts: AttnOptions) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token decode with (ring-buffered when SWA) KV cache.

    x: (B,1,d); cache_k/v: (B,W,KV,hd); pos: scalar int32 current position.
    Returns (out (B,1,d), new_cache_k, new_cache_v).
    """
    B = x.shape[0]
    KV, hd, H = cfg.n_kv_heads, cfg.head_dim, cfg.n_heads
    W = cache_k.shape[1]
    positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
    q, k, v = gqa_project(p, cfg, x, positions)
    slot = (pos % W).astype(jnp.int32)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot, axis=1)
    # key positions for ring buffer: absolute position stored in each slot
    idx = jnp.arange(W, dtype=jnp.int32)
    wraps = (pos // W).astype(jnp.int32)
    kpos = jnp.where(idx <= slot, wraps * W + idx, (wraps - 1) * W + idx)
    # unwritten slots get a FUTURE position so the causal mask rejects them
    kpos = jnp.where(kpos >= 0, kpos, 1_000_000_000)
    kpos = jnp.broadcast_to(kpos[None, :], (B, W))
    window = cfg.sliding_window if cfg.sliding_window else 0
    out = attention_core(q, cache_k, cache_v, positions, kpos, window,
                         dataclasses.replace(opts, backend="naive"))
    out = out.reshape(B, 1, H * hd) @ p["wo"]
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------


def mla_spec(cfg: ArchConfig):
    d, H = cfg.d_model, cfg.n_heads
    r, rope, nope, vh = cfg.kv_lora_rank, cfg.qk_rope_dim, cfg.qk_nope_dim, cfg.v_head_dim
    return {
        "wq": spec((d, H * (nope + rope)), ("embed", "qkv")),
        "w_dkv": spec((d, r + rope), ("embed", "kv_lora")),
        "w_uk": spec((r, H * nope), ("kv_lora", "qkv")),
        "w_uv": spec((r, H * vh), ("kv_lora", "qkv")),
        "wo": spec((H * vh, d), ("qkv", "embed"), init="small"),
        "kv_norm": rms_norm_spec(r),
    }


def _mla_qc(p, cfg, x, positions):
    """Queries + compressed KV stream.  Returns q_nope,(B,S,H,nope) q_rope,
    ckv (B,S,r), k_rope (B,S,rope)."""
    B, S, _ = x.shape
    H = cfg.n_heads
    rope, nope, r = cfg.qk_rope_dim, cfg.qk_nope_dim, cfg.kv_lora_rank
    q = (x @ p["wq"]).reshape(B, S, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    dkv = x @ p["w_dkv"]                                   # (B,S,r+rope)
    ckv = rms_norm(dkv[..., :r], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(dkv[..., None, r:], positions, cfg.rope_theta)[..., 0, :]
    return q_nope, q_rope, ckv, k_rope


def mla_apply(p: Dict, cfg: ArchConfig, x: jax.Array, positions: jax.Array,
              opts: AttnOptions, return_cache: bool = False):
    """Full-sequence MLA (non-absorbed: expand K,V then plain MHA)."""
    B, S, _ = x.shape
    H = cfg.n_heads
    rope, nope, vh, r = cfg.qk_rope_dim, cfg.qk_nope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    q_nope, q_rope, ckv, k_rope = _mla_qc(p, cfg, x, positions)
    k_nope = (ckv @ p["w_uk"]).reshape(B, S, H, nope)
    v = (ckv @ p["w_uv"]).reshape(B, S, H, vh)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)         # (B,S,H,nope+rope)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, rope))],
                        axis=-1)
    # treat as MHA: KV=H, G=1; pad v to qk head_dim not needed (separate v dim)
    scale = 1.0 / np.sqrt(nope + rope)
    qq = q.reshape(B, S, H, 1, nope + rope)
    out = attention_core(qq, k, v, positions, positions, 0, opts, scale=scale)
    out = out.reshape(B, S, H * vh)
    out = out @ p["wo"]
    if return_cache:
        return out, (ckv, k_rope)     # compressed cache (B,S,r), (B,S,rope)
    return out


# int8 KV-cache quantization (symmetric, static scale): halves the decode
# memory sweep vs bf16 — §Perf cell-C lever.  The latent c_kv stream is
# RMS-normed (unit-ish scale), so a static range works; per-position scales
# would add a (B,W) f32 sidecar for ~0.1% extra bytes if needed.
KV_QUANT_RANGE = 8.0


def quant_kv(x: jax.Array) -> jax.Array:
    s = 127.0 / KV_QUANT_RANGE
    return jnp.clip(jnp.round(x.astype(jnp.float32) * s), -127, 127
                    ).astype(jnp.int8)


def dequant_kv(q: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * (KV_QUANT_RANGE / 127.0)


def mla_decode(p: Dict, cfg: ArchConfig, x: jax.Array, cache_ckv: jax.Array,
               cache_krope: jax.Array, pos: jax.Array,
               opts: AttnOptions) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Absorbed-matrix MLA decode over the *compressed* cache.

    cache_ckv: (B,W,r); cache_krope: (B,W,rope).  The up-projections are
    absorbed into the query/output so per-step attention runs in the latent
    space — the memory term reads r+rope (=576) per position instead of
    H*(nope+vh) (=4096): the KV-cache compression that makes decode_32k's
    memory roofline 7x smaller (§Roofline).
    """
    B = x.shape[0]
    H = cfg.n_heads
    rope, nope, vh, r = cfg.qk_rope_dim, cfg.qk_nope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    W = cache_ckv.shape[1]
    positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
    q_nope, q_rope, ckv, k_rope = _mla_qc(p, cfg, x, positions)
    slot = (pos % W).astype(jnp.int32)
    quantized = cache_ckv.dtype == jnp.int8
    if quantized:
        ckv_store, krope_store = quant_kv(ckv), quant_kv(k_rope)
    else:
        ckv_store = ckv.astype(cache_ckv.dtype)
        krope_store = k_rope.astype(cache_krope.dtype)
    cache_ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_ckv, ckv_store, slot, 1)
    cache_krope = jax.lax.dynamic_update_slice_in_dim(
        cache_krope, krope_store, slot, 1)
    ckv_read = dequant_kv(cache_ckv) if quantized \
        else cache_ckv.astype(jnp.float32)
    krope_read = dequant_kv(cache_krope) if quantized \
        else cache_krope.astype(jnp.float32)
    # absorb W_uk into q: (B,1,H,nope) x (r,H,nope) -> (B,1,H,r)
    w_uk = p["w_uk"].reshape(r, H, nope)
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scores = jnp.einsum("bqhr,bsr->bhqs", q_lat, ckv_read)
    scores += jnp.einsum("bqhe,bse->bhqs", q_rope.astype(jnp.float32),
                         krope_read)
    scores *= 1.0 / np.sqrt(nope + rope)
    idx = jnp.arange(W, dtype=jnp.int32)
    valid = idx[None, :] <= slot                           # no wrap: W == S_max
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    lat = jnp.einsum("bhqs,bsr->bqhr", w, ckv_read)
    w_uv = p["w_uv"].reshape(r, H, vh)
    out = jnp.einsum("bqhr,rhv->bqhv", lat, w_uv.astype(jnp.float32))
    out = out.reshape(B, 1, H * vh).astype(x.dtype) @ p["wo"]
    return out, cache_ckv, cache_krope
